"""Paper Fig. 12 — strong scaling of Q26 (1..8 fake host devices).

Each point runs in a subprocess with a different host-device count (the CPU
stand-in for nodes).  The paper's point: HiFrames keeps scaling where Spark's
master bottleneck inverts it; our analogue is that the compiled SPMD plan has
no coordinator — scaling is bounded only by the collectives.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from .common import report

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devs}"
import time
import numpy as np
import jax
from repro import hiframes as hf
from repro.data import synth

ss = synth.store_sales({rows}, 5000, 20000, seed=10)
it = synth.item(5000, seed=11)
store_sales, item = hf.table(ss, "ss"), hf.table(it, "it")
sale_items = hf.join(store_sales, item, on=("ss_item_sk", "i_item_sk"))
c_i = hf.aggregate(sale_items, "ss_customer_sk",
                   c_i_count=hf.count(),
                   id1=hf.sum_(sale_items["i_class_id"] == 1))
plan = c_i[c_i["c_i_count"] > 2].lower()
plan()   # warmup/compile
ts = []
for _ in range(3):
    t0 = time.perf_counter()
    t = plan()
    np.asarray(t.counts)
    ts.append(time.perf_counter() - t0)
print("US_PER_CALL", np.median(ts) * 1e6)
"""


def run(scale: float = 1.0, devices=(1, 2, 4, 8)):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    rows = int(200_000 * scale)
    base = None
    for d in devices:
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(root, "src")
        env.pop("XLA_FLAGS", None)
        res = subprocess.run(
            [sys.executable, "-c", _SCRIPT.format(devs=d, rows=rows)],
            env=env, capture_output=True, text=True, timeout=900)
        if res.returncode != 0:
            report(f"fig12_q26_scaling_p{d}", -1.0,
                   f"FAILED:{res.stderr.strip().splitlines()[-1][:80] if res.stderr else '?'}")
            continue
        us = float(res.stdout.split("US_PER_CALL")[1].strip().split()[0])
        if base is None:
            base = us
        report(f"fig12_q26_scaling_p{d}", us,
               f"speedup_vs_p1={base/us:.2f}x")

"""Paper Fig. 11 — TPCx-BB Q05 / Q25 / Q26 (relational stages).

Implemented on BigBench-like synthetic tables (data/synth.py).  Q05 uses a
Zipf-skewed join key — the paper's skew stress where hash partitioning load-
imbalances (Spark OOMs at SF>50; HiFrames at SF=400).  Our static-capacity
carrier turns that failure mode into overflow-flag + driver retry, which the
benchmark exercises and reports.
"""
from __future__ import annotations

import numpy as np

from repro import hiframes as hf
from repro.data import synth
from repro.runtime import run_with_overflow_retry

from .common import report, timeit


def q26(ss, it, min_count=4):
    store_sales, item = hf.table(ss, "ss"), hf.table(it, "it")
    sale_items = hf.join(store_sales, item, on=("ss_item_sk", "i_item_sk"))
    c_i = hf.aggregate(
        sale_items, "ss_customer_sk",
        c_i_count=hf.count(),
        id1=hf.sum_(sale_items["i_class_id"] == 1),
        id2=hf.sum_(sale_items["i_class_id"] == 2),
        id3=hf.sum_(sale_items["i_class_id"] == 3))
    return c_i[c_i["c_i_count"] > min_count]


def q26_multikey(ss, dim, min_count=4):
    """Q26 with a realistic composite key: sales join a per-(item, region)
    dimension on BOTH columns and aggregate by the SAME key pair — the shape
    whose aggregate exchange + sort the physical planner elides (3 shuffles
    3 sorts -> 2 shuffles 1 sort, docs/physical_plan.md)."""
    store_sales, d = hf.table(ss, "ss"), hf.table(dim, "dim")
    sale_items = hf.join(
        store_sales, d,
        on=[("ss_item_sk", "i_item_sk"), ("ss_region", "i_region")])
    per_key = hf.aggregate(
        sale_items, by=("ss_item_sk", "ss_region"),
        n=hf.count(),
        paid=hf.sum_(sale_items["ss_net_paid"]),
        id1=hf.sum_(sale_items["i_class_id"] == 1),
        id2=hf.sum_(sale_items["i_class_id"] == 2))
    return per_key[per_key["n"] > min_count]


def _region_tables(ss, it, n_regions=4, seed=13):
    """Augment the synthetic tables with a region column / dimension."""
    rng = np.random.default_rng(seed)
    ss = dict(ss)
    ss["ss_region"] = rng.integers(0, n_regions,
                                   len(ss["ss_item_sk"])).astype(np.int32)
    n_items = len(it["i_item_sk"])
    dim = {
        "i_item_sk": np.tile(it["i_item_sk"], n_regions),
        "i_region": np.repeat(np.arange(n_regions, dtype=np.int32), n_items),
        "i_class_id": np.tile(it["i_class_id"], n_regions),
    }
    return ss, dim


def q26_fluent(ss_df, item_df, min_count=4):
    """Q26 in the fluent v2 spelling, parameterized over the item-dimension
    frame so the persisted-vs-cold A/B can swap it in place."""
    sale_items = ss_df.merge(item_df, on=("ss_item_sk", "i_item_sk"))
    c_i = (sale_items.groupby("ss_customer_sk")
           .agg(c_i_count="count",
                id1=(sale_items["i_class_id"] == 1, "sum"),
                id2=(sale_items["i_class_id"] == 2, "sum"),
                id3=(sale_items["i_class_id"] == 3, "sum")))
    return c_i[c_i["c_i_count"] > min_count]


def q25(ss):
    """Customer value segmentation: frequency (distinct tickets), monetary."""
    s = hf.table(ss, "ss")
    return hf.aggregate(
        s, "ss_customer_sk",
        frequency=hf.nunique(s["ss_ticket_number"]),
        totalspend=hf.sum_(s["ss_net_paid"]),
        maxspend=hf.max_(s["ss_net_paid"]))


def q05(wcs, it):
    """Click-category features per user (logistic-regression assembly)."""
    clicks, item = hf.table(wcs, "wcs"), hf.table(it, "it")
    j = hf.join(clicks, item, on=("wcs_item_sk", "i_item_sk"))
    return hf.aggregate(
        j, "wcs_user_sk",
        clicks_in_1=hf.sum_(j["i_category_id"] == 1),
        clicks_in_2=hf.sum_(j["i_category_id"] == 2),
        clicks_in_3=hf.sum_(j["i_category_id"] == 3),
        clicks_in_4=hf.sum_(j["i_category_id"] == 4),
        total=hf.count())


def q05_string(wcs_df, item_df):
    """Q05 with STRING category names: the equality and membership tests
    rewrite into dictionary-code space at plan construction, so the plan
    (exchanges, sorts, packed bytes) is identical to the int-category q05
    shape — only the host-side ingest encode differs (docs/dtypes.md)."""
    j = wcs_df.merge(item_df, on=("wcs_item_sk", "i_item_sk"))
    return j.groupby("wcs_user_sk").agg(
        clicks_books=(j["i_category_name"] == "books", "sum"),
        clicks_media=(j["i_category_name"].isin(["electronics", "music"]),
                      "sum"),
        total="count")


def q09_channel(ss_df):
    """TPCx-BB Q09-style multi-predicate revenue rollup, on the STRING
    sales channel: a code-space membership filter, a string groupby key
    with null holes (pandas ``dropna=True`` grouping), and skipna
    aggregation over the nullable discount column."""
    f = ss_df[ss_df["ss_channel"].isin(["web", "catalog"])]
    return f.groupby("ss_channel").agg(
        revenue=("ss_net_paid", "sum"),
        avg_disc=("ss_discount", "mean"),
        n_disc=("ss_discount", "count"),
        n="count")


def run(scale: float = 1.0):
    n_sales = int(400_000 * scale)
    n_items = int(20_000 * scale)
    n_cust = int(50_000 * scale)

    ss = synth.store_sales(n_sales, n_items, n_cust, seed=10)
    it = synth.item(n_items, seed=11)

    plan = q26(ss, it).lower()
    us = timeit(plan)
    report(f"fig11_q26_sf{scale}", us, f"rows={n_sales}")

    plan = q25(ss).lower()
    us = timeit(plan)
    report(f"fig11_q25_sf{scale}", us, f"rows={n_sales}")

    # Q26 on a composite (item, region) key: exchange elision A/B.  The
    # "elided" run skips the aggregate's shuffle; the baseline
    # (elide_exchanges=False) restores the exchange-per-operator plan.
    # (Both legs use the rank join, so the pre-refactor 3-local-sort plan is
    # gone from BOTH — the A/B isolates the exchange elision alone.)
    ss_r, dim_r = _region_tables(ss, it)
    frame = q26_multikey(ss_r, dim_r)
    for tag, cfg in (("elided", hf.ExecConfig()),
                     ("baseline", hf.ExecConfig(elide_exchanges=False))):
        pplan = frame.physical_plan(cfg)
        shuffles = pplan.shuffle_count()
        sorts = pplan.counts()["local_sorts"]
        us = timeit(frame.lower(cfg))
        report(f"fig11_q26_multikey_{tag}_sf{scale}", us,
               f"shuffles={shuffles};local_sorts={sorts};rows={n_sales}")

    # Q26 packed-exchange A/B: the same multikey pipeline with the payload
    # word-packing on (2 all_to_all per exchange) vs per-column collectives.
    # derived records the P=8 collective census alongside the timing.
    for tag, cfg in (("on", hf.ExecConfig()),
                     ("off", hf.ExecConfig(packed_exchange=False))):
        census = frame.physical_plan(cfg).shuffle_census(P=8)
        us = timeit(frame.lower(cfg))
        report(f"fig11_q26_packed_{tag}_sf{scale}", us,
               f"collectives={census['all_to_all']};"
               f"payload_bytes={census['payload_bytes']};rows={n_sales}")

    # Fig 12 (new): REPEATED Q26 against a persisted vs cold dimension
    # table — the hot-dimension-table serving scenario.  The dimension is
    # persisted hash-partitioned on the join key (a first-agg dedup), so
    # its device shards re-enter every later run without a host round-trip
    # and the join exchanges ONLY the fact side: the persisted leg issues
    # strictly fewer collectives (and shuffles) than the cold leg.
    ss_df = hf.table(ss, "ss")
    cold_item = hf.table(it, "it")
    pdim = (cold_item.groupby("i_item_sk")
            .agg(i_class_id=("i_class_id", "first"))
            .persist())
    legs = (("cold", cold_item), ("persisted", pdim))
    colls = {}
    for tag, item_df in legs:
        frame = q26_fluent(ss_df, item_df)
        pplan = frame.physical_plan()
        colls[tag] = pplan.collective_count()
        us = timeit(frame.lower())
        report(f"fig12_repeated_q26_{tag}_sf{scale}", us,
               f"collectives={colls[tag]};shuffles={pplan.shuffle_count()};"
               f"rows={n_sales}")
    assert colls["persisted"] < colls["cold"], colls

    wcs = synth.web_clickstream(n_sales, n_items, n_cust, seed=12, skew=1.1)

    # Multi-query string/categorical subset (PR 8): Q05 over string
    # category names and a Q09-style channel rollup with nullable columns.
    # Both ingest-encode host-side and run entirely in code space; the
    # string-key census gate (tests/test_plan_census.py) pins the plans
    # byte-identical to their int-keyed shapes.
    it_x = synth.item_ext(n_items, seed=11)
    frame = q05_string(hf.table(wcs, "wcs"), hf.table(it_x, "itx"))
    pplan = frame.physical_plan()
    us = timeit(frame.lower())
    report(f"fig11_q05_string_sf{scale}", us,
           f"shuffles={pplan.shuffle_count()};rows={n_sales}")

    ss_x = synth.store_sales_ext(n_sales, n_items, n_cust, seed=10)
    frame = q09_channel(hf.table(ss_x, "ssx"))
    pplan = frame.physical_plan()
    us = timeit(frame.lower())
    report(f"fig11_q09_channel_sf{scale}", us,
           f"shuffles={pplan.shuffle_count()};rows={n_sales}")
    # Q05 under skew: run through the overflow-retry driver and report the
    # number of replans the skew forced (the paper's Q05 story).
    def build(slack):
        cfg = hf.ExecConfig(safe_capacities=False, shuffle_slack=slack,
                            join_expansion=slack, auto_retry=0)
        return q05(wcs, it).collect(cfg)
    table, attempts = run_with_overflow_retry(build, base_slack=2.0,
                                              max_retries=6)
    plan = q05(wcs, it).lower()       # safe-capacity timing
    us = timeit(plan)
    report(f"fig11_q05_skew_sf{scale}", us,
           f"skew_retries={attempts};rows={table.num_rows()}")

"""Paper Fig. 8b — advanced analytics: cumsum, SMA, WMA.

The paper's 1,000–20,000x-vs-Spark gaps come from scan/stencil patterns that
map-reduce cannot express; here we compare against a pure-Python row loop
(the "UDF rolling apply" role that made Pandas 15,781x slower than HiFrames
for WMA) and eager NumPy.
"""
from __future__ import annotations

import numpy as np

from repro import hiframes as hf
from repro.data import synth

from .common import report, timeit


def _python_wma(x, w):
    out = np.zeros(len(x), np.float32)
    k = len(w) // 2
    for i in range(k, len(x) - k):
        acc = 0.0
        for j, wj in enumerate(w):
            acc += wj * x[i + j - k]
        out[i] = acc
    return out


def run(scale: float = 1.0):
    n = int(1_000_000 * scale)
    x = synth.series(n, seed=3)
    df = hf.table({"x": x})

    # cumsum
    us_np = timeit(lambda: np.cumsum(x))
    plan = hf.cumsum(df, df["x"], out="c").lower()
    us_hf = timeit(plan)
    report(f"fig8b_cumsum_numpy_n{n}", us_np, "")
    report(f"fig8b_cumsum_hiframes_n{n}", us_hf, f"speedup={us_np/us_hf:.2f}x")

    # SMA
    us_np = timeit(lambda: np.convolve(x, np.ones(3) / 3, mode="same"))
    plan = hf.sma(df, df["x"], 3, out="s").lower()
    us_hf = timeit(plan)
    report(f"fig8b_sma_numpy_n{n}", us_np, "")
    report(f"fig8b_sma_hiframes_n{n}", us_hf, f"speedup={us_np/us_hf:.2f}x")

    # WMA: python-loop baseline measured on a slice and scaled (the loop is
    # too slow to run at full n — the paper's point)
    n_loop = min(20_000, n)
    us_loop = timeit(lambda: _python_wma(x[:n_loop], [0.25, 0.5, 0.25]),
                     warmup=0, repeat=1) * (n / n_loop)
    plan = hf.wma(df, df["x"], [1, 2, 1], out="w").lower()
    us_hf = timeit(plan)
    report(f"fig8b_wma_pyloop_n{n}", us_loop, "(extrapolated)")
    report(f"fig8b_wma_hiframes_n{n}", us_hf, f"speedup={us_loop/us_hf:.0f}x")

    # kernel-backed variant
    plan_k = hf.wma(df, df["x"], [1, 2, 1], out="w").lower(
        hf.ExecConfig(use_pallas="interpret"))
    us_k = timeit(plan_k)
    report(f"fig8b_wma_hiframes_kernel_n{n}", us_k, "interpret-mode on CPU")

    # partitioned WMA (OVER (PARTITION BY g ORDER BY t)) downstream of a
    # join on the partition key: with property elision the window rides the
    # join's hash layout (2 exchanges total); the baseline re-shuffles (3).
    rng = np.random.default_rng(7)
    n_grp = max(16, int(np.sqrt(n)))
    fact = hf.table({"g": rng.integers(0, n_grp, n).astype(np.int32),
                     "t": rng.permutation(n).astype(np.int32),
                     "x": x})
    dim = hf.table({"g": np.arange(n_grp, dtype=np.int32),
                    "w0": rng.normal(size=n_grp).astype(np.float32)}, "dim")
    j = hf.join(fact, dim, on="g")
    win = hf.wma(j, j["x"] * j["w0"], [1, 2, 1], out="wma",
                 partition_by="g", order_by="t")
    shuffles = {cfg_name: win.physical_plan(cfg).shuffle_count()
                for cfg_name, cfg in
                [("elided", hf.ExecConfig()),
                 ("baseline", hf.ExecConfig(elide_exchanges=False))]}
    us_e = timeit(win.lower())
    us_b = timeit(win.lower(hf.ExecConfig(elide_exchanges=False)))
    report(f"fig8b_wma_partitioned_elided_n{n}",
           us_e, f"shuffles={shuffles['elided']}")
    report(f"fig8b_wma_partitioned_baseline_n{n}",
           us_b, f"shuffles={shuffles['baseline']} "
                 f"speedup={us_b/us_e:.2f}x")

"""Bench trend gate: diff the two newest BENCH_*.json snapshots.

``python -m benchmarks.trend [old.json new.json]`` — with no arguments the
two newest ``BENCH_*.json`` files in the repo root are compared (newest =
highest number in the name).  For every row name present in BOTH snapshots
the us_per_call ratio is printed; any shared row slower by more than
``--threshold`` (default 25%) fails the run with exit code 1 — the CI
bench-smoke regression gate.  Rows only one side has (new benches, retired
benches) are listed but never fail; if the snapshots share no rows at all
the gate passes vacuously with a warning.

Snapshots record the host they were generated on (``host`` block written
by benchmarks/run.py — cpu count + arch).  When the two newest snapshots
come from different hosts the absolute timings are not comparable (a
1-core container runs every 8-fake-device shard_map ~serialized), so the
diff is printed for information but regressions do NOT fail the gate.  A
snapshot without a host block (pre-PR-10) counts as unknown = different.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _latest_two(root: str) -> tuple[str, str]:
    snaps = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    if len(snaps) < 2:
        sys.exit(f"trend: need two BENCH_*.json snapshots under {root}, "
                 f"found {len(snaps)}")
    return snaps[-2], snaps[-1]


def _load(path: str) -> tuple[dict[str, float], dict | None]:
    with open(path) as f:
        doc = json.load(f)
    rows = {r["name"]: float(r["us_per_call"]) for r in doc["rows"]}
    return rows, doc.get("host")


def compare(old_path: str, new_path: str, threshold: float = 0.25,
            out=sys.stdout) -> list[str]:
    """Return the names of shared rows regressing past ``threshold``.

    Returns [] (informational diff only) when the snapshots were generated
    on different hosts — absolute timings across machines are noise.
    """
    (old, old_host), (new, new_host) = _load(old_path), _load(new_path)
    same_host = old_host is not None and old_host == new_host
    shared = sorted(set(old) & set(new))
    print(f"trend: {os.path.basename(old_path)} -> "
          f"{os.path.basename(new_path)}  ({len(shared)} shared rows, "
          f"gate at +{threshold:.0%})", file=out)
    if not same_host:
        print(f"trend: host changed ({old_host} -> {new_host}) — "
              "timings not comparable, diff is informational only",
              file=out)
    regressed = []
    for name in shared:
        ratio = new[name] / old[name] if old[name] > 0 else float("inf")
        flag = ""
        if ratio > 1.0 + threshold:
            regressed.append(name)
            flag = "  <-- REGRESSION"
        print(f"  {name}: {old[name]:.1f} -> {new[name]:.1f} us "
              f"({ratio - 1.0:+.1%} vs old){flag}", file=out)
    for name in sorted(set(new) - set(old)):
        print(f"  {name}: (new row, {new[name]:.1f} us)", file=out)
    for name in sorted(set(old) - set(new)):
        print(f"  {name}: (retired row)", file=out)
    if not shared:
        print("trend: WARNING — no shared rows; gate passes vacuously",
              file=out)
    return regressed if same_host else []


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("snapshots", nargs="*",
                    help="old.json new.json (default: two newest "
                         "BENCH_*.json in the repo root)")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="fail when new/old - 1 exceeds this (default 0.25)")
    args = ap.parse_args()
    if len(args.snapshots) == 2:
        old_path, new_path = args.snapshots
    elif not args.snapshots:
        old_path, new_path = _latest_two(REPO_ROOT)
    else:
        ap.error("pass exactly two snapshot paths, or none")
    regressed = compare(old_path, new_path, args.threshold)
    if regressed:
        sys.exit(f"trend: {len(regressed)} row(s) regressed past "
                 f"+{args.threshold:.0%}: {regressed}")


if __name__ == "__main__":
    main()

"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  --scale shrinks/grows datasets
(defaults are CPU-feasible stand-ins for the paper's cluster sizes);
--skip lets CI drop the slow subprocess scaling runs; --out additionally
writes the rows as JSON (the CI bench-smoke artifact).
"""
import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--skip", nargs="*", default=[],
                    choices=["relational", "multikey", "analytics", "udf",
                             "tpcx", "scaling", "kernels", "pallas_ab",
                             "validate", "serve", "serve_reshard"])
    ap.add_argument("--out", default=None,
                    help="write results as JSON to this path")
    args = ap.parse_args()

    from . import (bench_analytics, bench_kernels, bench_pallas_ab,
                   bench_relational, bench_scaling, bench_serve, bench_tpcx,
                   bench_udf, bench_validate)

    suites = {
        "relational": lambda: bench_relational.run(args.scale),
        "multikey": lambda: bench_relational.run_multikey(args.scale),
        "analytics": lambda: bench_analytics.run(args.scale),
        "udf": lambda: bench_udf.run(args.scale),
        "tpcx": lambda: bench_tpcx.run(args.scale),
        "kernels": lambda: bench_kernels.run(args.scale),
        "pallas_ab": lambda: bench_pallas_ab.run(args.scale),
        "validate": lambda: bench_validate.run(args.scale),
        "serve": lambda: bench_serve.run(args.scale),
        "serve_reshard": lambda: bench_serve.run_reshard(args.scale),
        "scaling": lambda: bench_scaling.run(args.scale),
    }
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if name in args.skip:
            continue
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if args.out:
        import os
        import platform

        from . import common
        rows = [{"name": n, "us_per_call": us, "derived": d}
                for (n, us, d) in common.ROWS]
        # host fingerprint: trend.py only enforces its regression gate
        # between snapshots from the same host — cross-machine absolute
        # timings are noise (see trend.py docstring).
        host = {"nproc": os.cpu_count(), "machine": platform.machine()}
        with open(args.out, "w") as f:
            json.dump({"scale": args.scale, "skipped": args.skip,
                       "failed": failed, "host": host, "rows": rows},
                      f, indent=2)
        print(f"wrote {len(rows)} rows to {args.out}", file=sys.stderr)
    if failed:
        sys.exit(f"benchmark suites failed: {failed}")


if __name__ == "__main__":
    main()

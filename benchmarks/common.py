"""Benchmark utilities: timing + CSV reporting (name,us_per_call,derived)."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

ROWS: list[tuple[str, float, str]] = []


def block(x):
    return jax.block_until_ready(x) if hasattr(x, "block_until_ready") or \
        isinstance(x, (list, tuple, dict)) else x


def timeit(fn: Callable, *, warmup: int = 1, repeat: int = 3) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        jax.tree.map(lambda a: getattr(a, "block_until_ready", lambda: a)(),
                     fn())
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        jax.tree.map(lambda a: getattr(a, "block_until_ready", lambda: a)(),
                     out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def report(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def flush_rows():
    out = list(ROWS)
    ROWS.clear()
    return out

"""Per-kernel micro-benchmarks: Pallas (interpret on CPU — correctness-level
timing only; the TPU numbers come from the §Roofline analysis) vs jnp refs."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.hash_partition import ops as hp_ops, ref as hp_ref
from repro.kernels.segment_reduce import ops as sr_ops
from repro.kernels.stencil1d import ops as st_ops, ref as st_ref
from repro.kernels.stream_compact import ops as sc_ops

from .common import report, timeit


def run(scale: float = 1.0):
    n = int(262_144 * scale)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=n).astype(np.float32))

    w = [0.25, 0.5, 0.25]
    ext = jnp.asarray(rng.normal(size=n + 2).astype(np.float32))
    us_ref = timeit(lambda: st_ref.stencil1d_ref(ext, w))
    us_k = timeit(lambda: st_ops.stencil1d(ext, w))
    report(f"kern_stencil1d_ref_n{n}", us_ref, "")
    report(f"kern_stencil1d_pallas_n{n}", us_k, "interpret")

    ki = jnp.asarray(rng.integers(0, 2, n).astype(np.int32))
    us_ref = timeit(lambda: jnp.cumsum(ki))
    us_k = timeit(lambda: sc_ops.prefix_sum(ki))
    report(f"kern_prefix_ref_n{n}", us_ref, "")
    report(f"kern_prefix_pallas_n{n}", us_k, "interpret")

    P = 64
    dest = jnp.asarray(rng.integers(0, P, n).astype(np.int32))
    us_ref = timeit(lambda: hp_ref.bucket_ranks_ref(dest, P))
    us_k = timeit(lambda: hp_ops.bucket_ranks(dest, P))
    report(f"kern_bucketrank_ref_n{n}_P{P}", us_ref, "")
    report(f"kern_bucketrank_pallas_n{n}_P{P}", us_k, "interpret")

"""Fig. 14 — runtime-validation overhead (docs/robustness.md).

``ExecConfig.validate`` adds per-exchange row-count/checksum pairs and
post-sort monotonicity flags to every plan.  All checks are computed from
per-shard locals and reduced host-side (zero extra collectives), so the
overhead should be a small constant factor on an exchange-heavy pipeline.
This pair measures the same groupby->join->sort pipeline with validation
off and on; the derived column reports the ratio.
"""
from __future__ import annotations

import numpy as np

from repro import hiframes as hf
from repro.data import synth

from .common import report, timeit


def _pipeline(n: int):
    rng = np.random.default_rng(14)
    fact = hf.table({
        "k": rng.integers(0, max(8, n // 16), n).astype(np.int32),
        "v": synth.series(n, seed=14),
    })
    dim = hf.table({
        "k": np.arange(max(8, n // 16), dtype=np.int32),
        "w": rng.normal(size=max(8, n // 16)).astype(np.float32),
    }, "dim")
    agg = hf.aggregate(fact, by="k", v_sum=("v", "sum"), v_cnt=("v", "count"))
    j = hf.join(agg, dim, on="k")
    return j.sort_values("v_sum")


def run(scale: float = 1.0):
    n = int(400_000 * scale)
    q = _pipeline(n)

    us_off = timeit(q.lower(hf.ExecConfig(validate=False)))
    us_on = timeit(q.lower(hf.ExecConfig(validate=True)))
    report(f"fig14_validate_overhead_off_n{n}", us_off, "")
    report(f"fig14_validate_overhead_on_n{n}", us_on,
           f"overhead={us_on / us_off:.2f}x (zero extra collectives)")

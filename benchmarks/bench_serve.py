"""Serving-layer benchmark (beyond-paper Fig. 15): cold vs warm query mix
through a Session, plus the P -> P' resharding path.

``fig15_serve_cold`` times the FIRST execution of the Q26-ish mix (plan +
lower + compile + run); ``fig15_serve_warm`` times a later pass where every
query hits the session plan cache (rebind + replay only) — the steady-state
serving cost.  ``fig15_serve_reshard_2to4`` times re-entering a frame
persisted at P=2 on the full mesh via the on-device reshard (skipped below
4 devices).
"""
from __future__ import annotations

import numpy as np

from repro import hiframes as hf
from repro.core.api import ExecConfig
from repro.launch.serve import build_mix, register_tables
from repro.runtime.session import Session

from .common import report, timeit


def run(scale: float = 0.25) -> None:
    with Session(ExecConfig()) as sess:
        register_tables(sess, scale)
        mix = build_mix(sess)

        def one_pass():
            return [sess.collect(q()) for q in mix]

        # cold: dedicated cache-empty timing (no timeit warmup — warmup IS
        # the thing being measured), then steady-state through timeit.
        import time
        t0 = time.perf_counter()
        tables = one_pass()
        cold_us = (time.perf_counter() - t0) * 1e6
        recs = [t.query_record for t in tables]
        report(f"fig15_serve_cold_sf{scale}", cold_us,
               f"queries={len(recs)} compiles={sum(r.compiles for r in recs)}")

        us = timeit(one_pass, warmup=1, repeat=3)
        st = sess.stats()
        report(f"fig15_serve_warm_sf{scale}", us,
               f"hit_rate={st['plan_cache']['hits']}/"
               f"{st['plan_cache']['hits'] + st['plan_cache']['misses']} "
               f"speedup={cold_us / max(us, 1):.1f}x")


def run_reshard(scale: float = 0.25) -> None:
    import jax
    from jax.sharding import Mesh

    if jax.device_count() < 4:
        print("fig15_serve_reshard: skipped (<4 devices)")
        return
    from repro.data import synth
    from repro.runtime.reshard import reshard

    n = max(int(200_000 * scale), 2_000)
    ss = synth.store_sales(n, max(int(2_000 * scale), 64),
                           max(int(10_000 * scale), 128), seed=0)
    cfg2 = ExecConfig(mesh=Mesh(np.array(jax.devices()[:2]), ("data",)))
    cfg4 = ExecConfig(mesh=Mesh(np.array(jax.devices()[:4]), ("data",)))
    p2 = hf.table(ss, "ss").repartition("ss_item_sk").persist(
        cfg2, name="ss2")

    us = timeit(lambda: reshard(p2, 4, cfg4).node.columns["ss_item_sk"],
                warmup=1, repeat=3)
    report(f"fig15_serve_reshard_2to4_sf{scale}", us,
           f"rows={n} (on-device split + hash re-establish)")

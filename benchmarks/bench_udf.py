"""Paper Fig. 10 — UDF overhead.

Spark SQL pays 24–46% for UDFs because they cross the SQL/JVM boundary;
HiFrames compiles UDFs into the same program.  We go further than timing:
the OPTIMIZED HLO op-histogram of the UDF plan must be IDENTICAL to the
built-in plan — zero overhead by construction, not by measurement.
"""
from __future__ import annotations

import collections
import re

import numpy as np

from repro import hiframes as hf
from repro.data import synth

from .common import report, timeit

_OP_RE = re.compile(r"=\s*[\w\[\],{}()\s]*?([a-z][\w\-]*)\(")


def op_histogram(hlo: str) -> collections.Counter:
    c: collections.Counter = collections.Counter()
    for line in hlo.splitlines():
        m = _OP_RE.search(line)
        if m:
            c[m.group(1)] += 1
    return c


def run(scale: float = 1.0):
    n = int(1_000_000 * scale)
    t = synth.relational_tables(n, n_keys=100, seed=4)
    df = hf.table(t)

    builtin = df[(df["x"] * 2.0 + df["y"]) > 0.5]
    udf = df[hf.udf(lambda x, y: x * 2.0 + y > 0.5, df["x"], df["y"])]

    plan_b = builtin.lower()
    plan_u = udf.lower()

    us_b = timeit(plan_b)
    us_u = timeit(plan_u)
    overhead = (us_u - us_b) / us_b * 100

    hist_b = op_histogram(plan_b.hlo_text())
    hist_u = op_histogram(plan_u.hlo_text())
    identical_hlo = hist_b == hist_u

    ob, ou = plan_b().to_numpy(), plan_u().to_numpy()
    identical_out = all(np.array_equal(ob[k], ou[k]) for k in ob)

    report(f"fig10_builtin_n{n}", us_b, "")
    report(f"fig10_udf_n{n}", us_u,
           f"overhead={overhead:+.1f}%;identical_hlo={identical_hlo};"
           f"identical_results={identical_out}")
    assert identical_hlo and identical_out

"""Paper Fig. 8a — basic relational operations: filter / join / aggregate.

Baselines (the Pandas/Julia roles are played by eager NumPy — sequential,
no compilation; Spark cannot run here):
  numpy-eager     sequential host baseline
  hiframes        compiled single-jit plan (this paper)
  hiframes+kern   same, hot loops through the Pallas kernels (interpret on CPU)

The paper's sizes (2B/0.5M/256M rows) are scaled to CPU-feasible defaults;
pass --scale to grow them.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np

from repro import hiframes as hf
from repro.data import synth

from .common import report, timeit


def bench_filter(n):
    t = synth.relational_tables(n, n_keys=1000, seed=0)

    def np_eager():
        m = t["x"] < 0.5
        return {k: v[m] for k, v in t.items()}
    us_np = timeit(np_eager)

    df = hf.table(t)
    plan = df[df["x"] < 0.5].lower()
    us_hf = timeit(plan)
    report(f"fig8a_filter_numpy_n{n}", us_np, "")
    report(f"fig8a_filter_hiframes_n{n}", us_hf,
           f"speedup={us_np/us_hf:.2f}x")


def bench_join(n_left, n_right):
    rng = np.random.default_rng(1)
    left = {"id": rng.integers(0, n_right, n_left).astype(np.int32),
            "x": rng.normal(size=n_left).astype(np.float32)}
    right = {"cid": np.arange(n_right, dtype=np.int32),
             "w": rng.normal(size=n_right).astype(np.float32)}

    def np_eager():
        order = np.argsort(right["cid"])
        pos = np.searchsorted(right["cid"], left["id"], sorter=order)
        return right["w"][order[pos]]
    us_np = timeit(np_eager)

    plan = hf.join(hf.table(left, "l"), hf.table(right, "r"),
                   on=("id", "cid")).lower()
    us_hf = timeit(plan)
    report(f"fig8a_join_numpy_n{n_left}", us_np, "")
    report(f"fig8a_join_hiframes_n{n_left}", us_hf,
           f"speedup={us_np/us_hf:.2f}x")


def bench_aggregate(n):
    t = synth.relational_tables(n, n_keys=4096, seed=2)

    def np_eager():
        order = np.argsort(t["id"], kind="stable")
        sid = t["id"][order]
        sx = t["x"][order]
        bounds = np.flatnonzero(np.diff(sid)) + 1
        return np.add.reduceat(sx, np.concatenate([[0], bounds]))
    us_np = timeit(np_eager)

    df = hf.table(t)
    plan = hf.aggregate(df, "id", s=hf.sum_(df["x"]),
                        m=hf.mean(df["y"])).lower()
    us_hf = timeit(plan)
    report(f"fig8a_aggregate_numpy_n{n}", us_np, "")
    report(f"fig8a_aggregate_hiframes_n{n}", us_hf,
           f"speedup={us_np/us_hf:.2f}x")


def bench_aggregate_multikey(n):
    """Composite-key group-by: shuffles on the combined hash of two key
    columns and segment-aggregates over lexicographic runs — tracks the
    multi-key shuffle path introduced with composite-key support."""
    rng = np.random.default_rng(3)
    t = {"k1": rng.integers(0, 64, n).astype(np.int32),
         "k2": rng.integers(0, 64, n).astype(np.int32),
         "x": rng.normal(size=n).astype(np.float32)}

    def np_eager():
        packed = t["k1"].astype(np.int64) * 64 + t["k2"]
        order = np.argsort(packed, kind="stable")
        sp = packed[order]
        sx = t["x"][order]
        bounds = np.flatnonzero(np.diff(sp)) + 1
        return np.add.reduceat(sx, np.concatenate([[0], bounds]))
    us_np = timeit(np_eager)

    df = hf.table(t)
    plan = hf.aggregate(df, by=("k1", "k2"), s=hf.sum_(df["x"]),
                        c=hf.count()).lower()
    us_hf = timeit(plan)
    report(f"multikey_aggregate_numpy_n{n}", us_np, "")
    report(f"multikey_aggregate_hiframes_n{n}", us_hf,
           f"speedup={us_np/us_hf:.2f}x")


def bench_groupby_partialagg(n):
    """Map-side partial aggregation A/B (paper Fig. 10 axis: shuffle volume
    dominates group-by cost).  Low-cardinality keys are the favorable case:
    the partial stage collapses each shard's rows to <= n_keys partial rows
    before the exchange.  The derived field records the P=8 collective/byte
    census so the bench JSON captures the wire-volume delta, not just time."""
    n_keys = 64
    rng = np.random.default_rng(7)
    t = {"k": rng.integers(0, n_keys, n).astype(np.int32),
         "x": rng.normal(size=n).astype(np.float32)}
    df = hf.table(t)
    frame = hf.aggregate(df, "k", s=hf.sum_(df["x"]), c=hf.count(),
                         m=hf.mean(df["x"]))
    for tag, cfg in (("on", hf.ExecConfig(agg_group_cap=2 * n_keys)),
                     ("off", hf.ExecConfig(partial_agg=False))):
        census = frame.physical_plan(cfg).shuffle_census(P=8)
        us = timeit(frame.lower(cfg))
        report(f"fig10_groupby_partialagg_{tag}_n{n}", us,
               f"collectives={census['all_to_all']};"
               f"payload_bytes={census['payload_bytes']};rows={n}")


# Fig. 13 (repo extension) — zipf-skew join, salted vs stats-blind planning.
# Runs in a subprocess at a FIXED 8 fake host devices so the skew actually
# lands on shards regardless of the parent bench environment; one process
# measures both arms so they share data, compile cache state and machine
# noise.  The baseline gets shuffle_slack doubled iff default slack overflows
# the hot bucket (the steady state the overflow-retry driver reaches on this
# distribution); the salted arm runs adaptive defaults.
_SKEW_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import numpy as np
from repro import hiframes as hf

n, m = {n}, {m}
rng = np.random.default_rng(13)
k = rng.integers(0, m, n).astype(np.int32)
k[: int(0.30 * n)] = 3          # one zipf-hot key: ~30% of all probe rows
rng.shuffle(k)
probe = {{"k": k, "v": rng.normal(size=n).astype(np.float32)}}
dim = {{"k": np.arange(m, dtype=np.int32),
        "w": rng.normal(size=m).astype(np.float32)}}
j = hf.table(probe, "probe").merge(hf.table(dim, "dim"), on="k")

base_cfg = hf.ExecConfig(safe_capacities=False)
if j.lower(base_cfg)().overflow:
    base_cfg = hf.ExecConfig(safe_capacities=False, shuffle_slack=4.0)
for tag, cfg in (("baseline", base_cfg),
                 ("salted", hf.ExecConfig(adaptive_stats=True,
                                          safe_capacities=False))):
    plan = j.lower(cfg)
    t = plan()                  # warmup/compile
    assert not t.overflow, tag
    c = np.asarray(t.counts, dtype=np.float64)
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        out = plan()
        np.asarray(out.counts)
        ts.append(time.perf_counter() - t0)
    print("ROW", tag, np.median(ts) * 1e6,
          c.max() / c.mean(), int(c.max()), int(c.sum()),
          cfg.shuffle_slack)
"""


def bench_skew_join(n):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    m = max(64, n // 50)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c",
         textwrap.dedent(_SKEW_SCRIPT).format(n=n, m=m)],
        env=env, capture_output=True, text=True, timeout=900)
    if res.returncode != 0:
        tail = res.stderr.strip().splitlines()[-1][:80] if res.stderr else "?"
        report(f"fig13_skew_join_baseline_n{n}", -1.0, f"FAILED:{tail}")
        report(f"fig13_skew_join_salted_n{n}", -1.0, f"FAILED:{tail}")
        return
    rows = {}
    for line in res.stdout.splitlines():
        if line.startswith("ROW "):
            _, tag, us, ratio, cmax, total, slack = line.split()
            rows[tag] = (float(us), float(ratio), int(cmax), int(total),
                         float(slack))
    us_b, r_b, mx_b, _, slack_b = rows["baseline"]
    us_s, r_s, mx_s, _, _ = rows["salted"]
    report(f"fig13_skew_join_baseline_n{n}", us_b,
           f"P=8;occ_max_over_mean={r_b:.2f};max_shard={mx_b};"
           f"slack={slack_b:g}")
    report(f"fig13_skew_join_salted_n{n}", us_s,
           f"P=8;occ_max_over_mean={r_s:.2f};max_shard={mx_s};"
           f"speedup={us_b/us_s:.2f}x")


def run(scale: float = 1.0):
    bench_filter(int(2_000_000 * scale))
    bench_join(int(500_000 * scale), int(50_000 * scale))
    bench_aggregate(int(1_000_000 * scale))
    bench_groupby_partialagg(int(1_000_000 * scale))
    bench_skew_join(int(400_000 * scale))


def run_multikey(scale: float = 1.0):
    """Composite-key suite (its own benchmarks/run.py entry, "multikey")."""
    bench_aggregate_multikey(int(1_000_000 * scale))

"""Paper Fig. 8a — basic relational operations: filter / join / aggregate.

Baselines (the Pandas/Julia roles are played by eager NumPy — sequential,
no compilation; Spark cannot run here):
  numpy-eager     sequential host baseline
  hiframes        compiled single-jit plan (this paper)
  hiframes+kern   same, hot loops through the Pallas kernels (interpret on CPU)

The paper's sizes (2B/0.5M/256M rows) are scaled to CPU-feasible defaults;
pass --scale to grow them.
"""
from __future__ import annotations

import numpy as np

from repro import hiframes as hf
from repro.data import synth

from .common import report, timeit


def bench_filter(n):
    t = synth.relational_tables(n, n_keys=1000, seed=0)

    def np_eager():
        m = t["x"] < 0.5
        return {k: v[m] for k, v in t.items()}
    us_np = timeit(np_eager)

    df = hf.table(t)
    plan = df[df["x"] < 0.5].lower()
    us_hf = timeit(plan)
    report(f"fig8a_filter_numpy_n{n}", us_np, "")
    report(f"fig8a_filter_hiframes_n{n}", us_hf,
           f"speedup={us_np/us_hf:.2f}x")


def bench_join(n_left, n_right):
    rng = np.random.default_rng(1)
    left = {"id": rng.integers(0, n_right, n_left).astype(np.int32),
            "x": rng.normal(size=n_left).astype(np.float32)}
    right = {"cid": np.arange(n_right, dtype=np.int32),
             "w": rng.normal(size=n_right).astype(np.float32)}

    def np_eager():
        order = np.argsort(right["cid"])
        pos = np.searchsorted(right["cid"], left["id"], sorter=order)
        return right["w"][order[pos]]
    us_np = timeit(np_eager)

    plan = hf.join(hf.table(left, "l"), hf.table(right, "r"),
                   on=("id", "cid")).lower()
    us_hf = timeit(plan)
    report(f"fig8a_join_numpy_n{n_left}", us_np, "")
    report(f"fig8a_join_hiframes_n{n_left}", us_hf,
           f"speedup={us_np/us_hf:.2f}x")


def bench_aggregate(n):
    t = synth.relational_tables(n, n_keys=4096, seed=2)

    def np_eager():
        order = np.argsort(t["id"], kind="stable")
        sid = t["id"][order]
        sx = t["x"][order]
        bounds = np.flatnonzero(np.diff(sid)) + 1
        return np.add.reduceat(sx, np.concatenate([[0], bounds]))
    us_np = timeit(np_eager)

    df = hf.table(t)
    plan = hf.aggregate(df, "id", s=hf.sum_(df["x"]),
                        m=hf.mean(df["y"])).lower()
    us_hf = timeit(plan)
    report(f"fig8a_aggregate_numpy_n{n}", us_np, "")
    report(f"fig8a_aggregate_hiframes_n{n}", us_hf,
           f"speedup={us_np/us_hf:.2f}x")


def bench_aggregate_multikey(n):
    """Composite-key group-by: shuffles on the combined hash of two key
    columns and segment-aggregates over lexicographic runs — tracks the
    multi-key shuffle path introduced with composite-key support."""
    rng = np.random.default_rng(3)
    t = {"k1": rng.integers(0, 64, n).astype(np.int32),
         "k2": rng.integers(0, 64, n).astype(np.int32),
         "x": rng.normal(size=n).astype(np.float32)}

    def np_eager():
        packed = t["k1"].astype(np.int64) * 64 + t["k2"]
        order = np.argsort(packed, kind="stable")
        sp = packed[order]
        sx = t["x"][order]
        bounds = np.flatnonzero(np.diff(sp)) + 1
        return np.add.reduceat(sx, np.concatenate([[0], bounds]))
    us_np = timeit(np_eager)

    df = hf.table(t)
    plan = hf.aggregate(df, by=("k1", "k2"), s=hf.sum_(df["x"]),
                        c=hf.count()).lower()
    us_hf = timeit(plan)
    report(f"multikey_aggregate_numpy_n{n}", us_np, "")
    report(f"multikey_aggregate_hiframes_n{n}", us_hf,
           f"speedup={us_np/us_hf:.2f}x")


def bench_groupby_partialagg(n):
    """Map-side partial aggregation A/B (paper Fig. 10 axis: shuffle volume
    dominates group-by cost).  Low-cardinality keys are the favorable case:
    the partial stage collapses each shard's rows to <= n_keys partial rows
    before the exchange.  The derived field records the P=8 collective/byte
    census so the bench JSON captures the wire-volume delta, not just time."""
    n_keys = 64
    rng = np.random.default_rng(7)
    t = {"k": rng.integers(0, n_keys, n).astype(np.int32),
         "x": rng.normal(size=n).astype(np.float32)}
    df = hf.table(t)
    frame = hf.aggregate(df, "k", s=hf.sum_(df["x"]), c=hf.count(),
                         m=hf.mean(df["x"]))
    for tag, cfg in (("on", hf.ExecConfig(agg_group_cap=2 * n_keys)),
                     ("off", hf.ExecConfig(partial_agg=False))):
        census = frame.physical_plan(cfg).shuffle_census(P=8)
        us = timeit(frame.lower(cfg))
        report(f"fig10_groupby_partialagg_{tag}_n{n}", us,
               f"collectives={census['all_to_all']};"
               f"payload_bytes={census['payload_bytes']};rows={n}")


def run(scale: float = 1.0):
    bench_filter(int(2_000_000 * scale))
    bench_join(int(500_000 * scale), int(50_000 * scale))
    bench_aggregate(int(1_000_000 * scale))
    bench_groupby_partialagg(int(1_000_000 * scale))


def run_multikey(scale: float = 1.0):
    """Composite-key suite (its own benchmarks/run.py entry, "multikey")."""
    bench_aggregate_multikey(int(1_000_000 * scale))

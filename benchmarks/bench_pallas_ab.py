"""Kernel-backend A/B: every hot-path primitive through the registry's
``ref`` lax compositions (``use_pallas="off"``) vs the fused Pallas kernels
(``use_pallas="interpret"`` on CPU; "compiled" on a real TPU).

One row pair per paper workload family:

  fig8a — relational: filter compaction + join/aggregate shuffles
          (prefix_sum, bucket_scatter, segment_sums)
  fig8b — analytics: partitioned cumsum/rank + exact rolling mean
          (segment_scan, segment_rank, segment_stencil, stencil1d_exact)
  fig11 — TPCx-BB Q26: the end-to-end join+aggregate query

The plans are identical by construction (the census gate in
tests/test_kernel_registry.py) — the A/B isolates kernel numerics time.
Interpret mode on CPU measures overhead, not speedup; the pair pins the
lever's cost model either way and becomes the fig8 speedup harness on TPU.
"""
from __future__ import annotations

import numpy as np

from repro import hiframes as hf
from repro.data import synth

from .common import report, timeit

MODES = ("off", "interpret")


def _ab(tag: str, frame):
    for mode in MODES:
        plan = frame.lower(hf.ExecConfig(use_pallas=mode))
        us = timeit(plan)
        report(f"{tag}_pallas_{mode}", us, f"use_pallas={mode}")


def bench_fig8a(n):
    t = synth.relational_tables(n, n_keys=1000, seed=0)
    df = hf.table(t)
    _ab(f"fig8a_filter_n{n}", df[df["x"] < 0.5])
    _ab(f"fig8a_aggregate_n{n}",
        hf.aggregate(df, "id", s=hf.sum_(df["x"]), m=hf.mean(df["y"])))
    rng = np.random.default_rng(1)
    n_right = max(100, n // 10)
    left = {"id": rng.integers(0, n_right, n).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32)}
    right = {"cid": np.arange(n_right, dtype=np.int32),
             "w": rng.normal(size=n_right).astype(np.float32)}
    _ab(f"fig8a_join_n{n}",
        hf.join(hf.table(left, "l"), hf.table(right, "r"), on=("id", "cid")))


def bench_fig8b(n):
    rng = np.random.default_rng(5)
    n_grp = max(16, int(np.sqrt(n)))
    df = hf.table({"g": rng.integers(0, n_grp, n).astype(np.int32),
                   "t": rng.permutation(n).astype(np.int32),
                   "x": rng.normal(size=n).astype(np.float32)})
    w = df.over("g", order_by="t")
    _ab(f"fig8b_part_cumsum_n{n}", w.cumsum(df["x"], out="cs"))
    _ab(f"fig8b_part_rank_n{n}", w.rank(out="r"))
    _ab(f"fig8b_rolling_exact_n{n}",
        w.rolling_mean(df["x"], 8, out="m", exact=True))


def bench_fig11(n_sales, n_items, n_cust):
    from .bench_tpcx import q26
    ss = synth.store_sales(n_sales, n_items, n_cust, seed=10)
    it = synth.item(n_items, seed=11)
    _ab(f"fig11_q26_n{n_sales}", q26(ss, it))


def run(scale: float = 1.0):
    bench_fig8a(int(1_000_000 * scale))
    bench_fig8b(int(1_000_000 * scale))
    bench_fig11(int(500_000 * scale), int(20_000 * scale) or 100,
                int(50_000 * scale) or 100)

from . import adamw, compression
from .adamw import OptConfig

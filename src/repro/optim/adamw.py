"""AdamW with ZeRO-1 state sharding, global-norm clipping, LR schedules.

Optimizer states are sharded over the DATA axes (ZeRO-1): each parameter's
m/v (and optional f32 master copy) carry a NamedSharding that extends the
parameter's own spec with the "data"/"pod" axes on the largest divisible dim.
On a real pod this converts optimizer memory from replicated to 1/64th per
chip and turns the update into reduce-scatter + all-gather, which GSPMD
emits from the sharding specs alone.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # cosine | linear | const
    state_dtype: str = "float32"      # bf16 halves optimizer memory (kimi-k2)
    use_master: bool = False          # fp32 master params (extra 4 bytes/param)
    zero1: bool = True


def lr_at(step, cfg: OptConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = 1.0 - t
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def init_state(params, cfg: OptConfig):
    sdt = jnp.dtype(cfg.state_dtype)

    def zeros(p):
        return jnp.zeros(p.shape, sdt)

    state = {"m": jax.tree.map(zeros, params),
             "v": jax.tree.map(zeros, params),
             "step": jnp.zeros((), jnp.int32)}
    if cfg.use_master:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def state_specs(params, cfg: OptConfig):
    """ShapeDtypeStructs of the optimizer state (dry-run, no allocation)."""
    sdt = jnp.dtype(cfg.state_dtype)

    def spec(p):
        return jax.ShapeDtypeStruct(p.shape, sdt)

    out = {"m": jax.tree.map(spec, params), "v": jax.tree.map(spec, params),
           "step": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.use_master:
        out["master"] = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    return out


def global_norm(tree):
    sq = jax.tree.reduce(
        lambda a, b: a + jnp.sum(jnp.square(b.astype(jnp.float32))), tree, 0.0)
    return jnp.sqrt(sq)


def update(params, grads, state, cfg: OptConfig):
    """One AdamW step; returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    lr = lr_at(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v, master=None):
        gf = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mh = m_new / c1
        vh = v_new / c2
        base = master if master is not None else p.astype(jnp.float32)
        new = base - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                           + cfg.weight_decay * base)
        return new, m_new.astype(sdt), v_new.astype(sdt)

    if cfg.use_master:
        out = jax.tree.map(upd, params, grads, state["m"], state["v"],
                           state["master"])
    else:
        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_master = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda n, p: n.astype(p.dtype), new_master, params)
    new_state = {"m": new_m, "v": new_v, "step": step}
    if cfg.use_master:
        new_state["master"] = new_master
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# ZeRO-1 sharding of optimizer states
# ---------------------------------------------------------------------------


def zero1_spec(mesh: Mesh, param_spec: P, shape: tuple) -> P:
    """Extend a param spec with data-axis sharding on the largest free dim."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not dp:
        return param_spec
    # idempotent: already data-sharded specs pass through (FSDP params)
    flat = set()
    for e in param_spec:
        if isinstance(e, (tuple, list)):
            flat.update(e)
        elif e is not None:
            flat.add(e)
    if flat & set(dp):
        return param_spec
    dpn = int(np.prod([mesh.shape[a] for a in dp]))
    entries = list(param_spec) + [None] * (len(shape) - len(param_spec))
    # choose the largest unsharded dim divisible by the dp product
    best, best_dim = -1, None
    for i, (dim, e) in enumerate(zip(shape, entries)):
        if e is None and dim % dpn == 0 and dim > best:
            best, best_dim = dim, i
    if best_dim is None:
        return param_spec
    entries[best_dim] = dp if len(dp) > 1 else dp[0]
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def state_shardings(mesh: Mesh, param_shardings, params, cfg: OptConfig):
    def one(sh, p):
        spec = zero1_spec(mesh, sh.spec, p.shape) if cfg.zero1 else sh.spec
        return NamedSharding(mesh, spec)
    m = jax.tree.map(one, param_shardings, params)
    out = {"m": m, "v": m, "step": NamedSharding(mesh, P())}
    if cfg.use_master:
        out["master"] = m
    return out

"""Gradient compression: int8 quantized all-reduce with error feedback.

Used by the shard_map-based training step (launch/steps.py, optional): each
device quantizes its local gradient to int8 with a per-tensor scale, the
all-reduce runs on int8 payloads (4x less ICI traffic — the collective-bound
roofline term), and the quantization error is fed back into the next step's
gradient (error-feedback keeps SGD convergence, Karimireddy et al. 2019).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def quantize(g, bits: int = 8):
    """Symmetric per-tensor int quantization. Returns (q int8, scale f32)."""
    assert bits == 8
    amax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127) \
        .astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(g, err, axis_names):
    """Quantized psum of one gradient tensor with error feedback.

    g: this device's local gradient; err: carried error-feedback buffer.
    Returns (g_mean, new_err).  The int8 payload is what crosses the ICI.
    All devices agree on ONE scale (pmax of local amax — a scalar pmax,
    negligible traffic) BEFORE quantizing, so the summed int8 payload
    dequantizes exactly.
    """
    if hasattr(lax, "axis_size"):                 # jax >= 0.6
        P = 1
        for a in axis_names:
            P *= lax.axis_size(a)
    else:                                         # 0.4.x: constant-folded psum
        P = lax.psum(1, tuple(axis_names))
    corrected = g.astype(jnp.float32) + err
    amax = jnp.max(jnp.abs(corrected))
    scale = jnp.maximum(lax.pmax(amax, axis_names) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    # int8 payload summed as int32 (no overflow for P <= 2^23)
    qsum = lax.psum(q.astype(jnp.int32), axis_names)
    g_sum = qsum.astype(jnp.float32) * scale
    g_mean = (g_sum / P).astype(g.dtype)
    new_err = corrected - dequantize(q, scale)
    return g_mean, new_err


def tree_compressed_psum(grads, errs, axis_names):
    out = jax.tree.map(lambda g, e: compressed_psum(g, e, axis_names),
                       grads, errs)
    g = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    e = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return g, e


def init_error_buffers(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

"""Sharded, atomic, async checkpointing with elastic restore.

Layout: a checkpoint is a directory
    <dir>/step_000123/
        manifest.json       # tree structure, dtypes, shapes, step, metadata
        <leafpath>.npy      # one file per pytree leaf

Writes go to ``step_X.tmp`` and are os.replace'd into place — a crash mid-
save never corrupts the latest checkpoint (restart-safe).  ``save_async``
snapshots device arrays (jax arrays are immutable) and writes from a
background thread so the training loop is not blocked.

Elastic restore: leaves are stored UNSHARDED (gathered), so a checkpoint
written on an N-device mesh restores onto any M-device mesh — ``restore``
device_puts each leaf with the target sharding.  On a real multi-host pod
each host would write its address-partition of each leaf; the manifest
format already records per-leaf shapes to support that extension.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

_SEP = "__"

# numpy cannot npy-roundtrip bfloat16/float8; store them as raw uint views
# and record the logical dtype in the manifest.
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8}


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = leaf
    return flat


def _unflatten_into(template, flat: dict[str, Any]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        leaves.append(flat.get(key, leaf))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir: str, step: int, tree, metadata: dict | None = None) -> str:
    """Atomic synchronous save; returns the final directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "metadata": metadata or {}, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(leaf)
        logical = str(arr.dtype)
        if logical in _EXOTIC:
            arr = arr.view(_EXOTIC[logical])
        np.save(os.path.join(tmp, key + ".npy"), arr)
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": logical}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(ckpt_dir)
    return final


class AsyncSaver:
    """Non-blocking checkpoint writer (one in flight at a time)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree, metadata: dict | None = None):
        self.wait()
        # snapshot to host first (device arrays could be donated afterwards)
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_tree, metadata),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template, step: int | None = None,
            shardings=None) -> tuple[Any, int, dict]:
    """Restore into the ``template`` pytree structure.

    ``shardings``: optional pytree of NamedShardings for the TARGET mesh —
    this is the elastic path: a checkpoint from any mesh size restores onto
    the current one.  Missing leaves keep the template's values (partial
    restore for model surgery).
    """
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for key, info in manifest["leaves"].items():
        arr = np.load(os.path.join(d, key + ".npy"))
        if info["dtype"] in _EXOTIC:
            arr = arr.view(getattr(ml_dtypes, info["dtype"]))
        flat[key] = arr
    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda leaf, sh: jax.device_put(leaf, sh), tree, shardings)
    return tree, step, manifest["metadata"]


def _gc(ckpt_dir: str, keep: int = 3):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)

"""Pure-jnp oracle for segment_scan: cumsum minus the pre-segment base."""
import jax.numpy as jnp
from jax import lax


def segment_scan_ref(x, boundary):
    """Segmented inclusive sum-scan; boundary != 0 starts a new segment."""
    n = x.shape[0]
    incl = jnp.cumsum(x)
    idx = jnp.arange(n, dtype=jnp.int32)
    first = lax.cummax(jnp.where(boundary != 0, idx, 0))
    base = jnp.where(first > 0, incl[jnp.maximum(first - 1, 0)],
                     jnp.zeros((), incl.dtype))
    return (incl - base).astype(x.dtype)

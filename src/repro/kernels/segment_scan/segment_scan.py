"""Pallas kernel: fused segmented inclusive scan (boundary mask + scan, one pass).

The partitioned-window backbone: ``out[i]`` is the running sum of ``x`` within
the segment containing row i, where ``boundary[i] != 0`` marks segment heads.
The lax composition (``ref.py``) needs three sweeps — a global cumsum, a
cummax to locate segment heads, and a gather to subtract the pre-segment
base.  This kernel fuses them into ONE pass using the segmented-scan monoid

    (v1, f1) + (v2, f2) = (v2 if f2 else v1 + v2,  f1 | f2)

applied as an in-block Hillis-Steele ladder (log2(BLOCK) static shifted adds,
pure VPU, no gathers), with a single-element VMEM cell carrying the segmented
scan value at the previous block's last row.  Rows before the first in-block
boundary continue the prior segment, so adding the carry where the
accumulated flag is still unset is exactly the cross-block fixup.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 2048


def _kernel(x_ref, b_ref, o_ref, carry):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry[0] = jnp.zeros((), x_ref.dtype)

    v = x_ref[...]
    f = b_ref[...] != 0
    shift = 1
    while shift < BLOCK:                      # static ladder: log2(BLOCK) steps
        vs = jnp.concatenate([jnp.zeros((shift,), v.dtype), v[:-shift]])
        fs = jnp.concatenate([jnp.zeros((shift,), jnp.bool_), f[:-shift]])
        v = v + jnp.where(f, jnp.zeros((), v.dtype), vs)
        f = f | fs
        shift *= 2
    out = v + jnp.where(f, jnp.zeros((), v.dtype), carry[0])
    o_ref[...] = out
    carry[0] = out[-1]


def segment_scan_pallas(x: jax.Array, boundary: jax.Array,
                        interpret: bool = True) -> jax.Array:
    """Segmented inclusive sum-scan; boundary != 0 starts a new segment."""
    n = x.shape[0]
    nb = max(1, -(-n // BLOCK))
    xp = jnp.pad(x, (0, nb * BLOCK - n))
    bp = jnp.pad(boundary.astype(jnp.int32), (0, nb * BLOCK - n))
    out = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,)),
                  pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * BLOCK,), x.dtype),
        scratch_shapes=[pltpu.VMEM((1,), x.dtype)],
        interpret=interpret,
    )(xp, bp)
    return out[:n]

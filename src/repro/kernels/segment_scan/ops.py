"""jit'd wrapper for the fused segment_scan kernel."""
import functools

import jax

from .segment_scan import segment_scan_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def segment_scan(x, boundary, interpret: bool = True):
    return segment_scan_pallas(x, boundary, interpret=interpret)

"""Pure-jnp oracle for hash_partition bucket ranks."""
import jax.numpy as jnp


def bucket_ranks_ref(dest, P: int):
    """Stable within-bucket rank of every row + per-bucket counts."""
    n = dest.shape[0]
    onehot = (dest[:, None] == jnp.arange(P, dtype=dest.dtype)[None, :]).astype(jnp.int32)
    excl = jnp.cumsum(onehot, axis=0) - onehot
    ranks = jnp.sum(excl * onehot, axis=1)
    counts = jnp.sum(onehot, axis=0)
    return ranks, counts

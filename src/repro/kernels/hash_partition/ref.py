"""Pure-jnp oracles for hash_partition bucket ranks."""
import jax.numpy as jnp


def bucket_ranks_ref(dest, P: int):
    """Stable within-bucket rank of every row + per-bucket counts."""
    n = dest.shape[0]
    onehot = (dest[:, None] == jnp.arange(P, dtype=dest.dtype)[None, :]).astype(jnp.int32)
    excl = jnp.cumsum(onehot, axis=0) - onehot
    ranks = jnp.sum(excl * onehot, axis=1)
    counts = jnp.sum(onehot, axis=0)
    return ranks, counts


def bucket_ranks_argsort(dest, P: int):
    """Stable within-bucket ranks via stable argsort — O(n log n) but
    O(n)-memory (no (n, P) one-hot).  This is the registry's `ref` backend
    for the exchange bucket scatter: a row's stable rank equals its slot in
    the sorted order minus its bucket's offset, scattered back to original
    row positions.  Rows with dest == P (invalid) get garbage ranks; callers
    mask them with ``dest < P``."""
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    sdest = dest[order]
    counts = jnp.bincount(dest, length=P + 1)[:P].astype(jnp.int32)
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    slot_sorted = (jnp.arange(n, dtype=jnp.int32)
                   - offs[jnp.clip(sdest, 0, max(P - 1, 0))])
    ranks = jnp.zeros((n,), jnp.int32).at[order].set(slot_sorted)
    return ranks, counts

"""Pallas kernel: bucket rank + histogram for the shuffle (Alltoallv analogue).

The exchange operator must place row i into slot ``rank(i)`` of bucket
``dest(i)`` where rank is the stable within-bucket position.  The reference
path derives ranks from a stable argsort (O(n log n) bitonic on TPU); this
kernel computes them in ONE streaming pass: per block, a (BLOCK, P) one-hot
of destinations gives within-block exclusive ranks via a column cumsum, and a
(P,)-vector VMEM scratch carries the running per-bucket histogram across the
sequential grid.  Work is O(n·P / lanes) with unit-stride VPU ops — the
dominant shuffle-planning cost drops ~log(n)× (see EXPERIMENTS.md §Perf).

Rows with dest == P (invalid/padding) match no one-hot column: rank 0,
counted nowhere.  Valid rows form a prefix, so their ranks are unaffected.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 1024  # (BLOCK, P) one-hot must fit VMEM: 1024x256 i32 = 1 MB


def _kernel(dest_ref, rank_ref, hist_ref, hist, *, P: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist[...] = jnp.zeros((P,), jnp.int32)

    d = dest_ref[...]
    onehot = (d[:, None] == jnp.arange(P, dtype=d.dtype)[None, :]).astype(jnp.int32)
    excl = jnp.cumsum(onehot, axis=0) - onehot          # within-block rank
    base = hist[...]                                    # carried bucket counts
    rank_ref[...] = jnp.sum((excl + base[None, :]) * onehot, axis=1)
    new_hist = base + jnp.sum(onehot, axis=0)
    hist[...] = new_hist
    hist_ref[...] = new_hist                            # last write = totals


def bucket_ranks_pallas(dest: jax.Array, P: int, interpret: bool = True):
    """(ranks, send_counts) for bucket ids in [0, P]; P marks invalid rows."""
    n = dest.shape[0]
    nb = max(1, -(-n // BLOCK))
    dp = jnp.pad(dest.astype(jnp.int32), (0, nb * BLOCK - n),
                 constant_values=P)
    ranks, counts = pl.pallas_call(
        functools.partial(_kernel, P=P),
        grid=(nb,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,)),
                   pl.BlockSpec((P,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((nb * BLOCK,), jnp.int32),
                   jax.ShapeDtypeStruct((P,), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((P,), jnp.int32)],
        interpret=interpret,
    )(dp)
    return ranks[:n], counts

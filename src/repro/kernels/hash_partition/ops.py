"""jit'd wrapper for the hash_partition kernel."""
import functools

import jax

from .hash_partition import bucket_ranks_pallas


@functools.partial(jax.jit, static_argnames=("P", "interpret"))
def bucket_ranks(dest, P: int, interpret: bool = True):
    return bucket_ranks_pallas(dest, P, interpret=interpret)

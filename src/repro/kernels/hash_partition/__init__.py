from . import ops, ref
from .hash_partition import bucket_ranks_pallas

"""Pallas TPU kernels for HiFrames hot spots.

Each subpackage ships three files:
  <name>.py — the pl.pallas_call kernel with explicit BlockSpec VMEM tiling
  ops.py    — jit'd wrapper (interpret=True on CPU, compiled on TPU)
  ref.py    — pure-jnp oracle used by the shape/dtype sweep tests

``registry.py`` binds every subpackage's (ref, pallas) pair into one typed
table keyed by primitive name; ``core.lower`` resolves it from the
``ExecConfig.use_pallas`` lever ("off" | "interpret" | "compiled").  See
docs/kernels.md for the registry contract.

  stream_compact — filter compaction prefix-scan        (paper Fig. 8a)
  segment_scan   — fused segmented scan (windows/aggs)  (paper Fig. 8b)
  segment_rank   — fused in-segment ranking             (paper §4.4)
  segment_reduce — sorted-run aggregation scan          (paper Fig. 8a)
  stencil1d      — SMA/WMA windowed weighted sum        (paper Fig. 8b)
  hash_partition — shuffle bucket rank/histogram        (paper §4.5)
"""
import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_default() -> bool:
    return not on_tpu()

"""Pallas TPU kernels for HiFrames hot spots.

Each subpackage ships three files:
  <name>.py — the pl.pallas_call kernel with explicit BlockSpec VMEM tiling
  ops.py    — jit'd wrapper (interpret=True on CPU, compiled on TPU)
  ref.py    — pure-jnp oracle used by the shape/dtype sweep tests

``kernel_table()`` returns the hook dict consumed by core.lower.Lowered:
  stencil1d      — SMA/WMA windowed weighted sum       (paper Fig. 8b)
  stream_compact — filter compaction prefix-scan       (paper Fig. 8a)
  segment_reduce — sorted-run aggregation scan          (paper Fig. 8a)
  hash_partition — shuffle bucket rank/histogram        (paper §4.5)
"""
import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_default() -> bool:
    return not on_tpu()


def kernel_table(interpret: bool | None = None) -> dict:
    from .hash_partition import ops as hp
    from .segment_reduce import ops as sr
    from .stencil1d import ops as st
    from .stream_compact import ops as sc

    it = interpret_default() if interpret is None else interpret
    return {
        "stencil1d": lambda ext, w, center: st.stencil1d(ext, w, interpret=it),
        "prefix_sum": lambda x: sc.prefix_sum(x, interpret=it),
        "segment_sums": lambda v, seg_id, valid, nseg: sr.segment_sums(
            v, seg_id, valid, nseg, interpret=it),
        "hash_partition": lambda dest, P: hp.bucket_ranks(dest, P, interpret=it),
    }

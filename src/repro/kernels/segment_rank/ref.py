"""Pure-jnp oracle for segment_rank: absolute-index compositions.

These are the lax sweeps that lived inline in ``physical.segment_rank``
before the registry: ranks from cummax-located segment/run heads.
"""
import jax.numpy as jnp
from jax import lax


def segment_rank_ref(seg_b, ord_b, kind: str):
    """1-based in-segment ranks; kind in {row_number, rank, dense_rank}."""
    n = seg_b.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    seg_first = lax.cummax(jnp.where(seg_b != 0, idx, 0))
    if kind == "row_number":
        return idx - seg_first + 1
    if kind == "dense_rank":
        runs = jnp.cumsum((ord_b != 0).astype(jnp.int32))
        return runs - runs[seg_first] + 1
    if kind == "rank":
        ord_first = lax.cummax(jnp.where(ord_b != 0, idx, 0))
        return ord_first - seg_first + 1
    raise ValueError(f"unknown rank kind: {kind!r}")

"""Pallas kernel: fused in-segment ranking (row_number / rank / dense_rank).

Inputs are two boundary masks: ``seg_b`` marks segment heads, ``ord_b`` marks
order-key run heads (every segment head is also a run head, by construction
in ``physical.segment_rank``).  All three rank kinds reduce to segmented
scans of those masks:

  row_number[i] = segmented sum of 1        (position in segment, 1-based)
  dense_rank[i] = segmented sum of ord_b    (run index in segment, 1-based)
  rank[i]       = segmented running max of (ord_b ? row_number : 0)
                  (row_number at the latest run head — ties share it)

The kernel runs the same Hillis-Steele segmented-scan ladder as
``segment_scan`` (sum monoid for the count, max monoid with identity 0 for
rank), with a two-cell VMEM carry: cell 0 holds the count scan at the
previous block's last row, cell 1 the running max.  The max carry is valid
across blocks because row_number only grows within a segment and the latest
run head at or before row i is always inside row i's segment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 2048


def _seg_ladder(v, f, combine):
    shift = 1
    while shift < BLOCK:
        vs = jnp.concatenate([jnp.zeros((shift,), v.dtype), v[:-shift]])
        fs = jnp.concatenate([jnp.zeros((shift,), jnp.bool_), f[:-shift]])
        v = combine(v, jnp.where(f, jnp.zeros((), v.dtype), vs))
        f = f | fs
        shift *= 2
    return v, f


def _kernel(seg_ref, ord_ref, o_ref, carry, *, kind: str):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry[0] = jnp.zeros((), jnp.int32)
        carry[1] = jnp.zeros((), jnp.int32)

    f = seg_ref[...] != 0
    ob = ord_ref[...] != 0
    inc = ob.astype(jnp.int32) if kind == "dense_rank" \
        else jnp.ones((BLOCK,), jnp.int32)
    v, ff = _seg_ladder(inc, f, jnp.add)
    rn = v + jnp.where(ff, 0, carry[0])
    carry[0] = rn[-1]
    if kind == "rank":
        m, fm = _seg_ladder(jnp.where(ob, rn, 0), f, jnp.maximum)
        out = jnp.maximum(m, jnp.where(fm, 0, carry[1]))
        carry[1] = out[-1]
        o_ref[...] = out
    else:
        o_ref[...] = rn


def segment_rank_pallas(seg_b: jax.Array, ord_b: jax.Array, kind: str,
                        interpret: bool = True) -> jax.Array:
    """1-based in-segment ranks; kind in {row_number, rank, dense_rank}."""
    n = seg_b.shape[0]
    nb = max(1, -(-n // BLOCK))
    pad = (0, nb * BLOCK - n)
    sp = jnp.pad(seg_b.astype(jnp.int32), pad, constant_values=1)
    op = jnp.pad(ord_b.astype(jnp.int32), pad, constant_values=1)
    out = pl.pallas_call(
        functools.partial(_kernel, kind=kind),
        grid=(nb,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,)),
                  pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * BLOCK,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((2,), jnp.int32)],
        interpret=interpret,
    )(sp, op)
    return out[:n]

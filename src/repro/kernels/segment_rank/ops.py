"""jit'd wrapper for the fused segment_rank kernel."""
import functools

import jax

from .segment_rank import segment_rank_pallas


@functools.partial(jax.jit, static_argnames=("kind", "interpret"))
def segment_rank(seg_b, ord_b, kind: str, interpret: bool = True):
    return segment_rank_pallas(seg_b, ord_b, kind, interpret=interpret)

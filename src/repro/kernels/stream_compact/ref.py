"""Pure-jnp oracle for the stream_compact prefix-sum kernel."""
import jax.numpy as jnp


def prefix_sum_ref(x):
    return jnp.cumsum(x)


def compact_ref(values, keep, cap_out):
    """Oracle for full compaction: kept values moved to a dense prefix."""
    keep_i = keep.astype(jnp.int32)
    dest = jnp.cumsum(keep_i) - 1
    dest = jnp.where(keep_i > 0, dest, cap_out)
    out = jnp.zeros((cap_out,), values.dtype).at[dest].set(values, mode="drop")
    return out, jnp.minimum(jnp.sum(keep_i), cap_out)

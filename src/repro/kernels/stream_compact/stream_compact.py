"""Pallas kernel: carried blocked prefix-sum (stream compaction backbone).

Filter is the paper's no-communication operator: each shard moves its kept
rows into a dense prefix.  The hot loop is the inclusive prefix-sum of the
keep-predicate that assigns destination slots.  TPU grid steps execute
sequentially, so a single-element VMEM scratch carries the running total
across blocks — one pass, no re-scan (the classic decoupled-lookback is
unnecessary on TPU's sequential grid).

The same kernel (float path) is the local phase of distributed cumsum
(paper Fig. 8b) — MPI_Exscan's local partial sums.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 2048


def _kernel(x_ref, o_ref, carry):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry[0] = jnp.zeros((), x_ref.dtype)

    x = x_ref[...]
    c = jnp.cumsum(x)
    o_ref[...] = c + carry[0]
    carry[0] = carry[0] + c[-1]


def prefix_sum_pallas(x: jax.Array, interpret: bool = True) -> jax.Array:
    """Inclusive prefix sum over a 1-D array (int32/float32)."""
    n = x.shape[0]
    nb = max(1, -(-n // BLOCK))
    xp = jnp.pad(x, (0, nb * BLOCK - n))
    out = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * BLOCK,), x.dtype),
        scratch_shapes=[pltpu.VMEM((1,), x.dtype)],
        interpret=interpret,
    )(xp)
    return out[:n]

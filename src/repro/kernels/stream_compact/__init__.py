from . import ops, ref
from .stream_compact import prefix_sum_pallas

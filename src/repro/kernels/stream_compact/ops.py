"""jit'd wrappers for the stream_compact kernel."""
import functools

import jax
import jax.numpy as jnp

from .stream_compact import prefix_sum_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def prefix_sum(x, interpret: bool = True):
    return prefix_sum_pallas(x, interpret=interpret)


def compact(values, keep, cap_out: int, interpret: bool = True):
    """Full compaction using the kernel for slot assignment."""
    keep_i = keep.astype(jnp.int32)
    incl = prefix_sum(keep_i, interpret=interpret)
    dest = jnp.where(keep_i > 0, incl - 1, cap_out)
    out = jnp.zeros((cap_out,), values.dtype).at[dest].set(values, mode="drop")
    total = incl[-1] if incl.shape[0] else jnp.int32(0)
    return out, jnp.minimum(total, cap_out)

"""Pallas kernel: 1-D weighted window (SMA/WMA) — the paper's stencil op.

Tiling: the extended array ``ext`` (local shard + exchanged halos, length
n + K - 1) is processed in blocks of ``BLOCK`` output elements.  Each grid
step loads its (BLOCK,) slice of ext plus a (K-1,) tail (the first K-1
elements of the next block) into VMEM and computes the weighted window sum
with K static shifted adds — MXU-free, pure VPU, unit-stride lane access.
Weights are compile-time constants folded into the kernel body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK = 2048  # multiple of the 8x128 VREG tile; ~8KB f32 per operand in VMEM


def _kernel(x_ref, tail_ref, o_ref, *, weights: tuple[float, ...]):
    K = len(weights)
    x = x_ref[...]
    if K > 1:
        ext = jnp.concatenate([x, tail_ref[0, :]])
    else:
        ext = x
    acc = np.float32(weights[0]) * ext[0:BLOCK]
    for j in range(1, K):
        acc = acc + np.float32(weights[j]) * ext[j:j + BLOCK]
    o_ref[...] = acc


def stencil1d_pallas(ext: jax.Array, weights: tuple[float, ...],
                     interpret: bool = True) -> jax.Array:
    """out[i] = sum_j w[j] * ext[i+j], for i in [0, len(ext) - K + 1)."""
    K = len(weights)
    n = ext.shape[0] - (K - 1)
    nb = max(1, -(-n // BLOCK))
    ext_p = jnp.pad(ext.astype(jnp.float32), (0, nb * BLOCK + K - 1 - ext.shape[0]))
    x = ext_p[: nb * BLOCK]
    if K > 1:
        idx = (jnp.arange(nb)[:, None] + 1) * BLOCK + jnp.arange(K - 1)[None, :]
        tails = ext_p[idx]                       # (nb, K-1) — tiny halo table
    else:
        tails = jnp.zeros((nb, 1), jnp.float32)
    out = pl.pallas_call(
        functools.partial(_kernel, weights=tuple(weights)),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1, max(K - 1, 1)), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * BLOCK,), jnp.float32),
        interpret=interpret,
    )(x, tails)
    return out[:n]

"""Pallas kernel: 1-D weighted window (SMA/WMA) — the paper's stencil op.

Tiling: the extended array ``ext`` (local shard + exchanged halos, length
n + K - 1) is processed in blocks of ``BLOCK`` output elements.  Each grid
step loads its (BLOCK,) slice of ext plus a (K-1,) tail (the first K-1
elements of the next block) into VMEM and computes the weighted window sum
with K static shifted adds — MXU-free, pure VPU, unit-stride lane access.
Weights are compile-time constants folded into the kernel body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK = 2048  # multiple of the 8x128 VREG tile; ~8KB f32 per operand in VMEM


def _kernel(x_ref, tail_ref, o_ref, *, weights: tuple[float, ...]):
    K = len(weights)
    x = x_ref[...]
    if K > 1:
        ext = jnp.concatenate([x, tail_ref[0, :]])
    else:
        ext = x
    acc = np.float32(weights[0]) * ext[0:BLOCK]
    for j in range(1, K):
        acc = acc + np.float32(weights[j]) * ext[j:j + BLOCK]
    o_ref[...] = acc


def _blocked_ext(ext, nb, K, dtype, fill=0):
    """Split an extended array into (nb*BLOCK,) blocks + (nb, K-1) tails."""
    ext_p = jnp.pad(ext.astype(dtype), (0, nb * BLOCK + K - 1 - ext.shape[0]),
                    constant_values=fill)
    x = ext_p[: nb * BLOCK]
    if K > 1:
        idx = (jnp.arange(nb)[:, None] + 1) * BLOCK + jnp.arange(K - 1)[None, :]
        tails = ext_p[idx]                       # (nb, K-1) — tiny halo table
    else:
        tails = jnp.zeros((nb, 1), dtype)
    return x, tails


def stencil1d_pallas(ext: jax.Array, weights: tuple[float, ...],
                     interpret: bool = True) -> jax.Array:
    """out[i] = sum_j w[j] * ext[i+j], for i in [0, len(ext) - K + 1)."""
    K = len(weights)
    n = ext.shape[0] - (K - 1)
    nb = max(1, -(-n // BLOCK))
    x, tails = _blocked_ext(ext, nb, K, jnp.float32)
    out = pl.pallas_call(
        functools.partial(_kernel, weights=tuple(weights)),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1, max(K - 1, 1)), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * BLOCK,), jnp.float32),
        interpret=interpret,
    )(x, tails)
    return out[:n]


def _kernel_exact(x_ref, xt_ref, m_ref, mt_ref, o_ref, *,
                  weights: tuple[float, ...]):
    K = len(weights)
    x, m = x_ref[...], m_ref[...]
    if K > 1:
        x = jnp.concatenate([x, xt_ref[0, :]])
        m = jnp.concatenate([m, mt_ref[0, :]])
    acc = np.float32(weights[0]) * x[0:BLOCK]
    mass = np.float32(weights[0]) * m[0:BLOCK]
    for j in range(1, K):
        acc = acc + np.float32(weights[j]) * x[j:j + BLOCK]
        mass = mass + np.float32(weights[j]) * m[j:j + BLOCK]
    total = np.float32(sum(weights))
    safe = jnp.where(mass != 0.0, mass, np.float32(1.0))
    o_ref[...] = jnp.where(mass != 0.0, acc * total / safe, np.float32(0.0))


def stencil1d_exact_pallas(ext: jax.Array, ext_m: jax.Array,
                           weights: tuple[float, ...],
                           interpret: bool = True) -> jax.Array:
    """Fused stencil + edge renormalize: the weighted sum over in-bounds taps
    (``ext_m`` carries the validity mask through the same halo machinery) is
    rescaled by total_weight / covered_mass in the SAME kernel pass — the
    second full stencil sweep that ``exact=True`` rolling windows used to pay
    disappears."""
    K = len(weights)
    n = ext.shape[0] - (K - 1)
    nb = max(1, -(-n // BLOCK))
    x, xt = _blocked_ext(ext, nb, K, jnp.float32)
    m, mt = _blocked_ext(ext_m, nb, K, jnp.float32)
    out = pl.pallas_call(
        functools.partial(_kernel_exact, weights=tuple(weights)),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1, max(K - 1, 1)), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1, max(K - 1, 1)), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * BLOCK,), jnp.float32),
        interpret=interpret,
    )(x, xt, m, mt)
    return out[:n]


def _kernel_segment(x_ref, xt_ref, s_ref, st_ref, o_ref, *,
                    weights: tuple[float, ...], center: int, exact: bool):
    K = len(weights)
    ex, es = x_ref[...], s_ref[...]
    if K > 1:
        ex = jnp.concatenate([ex, xt_ref[0, :]])
        es = jnp.concatenate([es, st_ref[0, :]])
    sid = es[center:center + BLOCK]
    acc = jnp.zeros((BLOCK,), jnp.float32)
    mass = jnp.zeros((BLOCK,), jnp.float32)
    for j in range(K):
        same = es[j:j + BLOCK] == sid
        acc = acc + np.float32(weights[j]) * jnp.where(same, ex[j:j + BLOCK],
                                                       np.float32(0.0))
        if exact:
            mass = mass + np.float32(weights[j]) * same.astype(jnp.float32)
    if exact:
        total = np.float32(sum(weights))
        safe = jnp.where(mass != 0.0, mass, np.float32(1.0))
        acc = jnp.where(mass != 0.0, acc * total / safe, np.float32(0.0))
    o_ref[...] = acc


def segment_stencil_pallas(ext: jax.Array, ext_s: jax.Array,
                           weights: tuple[float, ...], center: int,
                           exact: bool = False,
                           interpret: bool = True) -> jax.Array:
    """Partition-masked stencil: tap j contributes only where the extended
    segment-id array matches the centre row's id (``ext_s`` uses sentinel ids
    for halo/invalid rows, so cross-partition taps never match).  With
    ``exact`` the in-segment mass renormalize is fused in, same as
    ``stencil1d_exact``."""
    K = len(weights)
    n = ext.shape[0] - (K - 1)
    nb = max(1, -(-n // BLOCK))
    x, xt = _blocked_ext(ext, nb, K, jnp.float32)
    s, st = _blocked_ext(ext_s, nb, K, jnp.int32, fill=-2)
    out = pl.pallas_call(
        functools.partial(_kernel_segment, weights=tuple(weights),
                          center=center, exact=exact),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1, max(K - 1, 1)), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1, max(K - 1, 1)), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * BLOCK,), jnp.float32),
        interpret=interpret,
    )(x, xt, s, st)
    return out[:n]

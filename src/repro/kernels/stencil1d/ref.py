"""Pure-jnp oracle for the stencil1d kernel."""
import jax.numpy as jnp
import numpy as np
from jax import lax


def stencil1d_ref(ext, weights):
    """out[i] = sum_j w[j] * ext[i+j]."""
    K = len(weights)
    n = ext.shape[0] - (K - 1)
    ext = ext.astype(jnp.float32)
    out = jnp.zeros((n,), jnp.float32)
    for j, wj in enumerate(weights):
        out = out + np.float32(wj) * lax.dynamic_slice(ext, (j,), (n,))
    return out

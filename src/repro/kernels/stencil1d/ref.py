"""Pure-jnp oracles for the stencil1d kernels."""
import jax.numpy as jnp
import numpy as np
from jax import lax


def stencil1d_ref(ext, weights):
    """out[i] = sum_j w[j] * ext[i+j]."""
    K = len(weights)
    n = ext.shape[0] - (K - 1)
    ext = ext.astype(jnp.float32)
    out = jnp.zeros((n,), jnp.float32)
    for j, wj in enumerate(weights):
        out = out + np.float32(wj) * lax.dynamic_slice(ext, (j,), (n,))
    return out


def _renorm(acc, mass, weights):
    total = np.float32(sum(float(w) for w in weights))
    safe = jnp.where(mass != 0.0, mass, np.float32(1.0))
    return jnp.where(mass != 0.0, acc * total / safe, np.float32(0.0))


def stencil1d_exact_ref(ext, ext_m, weights):
    """Two plain stencil passes (values + mask mass) and a renormalize."""
    return _renorm(stencil1d_ref(ext, weights),
                   stencil1d_ref(ext_m, weights), weights)


def segment_stencil_ref(ext, ext_s, weights, center, exact=False):
    """Tap loop with segment-id equality masking (the pre-registry lax
    composition from ``physical.segment_stencil1d``)."""
    K = len(weights)
    n = ext.shape[0] - (K - 1)
    ext = ext.astype(jnp.float32)
    sid = lax.dynamic_slice(ext_s, (center,), (n,))
    acc = jnp.zeros((n,), jnp.float32)
    mass = jnp.zeros((n,), jnp.float32)
    for j, wj in enumerate(weights):
        same = lax.dynamic_slice(ext_s, (j,), (n,)) == sid
        acc = acc + np.float32(wj) * jnp.where(same,
                                               lax.dynamic_slice(ext, (j,), (n,)),
                                               np.float32(0.0))
        if exact:
            mass = mass + np.float32(wj) * same.astype(jnp.float32)
    if exact:
        acc = _renorm(acc, mass, weights)
    return acc

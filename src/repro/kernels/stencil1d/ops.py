"""jit'd wrapper for the stencil1d Pallas kernel."""
import functools

import jax

from .stencil1d import stencil1d_pallas


@functools.partial(jax.jit, static_argnames=("w", "interpret"))
def _stencil(ext, w: tuple[float, ...], interpret: bool):
    return stencil1d_pallas(ext, w, interpret=interpret)


def stencil1d(ext, weights, interpret: bool = True):
    return _stencil(ext, tuple(float(x) for x in weights), interpret)

"""jit'd wrappers for the stencil1d Pallas kernels."""
import functools

import jax

from .stencil1d import (segment_stencil_pallas, stencil1d_exact_pallas,
                        stencil1d_pallas)


@functools.partial(jax.jit, static_argnames=("w", "interpret"))
def _stencil(ext, w: tuple[float, ...], interpret: bool):
    return stencil1d_pallas(ext, w, interpret=interpret)


def stencil1d(ext, weights, interpret: bool = True):
    return _stencil(ext, tuple(float(x) for x in weights), interpret)


@functools.partial(jax.jit, static_argnames=("w", "interpret"))
def _stencil_exact(ext, ext_m, w: tuple[float, ...], interpret: bool):
    return stencil1d_exact_pallas(ext, ext_m, w, interpret=interpret)


def stencil1d_exact(ext, ext_m, weights, interpret: bool = True):
    return _stencil_exact(ext, ext_m, tuple(float(x) for x in weights),
                          interpret)


@functools.partial(jax.jit,
                   static_argnames=("w", "center", "exact", "interpret"))
def _segment_stencil(ext, ext_s, w: tuple[float, ...], center: int,
                     exact: bool, interpret: bool):
    return segment_stencil_pallas(ext, ext_s, w, center, exact=exact,
                                  interpret=interpret)


def segment_stencil(ext, ext_s, weights, center, exact=False,
                    interpret: bool = True):
    return _segment_stencil(ext, ext_s, tuple(float(x) for x in weights),
                            int(center), bool(exact), interpret)

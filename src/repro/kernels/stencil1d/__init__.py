from . import ops, ref
from .stencil1d import stencil1d_pallas

"""Pure-jnp oracle for decode attention."""
import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q, k, v, length):
    """q: (B, Hkv, G, hd); k/v: (B, S, Hkv, hd); length: (B,)."""
    hd = q.shape[-1]
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    mask = jnp.arange(k.shape[1])[None, None, None, :] < \
        length[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhgs,bshd->bhgd", p,
                      v.astype(jnp.float32)).astype(q.dtype)

from . import ops, ref
from .decode_attention import decode_attention_pallas

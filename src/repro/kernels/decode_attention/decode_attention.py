"""Pallas kernel: fused single-token GQA decode attention.

The §Roofline analysis shows batched decode is KV-cache-bandwidth bound
(EXPERIMENTS.md §Perf cell 3): each token must stream the whole local cache
once.  This kernel fuses q·K, online softmax, and ·V into ONE pass over the
cache so the bandwidth floor is met with no intermediate (B,H,S) score
materialization in HBM.

Tiling: grid (B, S/S_BLK); TPU executes the grid sequentially in row-major
order, so the S-blocks of one batch row run back-to-back and carry the
online-softmax state (m, l, acc) in VMEM scratch, reset at block 0.  Each
step streams a (S_BLK, Hkv, hd) tile of K and V through VMEM; q for the
current row (Hkv, G, hd) stays resident.  GQA is computed grouped (no KV
head repetition).  Entries at positions >= ``length`` are masked.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

S_BLK = 512


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, n_blocks: int, scale: float):
    sb = pl.program_id(1)

    @pl.when(sb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (Hkv, G, hd)
    k = k_ref[0]                                   # (S_BLK, Hkv, hd)
    v = v_ref[0]
    length = len_ref[0]

    s = jax.lax.dot_general(                        # scores (Hkv, G, S_BLK)
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((2,), (2,)), ((0,), (1,)))) * np.float32(scale)
    pos = sb * S_BLK + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    s = jnp.where(pos < length, s, -1e30)

    m_prev = m_ref[...]                            # (Hkv, G)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=2))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])              # (Hkv, G, S_BLK)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=2)
    pv = jax.lax.dot_general(                      # (Hkv, G, hd)
        p, v.astype(jnp.float32), (((2,), (0,)), ((0,), (1,))))
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_new

    @pl.when(sb == n_blocks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, length, interpret: bool = True):
    """q: (B, Hkv, G, hd); k/v: (B, S, Hkv, hd); length: (B,) valid prefix.

    Returns (B, Hkv, G, hd) attention output."""
    b, hkv, g, hd = q.shape
    s = k.shape[1]
    nb = -(-s // S_BLK)
    pad = nb * S_BLK - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = 1.0 / np.sqrt(hd)
    out = pl.pallas_call(
        functools.partial(_kernel, n_blocks=nb, scale=scale),
        grid=(b, nb),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, hkv, g, hd), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((1, S_BLK, hkv, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, S_BLK, hkv, hd), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hkv, g, hd), lambda i, j: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hkv, g), jnp.float32),      # running max
            pltpu.VMEM((hkv, g), jnp.float32),      # running denom
            pltpu.VMEM((hkv, g, hd), jnp.float32),  # running numerator
        ],
        interpret=interpret,
    )(length, q, k, v)
    return out

"""jit'd wrapper for the decode-attention kernel."""
import functools

import jax

from .decode_attention import decode_attention_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k, v, length, interpret: bool = True):
    return decode_attention_pallas(q, k, v, length, interpret=interpret)

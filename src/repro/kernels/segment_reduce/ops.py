"""jit'd wrapper: per-segment sums via scan-difference at run boundaries."""
import functools

import jax
import jax.numpy as jnp

from .segment_reduce import value_scan_pallas


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def segment_sums(values, seg_id, valid, num_segments: int, interpret: bool = True):
    """Sums of sorted, consecutive segments 0..num_segments-1.

    values: (n,), seg_id: (n,) int32 sorted ascending over the valid prefix.
    Returns (num_segments,) f32 sums (empty segments -> 0).
    """
    n = values.shape[0]
    v = jnp.where(valid, values.astype(jnp.float32), 0.0)
    s = value_scan_pallas(v, interpret=interpret)            # kernel phase
    nxt = jnp.concatenate([seg_id[1:], jnp.full((1,), -1, seg_id.dtype)])
    nxt_valid = jnp.concatenate([valid[1:], jnp.zeros((1,), bool)])
    is_end = valid & ((seg_id != nxt) | ~nxt_valid)
    sid = jnp.where(is_end, seg_id, num_segments)
    # E[k] = scan value at the end of segment k
    sE = jnp.zeros((num_segments + 1,), jnp.float32).at[sid].set(s, mode="drop")
    sE = sE[:num_segments]
    prev = jnp.concatenate([jnp.zeros((1,), jnp.float32), sE[:-1]])
    # empty segments cannot occur by construction (consecutive ids), so the
    # running difference recovers exact segment totals.
    return sE - prev

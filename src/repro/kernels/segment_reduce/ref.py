"""Pure-jnp oracle for segment_reduce."""
import jax
import jax.numpy as jnp


def segment_sums_ref(values, seg_id, valid, num_segments: int):
    """Per-segment sums; seg_id must be sorted and consecutive from 0."""
    v = jnp.where(valid, values.astype(jnp.float32), 0.0)
    sid = jnp.where(valid, seg_id, num_segments)
    return jax.ops.segment_sum(v, sid, num_segments=num_segments + 1)[:num_segments]


def segment_sums_exact(values, seg_id, valid, num_segments: int):
    """Dtype-preserving variant — the registry's `ref` backend.  Matches the
    pre-registry inline composition in ``physical.segment_aggregate`` bit for
    bit (no f32 cast, invalid rows zeroed in the value domain)."""
    v = jnp.where(valid, values, jnp.zeros((), values.dtype))
    return jax.ops.segment_sum(v, seg_id,
                               num_segments=num_segments + 1)[:num_segments]

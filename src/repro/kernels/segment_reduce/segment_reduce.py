"""Pallas kernel: segmented reduction over sorted runs (aggregate backend).

The TPU replacement for the paper's hash-table aggregation: after the shuffle
and local sort, rows with equal keys are contiguous runs.  The kernel computes
a carried inclusive prefix-sum of the values (float32 accumulation); the
wrapper then derives every run's sum as the difference of the scan at run
boundaries — one sequential pass over HBM-streamed blocks, no scatter in the
inner loop (scatters are the VPU's weakness; boundary gathers are tiny).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 2048


def _scan_kernel(v_ref, o_ref, carry):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry[0] = jnp.zeros((), jnp.float32)

    v = v_ref[...].astype(jnp.float32)
    c = jnp.cumsum(v)
    o_ref[...] = c + carry[0]
    carry[0] = carry[0] + c[-1]


def value_scan_pallas(values: jax.Array, interpret: bool = True) -> jax.Array:
    """Inclusive f32 prefix sum of values (the kernel phase)."""
    n = values.shape[0]
    nb = max(1, -(-n // BLOCK))
    vp = jnp.pad(values.astype(jnp.float32), (0, nb * BLOCK - n))
    out = pl.pallas_call(
        _scan_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * BLOCK,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1,), jnp.float32)],
        interpret=interpret,
    )(vp)
    return out[:n]

from . import ops, ref
from .segment_reduce import value_scan_pallas

"""Typed kernel registry: every execution hot-path primitive, two backends.

Each primitive is registered once with a ``ref`` implementation (the pure
lax/jnp composition that used to live inline in ``core/physical.py``) and a
``pallas`` implementation (a fused Pallas kernel from a sibling subpackage).
``core.lower.Lowered`` resolves the whole table to a :class:`KernelSet` from
the single ``ExecConfig.use_pallas`` lever:

  "off"       -> every primitive is its ref composition (bit-for-bit the
                 pre-registry numerics)
  "interpret" -> Pallas kernels under the interpreter (CPU CI / debugging)
  "compiled"  -> Pallas kernels compiled for the accelerator (TPU)

The backends are numerics-only swaps: the physical planner never sees the
mode, so plans, exchanges and collective counts are identical across all
three (asserted by the census gate in ``tests/test_kernel_registry.py``).

Registered primitives and their contracts:

  prefix_sum(x)                         dtype-preserving inclusive scan
  segment_scan(x, boundary)             segmented inclusive scan; boundary
                                        != 0 starts a segment
  segment_rank(seg_b, ord_b, kind)      1-based in-segment ranks (int32);
                                        kind static
  segment_sums(values, seg_id, valid, num_segments)
                                        per-segment sums of the valid prefix
  bucket_scatter(dest, P)               (slot, send_counts): stable
                                        within-bucket slot of every row at
                                        its ORIGINAL position; dest == P
                                        marks invalid rows (slot garbage,
                                        masked by callers)
  stencil1d(ext, weights)               weighted window over an extended
                                        (halo-carrying) array
  stencil1d_exact(ext, ext_m, weights)  stencil + mass renormalize, fused
  segment_stencil(ext, ext_s, weights, center, exact)
                                        partition-masked stencil (+ fused
                                        renormalize when exact)

To add a primitive: ship a ``ref.py`` oracle and a Pallas kernel whose jit'd
wrapper takes a trailing ``interpret`` keyword, then ``register()`` the pair
below.  ``tests/test_kernel_registry.py`` sweeps every registered name, so a
new primitive gets ref-vs-pallas parity coverage for free.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable

MODES = ("off", "interpret", "compiled")


@dataclass(frozen=True)
class KernelSpec:
    """One named primitive with its two backends."""
    name: str
    ref: Callable
    pallas: Callable


_REGISTRY: dict[str, KernelSpec] = {}


def register(name: str, *, ref: Callable, pallas: Callable) -> None:
    if name in _REGISTRY:
        raise ValueError(f"kernel {name!r} already registered")
    _REGISTRY[name] = KernelSpec(name, ref, pallas)


def names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get(name: str) -> KernelSpec:
    return _REGISTRY[name]


class KernelSet:
    """The registry resolved for one backend mode.

    Primitives are attributes: ``kernels.prefix_sum(x)``.  In "off" mode the
    attribute IS the ref callable; otherwise it is the pallas callable with
    ``interpret`` pre-bound, so call sites are mode-oblivious.

    ``overrides`` maps kernel name -> mode, stepping INDIVIDUAL kernels off
    the global mode — the carrier of the per-kernel degradation ladder
    (compiled -> interpret -> off) the retry policy drives on
    :class:`~repro.core.errors.KernelBackendError`.  ``wrap`` is an optional
    ``wrap(name, mode, fn) -> fn`` hook applied to every resolved callable
    (error typing + fault injection, core/lower.py).
    """

    def __init__(self, mode: str, overrides: dict | None = None, wrap=None):
        if mode not in MODES:
            raise ValueError(
                f"use_pallas must be one of {MODES}, got {mode!r}")
        overrides = dict(overrides or {})
        bad = {m for m in overrides.values() if m not in MODES}
        if bad:
            raise ValueError(f"kernel fallback modes must be in {MODES}, "
                             f"got {sorted(bad)}")
        fns = {}
        modes = {}
        for name, spec in _REGISTRY.items():
            m = overrides.get(name, mode)
            if m == "off":
                fn = spec.ref
            else:
                fn = functools.partial(
                    spec.pallas, interpret=(m == "interpret"))
            if wrap is not None:
                fn = wrap(name, m, fn)
            fns[name] = fn
            modes[name] = m
        self.mode = mode
        self.kernel_modes = modes
        self._fns = fns

    def mode_of(self, name: str) -> str:
        """The backend mode ``name`` actually resolves to (after overrides)."""
        return self.kernel_modes[name]

    def __getattr__(self, name):
        try:
            return self.__dict__["_fns"][name]
        except KeyError:
            raise AttributeError(
                f"no kernel {name!r} registered (have: {names()})") from None

    def __repr__(self):
        return f"KernelSet(mode={self.mode!r}, kernels={names()})"


@functools.lru_cache(maxsize=None)
def resolve(mode: str) -> KernelSet:
    """KernelSet for a ``use_pallas`` mode; cached, one instance per mode."""
    return KernelSet(mode)


def resolve_with(mode: str, overrides: dict | None = None,
                 wrap=None) -> KernelSet:
    """KernelSet with per-kernel mode ``overrides`` and an optional ``wrap``
    hook.  Falls back to the cached plain set when neither is given."""
    if not overrides and wrap is None:
        return resolve(mode)
    return KernelSet(mode, overrides, wrap)


DOWNGRADE = {"compiled": "interpret", "interpret": "off", "off": None}
"""The degradation ladder: next-softer backend per mode (None = exhausted)."""


# -- registrations -------------------------------------------------------------

from .hash_partition import ops as _hp_ops, ref as _hp_ref    # noqa: E402
from .segment_rank import ops as _rk_ops, ref as _rk_ref      # noqa: E402
from .segment_reduce import ops as _sr_ops, ref as _sr_ref    # noqa: E402
from .segment_scan import ops as _ss_ops, ref as _ss_ref      # noqa: E402
from .stencil1d import ops as _st_ops, ref as _st_ref         # noqa: E402
from .stream_compact import ops as _sc_ops, ref as _sc_ref    # noqa: E402

register("prefix_sum",
         ref=_sc_ref.prefix_sum_ref, pallas=_sc_ops.prefix_sum)
register("segment_scan",
         ref=_ss_ref.segment_scan_ref, pallas=_ss_ops.segment_scan)
register("segment_rank",
         ref=_rk_ref.segment_rank_ref, pallas=_rk_ops.segment_rank)
register("segment_sums",
         ref=_sr_ref.segment_sums_exact, pallas=_sr_ops.segment_sums)
register("bucket_scatter",
         ref=_hp_ref.bucket_ranks_argsort, pallas=_hp_ops.bucket_ranks)
register("stencil1d",
         ref=_st_ref.stencil1d_ref, pallas=_st_ops.stencil1d)
register("stencil1d_exact",
         ref=_st_ref.stencil1d_exact_ref, pallas=_st_ops.stencil1d_exact)
register("segment_stencil",
         ref=_st_ref.segment_stencil_ref, pallas=_st_ops.segment_stencil)

# The default backend: pure lax compositions (the "off" lever position).
REF = resolve("off")

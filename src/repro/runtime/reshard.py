"""On-device resharding of persisted frames: P -> P' without a host gather.

A persisted frame's columns are ``(P * cap,)`` device arrays with per-shard
valid prefixes (the 1D_VAR carrier).  Re-entering the same data under a
different shard count P' — a serving session restarted on a larger or
smaller mesh, or a registered table shared with a query running at another
parallelism — previously meant ``ScanLayout.gather_host()``: copy every
valid prefix to host numpy, re-pad, re-upload.  This module replaces that
round-trip with a pure device-side gather:

  * the **index map** is computed from the layout's ``counts`` vector alone
    (host metadata, O(P) ints in, one int per row out) — row data never
    leaves the device;
  * the new geometry is the order-preserving balanced re-block: the global
    valid-row stream (shard-0 prefix, then shard-1, ...) is cut into P'
    near-equal contiguous prefixes.  For divisible ratios this degenerates
    to the natural split (each old shard becomes k new ones) / merge (k old
    shards concatenate into one new one);
  * because global row order is preserved, ordering claims survive:
    ``globally_sorted`` + ``sorted_by`` carry over verbatim.  Hash/range
    partitioning claims are shard-count-bound (routing is ``hash % P`` /
    splitter-based) and are dropped — :func:`reshard` can re-establish them
    with ONE on-device exchange (``repartition(keys).persist()`` over the
    already-resharded scan, which is device-valid at P', so the planner
    starts from device shards, not a host table).

Failure behaviour (PR 9 taxonomy): a frame without device buffers
(``counts is None``) raises ``ValueError`` — there is nothing to reshard;
capacity overflow cannot occur (the new capacity is sized from the true
row count).
"""
from __future__ import annotations

import dataclasses as _dc

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..core import ir


def _index_map(counts: np.ndarray, cap_old: int, P_new: int
               ) -> tuple[np.ndarray, np.ndarray, int]:
    """(flat gather indices, new per-shard counts, new capacity) for the
    order-preserving balanced re-block.  Pure counts metadata — no row data.
    """
    cnts = np.asarray(counts, dtype=np.int64)
    P_old = cnts.shape[0]
    total = int(cnts.sum())
    base, rem = divmod(total, P_new)
    counts_new = base + (np.arange(P_new) < rem).astype(np.int64)
    cap_new = max(int(counts_new.max(initial=0)), 1)
    cum = np.concatenate([[0], np.cumsum(cnts)])
    cumn = np.concatenate([[0], np.cumsum(counts_new)])
    pos = np.arange(P_new * cap_new, dtype=np.int64)
    r_new, j = pos // cap_new, pos % cap_new
    # global rank of each output slot's row (invalid slots clamp to a valid
    # rank — their gathered value is masked off by the count vector anyway)
    q = cumn[r_new] + np.minimum(j, np.maximum(counts_new[r_new] - 1, 0))
    q = np.clip(q, 0, max(total - 1, 0))
    src_shard = np.clip(np.searchsorted(cum, q, side="right") - 1, 0,
                        max(P_old - 1, 0))
    idx = src_shard * cap_old + (q - cum[src_shard])
    return idx.astype(np.int32), counts_new.astype(np.int32), cap_new


def reshard(df, P_new: int, cfg=None, *, reestablish: bool = True,
            name: str | None = None):
    """Re-enter a persisted frame's device shards at shard count ``P_new``.

    ``df`` must be a persisted DataFrame (its node an ``ir.Scan`` carrying
    device buffers).  Returns a new persisted frame whose scan is
    ``device_valid(P_new)``.  Ordering claims survive; hash/range claims are
    re-established via one on-device exchange when ``reestablish=True`` and
    ``cfg`` (an ExecConfig for the P_new mesh) is given, else dropped.
    """
    from ..core.api import DataFrame

    node = df.node if isinstance(df, DataFrame) else df
    if not isinstance(node, ir.Scan) or node.layout is None:
        raise ValueError("reshard: input must be a persisted frame "
                         "(df.persist()) whose scan carries a layout")
    lay = node.layout
    if lay.counts is None:
        raise ValueError(
            "reshard: frame has no device shards (host/REP table) — "
            "re-enter it directly; only device layouts need resharding")
    P_new = int(P_new)
    if P_new < 1:
        raise ValueError(f"reshard: invalid shard count {P_new}")
    if lay.nshards == P_new:
        return df if isinstance(df, DataFrame) else DataFrame(node)

    idx, counts_new, cap_new = _index_map(lay.counts, int(lay.capacity),
                                          P_new)
    jidx = jnp.asarray(idx)
    # the gather runs wherever the source shards live; the result is then
    # committed onto the TARGET mesh (device-to-device placement — the rows
    # never surface as host numpy).
    if cfg is not None:
        mesh, axes = cfg.get_mesh(), cfg.axes
        got = int(np.prod([mesh.shape[a] for a in axes]))
        if got != P_new:
            raise ValueError(
                f"reshard: cfg mesh has {got} shard(s), target is {P_new}")
    else:
        mesh, axes = Mesh(np.array(jax.devices()[:P_new]), ("data",)), ("data",)
    sh = NamedSharding(mesh, P(axes))
    cols = {c: jax.device_put(jnp.take(jnp.asarray(v), jidx, axis=0), sh)
            for c, v in node.columns.items()}

    keep_part = lay.kind in ("hash", "range") and bool(lay.partitioned_by)
    new_lay = _dc.replace(
        lay,
        kind="block" if keep_part else lay.kind,
        partitioned_by=() if keep_part else lay.partitioned_by,
        counts=counts_new, capacity=int(cap_new), nshards=P_new,
        dist="1D_VAR")
    scan = ir.Scan(name or f"{node.name}@P{P_new}", cols, layout=new_lay)
    out = DataFrame(scan)
    if keep_part and reestablish and cfg is not None and lay.kind == "hash":
        # one on-device hash exchange re-establishes the partitioning claim
        # at P_new (the intermediate scan is device-valid, so the planner
        # feeds device shards straight through — no host round-trip).
        q = out.repartition(list(lay.partitioned_by))
        if lay.sorted_by:
            q = q.sort_within_partitions(list(lay.sorted_by))
        out = q.persist(cfg, name=scan.name)
    return out

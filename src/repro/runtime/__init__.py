from . import ft
from .faults import FaultPlan
from .ft import FTConfig, TrainDriver, run_with_overflow_retry
from .retry import RetryEvent, RetryPolicy, clear_events, events_for

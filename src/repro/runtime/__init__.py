from . import ft
from .ft import FTConfig, TrainDriver, run_with_overflow_retry

"""Unified capacity-overflow retry + graceful-degradation policy.

One :class:`RetryPolicy` drives every re-execution decision in the engine
(consumed by ``DataFrame.collect()``/``persist()`` and, as a thin shim, by
``ft.run_with_overflow_retry``):

  * **Per-op escalation** (scope="op", the default): a failed run's
    ``DTable.overflow_ops`` attribution (core/lower.py capacity sites) maps
    each overflowed physical-plan op to its observed requirement.  Sites with
    an "abs" strategy report a TRUE upper bound, so one retry at that size
    heals; "double" sites (join/salt expansion) escalate geometrically.  The
    escalation lands as ``ExecConfig.cap_overrides`` floors consumed by
    ``compute_capacities`` — only the overflowed op grows, which is strictly
    fewer retries and smaller buffers than global slack-doubling on skewed
    data (asserted in tests/test_faults.py).
  * **Global escalation** (scope="global", the legacy behaviour): double the
    four capacity knobs (join_expansion, shuffle_slack, stats_cap_slack,
    agg_group_cap) and replan.
  * **Degradation ladder** — never a crash when a softer mode exists:
    ``KernelBackendError`` steps ONE kernel down compiled -> interpret -> off
    (kernels/registry.DOWNGRADE, carried in ``ExecConfig.kernel_fallbacks``);
    a packed-exchange checksum/rowcount invariant failure falls back to the
    unpacked per-column exchange; a stats failure already degraded
    adaptive -> static inside ``lower()`` and surfaces here as an event.
  * **Structured event log**: every retry and degradation step is a
    :class:`RetryEvent`, returned on the DTable (``.events``, the collect
    report) and recorded per plan fingerprint so ``explain()`` can render
    what the last execution of the same plan actually did.

Invariant failures that no ladder step can heal (monotonicity, category code
range, or a checksum mismatch already on the unpacked path) raise a typed
:class:`~repro.core.errors.PlanInvariantError` — corruption is never silent.
"""
from __future__ import annotations

import dataclasses as _dc
from dataclasses import dataclass

from ..core import errors as err


@dataclass(frozen=True)
class RetryEvent:
    """One structured entry in the retry/degradation log.

    kind: "retry" (per-op escalation) | "retry_global" (slack doubling) |
    "degrade_kernel" | "degrade_packed" | "degrade_stats" |
    "overflow_exhausted".
    """

    kind: str
    attempt: int = 0
    op_id: int = -1
    detail: str = ""

    def render(self) -> str:
        op = f" op#{self.op_id}" if self.op_id >= 0 else ""
        return f"[attempt {self.attempt}] {self.kind}{op}: {self.detail}"


# -- per-fingerprint event store (explain() renders the last run's events) ----
# The dict lives on core.stats.StatsStore (the realized-stats store's
# sibling), so a session scopes + persists both through ONE sidecar.


def _strip_rebalance(root):
    from ..core import ir
    while isinstance(root, ir.Rebalance):
        root = root.child
    return root


def record_events(root, events) -> None:
    """Remember a run's retry/degradation events under the plan fingerprint
    (same keying as the realized-stats store: structural, id-free)."""
    if not events:
        return
    from ..core.stats import current_store, plan_fingerprint
    current_store().events[
        plan_fingerprint(_strip_rebalance(root))] = tuple(events)


def events_for(root) -> tuple:
    from ..core.stats import current_store, plan_fingerprint
    return current_store().events.get(
        plan_fingerprint(_strip_rebalance(root)), ())


def clear_events() -> None:
    from ..core.stats import current_store
    current_store().events.clear()


# -- the policy ---------------------------------------------------------------

_PAIR_KINDS = frozenset({"checksum", "rowcount"})


@dataclass
class RetryPolicy:
    """Bounded re-execution: at most ``max_retries`` capacity retries, plus
    degradation steps (each bounded by the ladder depth, so the whole loop
    terminates)."""

    max_retries: int = 3
    scope: str = "op"               # "op" | "global"

    # -- full engine loop (collect/persist) ---------------------------------

    def execute(self, run_once, cfg):
        """Run ``run_once(cfg) -> (lowered, table)`` under the policy.

        Returns ``(lowered, table, events, cfg)`` — the table may still be
        overflow-flagged after exhaustion (collect() hands it back for
        inspection; persist() raises CapacityOverflow from it).  Raises
        PlanInvariantError / KernelBackendError when no ladder step heals.
        """
        events: list[RetryEvent] = []
        attempt = 0
        while True:
            try:
                lowered, t = run_once(cfg)
            except err.KernelBackendError as e:
                cfg2 = self._degrade_kernel(cfg, e, events, attempt)
                if cfg2 is None:
                    raise
                cfg = cfg2
                continue
            for ev in getattr(lowered, "events", ()):
                e = RetryEvent(kind=ev.get("kind", "event"), attempt=attempt,
                               detail=ev.get("detail", ""))
                if e not in events:     # lower() re-emits per build
                    events.append(e)
            fails = tuple(getattr(t, "invariant_failures", ()) or ())
            if fails:
                cfg2 = self._degrade_packed(cfg, fails, events, attempt)
                if cfg2 is None:
                    raise err.PlanInvariantError(fails)
                cfg = cfg2
                continue
            if not getattr(t, "overflow", False):
                t.events = tuple(events)
                return lowered, t, tuple(events), cfg
            if attempt >= self.max_retries:
                events.append(RetryEvent(
                    "overflow_exhausted", attempt,
                    detail=f"{len(t.overflow_ops or {})} op(s) still over "
                           f"capacity after {attempt} retries"))
                t.events = tuple(events)
                return lowered, t, tuple(events), cfg
            cfg = self._escalate(cfg, lowered, t, events, attempt)
            attempt += 1

    # -- ft.run_with_overflow_retry compatibility loop ----------------------

    def run_slack(self, build_and_run, base_slack: float = 2.0):
        """The legacy slack-doubling loop: ``build_and_run(slack)`` returns a
        DTable; overflow doubles the slack.  Returns (table, attempts)."""
        slack = base_slack
        last = base_slack
        for attempt in range(self.max_retries + 1):
            table = build_and_run(slack)
            if not getattr(table, "overflow", False):
                return table, attempt
            last = slack
            slack *= 2.0
        raise err.CapacityOverflow(
            attempts=self.max_retries + 1,
            message=(f"shuffle capacity overflow persisted after "
                     f"{self.max_retries} retries (last slack attempted "
                     f"{last}) — data skew exceeds plan bounds (cf. paper "
                     "Q05 skew discussion)"))

    # -- escalation ----------------------------------------------------------

    def _escalate(self, cfg, lowered, t, events, attempt):
        ops = dict(getattr(t, "overflow_ops", None) or {})
        if self.scope == "op" and ops:
            overrides = dict(getattr(cfg, "cap_overrides", None) or {})
            for op_id, rec in sorted(ops.items()):
                op = lowered.pplan.ops[op_id]
                bucket = int(op.bucket or 0)
                if rec["strategy"] == "double":
                    new_cap = max(int(op.cap), 1) * 2
                    new_bucket = bucket * 2
                else:                   # "abs": observed requirement heals
                    new_cap = max(int(rec["cap_req"]), 1)
                    new_bucket = int(rec["bucket_req"]) if bucket else 0
                prev = overrides.get(op_id, (0, 0))
                overrides[op_id] = (max(new_cap, prev[0]),
                                    max(new_bucket, prev[1]))
                events.append(RetryEvent(
                    "retry", attempt + 1, op_id,
                    f"{rec['kind']} cap {rec['cap']} -> "
                    f"{overrides[op_id][0]}"
                    + (f", bucket {rec['bucket']} -> {overrides[op_id][1]}"
                       if bucket else "")))
            return _dc.replace(cfg, cap_overrides=overrides)
        events.append(RetryEvent(
            "retry_global", attempt + 1,
            detail=f"slack x2: join_expansion -> "
                   f"{max(cfg.join_expansion, 1.0) * 2}, shuffle_slack -> "
                   f"{cfg.shuffle_slack * 2}"))
        return _dc.replace(
            cfg,
            join_expansion=max(cfg.join_expansion, 1.0) * 2,
            shuffle_slack=cfg.shuffle_slack * 2,
            stats_cap_slack=cfg.stats_cap_slack * 2,
            agg_group_cap=(max(1, cfg.agg_group_cap) * 2
                           if cfg.agg_group_cap is not None else None))

    # -- degradation ladder --------------------------------------------------

    def _degrade_kernel(self, cfg, e, events, attempt):
        """One rung down for the failing kernel; None when exhausted."""
        from ..kernels import registry as kreg
        fallbacks = dict(getattr(cfg, "kernel_fallbacks", None) or {})
        nxt = kreg.DOWNGRADE.get(e.backend)
        if nxt is None:
            return None
        fallbacks[e.kernel] = nxt
        events.append(RetryEvent(
            "degrade_kernel", attempt,
            detail=f"{e.kernel}: {e.backend} -> {nxt} ({e.cause})"))
        return _dc.replace(cfg, kernel_fallbacks=fallbacks)

    def _degrade_packed(self, cfg, fails, events, attempt):
        """Packed-exchange payload fault -> unpacked per-column exchange.
        Only pair-check failures are healable this way, and only once."""
        if not getattr(cfg, "packed_exchange", True):
            return None
        if not all(f.kind in _PAIR_KINDS for f in fails):
            return None
        events.append(RetryEvent(
            "degrade_packed", attempt, fails[0].op_id,
            "packed -> unpacked exchange after "
            + "; ".join(f.render() for f in fails)))
        return _dc.replace(cfg, packed_exchange=False)

"""Multi-query serving: a long-lived Session over ONE device mesh.

The paper's compiler model — and every PR before this one — is
one-query-one-process: build the plan, compile the SPMD program, run,
exit.  A serving deployment amortizes all of that across queries instead.
A :class:`Session` owns the mesh for its lifetime and provides:

  * **Shared-table registry** — ``session.register("item", df)`` persists
    the frame once (device shards + layout claims) and hands every later
    query the SAME layout-carrying scan via ``session.table("item")``.
    Frames persisted at a different shard count re-enter through
    :func:`~repro.runtime.reshard.reshard` — an on-device split/merge, no
    host gather.
  * **Plan cache** — compiled executables keyed by the *shape* plan
    fingerprint (``stats.plan_fingerprint(node, scans="shape")``: structure
    + dictionary-aware schemas + layout geometry, NO table identity) plus
    the ExecConfig signature.  A hit replays the compiled ``shard_map``
    executable and merely **rebinds** the scan buffers (``Lowered``'s
    ``scan_nodes`` path), so the same query shape over a different
    registered table costs zero lowers and zero compiles.  LRU eviction at
    ``cache_capacity``; hit/miss/eviction counters via :meth:`stats`.
  * **Concurrent admission** — ``submit()`` is thread-safe and returns a
    ticket; host-side planning/lowering for distinct queries overlaps in a
    small worker pool while a mesh lock serializes device execution
    (SPMD collectives cannot interleave).  ``admission`` bounds queued
    queries; each finished query carries a :class:`QueryRecord` with
    timings, cache outcome, retry events, and the plan's collective count.
  * **Stats persistence** — the session scopes its own
    :class:`~repro.core.stats.StatsStore` (realized row counts + retry
    events) and persists it as ``<session_dir>/stats.json``, so a
    restarted server plans with yesterday's feedback.  A corrupt sidecar
    raises :class:`~repro.core.errors.StatsError` unless
    ``recover_stats=True`` quarantines it and starts cold.

Failure behaviour follows the PR 9 taxonomy: a cache-hit execution that
overflows (the cached capacities were sized for a smaller table) or trips
an invariant/kernel error falls back to the MISS path — replan + the full
retry ladder — and the refreshed entry replaces the stale one.  See
docs/serving.md.
"""
from __future__ import annotations

import dataclasses as _dc
import os
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import numpy as np

from ..core import errors as err
from ..core import ir
from ..core import stats as _st
from ..core.api import DataFrame
from ..core.lower import ExecConfig, Lowered, lower
from . import retry as _rt
from .reshard import reshard as _reshard

_MONO = time.monotonic


def cfg_signature(cfg: ExecConfig, P: int) -> tuple:
    """Hashable signature of every plan-shaping ExecConfig lever.

    The mesh object itself is excluded (not hashable, and two meshes of the
    same shape compile identically); its shard count ``P`` stands in.  Dict
    levers (cap_overrides, kernel_fallbacks) canonicalize to sorted tuples.
    """
    parts: list = [("P", P)]
    for f in _dc.fields(cfg):
        if f.name == "mesh":
            continue
        v = getattr(cfg, f.name)
        if isinstance(v, dict):
            v = tuple(sorted(v.items()))
        elif isinstance(v, (list, set)):
            v = tuple(sorted(v))
        elif not isinstance(v, (str, int, float, bool, tuple, type(None))):
            v = repr(v)
        parts.append((f.name, v))
    return tuple(parts)


@dataclass
class QueryRecord:
    """Per-query serving record (returned by :meth:`Session.collect` via
    ``DTable.query_record`` and listed by :meth:`Session.stats`)."""

    qid: int
    fingerprint: str
    cache: str = "miss"             # "hit" | "miss" | "hit_fallback"
    plan_s: float = 0.0             # host-side planning + lowering
    exec_s: float = 0.0             # device execution (mesh lock held)
    collectives: int = 0            # plan's all_to_all count per execution
    compiles: int = 0               # NEW jit entries this query caused
    events: tuple = ()


@dataclass
class _CacheEntry:
    lowered: Lowered
    scan_ids: tuple                 # pre-optimization scan ids, topo order
    rebindable: bool                # post-opt scans map 1:1 onto pre-opt


class PlanCache:
    """LRU map: (shape fingerprint, ExecConfig signature) -> compiled plan."""

    def __init__(self, capacity: int = 64):
        self.capacity = int(capacity)
        self._d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._lock = threading.Lock()

    def get(self, key) -> Optional[_CacheEntry]:
        with self._lock:
            e = self._d.get(key)
            if e is None:
                self.misses += 1
                return None
            self._d.move_to_end(key)
            self.hits += 1
            return e

    def put(self, key, entry: _CacheEntry) -> None:
        with self._lock:
            self._d[key] = entry
            self._d.move_to_end(key)
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)
                self.evictions += 1

    def __len__(self) -> int:
        return len(self._d)


def _topo_scans(node: ir.Node) -> list[ir.Scan]:
    return [n for n in ir.topo_order(node) if isinstance(n, ir.Scan)]


class Session:
    """A long-lived serving session over one device mesh (docs/serving.md).

    >>> sess = Session(cfg)
    >>> sess.register("item", item_df)          # persist once
    >>> t = sess.collect(q26(sess.table("store_sales"), sess.table("item")))
    >>> sess.stats()["plan_cache"]["hits"]
    """

    def __init__(self, cfg: ExecConfig | None = None,
                 session_dir: str | None = None, *,
                 cache_capacity: int = 64, admission: int = 8,
                 workers: int = 4, recover_stats: bool = False):
        self.cfg = cfg or ExecConfig()
        self.mesh = self.cfg.get_mesh()
        self.P = int(np.prod([self.mesh.shape[a] for a in self.cfg.axes]))
        if self.cfg.mesh is None:
            # pin the session's mesh into its config so every plan/reshard
            # built through the session targets the same devices.
            self.cfg = _dc.replace(self.cfg, mesh=self.mesh)
        self.session_dir = session_dir
        self._sidecar = (os.path.join(session_dir, "stats.json")
                         if session_dir else None)
        self.store = self._load_store(recover_stats)
        # the session's store becomes the process-current store for its
        # lifetime (module-level record_realized/record_events land in it
        # from any worker thread); close() restores the previous one.
        self._prev_store = _st.use_store(self.store)
        self.plan_cache = PlanCache(cache_capacity)
        self._tables: dict[str, DataFrame] = {}
        self._tables_lock = threading.Lock()
        self._mesh_lock = threading.Lock()
        self._admit = threading.BoundedSemaphore(max(admission, 1))
        self._pool = ThreadPoolExecutor(
            max_workers=max(workers, 1), thread_name_prefix="hf-serve")
        self._records: list[QueryRecord] = []
        self._records_lock = threading.Lock()
        self._qid = 0
        self._register_collectives = 0
        self._closed = False

    # -- lifecycle -----------------------------------------------------------

    def _load_store(self, recover: bool) -> _st.StatsStore:
        if not self._sidecar or not os.path.exists(self._sidecar):
            return _st.StatsStore()
        try:
            return _st.StatsStore.load(self._sidecar)
        except err.StatsError:
            if not recover:
                raise
            # quarantine the corrupt sidecar (keep it for inspection) and
            # start cold — recover_stats is the operator's explicit opt-in.
            os.replace(self._sidecar, self._sidecar + ".corrupt")
            return _st.StatsStore()

    def save_stats(self) -> None:
        if self._sidecar:
            self.store.save(self._sidecar)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._pool.shutdown(wait=True)
        self.save_stats()
        _st.use_store(self._prev_store)

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- shared-table registry ----------------------------------------------

    def register(self, name: str, df: DataFrame, *,
                 partition_by=None, sort_by=None) -> DataFrame:
        """Persist ``df`` once under ``name`` and share its layout-carrying
        scan with every later query.

        ``partition_by``/``sort_by`` request a layout (one on-device
        exchange / local sort) before persisting.  An already-persisted
        frame at a different shard count is resharded on device (split or
        merge, never a host gather)."""
        node = df.node
        q = df
        if isinstance(node, ir.Scan) and node.layout is not None \
                and node.layout.counts is not None:
            if node.layout.nshards != self.P:
                q = _reshard(df, self.P, self.cfg, name=name)
            if partition_by or sort_by:
                q = self._relayout(q, partition_by, sort_by, name)
        else:
            if partition_by:
                q = q.repartition(partition_by)
            if sort_by:
                q = q.sort_within_partitions(sort_by)
            q = self._persist(q, name)
        with self._tables_lock:
            self._tables[name] = q
        return q

    def _relayout(self, df, partition_by, sort_by, name):
        q = df
        if partition_by:
            q = q.repartition(partition_by)
        if sort_by:
            q = q.sort_within_partitions(sort_by)
        return self._persist(q, name)

    def _persist(self, df: DataFrame, name: str) -> DataFrame:
        with self._mesh_lock:
            out = df.persist(self.cfg, name=name)
        # registration cost (collectives) is charged to the session, not to
        # the steady-state query mix (the serve smoke's pass-1 total): a
        # host-only re-lower of the same plan yields the collective count.
        try:
            low, _ = lower(df.node, self.cfg, force_rep=df._force_rep())
            self._register_collectives += low.pplan.collective_count()
        except Exception:
            pass
        return out

    def table(self, name: str) -> DataFrame:
        with self._tables_lock:
            if name not in self._tables:
                raise KeyError(
                    f"no table {name!r} registered (have "
                    f"{sorted(self._tables)})")
            return self._tables[name]

    def tables(self) -> dict[str, DataFrame]:
        with self._tables_lock:
            return dict(self._tables)

    # -- query execution -----------------------------------------------------

    def submit(self, df: DataFrame, cfg: ExecConfig | None = None) -> Future:
        """Thread-safe asynchronous admission: returns a Future resolving to
        the DTable.  Host-side planning/lowering overlaps across queries;
        device execution serializes on the mesh lock.  At most ``admission``
        queries are queued/in flight; further submits block."""
        if self._closed:
            raise RuntimeError("session is closed")
        self._admit.acquire()

        def run():
            try:
                return self._run_query(df, cfg or self.cfg)
            finally:
                self._admit.release()

        return self._pool.submit(run)

    def collect(self, df: DataFrame, cfg: ExecConfig | None = None):
        """Synchronous execute-through-the-session (admission + cache)."""
        return self.submit(df, cfg).result()

    def _next_qid(self) -> int:
        with self._records_lock:
            self._qid += 1
            return self._qid

    @staticmethod
    def _rep_key(df: DataFrame) -> tuple:
        """Positional REP pins per scan (``df.replicate()`` changes the plan
        without changing the IR structure, so it must key the cache)."""
        rep = df._force_rep()
        return tuple(n.id in rep for n in _topo_scans(df.node))

    def _run_query(self, df: DataFrame, cfg: ExecConfig):
        qid = self._next_qid()
        fp = _st.plan_fingerprint(df.node, scans="shape")
        key = (fp, cfg_signature(cfg, self.P), self._rep_key(df))
        rec = QueryRecord(qid=qid, fingerprint=fp)
        t0 = _MONO()
        entry = self.plan_cache.get(key)
        if entry is not None and entry.rebindable:
            t = self._try_hit(df, entry, rec, t0)
            if t is not None:
                self._finish(rec, t)
                return t
            rec.cache = "hit_fallback"
        t = self._run_miss(df, cfg, key, rec, t0)
        self._finish(rec, t)
        return t

    def _try_hit(self, df: DataFrame, entry: _CacheEntry, rec: QueryRecord,
                 t0: float):
        """Replay the cached executable with this query's scan buffers.
        Returns None when the entry cannot serve this query (falls back to
        the miss path, which replaces the entry)."""
        lowered = entry.lowered
        new_scans = _topo_scans(df.node)
        if len(new_scans) != len(lowered.scans):
            return None
        scan_nodes = {str(s.id): new_scans[i]
                      for i, s in enumerate(lowered.scans)}
        before = lowered.compiles
        rec.plan_s = _MONO() - t0
        t1 = _MONO()
        try:
            with self._mesh_lock:
                t = lowered(scan_nodes=scan_nodes)
        except (ValueError, err.KernelBackendError, err.PlanInvariantError):
            return None
        if getattr(t, "overflow", False) or getattr(
                t, "invariant_failures", ()):
            # cached capacities were sized for a different table: replan
            return None
        rec.cache = "hit"
        rec.exec_s = _MONO() - t1
        rec.collectives = lowered.pplan.collective_count()
        rec.compiles = lowered.compiles - before
        return t

    def _run_miss(self, df: DataFrame, cfg: ExecConfig, key, rec: QueryRecord,
                  t0: float):
        """Full plan + retry-ladder execution; caches the survivor."""
        policy = _rt.RetryPolicy(max_retries=max(cfg.auto_retry, 0),
                                 scope=getattr(cfg, "retry_scope", "op"))

        timings = {"plan": 0.0, "exec": 0.0}

        def run_once(c):
            # lowering (host-side) runs outside the mesh lock so other
            # queries' planning overlaps; execution serializes.
            ta = _MONO()
            lowered, _ = lower(df.node, c, force_rep=df._force_rep())
            tb = _MONO()
            timings["plan"] += tb - ta
            with self._mesh_lock:
                t = lowered()
            timings["exec"] += _MONO() - tb
            return lowered, t

        lowered, t, events, cfg2 = policy.execute(run_once, cfg)
        if events:
            _rt.record_events(lowered.root, events)
        if cfg2.adaptive_stats and not t.overflow:
            _st.record_realized(lowered.root, np.asarray(t.counts))
        rec.plan_s = timings["plan"]
        rec.exec_s = timings["exec"]
        rec.collectives = lowered.pplan.collective_count()
        rec.compiles = lowered.compiles
        rec.events = tuple(events)
        if not getattr(t, "overflow", False):
            self.plan_cache.put(key, self._make_entry(df, lowered))
        self.save_stats()
        return t

    def _make_entry(self, df: DataFrame, lowered: Lowered) -> _CacheEntry:
        # ``lowered.scans`` is the optimized plan's scans in topo order; the
        # optimizer rewrites scan NODES (column pruning mints new ids) but
        # preserves count and relative order, so a later query with the same
        # shape fingerprint maps its scans onto the cached ones positionally.
        # A plan whose optimization dropped or duplicated scans is cached
        # but not rebindable (hits would mis-wire tables: treat as miss).
        pre_ids = tuple(s.id for s in _topo_scans(df.node))
        post_ids = [s.id for s in lowered.scans]
        rebindable = len(post_ids) == len(pre_ids) == len(set(post_ids))
        return _CacheEntry(lowered, pre_ids, rebindable)

    def _finish(self, rec: QueryRecord, t) -> None:
        rec.events = rec.events or tuple(getattr(t, "events", ()) or ())
        t.query_record = rec
        with self._records_lock:
            self._records.append(rec)

    # -- introspection -------------------------------------------------------

    def stats(self) -> dict:
        with self._records_lock:
            recs = list(self._records)
        pc = self.plan_cache
        return {
            "P": self.P,
            "queries": len(recs),
            "plan_cache": {"hits": pc.hits, "misses": pc.misses,
                           "evictions": pc.evictions, "size": len(pc),
                           "capacity": pc.capacity},
            "compiles": sum(r.compiles for r in recs),
            "collectives": sum(r.collectives for r in recs),
            "register_collectives": self._register_collectives,
            "tables": sorted(self._tables),
            "records": recs,
        }

    def explain(self, df: DataFrame, cfg: ExecConfig | None = None) -> str:
        """Cache-aware EXPLAIN: the plan plus this session's cache outcome
        for the query's key and the last recorded retry events."""
        cfg = cfg or self.cfg
        fp = _st.plan_fingerprint(df.node, scans="shape")
        key = (fp, cfg_signature(cfg, self.P),
               tuple(sorted(n.id in df._force_rep()
                            for n in _topo_scans(df.node))))
        with self.plan_cache._lock:
            cached = key in self.plan_cache._d
        prev = _st.use_store(self.store)
        try:
            from ..core.api import explain as _explain
            body = _explain(df, cfg)
        finally:
            _st.use_store(prev)
        evs = self.store.events.get(_st.plan_fingerprint(df.node), ())
        lines = [f"session: P={self.P} plan_cache="
                 f"{'HIT' if cached else 'MISS'} fingerprint={fp[:12]}",
                 body]
        if evs:
            lines.append("last run events:")
            lines.extend(f"  {e.render()}" for e in evs)
        return "\n".join(lines)

"""Fault-tolerance runtime: preemption-safe training driver, straggler stats,
capacity-overflow retry for the data-frame layer.

On a real pod this process runs per host; here the same control flow runs
single-process.  The three mechanisms the paper's deployment story needs:

1. Checkpoint/restart (HPAT provides this for iterative ML; §2.5): periodic
   async checkpoints + SIGTERM/SIGINT handler that writes a final checkpoint
   before exit (preemption-safe on spot/maintenance events).
2. Straggler detection: per-step wall-time EMA; steps slower than
   ``straggler_factor``x the EMA are counted and surfaced — the hook where a
   cluster controller would trigger hot-spare swap / re-layout.
3. Shuffle-capacity overflow retry: the static-capacity Alltoallv carrier
   (DESIGN.md §2) flags overflow instead of corrupting; the driver re-plans
   with doubled slack and re-executes — turning a hard distributed failure
   mode into a bounded retry.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from ..checkpoint import AsyncSaver, latest_step, restore


@dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    max_retries: int = 3


@dataclass
class StepStats:
    times: list = field(default_factory=list)
    ema: float = 0.0
    stragglers: int = 0

    def record(self, dt: float, factor: float) -> bool:
        self.times.append(dt)
        straggler = self.ema > 0 and dt > factor * self.ema
        self.ema = dt if self.ema == 0 else 0.9 * self.ema + 0.1 * dt
        self.stragglers += int(straggler)
        return straggler


class TrainDriver:
    """Preemption-safe step loop around a compiled train_step."""

    def __init__(self, cfg: FTConfig, state, step_fn: Callable,
                 shardings=None, metadata: dict | None = None):
        self.cfg = cfg
        self.state = state
        self.step_fn = step_fn
        self.shardings = shardings
        self.metadata = metadata or {}
        self.saver = AsyncSaver(cfg.ckpt_dir, keep=cfg.keep)
        self.stats = StepStats()
        self.step = 0
        self._preempted = False
        self._old_handlers = {}

    # -- preemption ---------------------------------------------------------
    def _handler(self, signum, frame):
        self._preempted = True

    def install_signal_handlers(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._old_handlers[sig] = signal.signal(sig, self._handler)

    def restore_signal_handlers(self):
        for sig, h in self._old_handlers.items():
            signal.signal(sig, h)

    # -- resume ---------------------------------------------------------------
    def maybe_resume(self):
        last = latest_step(self.cfg.ckpt_dir)
        if last is not None:
            self.state, self.step, meta = restore(
                self.cfg.ckpt_dir, self.state, shardings=self.shardings)
            return True
        return False

    # -- main loop -------------------------------------------------------------
    def run(self, batches, num_steps: int, log_every: int = 10,
            log_fn=print) -> dict:
        self.install_signal_handlers()
        losses = []
        try:
            for batch in batches:
                if self.step >= num_steps or self._preempted:
                    break
                t0 = time.perf_counter()
                self.state, loss = self.step_fn(self.state, batch)
                loss = float(loss)
                dt = time.perf_counter() - t0
                straggler = self.stats.record(dt, self.cfg.straggler_factor)
                self.step += 1
                losses.append(loss)
                if straggler:
                    log_fn(f"[ft] straggler step {self.step}: {dt:.3f}s "
                           f"(ema {self.stats.ema:.3f}s)")
                if self.step % log_every == 0:
                    log_fn(f"step {self.step} loss {loss:.4f} {dt*1e3:.1f}ms")
                if self.step % self.cfg.ckpt_every == 0:
                    self.saver.save(self.step, self.state, self.metadata)
            if self._preempted:
                log_fn(f"[ft] preemption signal — checkpointing at step {self.step}")
            self.saver.save(self.step, self.state, self.metadata)
            self.saver.wait()
        finally:
            self.restore_signal_handlers()
        return {"steps": self.step, "losses": losses,
                "stragglers": self.stats.stragglers,
                "mean_step_s": float(np.mean(self.stats.times)) if self.stats.times else 0.0}


def run_with_overflow_retry(build_and_run: Callable[[float], Any],
                            base_slack: float = 2.0, max_retries: int = 3):
    """Retry hook for 1D_VAR capacity overflow (DESIGN.md §2).

    ``build_and_run(slack)`` must return a DTable; if its overflow flag is
    set, the plan is rebuilt with doubled slack.  Raises a typed
    ``CapacityOverflow`` (a RuntimeError subclass) after max_retries.

    Thin shim over :class:`runtime.retry.RetryPolicy` — the engine's single
    retry implementation; kept for API compatibility with external drivers.
    """
    from .retry import RetryPolicy
    return RetryPolicy(max_retries=max_retries, scope="global").run_slack(
        build_and_run, base_slack)

"""Deterministic fault injection for the execution guardrails.

A :class:`FaultPlan` on ``ExecConfig.fault_inject`` arms injection points in
well-defined places so the chaos suite (tests/test_faults.py) can PROVE the
failure handling works instead of waiting for real skew/backend bugs:

  * ``force_overflow`` — force the overflow flag of matching capacity sites
    (by physical-plan op id or op class name, e.g. ``"HashExchange"``).
    ``overflow_shots`` bounds how many plan BUILDS are affected, so the
    retry loop heals once the shots are consumed: the data is never touched,
    only the flag, which exercises the exact attribution/escalation path a
    real overflow takes.
  * ``fail_kernel`` — raise :class:`~repro.core.errors.KernelBackendError`
    when the named kernel is resolved on one of ``fail_modes``; the
    degradation ladder steps that kernel down (compiled -> interpret -> ref)
    and the query still answers.
  * ``corrupt_exchange`` — flip a value in the first output column of
    matching exchanges (row 0, valid rows only): the model of a packed-payload
    bug.  ``ExecConfig.validate`` checksums catch it; by default the
    corruption only fires while ``packed_exchange`` is on, so the
    packed -> unpacked degradation heals the query.  Set
    ``corrupt_packed_only=False`` to model a bug the fallback does NOT fix —
    the run then ends in a typed :class:`PlanInvariantError`.
  * ``poison_stats`` — sabotage the adaptive statistics pass: ``"ndv"``
    clamps the distinct-count buffer bound to 1 (undersized PartialAgg,
    healed by the per-op overflow retry); ``"raise"`` makes the pass raise
    :class:`~repro.core.errors.StatsError` (lowering degrades to static
    planning and logs a degradation event).

Injection is config-scoped and deterministic — no randomness, no globals —
so every chaos test replays bit-identically.  A plan built with
``fault_inject=None`` is byte-identical to one built without the feature
(census-gated in tests/test_faults.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class FaultPlan:
    """Injection points, all disarmed by default."""

    # capacity sites whose overflow flag is forced: physical-plan op ids
    # (int) and/or op class names (str, e.g. "HashExchange", "PartialAgg").
    force_overflow: tuple = ()
    # plan builds affected by force_overflow before it disarms (a retry then
    # heals); negative = every build (the give-up / typed-error path).
    overflow_shots: int = 1
    # kernel registry: raise KernelBackendError when this kernel resolves on
    # one of fail_modes ("compiled"/"interpret"; include "off" to make even
    # the ref backend fail — the ladder then exhausts and re-raises).
    fail_kernel: str = ""
    fail_modes: tuple = ("compiled", "interpret")
    # exchanges whose first output column gets one value flipped (op ids
    # and/or class names, like force_overflow).
    corrupt_exchange: tuple = ()
    # corruption only fires under packed_exchange=True (the packed->unpacked
    # degradation then heals); False keeps corrupting after the fallback.
    corrupt_packed_only: bool = True
    # adaptive statistics sabotage: "" (off) | "ndv" | "raise".
    poison_stats: str = ""

    _overflow_spent: int = field(default=0, repr=False, compare=False)

    # -- site matching -------------------------------------------------------

    @staticmethod
    def _matches(spec: tuple, op) -> bool:
        return any((isinstance(s, int) and s == op.op_id)
                   or (isinstance(s, str) and type(op).__name__ == s)
                   for s in spec)

    def take_overflow_sites(self, ops) -> frozenset:
        """Op ids to force-overflow in the NEXT plan build; consumes one
        shot.  Called once per ``Lowered`` build."""
        if not self.force_overflow:
            return frozenset()
        if self.overflow_shots >= 0:
            if self._overflow_spent >= self.overflow_shots:
                return frozenset()
            self._overflow_spent += 1
        return frozenset(op.op_id for op in ops
                         if self._matches(self.force_overflow, op))

    def corrupt_sites(self, ops, packed: bool) -> frozenset:
        """Op ids whose exchange output gets corrupted in this build."""
        if not self.corrupt_exchange:
            return frozenset()
        if self.corrupt_packed_only and not packed:
            return frozenset()
        return frozenset(op.op_id for op in ops
                         if self._matches(self.corrupt_exchange, op))

    def kernel_fails(self, name: str, mode: str) -> bool:
        return bool(self.fail_kernel) and name == self.fail_kernel \
            and mode in self.fail_modes

"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1, pod_axis: int = 0):
    """Mesh over whatever devices exist (tests/examples).

    Shapes to (data, model) or (pod, data, model) with the requested model
    axis; data absorbs the rest.
    """
    devs = np.array(jax.devices())
    n = len(devs)
    assert n % max(model_axis, 1) == 0
    if pod_axis:
        data = n // (model_axis * pod_axis)
        return Mesh(devs.reshape(pod_axis, data, model_axis),
                    ("pod", "data", "model"))
    data = n // max(model_axis, 1)
    return Mesh(devs.reshape(data, max(model_axis, 1)), ("data", "model"))


def mesh_chip_count(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))

"""Production training launcher: --arch <id> on the local (or production) mesh.

Wires every substrate layer together: arch config -> sharded params/optimizer
-> HiFrames data pipeline -> FT driver (async checkpoints, preemption safety,
straggler stats).  On this CPU container use --reduced (the full configs are
exercised by the dry-run); on a real pod drop --reduced and point --mesh at
make_production_mesh.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
        --steps 20 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import ShapeSpec
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.data.synth import token_corpus
from repro.launch import steps as S
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import lm, moe as moe_mod
from repro.optim import OptConfig, adamw
from repro.runtime import FTConfig, TrainDriver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--moe-impl", default=None, choices=["gspmd", "ep"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch) if args.reduced \
        else configs.get_config(args.arch)
    if args.moe_impl:
        cfg = cfg.replace(moe_impl=args.moe_impl)
    if cfg.family == "encdec":
        raise SystemExit("use whisper-specific driver for encdec training demo")

    mesh = make_production_mesh() if args.production_mesh \
        else make_local_mesh(model_axis=args.model_axis)
    moe_mod.set_ep_mesh(mesh)
    print(f"mesh {dict(mesh.shape)}; model {cfg.name} "
          f"{cfg.param_count()/1e6:.1f}M params")

    shape = ShapeSpec("cli", "train", args.seq, args.batch)
    ocfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                     total_steps=args.steps)
    cell = S.cell_shardings(cfg, shape, mesh, ocfg)

    params = jax.device_put(lm.init_params(cfg, jax.random.PRNGKey(0)),
                            cell["params"])
    opt = adamw.init_state(params, ocfg)
    state = {"params": params, "opt": opt}
    step_fn = jax.jit(S.make_train_step(cfg, ocfg, n_micro=args.micro))

    corpus = token_corpus(2_000, cfg.vocab)
    pipe = TokenPipeline(corpus, PipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))

    driver = TrainDriver(FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50),
                         state, step_fn, metadata={"arch": args.arch})
    if args.resume and driver.maybe_resume():
        print(f"resumed at step {driver.step}")

    def batches():
        for b in pipe:
            out = {"tokens": jnp.asarray(b["tokens"]),
                   "labels": jnp.asarray(b["labels"])}
            if cfg.family == "vlm":
                B, Sq = out["tokens"].shape
                out["inputs_embeds"] = jnp.zeros((B, Sq, cfg.d_model),
                                                 jnp.bfloat16)
                out["positions"] = jnp.broadcast_to(
                    jnp.arange(Sq, dtype=jnp.int32)[None, None], (3, B, Sq))
                out["tokens"] = None
            yield out

    res = driver.run(batches(), num_steps=args.steps, log_every=5)
    pipe.close()
    print(f"done: {res['steps']} steps, loss {res['losses'][0]:.3f} -> "
          f"{res['losses'][-1]:.3f}, {res['mean_step_s']*1e3:.0f} ms/step")


if __name__ == "__main__":
    main()

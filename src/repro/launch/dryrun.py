import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import — jax locks the
# device count at first init.  (This also forces the docstring below them.)

_DOC = """Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and extract the roofline terms.

Run:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --out runs/dryrun

Per cell this produces:
  * compiled.memory_analysis()  -> bytes per device (proves it fits / doesn't)
  * compiled.cost_analysis()    -> per-device HLO FLOPs & bytes
  * collective bytes parsed from the post-SPMD HLO text
  * an L-extrapolation pair (layers scanned => XLA costs the While body ONCE;
    we compile L_small/L_big variants and scale the per-layer delta — see
    EXPERIMENTS.md §Dry-run methodology)
and dumps JSON consumed by launch/roofline.py.
"""
import argparse
import json
import re
import time
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.models import sharding as shard_mod
from repro.optim import OptConfig

# ---------------------------------------------------------------------------
# HLO collective-byte accounting
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum per-device operand bytes of every collective op in the partitioned
    module.  Start/done pairs are counted once (on the -start)."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        # match "  %name = TYPE[dims] op-name(" or fused start variants
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]*?)\s*"
                     r"([a-z\-]+)(-start)?\(", ls)
        if not m:
            continue
        op = m.group(2)
        if m.group(3) == "-start" or op.endswith("-done"):
            base = op.replace("-done", "")
            if op.endswith("-done"):
                continue  # counted at start
            op = base
        if op not in _COLLECTIVES:
            continue
        out[op] += _shape_bytes(m.group(1))
        out["count"] += 1
    return out


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------


def _logits_sharding(mesh, cfg, batch):
    return NamedSharding(mesh, shard_mod.fit_spec(
        mesh, (batch, cfg.vocab),
        (shard_mod.dp_axes(mesh), "model")))


def lower_cell(arch: str, shape_name: str, mesh, *,
               cfg_override=None, n_micro=None, moe_impl: str = "gspmd",
               fsdp: bool = False):
    """Lower one (arch, shape) on the given mesh; returns (lowered, meta)."""
    cfg = cfg_override or configs.get_config(arch)
    if moe_impl != "gspmd":
        from repro.models import moe as moe_mod
        moe_mod.set_ep_mesh(mesh)
        cfg = cfg.replace(moe_impl=moe_impl)
    if getattr(cfg, "attn_batch_shard", False):
        from repro.models import lm as lm_mod
        lm_mod.set_tp_mesh(mesh)
    if getattr(cfg, "attn_seq_shard", False) or \
            getattr(cfg, "cache_update", "dus") == "masked":
        from repro.models import layers as layers_mod
        layers_mod.set_tp_mesh(mesh)
    shape = configs.SHAPES[shape_name]
    ocfg = OptConfig(state_dtype="bfloat16" if cfg.param_count() > 2e11
                     else "float32", zero1=True)
    cell = steps_mod.cell_shardings(cfg, shape, mesh, ocfg, fsdp=fsdp)
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        nm = n_micro if n_micro is not None else steps_mod.micro_batches(cfg, shape, mesh)
        fn = steps_mod.make_train_step(cfg, ocfg, n_micro=nm)
        state_specs = {"params": cell["param_specs"], "opt": cell["opt_specs"]}
        state_sh = {"params": cell["params"], "opt": cell["opt_sh"]}
        jf = jax.jit(fn, in_shardings=(state_sh, cell["input_sh"]),
                     out_shardings=(state_sh, rep))
        lowered = jf.lower(state_specs, cell["inputs"])
        meta = {"n_micro": nm}
    elif shape.kind == "prefill":
        fn = steps_mod.make_prefill_step(cfg, shape.seq)
        csh = shard_mod.cache_shardings(mesh, steps_mod.cache_specs(cfg, shape))
        jf = jax.jit(fn, in_shardings=(cell["params"], cell["input_sh"]),
                     out_shardings=(_logits_sharding(mesh, cfg, shape.batch), csh))
        lowered = jf.lower(cell["param_specs"], cell["inputs"])
        meta = {}
    else:  # decode
        fn = steps_mod.make_decode_step(cfg)
        csh = cell["cache_sh"]
        jf = jax.jit(fn, in_shardings=(cell["params"], cell["input_sh"]["token"], csh),
                     out_shardings=(_logits_sharding(mesh, cfg, shape.batch), csh))
        lowered = jf.lower(cell["param_specs"], cell["inputs"]["token"],
                           cell["cache_specs"])
        meta = {}
    return lowered, cfg, meta


def analyse(lowered, compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_d = {"error": str(e)}
    text = compiled.as_text()
    coll = collective_bytes(text)
    return {
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_per_device": cost.get("bytes accessed", 0.0),
        "memory": mem_d,
        "collectives": coll,
    }


# ---------------------------------------------------------------------------
# L-extrapolation (scan bodies are costed once by XLA)
# ---------------------------------------------------------------------------


def l_pair(cfg, seq: int = 4096):
    """(cfg_small, cfg_big, units_small, units_big, units_full).

    The pair is compiled with unroll_scans=True (XLA costs While bodies once)
    and coarser KV/SSM chunks to bound unrolled-HLO size (zamba2's 64-chunk
    scan x 12 unrolled layers otherwise explodes compile time; chunk size
    does not change FLOPs/bytes totals)."""
    cfg = cfg.replace(unroll_scans=True,
                      kv_chunk=max(1024, seq // 16))
    if cfg.family in ("ssm", "hybrid"):
        cfg = cfg.replace(ssm_chunk=max(cfg.ssm_chunk, seq // 4, 256))
    f = cfg.family
    if f == "moe":
        fd = max(cfg.first_dense_layers, 0)
        return (cfg.replace(n_layers=fd + 1), cfg.replace(n_layers=fd + 2),
                fd + 1, fd + 2, cfg.n_layers)
    if f == "hybrid":
        p = cfg.shared_attn_period or cfg.n_layers
        return (cfg.replace(n_layers=p), cfg.replace(n_layers=2 * p),
                p, 2 * p, cfg.n_layers)
    if f == "encdec":
        return (cfg.replace(n_layers=1, n_enc_layers=1),
                cfg.replace(n_layers=2, n_enc_layers=2), 1, 2, cfg.n_layers)
    return (cfg.replace(n_layers=1), cfg.replace(n_layers=2), 1, 2,
            cfg.n_layers)


def extrapolate(c_small: dict, c_big: dict, us: int, ub: int, uf: int,
                n_micro: int = 1) -> dict:
    """Total-cost estimate from the L-pair (per device)."""
    out = {}
    for key in ("flops_per_device", "bytes_per_device"):
        delta = (c_big[key] - c_small[key]) / max(ub - us, 1)
        out[key] = (c_small[key] + delta * (uf - us)) * n_micro
    coll = {}
    for k in _COLLECTIVES:
        delta = (c_big["collectives"][k] - c_small["collectives"][k]) / max(ub - us, 1)
        coll[k] = (c_small["collectives"][k] + delta * (uf - us)) * n_micro
    out["collective_bytes_per_device"] = coll
    out["collective_total"] = float(sum(coll.values()))
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str,
             skip_full: bool = False, skip_extrap: bool = False,
             verbose: bool = True, moe_impl: str = "gspmd") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chip_count(mesh)
    cfg = configs.get_config(arch)
    shape = configs.SHAPES[shape_name]
    ok, why = configs.shape_applicable(cfg, shape)
    tag = f"{arch}/{shape_name}/{'2pod' if multi_pod else '1pod'}"
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
               "skipped": why}
        _dump(out_dir, tag, rec)
        if verbose:
            print(f"[dryrun] {tag}: {why}")
        return rec

    t0 = time.time()
    rec: dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "multi_pod": multi_pod, "chips": chips}

    rec["moe_impl"] = moe_impl
    # 1. FULL config compile — proves lowering + sharding + memory
    if not skip_full:
        lowered, _, meta = lower_cell(arch, shape_name, mesh, moe_impl=moe_impl)
        compiled = lowered.compile()
        rec["full"] = analyse(lowered, compiled)
        rec["full"]["compile_s"] = round(time.time() - t0, 2)
        rec.update(meta)
        if verbose:
            m = rec["full"]["memory"]
            print(f"[dryrun] {tag}: compiled in {rec['full']['compile_s']}s; "
                  f"args={_gb(m.get('argument_bytes'))} "
                  f"temp={_gb(m.get('temp_bytes'))} "
                  f"flops/dev={rec['full']['flops_per_device']:.3e}")

    # 2. L-extrapolation pair (cheap compiles; true total cost).
    # The multi-pod pass proves the pod axis shards (full compile above);
    # the roofline table is single-pod only, so extrapolation can be skipped.
    if skip_extrap:
        rec["wall_s"] = round(time.time() - t0, 2)
        _dump(out_dir, tag, rec)
        return rec
    small, big, us, ub, uf = l_pair(cfg, seq=shape.seq)
    res = []
    for c in (small, big):
        # NOTE: train L-pairs run with n_micro=1 over the FULL global batch,
        # so their costs are already whole-step costs — no micro scaling.
        lw, _, _ = lower_cell(arch, shape_name, mesh, cfg_override=c,
                              n_micro=1 if shape.kind == "train" else None,
                              moe_impl=moe_impl)
        res.append(analyse(lw, lw.compile()))
    rec["l_extrap"] = extrapolate(res[0], res[1], us, ub, uf, n_micro=1)
    rec["l_pair"] = {"small": res[0], "big": res[1],
                     "units": [us, ub, uf], "n_micro_scale": 1}
    rec["wall_s"] = round(time.time() - t0, 2)
    _dump(out_dir, tag, rec)
    if verbose:
        print(f"[dryrun] {tag}: extrapolated flops/dev="
              f"{rec['l_extrap']['flops_per_device']:.3e} "
              f"coll={_gb(rec['l_extrap']['collective_total'])} "
              f"({rec['wall_s']}s total)")
    return rec


def _gb(x):
    return "n/a" if x is None else f"{x/2**30:.2f}GiB"


def _dump(out_dir: str, tag: str, rec: dict):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, tag.replace("/", "__") + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-full", action="store_true",
                    help="only the L-extrapolation compiles (fast)")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose JSON already exists")
    ap.add_argument("--moe-impl", default="gspmd", choices=["gspmd", "ep"])
    ap.add_argument("--skip-extrap", action="store_true",
                    help="full compile only (multi-pod proof pass)")
    ap.add_argument("--tag", default="", help="suffix for output filenames")
    ap.add_argument("--out", default="runs/dryrun")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = []
    if args.all:
        for a in configs.ARCH_IDS:
            for s in configs.SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    failures = []
    for mp in meshes:
        for a, s in cells:
            tag = f"{a}__{s}__{'2pod' if mp else '1pod'}.json"
            if args.resume and os.path.exists(os.path.join(args.out, tag)):
                continue
            try:
                run_cell(a, s, multi_pod=mp, out_dir=args.out,
                         skip_full=args.skip_full, skip_extrap=args.skip_extrap,
                         moe_impl=args.moe_impl)
            except Exception as e:  # noqa: BLE001
                print(f"[dryrun] FAIL {a}/{s}/mp={mp}: {type(e).__name__}: {e}")
                failures.append((a, s, mp, str(e)))
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: "
                         + "; ".join(f"{a}/{s}" for a, s, _, _ in failures))
    print("[dryrun] all requested cells compiled")


if __name__ == "__main__":
    main()

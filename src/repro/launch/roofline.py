"""Roofline analysis — reads the dry-run JSONs and derives the three terms.

    compute term    = HLO_FLOPs   / (chips x 197 TFLOP/s bf16)
    memory term     = HLO_bytes   / (chips x 819 GB/s HBM)
    collective term = coll_bytes  / (chips x 50 GB/s per-link ICI)

HLO quantities come from the L-extrapolated unrolled compiles (per-device,
so the chip division is implicit); collective bytes are summed over
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
operands in the post-SPMD module.  MODEL_FLOPS is the analytic 6·N_active·D
(train) or 2·N_active·D (inference); the ratio against HLO_FLOPs exposes
remat/dispatch/resharding waste.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline --in runs/dryrun --md
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro import configs

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link (per-device collective bytes / link)
HBM_CAP = 16 * 2**30         # v5e

# wire-traffic factors: ring all-reduce moves ~2x its operand bytes
# (reduce-scatter + all-gather phases); the others move ~1x.
WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def model_flops_cell(cfg, shape) -> float:
    """Analytic useful FLOPs per device per step."""
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        total = 6.0 * n_act * tokens
    elif shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        total = 2.0 * n_act * tokens
    else:  # decode: one token per sequence + KV-cache attention reads
        total = 2.0 * n_act * shape.batch
        if cfg.family not in ("ssm",):
            kv = 2 * cfg.n_kv_heads * cfg.hd
            att_layers = cfg.n_layers if cfg.family != "hybrid" else \
                -(-cfg.n_layers // (cfg.shared_attn_period or cfg.n_layers))
            total += 2.0 * shape.batch * shape.seq * kv * att_layers \
                * (cfg.n_heads // max(cfg.n_kv_heads, 1))
    return total


def _re_extrapolate(rec: dict) -> dict:
    """Recompute total cost from the raw L-pair (the pair is compiled over
    the FULL global batch, so costs are whole-step — no micro scaling)."""
    lp = rec["l_pair"]
    us, ub, uf = lp["units"]
    out = {}
    for key in ("flops_per_device", "bytes_per_device"):
        delta = (lp["big"][key] - lp["small"][key]) / max(ub - us, 1)
        out[key] = lp["small"][key] + delta * (uf - us)
    coll = {}
    for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute"):
        delta = (lp["big"]["collectives"][k] - lp["small"]["collectives"][k]) \
            / max(ub - us, 1)
        coll[k] = lp["small"]["collectives"][k] + delta * (uf - us)
    out["collective_bytes_per_device"] = coll
    return out


def analyse_record(rec: dict) -> dict | None:
    if "skipped" in rec:
        return None
    cfg = configs.get_config(rec["arch"])
    shape = configs.SHAPES[rec["shape"]]
    chips = rec["chips"]
    ex = _re_extrapolate(rec)
    flops_dev = ex["flops_per_device"]
    bytes_dev = ex["bytes_per_device"]
    coll_dev = sum(WIRE_FACTOR.get(k, 1.0) * v
                   for k, v in ex["collective_bytes_per_device"].items())

    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())

    mf = model_flops_cell(cfg, shape) / chips
    mem = rec.get("full", {}).get("memory", {})
    resident = (mem.get("argument_bytes") or 0)
    peak = resident + (mem.get("temp_bytes") or 0)

    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "2x16x16" if rec["multi_pod"] else "16x16",
        "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "roofline_fraction": t_comp / bound if bound > 0 else 0.0,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": flops_dev,
        "useful_ratio": mf / flops_dev if flops_dev else 0.0,
        "hbm_resident_gib": resident / 2**30,
        "hbm_peak_gib": peak / 2**30,
        "fits_hbm": peak <= HBM_CAP,
        "n_micro": rec.get("n_micro", 1),
        "collectives": ex.get("collective_bytes_per_device", {}),
    }


def load(in_dir: str, mesh_filter: str | None = "1pod"):
    rows, skips = [], []
    for path in sorted(glob.glob(os.path.join(in_dir, "*.json"))):
        if mesh_filter and mesh_filter not in path:
            continue
        with open(path) as f:
            rec = json.load(f)
        if "skipped" in rec:
            skips.append(rec)
            continue
        r = analyse_record(rec)
        if r:
            rows.append(r)
    return rows, skips


def what_would_help(r: dict) -> str:
    d = r["dominant"]
    if d == "collective":
        big = max(r["collectives"], key=lambda k: r["collectives"].get(k, 0)) \
            if r["collectives"] else "?"
        return f"cut {big} volume (resharding/dispatch schedule)"
    if d == "memory":
        return "fuse/bigger per-step tiles; reduce remat traffic"
    return "already compute-bound; raise useful_ratio " \
           f"({r['useful_ratio']:.2f})"


def to_markdown(rows, skips) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | roofline frac | useful ratio | HBM GiB (resident/peak) "
           "| fits | next lever |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['roofline_fraction']:.2f} | {r['useful_ratio']:.2f} "
            f"| {r['hbm_resident_gib']:.1f}/{r['hbm_peak_gib']:.1f} "
            f"| {'Y' if r['fits_hbm'] else 'N'} | {what_would_help(r)} |")
    for s in skips:
        lines.append(f"| {s['arch']} | {s['shape']} | — | — | — | — | — | — "
                     f"| — | — | — | {s['skipped']} |")
    return hdr + "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="in_dir", default="runs/dryrun")
    ap.add_argument("--mesh", default="1pod", choices=["1pod", "2pod", "all"])
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    rows, skips = load(args.in_dir,
                       None if args.mesh == "all" else f"{args.mesh}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)
    if args.md:
        print(to_markdown(rows, skips))
    else:
        for r in rows:
            print(f"{r['arch']:20s} {r['shape']:12s} {r['dominant']:10s} "
                  f"frac={r['roofline_fraction']:.2f} "
                  f"useful={r['useful_ratio']:.2f} "
                  f"peak={r['hbm_peak_gib']:.1f}GiB")


if __name__ == "__main__":
    main()

from . import mesh, steps

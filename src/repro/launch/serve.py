"""Serving launcher: batched prefill + decode for any --arch (reduced on CPU).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 4 --new-tokens 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as S
from repro.models import lm, whisper


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch)
    B, Sp, T = args.requests, args.prompt_len, args.new_tokens
    max_seq = Sp + T
    rng = np.random.default_rng(0)

    if cfg.family == "encdec":
        params = whisper.init_params(cfg, jax.random.PRNGKey(0))
        frames = jnp.asarray(rng.normal(size=(B, cfg.enc_frames, cfg.d_model))
                             .astype(np.float32), jnp.bfloat16)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, Sp)).astype(np.int32))
        t0 = time.perf_counter()
        lg, cache = whisper.prefill(params, frames, toks, cfg, max_seq)
        step = jax.jit(S.make_decode_step(cfg))
        outs = []
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        for _ in range(T):
            outs.append(np.asarray(tok[:, 0]))
            lg, cache = step(params, tok, cache)
            tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        dt = time.perf_counter() - t0
    else:
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, Sp)).astype(np.int32))
        prefill = jax.jit(S.make_prefill_step(cfg, max_seq))
        step = jax.jit(S.make_decode_step(cfg))
        batch = {"tokens": prompts}
        if cfg.family == "vlm":
            batch = {"inputs_embeds": jnp.zeros((B, Sp, cfg.d_model), jnp.bfloat16),
                     "positions": jnp.broadcast_to(
                         jnp.arange(Sp, dtype=jnp.int32)[None, None], (3, B, Sp))}
        t0 = time.perf_counter()
        lg, cache = prefill(params, batch)
        outs = []
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        for _ in range(T):
            outs.append(np.asarray(tok[:, 0]))
            lg, cache = step(params, tok, cache)
            tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        dt = time.perf_counter() - t0

    gen = np.stack(outs, axis=1)
    print(f"{args.arch} (reduced): {B} reqs, prompt {Sp}, generated {T} "
          f"tokens each in {dt*1e3:.0f} ms")
    print("req0:", gen[0])
    assert gen.shape == (B, T) and np.isfinite(gen).all()


if __name__ == "__main__":
    main()

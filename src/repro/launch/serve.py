"""Serving entrypoint: a long-lived dataframe session over one mesh.

Boots a :class:`~repro.runtime.session.Session`, registers the synthetic
TPCx-BB tables with serving layouts (store_sales hash-partitioned on the
join key, item replicated), and replays a Q26-shaped query mix through the
session's plan cache.  Two modes:

  * default — one pass over the mix, then print session stats (plan-cache
    hit rate, compiles, collectives, per-query timings);
  * ``--smoke`` — the CI gate: replay the mix TWICE and assert the serving
    contract (docs/serving.md): every second-pass query HITS the plan
    cache with ZERO new compiles, and the second pass issues strictly
    fewer collectives than the first (pass 1 pays registration).  Exits
    nonzero on violation.

Run on N fake devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m repro.launch.serve --smoke
"""
from __future__ import annotations

import argparse
import sys
import tempfile

from repro import hiframes as hf
from repro.core.api import DataFrame, ExecConfig
from repro.data import synth
from repro.runtime.session import Session


def build_mix(sess: Session) -> list:
    """The replayed query mix: Q26 (join + aggregate + filter), a grouped
    top-up aggregate, and a global leaderboard rank — three distinct plan
    shapes exercising join, aggregation, and the global-window path."""
    ss, it = sess.table("store_sales"), sess.table("item")

    def q26() -> DataFrame:
        j = ss.merge(it, on=("ss_item_sk", "i_item_sk"))
        c_i = (j.groupby("ss_customer_sk")
               .agg(c_i_count="count",
                    id1=hf.sum_(j["i_class_id"] == 1),
                    id2=hf.sum_(j["i_class_id"] == 2)))
        return c_i[c_i["c_i_count"] > 4]

    def per_item() -> DataFrame:
        return ss.groupby("ss_item_sk").agg(paid=("ss_net_paid", "sum"),
                                            n=("ss_net_paid", "count"))

    def leaderboard() -> DataFrame:
        per = ss.groupby("ss_customer_sk").agg(spend=("ss_net_paid", "sum"))
        return hf.rank(per, [], ["spend"], out="r", ascending=False)

    return [q26, per_item, leaderboard]


def register_tables(sess: Session, scale: float, seed: int = 0) -> None:
    n_sales = max(int(200_000 * scale), 2_000)
    n_items = max(int(2_000 * scale), 64)
    n_cust = max(int(10_000 * scale), 128)
    ss = synth.store_sales(n_sales, n_items, n_cust, seed=seed)
    it = synth.item(n_items, seed=seed + 1)
    sess.register("store_sales", hf.table(ss, "store_sales"),
                  partition_by="ss_item_sk")
    sess.register("item", hf.table(it, "item").replicate())


def run_pass(sess: Session, mix, repeats: int = 2) -> dict:
    """Submit the whole mix (each query ``repeats`` times) through the
    session's concurrent admission and collect per-pass totals."""
    futures = [sess.submit(q()) for _ in range(repeats) for q in mix]
    recs = [f.result().query_record for f in futures]
    return {"queries": len(recs),
            "hits": sum(r.cache == "hit" for r in recs),
            "compiles": sum(r.compiles for r in recs),
            "collectives": sum(r.collectives for r in recs),
            "plan_s": sum(r.plan_s for r in recs),
            "exec_s": sum(r.exec_s for r in recs)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scale", type=float, default=0.05,
                    help="synthetic data scale factor")
    ap.add_argument("--repeats", type=int, default=2,
                    help="times each mix query runs per pass")
    ap.add_argument("--session-dir", default=None,
                    help="stats sidecar directory (default: temp dir)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: two passes; assert pass-2 hit rate 100%%,"
                         " zero compiles, strictly fewer collectives")
    args = ap.parse_args(argv)

    sdir = args.session_dir or tempfile.mkdtemp(prefix="hf-serve-")
    cfg = ExecConfig()
    with Session(cfg, session_dir=sdir) as sess:
        register_tables(sess, args.scale)
        mix = build_mix(sess)
        p1 = run_pass(sess, mix, args.repeats)
        p1_total_coll = p1["collectives"] + sess.stats()[
            "register_collectives"]
        print(f"pass 1: {p1['queries']} queries, {p1['hits']} cache hits, "
              f"{p1['compiles']} compiles, "
              f"{p1_total_coll} collectives (incl. registration), "
              f"plan {p1['plan_s']*1e3:.0f} ms exec {p1['exec_s']*1e3:.0f} ms")
        if not args.smoke:
            st = sess.stats()
            pc = st["plan_cache"]
            print(f"plan cache: {pc['hits']} hits / {pc['misses']} misses, "
                  f"{pc['size']}/{pc['capacity']} entries")
            return 0
        p2 = run_pass(sess, mix, args.repeats)
        print(f"pass 2: {p2['queries']} queries, {p2['hits']} cache hits, "
              f"{p2['compiles']} compiles, {p2['collectives']} collectives, "
              f"plan {p2['plan_s']*1e3:.0f} ms exec {p2['exec_s']*1e3:.0f} ms")
        ok = True
        if p2["hits"] != p2["queries"]:
            print(f"SMOKE FAIL: pass-2 hit rate "
                  f"{p2['hits']}/{p2['queries']} != 100%")
            ok = False
        if p2["compiles"] != 0:
            print(f"SMOKE FAIL: pass-2 compiled {p2['compiles']} new "
                  "executables (expected 0)")
            ok = False
        if not p2["collectives"] < p1_total_coll:
            print(f"SMOKE FAIL: pass-2 collectives {p2['collectives']} not "
                  f"strictly fewer than pass-1 total {p1_total_coll}")
            ok = False
        print("serve smoke:", "PASS" if ok else "FAIL")
        return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""Step builders: train / prefill / decode with full sharding specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of an (architecture x shape) cell — weak-type-correct, shardable,
zero allocation — exactly what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeSpec
from repro.models import lm, sharding, whisper
from repro.models.config import ModelConfig
from repro.optim import OptConfig, adamw

# gradient-accumulation factors: big models microbatch the 256-seq global
# batch so per-layer live activations stay within HBM (see DESIGN.md §5).
DEFAULT_MICRO = {
    "kimi-k2-1t-a32b": 16, "yi-34b": 8, "qwen2.5-32b": 8,
    "zamba2-7b": 4, "falcon-mamba-7b": 4, "deepseek-moe-16b": 4,
}


def micro_batches(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh | None = None) -> int:
    m = DEFAULT_MICRO.get(cfg.name, 1)
    # per-micro batch must still cover the dp axes
    if mesh is not None:
        dpn = int(np.prod([mesh.shape[a] for a in sharding.dp_axes(mesh)]))
        while m > 1 and (shape.batch // m) % dpn != 0:
            m //= 2
    return max(m, 1)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs — no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    B, S = shape.batch, shape.seq
    cdt = jnp.dtype(cfg.compute_dtype)
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.family == "vlm":
            return {"inputs_embeds": sd((B, S, cfg.d_model), cdt),
                    "positions": sd((3, B, S), i32),
                    "labels": sd((B, S), i32)}
        if cfg.family == "encdec":
            return {"frames": sd((B, cfg.enc_frames, cfg.d_model), cdt),
                    "tokens": sd((B, S), i32), "labels": sd((B, S), i32)}
        return {"tokens": sd((B, S), i32), "labels": sd((B, S), i32)}
    if shape.kind == "prefill":
        if cfg.family == "vlm":
            return {"inputs_embeds": sd((B, S, cfg.d_model), cdt),
                    "positions": sd((3, B, S), i32)}
        if cfg.family == "encdec":
            return {"frames": sd((B, cfg.enc_frames, cfg.d_model), cdt),
                    "tokens": sd((B, S), i32)}
        return {"tokens": sd((B, S), i32)}
    if shape.kind == "decode":
        return {"token": sd((B, 1), i32)}
    raise ValueError(shape.kind)


def cache_specs(cfg: ModelConfig, shape: ShapeSpec):
    if cfg.family == "encdec":
        return whisper.init_cache_specs(cfg, shape.batch, shape.seq)
    return lm.init_cache_specs(cfg, shape.batch, shape.seq)


# ---------------------------------------------------------------------------
# loss wrappers (uniform across families)
# ---------------------------------------------------------------------------


def make_loss_fn(cfg: ModelConfig):
    if cfg.family == "encdec":
        return functools.partial(whisper.loss_fn, cfg=cfg)
    return functools.partial(lm.loss_fn, cfg=cfg)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, ocfg: OptConfig, n_micro: int = 1):
    """(state, batch) -> (state, loss) with gradient accumulation."""
    loss_fn = make_loss_fn(cfg)

    def train_step(state, batch):
        params = state["params"]

        def split(x):
            return x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])

        if n_micro > 1:
            mbatch = {k: (split(v) if k != "positions" else
                          v.reshape(v.shape[0], n_micro, v.shape[1] // n_micro,
                                    *v.shape[2:]).swapaxes(0, 1))
                      for k, v in batch.items()}

            def micro(carry, mb):
                gsum, lsum = carry
                loss, g = jax.value_and_grad(lambda p: loss_fn(p, mb))(params)
                return (jax.tree.map(jnp.add, gsum, g), lsum + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mbatch,
                                           unroll=cfg.unroll_scans)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
        else:
            loss, grads = jax.value_and_grad(lambda p: loss_fn(p, batch))(params)

        new_params, new_opt, _stats = adamw.update(params, grads, state["opt"], ocfg)
        return {"params": new_params, "opt": new_opt}, loss

    return train_step


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    """(params, batch) -> (last_logits, caches)."""
    if cfg.family == "encdec":
        def prefill(params, batch):
            return whisper.prefill(params, batch["frames"], batch["tokens"],
                                   cfg, max_seq)
        return prefill

    def prefill(params, batch):
        b = (batch.get("tokens") if batch.get("tokens") is not None
             else batch["inputs_embeds"]).shape[0]
        caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                              lm.init_cache_specs(cfg, b, max_seq))
        logits, caches, _ = lm.forward(
            params, batch.get("tokens"), cfg, caches=caches,
            positions=batch.get("positions"),
            inputs_embeds=batch.get("inputs_embeds"), q_offset=0)
        return logits[:, -1], caches
    return prefill


def make_decode_step(cfg: ModelConfig):
    if cfg.family == "encdec":
        def step(params, token, caches):
            return whisper.decode_step(params, token, caches, cfg)
        return step

    def step(params, token, caches):
        positions = None
        if cfg.mrope:
            if cfg.family in ("dense", "vlm", "moe"):
                idx = caches["layers"]["index"][0]
            else:
                idx = caches["shared"]["grp"]["index"][0]
            b = token.shape[0]
            positions = jnp.broadcast_to(
                jnp.full((1, 1), 0, jnp.int32) + idx, (b, 1))
            positions = jnp.broadcast_to(positions[None], (3, b, 1))
        return lm.decode_step(params, token, caches, cfg, positions=positions)
    return step


# ---------------------------------------------------------------------------
# sharding bundles for a cell
# ---------------------------------------------------------------------------


def model_param_specs(cfg: ModelConfig):
    if cfg.family == "encdec":
        return whisper.param_specs(cfg)
    return lm.param_specs(cfg)


def cell_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                   ocfg: OptConfig | None = None, fsdp: bool = False):
    """All NamedShardings a dry-run cell needs.

    fsdp=True additionally shards PARAMETERS over the data axes (ZeRO-3 /
    FSDP via GSPMD: weights are all-gathered per use and freed) — required
    for kimi-k2's 1T parameters, whose TP=16 shard alone is 128 GiB/device.
    """
    pspecs = model_param_specs(cfg)
    psh = sharding.param_shardings(cfg, mesh, pspecs)
    if fsdp:
        psh = jax.tree.map(
            lambda sh, sp: NamedSharding(
                mesh, adamw.zero1_spec(mesh, sh.spec, sp.shape)),
            psh, pspecs)
    out = {"params": psh, "param_specs": pspecs}
    ins = input_specs(cfg, shape)
    out["inputs"] = ins
    out["input_sh"] = {k: NamedSharding(mesh, sharding.batch_spec(mesh, k, v.shape))
                       for k, v in ins.items()}
    if shape.kind == "decode":
        cs = cache_specs(cfg, shape)
        out["cache_specs"] = cs
        out["cache_sh"] = sharding.cache_shardings(mesh, cs)
    if shape.kind == "train" and ocfg is not None:
        os_ = adamw.state_specs(pspecs, ocfg)
        osh = {"m": jax.tree.map(lambda s, p: NamedSharding(
                   mesh, adamw.zero1_spec(mesh, p.spec, s.shape) if ocfg.zero1
                   else p.spec), os_["m"], psh),
               "step": NamedSharding(mesh, P())}
        osh["v"] = osh["m"]
        if ocfg.use_master:
            osh["master"] = osh["m"]
        out["opt_specs"] = os_
        out["opt_sh"] = osh
    return out

"""zamba2-7b — [hybrid] 81L d=3584 (Mamba2) + ONE shared attn block
(32H kv=32, ff=14336), V=32000, ssm_state=64 [arXiv:2411.15242; unverified].

Zamba2 applies a single weight-shared attention+MLP block interleaved with
the Mamba2 backbone; we apply it every 6 mamba layers (13 applications +
tail), which matches the paper's sharing ratio.  d_inner = 2*d = 7168,
112 SSD heads of 64 channels.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab=32000, ssm_state=64, d_inner=7168, mamba_headdim=64,
    mamba_version=2, shared_attn_period=6, conv_kernel=4, ssm_chunk=64,
    source="arXiv:2411.15242; unverified",
)

REDUCED = CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                         d_ff=128, vocab=512, ssm_state=8, d_inner=128,
                         mamba_headdim=16, shared_attn_period=2, ssm_chunk=8)

"""olmo-1b — [dense] 16L d=2048 16H (kv=16) ff=8192 V=50304.

Non-parametric LayerNorm (no learnable scale/bias), tied embeddings
[arXiv:2402.00838; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=50304, nonparam_ln=True, tie_embeddings=True, rope_theta=10000.0,
    source="arXiv:2402.00838; hf",
)

REDUCED = CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                         d_ff=256, vocab=512)

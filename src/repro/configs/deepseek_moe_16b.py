"""deepseek-moe-16b — [moe] 28L d=2048 16H (kv=16) V=102400.

Fine-grained MoE: 64 routed experts (ff=1408) top-6 + 2 shared experts;
layer 0 is dense with ff=10944 [arXiv:2401.06066; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=102400, n_experts=64, n_shared_experts=2, top_k=6,
    d_ff_expert=1408, first_dense_layers=1, d_ff_first_dense=10944,
    rope_theta=10000.0, source="arXiv:2401.06066; hf",
)

REDUCED = CONFIG.replace(n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
                         d_ff=96, vocab=512, n_experts=8, top_k=2,
                         d_ff_expert=32, first_dense_layers=1,
                         d_ff_first_dense=96)

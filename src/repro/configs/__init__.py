"""Architecture registry + assigned input shapes.

Every assigned architecture is a selectable config (``--arch <id>``); each is
paired with the LM shape set below.  ``long_500k`` requires sub-quadratic
attention and therefore only runs for the SSM/hybrid families — the skip is
recorded per-arch here and explained in DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCH_IDS = [
    "qwen2-vl-2b", "qwen2.5-32b", "olmo-1b", "qwen3-0.6b", "yi-34b",
    "zamba2-7b", "whisper-base", "deepseek-moe-16b", "kimi-k2-1t-a32b",
    "falcon-mamba-7b",
]

_MODULES = {
    "qwen2-vl-2b": "qwen2_vl_2b",
    "qwen2.5-32b": "qwen2_5_32b",
    "olmo-1b": "olmo_1b",
    "qwen3-0.6b": "qwen3_0_6b",
    "yi-34b": "yi_34b",
    "zamba2-7b": "zamba2_7b",
    "whisper-base": "whisper_base",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.REDUCED


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) — the 40-cell table logic."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "SKIP(full-attention: 500k KV infeasible; see DESIGN.md)"
    return True, ""


def cells():
    """All 40 (arch, shape) cells with applicability."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = shape_applicable(cfg, s)
            out.append((a, s.name, ok, why))
    return out

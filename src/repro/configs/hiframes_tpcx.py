"""The paper's own workload configurations (TPCx-BB-derived).

Scale factors follow the paper's evaluation section: the micro-benchmarks
use uniform tables (filter 2B rows / join 0.5M / aggregate 256M at paper
scale), Q05/Q25/Q26 use BigBench-like tables; Q05 adds the Zipf skew that
drives the paper's skew/OOM discussion.  ``scaled(sf)`` maps a TPCx-BB-ish
scale factor to row counts; the benchmark harness defaults to CPU-feasible
fractions of these.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class TpcxConfig:
    name: str
    store_sales_rows: int
    items: int
    customers: int
    clickstream_rows: int
    skew: float = 0.0            # zipf exponent-1 for wcs_item_sk / Q05

    def scaled(self, f: float) -> "TpcxConfig":
        return TpcxConfig(
            self.name,
            int(self.store_sales_rows * f), max(int(self.items * f), 16),
            max(int(self.customers * f), 16),
            int(self.clickstream_rows * f), self.skew)


# paper-scale reference points (Fig. 11 / Fig. 12 use SF 100..1000; Q26 at
# SF1000 has a 1.2B-row fact table)
SF100 = TpcxConfig("sf100", 120_000_000, 178_000, 990_000, 390_000_000)
SF1000 = TpcxConfig("sf1000", 1_200_000_000, 500_000, 5_000_000,
                    3_900_000_000)
Q05_SKEWED = TpcxConfig("q05skew", 120_000_000, 178_000, 990_000,
                        390_000_000, skew=1.1)

# CPU-feasible default used by benchmarks/bench_tpcx.py
LOCAL = TpcxConfig("local", 400_000, 20_000, 50_000, 400_000, skew=1.1)

MICRO = {
    # paper Fig. 8a row counts (scaled by the harness)
    "filter_rows": 2_000_000_000,
    "join_rows": 500_000,
    "aggregate_rows": 256_000_000,
    # Fig. 8b series length
    "analytics_rows": 256_000_000,
}

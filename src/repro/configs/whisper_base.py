"""whisper-base — [audio] enc-dec, 6+6L d=512 8H ff=2048 V=51865.

Conv/audio frontend is a STUB (input_specs provides 1500 precomputed frame
embeddings).  Sinusoidal positions replace the learned tables so the
assigned 32k decoder shapes are well-formed (noted in DESIGN.md — Whisper's
trained context is 448) [arXiv:2212.04356; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, enc_frames=1500, tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
)

REDUCED = CONFIG.replace(n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
                         n_kv_heads=4, d_ff=128, vocab=512, enc_frames=16)

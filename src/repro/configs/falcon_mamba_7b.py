"""falcon-mamba-7b — [ssm] 64L d=4096 attention-free, V=65024, state=16.

Pure Mamba1 architecture [arXiv:2410.05355; unverified].  d_inner = 8192,
dt_rank = 256.  Decode state is O(1) in context length -> runs long_500k."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=65024, ssm_state=16, d_inner=8192, mamba_version=1,
    conv_kernel=4, ssm_chunk=256, source="arXiv:2410.05355; unverified",
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, d_inner=128, vocab=512,
                         ssm_state=4, ssm_chunk=8)

"""qwen2.5-32b — [dense] 64L d=5120 40H (GQA kv=8) ff=27648 V=152064.

GQA + QKV bias [hf:Qwen/Qwen2.5-0.5B lineage; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=27648,
    vocab=152064, head_dim=128, qkv_bias=True, rope_theta=1e6,
    source="hf:Qwen/Qwen2.5-32B; hf",
)

REDUCED = CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                         d_ff=320, vocab=512, head_dim=32)

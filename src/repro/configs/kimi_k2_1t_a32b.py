"""kimi-k2-1t-a32b — [moe] 61L d=7168 64H (GQA kv=8 per the paper table —
the real model uses MLA; the table pins GQA) V=163840.

384 routed experts (ff=2048) top-8 + 1 shared; layer 0 dense (ff=18432,
DeepSeek-V3 lineage).  ~1.04T total params, ~32B active
[arXiv:2501.kimi2; unverified; paper-table]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab=163840, head_dim=128, n_experts=384, n_shared_experts=1, top_k=8,
    d_ff_expert=2048, first_dense_layers=1, d_ff_first_dense=18432,
    rope_theta=5e7, source="arXiv:2501.kimi2; unverified",
)

REDUCED = CONFIG.replace(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=64, vocab=512, head_dim=16, n_experts=8,
                         top_k=2, d_ff_expert=32, first_dense_layers=1,
                         d_ff_first_dense=96)

"""yi-34b — [dense] 60L d=7168 56H (GQA kv=8) ff=20480 V=64000.

llama-architecture GQA [arXiv:2403.04652; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab=64000, head_dim=128, rope_theta=5e6,
    source="arXiv:2403.04652; hf",
)

REDUCED = CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                         d_ff=320, vocab=512, head_dim=32)

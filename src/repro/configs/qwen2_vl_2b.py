"""qwen2-vl-2b — [vlm] 28L d=1536 12H (GQA kv=2) ff=8960 V=151936.

M-RoPE + dynamic resolution [arXiv:2409.12191; hf].  Backbone only: the
vision frontend is a STUB — input_specs provides patch/frame embeddings and
3-axis (t,h,w) position ids.  head_dim = 1536/12 = 128; M-RoPE sections
(16,24,24) over the 64 frequency slots.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab=151936, head_dim=128, qkv_bias=True, mrope=True,
    mrope_sections=(16, 24, 24), rope_theta=1e6, tie_embeddings=True,
    source="arXiv:2409.12191; hf",
)

REDUCED = CONFIG.replace(n_layers=2, d_model=96, n_heads=4, n_kv_heads=2,
                         d_ff=256, vocab=512, head_dim=32,
                         mrope_sections=(4, 6, 6))

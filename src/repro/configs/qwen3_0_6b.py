"""qwen3-0.6b — [dense] 28L d=1024 16H (GQA kv=8) ff=3072 V=151936.

Per-head qk RMSNorm, head_dim=128 (> d_model/n_heads — Qwen3 style), GQA
[hf:Qwen/Qwen3-0.6B lineage; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=3072,
    vocab=151936, head_dim=128, qk_norm=True, tie_embeddings=True,
    rope_theta=1e6, source="hf:Qwen/Qwen3-8B; hf",
)

REDUCED = CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                         d_ff=256, vocab=512, head_dim=32)

"""Physical operators — the CGen analogue (paper §4.5), re-thought for TPU.

Every function in this module is *per-shard* code: it runs inside a single
``jax.shard_map`` region spanning the whole query plan, operating on one
shard's ``(capacity,)`` column slices plus a scalar valid-row ``count``.
Collectives (`lax.all_to_all`, `lax.all_gather`, `lax.ppermute`, `lax.psum`)
replace the paper's MPI calls:

  MPI_Alltoallv  -> fixed-capacity bucketed all_to_all + count vector
  MPI_Alltoall   -> (the count exchange folds into the same all_to_all)
  MPI_Exscan     -> ppermute ladder / all_gather-of-scalars exclusive scan
  Isend/Irecv    -> ppermute halo exchange (XLA emits async start/done pairs)

All shapes are static; validity is tracked with counts and masks (DESIGN.md
§2).  Key sentinel for sorts is the dtype max, so padding sorts to the end.
"""
from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..kernels import registry as _registry

Axes = tuple[str, ...]


def _K(kernels) -> "_registry.KernelSet":
    """Resolve the kernel set for a per-shard operator.

    ``Lowered`` threads the :class:`~repro.kernels.registry.KernelSet` picked
    by ``ExecConfig.use_pallas`` into every call below; ``None`` (direct
    callers, tests) falls back to the ref backends — the pure lax
    compositions that are bit-for-bit the pre-registry numerics.
    """
    return kernels if kernels is not None else _registry.REF


# ---------------------------------------------------------------------------
# small helpers
# ---------------------------------------------------------------------------

def nshards(axes: Axes) -> int:
    if hasattr(lax, "axis_size"):                 # jax >= 0.6
        return int(np.prod([lax.axis_size(a) for a in axes]))
    return int(lax.psum(1, tuple(axes)))          # 0.4.x: psum of a python int
                                                  # is constant-folded -> static


def my_rank(axes: Axes):
    return lax.axis_index(axes)


def valid_mask(count, cap: int):
    return jnp.arange(cap, dtype=jnp.int32) < count


def _sentinel(dtype) -> jnp.ndarray:
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.finfo(dtype).max, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def hash_u32(x: jax.Array) -> jax.Array:
    """Lowbias32-style integer mix; floats are bitcast first."""
    if jnp.issubdtype(x.dtype, jnp.floating):
        x = lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    else:
        x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * np.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def hash_combine(h: jax.Array, h2: jax.Array) -> jax.Array:
    """Boost-style hash combine on uint32 (wraps mod 2^32)."""
    return h ^ (h2 + np.uint32(0x9E3779B9) + (h << 6) + (h >> 2))


def hash_keys(cols: dict[str, jax.Array], key_names: Sequence[str]) -> jax.Array:
    """Composite row hash: per-column hash_u32 folded with hash_combine.

    Rows whose key TUPLES are equal get equal hashes, so shuffle_by_key
    co-locates composite-key groups exactly as it does single-key ones.
    """
    h = hash_u32(cols[key_names[0]])
    for kn in key_names[1:]:
        h = hash_combine(h, hash_u32(cols[kn]))
    return h


# ---------------------------------------------------------------------------
# compaction (filter backend) — paper: "filter requires no communication"
# ---------------------------------------------------------------------------

def compact(cols: dict[str, jax.Array], keep: jax.Array, cap_out: int,
            kernels=None):
    """Move rows where ``keep`` into the prefix of fresh (cap_out, ...) buffers.

    Returns (cols_out, count_out, overflow).  Rows beyond cap_out are dropped
    and flagged — the driver's retry hook (fault tolerance for capacity
    planning, DESIGN.md §2).  The slot-assignment scan resolves through the
    registry's ``prefix_sum`` primitive (stream_compact Pallas kernel when
    ``use_pallas`` is on); ``keep`` may be boolean or an integer 0/1 vector —
    both take the same path.  Columns may carry trailing dims (the
    packed-word matrix of :func:`pack_columns` compacts row-wise like any
    scalar column).  A zero-length shard (empty ``keep``) short-circuits
    before any scan runs — the prefix kernel never sees a zero-size input.
    """
    if keep.shape[0] == 0:
        out = {name: jnp.zeros((cap_out,) + v.shape[1:], v.dtype)
               for name, v in cols.items()}
        return out, jnp.int32(0), jnp.array(False)
    keep = keep.astype(jnp.int32)
    incl = _K(kernels).prefix_sum(keep)
    dest = incl - 1
    total = incl[-1]
    dest = jnp.where(keep > 0, dest, cap_out)          # parked -> dropped
    overflow = total > cap_out
    out = {}
    for name, v in cols.items():
        buf = jnp.zeros((cap_out,) + v.shape[1:], v.dtype)
        out[name] = buf.at[dest].set(v, mode="drop")
    return out, jnp.minimum(total, cap_out).astype(jnp.int32), overflow


# ---------------------------------------------------------------------------
# skew salting (adaptive_stats; docs/adaptive_planning.md)
# ---------------------------------------------------------------------------

# The salt column a salted join's two SaltOps inject and the join strips.
SALT_COL = "__salt__"


def hot_mask(cols: dict[str, jax.Array], key_names: Sequence[str],
             hot: Sequence[tuple]) -> jax.Array:
    """Boolean row mask: key tuple ∈ ``hot`` (a STATIC plan constant — the
    same literal set on both join sides, so membership agrees exactly)."""
    cap = cols[key_names[0]].shape[0]
    m = jnp.zeros((cap,), dtype=bool)
    for vals in hot:
        eq = jnp.ones((cap,), dtype=bool)
        for kn, v in zip(key_names, vals):
            c = cols[kn]
            eq = eq & (c == jnp.asarray(v, c.dtype))
        m = m | eq
    return m


def salt_probe(cols: dict[str, jax.Array], count, key_names: Sequence[str],
               hot: Sequence[tuple], R: int):
    """Probe-side salting: hot rows get salt ``position % R`` (spreading a
    hot key's rows over R sub-partitions of the keys+salt exchange), every
    other row salt 0.  Row set and order unchanged; returns (cols, count)."""
    cap = cols[key_names[0]].shape[0]
    is_hot = hot_mask(cols, key_names, hot)
    salt = jnp.where(is_hot, jnp.arange(cap, dtype=jnp.int32) % R,
                     jnp.int32(0))
    out = dict(cols)
    out[SALT_COL] = salt
    return out, count


def salt_build(cols: dict[str, jax.Array], count, key_names: Sequence[str],
               hot: Sequence[tuple], R: int, cap_out: int, kernels=None):
    """Build-side salting: hot rows are replicated to every salt 0..R-1 so
    each probe sub-partition finds its match; non-hot rows keep one salt-0
    copy.  Every (probe row, build row) pair with equal keys then agrees on
    exactly ONE salt value — the salted join's row set is exactly the
    unsalted one.  Returns (cols, count, overflow) via :func:`compact`."""
    cap = cols[key_names[0]].shape[0]
    is_hot = hot_mask(cols, key_names, hot)
    valid = valid_mask(count, cap)
    rep = {name: jnp.concatenate([v] * R)       # replica r at rows [r*cap, ...)
           for name, v in cols.items()}
    rep[SALT_COL] = jnp.repeat(jnp.arange(R, dtype=jnp.int32), cap)
    keep = jnp.tile(valid, R) & ((rep[SALT_COL] == 0) | jnp.tile(is_hot, R))
    return compact(rep, keep, cap_out, kernels=kernels)


# ---------------------------------------------------------------------------
# column packing — the byte-transport layer of the packed exchange
# ---------------------------------------------------------------------------

# Word width of the packed transport buffer: every column is bitcast into
# uint32 words, so a whole table shuffles as ONE (P, bucket_cap, W) payload.
PACK_WORD_BYTES = 4


def col_words(dtype) -> int:
    """uint32 words one value of ``dtype`` occupies in the packed layout.

    4-byte types bitcast 1:1; 8-byte types split into two words; sub-word
    types (bool, int8/16, fp16/bf16) zero-extend into one word — the packed
    layout trades a little padding on narrow columns for a single collective.
    """
    dtype = np.dtype(dtype)
    if dtype == np.bool_:
        return 1
    return max(1, dtype.itemsize // PACK_WORD_BYTES)


def pack_columns(cols: dict[str, jax.Array]):
    """Bitcast-pack every column into one (rows, W) uint32 word matrix.

    Returns ``(words, layout)`` where ``layout`` is the per-column
    ``(name, dtype, word_offset, n_words)`` recipe :func:`unpack_columns`
    inverts.  Pure bit movement (``lax.bitcast_convert_type``): floats keep
    their payload bits exactly — NaNs, signed zeros and all.
    """
    words, layout, off = [], [], 0
    for name, v in cols.items():
        dt = jnp.dtype(v.dtype)
        if dt == jnp.bool_:
            w = v.astype(jnp.uint32)[:, None]
        elif dt.itemsize == 4:
            w = lax.bitcast_convert_type(v, jnp.uint32)[:, None]
        elif dt.itemsize == 8:
            w = lax.bitcast_convert_type(v, jnp.uint32)       # (rows, 2)
        elif dt.itemsize == 2:
            w = lax.bitcast_convert_type(v, jnp.uint16).astype(jnp.uint32)[:, None]
        else:                                                 # 1-byte ints
            w = lax.bitcast_convert_type(v, jnp.uint8).astype(jnp.uint32)[:, None]
        layout.append((name, dt, off, w.shape[1]))
        off += w.shape[1]
        words.append(w)
    return jnp.concatenate(words, axis=1), layout


def unpack_columns(words: jax.Array, layout) -> dict[str, jax.Array]:
    """Invert :func:`pack_columns`: slice each column's words and bitcast
    back to its original dtype."""
    out = {}
    for name, dt, off, nw in layout:
        w = words[:, off:off + nw]
        if dt == jnp.bool_:
            out[name] = w[:, 0] != 0
        elif dt.itemsize == 4:
            out[name] = lax.bitcast_convert_type(w[:, 0], dt)
        elif dt.itemsize == 8:
            out[name] = lax.bitcast_convert_type(w, dt)       # (rows, 2) -> (rows,)
        elif dt.itemsize == 2:
            out[name] = lax.bitcast_convert_type(w[:, 0].astype(jnp.uint16), dt)
        else:
            out[name] = lax.bitcast_convert_type(w[:, 0].astype(jnp.uint8), dt)
    return out


# ---------------------------------------------------------------------------
# exchange (MPI_Alltoallv analogue) — backbone of shuffle/join/aggregate,
# and of MoE expert-parallel dispatch (models/moe.py reuses this).
# ---------------------------------------------------------------------------

def exchange(cols: dict[str, jax.Array], count, dest: jax.Array, *,
             axes: Axes, bucket_cap: int, cap_out: int,
             kernels=None, packed: bool = True):
    """Route row i of this shard to shard ``dest[i]``.

    Static-shape plan: rows are stably grouped by destination into a
    per-shard bucket buffer, exchanged with ``lax.all_to_all``, then
    compacted into a (cap_out,) valid-prefix buffer.  Counts ride along as a
    (P,) vector through their own all_to_all.  Stability: row order within a
    (src, dst) pair is preserved and receives are concatenated in src order,
    so global row order is preserved for order-sensitive users (rebalance).

    Slot assignment resolves through the registry's ``bucket_scatter``
    primitive — ``(slot, send_counts)`` with each row's stable within-bucket
    slot at its ORIGINAL position, so rows scatter straight into the bucket
    buffer with no reorder pass.  The ref backend derives slots from a
    stable argsort; the Pallas backend (hash_partition) computes them in one
    streaming count+scatter pass with a carried per-bucket histogram.

    ``packed=True`` (default) ships ALL columns as one word-packed
    (P, bucket_cap, W) uint32 payload (:func:`pack_columns`), so an exchange
    of any table costs exactly TWO collectives — counts + payload — with a
    single fused scatter for slot assignment and one unpack after the wire.
    ``packed=False`` restores the one-collective-per-column baseline (the
    ``ExecConfig.packed_exchange`` A/B lever).
    """
    P = nshards(axes) if axes else 1
    valid = valid_mask(count, dest.shape[0])
    dest = jnp.where(valid, dest.astype(jnp.int32), P)

    if P == 1:
        # single shard: no collective; just clamp into the output capacity.
        return compact(cols, valid, cap_out, kernels=kernels)

    slot, send_counts = _K(kernels).bucket_scatter(dest, P)
    in_range = dest < P
    overflow_send = jnp.any(in_range & (slot >= bucket_cap))
    scatter_slot = jnp.where(in_range & (slot < bucket_cap), slot, bucket_cap)

    sent = jnp.minimum(send_counts, bucket_cap)
    recv_counts = lax.all_to_all(sent.reshape(P, 1), axes, 0, 0).reshape(P)

    slot_idx = jnp.arange(bucket_cap, dtype=jnp.int32)[None, :]
    keep = (slot_idx < recv_counts[:, None]).reshape(-1)

    if packed:
        # ONE payload collective for the whole table: pack -> one fused
        # scatter into (P, bucket_cap+1, W) -> one all_to_all -> compact the
        # word matrix row-wise -> unpack.
        words, layout = pack_columns(cols)
        buf = jnp.zeros((P, bucket_cap + 1, words.shape[1]), jnp.uint32)
        buf = buf.at[dest, scatter_slot].set(words, mode="drop")
        recv = lax.all_to_all(buf[:, :bucket_cap, :], axes, 0, 0)
        flat = {"__packed__": recv.reshape(P * bucket_cap, -1)}
        out, count_out, overflow_recv = compact(flat, keep, cap_out,
                                                kernels=kernels)
        out = unpack_columns(out["__packed__"], layout)
        return out, count_out, overflow_send | overflow_recv

    recv = {}
    for name, v in cols.items():
        buf = jnp.zeros((P, bucket_cap + 1), v.dtype)
        buf = buf.at[dest, scatter_slot].set(v, mode="drop")
        buf = buf[:, :bucket_cap]
        recv[name] = lax.all_to_all(buf, axes, 0, 0)

    flat = {k: v.reshape(-1) for k, v in recv.items()}
    out, count_out, overflow_recv = compact(flat, keep, cap_out, kernels=kernels)
    return out, count_out, overflow_send | overflow_recv


def shuffle_by_key(cols: dict[str, jax.Array], count, key_names, *,
                   axes: Axes, bucket_cap: int, cap_out: int,
                   kernels=None, packed: bool = True):
    """Hash-partition rows so equal (possibly composite) keys co-locate.

    ``key_names`` is a column name or a sequence of names; multiple names
    route on the combined hash (see :func:`hash_keys`).
    """
    if isinstance(key_names, str):
        key_names = (key_names,)
    P = nshards(axes) if axes else 1
    dest = (hash_keys(cols, key_names) % np.uint32(P)).astype(jnp.int32)
    return exchange(cols, count, dest, axes=axes, bucket_cap=bucket_cap,
                    cap_out=cap_out, kernels=kernels, packed=packed)


# ---------------------------------------------------------------------------
# local sort (bitonic via lax.sort — the TPU-native Timsort replacement)
# ---------------------------------------------------------------------------

def local_sort(cols: dict[str, jax.Array], count, key_names):
    """Stable lexicographic sort of valid rows by one or more key columns
    (padding sorts to the end via per-dtype max sentinels).

    ``key_names`` is a column name or a sequence of names (most-significant
    first); ``lax.sort`` with ``num_keys=len(keys)+1`` does the multi-key
    comparison natively on TPU.  Returns ``(sorted_cols, skeys)`` where
    ``skeys`` is the tuple of SENTINEL-MASKED sorted key arrays (one per name
    in ``key_names``) used for splitter sampling downstream.
    """
    if isinstance(key_names, str):
        key_names = (key_names,)
    key_names = tuple(key_names)
    cap = cols[key_names[0]].shape[0]
    valid = valid_mask(count, cap)
    keys = [jnp.where(valid, cols[kn], _sentinel(cols[kn].dtype))
            for kn in key_names]
    # stable tiebreaker: original index
    keys.append(jnp.arange(cap, dtype=jnp.int32))
    names = list(cols)
    operands = keys + [cols[n] for n in names]
    res = lax.sort(tuple(operands), num_keys=len(keys))
    sorted_keys = dict(zip(key_names, res[: len(keys) - 1]))
    sorted_cols = dict(zip(names, res[len(keys):]))
    # masked key columns come back with sentinels; restore real values where valid
    for kn, kv in sorted_keys.items():
        sorted_cols[kn] = jnp.where(valid, kv, jnp.zeros((), kv.dtype))
    return sorted_cols, tuple(sorted_keys[kn] for kn in key_names)


# ---------------------------------------------------------------------------
# merge join (rank join: one fused union sort; inputs need NOT be pre-sorted)
# ---------------------------------------------------------------------------


def lex_ranks(keycols: Sequence[jax.Array], valid: jax.Array):
    """Dense lexicographic ranks of row tuples via ONE multi-key sort.

    Sorts the tuples (``lax.sort`` with a stable index tiebreaker), detects
    run boundaries, and scatters the dense rank back to each row's original
    position.  Equal tuples share a rank and rank order equals lexicographic
    tuple order.  Invalid rows carry per-dtype max sentinels (they sort to
    the end) and get the int32 max sentinel rank.

    Returns ``(ranks, sidx, rank_sorted)``: per-original-row ranks, the
    original indices in sorted order, and the rank sequence in sorted order —
    the latter two let callers recover a key-sorted permutation without a
    second sort (merge join, sample-sort splitter routing).
    """
    n = keycols[0].shape[0]
    masked = [jnp.where(valid, k, _sentinel(k.dtype)) for k in keycols]
    idx = jnp.arange(n, dtype=jnp.int32)
    res = lax.sort(tuple(masked) + (idx,), num_keys=len(masked) + 1)
    sk, sidx = res[:-1], res[-1]
    neq = functools.reduce(jnp.logical_or, [k[1:] != k[:-1] for k in sk])
    boundary = jnp.concatenate([jnp.full((1,), True), neq])
    rank_sorted = jnp.cumsum(boundary.astype(jnp.int32)) - 1
    ranks = jnp.zeros((n,), jnp.int32).at[sidx].set(rank_sorted)
    ranks = jnp.where(valid, ranks, _sentinel(jnp.int32))
    return ranks, sidx, rank_sorted


def merge_join(lcols, lcount, rcols, rcount, lkeys, rkeys, *,
               cap_out: int, r_suffix_map: dict[str, str], how: str = "inner",
               null_fill: dict[str, Any] | None = None):
    """Equi-join of two co-partitioned shards (inner or left-outer) on one
    or more key columns.  Inputs do NOT need to be pre-sorted.

    Both sides' key columns are concatenated and sorted ONCE as tuples
    (:func:`lex_ranks`); the same sort yields (a) a dense rank per row and
    (b) the right side's key-sorted permutation.  Expansion trick: per-left-
    row match counts -> prefix sums -> each output slot s maps back to
    (left row, offset within its match range) with two searchsorteds into
    the rank arrays; matched right rows are gathered through the
    permutation.  Output rows follow LEFT row order, so a sorted left input
    yields key-sorted output.  Left-outer: unmatched rows get count 1 and
    zero-filled right columns plus a ``_matched`` indicator (the
    static-shape NULL).  Fully static shapes; overflow flagged.
    """
    if isinstance(lkeys, str):
        lkeys = (lkeys,)
    if isinstance(rkeys, str):
        rkeys = (rkeys,)
    lkeys, rkeys = tuple(lkeys), tuple(rkeys)
    lcap = lcols[lkeys[0]].shape[0]
    rcap = rcols[rkeys[0]].shape[0]
    lvalid = valid_mask(lcount, lcap)
    rvalid = valid_mask(rcount, rcap)

    valid = jnp.concatenate([lvalid, rvalid])
    keycols = []
    for lk, rk in zip(lkeys, rkeys):
        la, ra = lcols[lk], rcols[rk]
        dt = jnp.promote_types(la.dtype, ra.dtype)
        keycols.append(jnp.concatenate([la.astype(dt), ra.astype(dt)]))
    ranks, sidx, rank_sorted = lex_ranks(keycols, valid)
    lrank = ranks[:lcap]

    # right rows in key-sorted order, extracted from the SAME sort: a stable
    # compaction of the sorted union down to right-side entries.
    is_r = (sidx >= lcap).astype(jnp.int32)
    pos_r = jnp.cumsum(is_r) - 1
    scat = jnp.where(is_r > 0, pos_r, lcap + rcap)
    rsorted_rank = jnp.full((rcap,), _sentinel(jnp.int32)) \
        .at[scat].set(rank_sorted, mode="drop")
    rperm = jnp.zeros((rcap,), jnp.int32) \
        .at[scat].set((sidx - lcap).astype(jnp.int32), mode="drop")

    lo = jnp.searchsorted(rsorted_rank, lrank, side="left")
    hi = jnp.searchsorted(rsorted_rank, lrank, side="right")
    hi = jnp.minimum(hi, rcount)
    lo = jnp.minimum(lo, rcount)
    matches = (hi - lo).astype(jnp.int32)
    cnt = jnp.where(lvalid, matches, 0)
    if how == "left":
        cnt = jnp.where(lvalid & (matches == 0), 1, cnt)

    incl = jnp.cumsum(cnt)
    excl = incl - cnt
    total = incl[-1] if lcap else jnp.int32(0)
    overflow = total > cap_out

    s = jnp.arange(cap_out, dtype=jnp.int32)
    li = jnp.searchsorted(incl, s, side="right")
    li_c = jnp.clip(li, 0, lcap - 1)
    matched = matches[li_c] > 0
    rpos = lo[li_c] + (s - excl[li_c])          # position in key-sorted right
    ri_c = rperm[jnp.clip(rpos, 0, rcap - 1)]   # original right row
    out_valid = s < jnp.minimum(total, cap_out)
    r_valid = out_valid & (matched if how == "left" else True)

    out = {}
    for name, v in lcols.items():
        out[name] = jnp.where(out_valid, v[li_c], jnp.zeros((), v.dtype))
    for name, v in rcols.items():
        if name in rkeys:
            continue
        # unmatched left rows NULL-fill right columns: NaN for floats, the
        # null dictionary code for categories (null_fill, from the schema);
        # other dtypes keep zero-fill + the _matched indicator.
        fill = jnp.asarray((null_fill or {}).get(name, 0), v.dtype)
        out[r_suffix_map.get(name, name)] = jnp.where(r_valid, v[ri_c], fill)
    if how == "left":
        out["_matched"] = (out_valid & matched).astype(jnp.int32)
    return out, jnp.minimum(total, cap_out).astype(jnp.int32), overflow


# ---------------------------------------------------------------------------
# segmented aggregation (group-by backend; sorted-key TPU idiom)
# ---------------------------------------------------------------------------

def null_mask(x: jax.Array, nulltag: str | None):
    """Row nullity under the in-band null encoding (docs/dtypes.md):
    ``"nan"`` — floats, null iff NaN; ``"code"`` — dictionary codes, null
    iff negative; ``None`` — the column cannot hold nulls."""
    if nulltag == "nan":
        if not jnp.issubdtype(x.dtype, jnp.floating):
            return jnp.zeros(x.shape, bool)
        return jnp.isnan(x)
    if nulltag == "code":
        return x < 0
    return None


def null_value(dtype, nulltag: str | None):
    """The in-band null of a value dtype (NaN / the null code)."""
    dtype = jnp.asarray(jnp.zeros((), dtype)).dtype
    if nulltag == "code" or not jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(-1, dtype)
    return jnp.asarray(jnp.nan, dtype)


def _value_spec(spec):
    """Normalize a values entry: (fn, x) or (fn, x, skipna, nulltag)."""
    if len(spec) == 2:
        fn, x = spec
        return fn, x, True, None
    fn, x, skipna, nulltag = spec
    return fn, x, skipna, nulltag


def segment_aggregate(keys_sorted, count, values: dict[str, tuple],
                      *, cap_out: int, kernels=None,
                      presorted: Sequence[str] = ()):
    """Aggregate ``values`` over runs of equal (grouped) composite keys.

    ``keys_sorted`` is one key array or a tuple of them; the valid prefix
    must have equal key tuples CONTIGUOUS (sorted by a key prefix, either
    direction — though ``nunique`` additionally requires ascending, see
    below).  A new run starts where ANY key column differs from the previous
    row.  values: name -> (fn, value_array) or (fn, value_array, skipna,
    nulltag) with fn in {sum, mean, count, min, max, prod, any, all, var,
    std, first, nunique} (``any``/``all`` reduce the truth of ``x != 0`` and
    return bool).

    ``nulltag`` ("nan" | "code" | None, see :func:`null_mask`) marks value
    columns that can hold in-band nulls; with ``skipna=True`` (pandas
    default) null rows are excluded from the reduction and all-null groups
    yield the null value; with ``skipna=False`` nulls poison their group's
    result.  ``count`` over a nullable column counts non-null rows (pandas
    ``count``); ``nunique`` always ignores nulls (pandas ``dropna=True``).
    Columns without a nulltag take the exact pre-null code paths.

    Any number of nunique columns is
    supported: each one re-sorts (keys..., x) independently with one
    ``lax.sort`` and counts within-run value boundaries; the aux sort is
    ascending, so its group order matches the main segment order only for
    ascending inputs (the physical planner inserts a LocalSort otherwise).
    ``presorted`` names nunique entries whose value column already arrives
    sorted WITHIN each key run (it rode the planner's LocalSort as a trailing
    sort key) — those skip the aux ``lax.sort`` and count boundaries off the
    main segment machinery directly.
    Returns ``({__key0__..., **aggs}, n_groups, overflow)`` with one output
    column per key, in key order, named ``__key<i>__``.
    """
    if not isinstance(keys_sorted, (tuple, list)):
        keys_sorted = (keys_sorted,)
    keys_sorted = tuple(keys_sorted)
    cap = keys_sorted[0].shape[0]
    valid = valid_mask(count, cap)
    neq = functools.reduce(jnp.logical_or,
                           [k[1:] != k[:-1] for k in keys_sorted])
    prev = jnp.concatenate([jnp.full((1,), True), neq])
    seg_start = valid & prev
    seg_id = jnp.cumsum(seg_start.astype(jnp.int32)) - 1
    seg_id = jnp.where(valid, seg_id, cap_out)          # padding -> dropped
    n_seg = jnp.sum(seg_start.astype(jnp.int32))
    overflow = n_seg > cap_out

    def ssum(x, v=None):
        v = valid if v is None else v
        if x.dtype == jnp.bool_:
            x = x.astype(jnp.int32)      # sum(:x < 1.0) counts True rows
        if jnp.issubdtype(x.dtype, jnp.floating):
            # registry segment_sums: ref is the dtype-preserving
            # jax.ops.segment_sum composition; the Pallas backend is the
            # segment_reduce scan-difference kernel (f32 accumulation).
            return _K(kernels).segment_sums(x, seg_id, v, cap_out)
        # integer sums stay on segment_sum directly for exactness (the
        # Pallas kernel accumulates in f32).
        return jax.ops.segment_sum(jnp.where(v, x, jnp.zeros((), x.dtype)),
                                   seg_id, num_segments=cap_out + 1)[:cap_out]

    def smin(x, v=None):
        v = valid if v is None else v
        if x.dtype == jnp.bool_:
            x = x.astype(jnp.int32)      # bool has no iinfo sentinel
        big = _sentinel(x.dtype)
        return jax.ops.segment_min(jnp.where(v, x, big), seg_id,
                                   num_segments=cap_out + 1)[:cap_out]

    def smax(x, v=None):
        v = valid if v is None else v
        if x.dtype == jnp.bool_:
            x = x.astype(jnp.int32)
        if jnp.issubdtype(x.dtype, jnp.floating):
            small = jnp.array(jnp.finfo(x.dtype).min, x.dtype)
        else:
            small = jnp.array(jnp.iinfo(x.dtype).min, x.dtype)
        return jax.ops.segment_max(jnp.where(v, x, small), seg_id,
                                   num_segments=cap_out + 1)[:cap_out]

    def sprod(x, v=None):
        v = valid if v is None else v
        if x.dtype == jnp.bool_:
            x = x.astype(jnp.int32)
        one = jnp.ones((), x.dtype)
        return jax.ops.segment_prod(jnp.where(v, x, one), seg_id,
                                    num_segments=cap_out + 1)[:cap_out]

    ones = valid.astype(jnp.int32)
    group_n = jax.ops.segment_sum(ones, seg_id, num_segments=cap_out + 1)[:cap_out]

    out: dict[str, jax.Array] = {}
    for i, ks in enumerate(keys_sorted):
        neg = (jnp.array(jnp.iinfo(ks.dtype).min, ks.dtype)
               if jnp.issubdtype(ks.dtype, jnp.integer)
               else jnp.array(jnp.finfo(ks.dtype).min, ks.dtype))
        out[f"__key{i}__"] = jax.ops.segment_max(
            jnp.where(valid, ks, neg),
            seg_id, num_segments=cap_out + 1)[:cap_out]

    for name, spec in values.items():
        fn, x, skipna, nulltag = _value_spec(spec)
        nullm = null_mask(x, nulltag) if x is not None else None
        # vvalid: rows contributing under skipna; vn: their per-group count;
        # has_null: whether the group saw a null (skipna=False poisoning).
        vvalid = valid if nullm is None else valid & ~nullm
        vn = has_null = None
        if nullm is not None:
            vn = jax.ops.segment_sum(vvalid.astype(jnp.int32), seg_id,
                                     num_segments=cap_out + 1)[:cap_out]
            has_null = vn < group_n

        def _null_out(res, dt):
            """null-fill groups with no contributing rows (skipna) or with
            any null row (skipna=False)."""
            if nullm is None:
                return res
            bad = (vn == 0) if skipna else has_null
            return jnp.where(bad, null_value(dt, nulltag).astype(dt), res)

        if fn == "count":
            out[name] = group_n if nullm is None else vn
        elif fn == "sum":
            # skipna sum of an all-null group is 0 (pandas); skipna=False
            # lets NaN propagate — codes are never summed.
            out[name] = ssum(x, vvalid if skipna else valid)
        elif fn == "mean":
            xf = x.astype(jnp.float32)
            v = vvalid if skipna else valid
            n = vn if (skipna and nullm is not None) else group_n
            res = ssum(xf, v) / jnp.maximum(n, 1)
            out[name] = _null_out(res, res.dtype)
        elif fn == "min":
            res = smin(x, vvalid if skipna else valid)
            out[name] = _null_out(res, res.dtype)
        elif fn == "max":
            res = smax(x, vvalid if skipna else valid)
            out[name] = _null_out(res, res.dtype)
        elif fn == "prod":
            # skipna prod of an all-null group is 1 (pandas)
            out[name] = sprod(x, vvalid if skipna else valid)
        elif fn == "any":
            # skipna: nulls never assert truth; skipna=False: NaN is truthy
            # (x != 0 holds for NaN), matching pandas
            flag = (x != 0).astype(jnp.int32)
            out[name] = smax(flag, vvalid if skipna else valid) > 0
        elif fn == "all":
            flag = (x != 0).astype(jnp.int32)
            out[name] = smin(flag, vvalid if skipna else valid) > 0
        elif fn in ("var", "std"):
            xf = x.astype(jnp.float32)
            v = vvalid if skipna else valid
            n = vn if (skipna and nullm is not None) else group_n
            m = ssum(xf, v) / jnp.maximum(n, 1)
            m2 = ssum(xf * xf, v) / jnp.maximum(n, 1)
            var = jnp.maximum(m2 - m * m, 0.0)
            res = jnp.sqrt(var) if fn == "std" else var
            out[name] = _null_out(res, res.dtype)
        elif fn == "first":
            # pandas groupby.first(skipna=True) takes the first NON-NULL
            v = vvalid if skipna else valid
            first_idx = jax.ops.segment_min(
                jnp.where(v, jnp.arange(cap, dtype=jnp.int32), cap),
                seg_id, num_segments=cap_out + 1)[:cap_out]
            res = x[jnp.clip(first_idx, 0, cap - 1)]
            if nullm is not None and skipna:
                res = jnp.where(first_idx >= cap,
                                null_value(res.dtype, nulltag).astype(res.dtype),
                                res)
            out[name] = res
        elif fn == "nunique" and name in presorted:
            # aux-sort elision: x is already sorted within each key run (it
            # was a trailing key of the planner's LocalSort), so distinct
            # values are contiguous and boundaries fall out of the MAIN
            # segment machinery — no extra lax.sort.
            vprev = jnp.concatenate([jnp.full((1,), True), x[1:] != x[:-1]])
            boundary = (seg_start | vprev) & vvalid   # nulls never distinct
            out[name] = jax.ops.segment_sum(boundary.astype(jnp.int32), seg_id,
                                            num_segments=cap_out + 1)[:cap_out]
        elif fn == "nunique":
            # independent aux sort by (keys..., x): groups x within each key
            # run.  Group ORDER matches the main segment order because both
            # enumerate distinct key tuples ascending (see docstring).
            masked = [jnp.where(valid, k, _sentinel(k.dtype))
                      for k in keys_sorted]
            res = lax.sort(tuple(masked) + (x,), num_keys=len(masked) + 1)
            sx = res[-1]
            neq2 = functools.reduce(jnp.logical_or,
                                    [k[1:] != k[:-1] for k in res[:-1]])
            prev2 = jnp.concatenate([jnp.full((1,), True), neq2])
            seg_start2 = valid & prev2          # valid rows stay a prefix
            seg_id2 = jnp.cumsum(seg_start2.astype(jnp.int32)) - 1
            seg_id2 = jnp.where(valid, seg_id2, cap_out)
            vprev = jnp.concatenate([jnp.full((1,), True), sx[1:] != sx[:-1]])
            boundary = (seg_start2 | vprev) & valid
            snullm = null_mask(sx, nulltag)
            if snullm is not None:
                boundary = boundary & ~snullm   # null runs don't count
            out[name] = jax.ops.segment_sum(boundary.astype(jnp.int32), seg_id2,
                                            num_segments=cap_out + 1)[:cap_out]
        else:
            raise ValueError(fn)
    gvalid = jnp.arange(cap_out, dtype=jnp.int32) < jnp.minimum(n_seg, cap_out)
    for name in out:
        out[name] = jnp.where(gvalid, out[name], jnp.zeros((), out[name].dtype))
    return out, jnp.minimum(n_seg, cap_out).astype(jnp.int32), overflow


# ---------------------------------------------------------------------------
# map-side partial aggregation (combiner algebra for the shuffle engine)
#
# Every decomposable agg fn splits into partial statistics a shard can
# pre-reduce over its LOCAL key groups before the hash exchange, so the wire
# carries at most the shard's DISTINCT key tuples instead of all raw rows.
# The WHOLE algebra lives in one table (AGG_DECOMP): per fn, the partial
# columns it decomposes into — suffix, map-side segment fn, reduce-side
# combine fn, wire dtype rule, input transform — plus the finalizer that
# folds the combined partials into the result.  partial_decompose /
# final_aggregate / the planner's schema annotation all read this table, so
# adding a decomposable fn is ONE entry (prod, any and all below are exactly
# that).
#
# first (arrival-order-sensitive) and nunique (set-valued partial state)
# are NOT decomposable — the planner keeps those on the raw-row path.
# ---------------------------------------------------------------------------


class PartialSpec:
    """One partial column of a decomposable aggregation.

    ``suffix``     the wire column is named ``__p_<out>__<suffix>``
    ``partial_fn`` segment fn reducing raw rows map-side
    ``combine_fn`` segment fn merging per-shard partials reduce-side
                   (count partials COMBINE by sum, hence the split)
    ``dtype``      wire dtype as a function of the value column's dtype
    ``prep``       input transform applied before the partial stage
    """

    __slots__ = ("suffix", "partial_fn", "combine_fn", "dtype", "prep")

    def __init__(self, suffix, partial_fn, combine_fn=None, dtype=None,
                 prep=None):
        self.suffix = suffix
        self.partial_fn = partial_fn
        self.combine_fn = combine_fn or partial_fn
        self.dtype = dtype or (lambda vd: np.dtype(np.int32)
                               if np.dtype(vd) == np.bool_ else np.dtype(vd))
        self.prep = prep or (lambda x: x)


def _dt_i32(_vd):
    return np.dtype(np.int32)


def _dt_f32(_vd):
    return np.dtype(np.float32)


def _as_f32(x):
    return x.astype(jnp.float32)


def _as_flag(x):
    return (x != 0).astype(jnp.int32)


def _as_int_if_bool(x):
    # min/max of a bool column compare as 0/1 int32 (bool has no sentinel;
    # the raw-path smin/smax apply the same cast, so both paths agree).
    return x.astype(jnp.int32) if x.dtype == jnp.bool_ else x


def _mean_final(p):
    return p["s"] / jnp.maximum(p["n"], 1)


def _var_final(p):
    n = jnp.maximum(p["n"], 1)
    m = p["s"] / n
    m2 = p["q"] / n
    return jnp.maximum(m2 - m * m, 0.0)


# fn -> (partial column specs, finalize(dict suffix -> combined array))
AGG_DECOMP: dict[str, tuple[tuple[PartialSpec, ...], Any]] = {
    "sum":   ((PartialSpec("s", "sum"),), lambda p: p["s"]),
    "count": ((PartialSpec("n", "count", combine_fn="sum", dtype=_dt_i32),),
              lambda p: p["n"]),
    "min":   ((PartialSpec("m", "min", prep=_as_int_if_bool),),
              lambda p: p["m"]),
    "max":   ((PartialSpec("m", "max", prep=_as_int_if_bool),),
              lambda p: p["m"]),
    "prod":  ((PartialSpec("p", "prod"),), lambda p: p["p"]),
    "any":   ((PartialSpec("b", "max", dtype=_dt_i32, prep=_as_flag),),
              lambda p: p["b"] != 0),
    "all":   ((PartialSpec("b", "min", dtype=_dt_i32, prep=_as_flag),),
              lambda p: p["b"] != 0),
    "mean":  ((PartialSpec("s", "sum", dtype=_dt_f32, prep=_as_f32),
               PartialSpec("n", "count", combine_fn="sum", dtype=_dt_i32)),
              _mean_final),
    "var":   ((PartialSpec("s", "sum", dtype=_dt_f32, prep=_as_f32),
               PartialSpec("q", "sum", dtype=_dt_f32,
                           prep=lambda x: _as_f32(x) * _as_f32(x)),
               PartialSpec("n", "count", combine_fn="sum", dtype=_dt_i32)),
              _var_final),
    "std":   ((PartialSpec("s", "sum", dtype=_dt_f32, prep=_as_f32),
               PartialSpec("q", "sum", dtype=_dt_f32,
                           prep=lambda x: _as_f32(x) * _as_f32(x)),
               PartialSpec("n", "count", combine_fn="sum", dtype=_dt_i32)),
              lambda p: jnp.sqrt(_var_final(p))),
}

DECOMPOSABLE_AGGS = frozenset(AGG_DECOMP)


def _agg_null_spec(fn: str, skipna: bool, nulltag: str | None):
    """Normalize a final_aggregate ``agg_fns`` entry (str, or a tuple of
    (fn, skipna, nulltag)) — nulltag None means the pre-null exact path."""
    return fn, skipna, nulltag


def decomposable(fn: str, skipna: bool = True, nulltag: str | None = None) -> bool:
    """Whether this agg can take the partial/final two-stage path.

    ``skipna=False`` on a nullable column needs the group's full row set to
    poison correctly, so the planner keeps it on the raw single-stage path.
    """
    if fn not in AGG_DECOMP:
        return False
    return skipna or nulltag is None


def _partial_marker(partial_fn: str, dtype):
    """The in-band "no contributing rows" marker a null-masked partial
    min/max reduces to: the same sentinel the validity masking uses, so an
    all-null group's partial is the sentinel on every shard and survives the
    combine.  The finalizer maps it to the null value."""
    if partial_fn == "min":
        return _sentinel(dtype)
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.finfo(dtype).min, dtype)
    return jnp.array(jnp.iinfo(dtype).min, dtype)


def partial_decompose(name: str, fn: str, x: jax.Array, skipna: bool = True,
                      nulltag: str | None = None):
    """Partial-column specs for one decomposable agg output: a list of
    ``(partial_name, partial_fn, array)`` triples feeding segment_aggregate.

    With a ``nulltag`` the preps implement skipna map-side: null rows
    contribute the reduction identity (0 for sums, 1 for prod, the sentinel
    for min/max) and count partials count NON-null rows — so the wire schema
    (column count and dtypes) is identical to the null-free decomposition
    and the finalizer undoes the identities (docs/dtypes.md).
    """
    if not decomposable(fn, skipna, nulltag):
        raise ValueError(f"{fn} is not decomposable")
    specs, _final = AGG_DECOMP[fn]
    nullm = null_mask(x, nulltag) if x is not None else None
    out = []
    for s in specs:
        pcol = f"__p_{name}__{s.suffix}"
        if nullm is None:
            out.append((pcol, s.partial_fn, s.prep(x)))
            continue
        if s.partial_fn == "count":
            # count partials become sums of the non-null flag (same wire
            # column name/dtype; the combine is already "sum")
            out.append((pcol, "sum", (~nullm).astype(jnp.int32)))
            continue
        arr = s.prep(x)
        if s.partial_fn in ("min", "max"):
            ident = _partial_marker(s.partial_fn, arr.dtype)
        elif s.partial_fn == "prod":
            ident = jnp.ones((), arr.dtype)
        else:                                   # sum
            ident = jnp.zeros((), arr.dtype)
        out.append((pcol, s.partial_fn, jnp.where(nullm, ident, arr)))
    return out


def partial_aggregate(keys_sorted, count, values: dict[str, tuple],
                      *, cap_out: int, kernels=None):
    """Map-side stage: reduce each LOCAL key run to its partial statistics.

    Same grouped-input contract, values-entry forms ((fn, x) or
    (fn, x, skipna, nulltag)) and ``(__key<i>__, ...)`` output convention
    as :func:`segment_aggregate`; the output rows (one per local distinct key
    tuple) are what the hash exchange ships.
    """
    pvals: dict[str, tuple[str, jax.Array]] = {}
    for name, spec in values.items():
        fn, x, skipna, nulltag = _value_spec(spec)
        for pcol, pfn, arr in partial_decompose(name, fn, x, skipna, nulltag):
            pvals[pcol] = (pfn, arr)
    return segment_aggregate(keys_sorted, count, pvals, cap_out=cap_out,
                             kernels=kernels)


def final_aggregate(keys_sorted, count, agg_fns: dict[str, Any],
                    cols: dict[str, jax.Array], *, cap_out: int,
                    kernels=None):
    """Reduce-side stage: combine :func:`partial_aggregate` rows from every
    shard (grouped by key after the exchange + local sort) into final
    results.  ``agg_fns`` maps output name -> original agg fn (a bare str,
    or ``(fn, skipna, nulltag)`` for nullable value columns); ``cols``
    holds the partial ``__p_<name>__*`` columns.
    """
    norm = {name: (_agg_null_spec(*spec) if isinstance(spec, tuple)
                   else (spec, True, None))
            for name, spec in agg_fns.items()}
    cvals: dict[str, tuple[str, jax.Array]] = {}
    for name, (fn, skipna, tag) in norm.items():
        if not decomposable(fn, skipna, tag):
            raise ValueError(f"{fn} is not decomposable")
        for s in AGG_DECOMP[fn][0]:
            pcol = f"__p_{name}__{s.suffix}"
            cvals[pcol] = (s.combine_fn, cols[pcol])
    agg, n_seg, ovf = segment_aggregate(keys_sorted, count, cvals,
                                        cap_out=cap_out, kernels=kernels)
    gvalid = jnp.arange(cap_out, dtype=jnp.int32) < n_seg
    out = {k: v for k, v in agg.items() if k.startswith("__key")}
    for name, (fn, skipna, nulltag) in norm.items():
        specs, final = AGG_DECOMP[fn]
        p = {s.suffix: agg[f"__p_{name}__{s.suffix}"] for s in specs}
        res = final(p)
        if nulltag is not None and skipna:
            # undo the skipna identities: all-null groups reduced to the
            # pure marker/identity — map them back to the null value
            if fn in ("min", "max"):
                pf = specs[0].partial_fn
                marker = _partial_marker(pf, res.dtype)
                res = jnp.where(gvalid & (res == marker),
                                null_value(res.dtype, nulltag).astype(res.dtype),
                                res)
            elif fn in ("mean", "var", "std"):
                res = jnp.where(gvalid & (p["n"] == 0),
                                null_value(res.dtype, nulltag).astype(res.dtype),
                                res)
        out[name] = res
    return out, n_seg, ovf


# ---------------------------------------------------------------------------
# partitioned (segmented) windows — OVER (PARTITION BY ... ORDER BY ...)
#
# The physical planner guarantees the input is hash-partitioned on the
# partition keys (every group lives whole on ONE shard) and locally sorted by
# (partition keys, order keys), so all three kernels below are collective-free
# segment computations: the group-by layout that makes relational planning
# and array analytics compose (paper's core thesis).
# ---------------------------------------------------------------------------

def run_starts(keys: Sequence[jax.Array], valid: jax.Array) -> jax.Array:
    """Boolean mask: True at the first row of each run of equal key tuples
    (grouped input).  Invalid rows are never starts."""
    neq = functools.reduce(jnp.logical_or, [k[1:] != k[:-1] for k in keys])
    return valid & jnp.concatenate([jnp.full((1,), True), neq])


def _segment_first_index(seg_start: jax.Array) -> jax.Array:
    """For every row, the index of its segment's first row (running max of
    start positions; rows before the first start map to 0)."""
    idx = jnp.arange(seg_start.shape[0], dtype=jnp.int32)
    return lax.cummax(jnp.where(seg_start, idx, 0))


def segment_cumsum(x: jax.Array, part_keys: Sequence[jax.Array], count,
                   kernels=None, nulltag: str | None = None):
    """Grouped cumulative sum via the registry's ``segment_scan`` primitive.
    The ref backend is a plain inclusive scan minus the running total at each
    row's segment start (segment-reset exscan); the Pallas backend fuses the
    boundary mask and the scan into one pass.  No collectives — groups are
    shard-local under hash(partition_by).

    With a ``nulltag`` the semantics match pandas cumsum on nullable data:
    null rows stay null in the output and the running total skips them.
    """
    cap = x.shape[0]
    valid = valid_mask(count, cap)
    nullm = null_mask(x, nulltag)
    skip = valid if nullm is None else valid & ~nullm
    xz = jnp.where(skip, x, jnp.zeros((), x.dtype))
    if xz.dtype == jnp.bool_:
        xz = xz.astype(jnp.int32)        # cumsum of bool promotes anyway
    seg_start = run_starts(part_keys, valid)
    out = _K(kernels).segment_scan(xz, seg_start.astype(jnp.int32))
    if nullm is not None:
        out = jnp.where(nullm, null_value(out.dtype, nulltag).astype(out.dtype),
                        out)
    return jnp.where(valid, out, jnp.zeros((), out.dtype))


def segment_stencil1d(x: jax.Array, part_keys: Sequence[jax.Array], count,
                      weights: Sequence[float], center: int,
                      exact: bool = False, kernels=None):
    """Boundary-masked 1-D stencil: taps that would cross a group edge are
    zeroed (the zero-border convention applied per group).  No halo exchange
    — groups are shard-local, so neighbors outside the group are simply
    masked by segment-id mismatch.  The tap loop (and the ``exact`` mass
    renormalize, fused) resolves through the registry's ``segment_stencil``
    primitive.

    ``exact=True`` renormalizes each output by the realized weight mass:
    rows near a group edge divide by the weights of the taps that actually
    contributed instead of the full window (for uniform weights this is
    pandas' ``min_periods=1`` exact rolling mean; interior rows are
    untouched since their mass is the full weight sum).
    """
    w = [float(v) for v in weights]
    k_left, k_right = center, len(w) - 1 - center
    cap = x.shape[0]
    valid = valid_mask(count, cap)
    xz = jnp.where(valid, x.astype(jnp.float32), 0.0)
    seg_start = run_starts(part_keys, valid)
    sid = jnp.cumsum(seg_start.astype(jnp.int32)) - 1
    sid = jnp.where(valid, sid, -1)                 # padding never matches
    ext_x = jnp.concatenate([jnp.zeros((k_left,), jnp.float32), xz,
                             jnp.zeros((k_right,), jnp.float32)])
    ext_s = jnp.concatenate([jnp.full((k_left,), -2, jnp.int32), sid,
                             jnp.full((k_right,), -2, jnp.int32)])
    out = _K(kernels).segment_stencil(ext_x, ext_s, w, center, exact)
    return jnp.where(valid, out, 0.0)


def segment_rank(part_keys: Sequence[jax.Array],
                 order_keys: Sequence[jax.Array], count, kind: str,
                 kernels=None):
    """SQL ranking within groups of rows sorted by (part_keys, order_keys).

    row_number: 1-based position in the group (ties broken by the stable
    sort).  rank: 1 + position of the first row with the same order-key
    tuple (ties share, gaps after).  dense_rank: 1 + number of distinct
    order-key tuples before this row's (ties share, no gaps).  The two
    boundary masks (group starts, (group, order) run starts — every group
    start is also a run start) feed the registry's ``segment_rank``
    primitive; the ref backend composes cummax-located head indices, the
    Pallas backend runs fused segmented scans of the masks.
    """
    if kind not in ("row_number", "rank", "dense_rank"):
        raise ValueError(kind)
    cap = part_keys[0].shape[0]
    valid = valid_mask(count, cap)
    seg_start = run_starts(part_keys, valid)
    if kind == "row_number":
        order_start = seg_start
    else:
        order_start = run_starts(tuple(part_keys) + tuple(order_keys), valid)
    r = _K(kernels).segment_rank(seg_start.astype(jnp.int32),
                                 order_start.astype(jnp.int32), kind)
    return jnp.where(valid, r, 0).astype(jnp.int32)


def global_rank(order_keys: Sequence[jax.Array], count, cap: int, kind: str,
                axes: Axes, method: str = "allgather", kernels=None):
    """GLOBAL SQL ranking (no PARTITION BY) over the shard-concatenated
    stream, via a per-shard-count exscan — never a second global sort.

    row_number: 1-based global position in arrival order (an exclusive scan
    of the per-shard valid counts plus the local index).  rank/dense_rank:
    REQUIRE equal order-key tuples adjacent across the global stream (the
    planner guarantees it; api.rank sorts first).  Cross-shard tie runs are
    reconciled from tiny all-gathered per-shard scalars — each shard's
    count, first/last key tuple, trailing-run start and run count — so the
    only collectives are O(P) scalar gathers, no row movement.
    """
    if kind not in ("row_number", "rank", "dense_rank"):
        raise ValueError(kind)
    valid = valid_mask(count, cap)
    cnt = jnp.asarray(count, jnp.int32).reshape(())
    idx = jnp.arange(cap, dtype=jnp.int32)
    P = nshards(axes) if axes else 1

    if kind == "row_number":
        base = (exscan_scalar(cnt, axes, method=method) if axes
                else jnp.int32(0))
        return jnp.where(valid, base + idx + 1, 0).astype(jnp.int32)

    keys = tuple(order_keys)
    order_start = run_starts(keys, valid)
    start_idx = _segment_first_index(order_start)       # local run-start index
    run_ord = jnp.cumsum(order_start.astype(jnp.int32))  # 1-based local run #

    if P == 1:
        r = start_idx + 1 if kind == "rank" else run_ord
        return jnp.where(valid, r, 0).astype(jnp.int32)

    # -- tiny boundary gathers (one scalar all_gather per quantity) ----------
    last_i = jnp.clip(cnt - 1, 0, cap - 1)
    t_loc = start_idx[last_i]                   # trailing run's local start
    nruns = jnp.sum(order_start.astype(jnp.int32))
    gather = functools.partial(lax.all_gather, axis_name=axes, tiled=False)
    cnts = gather(cnt)                                          # (P,)
    ts = gather(t_loc)
    runs = gather(nruns)
    firsts = [gather(k[0]) for k in keys]
    lasts = [gather(k[last_i]) for k in keys]
    bases = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                             jnp.cumsum(cnts)[:-1]])            # exclusive
    r_me = my_rank(axes)
    base = bases[r_me]

    def key_eq(cols_a, j, cols_b):
        return functools.reduce(
            jnp.logical_and, [a[j] == b for a, b in zip(cols_a, cols_b)])

    if kind == "rank":
        # Walk backward from my shard: while the previous shard's trailing
        # run carries my first key, my leading run started there (or
        # earlier, when that whole shard is the key).  P is static and
        # small, so the walk unrolls to scalar selects.
        fk = [k[0] for k in keys]
        g = base                                 # leading run's global start
        alive = cnt > 0
        for step in range(1, P):
            j = jnp.maximum(r_me - step, 0)
            inb = (r_me - step >= 0) & alive
            nonempty = cnts[j] > 0
            take = inb & nonempty & key_eq(lasts, j, fk)
            g = jnp.where(take, bases[j] + ts[j], g)
            alive = inb & (~nonempty | (take & (ts[j] == 0)))
        out = jnp.where(start_idx == 0, g, base + start_idx) + 1
        return jnp.where(valid, out, 0).astype(jnp.int32)

    # dense_rank: distinct runs in shards before mine, minus the boundary
    # merges (a run continuing across consecutive non-empty shards counts
    # once).  M[j] = shard j's first key equals the last key of the nearest
    # previous non-empty shard.
    prev_any = jnp.bool_(False)
    prev_last = [jnp.zeros((), k.dtype) for k in keys]
    merges = []
    for j in range(P):                           # static unroll
        nonempty = cnts[j] > 0
        merges.append(nonempty & prev_any & key_eq(firsts, j, prev_last))
        prev_last = [jnp.where(nonempty, c[j], p)
                     for c, p in zip(lasts, prev_last)]
        prev_any = prev_any | nonempty
    m = jnp.stack(merges).astype(jnp.int32)
    sh = jnp.arange(P)
    runs_before = (jnp.sum(jnp.where(sh < r_me, runs, 0))
                   - jnp.sum(jnp.where(sh <= r_me, m, 0)))
    return jnp.where(valid, runs_before + run_ord, 0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# distributed scans (MPI_Exscan analogue)
# ---------------------------------------------------------------------------

def exscan_scalar(v, axes: Axes, method: str = "allgather"):
    """Exclusive prefix-sum of a per-shard scalar across shards."""
    P = nshards(axes)
    if P == 1:
        return jnp.zeros_like(v)
    if method == "ladder" and len(axes) == 1:
        # Hillis–Steele ladder over ppermute: log2(P) hops on the ICI ring.
        x = v
        shift = 1
        while shift < P:
            y = lax.ppermute(x, axes[0],
                             perm=[(i, i + shift) for i in range(P - shift)])
            x = x + y
            shift *= 2
        return x - v
    idx = my_rank(axes)
    allv = lax.all_gather(v, axes, tiled=False)          # (P, ...)
    ranks = jnp.arange(P)
    mask = (ranks < idx).astype(allv.dtype)
    return jnp.tensordot(mask, allv, axes=1)


def dist_cumsum(x: jax.Array, count, axes: Axes, method: str = "allgather",
                kernels=None):
    """Distributed cumulative sum over the valid prefix of each shard."""
    valid = valid_mask(count, x.shape[0])
    xz = jnp.where(valid, x, jnp.zeros((), x.dtype))
    local = _K(kernels).prefix_sum(xz) if x.shape[0] else xz
    total = local[-1] if x.shape[0] else jnp.zeros((), x.dtype)
    base = exscan_scalar(total, axes, method=method)
    return local + base


# ---------------------------------------------------------------------------
# 1-D stencil with halo exchange (SMA / WMA)
# ---------------------------------------------------------------------------

def halo_exchange(x: jax.Array, count, k_left: int, k_right: int, axes: Axes):
    """Count-aware halo exchange over the valid prefixes.

    Each shard's valid rows are the prefix ``x[:count]``; the global array is
    the concatenation of the prefixes.  The left halo is the left neighbor's
    *valid tail* ``x[count-k : count]``; the right halo is the right
    neighbor's (masked) head ``x[:k]``.  Zeros at the global borders.  The
    window radius must not exceed the smallest non-empty shard count (true
    for 1D_BLOCK layouts with radius << block — asserted at plan time).
    """
    P = nshards(axes) if axes else 1
    cap = x.shape[0]
    xz = jnp.where(valid_mask(count, cap), x, jnp.zeros((), x.dtype))
    left = jnp.zeros((k_left,), x.dtype)
    right = jnp.zeros((k_right,), x.dtype)
    if P == 1:
        return left, right
    my_tail = lax.dynamic_slice(
        xz, (jnp.maximum(count - k_left, 0),), (max(k_left, 1),))[:k_left] \
        if k_left else jnp.zeros((0,), x.dtype)
    my_head = xz[:k_right] if k_right else jnp.zeros((0,), x.dtype)
    if len(axes) == 1:
        ax = axes[0]
        if k_left:
            left = lax.ppermute(my_tail, ax,
                                perm=[(i, i + 1) for i in range(P - 1)])
        if k_right:
            right = lax.ppermute(my_head, ax,
                                 perm=[(i + 1, i) for i in range(P - 1)])
    else:
        # multi-axis fallback: gather edges, select flat neighbors
        idx = my_rank(axes)
        if k_left:
            edges = lax.all_gather(my_tail, axes)         # (P, k)
            left = jnp.where(idx > 0, edges[jnp.maximum(idx - 1, 0)], left)
        if k_right:
            edges = lax.all_gather(my_head, axes)
            right = jnp.where(idx < P - 1,
                              edges[jnp.minimum(idx + 1, P - 1)], right)
    return left, right


def stencil1d(x: jax.Array, count, weights: Sequence[float], center: int,
              axes: Axes, kernels=None, exact: bool = False):
    """out[i] = sum_j w[j] * x[i + j - center] over the distributed valid
    prefix, halos from neighbors (paper's SMA/WMA; MPI_Isend/Irecv analogue).

    The windowed weighted sum resolves through the registry's ``stencil1d``
    primitive (kernels/stencil1d Pallas kernel vs the jnp sliding-window
    ref).

    ``exact=True`` renormalizes rows near the GLOBAL borders by the realized
    weight mass (see :func:`segment_stencil1d`): the mass is the same
    stencil applied to a ones-vector through the same halo machinery, so a
    tap into a populated neighbor shard counts while a tap past the global
    ends does not.  Both stencils and the renormalize fuse into ONE
    ``stencil1d_exact`` kernel pass (the halo exchange for the mass vector
    still happens — masses near shard edges depend on neighbor validity).
    """
    w = [float(v) for v in weights]
    k_left, k_right = center, len(w) - 1 - center
    cap = x.shape[0]
    valid = valid_mask(count, cap)

    def build_ext(vals):
        vz = jnp.where(valid, vals.astype(jnp.float32), 0.0)
        left, right = halo_exchange(vz, count, k_left, k_right, axes)
        # ext[k_left + i] = v[i] (valid rows), right halo lands AT the
        # dynamic position k_left + count so windows never straddle padding.
        ext = jnp.zeros((cap + k_left + k_right,), jnp.float32)
        ext = lax.dynamic_update_slice(ext, vz, (k_left,))
        if k_right:
            ext = lax.dynamic_update_slice(ext, right, (k_left + count,))
        if k_left:
            ext = lax.dynamic_update_slice(ext, left, (0,))
        return ext

    kset = _K(kernels)
    if exact:
        out = kset.stencil1d_exact(build_ext(x),
                                   build_ext(jnp.ones((cap,), jnp.float32)), w)
    else:
        out = kset.stencil1d(build_ext(x), w)
    return jnp.where(valid, out, 0.0)


# ---------------------------------------------------------------------------
# limit (first n rows in global shard-concatenation order; df.head backend)
# ---------------------------------------------------------------------------

def limit(cols: dict[str, jax.Array], count, n: int, axes: Axes,
          cap_out: int):
    """Keep the first ``n`` valid rows of the global concatenation.

    No rows move: each shard clamps its valid count to its slice of
    ``[0, n)`` via an exclusive scan of counts (REP inputs skip even that —
    every shard independently keeps its first ``n``).  Buffers shrink to
    ``cap_out`` (valid rows always fit: the clamped count is <= n <=
    cap_out).
    """
    if axes:
        base = exscan_scalar(count.astype(jnp.int32), axes)
    else:
        base = jnp.int32(0)
    cnt = jnp.clip(jnp.int32(n) - base, 0, count).astype(jnp.int32)
    out = {k: v[:cap_out] for k, v in cols.items()}
    return out, cnt


# ---------------------------------------------------------------------------
# rebalance (1D_VAR -> 1D_BLOCK) and sample sort
# ---------------------------------------------------------------------------

def rebalance(cols: dict[str, jax.Array], count, *, axes: Axes,
              bucket_cap: int, cap_out: int, kernels=None,
              packed: bool = True):
    """Even out row counts across shards, preserving global row order."""
    P = nshards(axes) if axes else 1
    cap = next(iter(cols.values())).shape[0]
    if P == 1:
        return compact(cols, valid_mask(count, cap), cap_out, kernels=kernels)
    counts = lax.all_gather(count, axes)                 # (P,)
    total = jnp.sum(counts)
    base = exscan_scalar(count, axes)
    block = (total + P - 1) // P                          # ceil
    g = base + jnp.arange(cap, dtype=jnp.int32)
    dest = jnp.where(valid_mask(count, cap),
                     g // jnp.maximum(block, 1), P).astype(jnp.int32)
    out, cnt, ovf = exchange(cols, count, dest, axes=axes,
                             bucket_cap=bucket_cap, cap_out=cap_out,
                             kernels=kernels, packed=packed)
    return out, cnt, ovf


def sample_sort(cols: dict[str, jax.Array], count, key_names, *,
                axes: Axes, bucket_cap: int, cap_out: int, n_samples: int = 64,
                ascending: bool = True, pre_sorted: bool = False,
                kernels=None, packed: bool = True):
    """Global sort: local sort -> splitter selection -> route -> local sort.

    ``key_names`` may name several columns (lexicographic order, all
    ascending or all descending).  ``pre_sorted=True`` skips the first local
    sort — the physical planner sets it when the input already provides the
    required ordering.

    Splitters are full key TUPLES sampled from every shard and sorted
    lexicographically; rows route via dense lexicographic ranks over the
    union of local rows and splitters (:func:`lex_ranks` — the same
    machinery merge join uses), with a side="right" comparison so rows tying
    with a splitter tuple co-locate.  Routing therefore balances on the
    WHOLE key, not just the most-significant column: heavy skew on key0 with
    varied minor keys spreads across shards instead of piling ties onto one
    (the pre-composite-splitter failure mode).  Cross-shard order follows
    the splitter tuples and within-shard order comes from the final
    multi-key local sort, so the concatenation of shard prefixes is globally
    lexicographically sorted.
    """
    if isinstance(key_names, str):
        key_names = (key_names,)
    key_names = tuple(key_names)
    P = nshards(axes) if axes else 1
    if pre_sorted:
        scols = cols
    else:
        scols, _ = local_sort(cols, count, key_names)
    cap = scols[key_names[0]].shape[0]
    valid = valid_mask(count, cap)
    if P > 1:
        # sample key tuples evenly from the valid prefix of every shard
        pos = (jnp.arange(n_samples, dtype=jnp.int32) *
               jnp.maximum(count, 1)) // n_samples
        pos = jnp.clip(pos, 0, cap - 1)
        allsamp = []
        for kn in key_names:
            kv = scols[kn]
            samp = jnp.where(count > 0, kv[pos], _sentinel(kv.dtype))
            allsamp.append(lax.all_gather(samp, axes).reshape(-1))   # (P*n,)
        ssamp = lax.sort(tuple(allsamp), num_keys=len(allsamp)) \
            if len(allsamp) > 1 else (jnp.sort(allsamp[0]),)
        # P-1 splitter tuples at even quantiles
        qpos = (jnp.arange(1, P, dtype=jnp.int32) * ssamp[0].shape[0]) // P
        splitters = tuple(s[qpos] for s in ssamp)
        if len(key_names) == 1:
            key_vals = jnp.where(valid, scols[key_names[0]],
                                 _sentinel(scols[key_names[0]].dtype))
            dest = jnp.searchsorted(splitters[0], key_vals,
                                    side="right").astype(jnp.int32)
        else:
            # dense ranks over rows ∪ splitters; splitter ranks ascend (the
            # splitters are sorted), so a searchsorted on ranks IS the
            # lexicographic tuple comparison.
            joint = [jnp.concatenate([jnp.where(valid, scols[kn],
                                                _sentinel(scols[kn].dtype)), sp])
                     for kn, sp in zip(key_names, splitters)]
            jvalid = jnp.concatenate([valid, jnp.full((P - 1,), True)])
            ranks, _, _ = lex_ranks(joint, jvalid)
            dest = jnp.searchsorted(ranks[cap:], ranks[:cap],
                                    side="right").astype(jnp.int32)
        if not ascending:
            dest = (P - 1) - dest
    else:
        dest = jnp.zeros((cap,), jnp.int32)
    out, cnt, ovf = exchange(scols, count, dest, axes=axes,
                             bucket_cap=bucket_cap, cap_out=cap_out,
                             kernels=kernels, packed=packed)
    out, _ = local_sort(out, cnt, key_names)
    if not ascending:
        # reverse valid prefix
        capo = out[key_names[0]].shape[0]
        idx = jnp.where(valid_mask(cnt, capo),
                        jnp.maximum(cnt - 1, 0) - jnp.arange(capo, dtype=jnp.int32),
                        jnp.arange(capo, dtype=jnp.int32))
        idx = jnp.clip(idx, 0, capo - 1)
        out = {k: v[idx] for k, v in out.items()}
    return out, cnt, ovf


# ---------------------------------------------------------------------------
# concat
# ---------------------------------------------------------------------------

def concat(parts: Sequence[tuple[dict[str, jax.Array], jax.Array]], cap_out: int,
           kernels=None):
    """Vertical concat of per-shard tables (counts add; padding squeezed)."""
    names = list(parts[0][0])
    stacked = {n: jnp.concatenate([p[0][n] for p in parts]) for n in names}
    keep = jnp.concatenate([valid_mask(c, p[next(iter(p))].shape[0])
                            for p, c in parts])
    return compact(stacked, keep, cap_out, kernels=kernels)

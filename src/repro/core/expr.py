"""Column expression trees — the Macro-Pass analogue of HiFrames.

In the paper, ``df[:x] < 1.0`` is desugared at macro time into element-wise
array operations on the underlying column arrays (``_df_x .< 1.0``).  Here the
same desugaring is done by building a small expression tree that is evaluated
with jnp ops at lowering time, inside the single jitted SPMD program.  Because
evaluation happens inside the trace, arbitrary user functions (UDFs) compile
to exactly the same HLO as built-in operators — the paper's Figure 10 claim.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Expression nodes
# ---------------------------------------------------------------------------


class Expr:
    """Base class for column expressions.  Immutable, hash-consable."""

    children: tuple["Expr", ...] = ()

    # -- operator overloading (the "syntactic sugar" layer) -----------------
    def _bin(self, other: Any, op: str) -> "BinOp":
        return BinOp(op, self, as_expr(other))

    def _rbin(self, other: Any, op: str) -> "BinOp":
        return BinOp(op, as_expr(other), self)

    def __add__(self, o):  return self._bin(o, "add")
    def __radd__(self, o): return self._rbin(o, "add")
    def __sub__(self, o):  return self._bin(o, "sub")
    def __rsub__(self, o): return self._rbin(o, "sub")
    def __mul__(self, o):  return self._bin(o, "mul")
    def __rmul__(self, o): return self._rbin(o, "mul")
    def __truediv__(self, o):  return self._bin(o, "div")
    def __rtruediv__(self, o): return self._rbin(o, "div")
    def __mod__(self, o):  return self._bin(o, "mod")
    def __rmod__(self, o): return self._rbin(o, "mod")
    def __lt__(self, o):   return self._bin(o, "lt")
    def __le__(self, o):   return self._bin(o, "le")
    def __gt__(self, o):   return self._bin(o, "gt")
    def __ge__(self, o):   return self._bin(o, "ge")
    def __eq__(self, o):   return self._bin(o, "eq")          # noqa: E721
    def __ne__(self, o):   return self._bin(o, "ne")
    def __and__(self, o):  return self._bin(o, "and")
    def __rand__(self, o): return self._rbin(o, "and")
    def __or__(self, o):   return self._bin(o, "or")
    def __ror__(self, o):  return self._rbin(o, "or")
    def __invert__(self):  return UnOp("not", self)
    def __neg__(self):     return UnOp("neg", self)
    def __abs__(self):     return UnOp("abs", self)

    def isin(self, values) -> "IsIn":
        """Membership test (pandas ``Series.isin``).  String values against a
        category column lower to code-space comparison at plan-build time."""
        return IsIn(self, tuple(values))

    def isna(self) -> "UnOp":
        """True where the value is null (NaN for floats, null code for
        category columns — resolved against the schema at plan-build time)."""
        return UnOp("isna", self)

    def notna(self) -> "UnOp":
        return UnOp("not", self.isna())

    def astype(self, dtype) -> "Cast":
        """Element-wise cast to a numpy dtype."""
        return Cast(self, dtype)

    def __hash__(self):
        return hash(self.key())

    def key(self) -> tuple:
        """Structural key for hash-consing / CSE."""
        raise NotImplementedError

    def equals(self, other: "Expr") -> bool:
        return isinstance(other, Expr) and self.key() == other.key()

    def columns(self) -> set[tuple[int, str]]:
        """All (table_id, column) references in this expression."""
        out: set[tuple[int, str]] = set()
        stack = [self]
        while stack:
            e = stack.pop()
            if isinstance(e, ColRef):
                out.add((e.table_id, e.name))
            stack.extend(e.children)
        return out

    def map_refs(self, fn: Callable[["ColRef"], "Expr"]) -> "Expr":
        """Rebuild the tree with every ColRef replaced via ``fn``."""
        if isinstance(self, ColRef):
            return fn(self)
        if not self.children:
            return self
        new = tuple(c.map_refs(fn) for c in self.children)
        return self.with_children(new)

    def with_children(self, children: tuple["Expr", ...]) -> "Expr":
        raise NotImplementedError


class ColRef(Expr):
    """Reference to a column of a logical plan node (by node id)."""

    def __init__(self, table_id: int, name: str):
        self.table_id = table_id
        self.name = name

    def key(self):
        return ("col", self.table_id, self.name)

    def __repr__(self):
        return f"col({self.table_id}.{self.name})"


class Const(Expr):
    def __init__(self, value: Any):
        self.value = value

    def key(self):
        v = self.value
        if isinstance(v, (np.ndarray, jnp.ndarray)):
            v = ("arr", id(v))
        return ("const", v)

    def __repr__(self):
        return f"const({self.value})"


class ExternalArray(Expr):
    """A free JAX array used inside a relational expression.

    This is the "tight integration with array computations" hook: any array
    from the surrounding program can appear inside a filter / aggregate
    expression, exactly as the paper allows referring to arrays of other
    data frames.  The array must be 1D_BLOCK-aligned with the table rows.
    """

    def __init__(self, array: Any, tag: str | None = None):
        self.array = array
        self.tag = tag or f"ext{id(array)}"

    def key(self):
        return ("ext", self.tag)

    def __repr__(self):
        return f"ext({self.tag})"


class BinOp(Expr):
    def __init__(self, op: str, a: Expr, b: Expr):
        self.op = op
        self.children = (a, b)

    def key(self):
        return ("bin", self.op, self.children[0].key(), self.children[1].key())

    def with_children(self, children):
        return BinOp(self.op, *children)

    def __repr__(self):
        return f"({self.children[0]} {self.op} {self.children[1]})"


class UnOp(Expr):
    def __init__(self, op: str, a: Expr):
        self.op = op
        self.children = (a,)

    def key(self):
        return ("un", self.op, self.children[0].key())

    def with_children(self, children):
        return UnOp(self.op, *children)

    def __repr__(self):
        return f"{self.op}({self.children[0]})"


class IsIn(Expr):
    """Membership of a column expression in a small literal value set.

    Evaluates as an OR-chain of equality comparisons (the set is a plan
    constant).  String value sets against category columns are rewritten to
    int32 code sets by the API layer before lowering.
    """

    def __init__(self, a: Expr, values: tuple):
        self.children = (a,)
        self.values = tuple(values)

    def key(self):
        return ("isin", self.children[0].key(), self.values)

    def with_children(self, children):
        return IsIn(children[0], self.values)

    def __repr__(self):
        return f"isin({self.children[0]}, {list(self.values)})"


class Cast(Expr):
    """Element-wise dtype cast (``Expr.astype`` / ``DataFrame.astype``)."""

    def __init__(self, a: Expr, dtype):
        self.children = (a,)
        self.to = np.dtype(dtype)

    def key(self):
        return ("cast", self.children[0].key(), self.to.str)

    def with_children(self, children):
        return Cast(children[0], self.to)

    def __repr__(self):
        return f"cast[{self.to.name}]({self.children[0]})"


class UDF(Expr):
    """Element-wise user-defined function over one or more columns.

    ``fn`` must be a jax-traceable function of scalars/arrays (applied
    vectorized).  It inlines into the same compiled program as built-in
    operators — zero-cost UDFs (paper Fig. 10).
    """

    def __init__(self, fn: Callable, *args: Expr, name: str | None = None):
        self.fn = fn
        self.name = name or getattr(fn, "__name__", "udf")
        self.children = tuple(as_expr(a) for a in args)

    def key(self):
        return ("udf", id(self.fn)) + tuple(c.key() for c in self.children)

    def with_children(self, children):
        return UDF(self.fn, *children, name=self.name)

    def __repr__(self):
        return f"udf:{self.name}({', '.join(map(repr, self.children))})"


def as_expr(x: Any) -> Expr:
    if isinstance(x, Expr):
        return x
    if isinstance(x, (jax.Array, np.ndarray)) and getattr(x, "ndim", 0) > 0:
        return ExternalArray(x)
    return Const(x)


# ---------------------------------------------------------------------------
# Aggregation specs (used by aggregate())
# ---------------------------------------------------------------------------

AGG_FNS = ("sum", "mean", "count", "min", "max", "prod", "any", "all",
           "var", "std", "first", "nunique")


@dataclasses.dataclass(frozen=True)
class AggExpr:
    """A reduction ``fn`` over an element-wise expression, e.g. sum(:x < 1.0).

    ``skipna`` follows pandas: nulls (NaN / null dictionary codes) are
    excluded from the reduction by default; ``skipna=False`` lets them
    poison the group result.  ``count`` over an expression counts non-null
    values (pandas ``count``); ``count`` with ``expr=None`` counts rows
    (pandas ``size``) and ignores ``skipna``.
    """

    fn: str
    expr: Expr = None  # None for count()
    skipna: bool = True

    def __post_init__(self):
        if self.fn not in AGG_FNS:
            raise ValueError(
                f"unknown aggregation fn {self.fn!r}; valid: {AGG_FNS}")


def sum_(e, skipna=True):    return AggExpr("sum", as_expr(e), skipna)
def mean(e, skipna=True):    return AggExpr("mean", as_expr(e), skipna)
def min_(e, skipna=True):    return AggExpr("min", as_expr(e), skipna)
def max_(e, skipna=True):    return AggExpr("max", as_expr(e), skipna)
def prod(e, skipna=True):    return AggExpr("prod", as_expr(e), skipna)
def any_(e, skipna=True):    return AggExpr("any", as_expr(e), skipna)
def all_(e, skipna=True):    return AggExpr("all", as_expr(e), skipna)
def var(e, skipna=True):     return AggExpr("var", as_expr(e), skipna)
def std(e, skipna=True):     return AggExpr("std", as_expr(e), skipna)
def first(e, skipna=True):   return AggExpr("first", as_expr(e), skipna)
def nunique(e, skipna=True): return AggExpr("nunique", as_expr(e), skipna)


def count(e=None):
    return AggExpr("count", as_expr(e) if e is not None else None)


# ---------------------------------------------------------------------------
# Evaluation (inside the jit trace)
# ---------------------------------------------------------------------------

_BIN_IMPL = {
    "add": jnp.add,
    "sub": jnp.subtract,
    "mul": jnp.multiply,
    "div": jnp.divide,
    "mod": jnp.mod,
    "lt": jnp.less,
    "le": jnp.less_equal,
    "gt": jnp.greater,
    "ge": jnp.greater_equal,
    "eq": jnp.equal,
    "ne": jnp.not_equal,
    "and": jnp.logical_and,
    "or": jnp.logical_or,
}

_UN_IMPL = {
    "not": jnp.logical_not,
    "neg": jnp.negative,
    "abs": jnp.abs,
    "log": jnp.log,
    "exp": jnp.exp,
    "sqrt": jnp.sqrt,
    "isnan": jnp.isnan,
    "floor": jnp.floor,
    "ceil": jnp.ceil,
}


def evaluate(e: Expr, env: dict[str, jax.Array],
             cache: dict | None = None) -> jax.Array:
    """Evaluate an expression against column arrays.

    ``env`` maps column names to per-shard arrays; ExternalArrays are looked
    up under ``"ext:<tag>"`` (they are fed through the same shard_map so they
    stay row-aligned).  ``cache`` provides hash-consed common-subexpression
    elimination: identical subtrees are computed once per evaluation context.
    (The paper gets CSE from the Julia compiler "for free"; we get it from
    memoized evaluation — XLA dedups the rest.)
    """
    if cache is None:
        cache = {}
    k = e.key()
    if k in cache:
        return cache[k]
    if isinstance(e, ColRef):
        out = env[e.name]
    elif isinstance(e, Const):
        out = jnp.asarray(e.value)
    elif isinstance(e, ExternalArray):
        out = env.get("ext:" + e.tag)
        if out is None:
            out = jnp.asarray(e.array)
    elif isinstance(e, BinOp):
        a = evaluate(e.children[0], env, cache)
        b = evaluate(e.children[1], env, cache)
        out = _BIN_IMPL[e.op](a, b)
    elif isinstance(e, UnOp):
        if e.op == "isna":
            # Unresolved fallback: floats are null iff NaN; non-float columns
            # cannot hold nulls (category isna is rewritten to a code test
            # against the schema before lowering).
            a = evaluate(e.children[0], env, cache)
            out = jnp.isnan(a) if jnp.issubdtype(a.dtype, jnp.floating) \
                else jnp.zeros(jnp.shape(a), dtype=bool)
        else:
            out = _UN_IMPL[e.op](evaluate(e.children[0], env, cache))
    elif isinstance(e, IsIn):
        a = evaluate(e.children[0], env, cache)
        if not e.values:
            out = jnp.zeros(jnp.shape(a), dtype=bool)
        else:
            out = jnp.zeros(jnp.shape(a), dtype=bool)
            for v in e.values:
                out = out | (a == jnp.asarray(v))
    elif isinstance(e, Cast):
        out = evaluate(e.children[0], env, cache).astype(e.to)
    elif isinstance(e, UDF):
        out = e.fn(*(evaluate(c, env, cache) for c in e.children))
    else:
        raise TypeError(f"unknown expr {e!r}")
    cache[k] = out
    return out


def fn_expr(fn: Callable, *args) -> UDF:
    """Public helper: lift a jax-traceable function into an expression."""
    return UDF(fn, *args)


def log(e):   return UnOp("log", as_expr(e))
def exp(e):   return UnOp("exp", as_expr(e))
def sqrt(e):  return UnOp("sqrt", as_expr(e))
def isnan(e): return UnOp("isnan", as_expr(e))


# ---------------------------------------------------------------------------
# Static result-dtype / nullability inference (schema propagation)
# ---------------------------------------------------------------------------

_BOOL_BIN = frozenset({"lt", "le", "gt", "ge", "eq", "ne", "and", "or"})
_BOOL_UN = frozenset({"not", "isnan", "isna"})
_FLOAT_UN = frozenset({"log", "exp", "sqrt", "floor", "ceil"})


def _float_ty() -> np.dtype:
    # jax's canonical float for the active x64 setting
    return np.dtype(jnp.result_type(float))


def infer_dtype(e: Expr, schema: dict[str, Any]) -> np.dtype:
    """Physical result dtype of ``e`` over columns typed by ``schema``.

    Mirrors jnp promotion under the active x64 setting, so ``explain()`` and
    the capacity/byte censuses report what the lowered program actually
    computes instead of a blanket float32 (the old ``ir.Project`` fallback).
    UDFs are abstractly traced via ``jax.eval_shape``; anything untraceable
    falls back to float32.
    """
    if isinstance(e, ColRef):
        dt = schema.get(e.name)
        return np.dtype(dt) if dt is not None else np.dtype(np.float32)
    if isinstance(e, Const):
        return np.dtype(jnp.result_type(e.value))
    if isinstance(e, ExternalArray):
        return np.dtype(jnp.result_type(e.array.dtype))
    if isinstance(e, IsIn):
        return np.dtype(bool)
    if isinstance(e, Cast):
        return e.to
    if isinstance(e, BinOp):
        if e.op in _BOOL_BIN:
            return np.dtype(bool)
        a = infer_dtype(e.children[0], schema)
        b = infer_dtype(e.children[1], schema)
        t = np.dtype(jnp.promote_types(a, b))
        if e.op == "div" and not np.issubdtype(t, np.floating):
            t = np.dtype(jnp.promote_types(t, _float_ty()))
        return t
    if isinstance(e, UnOp):
        if e.op in _BOOL_UN:
            return np.dtype(bool)
        t = infer_dtype(e.children[0], schema)
        if e.op in _FLOAT_UN and not np.issubdtype(t, np.floating):
            return np.dtype(jnp.promote_types(t, _float_ty()))
        if e.op == "neg" and t == np.dtype(bool):
            return np.dtype(np.int32)
        return t
    if isinstance(e, UDF):
        try:
            spec = [jax.ShapeDtypeStruct((4,), infer_dtype(c, schema))
                    for c in e.children]
            return np.dtype(jax.eval_shape(e.fn, *spec).dtype)
        except Exception:
            return np.dtype(np.float32)
    return np.dtype(np.float32)


def expr_nullable(e: Expr, schema: dict[str, Any]) -> bool:
    """Whether ``e`` can produce nulls (NaN / null codes) over ``schema``.

    Comparisons and membership tests are never null (NaN compares False —
    pandas semantics); arithmetic propagates nullability; non-nullable
    sources stay non-nullable, so null-free pipelines pay zero masking cost.
    """
    from .dtypes import is_nullable
    if isinstance(e, ColRef):
        return is_nullable(schema.get(e.name))
    if isinstance(e, (Const, ExternalArray, IsIn)):
        return False
    if isinstance(e, BinOp):
        if e.op in _BOOL_BIN:
            return False
        return any(expr_nullable(c, schema) for c in e.children)
    if isinstance(e, UnOp):
        if e.op in _BOOL_UN:
            return False
        return expr_nullable(e.children[0], schema)
    if isinstance(e, (Cast, UDF)):
        return any(expr_nullable(c, schema) for c in e.children)
    return False


def nulltag_for(e: Expr | None, schema: dict[str, Any]) -> str | None:
    """The in-band null encoding of an expression's values over ``schema``:
    ``"code"`` (dictionary code -1) for nullable category columns, ``"nan"``
    for nullable floating results, None for everything null-free — the tag
    the segment/partial kernels use to DERIVE validity masks, decided at
    lowering time so null-free pipelines take the exact pre-null code paths.
    """
    from .dtypes import is_category
    if e is None or not expr_nullable(e, schema):
        return None
    if isinstance(e, ColRef) and is_category(schema.get(e.name)):
        return "code"
    dt = np.dtype(infer_dtype(e, schema))
    return "nan" if np.issubdtype(dt, np.floating) else None

"""HiFrames user API — data frames tightly integrated with array code.

Mirrors the paper's Table 1 surface:

    import repro.hiframes as hf
    df  = hf.table({"id": ids, "x": xs})          # DataSource analogue
    v   = df["x"]                                  # projection -> expression
    df2 = df[df["id"] < 100]                       # filter
    df3 = hf.join(df1, df2, on=("id", "cid"))      # join (different key names OK)
    df4 = hf.aggregate(df1, "id", xc=hf.sum(df1["x"] < 1.0), ym=hf.mean(df1["y"]))
    df5 = hf.concat(df1, df2)                      # [df1; df2]
    c   = hf.cumsum(df1, df1["x"])                 # analytics
    a   = hf.stencil(df1, df1["x"], [1, 2, 1], scale=4.0)   # WMA
    out = df4.collect()                            # optimize+distribute+jit+run

Composite (multi-column) keys are supported end-to-end — join, group-by and
sort accept key tuples, which shuffle on a combined hash, sort
lexicographically and compare position-wise (TPCx-BB-style query shapes):

    hf.join(l, r, on=[("a", "ca"), ("b", "cb")])   # 2-column equi-join
    hf.join(l, r, on=["k1", "k2"])                 # same names both sides
    hf.aggregate(df, by=("k1", "k2"), s=hf.sum_(df["x"]))
    df.sort(by=("k1", "k2"))

``on=("id", "cid")`` — a 2-tuple of strings — keeps its historical meaning of
a SINGLE key pair with different names; use a list for composite keys.

Window functions may be PARTITIONED (SQL ``OVER (PARTITION BY ... ORDER BY
...)``) — per-group cumsum/SMA/WMA/lag/lead plus rank/row_number and rolling
sums/means, planned as hash co-location + grouped local sort (both elided
when the input already provides them — ``join → wma`` over the join keys
shuffles exactly as much as the bare join):

    w = df.over("g", order_by="t")                 # the OVER clause
    d1 = w.cumsum(df["x"])                         # per-group running total
    d2 = w.wma(df["x"], [1, 2, 1], out="wma")      # group-bounded stencil
    d3 = w.rank()                                  # SQL RANK()
    d4 = hf.lag(df, df["x"], partition_by="g", order_by="t")   # kwargs form

Every collected column is a plain jax.Array; any jax array can be attached
with ``with_column`` or referenced directly inside expressions (the paper's
"any array in the program" rule).
"""
from __future__ import annotations

from typing import Any, Sequence

import numpy as np

from . import distribution as D
from . import ir
from .expr import (AggExpr, ColRef, Expr, UDF, as_expr, count, first, fn_expr,
                   max_, mean, min_, nunique, std, sum_, var)
from .lower import ExecConfig, Lowered, lower
from .table import DTable

__all__ = [
    "DataFrame", "Over", "table", "join", "aggregate", "concat", "cumsum",
    "stencil", "sma", "wma", "lag", "lead", "rank", "dense_rank",
    "row_number", "rolling_sum", "rolling_mean", "sum_", "mean", "count",
    "min_", "max_", "var", "std", "first", "nunique", "udf", "ExecConfig",
    "explain",
]


def _over_keys(x) -> tuple[str, ...]:
    """Normalize an optional partition/order key spec to a tuple (an absent
    spec — None or an empty sequence — becomes ())."""
    return () if not x else ir.as_keys(x)


class DataFrame:
    """Lazy distributed data frame (wraps a logical plan node).

    ``rep_nodes`` tracks which plan nodes the user pinned to REP via
    :meth:`replicate` — the set survives joins/aggregates so a broadcast
    dimension table stays broadcast inside a larger plan."""

    def __init__(self, node: ir.Node, rep_nodes: frozenset = frozenset()):
        self.node = node
        self._rep_nodes = frozenset(rep_nodes)

    @property
    def _replicated(self) -> bool:
        return self.node.id in self._rep_nodes

    # -- schema ---------------------------------------------------------------
    @property
    def schema(self) -> dict[str, np.dtype]:
        return self.node.schema

    @property
    def columns(self) -> list[str]:
        return list(self.node.schema)

    # -- expression building ----------------------------------------------------
    def __getitem__(self, key):
        if isinstance(key, str):
            return ColRef(self.node.id, key)
        if isinstance(key, Expr):                       # df[pred] -> filter
            return DataFrame(ir.Filter(self.node, key), self._rep_nodes)
        if isinstance(key, (list, tuple)):              # df[["a","b"]] -> project
            cols = {k: ColRef(self.node.id, k) for k in key}
            return DataFrame(ir.Project(self.node, cols), self._rep_nodes)
        raise TypeError(key)

    def with_column(self, name: str, e) -> "DataFrame":
        """Attach a derived column (df[:id3] = expr analogue)."""
        cols = {k: ColRef(self.node.id, k) for k in self.node.schema}
        cols[name] = as_expr(e)
        return DataFrame(ir.Project(self.node, cols), self._rep_nodes)

    def rename(self, mapping: dict[str, str]) -> "DataFrame":
        cols = {mapping.get(k, k): ColRef(self.node.id, k) for k in self.node.schema}
        return DataFrame(ir.Project(self.node, cols), self._rep_nodes)

    def select(self, *names: str) -> "DataFrame":
        return self[list(names)]

    def sort(self, by, ascending: bool = True) -> "DataFrame":
        """Global sort; ``by`` is a column name or a tuple/list of names
        (lexicographic, most-significant first)."""
        return DataFrame(ir.Sort(self.node, ir.as_keys(by), ascending),
                         self._rep_nodes)

    def over(self, partition_by, order_by=None) -> "Over":
        """Partitioned window context (SQL ``OVER (PARTITION BY ... ORDER BY
        ...)``): ``df.over("g", order_by="t").cumsum(df["x"])``.  See
        docs/window_functions.md for the plan shapes."""
        return Over(self, partition_by, order_by)

    def replicate(self) -> "DataFrame":
        """Pin this frame to REP (broadcast) — small dimension tables."""
        return DataFrame(self.node,
                         frozenset(n.id for n in ir.topo_order(self.node)))

    # -- execution ---------------------------------------------------------------
    def _force_rep(self) -> set[int]:
        return set(self._rep_nodes)

    def collect(self, cfg: ExecConfig | None = None, keep: Sequence[str] | None = None,
                kernels: dict | None = None) -> DTable:
        """Execute with capacity-overflow auto-retry (doubled expansion —
        the 1D_VAR static-capacity fault-tolerance hook, DESIGN.md §2)."""
        import dataclasses as _dc
        cfg = cfg or ExecConfig()
        # Clamp once up front: a negative auto_retry means "no retries", and
        # the loop below must still run (and bind ``t``) exactly once.
        retries = max(cfg.auto_retry, 0)
        for _attempt in range(retries + 1):
            lowered, _ = lower(self.node, cfg, set(keep) if keep else None,
                               force_rep=self._force_rep(), kernels=kernels)
            t = lowered()
            if not t.overflow or _attempt == retries:
                return t
            cfg = _dc.replace(cfg,
                              join_expansion=max(cfg.join_expansion, 1.0) * 2,
                              shuffle_slack=cfg.shuffle_slack * 2,
                              agg_group_cap=(max(1, cfg.agg_group_cap) * 2
                                             if cfg.agg_group_cap is not None
                                             else None))
        return t

    def lower(self, cfg: ExecConfig | None = None, keep: Sequence[str] | None = None,
              collect_block: bool = False, kernels: dict | None = None) -> Lowered:
        lowered, _ = lower(self.node, cfg, set(keep) if keep else None,
                           collect_block=collect_block,
                           force_rep=self._force_rep(), kernels=kernels)
        return lowered

    def to_numpy(self, cfg: ExecConfig | None = None) -> dict[str, np.ndarray]:
        return self.collect(cfg).to_numpy()

    def collect_matrix(self, cols: Sequence[str], cfg: ExecConfig | None = None):
        """Matrix assembly (the paper's transpose(typed_hcat) pattern): returns
        a row-sharded (rows, k) float32 matrix + row count, rebalanced to
        1D_BLOCK as ML algorithms require."""
        import jax.numpy as jnp
        lowered, _ = lower(self.node, cfg, set(cols), collect_block=True,
                           force_rep=self._force_rep())
        t = lowered()
        mat = jnp.stack([t.columns[c].astype(jnp.float32) for c in cols], axis=1)
        return mat, t.counts, t.capacity

    def _plan(self, cfg: ExecConfig):
        """Shared planning prologue (optimize -> infer -> rebalance ->
        physical plan) for explain()/physical_plan().  Mirrors lower()'s
        sequence under the same config; a plain collect() executes this
        plan (collect(keep=...) / collect_matrix() additionally prune
        columns or append a root rebalance, which introspection omits)."""
        from . import optimizer as opt
        from . import physical_plan as pp
        root = self.node
        if cfg.optimize_plan:
            root, _ = opt.optimize(root)
        info = D.infer(root, force_rep=self._force_rep(),
                       broadcast_join=cfg.broadcast_join)
        root = D.insert_rebalance(root, info)
        return root, info, pp.plan_physical(root, info.dists, cfg)

    def physical_plan(self, cfg: ExecConfig | None = None):
        """The property-driven physical plan (core/physical_plan.py) this
        frame would execute: op list with partitioning/ordering annotations,
        plus ``counts()`` / ``shuffle_count()`` for introspection — the hook
        the exchange-elision tests and benchmarks use."""
        _root, _info, pplan = self._plan(cfg or ExecConfig())
        return pplan

    def explain(self, cfg: ExecConfig | None = None) -> str:
        """Logical plan with distribution annotations, followed by the
        physical plan: one line per operator with its provided partitioning
        and ordering, exchange/sort insertions made explicit, and a leading
        shuffle/sort census."""
        root, info, pplan = self._plan(cfg or ExecConfig())
        return ir.plan_str(root, info.dists) + "\n\n" + pplan.render()

    def __repr__(self):
        return f"DataFrame({list(self.node.schema)})\n{ir.plan_str(self.node)}"


# ---------------------------------------------------------------------------
# constructors / verbs
# ---------------------------------------------------------------------------


def table(columns: dict[str, Any], name: str = "t") -> DataFrame:
    """Create a data frame from host/device arrays (DataSource analogue)."""
    lens = {k: len(v) for k, v in columns.items()}
    if len(set(lens.values())) > 1:
        raise ValueError(f"column length mismatch: {lens}")
    return DataFrame(ir.Scan(name, dict(columns)))


def _parse_on(on) -> tuple[tuple[str, ...], tuple[str, ...]]:
    """Normalize the join key spec to (left_keys, right_keys) tuples.

    Accepted forms:
      "k"                       one key, same name both sides
      ("lk", "rk")              one key pair (historical form — a 2-tuple of
                                strings is a PAIR, not two key columns)
      ["k1", "k2", ...]         composite key, same names both sides
      [("a","ca"), "b", ...]    composite key, per-position pair or shared name
    """
    if isinstance(on, str):
        return (on,), (on,)
    # only a literal 2-TUPLE of strings is the historical pair form; a LIST
    # of two names (["k1","k2"]) is a composite key on shared names.
    if isinstance(on, tuple) and len(on) == 2 \
            and all(isinstance(x, str) for x in on):
        return (on[0],), (on[1],)
    lo, ro = [], []
    for item in on:
        if isinstance(item, str):
            lo.append(item)
            ro.append(item)
        else:
            l, r = item
            lo.append(l)
            ro.append(r)
    if not lo:
        raise ValueError("join requires at least one key column")
    return tuple(lo), tuple(ro)


def join(left: DataFrame, right: DataFrame, on, suffix: str = "_r",
         how: str = "inner") -> DataFrame:
    """Equi-join; ``on`` is a name, a (left_name, right_name) pair, or a list
    of names / pairs for composite (multi-column) keys — see :func:`_parse_on`.

    how="left" keeps unmatched left rows (right columns zero-filled; a
    ``_matched`` int column distinguishes real zeros — the static-shape
    stand-in for SQL NULLs, documented in DESIGN.md)."""
    lo, ro = _parse_on(on)
    if how not in ("inner", "left"):
        raise ValueError(how)
    rep = left._rep_nodes | right._rep_nodes
    node = ir.Join(left.node, right.node, lo, ro, suffix, how)
    if left._replicated and right._replicated:
        rep = rep | {node.id}
    return DataFrame(node, rep)


def aggregate(df: DataFrame, by, **aggs: AggExpr) -> DataFrame:
    """Group-by aggregation; ``by`` is a column name or a tuple/list of names
    (composite key — groups are distinct key combinations).  Any number of
    ``nunique`` aggregations may be mixed in (each counts distinct values of
    its own expression per group)."""
    for k, v in aggs.items():
        if not isinstance(v, AggExpr):
            raise TypeError(f"{k} must be an AggExpr (hf.sum/mean/...)")
    node = ir.Aggregate(df.node, ir.as_keys(by), dict(aggs))
    rep = df._rep_nodes | ({node.id} if df._replicated else set())
    return DataFrame(node, frozenset(rep))


def concat(*dfs: DataFrame) -> DataFrame:
    schemas = [tuple(d.node.schema) for d in dfs]
    if len(set(schemas)) > 1:
        raise ValueError(f"schema mismatch in concat: {schemas}")
    node = ir.Concat(tuple(d.node for d in dfs))
    rep = frozenset().union(*(d._rep_nodes for d in dfs))
    if all(d._replicated for d in dfs):
        rep = rep | {node.id}
    return DataFrame(node, frozenset(rep))


def cumsum(df: DataFrame, e, out: str = "cumsum", *,
           partition_by=None, order_by=None) -> DataFrame:
    """Distributed cumulative sum (MPI_Exscan analogue).

    With ``partition_by``, the sum restarts at every group boundary
    (``SUM(...) OVER (PARTITION BY ... ORDER BY ...)``) and rows come back
    hash-partitioned on the group keys, sorted by (partition, order) keys
    within each shard — the grouped layout, not input order."""
    return DataFrame(ir.Window(df.node, "cumsum", as_expr(e), out,
                               partition_by=_over_keys(partition_by),
                               order_by=_over_keys(order_by)),
                     df._rep_nodes)


def stencil(df: DataFrame, e, weights: Sequence[float], *, scale: float = 1.0,
            center: int | None = None, out: str = "stencil",
            partition_by=None, order_by=None) -> DataFrame:
    """1-D stencil: out[i] = sum_j w[j]/scale * x[i+j-center].

    SMA == stencil(x, [1,1,1], scale=3); WMA == stencil(x, [1,2,1], scale=4).
    With ``partition_by``, taps never cross a group boundary (the zero-border
    convention applies per group) — TPCx-BB Q26-style grouped moving
    averages."""
    w = tuple(float(x) / scale for x in weights)
    c = len(w) // 2 if center is None else center
    return DataFrame(ir.Window(df.node, "stencil", as_expr(e), out,
                               weights=w, center=c,
                               partition_by=_over_keys(partition_by),
                               order_by=_over_keys(order_by)),
                     df._rep_nodes)


def sma(df: DataFrame, e, window: int = 3, out: str = "sma", *,
        partition_by=None, order_by=None) -> DataFrame:
    return stencil(df, e, [1.0] * window, scale=float(window), out=out,
                   partition_by=partition_by, order_by=order_by)


def wma(df: DataFrame, e, weights: Sequence[float], out: str = "wma", *,
        partition_by=None, order_by=None) -> DataFrame:
    return stencil(df, e, weights, scale=float(sum(weights)), out=out,
                   partition_by=partition_by, order_by=order_by)


def lag(df: DataFrame, e, n: int = 1, out: str = "lag", *,
        partition_by=None, order_by=None) -> DataFrame:
    """SQL lag(): out[i] = x[i-n] across the distributed order (paper Table 1
    mentions SQL's lag/lead as the window-function alternative to stencils —
    here they ARE stencils: a one-hot window with offset).  Borders -> 0;
    with ``partition_by`` the border is the group edge."""
    return stencil(df, e, [1.0] + [0.0] * n, center=n, out=out,
                   partition_by=partition_by, order_by=order_by)


def lead(df: DataFrame, e, n: int = 1, out: str = "lead", *,
         partition_by=None, order_by=None) -> DataFrame:
    """SQL lead(): out[i] = x[i+n]; borders -> 0 (group edges when
    partitioned)."""
    return stencil(df, e, [0.0] * n + [1.0], center=0, out=out,
                   partition_by=partition_by, order_by=order_by)


def rolling_sum(df: DataFrame, e, window: int, out: str = "rolling_sum", *,
                partition_by=None, order_by=None) -> DataFrame:
    """Trailing rolling sum: out[i] = sum of x over rows [i-window+1 .. i].

    A one-sided stencil (center = window-1), so leading borders — the global
    start, or each group start when partitioned — contribute zeros."""
    return stencil(df, e, [1.0] * window, center=window - 1, out=out,
                   partition_by=partition_by, order_by=order_by)


def rolling_mean(df: DataFrame, e, window: int, out: str = "rolling_mean", *,
                 partition_by=None, order_by=None) -> DataFrame:
    """Trailing rolling mean = rolling_sum / window.  NOTE: the first
    window-1 rows of the series (or of each group) divide a zero-padded
    partial sum by the FULL window, per the stencil border convention."""
    return stencil(df, e, [1.0] * window, scale=float(window),
                   center=window - 1, out=out,
                   partition_by=partition_by, order_by=order_by)


def _rank_df(df: DataFrame, kind: str, partition_by, order_by,
             out: str) -> DataFrame:
    return DataFrame(ir.Window(df.node, kind, None, out,
                               partition_by=_over_keys(partition_by),
                               order_by=_over_keys(order_by)),
                     df._rep_nodes)


def rank(df: DataFrame, partition_by, order_by, out: str = "rank") -> DataFrame:
    """SQL RANK() OVER (PARTITION BY ... ORDER BY ...): 1-based; equal
    order-key tuples share a rank, with gaps after ties."""
    return _rank_df(df, "rank", partition_by, order_by, out)


def dense_rank(df: DataFrame, partition_by, order_by,
               out: str = "dense_rank") -> DataFrame:
    """SQL DENSE_RANK(): ties share a rank, no gaps."""
    return _rank_df(df, "dense_rank", partition_by, order_by, out)


def row_number(df: DataFrame, partition_by, order_by,
               out: str = "row_number") -> DataFrame:
    """SQL ROW_NUMBER(): 1-based position within the group (ties broken by
    the stable sort, so equal order keys number deterministically by
    post-exchange arrival order)."""
    return _rank_df(df, "row_number", partition_by, order_by, out)


class Over:
    """Fluent handle for partitioned windows: ``df.over(partition_by=...,
    order_by=...)`` then any window verb — the SQL ``OVER`` clause as an
    object.  Each method returns a new DataFrame with the window column
    appended; results come back in the grouped (hash-partitioned, locally
    sorted) layout."""

    def __init__(self, df: DataFrame, partition_by, order_by=None):
        self.df = df
        self.partition_by = ir.as_keys(partition_by)
        self.order_by = _over_keys(order_by)

    def _kw(self):
        return dict(partition_by=self.partition_by, order_by=self.order_by or None)

    def cumsum(self, e, out: str = "cumsum") -> DataFrame:
        return cumsum(self.df, e, out, **self._kw())

    def stencil(self, e, weights, *, scale: float = 1.0,
                center: int | None = None, out: str = "stencil") -> DataFrame:
        return stencil(self.df, e, weights, scale=scale, center=center,
                       out=out, **self._kw())

    def sma(self, e, window: int = 3, out: str = "sma") -> DataFrame:
        return sma(self.df, e, window, out, **self._kw())

    def wma(self, e, weights, out: str = "wma") -> DataFrame:
        return wma(self.df, e, weights, out, **self._kw())

    def lag(self, e, n: int = 1, out: str = "lag") -> DataFrame:
        return lag(self.df, e, n, out, **self._kw())

    def lead(self, e, n: int = 1, out: str = "lead") -> DataFrame:
        return lead(self.df, e, n, out, **self._kw())

    def rolling_sum(self, e, window: int, out: str = "rolling_sum") -> DataFrame:
        return rolling_sum(self.df, e, window, out, **self._kw())

    def rolling_mean(self, e, window: int, out: str = "rolling_mean") -> DataFrame:
        return rolling_mean(self.df, e, window, out, **self._kw())

    def rank(self, out: str = "rank") -> DataFrame:
        return rank(self.df, self.partition_by, self.order_by, out)

    def dense_rank(self, out: str = "dense_rank") -> DataFrame:
        return dense_rank(self.df, self.partition_by, self.order_by, out)

    def row_number(self, out: str = "row_number") -> DataFrame:
        return row_number(self.df, self.partition_by, self.order_by, out)


def udf(fn, *args) -> UDF:
    """Lift a jax-traceable elementwise function into an expression."""
    return fn_expr(fn, *args)


def explain(df: DataFrame, cfg: ExecConfig | None = None) -> str:
    return df.explain(cfg)

"""HiFrames user API — fluent, pandas-flavored data frames that compile
with the surrounding array code.

The surface is METHOD-CHAINED (API v2); every relational verb returns a new
lazy DataFrame wrapping a logical plan node:

    import repro.hiframes as hf
    df = hf.table({"id": ids, "x": xs, "y": ys})   # DataSource analogue

    out = (df[df.x > 0.0]                          # filter (df.x == df["x"])
             .merge(dim, on=("id", "cid"))         # equi-join
             .assign(z=df.x * 2.0)                 # derived columns
             .groupby("id")                        # GroupBy proxy
             .agg(total=("z", "sum"),              # pandas named-agg specs
                  n=("z", "count"),
                  ym=hf.mean(df.y))                # ...or AggExpr spellings
             .sort_values("total", ascending=False)
             .head(10)
             .collect())                           # optimize+distribute+jit+run

    df["r"] = df.x / df.y                          # column assignment
    df2 = df.drop(["y"])                           # column removal

Composite (multi-column) keys are supported end-to-end — merge, groupby and
sort accept key tuples, which shuffle on a combined hash, sort
lexicographically and compare position-wise (TPCx-BB-style query shapes):

    l.merge(r, on=[("a", "ca"), ("b", "cb")])      # 2-column equi-join
    df.groupby(("k1", "k2")).agg(s=("x", "sum"))
    df.sort(by=("k1", "k2"))

``on=("id", "cid")`` — a 2-tuple of strings — keeps its historical meaning of
a SINGLE key pair with different names; use a list for composite keys.

**Materialization with a layout contract** — the repeated-query hook:

    hot = df.groupby(("k1", "k2")).agg(s=("x", "sum")).persist()

``persist()`` (alias ``cache()``) executes the plan ONCE and returns a new
DataFrame backed by a Scan that carries the materialized layout — hash/range
partitioning keys, per-shard sort order, global sortedness, per-shard valid
counts.  The device shards re-enter later executions without a host
round-trip, and downstream ``groupby``/``merge``/``over``/``sort`` on the
persisted keys plan ZERO exchanges and ZERO sorts (docs/api.md).  A persisted
dimension table turns every query against it into the elided plan.

Window functions may be PARTITIONED (SQL ``OVER (PARTITION BY ... ORDER BY
...)``) — per-group cumsum/SMA/WMA/lag/lead plus rank/row_number and rolling
sums/means, planned as hash co-location + grouped local sort (both elided
when the input already provides them):

    w = df.over("g", order_by="t")                 # the OVER clause
    d1 = w.cumsum(df.x)                            # per-group running total
    d2 = w.rolling_mean(df.x, 5, exact=True)       # pandas min_periods=1 mode
    d3 = w.rank()                                  # SQL RANK()

Every collected column is a plain jax.Array; any jax array can be attached
with ``with_column``/``assign`` or referenced directly inside expressions
(the paper's "any array in the program" rule).

The pre-v2 free functions (``hf.join(df, ...)``, ``hf.aggregate(df, by,
...)``, ``hf.cumsum(df, ...)``) remain as thin shims delegating to the
fluent surface — existing code keeps working unchanged (migration table in
docs/api.md).
"""
from __future__ import annotations

import dataclasses as _dc
import functools as _ft
from typing import Any, Sequence

import numpy as np

from . import distribution as D
from . import ir
from .dtypes import (CODE_DTYPE, DType, NULL_CODE, as_nullable, categories_of,
                     coerce_column, dict_decode, is_category, is_nullable,
                     physical_dtype, recode_map, union_categories)
from .expr import (AGG_FNS, AggExpr, BinOp, Cast, ColRef, Const, Expr, IsIn,
                   UDF, UnOp, all_, any_, as_expr, count, first, fn_expr,
                   max_, mean, min_, nunique, prod, std, sum_, var)
from .lower import ExecConfig, Lowered, lower
from .table import DTable

__all__ = [
    "DataFrame", "GroupBy", "Over", "table", "from_pandas", "join",
    "aggregate", "concat",
    "cumsum", "stencil", "sma", "wma", "lag", "lead", "rank", "dense_rank",
    "row_number", "rolling_sum", "rolling_mean", "sum_", "mean", "count",
    "min_", "max_", "prod", "any_", "all_", "var", "std", "first", "nunique",
    "udf", "ExecConfig", "explain", "DType",
]


# ---------------------------------------------------------------------------
# string/null expression rewriting (docs/dtypes.md)
#
# Strings never reach the device: comparisons and membership tests against a
# category column are rewritten INTO CODE SPACE when the expression is
# attached to a plan (filter/assign/agg construction).  Dictionaries are
# sorted, so code order IS lexicographic order — equality maps to a code
# constant, ranges map to searchsorted thresholds — and isna() resolves to
# the dtype's in-band null test (code < 0, isnan) or a constant False.
# ---------------------------------------------------------------------------

_CMP_SWAP = {"lt": "gt", "le": "ge", "gt": "lt", "ge": "le",
             "eq": "eq", "ne": "ne"}


def _cat_dtype_of(e: Expr, schemas: dict[int, dict]):
    if isinstance(e, ColRef):
        dt = schemas.get(e.table_id, {}).get(e.name)
        if is_category(dt):
            return dt
    return None


def _code_const(code: int) -> Const:
    return Const(np.int32(code))


def _rewrite_cat_cmp(col: ColRef, dt, op: str, v: str) -> Expr:
    """One string comparison against a sorted dictionary, in code space.
    Nulls (code -1) compare False except under ``ne`` (pandas semantics)."""
    cats = categories_of(dt)
    if op in ("eq", "ne"):
        if v in cats:
            return BinOp(op, col, _code_const(cats.index(v)))
        return Const(op == "ne")            # absent value: eq False, ne True
    arr = np.asarray(cats)
    if op in ("lt", "le"):
        t = int(np.searchsorted(arr, v, side="left" if op == "lt" else "right"))
        if t == 0:
            return Const(False)
        return BinOp("and", BinOp("ge", col, _code_const(0)),
                     BinOp("lt", col, _code_const(t)))
    # gt / ge: codes >= threshold — null (-1) can never satisfy it
    t = int(np.searchsorted(arr, v, side="right" if op == "gt" else "left"))
    return BinOp("ge", col, _code_const(max(t, 0)))


def _rewrite_strings(e: Expr, schemas: dict[int, dict]) -> Expr:
    if e.children:
        kids = tuple(_rewrite_strings(c, schemas) for c in e.children)
        if any(k is not o for k, o in zip(kids, e.children)):
            e = e.with_children(kids)
    if isinstance(e, UnOp) and e.op == "isna":
        c = e.children[0]
        if _cat_dtype_of(c, schemas) is not None:
            return BinOp("lt", c, _code_const(0))
        if isinstance(c, ColRef):
            dt = schemas.get(c.table_id, {}).get(c.name)
            if dt is not None and not is_nullable(dt) and \
                    not np.issubdtype(physical_dtype(dt), np.floating):
                return Const(False)         # int/bool columns hold no nulls
        return e
    if isinstance(e, IsIn):
        dt = _cat_dtype_of(e.children[0], schemas)
        if dt is None or not any(isinstance(v, str) for v in e.values):
            return e
        cats = categories_of(dt)
        lut = {v: i for i, v in enumerate(cats)}
        bad = [v for v in e.values if not isinstance(v, str)]
        if bad:
            raise TypeError(
                f"isin on a category column mixes strings and {bad!r}; "
                "pass homogeneous string values")
        codes = tuple(np.int32(lut[v]) for v in e.values if v in lut)
        return IsIn(e.children[0], codes) if codes else Const(False)
    if isinstance(e, BinOp) and e.op in _CMP_SWAP:
        a, b = e.children
        da, db = _cat_dtype_of(a, schemas), _cat_dtype_of(b, schemas)
        if da is not None and db is not None:
            if categories_of(da) != categories_of(db):
                raise TypeError(
                    "cannot compare category columns with different "
                    "dictionaries; merge/concat unify them, or ingest the "
                    "columns together")
            return e
        if da is None and db is None:
            for x in (a, b):
                if isinstance(x, Const) and isinstance(x.value, str):
                    raise TypeError(
                        f"string constant {x.value!r} compared against a "
                        "non-category column — strings only compare against "
                        "dictionary-encoded (category) columns")
            return e
        col, const, op = (a, b, e.op) if da is not None \
            else (b, a, _CMP_SWAP[e.op])
        dt = da if da is not None else db
        if isinstance(const, Const) and isinstance(
                const.value, (int, np.integer)):
            return e                        # already in code space
        if not isinstance(const, Const) or not isinstance(const.value, str):
            raise TypeError(
                f"category column {col.name!r} compares against string "
                f"constants, got {const!r}")
        return _rewrite_cat_cmp(col, dt, op, const.value)
    return e


# Device-side null/dictionary helpers, lifted into expressions via fn_expr.
# Each is a closure factory so the host constants (LUT, fill code/value) bake
# into the trace as literals.


def _recode_fn(lut: np.ndarray, fill: int | None = None):
    """codes -> codes through a host LUT (dictionary unification); null
    codes stay null unless ``fill`` maps them to a new code (fillna)."""
    fillc = np.int32(NULL_CODE if fill is None else fill)

    def f(c):
        import jax.numpy as jnp
        return jnp.where(c >= 0, jnp.asarray(lut)[jnp.clip(c, 0)], fillc)
    return f


def _fill_code_fn(code: int):
    fillc = np.int32(code)

    def f(c):
        import jax.numpy as jnp
        return jnp.where(c < 0, fillc, c)
    return f


def _fill_nan_fn(v: float):
    def f(c):
        import jax.numpy as jnp
        return jnp.where(jnp.isnan(c), jnp.asarray(v, c.dtype), c)
    return f


def _over_keys(x) -> tuple[str, ...]:
    """Normalize an optional partition/order key spec to a tuple (an absent
    spec — None or an empty sequence — becomes ())."""
    return () if not x else ir.as_keys(x)


class DataFrame:
    """Lazy distributed data frame (wraps a logical plan node).

    ``rep_nodes`` tracks which plan nodes the user pinned to REP via
    :meth:`replicate` — the set survives joins/aggregates so a broadcast
    dimension table stays broadcast inside a larger plan."""

    def __init__(self, node: ir.Node, rep_nodes: frozenset = frozenset()):
        self.node = node
        self._rep_nodes = frozenset(rep_nodes)

    @property
    def _replicated(self) -> bool:
        return self.node.id in self._rep_nodes

    def _wrap(self, node: ir.Node) -> "DataFrame":
        return DataFrame(node, self._rep_nodes)

    def _rw(self, e) -> Expr:
        """Resolve string comparisons / isna against this frame's logical
        schema (applied wherever an expression attaches to the plan)."""
        e = as_expr(e)
        return _rewrite_strings(
            e, {n.id: n.schema for n in ir.topo_order(self.node)})

    # -- schema ---------------------------------------------------------------
    @property
    def schema(self) -> dict[str, np.dtype]:
        return self.node.schema

    @property
    def columns(self) -> list[str]:
        return list(self.node.schema)

    @property
    def dtypes(self) -> dict[str, Any]:
        """Logical dtypes by column (pandas ``df.dtypes`` analogue): plain
        ``np.dtype`` for numeric columns, :class:`DType` for category and
        nullable columns (repr'd ``category[str]``, ``float32?``, ...)."""
        return dict(self.node.schema)

    # -- expression building ---------------------------------------------------
    def __getitem__(self, key):
        if isinstance(key, str):
            return ColRef(self.node.id, key)
        if isinstance(key, Expr):                       # df[pred] -> filter
            return self._wrap(ir.Filter(self.node, self._rw(key)))
        if isinstance(key, (list, tuple)):              # df[["a","b"]] -> project
            cols = {k: ColRef(self.node.id, k) for k in key}
            return self._wrap(ir.Project(self.node, cols))
        raise TypeError(key)

    def __getattr__(self, name: str):
        """Column access as attributes: ``df.x`` is ``df["x"]``.  Methods and
        real attributes win (this hook only fires when normal lookup fails);
        columns shadowed by a method name need the subscript form."""
        try:
            node = object.__getattribute__(self, "node")
        except AttributeError:
            raise AttributeError(name) from None
        if not name.startswith("_") and name in node.schema:
            return ColRef(node.id, name)
        raise AttributeError(
            f"DataFrame has no attribute or column {name!r} "
            f"(columns: {list(node.schema)})")

    def __setitem__(self, name: str, value):
        """In-place column assignment, ``df["c"] = expr`` — the paper's
        ``df[:c] = ...``.  Rebinds this wrapper to a Project over the old
        node; previously built expressions stay valid (columns are resolved
        by name at evaluation)."""
        if not isinstance(name, str):
            raise TypeError(f"column name must be a str, got {name!r}")
        cols = {k: ColRef(self.node.id, k) for k in self.node.schema}
        cols[name] = self._rw(value)
        new = ir.Project(self.node, cols)
        if self.node.id in self._rep_nodes:
            self._rep_nodes = self._rep_nodes | {new.id}
        self.node = new

    def with_column(self, name: str, e) -> "DataFrame":
        """Attach a derived column (non-mutating form of ``df[name] = e``)."""
        return self.assign(**{name: e})

    def assign(self, **exprs) -> "DataFrame":
        """pandas-style ``df.assign(z=df.x * 2, w=lambda d: d.x + d.y)``:
        returns a new frame with the given columns added (or replaced).
        Values may be expressions, scalars, arrays, or callables taking the
        frame."""
        cols = {k: ColRef(self.node.id, k) for k in self.node.schema}
        for name, e in exprs.items():
            if callable(e) and not isinstance(e, Expr):
                e = e(self)
            cols[name] = self._rw(e)
        return self._wrap(ir.Project(self.node, cols))

    def rename(self, mapping: dict[str, str] | None = None, *,
               columns: dict[str, str] | None = None) -> "DataFrame":
        """Rename columns; accepts the mapping positionally or as the
        pandas-style ``columns=`` keyword."""
        mapping = mapping if mapping is not None else (columns or {})
        cols = {mapping.get(k, k): ColRef(self.node.id, k) for k in self.node.schema}
        return self._wrap(ir.Project(self.node, cols))

    def select(self, *names: str) -> "DataFrame":
        return self[list(names)]

    def drop(self, columns, *more: str) -> "DataFrame":
        """Drop columns: ``df.drop("a")``, ``df.drop(["a", "b"])`` or
        ``df.drop(columns=[...])``."""
        dropped = set(ir.as_keys(columns)) | set(more)
        missing = dropped - set(self.node.schema)
        if missing:
            raise KeyError(f"drop: {sorted(missing)} not in columns "
                           f"{list(self.node.schema)}")
        return self[[c for c in self.node.schema if c not in dropped]]

    # -- null / dtype surface (docs/dtypes.md) ---------------------------------
    def astype(self, dtype) -> "DataFrame":
        """Cast columns, pandas-style: ``df.astype(np.float64)`` (all
        columns) or ``df.astype({"x": np.int32})``.  Category columns can't
        be cast on device (decode with ``to_numpy()``), casting TO category
        happens at ingest, and nullable columns must be ``fillna``'d before
        a cast to a dtype with no null representation."""
        sch = self.node.schema
        mapping = dict(dtype) if isinstance(dtype, dict) \
            else {c: dtype for c in sch}
        exprs: dict[str, Expr] = {c: ColRef(self.node.id, c) for c in sch}
        dts = dict(sch)
        for c, t in mapping.items():
            if c not in sch:
                raise KeyError(f"astype: no column {c!r}")
            dt = sch[c]
            wants_cat = (isinstance(t, str) and t == "category") \
                or is_category(t)
            if wants_cat:
                if is_category(dt):
                    continue
                raise TypeError(
                    f"astype: column {c!r} -> category needs host-side "
                    "dictionary encoding; rebuild the input with hf.table() "
                    "or hf.from_pandas()")
            if is_category(dt):
                raise TypeError(
                    f"astype: column {c!r} is category[str]; decode with "
                    "to_numpy() instead of casting on device")
            target = np.dtype(t)
            if dt == target and not is_nullable(dt):
                continue
            if is_nullable(dt) and not np.issubdtype(target, np.floating):
                raise TypeError(
                    f"astype: column {c!r} is nullable ({dt!r}) and "
                    f"{target} has no null representation — fillna() first")
            exprs[c] = Cast(ColRef(self.node.id, c), target)
            dts[c] = (DType(target, nullable=True)
                      if is_nullable(dt) else target)
        return self._wrap(ir.Project(self.node, exprs, dts))

    def fillna(self, value, subset=None) -> "DataFrame":
        """Replace nulls: a scalar (applied to every nullable column, or to
        ``subset``), or a dict column -> fill value.  Filling a category
        column with a string outside its dictionary extends the dictionary.
        The filled columns come back non-nullable."""
        sch = self.node.schema
        if isinstance(value, dict):
            targets = dict(value)
        else:
            cols = ir.as_keys(subset) if subset is not None else tuple(sch)
            targets = {c: value for c in cols}
        exprs: dict[str, Expr] = {c: ColRef(self.node.id, c) for c in sch}
        dts = dict(sch)
        changed = False
        for c, v in targets.items():
            if c not in sch:
                raise KeyError(f"fillna: no column {c!r}")
            dt = sch[c]
            if not is_nullable(dt):
                continue
            col = ColRef(self.node.id, c)
            if is_category(dt):
                if not isinstance(v, str):
                    raise TypeError(
                        f"fillna: column {c!r} is category[str]; the fill "
                        f"value must be a string, got {v!r}")
                cats = categories_of(dt)
                if v in cats:
                    exprs[c] = fn_expr(_fill_code_fn(cats.index(v)), col)
                    dts[c] = DType(CODE_DTYPE, cats)
                else:
                    newcats = union_categories(cats, (v,))
                    lut = recode_map(cats, newcats)
                    exprs[c] = fn_expr(
                        _recode_fn(lut, fill=newcats.index(v)), col)
                    dts[c] = DType(CODE_DTYPE, newcats)
            else:
                exprs[c] = fn_expr(
                    _fill_nan_fn(float(v)), col)
                dts[c] = physical_dtype(dt)
            changed = True
        if not changed:
            return self
        return self._wrap(ir.Project(self.node, exprs, dts))

    def dropna(self, subset=None) -> "DataFrame":
        """Drop rows holding a null in any (or any ``subset``) column —
        a Filter on the in-band null tests, collective-free."""
        cols = ir.as_keys(subset) if subset is not None \
            else tuple(self.node.schema)
        sch = self.node.schema
        missing = set(cols) - set(sch)
        if missing:
            raise KeyError(f"dropna: {sorted(missing)} not in columns "
                           f"{list(sch)}")
        preds = []
        for c in cols:
            dt = sch[c]
            if not is_nullable(dt):
                continue
            col = ColRef(self.node.id, c)
            if is_category(dt):
                preds.append(BinOp("ge", col, _code_const(0)))
            elif np.issubdtype(physical_dtype(dt), np.floating):
                preds.append(UnOp("not", UnOp("isna", col)))
        if not preds:
            return self
        return self._wrap(ir.Filter(
            self.node, _ft.reduce(lambda a, b: BinOp("and", a, b), preds)))

    def isna(self) -> "DataFrame":
        """Per-cell null mask, one bool column per input column."""
        cols = {c: self._rw(UnOp("isna", ColRef(self.node.id, c)))
                for c in self.node.schema}
        return self._wrap(ir.Project(self.node, cols))

    def notna(self) -> "DataFrame":
        cols = {c: UnOp("not", self._rw(UnOp("isna", ColRef(self.node.id, c))))
                for c in self.node.schema}
        return self._wrap(ir.Project(self.node, cols))

    def _recode(self, targets: dict[str, tuple], nullable: dict[str, bool]
                ) -> "DataFrame":
        """Re-encode category columns against new (superset) dictionaries —
        the merge/concat unification step.  Identity for empty targets."""
        if not targets:
            return self
        sch = self.node.schema
        exprs: dict[str, Expr] = {c: ColRef(self.node.id, c) for c in sch}
        dts = dict(sch)
        for c, newcats in targets.items():
            dt = sch[c]
            cats = categories_of(dt)
            nb = nullable.get(c, is_nullable(dt))
            if cats != newcats:
                exprs[c] = fn_expr(_recode_fn(recode_map(cats, newcats)),
                                   ColRef(self.node.id, c))
            dts[c] = DType(CODE_DTYPE, newcats, nullable=nb)
        new = ir.Project(self.node, exprs, dts)
        rep = self._rep_nodes | ({new.id} if self._replicated else set())
        return DataFrame(new, frozenset(rep))

    # -- relational verbs -------------------------------------------------------
    def merge(self, right: "DataFrame", on, how: str = "inner",
              suffix: str = "_r") -> "DataFrame":
        """Equi-join; ``on`` is a name, a (left_name, right_name) pair, or a
        list of names / pairs for composite (multi-column) keys.

        how="left" keeps unmatched left rows; right float columns NaN-fill,
        right category columns null-code-fill, and right int columns
        zero-fill with a ``_matched`` int column distinguishing real zeros
        (docs/dtypes.md).

        String (category) keys join by dictionary code: both sides recode
        onto the union dictionary first, then the join plans exactly like an
        int-key join — same exchanges, same sorts, same packed bytes."""
        lo, ro = _parse_on(on)
        if how not in ("inner", "left"):
            raise ValueError(how)
        left, rgt = self, right
        lsch, rsch = left.node.schema, rgt.node.schema
        ltgt: dict[str, tuple] = {}
        rtgt: dict[str, tuple] = {}
        for lk, rk in zip(lo, ro):
            ldt, rdt = lsch.get(lk), rsch.get(rk)
            if ldt is None or rdt is None:
                continue                    # ir.Join reports the missing key
            if is_category(ldt) != is_category(rdt):
                raise TypeError(
                    f"merge: key {lk!r}/{rk!r} is category[str] on one side "
                    "and numeric on the other — encode both sides the same "
                    "way at ingest")
            if is_category(ldt) and \
                    categories_of(ldt) != categories_of(rdt):
                u = union_categories(categories_of(ldt), categories_of(rdt))
                ltgt[lk] = u
                rtgt[rk] = u
        left = left._recode(ltgt, {})
        rgt = rgt._recode(rtgt, {})
        rep = left._rep_nodes | rgt._rep_nodes
        node = ir.Join(left.node, rgt.node, lo, ro, suffix, how)
        if left._replicated and rgt._replicated:
            rep = rep | {node.id}
        return DataFrame(node, rep)

    def groupby(self, by) -> "GroupBy":
        """Group-by proxy: ``df.groupby("k").agg(total=("x", "sum"))``.
        ``by`` is a column name or a tuple/list of names (composite key)."""
        return GroupBy(self, by)

    def head(self, n: int = 5) -> "DataFrame":
        """First ``n`` rows in global (shard-concatenation) order — no data
        movement, just per-shard count clamps; partitioning and ordering
        survive, so a downstream verb on the same keys stays elided."""
        return self._wrap(ir.Limit(self.node, n))

    def limit(self, n: int) -> "DataFrame":
        """SQL-style alias of :meth:`head`."""
        return self.head(n)

    def sort(self, by, ascending: bool = True) -> "DataFrame":
        """Global sort; ``by`` is a column name or a tuple/list of names
        (lexicographic, most-significant first)."""
        return self._wrap(ir.Sort(self.node, ir.as_keys(by), ascending))

    def sort_values(self, by, ascending: bool = True) -> "DataFrame":
        """pandas-style alias of :meth:`sort`."""
        return self.sort(by, ascending)

    def repartition(self, by) -> "DataFrame":
        """Hash-partition rows across shards by key columns (Spark/Dask
        ``repartition``) — a pure layout verb: same rows, new placement.

        The planner inserts one hash exchange on ``by`` — elided entirely
        when the input is already hash-partitioned on (a superset-compatible
        form of) those keys.  Chained with :meth:`persist`, the materialized
        Scan carries the hash layout, so later ``groupby``/``merge``/``over``
        on the same keys plan zero exchanges."""
        keys = ir.as_keys(by)
        missing = set(keys) - set(self.node.schema)
        if missing:
            raise KeyError(f"repartition: {sorted(missing)} not in columns "
                           f"{list(self.node.schema)}")
        return self._wrap(ir.Repartition(self.node, by=keys))

    def sort_within_partitions(self, by, ascending: bool = True) -> "DataFrame":
        """Sort rows by ``by`` within each shard — no data movement (Spark's
        ``sortWithinPartitions``).  Partitioning is untouched; the per-shard
        order becomes part of the layout :meth:`persist` captures, so a
        persisted frame feeds segment kernels with zero local sorts.

        Only ascending order is supported (the shard-local sort primitive is
        ascending-only, matching ``sort``'s local path)."""
        if not ascending:
            raise ValueError(
                "sort_within_partitions: only ascending=True is supported")
        keys = ir.as_keys(by)
        missing = set(keys) - set(self.node.schema)
        if missing:
            raise KeyError(
                f"sort_within_partitions: {sorted(missing)} not in columns "
                f"{list(self.node.schema)}")
        return self._wrap(ir.Repartition(self.node, sort_by=keys))

    def over(self, partition_by, order_by=None) -> "Over":
        """Partitioned window context (SQL ``OVER (PARTITION BY ... ORDER BY
        ...)``): ``df.over("g", order_by="t").cumsum(df.x)``.  See
        docs/window_functions.md for the plan shapes."""
        return Over(self, partition_by, order_by)

    def replicate(self) -> "DataFrame":
        """Pin this frame to REP (broadcast) — small dimension tables."""
        return DataFrame(self.node,
                         frozenset(n.id for n in ir.topo_order(self.node)))

    # -- execution ---------------------------------------------------------------
    def _force_rep(self) -> set[int]:
        return set(self._rep_nodes)

    def _execute(self, cfg: ExecConfig, keep: Sequence[str] | None = None,
                 ) -> tuple[Lowered, DTable]:
        """Lower + run under the unified retry policy (runtime/retry.py):
        per-op capacity escalation from the overflow attribution vector
        (``cfg.retry_scope="global"`` restores legacy slack-doubling), the
        kernel / packed-exchange / stats degradation ladders, and a
        structured event log carried on the returned DTable (``.events``)
        and in the per-fingerprint store :meth:`explain` renders.
        Shared by :meth:`collect` and :meth:`persist`."""
        from ..runtime import retry as _rt
        policy = _rt.RetryPolicy(max_retries=max(cfg.auto_retry, 0),
                                 scope=getattr(cfg, "retry_scope", "op"))

        def run_once(c):
            lowered, _ = lower(self.node, c, set(keep) if keep else None,
                               force_rep=self._force_rep())
            return lowered, lowered()

        lowered, t, events, cfg = policy.execute(run_once, cfg)
        if events:
            _rt.record_events(lowered.root, events)
        if cfg.adaptive_stats:
            from . import stats as _st
            if not t.overflow:
                # feed realized per-shard counts back into the
                # per-fingerprint stats store: a repeated run of this exact
                # plan sizes PartialAgg from the true group count and lowers
                # the salting threshold if skew materialized.
                _st.record_realized(lowered.root, np.asarray(t.counts))
            else:
                # record the FAILURE's observed requirement so the next
                # adaptive run sizes the site correctly up front.
                for op_id, rec in (t.overflow_ops or {}).items():
                    if rec["kind"] in ("partial_agg", "segment_agg"):
                        _st.record_failure(lowered.pplan.ops[op_id].node,
                                           rec["req_shards"])
        return lowered, t

    def collect(self, cfg: ExecConfig | None = None,
                keep: Sequence[str] | None = None) -> DTable:
        """Execute the plan and return the materialized DTable."""
        return self._execute(cfg or ExecConfig(), keep)[1]

    def persist(self, cfg: ExecConfig | None = None, *,
                name: str = "persist") -> "DataFrame":
        """Execute ONCE and return a new DataFrame over the materialized
        result, carrying the layout the plan produced.

        The returned frame's Scan records the root op's partitioning
        (hash/range keys, direction, global sortedness) and per-shard
        ordering plus the 1D_VAR carrier (per-shard counts + capacity), so:

          * its device shards re-enter later executions directly — no host
            gather, no re-pad;
          * downstream ``groupby``/``merge``/``over``/``sort`` on the
            persisted keys plan zero exchanges and zero sorts (the plan
            census pins this, tests/test_api_v2.py).

        Hash/range claims are shard-count-bound: re-executing under a
        different device count falls back to a host gather and a plain
        block scan (correct, just not elided).  Replicated results re-enter
        as host tables pinned REP — a persisted dimension table keeps
        broadcasting.
        """
        cfg = cfg or ExecConfig()
        lowered, t = self._execute(cfg)
        if t.overflow:
            # collect() returns the flagged table for the caller to inspect;
            # baking truncated shards into a reusable frame would silently
            # drop rows from every later query.  The typed error names the
            # offending plan op and the cap that would have sufficed.
            from .errors import CapacityOverflow
            attempts = max(cfg.auto_retry, 0) + 1
            ops = t.overflow_ops or {}
            if ops:
                op_id, rec = max(ops.items(),
                                 key=lambda kv: kv[1]["cap_req"])
                raise CapacityOverflow(
                    op_id=op_id, op=rec["op"],
                    observed_est=rec["cap_req"], cap=rec["cap"],
                    attempts=attempts,
                    message=(
                        "persist(): capacity overflow survived the "
                        f"auto-retries at op #{op_id} ({rec['op']}): observed "
                        f"requirement ~{rec['cap_req']} rows > planned cap "
                        f"{rec['cap']} — raise ExecConfig.auto_retry or "
                        "pre-size via ExecConfig.cap_overrides"
                        f"[{op_id}] = ({rec['cap_req']}, "
                        f"{rec['bucket_req']})"))
            raise CapacityOverflow(
                attempts=attempts,
                message=(
                    "persist(): capacity overflow survived the auto-retries "
                    "— raise ExecConfig.shuffle_slack/join_expansion/"
                    "auto_retry"))
        root_op = lowered.pplan.root_op
        layout = ir.ScanLayout(
            kind=root_op.part.kind, partitioned_by=root_op.part.keys,
            ascending=root_op.part.ascending,
            globally_sorted=root_op.part.globally_sorted,
            sorted_by=root_op.order.keys,
            order_ascending=root_op.order.ascending,
            counts=np.asarray(t.counts, dtype=np.int32),
            capacity=int(t.capacity), nshards=int(t.nshards), dist=t.dist)
        if t.dist == D.REP:
            # replicated results are tiny by construction: re-enter as a
            # plain host table pinned REP, keeping the ordering contract.
            scan = ir.Scan(name, t.to_numpy(),
                           layout=_dc.replace(layout, kind="rep",
                                              counts=None))
            return DataFrame(scan, frozenset({scan.id}))
        scan = ir.Scan(name, dict(t.columns), layout=layout)
        return DataFrame(scan)

    def cache(self, cfg: ExecConfig | None = None, *,
              name: str = "cache") -> "DataFrame":
        """Alias of :meth:`persist` (Spark spelling)."""
        return self.persist(cfg, name=name)

    def lower(self, cfg: ExecConfig | None = None, keep: Sequence[str] | None = None,
              collect_block: bool = False) -> Lowered:
        lowered, _ = lower(self.node, cfg, set(keep) if keep else None,
                           collect_block=collect_block,
                           force_rep=self._force_rep())
        return lowered

    def to_numpy(self, cfg: ExecConfig | None = None, *,
                 decode: bool = True) -> dict[str, np.ndarray]:
        """Collect to host numpy.  Category columns decode back to string
        object arrays (``None`` for nulls); ``decode=False`` keeps the raw
        int32 dictionary codes."""
        out = self.collect(cfg).to_numpy()
        if decode:
            for c, dt in self.node.schema.items():
                if is_category(dt) and c in out:
                    out[c] = dict_decode(out[c], categories_of(dt))
        return out

    def collect_matrix(self, cols: Sequence[str], cfg: ExecConfig | None = None):
        """Matrix assembly (the paper's transpose(typed_hcat) pattern): returns
        a row-sharded (rows, k) float32 matrix + row count, rebalanced to
        1D_BLOCK as ML algorithms require."""
        import jax.numpy as jnp
        lowered, _ = lower(self.node, cfg, set(cols), collect_block=True,
                           force_rep=self._force_rep())
        t = lowered()
        mat = jnp.stack([t.columns[c].astype(jnp.float32) for c in cols], axis=1)
        return mat, t.counts, t.capacity

    def _plan(self, cfg: ExecConfig):
        """Shared planning prologue (optimize -> infer -> rebalance ->
        physical plan) for explain()/physical_plan().  Mirrors lower()'s
        sequence under the same config; a plain collect() executes this
        plan (collect(keep=...) / collect_matrix() additionally prune
        columns or append a root rebalance, which introspection omits)."""
        from . import optimizer as opt
        from . import physical_plan as pp
        from . import stats as st
        root = self.node
        if cfg.optimize_plan:
            root, _ = opt.optimize(root)
        info = D.infer(root, force_rep=self._force_rep(),
                       broadcast_join=cfg.broadcast_join)
        root = D.insert_rebalance(root, info)
        # Introspection always carries a stats context so explain() can
        # annotate estimated rows/bytes per exchange; it only changes
        # DECISIONS (salting, cheap side, auto caps) under
        # cfg.adaptive_stats — plans stay byte-identical with adaptive off.
        sctx = st.analyze(root, cfg)
        return root, info, pp.plan_physical(root, info.dists, cfg, stats=sctx)

    def physical_plan(self, cfg: ExecConfig | None = None):
        """The property-driven physical plan (core/physical_plan.py) this
        frame would execute: op list with partitioning/ordering annotations,
        plus ``counts()`` / ``shuffle_count()`` for introspection — the hook
        the exchange-elision tests and benchmarks use."""
        _root, _info, pplan = self._plan(cfg or ExecConfig())
        return pplan

    def explain(self, cfg: ExecConfig | None = None) -> str:
        """Logical plan with distribution annotations, followed by the
        physical plan: one line per operator with its provided partitioning
        and ordering, exchange/sort insertions made explicit, and a leading
        shuffle/sort census.  Exchanges carry estimated rows/bytes from the
        sampled statistics pass, and a trailing line compares the root's
        estimate against REALIZED counts when a previous adaptive run of
        this exact plan fingerprint recorded them."""
        from . import stats as st
        root, info, pplan = self._plan(cfg or ExecConfig())
        sch = ", ".join(f"{k}:{dt}" for k, dt in self.node.schema.items())
        txt = (ir.plan_str(root, info.dists) + "\nschema: " + sch
               + "\n\n" + pplan.render())
        est = pplan.root_op.rows_est
        tail = []
        if est is not None:
            tail.append(f"estimated output rows ~{int(est)}")
        rl = st.realized_for(root)
        if rl is not None:
            tail.append(
                f"realized (previous run): {rl['rows']} rows over "
                f"{rl['nshards']} shards, per-shard max/mean "
                f"{rl['max']}/{rl['mean']:.1f}")
        if tail:
            txt += "\nstats: " + "; ".join(tail)
        from ..runtime import retry as _rt
        evs = _rt.events_for(root)
        if evs:
            txt += "\nevents (previous run):\n" + "\n".join(
                "  " + e.render() for e in evs)
        return txt

    def __repr__(self):
        return f"DataFrame({list(self.node.schema)})\n{ir.plan_str(self.node)}"


# pandas-spelled aliases for the named-agg table (everything else matches).
_AGG_ALIASES = {"product": "prod", "size": "count", "average": "mean"}


class GroupBy:
    """Deferred group-by: ``df.groupby(keys)`` then :meth:`agg` (or a
    whole-frame sugar method).  Aggregation specs accept three spellings:

      * pandas named-agg tuples: ``agg(total=("x", "sum"))`` — the column
        may also be an expression: ``agg(hits=(df.x > 0, "sum"))``;
      * AggExpr objects: ``agg(total=hf.sum_(df.x))``;
      * row count: ``agg(n="count")`` (or the :meth:`size` sugar).

    Available fns: sum, mean, count, min, max, prod, any, all, var, std,
    first, nunique (``product``/``size``/``average`` alias the obvious
    ones).  Output rows come back hash-partitioned on the keys and sorted by
    them within each shard — the layout a following :meth:`DataFrame.persist`
    captures."""

    def __init__(self, df: DataFrame, by, select: tuple[str, ...] | None = None):
        self.df = df
        self.keys = ir.as_keys(by)
        self._select = select
        missing = set(self.keys) - set(df.node.schema)
        if missing:
            raise KeyError(f"groupby: {sorted(missing)} not in columns "
                           f"{list(df.node.schema)}")

    def __getitem__(self, cols) -> "GroupBy":
        """Column selection on the proxy — ``df.groupby("k")["x"].sum()``
        (pandas SeriesGroupBy/DataFrameGroupBy spelling).  Accepts a name or
        a list/tuple of names; the whole-frame sugar methods (:meth:`sum`,
        :meth:`mean`, ...) then aggregate only the selected columns."""
        sel = (cols,) if isinstance(cols, str) else tuple(cols)
        if not sel:
            raise ValueError("groupby[...]: empty column selection")
        bad = [c for c in sel if not isinstance(c, str)]
        if bad:
            raise TypeError(f"groupby[...]: column names must be str, "
                            f"got {bad!r}")
        missing = set(sel) - set(self.df.node.schema)
        if missing:
            raise KeyError(f"groupby[...]: {sorted(missing)} not in columns "
                           f"{list(self.df.node.schema)}")
        return GroupBy(self.df, self.keys, select=sel)

    # fns with no meaning on dictionary codes (a code sum is garbage);
    # min/max/first/count/nunique stay valid — code order is lexicographic.
    _NUMERIC_ONLY = ("sum", "mean", "var", "std", "prod", "any", "all")

    def _check_cat(self, name: str, fn: str, e) -> None:
        if fn not in self._NUMERIC_ONLY or not isinstance(e, ColRef):
            return
        dt = self.df.node.schema.get(e.name)
        if is_category(dt):
            raise TypeError(
                f"agg {name}: {fn!r} over category[str] column {e.name!r} "
                "has no meaning (dictionary codes aren't numbers); use "
                "min/max/first/count/nunique, or fillna+astype first")

    def _spec(self, name: str, a) -> AggExpr:
        if isinstance(a, AggExpr):
            self._check_cat(name, a.fn, a.expr)
            e = self.df._rw(a.expr) if a.expr is not None else None
            return AggExpr(a.fn, e, a.skipna)
        if isinstance(a, str):
            fn = _AGG_ALIASES.get(a, a)
            if fn == "count":
                return AggExpr("count", None)
            raise TypeError(
                f"agg {name}={a!r}: bare strings only spell 'count'/'size'; "
                f"use a (column, fn) tuple")
        if isinstance(a, tuple) and len(a) == 2:
            col, fn = a
            fn = _AGG_ALIASES.get(fn, fn)
            if not isinstance(fn, str) or fn not in AGG_FNS:
                raise TypeError(f"agg {name}: unknown fn {fn!r}; "
                                f"valid: {AGG_FNS} (+ aliases "
                                f"{tuple(_AGG_ALIASES)})")
            if isinstance(col, str) and col not in self.df.node.schema:
                raise KeyError(f"agg {name}: no column {col!r}")
            if fn == "count":
                # ("x", "count") counts non-null x when x is nullable
                # (pandas count); otherwise it degenerates to the row count
                # and keeps the expr-free form (no prep column on the wire).
                if isinstance(col, str) and \
                        is_nullable(self.df.node.schema.get(col)):
                    return AggExpr("count", ColRef(self.df.node.id, col))
                return AggExpr("count", None)
            e = col if isinstance(col, Expr) else ColRef(self.df.node.id, col)
            self._check_cat(name, fn, e)
            return AggExpr(fn, self.df._rw(e))
        raise TypeError(f"agg {name}: expected (column, fn), an AggExpr or "
                        f"'count', got {a!r}")

    def agg(self, **aggs) -> DataFrame:
        if not aggs:
            raise ValueError("agg() needs at least one name=(column, fn) spec")
        specs = {name: self._spec(name, a) for name, a in aggs.items()}
        # pandas groupby(dropna=True) default: null keys form no group.
        # Columns resolve by name at evaluation, so specs built against the
        # pre-drop node stay valid over the filtered child.
        sch = self.df.node.schema
        base = self.df
        if any(is_nullable(sch[k]) for k in self.keys):
            base = self.df.dropna(subset=self.keys)
        node = ir.Aggregate(base.node, self.keys, specs)
        rep = base._rep_nodes | ({node.id} if base._replicated else set())
        return DataFrame(node, frozenset(rep))

    aggregate = agg

    def size(self, name: str = "size") -> DataFrame:
        """Row count per group (pandas ``.size()``)."""
        return self.agg(**{name: AggExpr("count", None)})

    def _apply_all(self, fn: str, skipna: bool = True) -> DataFrame:
        if self._select is not None:
            cols = [c for c in self._select if c not in self.keys]
        else:
            cols = [c for c in self.df.node.schema if c not in self.keys]
        if fn in self._NUMERIC_ONLY:
            # pandas numeric_only: whole-frame sugar skips category columns
            # (an explicit agg spec on one raises instead).
            sch = self.df.node.schema
            cols = [c for c in cols if not is_category(sch[c])]
        if not cols:
            return self.size(name="count")
        return self.agg(**{c: AggExpr(fn, ColRef(self.df.node.id, c),
                                      skipna=skipna)
                           for c in cols})

    def transform(self, fn: str | None = None, **aggs) -> DataFrame:
        """Broadcast per-group aggregates back onto the rows (pandas
        ``groupby().transform``): aggregate, then join the result back on
        the group keys — every original row and column survives, with the
        group statistic alongside.

        Two spellings: ``transform("mean")`` applies the fn to every
        (selected) non-key column as ``<col>_<fn>``;
        ``transform(z=("x", "sum"))`` names outputs like :meth:`agg`.

        The broadcast join shares the groupby's keys, so under
        ``adaptive_stats`` a hot group rides the salted-join path and the
        tiny aggregated side replicates instead of pinning one shard.
        """
        if fn is not None:
            if aggs:
                raise TypeError(
                    "transform: pass a single fn OR name=(column, fn) "
                    "specs, not both")
            f = _AGG_ALIASES.get(fn, fn)
            if f not in AGG_FNS:
                raise TypeError(f"transform: unknown fn {fn!r}; valid: "
                                f"{AGG_FNS} (+ aliases {tuple(_AGG_ALIASES)})")
            if self._select is not None:
                cols = [c for c in self._select if c not in self.keys]
            else:
                cols = [c for c in self.df.node.schema if c not in self.keys]
            if not cols:
                raise ValueError("transform: no value columns to aggregate")
            aggs = {f"{c}_{f}": (c, f) for c in cols}
        if not aggs:
            raise ValueError(
                "transform() needs a fn or at least one name=(column, fn)")
        clash = sorted(set(aggs) & set(self.df.node.schema))
        if clash:
            raise ValueError(f"transform: output names {clash} collide "
                             f"with existing columns")
        return self.df.merge(self.agg(**aggs), on=list(self.keys))

    def head(self, n: int = 5) -> DataFrame:
        """First ``n`` rows per group, pandas ``groupby().head``: fused as
        ``row_number() <= n`` riding the grouped-sort layout the segment
        machinery already establishes — ONE hash exchange total (elided
        entirely on a frame persisted on the keys), and the filter itself
        is collective-free.  Row selection matches pandas exactly: the
        block exchange and stable local sort preserve each group's original
        arrival order."""
        if n < 0:
            raise ValueError(f"head: n must be >= 0, got {n}")
        w = row_number(self.df, list(self.keys), None, out="__rn__")
        return w[w["__rn__"] <= n].drop("__rn__")

    def sum(self, skipna: bool = True) -> DataFrame:
        return self._apply_all("sum", skipna)

    def mean(self, skipna: bool = True) -> DataFrame:
        return self._apply_all("mean", skipna)

    def min(self, skipna: bool = True) -> DataFrame:
        return self._apply_all("min", skipna)

    def max(self, skipna: bool = True) -> DataFrame:
        return self._apply_all("max", skipna)

    def prod(self, skipna: bool = True) -> DataFrame:
        return self._apply_all("prod", skipna)

    def any(self, skipna: bool = True) -> DataFrame:
        return self._apply_all("any", skipna)

    def all(self, skipna: bool = True) -> DataFrame:
        return self._apply_all("all", skipna)

    def count(self) -> DataFrame:
        return self._apply_all("count")

    def nunique(self) -> DataFrame:
        return self._apply_all("nunique")


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------


def table(columns: dict[str, Any], name: str = "t") -> DataFrame:
    """Create a data frame from host/device arrays (DataSource analogue).

    Host columns go through ingest coercion (docs/dtypes.md): string /
    object-of-string arrays (``None``/``NaN`` holes allowed) are
    dictionary-encoded into int32 codes with a ``category[str]`` dtype;
    float columns holding NaN and object columns of numbers with ``None``
    holes become nullable; datetime/complex/structured inputs raise an
    actionable error.  Device (jax) arrays pass through untouched — they are
    assumed clean, numeric, and possibly mid-computation."""
    lens = {k: len(v) for k, v in columns.items()}
    if len(set(lens.values())) > 1:
        raise ValueError(f"column length mismatch: {lens}")
    import jax
    cols: dict[str, Any] = {}
    sch: dict[str, Any] = {}
    for k, v in columns.items():
        if isinstance(v, jax.Array):
            cols[k] = v
            sch[k] = np.dtype(v.dtype)
            continue
        cols[k], sch[k] = coerce_column(k, v)
    return DataFrame(ir.Scan(name, cols, sch))


def from_pandas(df, name: str = "t") -> DataFrame:
    """Build a frame from a pandas DataFrame (duck-typed, no pandas import):
    columns feed :func:`table`'s ingest coercion, so object/string columns
    dictionary-encode and ``NaN``/``None``/``pd.NA`` holes become nulls."""
    if not hasattr(df, "columns") or not hasattr(df, "__getitem__"):
        raise TypeError(
            f"from_pandas expects a pandas DataFrame, got {type(df).__name__}")
    cols = {}
    for c in df.columns:
        s = df[c]
        cols[str(c)] = s.to_numpy() if hasattr(s, "to_numpy") else np.asarray(s)
    return table(cols, name)


def _parse_on(on) -> tuple[tuple[str, ...], tuple[str, ...]]:
    """Normalize the join key spec to (left_keys, right_keys) tuples.

    Accepted forms:
      "k"                       one key, same name both sides
      ("lk", "rk")              one key pair (historical form — a 2-tuple of
                                strings is a PAIR, not two key columns)
      ["k1", "k2", ...]         composite key, same names both sides
      [("a","ca"), "b", ...]    composite key, per-position pair or shared name
    """
    if isinstance(on, str):
        return (on,), (on,)
    # only a literal 2-TUPLE of strings is the historical pair form; a LIST
    # of two names (["k1","k2"]) is a composite key on shared names.
    if isinstance(on, tuple) and len(on) == 2 \
            and all(isinstance(x, str) for x in on):
        return (on[0],), (on[1],)
    lo, ro = [], []
    for item in on:
        if isinstance(item, str):
            lo.append(item)
            ro.append(item)
        else:
            l, r = item
            lo.append(l)
            ro.append(r)
    if not lo:
        raise ValueError("join requires at least one key column")
    return tuple(lo), tuple(ro)


# ---------------------------------------------------------------------------
# free-function shims (pre-v2 spellings; thin delegations to the fluent API)
# ---------------------------------------------------------------------------


def join(left: DataFrame, right: DataFrame, on, suffix: str = "_r",
         how: str = "inner") -> DataFrame:
    """Shim for :meth:`DataFrame.merge` (the historical spelling)."""
    return left.merge(right, on, how=how, suffix=suffix)


def aggregate(df: DataFrame, by, **aggs) -> DataFrame:
    """Shim for ``df.groupby(by).agg(...)``; ``by`` is a column name or a
    tuple/list of names (composite key).  Accepts the same specs as
    :meth:`GroupBy.agg` (AggExpr objects or pandas named-agg tuples); any
    number of ``nunique`` aggregations may be mixed in."""
    return df.groupby(by).agg(**aggs)


def concat(*dfs: DataFrame) -> DataFrame:
    """UNION ALL.  Column names must match; logical dtypes unify — category
    columns recode onto the union dictionary, and a column nullable in any
    part comes out nullable (ir.Concat reports part 0's schema, so the
    unified dtypes ride a Project override when parts disagree)."""
    schemas = [tuple(d.node.schema) for d in dfs]
    if len(set(schemas)) > 1:
        raise ValueError(f"schema mismatch in concat: {schemas}")
    parts = list(dfs)
    targets: list[dict[str, tuple]] = [{} for _ in parts]
    nullflags: list[dict[str, bool]] = [{} for _ in parts]
    over: dict[str, Any] = {}
    for c in schemas[0]:
        dts = [d.node.schema[c] for d in parts]
        flags = [is_category(dt) for dt in dts]
        if any(flags):
            if not all(flags):
                raise TypeError(
                    f"concat: column {c!r} is category[str] in some parts "
                    "and numeric in others — encode every part the same way")
            u = categories_of(dts[0])
            for dt in dts[1:]:
                u = union_categories(u, categories_of(dt))
            nb = any(is_nullable(dt) for dt in dts)
            for i, dt in enumerate(dts):
                if categories_of(dt) != u or is_nullable(dt) != nb:
                    targets[i][c] = u
                    nullflags[i][c] = nb
            over[c] = DType(CODE_DTYPE, u, nullable=nb)
        elif any(is_nullable(dt) for dt in dts) \
                and not is_nullable(dts[0]):
            over[c] = as_nullable(dts[0])
    parts = [d._recode(t, nf)
             for d, t, nf in zip(parts, targets, nullflags)]
    node = ir.Concat(tuple(d.node for d in parts))
    rep = frozenset().union(*(d._rep_nodes for d in parts))
    if all(d._replicated for d in parts):
        rep = rep | {node.id}
    if over:
        sch = node.schema
        proj = ir.Project(node, {c: ColRef(node.id, c) for c in sch},
                          {c: over.get(c, sch[c]) for c in sch})
        if node.id in rep:
            rep = rep | {proj.id}
        node = proj
    return DataFrame(node, frozenset(rep))


def cumsum(df: DataFrame, e, out: str = "cumsum", *,
           partition_by=None, order_by=None) -> DataFrame:
    """Distributed cumulative sum (MPI_Exscan analogue).

    With ``partition_by``, the sum restarts at every group boundary
    (``SUM(...) OVER (PARTITION BY ... ORDER BY ...)``) and rows come back
    hash-partitioned on the group keys, sorted by (partition, order) keys
    within each shard — the grouped layout, not input order."""
    return DataFrame(ir.Window(df.node, "cumsum", df._rw(e), out,
                               partition_by=_over_keys(partition_by),
                               order_by=_over_keys(order_by)),
                     df._rep_nodes)


def stencil(df: DataFrame, e, weights: Sequence[float], *, scale: float = 1.0,
            center: int | None = None, out: str = "stencil",
            partition_by=None, order_by=None, exact: bool = False) -> DataFrame:
    """1-D stencil: out[i] = sum_j w[j]/scale * x[i+j-center].

    SMA == stencil(x, [1,1,1], scale=3); WMA == stencil(x, [1,2,1], scale=4).
    With ``partition_by``, taps never cross a group boundary (the zero-border
    convention applies per group) — TPCx-BB Q26-style grouped moving
    averages.  ``exact=True`` renormalizes border windows by the weight mass
    of the taps that actually contributed (see :func:`rolling_mean`)."""
    w = tuple(float(x) / scale for x in weights)
    c = len(w) // 2 if center is None else center
    return DataFrame(ir.Window(df.node, "stencil", df._rw(e), out,
                               weights=w, center=c, exact=exact,
                               partition_by=_over_keys(partition_by),
                               order_by=_over_keys(order_by)),
                     df._rep_nodes)


def sma(df: DataFrame, e, window: int = 3, out: str = "sma", *,
        partition_by=None, order_by=None) -> DataFrame:
    return stencil(df, e, [1.0] * window, scale=float(window), out=out,
                   partition_by=partition_by, order_by=order_by)


def wma(df: DataFrame, e, weights: Sequence[float], out: str = "wma", *,
        partition_by=None, order_by=None) -> DataFrame:
    return stencil(df, e, weights, scale=float(sum(weights)), out=out,
                   partition_by=partition_by, order_by=order_by)


def lag(df: DataFrame, e, n: int = 1, out: str = "lag", *,
        partition_by=None, order_by=None) -> DataFrame:
    """SQL lag(): out[i] = x[i-n] across the distributed order (paper Table 1
    mentions SQL's lag/lead as the window-function alternative to stencils —
    here they ARE stencils: a one-hot window with offset).  Borders -> 0;
    with ``partition_by`` the border is the group edge."""
    return stencil(df, e, [1.0] + [0.0] * n, center=n, out=out,
                   partition_by=partition_by, order_by=order_by)


def lead(df: DataFrame, e, n: int = 1, out: str = "lead", *,
         partition_by=None, order_by=None) -> DataFrame:
    """SQL lead(): out[i] = x[i+n]; borders -> 0 (group edges when
    partitioned)."""
    return stencil(df, e, [0.0] * n + [1.0], center=0, out=out,
                   partition_by=partition_by, order_by=order_by)


def rolling_sum(df: DataFrame, e, window: int, out: str = "rolling_sum", *,
                partition_by=None, order_by=None) -> DataFrame:
    """Trailing rolling sum: out[i] = sum of x over rows [i-window+1 .. i].

    A one-sided stencil (center = window-1), so leading borders — the global
    start, or each group start when partitioned — contribute zeros."""
    return stencil(df, e, [1.0] * window, center=window - 1, out=out,
                   partition_by=partition_by, order_by=order_by)


def rolling_mean(df: DataFrame, e, window: int, out: str = "rolling_mean", *,
                 partition_by=None, order_by=None,
                 exact: bool = False) -> DataFrame:
    """Trailing rolling mean over rows [i-window+1 .. i].

    Default (``exact=False``, the zero-padded fast path): the first
    window-1 rows of the series — or of each group when partitioned —
    divide a zero-padded partial sum by the FULL window, per the stencil
    border convention.  ``exact=True`` divides by the number of rows that
    actually contributed instead (pandas ``rolling(window,
    min_periods=1).mean()``); it costs a second pass over the window mask —
    and, for the global form, a second halo exchange — which is why the
    padded form stays the default."""
    return stencil(df, e, [1.0] * window, scale=float(window),
                   center=window - 1, out=out, exact=exact,
                   partition_by=partition_by, order_by=order_by)


def _rank_df(df: DataFrame, kind: str, partition_by, order_by,
             out: str, ascending: bool = True) -> DataFrame:
    pk, ok = _over_keys(partition_by), _over_keys(order_by)
    node = df.node
    if not pk and ok:
        # GLOBAL window (no PARTITION BY): equal order-key tuples must be
        # adjacent across the shard-concatenated stream, so sort first.
        # The planner makes an already-globally-sorted input (leaderboard:
        # ``sort_values(...).persist()`` then rank) a FULL no-op — the rank
        # itself is a per-shard-count exscan, never a second global sort.
        node = ir.Sort(node, ok, ascending)
    return DataFrame(ir.Window(node, kind, None, out,
                               partition_by=pk, order_by=ok),
                     df._rep_nodes)


def rank(df: DataFrame, partition_by, order_by, out: str = "rank", *,
         ascending: bool = True) -> DataFrame:
    """SQL RANK() OVER ([PARTITION BY ...] ORDER BY ...): 1-based; equal
    order-key tuples share a rank, with gaps after ties.

    ``partition_by=None`` ranks GLOBALLY over ``order_by`` (``ascending``
    picks the direction, SQL ``ORDER BY ... DESC``): the engine sorts first
    — elided entirely when the input is already globally sorted that way —
    and computes ranks with a per-shard-count exscan plus boundary-run
    reconciliation (no second global pass)."""
    return _rank_df(df, "rank", partition_by, order_by, out, ascending)


def dense_rank(df: DataFrame, partition_by, order_by,
               out: str = "dense_rank", *,
               ascending: bool = True) -> DataFrame:
    """SQL DENSE_RANK(): ties share a rank, no gaps.  ``partition_by=None``
    ranks globally (see :func:`rank`)."""
    return _rank_df(df, "dense_rank", partition_by, order_by, out, ascending)


def row_number(df: DataFrame, partition_by, order_by=None,
               out: str = "row_number", *,
               ascending: bool = True) -> DataFrame:
    """SQL ROW_NUMBER(): 1-based position within the group (ties broken by
    the stable sort, so equal order keys number deterministically by
    post-exchange arrival order).

    ``partition_by=None`` numbers rows GLOBALLY: with ``order_by`` the
    stream is sorted first (no-op when already sorted), without it rows
    number in shard-concatenation arrival order — either way the numbers
    come from an exclusive scan of the per-shard counts, zero shuffles."""
    return _rank_df(df, "row_number", partition_by, order_by, out, ascending)


class Over:
    """Fluent handle for partitioned windows: ``df.over(partition_by=...,
    order_by=...)`` then any window verb — the SQL ``OVER`` clause as an
    object.  Each method returns a new DataFrame with the window column
    appended; results come back in the grouped (hash-partitioned, locally
    sorted) layout — which :meth:`DataFrame.persist` captures, so repeated
    windows over the same keys plan zero exchanges."""

    def __init__(self, df: DataFrame, partition_by, order_by=None):
        self.df = df
        self.partition_by = ir.as_keys(partition_by)
        self.order_by = _over_keys(order_by)

    def _kw(self):
        return dict(partition_by=self.partition_by, order_by=self.order_by or None)

    def cumsum(self, e, out: str = "cumsum") -> DataFrame:
        return cumsum(self.df, e, out, **self._kw())

    def stencil(self, e, weights, *, scale: float = 1.0,
                center: int | None = None, out: str = "stencil",
                exact: bool = False) -> DataFrame:
        return stencil(self.df, e, weights, scale=scale, center=center,
                       out=out, exact=exact, **self._kw())

    def sma(self, e, window: int = 3, out: str = "sma") -> DataFrame:
        return sma(self.df, e, window, out, **self._kw())

    def wma(self, e, weights, out: str = "wma") -> DataFrame:
        return wma(self.df, e, weights, out, **self._kw())

    def lag(self, e, n: int = 1, out: str = "lag") -> DataFrame:
        return lag(self.df, e, n, out, **self._kw())

    def lead(self, e, n: int = 1, out: str = "lead") -> DataFrame:
        return lead(self.df, e, n, out, **self._kw())

    def rolling_sum(self, e, window: int, out: str = "rolling_sum") -> DataFrame:
        return rolling_sum(self.df, e, window, out, **self._kw())

    def rolling_mean(self, e, window: int, out: str = "rolling_mean", *,
                     exact: bool = False) -> DataFrame:
        return rolling_mean(self.df, e, window, out, exact=exact, **self._kw())

    def rank(self, out: str = "rank") -> DataFrame:
        return rank(self.df, self.partition_by, self.order_by, out)

    def dense_rank(self, out: str = "dense_rank") -> DataFrame:
        return dense_rank(self.df, self.partition_by, self.order_by, out)

    def row_number(self, out: str = "row_number") -> DataFrame:
        return row_number(self.df, self.partition_by, self.order_by, out)


def udf(fn, *args) -> UDF:
    """Lift a jax-traceable elementwise function into an expression."""
    return fn_expr(fn, *args)


def explain(df: DataFrame, cfg: ExecConfig | None = None) -> str:
    return df.explain(cfg)

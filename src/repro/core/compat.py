"""Version compatibility shims for the jax API surface we depend on.

The codebase targets the modern ``jax.shard_map`` entry point (jax >= 0.6);
older releases (0.4.x) only expose ``jax.experimental.shard_map.shard_map``
and spell the replication-check flag ``check_rep`` instead of ``check_vma``.
Everything that builds an SPMD region goes through :func:`shard_map` so the
rest of the code can use one spelling.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` on new jax; the experimental fallback on 0.4.x."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)

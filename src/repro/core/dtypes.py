"""Logical dtypes: dictionary-encoded categories + validity/null model.

Schemas stay ``dict[str, dtype-like]``: plain columns carry a raw
``np.dtype``; category and nullable columns carry a :class:`DType` wrapper
that resolves to its physical dtype under ``np.dtype(...)`` — so packing,
byte censuses, sentinels and capacity planning never see the difference.
Encoding happens host-side at ingest (``hf.table`` / ``hf.from_pandas``);
on device a string column is int32 codes, one packed-exchange word, which is
why string-key plans are byte-identical to int-key ones (docs/dtypes.md).
"""
from __future__ import annotations

from typing import Any

import numpy as np

# ---------------------------------------------------------------------------
# logical dtypes (docs/dtypes.md)
# ---------------------------------------------------------------------------

#: dictionary code reserved for null — matches pandas.Categorical.codes.
NULL_CODE = -1

#: physical storage of dictionary codes; one packed-exchange word, same as an
#: int key, which is what makes string-key plans byte-identical to int-key.
CODE_DTYPE = np.dtype(np.int32)


class DType:
    """Logical column dtype: a physical ``np.dtype`` plus optional dictionary
    (categorical) and nullability metadata.

    Every physical layer keeps seeing a plain numpy dtype: ``np.dtype(DType)``
    resolves to ``physical`` (numpy reads the ``.dtype`` attribute), so
    packing, byte censuses, sentinels and capacity planning need no changes.
    A non-category ``DType`` compares equal to its physical dtype, so
    nullability never breaks a plain ``schema[c] == np.float32`` check;
    category dtypes only compare equal to category dtypes with the same
    dictionary.
    """

    __slots__ = ("physical", "categories", "nullable")

    def __init__(self, physical, categories: tuple | None = None,
                 nullable: bool = False):
        self.physical = np.dtype(physical)
        self.categories = tuple(categories) if categories is not None else None
        self.nullable = bool(nullable)
        if self.categories is not None and self.physical != CODE_DTYPE:
            raise ValueError("category columns are int32-coded")

    @property
    def dtype(self) -> np.dtype:        # np.dtype(DType) -> physical
        return self.physical

    @property
    def itemsize(self) -> int:
        return self.physical.itemsize

    @property
    def is_category(self) -> bool:
        return self.categories is not None

    def __eq__(self, other):
        if isinstance(other, DType):
            return (self.physical == other.physical
                    and self.categories == other.categories)
        if self.categories is not None:
            return False
        try:
            return self.physical == np.dtype(other)
        except TypeError:
            return NotImplemented

    def __hash__(self):
        return hash((self.physical, self.categories))

    def __repr__(self):
        if self.categories is not None:
            base = "category[str]"
            return base + ("?" if self.nullable else "")
        return self.physical.name + ("?" if self.nullable else "")


def physical_dtype(dt) -> np.dtype:
    """The on-device dtype of a logical-or-physical schema entry."""
    return np.dtype(dt)


def is_category(dt) -> bool:
    return isinstance(dt, DType) and dt.is_category


def is_nullable(dt) -> bool:
    return isinstance(dt, DType) and dt.nullable


def categories_of(dt) -> tuple:
    if not is_category(dt):
        raise TypeError(f"not a category dtype: {dt!r}")
    return dt.categories


def as_nullable(dt) -> Any:
    """The nullable variant of a schema entry (idempotent)."""
    if isinstance(dt, DType):
        if dt.nullable:
            return dt
        return DType(dt.physical, dt.categories, nullable=True)
    return DType(np.dtype(dt), nullable=True)


# -- dictionary encoding (host side, at ingest) ------------------------------


def _null_positions(values: np.ndarray) -> np.ndarray:
    """Boolean mask of None / NaN holes in a host object/str array."""
    out = np.zeros(len(values), dtype=bool)
    for i, v in enumerate(values):
        if v is None:
            out[i] = True
        elif isinstance(v, float) and np.isnan(v):
            out[i] = True
        elif type(v).__name__ == "NAType":    # pandas.NA, sans pandas import
            out[i] = True
    return out


def dict_encode(values: np.ndarray,
                categories: tuple | None = None
                ) -> tuple[np.ndarray, tuple, bool]:
    """Encode a host string array into (int32 codes, sorted dictionary,
    has_null).  ``None``/``NaN`` holes get ``NULL_CODE``.

    The dictionary is the *sorted* unique value set, so code order is
    lexicographic order — sorts and range comparisons on codes match sorts on
    the strings themselves.  Pass ``categories`` to encode against a fixed
    dictionary (values outside it raise).
    """
    values = np.asarray(values, dtype=object)
    nulls = _null_positions(values)
    strs = values[~nulls]
    for v in strs:
        if not isinstance(v, str):
            raise TypeError(
                f"dict_encode: non-string value {v!r}; mixed-type object "
                "columns are not supported")
    if categories is None:
        cats = tuple(sorted(set(strs.tolist())))
    else:
        cats = tuple(categories)
        extra = set(strs.tolist()) - set(cats)
        if extra:
            raise ValueError(f"values outside the dictionary: {sorted(extra)!r}")
    lut = {v: i for i, v in enumerate(cats)}
    codes = np.full(len(values), NULL_CODE, dtype=CODE_DTYPE)
    if len(strs):
        codes[~nulls] = np.fromiter((lut[v] for v in strs), dtype=CODE_DTYPE,
                                    count=len(strs))
    return codes, cats, bool(nulls.any())


def dict_decode(codes: np.ndarray, categories: tuple) -> np.ndarray:
    """Codes -> host object array of strings (``None`` for null codes)."""
    codes = np.asarray(codes)
    out = np.empty(len(codes), dtype=object)
    cats = np.asarray(categories, dtype=object) if categories else \
        np.empty(0, dtype=object)
    valid = codes >= 0
    if codes.size:
        out[valid] = cats[codes[valid]] if len(cats) else None
        out[~valid] = None
    return out


def union_categories(a: tuple, b: tuple) -> tuple:
    """Merged (sorted) dictionary for joining/concatenating two category
    columns encoded against different dictionaries."""
    return tuple(sorted(set(a) | set(b)))


def recode_map(old: tuple, new: tuple) -> np.ndarray:
    """Host int32 lookup table: ``new_code = map[old_code]`` (null stays
    null by convention — callers gate on ``code >= 0``)."""
    if not set(old) <= set(new):
        raise ValueError("recode target dictionary must be a superset")
    lut = {v: i for i, v in enumerate(new)}
    return np.asarray([lut[v] for v in old], dtype=CODE_DTYPE) if old else \
        np.zeros(1, dtype=CODE_DTYPE)


# -- ingest coercion ---------------------------------------------------------

_REJECT_KINDS = {
    "M": "datetime64 (convert to int64 epoch or string first)",
    "m": "timedelta64 (convert to a numeric duration first)",
    "c": "complex (split into real/imag float columns)",
    "V": "structured/void (pass each field as its own column)",
}


def coerce_column(name: str, values) -> tuple[np.ndarray, Any]:
    """Ingest-time coercion: host values -> (physical array, schema dtype).

    * str / object-of-str arrays (``None``/``NaN`` holes allowed) are
      dictionary-encoded to int32 codes with a ``category[str]`` dtype;
    * float arrays with NaN holes keep NaN in-band and get a nullable dtype;
    * object arrays of numbers with ``None`` holes become nullable float32
      (pandas promotes holed ints to float the same way);
    * plain numeric/bool arrays pass through unchanged;
    * datetime/complex/structured inputs raise an actionable TypeError
      instead of being silently cast.
    """
    arr = values if isinstance(values, np.ndarray) else np.asarray(values)
    kind = arr.dtype.kind
    if kind in _REJECT_KINDS:
        raise TypeError(
            f"column {name!r}: unsupported dtype {arr.dtype} — "
            f"{_REJECT_KINDS[kind]}")
    if kind in ("U", "S"):
        codes, cats, _ = dict_encode(arr.astype(object))
        return codes, DType(CODE_DTYPE, cats)
    if kind == "O":
        nulls = _null_positions(arr)
        rest = arr[~nulls]
        if all(isinstance(v, str) for v in rest):
            codes, cats, has_null = dict_encode(arr)
            return codes, DType(CODE_DTYPE, cats, nullable=has_null)
        if all(isinstance(v, (int, float, np.integer, np.floating))
               and not isinstance(v, bool) for v in rest):
            out = np.full(len(arr), np.nan, dtype=np.float32)
            out[~nulls] = rest.astype(np.float32)
            if not nulls.any() and all(
                    isinstance(v, (int, np.integer)) for v in rest):
                return rest.astype(np.int32), np.dtype(np.int32)
            return out, DType(np.float32, nullable=True)
        bad = {type(v).__name__ for v in rest
               if not isinstance(v, (str, int, float, np.integer, np.floating))}
        raise TypeError(
            f"column {name!r}: object column mixes strings and numbers or "
            f"holds unsupported values ({sorted(bad) or 'mixed str/number'}) "
            "— pass homogeneous strings or numbers")
    if kind == "f" and arr.size and bool(np.isnan(arr).any()):
        return arr, DType(arr.dtype, nullable=True)
    return arr, np.dtype(arr.dtype)

"""repro.core — HiFrames: compiler-based distributed data frames in JAX.

The paper's primary contribution: a lazy data-frame IR whose relational
operators are optimized (predicate pushdown, column pruning), distribution-
inferred over the 1D_BLOCK/1D_VAR/REP semilattice, and lowered into a single
jitted shard_map SPMD program alongside arbitrary array computation.
"""
from . import api, distribution, expr, ir, lower, optimizer, physical, table
from .api import *  # noqa: F401,F403
from .lower import ExecConfig
from .table import DTable

"""Structured error taxonomy for the execution guardrails (docs/robustness.md).

Every failure mode the engine can detect maps to ONE typed error here, so
callers (and the unified retry policy in runtime/retry.py) dispatch on type
instead of parsing messages:

  * :class:`CapacityOverflow`  — a 1D_VAR capacity site overflowed and the
    retry budget is exhausted.  Carries the physical-plan op id, the observed
    requirement and the planned cap, so the caller knows exactly which buffer
    to grow.
  * :class:`PlanInvariantError` — an ``ExecConfig.validate`` runtime check
    failed (row-count conservation, packed-payload checksum, post-sort
    monotonicity, category-code range): the result would be CORRUPT, never
    return it silently.
  * :class:`KernelBackendError` — a kernel backend (Pallas compiled or
    interpret) failed to build/trace; the degradation ladder steps the ONE
    offending kernel down (compiled -> interpret -> ref) before giving up.
  * :class:`StatsError`         — the adaptive statistics pass failed;
    lowering degrades to static planning and records a degradation event.

All of them subclass :class:`HiFramesError` (itself a ``RuntimeError``), so
pre-taxonomy callers catching ``RuntimeError`` keep working.
"""
from __future__ import annotations

from typing import Any, NamedTuple


class HiFramesError(RuntimeError):
    """Base of every typed engine error."""


class InvariantFailure(NamedTuple):
    """One failed runtime validation check (ExecConfig.validate).

    ``kind`` is the check family: "rowcount" (rows in != rows out across an
    exchange), "checksum" (packed-payload word checksum mismatch),
    "monotonic" (post-sort key order violated), "code_range" (category code
    outside [-1, n_categories)).  ``op_id`` anchors it to the physical plan.
    """

    kind: str
    op_id: int
    detail: str = ""

    def render(self) -> str:
        tail = f": {self.detail}" if self.detail else ""
        return f"{self.kind}@op#{self.op_id}{tail}"


class CapacityOverflow(HiFramesError):
    """A capacity site overflowed and retries are exhausted.

    ``observed_est`` is the host-reduced requirement estimate for the site
    (exact for compact/partial-agg/concat sites, a tight upper bound for
    exchanges, the worst-case product for joins); ``cap`` is the capacity the
    failing run planned.  The message names the op so "which buffer was too
    small" needs no plan spelunking.
    """

    def __init__(self, op_id: int = -1, op: str = "", observed_est: int = 0,
                 cap: int = 0, attempts: int = 0, message: str = ""):
        self.op_id = int(op_id)
        self.op = op
        self.observed_est = int(observed_est)
        self.cap = int(cap)
        self.attempts = int(attempts)
        if not message:
            where = f"op #{op_id} ({op})" if op else f"op #{op_id}"
            message = (
                f"capacity overflow at {where}: observed requirement "
                f"~{self.observed_est} rows > planned cap {self.cap} "
                f"after {self.attempts} attempt(s) — data skew exceeds plan "
                "bounds (cf. paper Q05 skew discussion)")
        super().__init__(message)


class PlanInvariantError(HiFramesError):
    """Runtime validation (ExecConfig.validate) detected corruption."""

    def __init__(self, failures: tuple[InvariantFailure, ...],
                 message: str = ""):
        self.failures = tuple(failures)
        if not message:
            body = "; ".join(f.render() for f in self.failures) or "unknown"
            message = (f"plan invariant violated ({len(self.failures)} "
                       f"check(s) failed): {body}")
        super().__init__(message)


class KernelBackendError(HiFramesError):
    """A kernel backend failed; carries what failed and on which backend so
    the retry policy can step exactly that kernel down the ladder."""

    def __init__(self, kernel: str, backend: str, cause: Any = None,
                 message: str = ""):
        self.kernel = kernel
        self.backend = backend
        self.cause = cause
        if not message:
            message = (f"kernel backend failure: {kernel!r} on backend "
                       f"{backend!r}" + (f" ({cause})" if cause else ""))
        super().__init__(message)


class StatsError(HiFramesError):
    """The adaptive statistics pass failed (lowering degrades to static)."""

"""Logical plan IR — the Domain-Pass analogue.

The paper encapsulates relational operations into first-class AST nodes
(``Expr(:aggregate, ...)``) so that the whole-program compiler can see and
transform them.  Here each node is an explicit dataclass; a DataFrame wraps a
node, and ``collect()`` triggers optimize → distribute → lower → jit.

Node ids are globally unique; expression ColRefs name columns as
(node_id, column_name), which gives the optimizer exact column provenance
(needed for predicate pushdown through join and for column pruning).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace
from typing import Any, Optional

import numpy as np

from .expr import AggExpr, ColRef, Expr

_ids = itertools.count()


def fresh_id() -> int:
    return next(_ids)


def as_keys(x) -> tuple[str, ...]:
    """Normalize a key spec (scalar name or sequence of names) to a tuple.

    Composite (multi-column) keys are carried as tuples everywhere in the IR;
    single-key call sites stay source-compatible via this normalization.
    """
    if isinstance(x, str):
        return (x,)
    keys = tuple(x)
    if not keys or not all(isinstance(k, str) for k in keys):
        raise TypeError(f"key columns must be non-empty str names, got {x!r}")
    return keys


@dataclass(eq=False)
class Node:
    """Base logical node.  ``schema`` maps column name -> numpy dtype."""

    id: int = field(default_factory=fresh_id, init=False)

    @property
    def children(self) -> tuple["Node", ...]:
        return ()

    @property
    def schema(self) -> dict[str, np.dtype]:
        raise NotImplementedError

    def with_children(self, children: tuple["Node", ...]) -> "Node":
        raise NotImplementedError

    def short(self) -> str:
        return type(self).__name__


@dataclass(eq=False)
class Scan(Node):
    """Leaf: a source table (in-memory arrays or a named dataset)."""

    name: str
    columns: dict[str, Any]          # name -> array (host or device)
    _schema: dict[str, np.dtype] = None

    def __post_init__(self):
        if self._schema is None:
            self._schema = {k: np.asarray(v[:0] if hasattr(v, "__getitem__") else v).dtype
                            for k, v in self.columns.items()}

    @property
    def schema(self):
        return dict(self._schema)

    def with_children(self, children):
        assert not children
        return self

    def short(self):
        return f"Scan({self.name})"


@dataclass(eq=False)
class Filter(Node):
    child: Node
    pred: Expr

    @property
    def children(self):
        return (self.child,)

    @property
    def schema(self):
        return self.child.schema

    def with_children(self, children):
        n = replace(self)
        n.child = children[0]
        return n

    def short(self):
        return f"Filter({self.pred})"


@dataclass(eq=False)
class Project(Node):
    """Column selection / renaming / derived columns.

    ``cols`` maps output name -> Expr over child columns.  Covers projection,
    column assignment (``df[:id3] = ...``) and renames.
    """

    child: Node
    cols: dict[str, Expr]
    dtypes: dict[str, np.dtype] = None  # resolved lazily at lowering

    @property
    def children(self):
        return (self.child,)

    @property
    def schema(self):
        if self.dtypes:
            return dict(self.dtypes)
        child_schema = self.child.schema
        out = {}
        for name, e in self.cols.items():
            if isinstance(e, ColRef) and e.name in child_schema:
                out[name] = child_schema[e.name]
            else:
                out[name] = np.dtype(np.float32)  # refined at lowering
        return out

    def passthrough(self) -> dict[str, str]:
        """Output columns that are pure renames: out name -> child column.

        The physical planner uses this to push partitioning/ordering
        properties through projections; computed columns provide nothing.
        """
        return {name: e.name for name, e in self.cols.items()
                if isinstance(e, ColRef)}

    def with_children(self, children):
        n = replace(self)
        n.child = children[0]
        return n

    def short(self):
        return f"Project({list(self.cols)})"


@dataclass(eq=False)
class Join(Node):
    """Equi-join (inner or left-outer) on one or more key column pairs.

    ``left_on``/``right_on`` are equal-length tuples; position i of each pair
    is compared for equality.  Scalar names normalize to 1-tuples.
    """

    left: Node
    right: Node
    left_on: tuple[str, ...]
    right_on: tuple[str, ...]
    suffix: str = "_r"
    how: str = "inner"

    def __post_init__(self):
        self.left_on = as_keys(self.left_on)
        self.right_on = as_keys(self.right_on)
        if len(self.left_on) != len(self.right_on):
            raise ValueError(f"key arity mismatch: {self.left_on} vs {self.right_on}")

    @property
    def children(self):
        return (self.left, self.right)

    @property
    def schema(self):
        ls, rs = self.left.schema, self.right.schema
        out = dict(ls)
        for name, dt in rs.items():
            if name in self.right_on:
                continue  # keys are unified into left_on
            out[name + self.suffix if name in out else name] = dt
        if self.how == "left":
            out["_matched"] = np.dtype(np.int32)
        return out

    def right_out_name(self, name: str) -> str:
        return name + self.suffix if name in self.left.schema else name

    def with_children(self, children):
        n = replace(self)
        n.left, n.right = children
        return n

    def short(self):
        pairs = ",".join(f"{l}=={r}" for l, r in zip(self.left_on, self.right_on))
        return f"Join({pairs})"


@dataclass(eq=False)
class Aggregate(Node):
    """Group-by ``key`` (one or more columns) with named aggregations."""

    child: Node
    key: tuple[str, ...]
    aggs: dict[str, AggExpr]

    def __post_init__(self):
        self.key = as_keys(self.key)

    @property
    def children(self):
        return (self.child,)

    @property
    def schema(self):
        cs = self.child.schema
        out = {k: cs[k] for k in self.key}
        for name, agg in self.aggs.items():
            if agg.fn in ("count", "nunique"):
                out[name] = np.dtype(np.int32)
            elif agg.fn in ("mean", "var", "std"):
                out[name] = np.dtype(np.float32)
            else:
                out[name] = np.dtype(np.float32)  # refined at lowering
        return out

    def with_children(self, children):
        n = replace(self)
        n.child = children[0]
        return n

    def short(self):
        by = self.key[0] if len(self.key) == 1 else list(self.key)
        return f"Aggregate(by={by}, {list(self.aggs)})"


@dataclass(eq=False)
class Concat(Node):
    """Vertical concatenation (UNION ALL); schemas must match."""

    parts: tuple[Node, ...]

    @property
    def children(self):
        return tuple(self.parts)

    @property
    def schema(self):
        return self.parts[0].schema

    def with_children(self, children):
        n = replace(self)
        n.parts = tuple(children)
        return n


# Window kinds whose output is an integer position within the group (they
# take no input expression — ``expr`` is None).
RANK_KINDS = ("rank", "dense_rank", "row_number")
WINDOW_KINDS = ("cumsum", "stencil") + RANK_KINDS


@dataclass(eq=False)
class Window(Node):
    """Analytics window ops: cumsum, 1-D stencil (SMA/WMA) or rank.

    kind='cumsum'      -> out = prefix sums of ``expr``
    kind='stencil'     -> out[i] = sum_j weights[j] * x[i + j - center]
    kind='rank' / 'dense_rank' / 'row_number'
                       -> SQL ranking over ``order_by`` (requires
                          ``partition_by``); ``expr`` is None.

    ``partition_by`` non-empty makes the window PARTITIONED (SQL
    ``OVER (PARTITION BY ... ORDER BY ...)``): the computation restarts at
    every group boundary and stencil taps never cross one.  The physical
    planner realizes it as hash(partition_by) co-location plus a
    (partition_by + order_by) local sort, both elided when the input
    already provides them.  Output rows come back in that grouped layout
    (not input order).  Adds column ``out`` to the child's schema.
    """

    child: Node
    kind: str
    expr: Optional[Expr]
    out: str
    weights: tuple[float, ...] = ()
    center: int = 0
    partition_by: tuple[str, ...] = ()
    order_by: tuple[str, ...] = ()

    def __post_init__(self):
        if self.kind not in WINDOW_KINDS:
            raise ValueError(f"unknown window kind {self.kind!r}")
        self.partition_by = as_keys(self.partition_by) if self.partition_by else ()
        self.order_by = as_keys(self.order_by) if self.order_by else ()
        if self.kind in RANK_KINDS:
            if not self.partition_by or not self.order_by:
                raise ValueError(
                    f"{self.kind} requires partition_by and order_by keys")
        elif self.order_by and not self.partition_by:
            # A global ORDER BY (no PARTITION BY) would need a global
            # re-sort before the scan/stencil; silently computing in
            # arrival order instead would be wrong — sort first.
            raise ValueError(
                f"{self.kind} with order_by requires partition_by; for a "
                f"globally ordered window, sort(by=order_by) first")

    def sort_keys(self) -> tuple[str, ...]:
        """Keys the grouped layout must be ordered by: partition keys first,
        then order keys (dropping duplicates already in the partition)."""
        return self.partition_by + tuple(
            k for k in self.order_by if k not in self.partition_by)

    @property
    def children(self):
        return (self.child,)

    @property
    def schema(self):
        s = self.child.schema
        s[self.out] = (np.dtype(np.int32) if self.kind in RANK_KINDS
                       else np.dtype(np.float32))
        return s

    def with_children(self, children):
        n = replace(self)
        n.child = children[0]
        return n

    def short(self):
        over = ""
        if self.partition_by:
            over = f" over({','.join(self.partition_by)}"
            if self.order_by:
                over += f"; {','.join(self.order_by)}"
            over += ")"
        return f"Window({self.kind}->{self.out}{over})"


@dataclass(eq=False)
class Sort(Node):
    """Global sample-sort, lexicographic over one or more key columns."""

    child: Node
    by: tuple[str, ...]
    ascending: bool = True

    def __post_init__(self):
        self.by = as_keys(self.by)

    @property
    def children(self):
        return (self.child,)

    @property
    def schema(self):
        return self.child.schema

    def with_children(self, children):
        n = replace(self)
        n.child = children[0]
        return n


@dataclass(eq=False)
class Rebalance(Node):
    """Inserted by the distribution pass: 1D_VAR -> 1D_BLOCK."""

    child: Node

    @property
    def children(self):
        return (self.child,)

    @property
    def schema(self):
        return self.child.schema

    def with_children(self, children):
        n = replace(self)
        n.child = children[0]
        return n


# ---------------------------------------------------------------------------
# DAG utilities
# ---------------------------------------------------------------------------


def topo_order(root: Node) -> list[Node]:
    seen: dict[int, Node] = {}
    order: list[Node] = []

    def visit(n: Node):
        if n.id in seen:
            return
        seen[n.id] = n
        for c in n.children:
            visit(c)
        order.append(n)

    visit(root)
    return order


def plan_str(root: Node, dists: dict[int, str] | None = None) -> str:
    """Pretty-printer used by EXPLAIN and the optimizer tests."""
    lines: list[str] = []

    def rec(n: Node, depth: int):
        d = f"  [{dists[n.id]}]" if dists and n.id in dists else ""
        lines.append("  " * depth + f"{n.short()} #{n.id}{d}")
        for c in n.children:
            rec(c, depth + 1)

    rec(root, 0)
    return "\n".join(lines)

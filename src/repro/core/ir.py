"""Logical plan IR — the Domain-Pass analogue.

The paper encapsulates relational operations into first-class AST nodes
(``Expr(:aggregate, ...)``) so that the whole-program compiler can see and
transform them.  Here each node is an explicit dataclass; a DataFrame wraps a
node, and ``collect()`` triggers optimize → distribute → lower → jit.

Node ids are globally unique; expression ColRefs name columns as
(node_id, column_name), which gives the optimizer exact column provenance
(needed for predicate pushdown through join and for column pruning).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace
from typing import Any, Optional

import numpy as np

from .expr import AggExpr, ColRef, Expr, expr_nullable, infer_dtype
from .dtypes import as_nullable, is_category, is_nullable

_ids = itertools.count()


def fresh_id() -> int:
    return next(_ids)


def as_keys(x) -> tuple[str, ...]:
    """Normalize a key spec (scalar name or sequence of names) to a tuple.

    Composite (multi-column) keys are carried as tuples everywhere in the IR;
    single-key call sites stay source-compatible via this normalization.
    """
    if isinstance(x, str):
        return (x,)
    keys = tuple(x)
    if not keys or not all(isinstance(k, str) for k in keys):
        raise TypeError(f"key columns must be non-empty str names, got {x!r}")
    return keys


@dataclass(eq=False)
class Node:
    """Base logical node.  ``schema`` maps column name -> numpy dtype."""

    id: int = field(default_factory=fresh_id, init=False)

    @property
    def children(self) -> tuple["Node", ...]:
        return ()

    @property
    def schema(self) -> dict[str, np.dtype]:
        raise NotImplementedError

    def with_children(self, children: tuple["Node", ...]) -> "Node":
        raise NotImplementedError

    def short(self) -> str:
        return type(self).__name__


@dataclass(frozen=True)
class ScanLayout:
    """Physical layout a MATERIALIZED scan carries (``df.persist()``).

    A persisted frame's Scan is not a plain host table: its columns may be
    device shards laid out by the plan that produced them, and this record
    is the contract that lets downstream planning start from those
    properties instead of "block, unordered":

      * ``kind``/``partitioned_by``/``ascending`` — the Partitioning the
        producing plan's root op provided (hash/range/rep/block);
        ``globally_sorted`` marks a block layout whose shard boundaries
        follow ``sorted_by`` (rebalanced sorted stream).
      * ``sorted_by``/``order_ascending`` — each shard's valid-prefix
        ordering.
      * ``counts``/``capacity``/``nshards`` — the 1D_VAR carrier: columns
        are ``(nshards * capacity,)`` device arrays with per-shard valid
        prefixes.  ``counts is None`` means the columns are plain host
        arrays (REP results re-enter that way) and only the ordering claims
        apply.
      * ``dist`` — the lattice element the table satisfies (seeds
        distribution inference).

    Hash/range claims are only valid at the shard count they were produced
    under (routing is ``hash % P`` / data-dependent splitters), so every
    consumer gates on :meth:`device_valid`.
    """

    kind: str = "block"                  # "hash" | "range" | "rep" | "block"
    partitioned_by: tuple[str, ...] = ()
    ascending: bool = True
    globally_sorted: bool = False
    sorted_by: tuple[str, ...] = ()
    order_ascending: bool = True
    counts: Any = None                   # (nshards,) np.int32, or None (host)
    capacity: int = 0
    nshards: int = 1
    dist: str = "1D_VAR"

    def device_valid(self, P: int) -> bool:
        """Do the device shards (and the partitioning claims that depend on
        shard routing) re-enter directly at shard count ``P``?"""
        return self.counts is not None and self.nshards == P

    def rows(self) -> int:
        return int(np.sum(self.counts)) if self.counts is not None else -1

    def restrict(self, live: set[str]) -> "ScanLayout":
        """Layout after pruning to ``live`` columns: partitioning survives
        iff every key survives; ordering keeps its longest surviving prefix
        (same rules as the physical planner's property restriction)."""
        kind, pkeys, gs = self.kind, self.partitioned_by, self.globally_sorted
        if kind in ("hash", "range") and not all(k in live for k in pkeys):
            kind, pkeys, gs = "block", (), False
        prefix = []
        for k in self.sorted_by:
            if k not in live:
                break
            prefix.append(k)
        if not prefix:
            gs = False
        return replace(self, kind=kind, partitioned_by=pkeys,
                       globally_sorted=gs, sorted_by=tuple(prefix))

    def gather_host(self, columns: dict[str, Any]) -> dict[str, np.ndarray]:
        """Fallback re-entry at a DIFFERENT shard count: concatenate every
        shard's valid prefix on the host (the round-trip ``device_valid``
        re-entry avoids)."""
        cnts = np.asarray(self.counts)
        out = {}
        for name, col in columns.items():
            a = np.asarray(col).reshape(self.nshards, self.capacity)
            out[name] = np.concatenate(
                [a[r, : cnts[r]] for r in range(self.nshards)])
        return out


@dataclass(eq=False)
class Scan(Node):
    """Leaf: a source table (in-memory arrays or a named dataset).

    ``layout`` is set for persisted/cached frames (see :class:`ScanLayout`):
    the columns are then device shards whose partitioning/ordering seed the
    physical planner, letting whole downstream pipelines start elided.
    """

    name: str
    columns: dict[str, Any]          # name -> array (host or device)
    _schema: dict[str, np.dtype] = None
    layout: Optional[ScanLayout] = None

    def __post_init__(self):
        if self._schema is None:
            self._schema = {k: np.asarray(v[:0] if hasattr(v, "__getitem__") else v).dtype
                            for k, v in self.columns.items()}

    @property
    def schema(self):
        return dict(self._schema)

    def with_children(self, children):
        assert not children
        return self

    def short(self):
        if self.layout is not None and self.layout.kind != "block":
            return f"Scan({self.name}|{self.layout.kind})"
        return f"Scan({self.name})"


@dataclass(eq=False)
class Filter(Node):
    child: Node
    pred: Expr

    @property
    def children(self):
        return (self.child,)

    @property
    def schema(self):
        return self.child.schema

    def with_children(self, children):
        n = replace(self)
        n.child = children[0]
        return n

    def short(self):
        return f"Filter({self.pred})"


@dataclass(eq=False)
class Project(Node):
    """Column selection / renaming / derived columns.

    ``cols`` maps output name -> Expr over child columns.  Covers projection,
    column assignment (``df[:id3] = ...``) and renames.
    """

    child: Node
    cols: dict[str, Expr]
    dtypes: dict[str, np.dtype] = None  # resolved lazily at lowering

    @property
    def children(self):
        return (self.child,)

    @property
    def schema(self):
        if self.dtypes:
            return dict(self.dtypes)
        child_schema = self.child.schema
        out = {}
        for name, e in self.cols.items():
            if isinstance(e, ColRef) and e.name in child_schema:
                out[name] = child_schema[e.name]  # logical dtype rides along
            else:
                dt = infer_dtype(e, child_schema)
                out[name] = (as_nullable(dt)
                             if expr_nullable(e, child_schema) else dt)
        return out

    def passthrough(self) -> dict[str, str]:
        """Output columns that are pure renames: out name -> child column.

        The physical planner uses this to push partitioning/ordering
        properties through projections; computed columns provide nothing.
        """
        return {name: e.name for name, e in self.cols.items()
                if isinstance(e, ColRef)}

    def with_children(self, children):
        n = replace(self)
        n.child = children[0]
        return n

    def short(self):
        return f"Project({list(self.cols)})"


@dataclass(eq=False)
class Join(Node):
    """Equi-join (inner or left-outer) on one or more key column pairs.

    ``left_on``/``right_on`` are equal-length tuples; position i of each pair
    is compared for equality.  Scalar names normalize to 1-tuples.
    """

    left: Node
    right: Node
    left_on: tuple[str, ...]
    right_on: tuple[str, ...]
    suffix: str = "_r"
    how: str = "inner"

    def __post_init__(self):
        self.left_on = as_keys(self.left_on)
        self.right_on = as_keys(self.right_on)
        if len(self.left_on) != len(self.right_on):
            raise ValueError(f"key arity mismatch: {self.left_on} vs {self.right_on}")

    @property
    def children(self):
        return (self.left, self.right)

    @property
    def schema(self):
        ls, rs = self.left.schema, self.right.schema
        out = dict(ls)
        for name, dt in rs.items():
            if name in self.right_on:
                continue  # keys are unified into left_on
            if self.how == "left" and (
                    is_category(dt) or np.issubdtype(np.dtype(dt), np.floating)):
                # unmatched left rows null-fill the right columns (NaN /
                # null code); int payloads keep zero-fill + _matched
                dt = as_nullable(dt)
            out[name + self.suffix if name in out else name] = dt
        if self.how == "left":
            out["_matched"] = np.dtype(np.int32)
        return out

    def right_out_name(self, name: str) -> str:
        return name + self.suffix if name in self.left.schema else name

    def with_children(self, children):
        n = replace(self)
        n.left, n.right = children
        return n

    def short(self):
        pairs = ",".join(f"{l}=={r}" for l, r in zip(self.left_on, self.right_on))
        return f"Join({pairs})"


@dataclass(eq=False)
class Aggregate(Node):
    """Group-by ``key`` (one or more columns) with named aggregations."""

    child: Node
    key: tuple[str, ...]
    aggs: dict[str, AggExpr]

    def __post_init__(self):
        self.key = as_keys(self.key)

    @property
    def children(self):
        return (self.child,)

    @property
    def schema(self):
        cs = self.child.schema
        out = {k: cs[k] for k in self.key}
        for name, agg in self.aggs.items():
            nullable = agg.expr is not None and (
                expr_nullable(agg.expr, cs)
                or (isinstance(agg.expr, ColRef)
                    and is_nullable(cs.get(agg.expr.name))))
            if agg.fn in ("count", "nunique"):
                out[name] = np.dtype(np.int32)
            elif agg.fn in ("any", "all"):
                out[name] = np.dtype(np.bool_)
            elif agg.fn in ("mean", "var", "std"):
                dt = np.dtype(np.float32)
                out[name] = as_nullable(dt) if nullable else dt
            elif agg.fn in ("min", "max", "first"):
                # value dtype passes through — category min/max/first stay
                # category (sorted dictionaries make code order string order)
                dt = infer_dtype(agg.expr, cs)
                if isinstance(agg.expr, ColRef) and is_category(cs.get(agg.expr.name)):
                    dt = cs[agg.expr.name]
                out[name] = as_nullable(dt) if nullable else dt
            elif agg.fn in ("sum", "prod"):
                dt = infer_dtype(agg.expr, cs)
                if dt == np.dtype(bool):
                    dt = np.dtype(np.int32)  # segment sums cast bool up
                out[name] = dt  # skipna sum/prod of all-null = 0/1, not null
            else:
                out[name] = np.dtype(np.float32)
        return out

    def with_children(self, children):
        n = replace(self)
        n.child = children[0]
        return n

    def short(self):
        by = self.key[0] if len(self.key) == 1 else list(self.key)
        return f"Aggregate(by={by}, {list(self.aggs)})"


@dataclass(eq=False)
class Concat(Node):
    """Vertical concatenation (UNION ALL); schemas must match."""

    parts: tuple[Node, ...]

    @property
    def children(self):
        return tuple(self.parts)

    @property
    def schema(self):
        return self.parts[0].schema

    def with_children(self, children):
        n = replace(self)
        n.parts = tuple(children)
        return n


# Window kinds whose output is an integer position within the group (they
# take no input expression — ``expr`` is None).
RANK_KINDS = ("rank", "dense_rank", "row_number")
WINDOW_KINDS = ("cumsum", "stencil") + RANK_KINDS


@dataclass(eq=False)
class Window(Node):
    """Analytics window ops: cumsum, 1-D stencil (SMA/WMA) or rank.

    kind='cumsum'      -> out = prefix sums of ``expr``
    kind='stencil'     -> out[i] = sum_j weights[j] * x[i + j - center]
    kind='rank' / 'dense_rank' / 'row_number'
                       -> SQL ranking over ``order_by`` (requires
                          ``partition_by``); ``expr`` is None.

    ``partition_by`` non-empty makes the window PARTITIONED (SQL
    ``OVER (PARTITION BY ... ORDER BY ...)``): the computation restarts at
    every group boundary and stencil taps never cross one.  The physical
    planner realizes it as hash(partition_by) co-location plus a
    (partition_by + order_by) local sort, both elided when the input
    already provides them.  Output rows come back in that grouped layout
    (not input order).  Adds column ``out`` to the child's schema.
    """

    child: Node
    kind: str
    expr: Optional[Expr]
    out: str
    weights: tuple[float, ...] = ()
    center: int = 0
    partition_by: tuple[str, ...] = ()
    order_by: tuple[str, ...] = ()
    # stencil-only: renormalize border windows by the realized weight mass
    # (divide by the weights of the taps that actually contributed instead
    # of the full window) — pandas' min_periods=1 exact rolling mean.
    exact: bool = False

    def __post_init__(self):
        if self.kind not in WINDOW_KINDS:
            raise ValueError(f"unknown window kind {self.kind!r}")
        if self.exact:
            # exact borders renormalize by the realized weight MASS, which
            # is only meaningful for nonnegative windows with positive
            # total weight (rolling means, SMA/WMA); a difference stencil
            # would divide by (near-)zero everywhere.
            if self.kind != "stencil":
                raise ValueError("exact= applies only to stencil windows")
            if any(w < 0 for w in self.weights) or sum(self.weights) <= 0:
                raise ValueError(
                    "exact=True requires nonnegative weights with a "
                    "positive sum (border renormalization divides by the "
                    "realized weight mass)")
        self.partition_by = as_keys(self.partition_by) if self.partition_by else ()
        self.order_by = as_keys(self.order_by) if self.order_by else ()
        if self.kind in RANK_KINDS:
            # row_number without order_by is well-defined: 1-based position
            # in post-exchange arrival order (segment_rank ignores order
            # keys for it) — the per-group top-k fusion relies on this.
            # rank/dense_rank compare order-key values, so they require one.
            # partition_by may be EMPTY: the window is then GLOBAL, lowered
            # as a per-shard-count exscan plus (for rank/dense_rank)
            # boundary-run reconciliation — the physical planner requires
            # equal order-key tuples adjacent across the global stream
            # (api.rank sorts first; already-sorted inputs plan a no-op).
            need_order = self.kind != "row_number"
            if need_order and not self.order_by:
                raise ValueError(f"{self.kind} requires order_by keys")
        elif self.order_by and not self.partition_by:
            # A global ORDER BY (no PARTITION BY) would need a global
            # re-sort before the scan/stencil; silently computing in
            # arrival order instead would be wrong — sort first.
            raise ValueError(
                f"{self.kind} with order_by requires partition_by; for a "
                f"globally ordered window, sort(by=order_by) first")

    def sort_keys(self) -> tuple[str, ...]:
        """Keys the grouped layout must be ordered by: partition keys first,
        then order keys (dropping duplicates already in the partition)."""
        return self.partition_by + tuple(
            k for k in self.order_by if k not in self.partition_by)

    @property
    def children(self):
        return (self.child,)

    @property
    def schema(self):
        s = self.child.schema
        if self.kind in RANK_KINDS:
            s[self.out] = np.dtype(np.int32)
        elif self.kind == "cumsum" and self.expr is not None:
            dt = infer_dtype(self.expr, s)
            if dt == np.dtype(bool):
                dt = np.dtype(np.int32)  # cumsum promotes bool
            s[self.out] = (as_nullable(dt)
                           if expr_nullable(self.expr, s) else dt)
        else:
            s[self.out] = np.dtype(np.float32)  # stencils compute in float
        return s

    def with_children(self, children):
        n = replace(self)
        n.child = children[0]
        return n

    def short(self):
        over = ""
        if self.partition_by:
            over = f" over({','.join(self.partition_by)}"
            if self.order_by:
                over += f"; {','.join(self.order_by)}"
            over += ")"
        return f"Window({self.kind}->{self.out}{over})"


@dataclass(eq=False)
class Limit(Node):
    """First ``n`` rows in global (shard-concatenation) order — the backend
    of ``df.head(n)`` / ``df.limit(n)``.

    No data moves: each shard clamps its valid count to the slice of
    ``[0, n)`` it owns (one exclusive scan of counts).  Partitioning and
    ordering both survive — a subset of co-located key groups is still
    co-located, and a prefix of sorted rows is still sorted.
    """

    child: Node
    n: int

    def __post_init__(self):
        if int(self.n) < 0:
            raise ValueError(f"limit must be >= 0, got {self.n}")
        self.n = int(self.n)

    @property
    def children(self):
        return (self.child,)

    @property
    def schema(self):
        return self.child.schema

    def with_children(self, children):
        m = replace(self)
        m.child = children[0]
        return m

    def short(self):
        return f"Limit({self.n})"


@dataclass(eq=False)
class Sort(Node):
    """Global sample-sort, lexicographic over one or more key columns."""

    child: Node
    by: tuple[str, ...]
    ascending: bool = True

    def __post_init__(self):
        self.by = as_keys(self.by)

    @property
    def children(self):
        return (self.child,)

    @property
    def schema(self):
        return self.child.schema

    def with_children(self, children):
        n = replace(self)
        n.child = children[0]
        return n


@dataclass(eq=False)
class Repartition(Node):
    """Layout-only verb: hash-partition by ``by`` and/or sort each shard by
    ``sort_by`` — same rows, new placement/order (``df.repartition()`` /
    ``df.sort_within_partitions()``).

    Purely a property request to the physical planner: it inserts a hash
    exchange (for ``by``) and/or a shard-local sort (for ``sort_by``), each
    elided when the input already provides the property.  Chained with
    ``persist()`` the produced layout is captured in the Scan, which is the
    point — pre-staging a hot table so later queries plan zero exchanges.
    """

    child: Node
    by: tuple[str, ...] = ()
    sort_by: tuple[str, ...] = ()

    def __post_init__(self):
        self.by = as_keys(self.by) if self.by else ()
        self.sort_by = as_keys(self.sort_by) if self.sort_by else ()
        if not self.by and not self.sort_by:
            raise ValueError("Repartition requires by= and/or sort_by= keys")

    @property
    def children(self):
        return (self.child,)

    @property
    def schema(self):
        return self.child.schema

    def with_children(self, children):
        n = replace(self)
        n.child = children[0]
        return n

    def short(self):
        parts = []
        if self.by:
            parts.append(f"by={','.join(self.by)}")
        if self.sort_by:
            parts.append(f"sort={','.join(self.sort_by)}")
        return f"Repartition({'; '.join(parts)})"


@dataclass(eq=False)
class Rebalance(Node):
    """Inserted by the distribution pass: 1D_VAR -> 1D_BLOCK."""

    child: Node

    @property
    def children(self):
        return (self.child,)

    @property
    def schema(self):
        return self.child.schema

    def with_children(self, children):
        n = replace(self)
        n.child = children[0]
        return n


# ---------------------------------------------------------------------------
# DAG utilities
# ---------------------------------------------------------------------------


def topo_order(root: Node) -> list[Node]:
    seen: dict[int, Node] = {}
    order: list[Node] = []

    def visit(n: Node):
        if n.id in seen:
            return
        seen[n.id] = n
        for c in n.children:
            visit(c)
        order.append(n)

    visit(root)
    return order


def plan_str(root: Node, dists: dict[int, str] | None = None) -> str:
    """Pretty-printer used by EXPLAIN and the optimizer tests."""
    lines: list[str] = []

    def rec(n: Node, depth: int):
        d = f"  [{dists[n.id]}]" if dists and n.id in dists else ""
        lines.append("  " * depth + f"{n.short()} #{n.id}{d}")
        for c in n.children:
            rec(c, depth + 1)

    rec(root, 0)
    return "\n".join(lines)

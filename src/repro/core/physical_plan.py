"""Property-driven physical planning — the exchange-elision layer.

The logical plan (ir.py) says WHAT relational result to compute; the
distribution pass (distribution.py) says WHERE rows may live (the lattice of
paper §4.4).  This module decides HOW rows move: it walks the
distribution-annotated logical plan and emits a linear physical plan of
operators (HashExchange, LocalSort, MergeJoin, SegmentAgg, SampleSort,
Compact, Map, ...), each carrying the *physical properties* its output
provides:

  * ``Partitioning`` — how rows are placed across shards:
      - ``hash(keys)``  equal key TUPLES co-locate (value-deterministic
        combined hash, so it aligns across tables),
      - ``range(keys)`` equal key tuples co-locate and shards are globally
        ordered (sample-sort output; splitters are data-dependent, so it
        does NOT align across tables),
      - ``rep``         every shard holds all rows,
      - ``block``       no co-location guarantee (scans, rebalance).
  * ``Ordering`` — the key prefix each shard's valid rows are sorted by.

Exchanges and sorts are inserted only where a consumer's REQUIRED property is
not already PROVIDED — the paper's "communicate only when the distribution
analysis demands it" (§4.5–4.6) made explicit.  The satisfaction rules are
deliberately conservative and composite-key-aware:

  * co-location on K is satisfied by hash/range partitioning on S iff S is an
    ordered subsequence of K (equal K-tuples are then equal S-tuples, hence
    co-located).  A superset or reordering of K does NOT satisfy K.
  * grouping/ordering on K is satisfied iff K is a prefix of the provided
    ordering keys (order-sensitive).
  * REP satisfies every co-location requirement (each shard is total).

Capacity planning (static per-shard buffer sizes, DESIGN.md §2) also lives
here and operates on physical ops: exchanges get (src,dst) buckets,
pass-through ops inherit their input's capacity, and an elided exchange means
the downstream op keeps the (smaller) local capacity.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from . import distribution as D
from . import ir
from .expr import infer_dtype, nulltag_for
from .physical import (AGG_DECOMP, PACK_WORD_BYTES, SALT_COL, col_words,
                       decomposable)


# ---------------------------------------------------------------------------
# physical properties
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Partitioning:
    """Row placement across shards; ``keys`` only meaningful for hash/range.

    ``ascending`` records the DIRECTION of range shard boundaries (shard 0
    holds the smallest tuples iff True).  Co-location never depends on it,
    but global-sortedness checks do: a locally ascending ordering over
    descending shard ranges is NOT globally sorted.  Meaningless (always
    True) for hash/rep/block.

    ``globally_sorted`` marks a BLOCK partitioning whose shard boundaries
    follow the op's Ordering: the concatenation of shard valid prefixes is
    globally sorted by the ordering keys (a Rebalance of a globally sorted
    stream).  It gives no key co-location — an equal-key run may straddle a
    boundary — but lets a downstream Sort on an ordering prefix plan a full
    no-op instead of paying splitter routing.
    """

    kind: str                       # "hash" | "range" | "rep" | "block"
    keys: tuple[str, ...] = ()
    ascending: bool = True
    globally_sorted: bool = False   # block-only: shard order follows Ordering

    def short(self) -> str:
        if not self.keys:
            return self.kind + (" sorted" if self.globally_sorted else "")
        d = "" if self.ascending else " desc"
        return f"{self.kind}({','.join(self.keys)}){d}"


@dataclass(frozen=True)
class Ordering:
    """Per-shard valid-prefix sort order; () means unordered."""

    keys: tuple[str, ...] = ()
    ascending: bool = True

    def short(self) -> str:
        if not self.keys:
            return "-"
        return f"({','.join(self.keys)}){'' if self.ascending else ' desc'}"


BLOCK = Partitioning("block")
REPL = Partitioning("rep")
UNORDERED = Ordering()


def subsequence_indices(sub: tuple[str, ...],
                        seq: tuple[str, ...]) -> Optional[tuple[int, ...]]:
    """Indices I with seq[I] == sub (greedy), or None if not a subsequence."""
    out = []
    j = 0
    for s in sub:
        while j < len(seq) and seq[j] != s:
            j += 1
        if j == len(seq):
            return None
        out.append(j)
        j += 1
    return tuple(out)


def colocates(part: Partitioning, keys: tuple[str, ...]) -> bool:
    """Does ``part`` already co-locate rows with equal ``keys`` tuples?

    hash/range partitioning on S co-locates K-groups iff S is an ordered
    subsequence of K: equal K-tuples are equal on S (same column order), so
    the value-deterministic routing sends them to one shard.  A superset or
    reordering of K gives no such guarantee and is rejected.
    """
    if part.kind == "rep":
        return True
    if part.kind in ("hash", "range") and part.keys:
        return subsequence_indices(part.keys, keys) is not None
    return False


def grouped(order: Ordering, keys: tuple[str, ...]) -> bool:
    """Are equal ``keys`` tuples contiguous?  True iff keys is an ordering
    prefix (rows sorted by a key prefix have contiguous key groups)."""
    return len(order.keys) >= len(keys) and order.keys[: len(keys)] == keys


# ---------------------------------------------------------------------------
# physical operators
# ---------------------------------------------------------------------------


@dataclass(eq=False)
class POp:
    """Base physical operator.

    ``node`` is the logical node this op realizes (inserted exchanges/sorts
    anchor to their consumer).  ``cap``/``bucket`` are filled by
    :func:`plan_capacities`.
    """

    node: ir.Node
    inputs: tuple[int, ...]         # op ids
    part: Partitioning
    order: Ordering
    dist: str                       # lattice element (axes selection)
    op_id: int = -1                 # assigned by the plan
    cap: int = 0
    bucket: int = 0
    # output schema estimate (name -> np.dtype), filled by annotate_schemas;
    # drives the collective/byte census of the packed exchange.
    schema: dict = field(default_factory=dict)
    # display-only annotations from the sampled statistics pass (core/stats):
    # estimated OUTPUT rows, and a free-text planner note (e.g. which side a
    # cheap-side decision picked).  Never consulted by capacity planning or
    # the census — plans stay byte-identical whether they are set or not.
    rows_est: Optional[float] = None
    note: str = ""

    def short(self) -> str:
        return type(self).__name__


@dataclass(eq=False)
class Source(POp):
    pass


@dataclass(eq=False)
class Compact(POp):
    """Filter backend: predicate + stable compaction (no communication)."""


@dataclass(eq=False)
class Map(POp):
    """Project: evaluate output expressions (no communication)."""


@dataclass(eq=False)
class WindowOp(POp):
    """cumsum / stencil / rank (row-preserving).

    Global: exscan or halo exchange.  Partitioned (``partition_by`` on the
    logical node): collective-free segment kernels over the grouped layout
    the planner establishes upstream (hash exchange + local sort, both
    elided when already provided)."""

    def short(self):
        n = self.node
        if n.partition_by:
            ob = f"; {','.join(n.order_by)}" if n.order_by else ""
            return f"WindowOp({n.kind} over {','.join(n.partition_by)}{ob})"
        return f"WindowOp({n.kind})"


@dataclass(eq=False)
class HashExchange(POp):
    keys: tuple[str, ...] = ()

    def short(self):
        return f"HashExchange({','.join(self.keys)})"


@dataclass(eq=False)
class LocalSort(POp):
    keys: tuple[str, ...] = ()

    def short(self):
        return f"LocalSort({','.join(self.keys)})"


@dataclass(eq=False)
class SaltOp(POp):
    """Skew-salting prologue (adaptive_stats only; docs/adaptive_planning.md).

    Injects a ``__salt__`` column so the ``hot`` heavy-hitter key tuples
    spread over ``R`` sub-partitions of the downstream keys+salt exchange.
    ``build=False`` (probe side): hot rows get salt ``position % R``, others
    salt 0.  ``build=True``: hot rows are replicated to every salt 0..R-1,
    others keep a single salt-0 copy — each (probe row, build row) key match
    then agrees on exactly one salt, so the join result is exactly the
    unsalted one.  The ``hot`` set is a static plan constant shared by both
    sides; a wrong estimate costs balance, never correctness.
    """

    keys: tuple[str, ...] = ()
    hot: tuple[tuple, ...] = ()     # heavy-hitter key VALUE tuples
    R: int = 2
    build: bool = False
    hot_frac: float = 0.0           # est. input fraction that is hot (+margin)

    def short(self):
        side = "build" if self.build else "probe"
        return f"Salt[{side}](R={self.R}, hot={len(self.hot)})"


@dataclass(eq=False)
class MergeJoin(POp):
    """Rank-based merge join of co-partitioned (NOT necessarily sorted)
    inputs; one fused union sort internally (physical.merge_join)."""

    broadcast: bool = False
    # salted: both inputs carry a __salt__ column (SaltOp) — join on
    # keys+salt, strip the salt from the output.
    salted: bool = False

    def short(self):
        n = self.node
        pairs = ",".join(f"{l}=={r}" for l, r in zip(n.left_on, n.right_on))
        tag = ", broadcast" if self.broadcast else ""
        tag += ", salted" if self.salted else ""
        return f"MergeJoin({pairs}{tag})"


@dataclass(eq=False)
class AggPrep(POp):
    """Evaluate aggregation input expressions into __v_* columns and narrow
    to key + value columns (keys keep their names: properties flow through)."""


@dataclass(eq=False)
class PartialAgg(POp):
    """Map-side partial aggregation: reduce local key runs to decomposable
    partial statistics BEFORE the hash exchange, so the wire carries at most
    this shard's distinct key tuples (physical.partial_aggregate)."""

    # adaptive_stats: distinct-group estimate that sizes this op's capacity
    # (and thereby the post-partial exchange bucket) when the user declared
    # no agg_group_cap.  ndv_src records where it came from ("sample" or
    # "realized" — the per-fingerprint feedback store).
    ndv_est: Optional[int] = None
    ndv_src: str = ""

    def short(self):
        tag = (f", ndv~{self.ndv_est} ({self.ndv_src})"
               if self.ndv_est is not None else "")
        return f"PartialAgg(by={','.join(self.node.key)}{tag})"


@dataclass(eq=False)
class SegmentAgg(POp):
    # from_partials: combine PartialAgg statistics (physical.final_aggregate)
    # instead of aggregating raw rows.
    from_partials: bool = False
    # aux-sort elision: name of the nunique agg whose value column rode the
    # planner-inserted LocalSort as a trailing key (skips one lax.sort).
    nunique_ride: Optional[str] = None

    def short(self):
        tag = ", combine" if self.from_partials else ""
        if self.nunique_ride:
            tag += f", nunique_ride={self.nunique_ride}"
        return f"SegmentAgg(by={','.join(self.node.key)}{tag})"


@dataclass(eq=False)
class SampleSort(POp):
    pre_sorted: bool = False        # input already sorted: skip the pre-sort

    def short(self):
        n = self.node
        tag = ", pre_sorted" if self.pre_sorted else ""
        return f"SampleSort({','.join(n.by)}{'' if n.ascending else ' desc'}{tag})"


@dataclass(eq=False)
class LimitOp(POp):
    """First n rows globally: per-shard count clamp off an exclusive scan of
    counts — no data movement, partitioning AND ordering pass through (a
    subset of co-located groups stays co-located; a sorted prefix stays
    sorted)."""

    def short(self):
        return f"Limit({self.node.n})"


@dataclass(eq=False)
class RebalanceOp(POp):
    pass


@dataclass(eq=False)
class ConcatOp(POp):
    pass


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------


def _row_words(schema: dict) -> int:
    """uint32 words one packed row of ``schema`` occupies (physical.col_words)."""
    return sum(col_words(dt) for dt in schema.values())


def _row_bytes_unpacked(schema: dict) -> int:
    """Native bytes per row when each column ships as its own collective."""
    return sum(np.dtype(dt).itemsize for dt in schema.values())


@dataclass
class PhysicalPlan:
    ops: list[POp] = field(default_factory=list)
    op_of: dict[int, int] = field(default_factory=dict)  # logical id -> op id
    root_id: int = -1
    packed: bool = True             # cfg.packed_exchange at plan time
    cfg: Any = None                 # the ExecConfig the plan was built under

    def add(self, op: POp) -> POp:
        op.op_id = len(self.ops)
        self.ops.append(op)
        return op

    @property
    def root_op(self) -> POp:
        return self.ops[self.root_id]

    def final_op(self, node: ir.Node) -> POp:
        return self.ops[self.op_of[node.id]]

    def counts(self) -> dict[str, int]:
        """Data-movement / sort census used by tests, explain and benches."""
        c = {"hash_exchanges": 0, "local_sorts": 0, "sample_sorts": 0,
             "rebalances": 0, "merge_joins": 0, "segment_aggs": 0,
             "partial_aggs": 0, "salt_ops": 0}
        for op in self.ops:
            if isinstance(op, HashExchange):
                c["hash_exchanges"] += 1
            elif isinstance(op, SaltOp):
                c["salt_ops"] += 1
            elif isinstance(op, LocalSort):
                c["local_sorts"] += 1
            elif isinstance(op, SampleSort):
                c["sample_sorts"] += 1
            elif isinstance(op, RebalanceOp):
                c["rebalances"] += 1
            elif isinstance(op, MergeJoin):
                c["merge_joins"] += 1
            elif isinstance(op, PartialAgg):
                c["partial_aggs"] += 1
            elif isinstance(op, SegmentAgg):
                c["segment_aggs"] += 1
        return c

    def shuffle_count(self) -> int:
        """All-to-all communication rounds (hash + range + rebalance)."""
        c = self.counts()
        return c["hash_exchanges"] + c["sample_sorts"] + c["rebalances"]

    # -- collective / byte census (the packed-exchange regression gate) ------

    def _exchange_ops(self) -> list[POp]:
        return [op for op in self.ops
                if isinstance(op, (HashExchange, SampleSort, RebalanceOp))]

    def op_collectives(self, op: POp) -> int:
        """all_to_all collectives ONE exchange issues at P>1: the count
        vector plus either one packed payload or one payload per column."""
        return 2 if self.packed else 1 + len(op.schema)

    def op_row_bytes(self, op: POp) -> int:
        """Wire bytes one row of this exchange costs (packed: 4 bytes per
        uint32 word incl. sub-word padding; unpacked: native itemsizes)."""
        return (_row_words(op.schema) * PACK_WORD_BYTES if self.packed
                else _row_bytes_unpacked(op.schema))

    def collective_count(self) -> int:
        """Total all_to_all collectives the plan issues per execution (P>1).
        A packed plan pays exactly 2 per exchange regardless of width."""
        return sum(self.op_collectives(op) for op in self._exchange_ops())

    def shuffle_row_bytes(self) -> int:
        """Wire bytes ONE row costs summed over every exchange it crosses —
        a shard-count-free volume estimate."""
        return sum(self.op_row_bytes(op) for op in self._exchange_ops())

    def buffer_bytes(self, P: int | None = None) -> int:
        """Total bytes of row buffers the LIVE capacity plan allocates across
        all shards: every op's (cap,) output columns plus each exchange's
        (P, bucket) send staging, at native column widths.

        The retry-quality metric (docs/robustness.md): per-op escalation must
        heal skew with strictly fewer total bytes than global slack-doubling,
        and this is the number tests/test_faults.py compares.
        """
        if P is None:
            mesh = self.cfg.get_mesh()
            P = int(np.prod([mesh.shape[a] for a in self.cfg.axes]))
        total = 0
        for op in self.ops:
            rb = _row_bytes_unpacked(op.schema)
            rows = op.cap + (P * op.bucket if op.bucket else 0)
            total += P * rows * rb
        return total

    def source_rows(self) -> dict[int, int]:
        """Scan id -> VALID row count, read off the Source ops' bound arrays
        (persisted scans: the layout's summed counts, not the padded
        buffer length)."""
        return {op.node.id: scan_rows(op.node)
                for op in self.ops if isinstance(op, Source)}

    def shuffle_census(self, P: int = 8) -> dict:
        """Deterministic collective + byte census at a FIXED shard count.

        Uses a scratch capacity pass at shard count ``P`` (never the live
        device count, so census regression gates stay environment-stable).
        Per exchange: ``collectives`` (all_to_all issued), ``row_bytes``
        (wire cost of one row) and ``payload_bytes`` (the full per-shard
        payload buffer, P * bucket * row_bytes — the count vector's P*4
        bytes are omitted as noise).  Map-side partial aggregation shows up
        as the post-partial exchange carrying ``__p_*`` statistic columns
        with a bucket sized by the (smaller) PartialAgg capacity.
        """
        caps = compute_capacities(self, P, self.cfg, self.source_rows())
        entries = []
        for op in self._exchange_ops():
            rb = self.op_row_bytes(op)
            _cap, bucket = caps[op.op_id]
            entries.append({"op": op.short(), "ncols": len(op.schema),
                            "row_bytes": rb,
                            "collectives": self.op_collectives(op),
                            "payload_bytes": P * bucket * rb})
        return {"P": P, "packed": self.packed,
                "all_to_all": sum(e["collectives"] for e in entries),
                "payload_bytes": sum(e["payload_bytes"] for e in entries),
                "exchanges": entries}

    def render(self) -> str:
        c = self.counts()
        lines = [f"physical plan: {self.shuffle_count()} shuffles "
                 f"({c['hash_exchanges']} hash exchanges, "
                 f"{c['sample_sorts']} sample sorts, "
                 f"{c['rebalances']} rebalances), "
                 f"{c['local_sorts']} local sorts, "
                 f"{c['partial_aggs']} partial aggs; "
                 f"{self.collective_count()} all_to_all "
                 f"({'packed' if self.packed else 'per-column'}), "
                 f"~{self.shuffle_row_bytes()} B/row shuffled"]
        for op in self.ops:
            src = ",".join(f"#{i}" for i in op.inputs)
            cap = f" cap={op.cap}" if op.cap else ""
            bkt = f" bucket={op.bucket}" if op.bucket else ""
            wire = ""
            if isinstance(op, (HashExchange, SampleSort, RebalanceOp)):
                wire = (f" wire={self.op_collectives(op)}coll/"
                        f"{self.op_row_bytes(op)}B-row")
                if op.rows_est is not None:
                    est_b = int(op.rows_est) * self.op_row_bytes(op)
                    wire += f" est~{int(op.rows_est)}r/~{est_b}B"
            note = f"  [{op.note}]" if op.note else ""
            lines.append(
                f"  #{op.op_id} {op.short()}  <- [{src}]  "
                f"part={op.part.short()} order={op.order.short()}"
                f"  [{op.dist}]{cap}{bkt}{wire}{note}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# property transfer helpers
# ---------------------------------------------------------------------------


def _remap_props(part: Partitioning, order: Ordering,
                 passthrough: dict[str, str]) -> tuple[Partitioning, Ordering]:
    """Push properties through a projection.

    ``passthrough`` maps output name -> input column for pure renames.
    Partitioning survives iff EVERY partition key survives (renamed);
    ordering keeps its longest surviving prefix (a dropped middle column
    breaks lexicographic order below it).
    """
    inv: dict[str, str] = {}
    for out_name, in_name in passthrough.items():
        inv.setdefault(in_name, out_name)
    new_part = part
    if part.kind in ("hash", "range"):
        if all(k in inv for k in part.keys):
            new_part = Partitioning(part.kind,
                                    tuple(inv[k] for k in part.keys),
                                    part.ascending)
        else:
            new_part = BLOCK
    prefix: list[str] = []
    for k in order.keys:
        if k not in inv:
            break
        prefix.append(inv[k])
    new_order = Ordering(tuple(prefix), order.ascending) if prefix else UNORDERED
    return new_part, new_order


def _restrict_props(part: Partitioning, order: Ordering,
                    surviving: set[str]) -> tuple[Partitioning, Ordering]:
    """Properties after dropping every column not in ``surviving``."""
    return _remap_props(part, order, {c: c for c in surviving})


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------


def plan_physical(root: ir.Node, dists: dict[int, str], cfg,
                  stats=None) -> PhysicalPlan:
    """Walk the distribution-annotated logical plan; insert exchanges and
    sorts only where a required property is not provided.

    ``cfg`` is an ExecConfig (broadcast_join / elide_exchanges /
    partial_agg / packed_exchange are read).  With ``elide_exchanges=False``
    provided properties are ignored and every Join/Aggregate/Sort pays its
    full exchange+sort — the pre-elision baseline, kept as an A/B lever for
    benchmarks.  With ``partial_agg=True`` (default) an aggregate whose
    exchange survives and whose agg fns are all decomposable splits into
    PartialAgg -> HashExchange -> LocalSort -> SegmentAgg(combine), so each
    shard ships at most its distinct local key groups.

    ``stats`` is an optional :class:`core.stats.StatsContext`.  When passed
    it always ANNOTATES (per-op ``rows_est`` estimates for explain), but it
    only changes planner DECISIONS — salted joins, cheaper-side
    re-exchange, PartialAgg ndv sizing — under ``cfg.adaptive_stats``, so a
    plan built with adaptive off is structurally byte-identical with or
    without a stats context (docs/adaptive_planning.md).
    """
    plan = PhysicalPlan(packed=getattr(cfg, "packed_exchange", True), cfg=cfg)
    elide = getattr(cfg, "elide_exchanges", True)
    partial_agg = getattr(cfg, "partial_agg", True)
    adaptive = stats is not None and getattr(cfg, "adaptive_stats", False)

    # Live shard count, resolved lazily: persisted-scan hash/range claims are
    # only valid at the shard count they were produced under (routing is
    # hash % P / data-dependent splitters), so property seeding gates on it.
    _P_live: list = []

    def live_shards() -> int:
        if not _P_live:
            mesh = cfg.get_mesh()
            _P_live.append(int(np.prod([mesh.shape[a] for a in cfg.axes])))
        return _P_live[0]

    def emit(cls, node, inputs, part, order, **kw) -> POp:
        d = dists[node.id]
        op = plan.add(cls(node=node, inputs=tuple(i.op_id for i in inputs),
                          part=part, order=order, dist=d, **kw))
        if stats is not None:
            op.rows_est = stats.rows_est.get(node.id)
        return op

    def hash_exchange(node, src: POp, keys: tuple[str, ...]) -> POp:
        op = emit(HashExchange, node, (src,), Partitioning("hash", keys),
                  UNORDERED, keys=keys)
        op.rows_est = src.rows_est      # an exchange moves its INPUT's rows
        return op

    def local_sort(node, src: POp, keys: tuple[str, ...]) -> POp:
        op = emit(LocalSort, node, (src,), src.part, Ordering(keys, True),
                  keys=keys)
        op.rows_est = src.rows_est
        return op

    def _est_shuffle_bytes(node: ir.Node) -> Optional[float]:
        """Estimated wire bytes of re-exchanging ``node``'s output: rows
        estimate x packed row width (mirrors shuffle_row_bytes)."""
        rows = stats.rows_est.get(node.id) if stats is not None else None
        if rows is None:
            return None
        return rows * _row_words(node.schema) * PACK_WORD_BYTES

    for n in ir.topo_order(root):
        if isinstance(n, ir.Scan):
            # lattice -> property seed: REP tables are whole on every shard
            # (satisfying every co-location requirement for free); 1D
            # elements place rows positionally — no key co-location.  A
            # PERSISTED scan (df.persist()) instead seeds the partitioning
            # and ordering its producing plan materialized, so downstream
            # groupby/merge/over/sort on the persisted keys start elided —
            # the repeated-query payoff.  Hash/range claims need the same
            # shard count they were produced under; ordering-only claims
            # (and REP re-entry) don't depend on routing.
            part = REPL if dists[n.id] == D.REP else BLOCK
            order = UNORDERED
            lay = n.layout
            if lay is not None and elide:
                dev = lay.device_valid(live_shards())
                if part.kind != "rep" and dev:
                    if lay.kind == "hash" and lay.partitioned_by:
                        part = Partitioning("hash", lay.partitioned_by)
                    elif lay.kind == "range" and lay.partitioned_by:
                        part = Partitioning("range", lay.partitioned_by,
                                            lay.ascending)
                    elif (lay.kind == "block" and lay.globally_sorted
                          and lay.sorted_by):
                        part = Partitioning("block", (), lay.order_ascending,
                                            globally_sorted=True)
                # Ordering claims hold only where the re-entry path preserves
                # per-shard order: the direct device path (dev, non-REP), or
                # a host-persisted table (counts is None — its rows ARE the
                # ordered valid prefix, whether replicated or block-split).
                # A device layout forced to REP (or at a foreign shard
                # count) re-enters via gather_host, whose shard-order concat
                # is NOT sorted — no claim there.
                host_ordered = lay.counts is None
                if lay.sorted_by and (host_ordered
                                      or (dev and part.kind != "rep")):
                    order = Ordering(lay.sorted_by, lay.order_ascending)
            op = emit(Source, n, (), part, order)

        elif isinstance(n, ir.Filter):
            c = plan.final_op(n.child)
            op = emit(Compact, n, (c,), c.part, c.order)

        elif isinstance(n, ir.Project):
            c = plan.final_op(n.child)
            part, order = _remap_props(c.part, c.order, n.passthrough())
            op = emit(Map, n, (c,), part, order)

        elif isinstance(n, ir.Window):
            c = plan.final_op(n.child)
            if n.partition_by:
                # Partitioned window: require hash(partition_by) co-location
                # plus (partition_by, order_by) ascending grouping; insert
                # the exchange/sort only where the input doesn't already
                # provide them.  join -> window over the join keys therefore
                # plans ZERO extra shuffles, and aggregate -> window on the
                # same keys reuses the grouped layout entirely.
                src = c
                if dists[n.id] != D.REP and \
                        not (elide and colocates(src.part, n.partition_by)):
                    src = hash_exchange(n, src, n.partition_by)
                skeys = n.sort_keys()
                if not (elide and grouped(src.order, skeys)
                        and src.order.ascending):
                    src = local_sort(n, src, skeys)
                part, order = src.part, src.order
            else:
                # global window: row-preserving pass-through.  Global RANK
                # kinds additionally need equal order-key tuples adjacent
                # across the WHOLE stream (a tie straddling a shard boundary
                # would rank wrong): provided by REP, by key co-location
                # (hash/range on an order-key subsequence), or by a
                # globally-sorted block layout.  api.rank inserts the Sort
                # that guarantees it (a full no-op on already-sorted
                # inputs), so this is a plan invariant, not a user surface.
                part, order = c.part, c.order
                src = c
                if n.kind in ("rank", "dense_rank") and n.order_by:
                    adjacent = (grouped(c.order, n.order_by)
                                and (dists[n.id] == D.REP
                                     or colocates(c.part, n.order_by)
                                     or (c.part.kind == "block"
                                         and c.part.globally_sorted)))
                    if not adjacent:
                        raise ValueError(
                            f"global {n.kind} requires equal "
                            f"{n.order_by} tuples adjacent across shards: "
                            "sort(by=order_by) first (api.rank does)")
            # adds column n.out (may shadow an existing one)
            if n.out in part.keys:
                part = BLOCK
            if n.out in order.keys:
                order = Ordering(order.keys[: order.keys.index(n.out)],
                                 order.ascending)
            op = emit(WindowOp, n, (src,), part, order)

        elif isinstance(n, ir.Limit):
            c = plan.final_op(n.child)
            op = emit(LimitOp, n, (c,), c.part, c.order)

        elif isinstance(n, ir.Rebalance):
            c = plan.final_op(n.child)
            # Positional exchange: key co-location is lost (an equal-key run
            # may now straddle a shard boundary, so even a range input can't
            # keep its partitioning).  Ordering is another story: rebalance
            # preserves the GLOBAL concatenated row order, so when the input
            # was globally sorted — range-partitioned with the range keys
            # and local ordering agreeing prefix-wise, or an already
            # globally-sorted block stream — every output shard receives a
            # contiguous slice of a sorted sequence and stays locally
            # sorted.  Per-shard-only ordering (e.g. hash + sort) does NOT
            # survive: a shard may receive [tail of s0, head of s1].  A
            # preserved ordering additionally marks the output partitioning
            # ``globally_sorted``: shard boundaries still follow the global
            # order, so a downstream Sort on an ordering prefix is a full
            # no-op (no splitter routing).
            order = UNORDERED
            part = BLOCK
            range_sorted = (c.part.kind == "range"
                            and c.part.ascending == c.order.ascending and (
                                c.part.keys == c.order.keys[: len(c.part.keys)]
                                or c.order.keys == c.part.keys[: len(c.order.keys)]))
            block_sorted = c.part.kind == "block" and c.part.globally_sorted
            if elide and c.order.keys and (range_sorted or block_sorted):
                order = c.order
                part = Partitioning("block", (), order.ascending,
                                    globally_sorted=True)
            op = emit(RebalanceOp, n, (c,), part, order)

        elif isinstance(n, ir.Concat):
            parts = [plan.final_op(p) for p in n.parts]
            if all(p.part.kind == "rep" for p in parts):
                part = REPL
            elif (all(p.part.kind == "hash" for p in parts)
                  and len({p.part.keys for p in parts}) == 1):
                part = parts[0].part    # same hash fn everywhere: still aligned
            else:
                part = BLOCK
            op = emit(ConcatOp, n, tuple(parts), part, UNORDERED)

        elif isinstance(n, ir.Sort):
            c = plan.final_op(n.child)
            sorted_already = (elide and grouped(c.order, n.by)
                              and c.order.ascending == n.ascending)
            # globally sorted iff locally sorted AND shard ranges follow the
            # requested keys: range keys a prefix of `by` (ties of the range
            # tuple co-locate; minor keys order locally) or `by` a prefix of
            # the range keys (lexicographic order implies order on any key
            # prefix, and eliding preserves the stable tie order a re-sort
            # would produce).  Shard-range DIRECTION must agree too: an
            # ascending local order over descending shard ranges (e.g. a
            # planner-inserted ascending LocalSort downstream of a
            # descending sample sort) is not globally sorted.
            range_ok = c.part.kind == "range" \
                and c.part.ascending == n.ascending and (
                    c.part.keys == n.by[: len(c.part.keys)]
                    or n.by == c.part.keys[: len(n.by)])
            # a globally-sorted block stream (rebalanced sorted data) is
            # sorted by any prefix of its ordering keys; ``sorted_already``
            # checks exactly that prefix + direction, so the flag alone
            # upgrades the local check to a global one.
            block_ok = c.part.kind == "block" and c.part.globally_sorted
            globally_sorted = sorted_already and (c.part.kind == "rep"
                                                  or range_ok or block_ok)
            if globally_sorted:
                plan.op_of[n.id] = c.op_id      # full no-op: reuse child
                op = c
            else:
                pre = (elide and grouped(c.order, n.by) and c.order.ascending)
                op = emit(SampleSort, n, (c,),
                          Partitioning("range", n.by, n.ascending),
                          Ordering(n.by, n.ascending), pre_sorted=pre)

        elif isinstance(n, ir.Repartition):
            # Pure layout request: the node itself computes nothing, it just
            # demands properties — hash(by) co-location and/or sort_by
            # per-shard ordering — and the usual insertion rules pay only
            # for what the input doesn't already provide.  Fully provided
            # layout => complete no-op (reuse the child op), so a redundant
            # repartition costs nothing.
            c = plan.final_op(n.child)
            src = c
            if n.by and dists[n.id] != D.REP and \
                    not (elide and colocates(src.part, n.by)):
                src = hash_exchange(n, src, n.by)
            if n.sort_by and not (elide and grouped(src.order, n.sort_by)
                                  and src.order.ascending):
                src = local_sort(n, src, n.sort_by)
            op = src

        elif isinstance(n, ir.Join):
            l, r = plan.final_op(n.left), plan.final_op(n.right)
            broadcast = dists[n.right.id] == D.REP and cfg.broadcast_join
            rep_join = dists[n.id] == D.REP and not broadcast
            salted = False
            if not broadcast and not rep_join:
                il = _hash_alignment(l.part, n.left_on) if elide else None
                ir_ = _hash_alignment(r.part, n.right_on) if elide else None
                # --- adaptive: salted skew join (docs/adaptive_planning.md).
                # Heavy-hitter probe keys spread over R keys+salt
                # sub-partitions; the build side replicates its hot rows
                # R-ways so every (probe, build) match agrees on exactly one
                # salt.  Free when both sides pay an exchange anyway; when
                # only the build side is pre-aligned we salt iff its
                # estimated re-exchange bytes are below the probe side's.
                # Never when the PROBE side is aligned — salting would
                # forfeit that elision.
                hot: tuple = ()
                R = int(getattr(cfg, "salt_factor", 8))
                if adaptive and R > 1:
                    thr = float(getattr(cfg, "salt_threshold", 0.1))
                    # realized skew from a previous run of this plan, OR
                    # skew a REGISTERED table's persisted ScanLayout counts
                    # show for free (hash-partitioned on the join keys: the
                    # shard occupancy IS the key distribution — no
                    # re-sampling pass; docs/serving.md): salt more eagerly.
                    if stats.skewed_before(n) or stats.layout_skewed(
                            n.left, n.left_on):
                        thr /= 2.0
                    hot = stats.hot_keys(n.left, n.left_on, thr)
                if hot:
                    lb = _est_shuffle_bytes(n.left)
                    rb = _est_shuffle_bytes(n.right)
                    salted = (il is None and ir_ is None) or (
                        il is None and ir_ is not None
                        and lb is not None and rb is not None and rb <= lb)
                if salted:
                    hf = stats.hot_fraction(n.right, n.right_on, hot)
                    vals = tuple(k for k, _f in hot)
                    sp = emit(SaltOp, n, (l,), l.part, l.order,
                              keys=n.left_on, hot=vals, R=R, build=False)
                    sp.rows_est = l.rows_est
                    l = hash_exchange(n, sp, n.left_on + (SALT_COL,))
                    sb = emit(SaltOp, n, (r,), r.part, r.order,
                              keys=n.right_on, hot=vals, R=R, build=True,
                              hot_frac=1.0 if hf is None else hf)
                    sb.rows_est = r.rows_est
                    r = hash_exchange(n, sb, n.right_on + (SALT_COL,))
                    # salt is stripped post-join, so a full-key group may
                    # straddle shards: the output provides NO co-location.
                    part = BLOCK
                elif il is not None and il == ir_:
                    idx = il
                    part = Partitioning("hash",
                                        tuple(n.left_on[i] for i in idx))
                elif il is not None and ir_ is not None and adaptive:
                    # both sides aligned on DIFFERENT key subsequences: one
                    # must re-hash.  The static rule keeps the left; stats
                    # pick whichever side ships fewer estimated bytes.
                    lb = _est_shuffle_bytes(n.left)
                    rb = _est_shuffle_bytes(n.right)
                    if lb is not None and rb is not None and lb < rb:
                        idx = ir_
                        l = hash_exchange(n, l,
                                          tuple(n.left_on[i] for i in idx))
                        l.note = (f"cheap side: re-hash left "
                                  f"~{int(lb)}B < ~{int(rb)}B")
                    else:
                        idx = il
                        r = hash_exchange(n, r,
                                          tuple(n.right_on[i] for i in idx))
                        if lb is not None and rb is not None:
                            r.note = (f"cheap side: re-hash right "
                                      f"~{int(rb)}B <= ~{int(lb)}B")
                    part = Partitioning("hash",
                                        tuple(n.left_on[i] for i in idx))
                elif il is not None:
                    idx = il
                    r = hash_exchange(n, r, tuple(n.right_on[i] for i in idx))
                    part = Partitioning("hash",
                                        tuple(n.left_on[i] for i in idx))
                elif ir_ is not None:
                    idx = ir_
                    l = hash_exchange(n, l, tuple(n.left_on[i] for i in idx))
                    part = Partitioning("hash",
                                        tuple(n.left_on[i] for i in idx))
                else:
                    l = hash_exchange(n, l, n.left_on)
                    r = hash_exchange(n, r, n.right_on)
                    part = Partitioning("hash", n.left_on)
            else:
                part = l.part
            # output rows follow left row order (each left row repeated per
            # match), so the left ordering survives verbatim.
            op = emit(MergeJoin, n, (l, r), part, l.order,
                      broadcast=broadcast, salted=salted)

        elif isinstance(n, ir.Aggregate):
            c = plan.final_op(n.child)
            part, order = _restrict_props(c.part, c.order, set(n.key))
            prep = emit(AggPrep, n, (c,), part, order)
            src: POp = prep
            # REP aggregates never exchange (each shard aggregates the whole
            # table) — independent of elision, like the join/sort rep guards.
            needs_exchange = dists[n.id] != D.REP and \
                not (elide and colocates(src.part, n.key))
            ch_schema = n.child.schema
            decomp = all(decomposable(a.fn, a.skipna,
                                      nulltag_for(a.expr, ch_schema))
                         for a in n.aggs.values())
            if needs_exchange and decomp and partial_agg:
                # Map-side partial aggregation: pre-reduce local key runs so
                # the exchange ships at most this shard's DISTINCT key
                # tuples.  A pre-partitioned input (needs_exchange False)
                # skips the partial stage entirely — the elision rules and
                # this rewrite compose rather than stack.
                if not (elide and grouped(src.order, n.key)
                        and src.order.ascending):
                    src = local_sort(n, src, n.key)
                # adaptive: size the partial-agg buckets (and thereby the
                # post-partial exchange) from a distinct-group estimate —
                # realized feedback from a previous run of this exact plan
                # wins over the sampled estimate.  Only consulted by
                # compute_capacities when the user declared no agg_group_cap.
                nd, nsrc = None, ""
                if adaptive:
                    rl = stats.realized(n)
                    if rl is not None:
                        nd, nsrc = int(rl["rows"]), "realized"
                    else:
                        d = stats.ndv_cap(n.child, n.key)
                        if d is not None:
                            nd, nsrc = int(d), "sample"
                src = emit(PartialAgg, n, (src,), src.part,
                           Ordering(n.key, True), ndv_est=nd, ndv_src=nsrc)
                src = hash_exchange(n, src, n.key)
                src = local_sort(n, src, n.key)
                op = emit(SegmentAgg, n, (src,), src.part,
                          Ordering(n.key, True), from_partials=True)
            else:
                if needs_exchange:
                    src = hash_exchange(n, src, n.key)
                nu_names = [name for name, a in n.aggs.items()
                            if a.fn == "nunique"]
                has_first = any(a.fn == "first" for a in n.aggs.values())
                pre_grouped = (elide and grouped(src.order, n.key)
                               and (src.order.ascending or not nu_names))
                ride = None
                if not pre_grouped:
                    skeys = n.key
                    if nu_names and not has_first:
                        # aux-sort elision: the FIRST nunique column rides
                        # this LocalSort as a trailing key, so
                        # segment_aggregate skips its own lax.sort for it.
                        # ("first" pins the in-group arrival order, which a
                        # trailing value key would scramble — no ride then.)
                        ride = nu_names[0]
                        skeys = n.key + ("__v_" + ride,)
                    src = local_sort(n, src, skeys)
                op = emit(SegmentAgg, n, (src,), src.part,
                          Ordering(n.key, src.order.ascending),
                          nunique_ride=ride)

        else:
            raise TypeError(n)

        plan.op_of[n.id] = op.op_id

    plan.root_id = plan.op_of[root.id]
    annotate_schemas(plan)
    return plan


def annotate_schemas(plan: PhysicalPlan) -> None:
    """Fill every op's output ``schema`` estimate (name -> np.dtype).

    One forward pass (ops are emitted in topo order): inserted exchanges and
    sorts pass their input schema through; AggPrep narrows to keys + __v_*
    value columns (dtype via expr.infer_dtype over the child schema — same
    inference ir.Project uses); PartialAgg replaces values with the
    decomposed __p_* statistics.  The estimates drive the collective/byte
    census of the packed exchange.
    """
    f32 = np.dtype(np.float32)
    i32 = np.dtype(np.int32)
    for op in plan.ops:
        n = op.node
        if isinstance(op, (HashExchange, LocalSort)):
            op.schema = dict(plan.ops[op.inputs[0]].schema)
        elif isinstance(op, SaltOp):
            op.schema = dict(plan.ops[op.inputs[0]].schema)
            op.schema[SALT_COL] = i32
        elif isinstance(op, AggPrep):
            base = plan.ops[op.inputs[0]].schema
            sch = {k: base.get(k, f32) for k in n.key}
            for name, agg in n.aggs.items():
                if agg.expr is None:
                    dt = i32            # bare count rides a zeros placeholder
                else:
                    dt = np.dtype(infer_dtype(agg.expr, base))
                sch["__v_" + name] = dt
            op.schema = sch
        elif isinstance(op, PartialAgg):
            # wire schema straight off the decomposition table — the same
            # single source of truth partial_decompose/final_aggregate use.
            base = plan.ops[op.inputs[0]].schema
            sch = {k: base.get(k, f32) for k in n.key}
            for name, agg in n.aggs.items():
                vd = np.dtype(base.get("__v_" + name, f32))
                for spec in AGG_DECOMP[agg.fn][0]:
                    sch[f"__p_{name}__{spec.suffix}"] = spec.dtype(vd)
            op.schema = sch
        else:
            op.schema = {k: np.dtype(dt) for k, dt in n.schema.items()}


def _hash_alignment(part: Partitioning,
                    on: tuple[str, ...]) -> Optional[tuple[int, ...]]:
    """If ``part`` is hash partitioning on a subsequence of the join keys,
    return the key-position indices it covers (the other side can then be
    exchanged on ITS columns at the same positions and the two sides align,
    because the combined hash is value-deterministic).  Else None."""
    if part.kind != "hash" or not part.keys:
        return None
    return subsequence_indices(part.keys, on)


# ---------------------------------------------------------------------------
# capacity planning (moved from lower.py; operates on physical ops)
# ---------------------------------------------------------------------------


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def scan_rows(n: ir.Scan) -> int:
    """Valid rows of a Scan: persisted device layouts count their valid
    prefixes (the columns are padded ``(nshards * capacity,)`` buffers)."""
    if n.layout is not None and n.layout.counts is not None:
        return n.layout.rows()
    return len(next(iter(n.columns.values())))


def compute_capacities(plan: PhysicalPlan, P: int, cfg,
                       source_rows: dict[int, int]) -> dict[int, tuple[int, int]]:
    """Capacity plan as a pure map op_id -> (cap, bucket) — shared by
    :func:`plan_capacities` (which writes the live fields) and the
    shuffle-byte census (which probes a FIXED P without touching them).

    Exchanges get (src,dst) bucket capacities and a post-exchange capacity;
    pass-through ops inherit their input capacity.  An elided exchange means
    the consumer keeps the local capacity — smaller buffers, not just fewer
    collectives.  Policy matches the original lower.py planner: "safe" bounds
    every buffer by the worst case; otherwise capacities are input*slack and
    overflow is flagged (driver retry, DESIGN.md §2).  A PartialAgg holds at
    most its input rows, and ``cfg.agg_group_cap`` (a user bound on distinct
    groups per shard) tightens it further — shrinking the bucket of the
    post-partial exchange, not just its row count.
    """
    safe = getattr(cfg, "safe_capacities", True)
    slack = getattr(cfg, "shuffle_slack", 2.0)
    join_exp = getattr(cfg, "join_expansion", 1.5)
    group_cap = getattr(cfg, "agg_group_cap", None)
    # per-op capacity overrides (runtime/retry.py escalation): op_id ->
    # (cap, bucket) FLOORS applied after the normal rule, so a retry grows
    # exactly the overflowed site and downstream ops inherit the growth
    # through this forward pass — no global slack-doubling.
    overrides = getattr(cfg, "cap_overrides", None) or {}
    caps: dict[int, tuple[int, int]] = {}

    def shuffle_plan(cap_in: int) -> tuple[int, int]:
        if safe:
            bucket = cap_in                 # worst case: all rows to one shard
            out = P * bucket
        else:
            bucket = max(32, _ceil_div(int(cap_in * slack), P))
            out = max(32, int(cap_in * slack))
        return bucket, out

    for op in plan.ops:
        ins = [caps[i] for i in op.inputs]
        cap, bucket = 0, 0
        if isinstance(op, Source):
            lay = op.node.layout
            # device shards only re-enter at their own capacity when the
            # runtime takes the device path (lower.dev_scans): matching
            # shard count AND a non-REP distribution — a force-replicated
            # persisted frame gathers to the host and re-pads per REP rules.
            if lay is not None and lay.device_valid(P) and op.dist != D.REP:
                cap = int(lay.capacity)
            else:
                rows = source_rows[op.node.id]
                cap = rows if op.dist == D.REP else max(1, _ceil_div(rows, P))
        elif isinstance(op, LimitOp):
            cap = max(1, min(ins[0][0], op.node.n))
        elif isinstance(op, (HashExchange, SampleSort)):
            bucket, cap = shuffle_plan(ins[0][0])
        elif isinstance(op, MergeJoin):
            lcap, rcap = ins[0][0], ins[1][0]
            cap = max(1, int(max(join_exp, 1.0) * (lcap + rcap)))
        elif isinstance(op, ConcatOp):
            cap = sum(i[0] for i in ins)
        elif isinstance(op, RebalanceOp):
            bucket = ins[0][0]
            cap = ins[0][0]
        elif isinstance(op, SaltOp):
            cap = ins[0][0]
            if op.build:
                # hot build rows gain R-1 replicas.  Safe mode bounds by the
                # all-hot worst case; otherwise size replicas off the
                # estimated hot fraction (overflow-retry backstops a lie).
                if safe:
                    cap = max(1, op.R * cap)
                else:
                    extra = max(32, int(np.ceil(cap * op.hot_frac * slack)))
                    cap = cap + (op.R - 1) * min(extra, cap)
        elif isinstance(op, PartialAgg):
            cap = ins[0][0]
            if group_cap is not None:
                cap = max(1, min(cap, int(group_cap)))
            elif op.ndv_est is not None:
                # adaptive auto-cap: local distinct groups never exceed the
                # GLOBAL group count, so realized feedback is an exact bound;
                # a sampled estimate gets stats_cap_slack headroom (the
                # overflow-retry loop widens it further if the sample lied).
                slk = getattr(cfg, "stats_cap_slack", 2.0)
                est = (int(op.ndv_est) if op.ndv_src == "realized"
                       else int(np.ceil(op.ndv_est * slk)))
                cap = max(1, min(cap, max(64, est)))
        else:   # Compact / Map / WindowOp / AggPrep / LocalSort / SegmentAgg
            cap = ins[0][0]
        if op.op_id in overrides:
            o_cap, o_bucket = overrides[op.op_id]
            cap = max(cap, int(o_cap))
            if bucket:
                bucket = max(bucket, int(o_bucket))
        caps[op.op_id] = (cap, bucket)
    return caps


def plan_capacities(plan: PhysicalPlan, P: int, cfg,
                    source_rows: dict[int, int]) -> None:
    """Fill ``cap``/``bucket`` on every op (see :func:`compute_capacities`)."""
    for op_id, (cap, bucket) in compute_capacities(plan, P, cfg,
                                                   source_rows).items():
        plan.ops[op_id].cap = cap
        plan.ops[op_id].bucket = bucket

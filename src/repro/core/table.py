"""DTable — the runtime carrier of a distributed data frame.

The paper's 1D_VAR distribution ("variable-length chunks per rank") is carried
on TPU as **static per-shard capacity + dynamic valid-prefix counts**: every
column is a dense array of global shape ``(P * capacity,)`` sharded by rows
over the data axes, plus a ``(P,)`` count vector.  Rows ``[count, capacity)``
of each shard are padding.  1D_BLOCK is the special case where every count
equals the block size (last shard possibly partial).

Columns are ordinary ``jax.Array``s — the paper's dual representation: any
column can flow into arbitrary array computation, and any array can become a
column.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import numpy as np

from . import distribution as D

# Logical dtypes live in core/dtypes.py; re-exported here because ingest
# coercion (dictionary encode, null promotion) is part of the table contract.
from .dtypes import (  # noqa: F401
    CODE_DTYPE, NULL_CODE, DType, as_nullable, categories_of, coerce_column,
    dict_decode, dict_encode, is_category, is_nullable, physical_dtype,
    recode_map, union_categories,
)

@dataclass(eq=False)
class DTable:
    """A materialized distributed table."""

    columns: dict[str, jax.Array]   # each of global shape (P * capacity,)
    counts: jax.Array               # (P,) int32 valid rows per shard
    capacity: int                   # per-shard row capacity
    nshards: int
    dist: str = D.ONE_D             # lattice element this table satisfies
    overflow: Any = None            # bool; True => some capacity site overflowed
    # per-op failure attribution (docs/robustness.md): physical-plan op id ->
    # {"kind", "op", "cap", "bucket", "cap_req", "bucket_req", "strategy"}
    # for every capacity site whose flag fired.  Empty dict on a clean run.
    overflow_ops: dict = None       # type: ignore[assignment]
    # ExecConfig.validate check results: tuple of errors.InvariantFailure.
    invariant_failures: tuple = ()
    # retry/degradation events (runtime/retry.RetryEvent) from the policy
    # that produced this table — the collect report.
    events: tuple = ()

    def __post_init__(self):
        if self.overflow_ops is None:
            self.overflow_ops = {}

    @property
    def schema(self) -> dict[str, np.dtype]:
        return {k: np.dtype(v.dtype) for k, v in self.columns.items()}

    def num_rows(self) -> int:
        counts = np.asarray(self.counts)
        if self.dist == D.REP:           # every shard holds the full table
            return int(counts[0])
        return int(np.sum(counts))

    def to_numpy(self) -> dict[str, np.ndarray]:
        """Gather valid rows to host (drops padding)."""
        counts = np.asarray(self.counts)
        shards = 1 if self.dist == D.REP else self.nshards
        out: dict[str, np.ndarray] = {}
        for name, col in self.columns.items():
            a = np.asarray(col).reshape(self.nshards, self.capacity)
            out[name] = np.concatenate(
                [a[r, : counts[r]] for r in range(shards)]) if shards else a[:0]
        return out

    def column(self, name: str) -> jax.Array:
        """The raw padded column array (1D_BLOCK tables: padding only on the
        last shard) — for tight integration with array code."""
        return self.columns[name]

    def __repr__(self):
        cols = ", ".join(f"{k}:{v.dtype}" for k, v in self.columns.items())
        return (f"DTable[{self.dist}] P={self.nshards} cap={self.capacity} "
                f"rows={self.num_rows()} ({cols})")


def pad_to(arr: np.ndarray, n: int) -> np.ndarray:
    """Pad a host array with zeros to length n."""
    if arr.shape[0] == n:
        return arr
    out = np.zeros((n,) + arr.shape[1:], dtype=arr.dtype)
    out[: arr.shape[0]] = arr
    return out


def block_counts(total_rows: int, nshards: int, capacity: int) -> np.ndarray:
    """Valid counts for a 1D_BLOCK layout of ``total_rows``."""
    c = np.clip(total_rows - np.arange(nshards) * capacity, 0, capacity)
    return c.astype(np.int32)

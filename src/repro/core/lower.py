"""Lowering: optimized logical plan -> ONE jitted SPMD program.

This is where the paper's end-to-end claim is realized: the entire plan —
relational operators, window analytics, UDFs and free array computation —
executes inside a single ``jax.shard_map`` region under a single ``jax.jit``,
so XLA fuses across relational boundaries exactly as CGen+icc fused the
generated C++.  There is no runtime scheduler and no master (paper §2.2).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import distribution as D
from . import ir, physical as phys
from .compat import shard_map as _compat_shard_map
from .expr import ExternalArray, evaluate
from .table import DTable, block_counts, pad_to


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclass
class ExecConfig:
    """Execution configuration (capacity planning + physical choices)."""

    mesh: Any = None                  # jax Mesh; default: all local devices, axis "data"
    axes: tuple[str, ...] = ("data",)
    # capacity policy: "safe" bounds every buffer by the worst case (tests);
    # otherwise capacities are input_cap * slack and overflow is flagged.
    safe_capacities: bool = True
    shuffle_slack: float = 2.0
    join_expansion: float = 1.5
    # physical choices (§Perf levers)
    exscan_method: str = "allgather"  # or "ladder"
    broadcast_join: bool = True       # beyond-paper: REP side joins without shuffle
    use_kernels: bool = False         # route hot loops through Pallas kernels
    optimize_plan: bool = True
    # capacity-overflow auto-retry (runtime/ft.py semantics, built into
    # collect): replan with doubled expansion, at most this many times.
    auto_retry: int = 3

    def get_mesh(self) -> Mesh:
        if self.mesh is not None:
            return self.mesh
        devs = np.array(jax.devices())
        return Mesh(devs.reshape((len(devs),)), ("data",))


# ---------------------------------------------------------------------------
# capacity planner
# ---------------------------------------------------------------------------


@dataclass
class NodePlan:
    cap: int                          # per-shard row capacity of the output
    shuffle_bucket: int = 0           # per-(src,dst) bucket capacity, if shuffles
    shuffle_cap: int = 0              # post-shuffle capacity, if shuffles


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def plan_capacities(order: list[ir.Node], dists: dict[int, str], P_: int,
                    cfg: ExecConfig, source_rows: dict[int, int]) -> dict[int, NodePlan]:
    plans: dict[int, NodePlan] = {}

    def shuffle_plan(cap_in: int, global_rows: int) -> tuple[int, int]:
        if cfg.safe_capacities:
            bucket = cap_in
            out = min(global_rows, P_ * bucket)
        else:
            bucket = max(32, _ceil_div(int(cap_in * cfg.shuffle_slack), P_))
            out = max(32, int(cap_in * cfg.shuffle_slack))
        return bucket, out

    for n in order:
        if isinstance(n, ir.Scan):
            rows = source_rows[n.id]
            cap = rows if dists[n.id] == D.REP else max(1, _ceil_div(rows, P_))
            plans[n.id] = NodePlan(cap=cap)
        elif isinstance(n, (ir.Filter, ir.Project, ir.Window)):
            plans[n.id] = NodePlan(cap=plans[n.child.id].cap)
        elif isinstance(n, ir.Join):
            lcap, rcap = plans[n.left.id].cap, plans[n.right.id].cap
            lb, lo = shuffle_plan(lcap, lcap * P_)
            rb, ro = shuffle_plan(rcap, rcap * P_)
            if dists[n.right.id] == D.REP and cfg.broadcast_join:
                lo, ro = lcap, rcap             # no shuffle at all
                lb = rb = 0
            out = int(max(cfg.join_expansion, 1.0) * (lo + ro))
            plans[n.id] = NodePlan(cap=max(out, 1), shuffle_bucket=max(lb, rb),
                                   shuffle_cap=max(lo, ro))
            plans[(n.id, "l")] = NodePlan(cap=lo, shuffle_bucket=lb)   # type: ignore
            plans[(n.id, "r")] = NodePlan(cap=ro, shuffle_bucket=rb)   # type: ignore
        elif isinstance(n, ir.Aggregate):
            ccap = plans[n.child.id].cap
            b, o = shuffle_plan(ccap, ccap * P_)
            plans[n.id] = NodePlan(cap=o, shuffle_bucket=b, shuffle_cap=o)
        elif isinstance(n, ir.Concat):
            plans[n.id] = NodePlan(cap=sum(plans[c.id].cap for c in n.parts))
        elif isinstance(n, ir.Rebalance):
            ccap = plans[n.child.id].cap
            plans[n.id] = NodePlan(cap=ccap, shuffle_bucket=ccap, shuffle_cap=ccap)
        elif isinstance(n, ir.Sort):
            ccap = plans[n.child.id].cap
            b, o = shuffle_plan(ccap, ccap * P_)
            plans[n.id] = NodePlan(cap=o, shuffle_bucket=b, shuffle_cap=o)
        else:
            raise TypeError(n)
    return plans


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------


class Lowered:
    """A compiled plan: callable on (possibly fresh) source arrays."""

    def __init__(self, root: ir.Node, cfg: ExecConfig, dists: dict[int, str],
                 plans: dict[int, NodePlan], kernels: dict | None = None):
        self.root = root
        self.cfg = cfg
        self.dists = dists
        self.plans = plans
        self.kernels = kernels or {}
        self.mesh = cfg.get_mesh()
        self.P = int(np.prod([self.mesh.shape[a] for a in cfg.axes]))
        self._build()

    # -- input marshalling ---------------------------------------------------

    def _gather_inputs(self):
        scans = [n for n in ir.topo_order(self.root) if isinstance(n, ir.Scan)]
        exts: dict[str, Any] = {}
        ext_caps: dict[str, int] = {}
        for n in ir.topo_order(self.root):
            for e in _node_exprs(n):
                for sub in _walk_expr(e):
                    if isinstance(sub, ExternalArray):
                        exts[sub.tag] = sub.array
                        child = n.children[0] if n.children else n
                        ext_caps[sub.tag] = self.plans[child.id].cap
        self._ext_caps = ext_caps
        return scans, exts

    def _build(self):
        cfg, mesh, axes = self.cfg, self.mesh, self.cfg.axes
        scans, exts = self._gather_inputs()
        self.scans, self.exts = scans, exts
        Pn = self.P

        in_specs = {"scans": {}, "ext": {}}
        for s in scans:
            rep = self.dists[s.id] == D.REP
            spec = P() if rep else P(axes)
            in_specs["scans"][str(s.id)] = {c: spec for c in s.columns}
        for tag in exts:
            in_specs["ext"][tag] = P(axes)

        out_specs = {"cols": {c: P(axes) for c in self.root.schema},
                     "count": P(axes), "overflow": P(axes)}

        root = self.root
        dists, plans = self.dists, self.plans
        scan_rows = {str(s.id): None for s in scans}  # bound at call time

        def per_shard(inputs):
            rank = phys.my_rank(axes)
            outputs: dict[int, tuple[dict, Any]] = {}
            flags = []

            for n in ir.topo_order(root):
                if isinstance(n, ir.Scan):
                    cols = inputs["scans"][str(n.id)]
                    rows = inputs["rows"][str(n.id)]       # static int
                    cap = plans[n.id].cap
                    if dists[n.id] == D.REP:
                        cnt = jnp.int32(rows)
                    else:
                        cnt = jnp.clip(rows - rank * cap, 0, cap).astype(jnp.int32)
                    outputs[n.id] = (dict(cols), cnt)
                elif isinstance(n, ir.Filter):
                    cols, cnt = outputs[n.child.id]
                    env = dict(cols)
                    env.update({f"ext:{t}": v for t, v in inputs["ext"].items()})
                    pred = evaluate(n.pred, env)
                    keep = pred & phys.valid_mask(cnt, next(iter(cols.values())).shape[0])
                    out, cnt2, ovf = phys.compact(cols, keep, plans[n.id].cap,
                                                  prefix_fn=self.kernels.get("prefix_sum"))
                    flags.append(ovf)
                    outputs[n.id] = (out, cnt2)
                elif isinstance(n, ir.Project):
                    cols, cnt = outputs[n.child.id]
                    env = dict(cols)
                    env.update({f"ext:{t}": v for t, v in inputs["ext"].items()})
                    cache: dict = {}
                    out = {}
                    for name, e in n.cols.items():
                        v = evaluate(e, env, cache)
                        cap = next(iter(cols.values())).shape[0]
                        out[name] = jnp.broadcast_to(v, (cap,)) if v.ndim == 0 else v
                    outputs[n.id] = (out, cnt)
                elif isinstance(n, ir.Join):
                    outputs[n.id] = self._lower_join(n, outputs, inputs, flags, axes)
                elif isinstance(n, ir.Aggregate):
                    outputs[n.id] = self._lower_aggregate(n, outputs, inputs, flags, axes)
                elif isinstance(n, ir.Window):
                    cols, cnt = outputs[n.child.id]
                    env = dict(cols)
                    env.update({f"ext:{t}": v for t, v in inputs["ext"].items()})
                    x = evaluate(n.expr, env)
                    ax = axes if dists[n.id] != D.REP else ()
                    if n.kind == "cumsum":
                        col = phys.dist_cumsum(x, cnt, ax, method=cfg.exscan_method,
                                               prefix_fn=self.kernels.get("prefix_sum"))
                    else:
                        col = phys.stencil1d(x, cnt, n.weights, n.center, ax,
                                             kernel_fn=self.kernels.get("stencil1d"))
                    out = dict(cols)
                    out[n.out] = col
                    outputs[n.id] = (out, cnt)
                elif isinstance(n, ir.Concat):
                    parts = [outputs[c.id] for c in n.parts]
                    out, cnt, ovf = phys.concat(parts, plans[n.id].cap)
                    flags.append(ovf)
                    outputs[n.id] = (out, cnt)
                elif isinstance(n, ir.Rebalance):
                    cols, cnt = outputs[n.child.id]
                    pl = plans[n.id]
                    out, cnt2, ovf = phys.rebalance(
                        cols, cnt, axes=axes, bucket_cap=pl.shuffle_bucket,
                        cap_out=pl.cap,
                        partition_fn=self.kernels.get("hash_partition"),
                        prefix_fn=self.kernels.get("prefix_sum"))
                    flags.append(ovf)
                    outputs[n.id] = (out, cnt2)
                elif isinstance(n, ir.Sort):
                    cols, cnt = outputs[n.child.id]
                    pl = plans[n.id]
                    ax = axes if dists[n.id] != D.REP else ()
                    out, cnt2, ovf = phys.sample_sort(
                        cols, cnt, n.by, axes=ax, bucket_cap=pl.shuffle_bucket,
                        cap_out=pl.cap, ascending=n.ascending)
                    flags.append(ovf)
                    outputs[n.id] = (out, cnt2)
                else:
                    raise TypeError(n)

            cols, cnt = outputs[root.id]
            ovf = functools.reduce(jnp.logical_or, flags, jnp.array(False))
            return {"cols": {k: cols[k] for k in root.schema},
                    "count": cnt.reshape(1),
                    "overflow": ovf.reshape(1)}

        # rows are static python ints — closed over, not traced.
        self._per_shard = per_shard
        self._in_specs = in_specs
        self._out_specs = out_specs

    # -- join / aggregate lowerings (need multiple steps) ---------------------

    def _lower_join(self, n: ir.Join, outputs, inputs, flags, axes):
        cfg, plans, dists = self.cfg, self.plans, self.dists
        lcols, lcnt = outputs[n.left.id]
        rcols, rcnt = outputs[n.right.id]
        pl_l = plans[(n.id, "l")]
        pl_r = plans[(n.id, "r")]
        broadcast = dists[n.right.id] == D.REP and cfg.broadcast_join
        rep_join = dists[n.id] == D.REP and not broadcast
        if not broadcast and not rep_join:
            pfn = self.kernels.get("hash_partition")
            sfn = self.kernels.get("prefix_sum")
            lcols, lcnt, o1 = phys.shuffle_by_key(
                lcols, lcnt, n.left_on, axes=axes,
                bucket_cap=pl_l.shuffle_bucket, cap_out=pl_l.cap,
                partition_fn=pfn, prefix_fn=sfn)
            rcols, rcnt, o2 = phys.shuffle_by_key(
                rcols, rcnt, n.right_on, axes=axes,
                bucket_cap=pl_r.shuffle_bucket, cap_out=pl_r.cap,
                partition_fn=pfn, prefix_fn=sfn)
            flags += [o1, o2]
        lcols, _ = phys.local_sort(lcols, lcnt, n.left_on)
        rcols, _ = phys.local_sort(rcols, rcnt, n.right_on)
        smap = {c: n.right_out_name(c) for c in rcols if c not in n.right_on}
        out, cnt, ovf = phys.merge_join(
            lcols, lcnt, rcols, rcnt, n.left_on, n.right_on,
            cap_out=plans[n.id].cap, r_suffix_map=smap, how=n.how)
        flags.append(ovf)
        return out, cnt

    def _lower_aggregate(self, n: ir.Aggregate, outputs, inputs, flags, axes):
        plans, dists = self.plans, self.dists
        cols, cnt = outputs[n.child.id]
        env = dict(cols)
        env.update({f"ext:{t}": v for t, v in inputs["ext"].items()})
        cache: dict = {}
        vals: dict[str, tuple[str, Any]] = {}
        nunique_col = None
        key0 = cols[n.key[0]]
        for name, agg in n.aggs.items():
            arr = (evaluate(agg.expr, env, cache) if agg.expr is not None
                   else jnp.zeros_like(key0, dtype=jnp.int32))
            if arr.ndim == 0:
                arr = jnp.broadcast_to(arr, key0.shape)
            vals[name] = (agg.fn, arr)
            if agg.fn == "nunique":
                if nunique_col is not None:
                    raise NotImplementedError("one nunique per aggregate")
                nunique_col = name
        pl = plans[n.id]
        key_names = tuple(f"__k{i}" for i in range(len(n.key)))
        shuf_cols = {kn: cols[k] for kn, k in zip(key_names, n.key)}
        for name, (_fn, arr) in vals.items():
            shuf_cols["v_" + name] = arr
        if dists[n.id] != D.REP:
            shuf_cols, cnt, ovf = phys.shuffle_by_key(
                shuf_cols, cnt, key_names, axes=axes,
                bucket_cap=pl.shuffle_bucket, cap_out=pl.shuffle_cap,
                partition_fn=self.kernels.get("hash_partition"),
                prefix_fn=self.kernels.get("prefix_sum"))
            flags.append(ovf)
        extra = ("v_" + nunique_col,) if nunique_col else ()
        sorted_cols, skeys = phys.local_sort(shuf_cols, cnt, key_names,
                                             extra_keys=extra)
        values = {name: (fn, sorted_cols["v_" + name]) for name, (fn, _a) in vals.items()}
        out, n_seg, ovf = phys.segment_aggregate(
            skeys, cnt, values, cap_out=pl.cap,
            segsum_fn=self.kernels.get("segment_sums"))
        flags.append(ovf)
        # key columns come back as __key<i>__ in key order; restore names
        # while keeping them FIRST in the output dict (schema order).
        renamed = {k: out.pop(f"__key{i}__") for i, k in enumerate(n.key)}
        renamed.update(out)
        return renamed, n_seg

    # -- public call -----------------------------------------------------------

    def _prepare(self, scan_arrays=None):
        """Marshal inputs and return the (cached) jitted shard_map callable.

        The jit is cached per source-row signature: rebuilding the closure on
        every call would otherwise retrace+recompile per execution (measured
        as a 50x CPU slowdown in the benchmark harness).
        """
        mesh, Pn = self.mesh, self.P
        inputs = {"scans": {}, "ext": {}, "rows": {}}
        for s in self.scans:
            src = (scan_arrays or {}).get(str(s.id), s.columns)
            rows = len(next(iter(src.values())))
            cap = self.plans[s.id].cap
            rep = self.dists[s.id] == D.REP
            n_pad = rows if rep else Pn * cap
            inputs["scans"][str(s.id)] = {
                c: jnp.asarray(pad_to(np.asarray(v), n_pad)) for c, v in src.items()}
            inputs["rows"][str(s.id)] = rows
        for tag, arr in self.exts.items():
            a = np.asarray(arr)
            cap = self._ext_caps[tag]
            inputs["ext"][tag] = jnp.asarray(pad_to(a, Pn * cap))

        rows_static = dict(inputs["rows"])
        key = tuple(sorted(rows_static.items()))
        if not hasattr(self, "_jit_cache"):
            self._jit_cache = {}
        if key not in self._jit_cache:
            def wrapped(scan_cols, ext_cols):
                return self._per_shard({"scans": scan_cols, "ext": ext_cols,
                                        "rows": rows_static})

            shard_fn = _compat_shard_map(
                wrapped, mesh=mesh,
                in_specs=(self._in_specs["scans"], self._in_specs["ext"]),
                out_specs=self._out_specs, check_vma=False)
            self._jit_cache[key] = jax.jit(shard_fn)
        return self._jit_cache[key], inputs

    def hlo_text(self, optimized: bool = True) -> str:
        """The (optimized) HLO of the whole plan — used by the UDF-identity
        benchmark (paper Fig. 10) and by EXPLAIN-style tooling."""
        fn, inputs = self._prepare()
        lowered = fn.lower(inputs["scans"], inputs["ext"])
        return lowered.compile().as_text() if optimized else lowered.as_text()

    def __call__(self, scan_arrays: dict[str, dict[str, np.ndarray]] | None = None):
        """Execute.  scan_arrays overrides source columns by scan id (str)."""
        fn, inputs = self._prepare(scan_arrays)
        out = fn(inputs["scans"], inputs["ext"])
        cap = self.plans[self.root.id].cap
        return DTable(columns=out["cols"], counts=out["count"],
                      capacity=cap, nshards=self.P, dist=self.dists[self.root.id],
                      overflow=bool(np.any(np.asarray(out["overflow"]))))


def _node_exprs(n: ir.Node):
    if isinstance(n, ir.Filter):
        yield n.pred
    elif isinstance(n, ir.Project):
        yield from n.cols.values()
    elif isinstance(n, ir.Aggregate):
        for a in n.aggs.values():
            if a.expr is not None:
                yield a.expr
    elif isinstance(n, ir.Window):
        yield n.expr


def _walk_expr(e):
    yield e
    for c in e.children:
        yield from _walk_expr(c)


def lower(root: ir.Node, cfg: ExecConfig | None = None,
          keep: set[str] | None = None, collect_block: bool = False,
          force_rep: set[int] = frozenset(), kernels: dict | None = None
          ) -> tuple[Lowered, dict]:
    """optimize -> infer distributions -> insert rebalance -> build executor."""
    from . import optimizer as opt

    cfg = cfg or ExecConfig()
    stats: dict = {}
    if cfg.optimize_plan:
        root, stats = opt.optimize(root, keep)
    info = D.infer(root, force_rep=force_rep,
                   broadcast_join=cfg.broadcast_join)
    root = D.insert_rebalance(root, info, collect_block=collect_block)
    mesh = cfg.get_mesh()
    Pn = int(np.prod([mesh.shape[a] for a in cfg.axes]))
    order = ir.topo_order(root)
    source_rows = {n.id: len(next(iter(n.columns.values())))
                   for n in order if isinstance(n, ir.Scan)}
    plans = plan_capacities(order, info.dists, Pn, cfg, source_rows)
    if kernels is None and cfg.use_kernels:
        from .. import kernels as K
        kernels = K.kernel_table()
    return Lowered(root, cfg, info.dists, plans, kernels=kernels), stats

"""Lowering: optimized logical plan -> physical plan -> ONE jitted SPMD program.

This is where the paper's end-to-end claim is realized: the entire plan —
relational operators, window analytics, UDFs and free array computation —
executes inside a single ``jax.shard_map`` region under a single ``jax.jit``,
so XLA fuses across relational boundaries exactly as CGen+icc fused the
generated C++.  There is no runtime scheduler and no master (paper §2.2).

The per-shard program is no longer derived node-by-node from the logical
plan: lowering first runs the property-driven physical planner
(core/physical_plan.py), which decides where hash exchanges and local sorts
are actually REQUIRED, and this module merely executes the resulting op list.
Capacity planning also lives with the physical plan — an elided exchange
means smaller buffers, not just fewer collectives.
"""
from __future__ import annotations

import functools
import os
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import distribution as D
from . import errors as err
from . import ir, physical as phys
from . import physical_plan as pp
from ..kernels import registry as kreg
from .compat import shard_map as _compat_shard_map
from .dtypes import NULL_CODE, categories_of, is_category, physical_dtype
from .expr import ExternalArray, evaluate, nulltag_for
from .table import DTable, pad_to


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclass
class ExecConfig:
    """Execution configuration (capacity planning + physical choices)."""

    mesh: Any = None                  # jax Mesh; default: all local devices, axis "data"
    axes: tuple[str, ...] = ("data",)
    # capacity policy: "safe" bounds every buffer by the worst case (tests);
    # otherwise capacities are input_cap * slack and overflow is flagged.
    safe_capacities: bool = True
    shuffle_slack: float = 2.0
    join_expansion: float = 1.5
    # physical choices (§Perf levers)
    exscan_method: str = "allgather"  # or "ladder"
    broadcast_join: bool = True       # beyond-paper: REP side joins without shuffle
    # use_pallas: the ONE kernel-backend lever.  "off" runs every hot-path
    # primitive as its lax composition (ref backend); "interpret" runs the
    # Pallas kernels under the interpreter (CPU CI, numerics debugging);
    # "compiled" compiles them for the accelerator (TPU).  Empty string
    # defers to $HIFRAMES_USE_PALLAS, defaulting to "off".  Backends are a
    # numerics swap only — the physical plan is identical in all modes.
    use_pallas: str = ""
    # deprecated alias for use_pallas="interpret" (the pre-registry bool).
    use_kernels: bool = False
    optimize_plan: bool = True
    # property-driven exchange/sort elision (core/physical_plan.py); False
    # restores the exchange-per-operator baseline — the A/B lever for
    # benchmarks and a safety valve.
    elide_exchanges: bool = True
    # -- shuffle engine v2 levers (both A/B-gated like elide_exchanges) -----
    # packed_exchange: ship ALL columns of an exchange as ONE word-packed
    # (P, bucket, W) uint32 payload — exactly 2 all_to_all per exchange
    # (counts + payload) instead of 1 + n_columns.  False restores the
    # per-column-collective baseline.
    packed_exchange: bool = True
    # partial_agg: split a shuffling aggregate with decomposable agg fns
    # into PartialAgg -> HashExchange -> FinalAgg, so each shard ships at
    # most its DISTINCT local key groups instead of all raw rows.
    partial_agg: bool = True
    # agg_group_cap: optional user bound on distinct groups per shard; when
    # set, PartialAgg buffers (and the post-partial exchange bucket) shrink
    # to it.  Overflow-flagged and doubled by the collect() retry loop.
    agg_group_cap: int | None = None
    # capacity-overflow auto-retry (runtime/ft.py semantics, built into
    # collect): replan with doubled expansion, at most this many times.
    auto_retry: int = 3
    # -- adaptive statistics (core/stats.py; docs/adaptive_planning.md) -----
    # adaptive_stats: build a sampled StatsContext per plan and let it make
    # planner DECISIONS: salted skew joins, cheaper-side re-exchange for
    # mixed-alignment joins, and PartialAgg auto-capacity from the
    # distinct-count estimate (plus realized feedback from previous runs of
    # the same plan fingerprint).  Off by default: plans are byte-identical
    # to the stats-blind planner.  explain() annotates estimates either way.
    adaptive_stats: bool = False
    # salt_threshold: sampled key frequency above which a join key counts as
    # a heavy hitter and gets salted across salt_factor sub-partitions.
    # Halved automatically when realized feedback shows shard skew.
    salt_threshold: float = 0.1
    salt_factor: int = 8
    # stats_sample: rows sampled per base table (even-position, like
    # sample_sort's splitter sampling).
    stats_sample: int = 256
    # stats_cap_slack: headroom multiplier on SAMPLED estimates when they
    # size buffers (realized feedback is exact and gets none).  Doubled by
    # the overflow-retry loop alongside shuffle_slack.
    stats_cap_slack: float = 2.0
    # -- execution guardrails (docs/robustness.md) --------------------------
    # validate: in-flight invariant checks — row-count conservation and a
    # packed-word checksum across every exchange, post-sort monotonicity,
    # category-code range.  All checks are per-shard locals reduced on the
    # host: they add ZERO collectives and change ZERO plans (census-gated).
    # None defers to $HIFRAMES_VALIDATE (default off).
    validate: Any = None
    # fault_inject: a runtime.faults.FaultPlan with deterministic injection
    # points (force-overflow an op, fail a kernel backend, poison a stats
    # estimate, corrupt an exchange payload).  None = no injection.
    fault_inject: Any = None
    # retry_scope: "op" escalates only the overflowed capacity site(s) via
    # cap_overrides (strictly fewer retries + smaller buffers on skew);
    # "global" restores the legacy slack-doubling across all four knobs.
    retry_scope: str = "op"
    # cap_overrides: {op_id: (cap_floor, bucket_floor)} applied as floors in
    # compute_capacities — written by runtime.retry.RetryPolicy, not users.
    cap_overrides: Any = None
    # kernel_fallbacks: {kernel name: mode} per-kernel backend overrides —
    # the degradation-ladder state (compiled -> interpret -> off) driven by
    # RetryPolicy on KernelBackendError.  None = all kernels on use_pallas.
    kernel_fallbacks: Any = None

    def __post_init__(self):
        if not self.use_pallas:
            self.use_pallas = os.environ.get("HIFRAMES_USE_PALLAS", "off")
        if self.use_kernels and self.use_pallas == "off":
            self.use_pallas = "interpret"
        if self.use_pallas not in kreg.MODES:
            raise ValueError(
                f"use_pallas must be one of {kreg.MODES}, "
                f"got {self.use_pallas!r}")
        if self.validate is None:
            self.validate = os.environ.get(
                "HIFRAMES_VALIDATE", "0").lower() in ("1", "true", "yes", "on")
        else:
            self.validate = bool(self.validate)
        if self.retry_scope not in ("op", "global"):
            raise ValueError(
                f"retry_scope must be 'op' or 'global', got {self.retry_scope!r}")

    def get_mesh(self) -> Mesh:
        if self.mesh is not None:
            return self.mesh
        devs = np.array(jax.devices())
        return Mesh(devs.reshape((len(devs),)), ("data",))


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------


def _cnt_tag(scan_id: int) -> str:
    """Reserved ext-group tag carrying a persisted scan's (P,) count vector
    (kept out of the scans group so the shard_map signature stays stable)."""
    return f"__cnt:{scan_id}"


class Lowered:
    """A compiled physical plan: callable on (possibly fresh) source arrays."""

    def __init__(self, root: ir.Node, cfg: ExecConfig, dists: dict[int, str],
                 pplan: pp.PhysicalPlan):
        self.root = root
        self.cfg = cfg
        self.dists = dists
        self.pplan = pplan
        fault = getattr(cfg, "fault_inject", None)
        fallbacks = getattr(cfg, "kernel_fallbacks", None)
        # wrap only when something can go wrong at the kernel layer: pallas
        # backends (typed KernelBackendError), per-kernel fallbacks, or an
        # injected kernel fault.  The default off-mode path keeps the cached
        # KernelSet untouched.
        need_wrap = (cfg.use_pallas != "off" or bool(fallbacks)
                     or (fault is not None
                         and getattr(fault, "fail_kernel", "")))
        self.kernels = kreg.resolve_with(
            cfg.use_pallas, fallbacks,
            wrap=_kernel_wrap(fault) if need_wrap else None)
        self.mesh = cfg.get_mesh()
        self.P = int(np.prod([self.mesh.shape[a] for a in cfg.axes]))
        self.events: list = []   # degradation events picked up by RetryPolicy
        self.compiles = 0        # jit-cache misses (plan-cache hit => stays 0)
        self._build()

    # -- input marshalling ---------------------------------------------------

    def _gather_inputs(self):
        scans = [n for n in ir.topo_order(self.root) if isinstance(n, ir.Scan)]
        exts: dict[str, Any] = {}
        ext_caps: dict[str, int] = {}
        for n in ir.topo_order(self.root):
            for e in _node_exprs(n):
                for sub in _walk_expr(e):
                    if isinstance(sub, ExternalArray):
                        exts[sub.tag] = sub.array
                        child = n.children[0] if n.children else n
                        ext_caps[sub.tag] = self.pplan.final_op(child).cap
        self._ext_caps = ext_caps
        return scans, exts

    def _build(self):
        cfg, mesh, axes = self.cfg, self.mesh, self.cfg.axes
        scans, exts = self._gather_inputs()
        self.scans, self.exts = scans, exts
        # persisted scans whose device shards re-enter directly (no host
        # round-trip): their per-shard valid counts ride in as a sharded
        # (P,) vector instead of being derived from a block row count.  The
        # vector travels in the ext input group under a reserved tag, so the
        # shard_map signature (scans, ext) stays stable.
        self.dev_scans = {s.id for s in scans
                          if s.layout is not None
                          and s.layout.device_valid(self.P)
                          and self.dists[s.id] != D.REP}

        in_specs = {"scans": {}, "ext": {}}
        for s in scans:
            rep = self.dists[s.id] == D.REP
            spec = P() if rep else P(axes)
            in_specs["scans"][str(s.id)] = {c: spec for c in s.columns}
            if s.id in self.dev_scans:
                in_specs["ext"][_cnt_tag(s.id)] = P(axes)
        for tag in exts:
            in_specs["ext"][tag] = P(axes)

        out_specs = {"cols": {c: P(axes) for c in self.root.schema},
                     "count": P(axes), "overflow": P(axes),
                     "ovf_req": P(axes)}

        root = self.root
        pplan = self.pplan
        kernels = self.kernels
        Pn = self.P
        validate = bool(getattr(cfg, "validate", False))
        fault = getattr(cfg, "fault_inject", None)

        # -- per-op failure attribution: the static capacity-site table.
        # per_shard emits one (flag, requirement-estimate) pair per site, in
        # this order; __call__ reduces them host-side into DTable.overflow_ops
        # so the retry policy can escalate exactly the op that overflowed.
        self.sites = _capacity_sites(pplan)
        forced = (fault.take_overflow_sites(pplan.ops)
                  if fault is not None else frozenset())
        corrupt = (fault.corrupt_sites(pplan.ops, cfg.packed_exchange)
                   if fault is not None else frozenset())

        # -- ExecConfig.validate: static check tables.  Flag checks emit one
        # per-shard bool; pair checks emit (in, out) uint32 scalars reduced
        # host-side — no collectives, no plan change (census-gated).
        self.val_flags_meta: list[tuple[str, int, str]] = []
        self.val_pairs_meta: list[tuple[str, int, str]] = []
        if validate:
            for op in pplan.ops:
                if isinstance(op, (pp.HashExchange, pp.SampleSort,
                                   pp.RebalanceOp)):
                    self.val_pairs_meta.append(
                        ("rowcount", op.op_id, type(op).__name__))
                    self.val_pairs_meta.append(
                        ("checksum", op.op_id, type(op).__name__))
                if isinstance(op, pp.LocalSort):
                    self.val_flags_meta.append(
                        ("monotonic", op.op_id, op.keys[0]))
                elif isinstance(op, pp.SampleSort):
                    self.val_flags_meta.append(
                        ("monotonic", op.op_id, op.node.by[0]))
            for c, dt in root.schema.items():
                if is_category(dt):
                    self.val_flags_meta.append(
                        ("code_range", pplan.root_id, c))
            out_specs["val_flags"] = P(axes)
            out_specs["val_pairs"] = P(axes)
        n_codes = {c: len(categories_of(dt))
                   for c, dt in root.schema.items() if is_category(dt)}

        def per_shard(inputs):
            rank = phys.my_rank(axes)
            env: dict[int, tuple[dict, Any]] = {}
            flags = []
            reqs = []
            vflags = []
            vpairs = []
            ext = {f"ext:{t}": v for t, v in inputs["ext"].items()}

            def flag(op, ovf, req):
                """Record one capacity site: overflow flag + this shard's
                requirement estimate (rows), with fault injection applied."""
                if op.op_id in forced:
                    ovf = jnp.logical_or(ovf, jnp.bool_(True))
                flags.append(ovf)
                reqs.append(jnp.asarray(req, jnp.float32).reshape(()))

            def pre_exchange(op, cols, cnt):
                if not validate:
                    return None
                return (cnt.astype(jnp.uint32), _checksum_u32(cols, cnt))

            def post_exchange(op, pre, out, cnt2):
                if op.op_id in corrupt:
                    # deterministic payload corruption: bump row 0 of the
                    # first non-bool column on every shard with rows.
                    name = next((k for k in sorted(out)
                                 if out[k].dtype != jnp.bool_), None)
                    if name is not None:
                        v = out[name]
                        bump = jnp.where(cnt2 > 0, jnp.ones((), v.dtype),
                                         jnp.zeros((), v.dtype))
                        out = dict(out)
                        out[name] = v.at[0].add(bump)
                if validate:
                    vpairs.append((pre[0], cnt2.astype(jnp.uint32)))
                    vpairs.append((pre[1], _checksum_u32(out, cnt2)))
                return out

            for op in pplan.ops:
                n = op.node
                ax = axes if op.dist != D.REP else ()

                if isinstance(op, pp.Source):
                    cols = inputs["scans"][str(n.id)]
                    if _cnt_tag(n.id) in inputs["ext"]:
                        # persisted device shards: this shard's valid count
                        # arrives sharded off the (P,) layout vector.
                        cnt = inputs["ext"][_cnt_tag(n.id)][0].astype(jnp.int32)
                    else:
                        rows = inputs["rows"][str(n.id)]   # static int
                        if op.dist == D.REP:
                            cnt = jnp.int32(rows)
                        else:
                            cnt = jnp.clip(rows - rank * op.cap, 0,
                                           op.cap).astype(jnp.int32)
                    res = (dict(cols), cnt)

                elif isinstance(op, pp.Compact):
                    cols, cnt = env[op.inputs[0]]
                    env_e = dict(cols)
                    env_e.update(ext)
                    pred = evaluate(n.pred, env_e)
                    keep = pred & phys.valid_mask(
                        cnt, next(iter(cols.values())).shape[0])
                    out, cnt2, ovf = phys.compact(cols, keep, op.cap,
                                                  kernels=kernels)
                    flag(op, ovf, jnp.sum(keep.astype(jnp.int32)))
                    res = (out, cnt2)

                elif isinstance(op, pp.Map):
                    cols, cnt = env[op.inputs[0]]
                    env_e = dict(cols)
                    env_e.update(ext)
                    cache: dict = {}
                    out = {}
                    for name, e in n.cols.items():
                        v = evaluate(e, env_e, cache)
                        cap = next(iter(cols.values())).shape[0]
                        out[name] = jnp.broadcast_to(v, (cap,)) if v.ndim == 0 else v
                    res = (out, cnt)

                elif isinstance(op, pp.WindowOp):
                    cols, cnt = env[op.inputs[0]]
                    env_e = dict(cols)
                    env_e.update(ext)
                    x = (evaluate(n.expr, env_e)
                         if n.expr is not None else None)
                    if n.partition_by:
                        # grouped layout established upstream (hash exchange
                        # + local sort, possibly elided): segment kernels,
                        # no collectives.
                        pk = tuple(cols[k] for k in n.partition_by)
                        if n.kind == "cumsum":
                            tag = nulltag_for(n.expr, n.children[0].schema)
                            col = phys.segment_cumsum(x, pk, cnt,
                                                      kernels=kernels,
                                                      nulltag=tag)
                        elif n.kind == "stencil":
                            col = phys.segment_stencil1d(x, pk, cnt,
                                                         n.weights, n.center,
                                                         exact=n.exact,
                                                         kernels=kernels)
                        else:
                            ok = tuple(cols[k] for k in n.order_by)
                            col = phys.segment_rank(pk, ok, cnt, n.kind,
                                                    kernels=kernels)
                    elif n.kind in ("rank", "dense_rank", "row_number"):
                        # global ranking: per-shard-count exscan + tiny
                        # boundary gathers, no row movement (planner enforces
                        # cross-shard tie adjacency for rank/dense_rank).
                        ok = tuple(cols[k] for k in (n.order_by or ()))
                        cap_w = next(iter(cols.values())).shape[0]
                        col = phys.global_rank(ok, cnt, cap_w, n.kind, ax,
                                               method=cfg.exscan_method,
                                               kernels=kernels)
                    elif n.kind == "cumsum":
                        tag = nulltag_for(n.expr, n.children[0].schema)
                        nullm = phys.null_mask(x, tag)
                        if nullm is not None:   # pandas: nulls stay null,
                            x = jnp.where(nullm, jnp.zeros((), x.dtype), x)
                        col = phys.dist_cumsum(x, cnt, ax,
                                               method=cfg.exscan_method,
                                               kernels=kernels)
                        if nullm is not None:   # the running total skips them
                            col = jnp.where(
                                nullm,
                                phys.null_value(col.dtype, tag).astype(col.dtype),
                                col)
                    else:
                        col = phys.stencil1d(x, cnt, n.weights, n.center, ax,
                                             kernels=kernels, exact=n.exact)
                    out = dict(cols)
                    out[n.out] = col
                    res = (out, cnt)

                elif isinstance(op, pp.HashExchange):
                    cols, cnt = env[op.inputs[0]]
                    # shuffle_by_key inlined so the routing hashes also feed
                    # the per-op requirement estimate (max destination load)
                    # without a second hash pass.
                    cap_in = next(iter(cols.values())).shape[0]
                    dest = (phys.hash_keys(cols, op.keys)
                            % np.uint32(Pn)).astype(jnp.int32)
                    valid = phys.valid_mask(cnt, cap_in)
                    hist = jnp.zeros((Pn,), jnp.int32).at[dest].add(
                        valid.astype(jnp.int32))
                    pre = pre_exchange(op, cols, cnt)
                    out, cnt2, ovf = phys.exchange(
                        cols, cnt, dest, axes=axes,
                        bucket_cap=op.bucket, cap_out=op.cap,
                        kernels=kernels, packed=cfg.packed_exchange)
                    flag(op, ovf, jnp.max(hist))
                    out = post_exchange(op, pre, out, cnt2)
                    res = (out, cnt2)

                elif isinstance(op, pp.LocalSort):
                    cols, cnt = env[op.inputs[0]]
                    out, _ = phys.local_sort(cols, cnt, op.keys)
                    if validate:
                        vflags.append(_mono_violation(out[op.keys[0]], cnt))
                    res = (out, cnt)

                elif isinstance(op, pp.MergeJoin):
                    lcols, lcnt = env[op.inputs[0]]
                    rcols, rcnt = env[op.inputs[1]]
                    lon, ron = n.left_on, n.right_on
                    if op.salted:
                        # join on keys+salt: each (probe, build) key match
                        # agrees on exactly one salt (see pp.SaltOp).
                        lon = lon + (phys.SALT_COL,)
                        ron = ron + (phys.SALT_COL,)
                    smap = {c: n.right_out_name(c) for c in rcols
                            if c not in ron}
                    out, cnt2, ovf = phys.merge_join(
                        lcols, lcnt, rcols, rcnt, lon, ron,
                        cap_out=op.cap, r_suffix_map=smap, how=n.how,
                        null_fill=_join_null_fill(n))
                    lf = lcnt.astype(jnp.float32)
                    flag(op, ovf,
                         jnp.maximum(lf * rcnt.astype(jnp.float32), lf))
                    out.pop(phys.SALT_COL, None)    # strip probe-side salt
                    res = (out, cnt2)

                elif isinstance(op, pp.SaltOp):
                    cols, cnt = env[op.inputs[0]]
                    if op.build:
                        out, cnt2, ovf = phys.salt_build(
                            cols, cnt, op.keys, op.hot, op.R,
                            cap_out=op.cap, kernels=kernels)
                        flag(op, ovf,
                             jnp.float32(op.R) * cnt.astype(jnp.float32))
                    else:
                        out, cnt2 = phys.salt_probe(cols, cnt, op.keys,
                                                    op.hot, op.R)
                    res = (out, cnt2)

                elif isinstance(op, pp.AggPrep):
                    cols, cnt = env[op.inputs[0]]
                    env_e = dict(cols)
                    env_e.update(ext)
                    cache = {}
                    key0 = cols[n.key[0]]
                    out = {k: cols[k] for k in n.key}
                    for name, agg in n.aggs.items():
                        arr = (evaluate(agg.expr, env_e, cache)
                               if agg.expr is not None
                               else jnp.zeros_like(key0, dtype=jnp.int32))
                        if arr.ndim == 0:
                            arr = jnp.broadcast_to(arr, key0.shape)
                        out["__v_" + name] = arr
                    res = (out, cnt)

                elif isinstance(op, pp.PartialAgg):
                    cols, cnt = env[op.inputs[0]]
                    tags = _agg_nulltags(n)
                    values = {name: (agg.fn, cols["__v_" + name],
                                     agg.skipna, tags[name])
                              if tags[name] is not None
                              else (agg.fn, cols["__v_" + name])
                              for name, agg in n.aggs.items()}
                    keys = tuple(cols[k] for k in n.key)
                    out, n_seg, ovf = phys.partial_aggregate(
                        keys, cnt, values, cap_out=op.cap, kernels=kernels)
                    flag(op, ovf, _distinct_runs(keys, cnt))
                    res = (_restore_key_names(out, n.key), n_seg)

                elif isinstance(op, pp.SegmentAgg):
                    cols, cnt = env[op.inputs[0]]
                    keys = tuple(cols[k] for k in n.key)
                    tags = _agg_nulltags(n)
                    if op.from_partials:
                        fns = {name: (agg.fn, agg.skipna, tags[name])
                               if tags[name] is not None else agg.fn
                               for name, agg in n.aggs.items()}
                        out, n_seg, ovf = phys.final_aggregate(
                            keys, cnt, fns,
                            cols, cap_out=op.cap, kernels=kernels)
                    else:
                        values = {name: (agg.fn, cols["__v_" + name],
                                         agg.skipna, tags[name])
                                  if tags[name] is not None
                                  else (agg.fn, cols["__v_" + name])
                                  for name, agg in n.aggs.items()}
                        out, n_seg, ovf = phys.segment_aggregate(
                            keys, cnt, values, cap_out=op.cap,
                            kernels=kernels,
                            presorted=(op.nunique_ride,)
                            if op.nunique_ride else ())
                    flag(op, ovf, _distinct_runs(keys, cnt))
                    res = (_restore_key_names(out, n.key), n_seg)

                elif isinstance(op, pp.SampleSort):
                    cols, cnt = env[op.inputs[0]]
                    pre = pre_exchange(op, cols, cnt)
                    out, cnt2, ovf = phys.sample_sort(
                        cols, cnt, n.by, axes=ax, bucket_cap=op.bucket,
                        cap_out=op.cap, ascending=n.ascending,
                        pre_sorted=op.pre_sorted, kernels=kernels,
                        packed=cfg.packed_exchange)
                    flag(op, ovf, cnt)
                    out = post_exchange(op, pre, out, cnt2)
                    if validate:
                        vflags.append(_mono_violation(
                            out[n.by[0]], cnt2, ascending=n.ascending))
                    res = (out, cnt2)

                elif isinstance(op, pp.LimitOp):
                    cols, cnt = env[op.inputs[0]]
                    out, cnt2 = phys.limit(cols, cnt, n.n, ax, cap_out=op.cap)
                    res = (out, cnt2)

                elif isinstance(op, pp.RebalanceOp):
                    cols, cnt = env[op.inputs[0]]
                    pre = pre_exchange(op, cols, cnt)
                    out, cnt2, ovf = phys.rebalance(
                        cols, cnt, axes=axes, bucket_cap=op.bucket,
                        cap_out=op.cap, kernels=kernels,
                        packed=cfg.packed_exchange)
                    flag(op, ovf, cnt)
                    out = post_exchange(op, pre, out, cnt2)
                    res = (out, cnt2)

                elif isinstance(op, pp.ConcatOp):
                    parts = [env[i] for i in op.inputs]
                    out, cnt, ovf = phys.concat(parts, op.cap, kernels=kernels)
                    flag(op, ovf,
                         functools.reduce(
                             jnp.add, [c.astype(jnp.float32)
                                       for _, c in parts]))
                    res = (out, cnt)

                else:
                    raise TypeError(op)

                env[op.op_id] = res

            cols, cnt = env[pplan.root_id]
            if validate:
                for kind, _oid, cname in self.val_flags_meta:
                    if kind != "code_range":
                        continue
                    colv = cols[cname]
                    validr = phys.valid_mask(cnt, colv.shape[0])
                    vflags.append(jnp.any(
                        validr & ((colv < NULL_CODE)
                                  | (colv >= n_codes[cname]))))

            assert len(flags) == len(self.sites), (len(flags), self.sites)
            outd = {"cols": {k: cols[k] for k in root.schema},
                    "count": cnt.reshape(1),
                    "overflow": (jnp.stack(flags) if flags
                                 else jnp.zeros((1,), jnp.bool_)),
                    "ovf_req": (jnp.stack(reqs) if reqs
                                else jnp.zeros((1,), jnp.float32))}
            if validate:
                assert len(vflags) == len(self.val_flags_meta)
                assert len(vpairs) == len(self.val_pairs_meta)
                outd["val_flags"] = (jnp.stack(vflags) if vflags
                                     else jnp.zeros((1,), jnp.bool_))
                outd["val_pairs"] = (
                    jnp.stack([jnp.stack([a, b]) for a, b in vpairs])
                    if vpairs else jnp.zeros((1, 2), jnp.uint32))
            return outd

        # rows are static python ints — closed over, not traced.
        self._per_shard = per_shard
        self._in_specs = in_specs
        self._out_specs = out_specs

    # -- public call -----------------------------------------------------------

    def _prepare(self, scan_arrays=None, scan_nodes=None):
        """Marshal inputs and return the (cached) jitted shard_map callable.

        The jit is cached per source-row signature: rebuilding the closure on
        every call would otherwise retrace+recompile per execution (measured
        as a 50x CPU slowdown in the benchmark harness).

        ``scan_nodes`` rebinds a scan to ANOTHER ir.Scan's buffers (by this
        plan's scan id, str-keyed) — the session plan cache's sanctioned path
        for re-executing a cached trace over a different same-shape table.
        For persisted device scans the substitute must carry a device layout
        with the same shard count and capacity, so the shard_map signature
        (and hence the compiled executable) is reused byte-identical.
        """
        mesh, Pn = self.mesh, self.P
        inputs = {"scans": {}, "ext": {}, "rows": {}}
        for s in self.scans:
            sub = scan_nodes.get(str(s.id)) if scan_nodes else None
            overridden = scan_arrays is not None and str(s.id) in scan_arrays
            src = scan_arrays[str(s.id)] if overridden else (
                sub.columns if sub is not None else s.columns)
            lay = s.layout
            if s.id in self.dev_scans:
                if overridden:
                    raise ValueError(
                        "cannot override columns of a persisted scan "
                        f"({s.name!r}): its buffers carry a device layout; "
                        "rebuild the input with hf.table(...) instead")
                if sub is not None:
                    slay = sub.layout
                    if (slay is None or not slay.device_valid(Pn)
                            or int(slay.capacity) != int(lay.capacity)):
                        raise ValueError(
                            f"scan rebind for {s.name!r}: substitute must be "
                            f"persisted at P={Pn} with capacity "
                            f"{lay.capacity} (got "
                            f"{None if slay is None else (slay.nshards, slay.capacity)})")
                    missing = [c for c in s.columns if c not in src]
                    if missing:
                        raise ValueError(
                            f"scan rebind for {s.name!r}: substitute lacks "
                            f"columns {missing}")
                    lay = slay
                # persisted device shards: feed the (P*cap,) arrays and the
                # (P,) count vector straight through — no host round-trip,
                # no padding pass.  The jit key is the (static) capacity,
                # negated to stay disjoint from host-scan row counts, so a
                # same-capacity rebind reuses the compiled executable.
                inputs["scans"][str(s.id)] = {c: src[c] for c in s.columns}
                inputs["ext"][_cnt_tag(s.id)] = jnp.asarray(
                    np.asarray(lay.counts, dtype=np.int32))
                inputs["rows"][str(s.id)] = -int(lay.capacity) - 1
                continue
            if sub is not None:
                lay = sub.layout
                src = {c: src[c] for c in s.columns}
            if lay is not None and lay.counts is not None and not overridden:
                # shard-count mismatch: gather the valid prefixes on the
                # host and re-enter as a plain block table (layout claims
                # were already dropped at planning time).
                src = lay.gather_host(src)
            rows = len(next(iter(src.values())))
            cap = self.pplan.final_op(s).cap
            rep = self.dists[s.id] == D.REP
            n_pad = rows if rep else Pn * cap
            inputs["scans"][str(s.id)] = {
                c: jnp.asarray(pad_to(np.asarray(v), n_pad)) for c, v in src.items()}
            inputs["rows"][str(s.id)] = rows
        for tag, arr in self.exts.items():
            a = np.asarray(arr)
            cap = self._ext_caps[tag]
            inputs["ext"][tag] = jnp.asarray(pad_to(a, Pn * cap))

        rows_static = dict(inputs["rows"])
        key = tuple(sorted(rows_static.items()))
        if not hasattr(self, "_jit_cache"):
            self._jit_cache = {}
        if key not in self._jit_cache:
            def wrapped(scan_cols, ext_cols):
                return self._per_shard({"scans": scan_cols, "ext": ext_cols,
                                        "rows": rows_static})

            shard_fn = _compat_shard_map(
                wrapped, mesh=mesh,
                in_specs=(self._in_specs["scans"], self._in_specs["ext"]),
                out_specs=self._out_specs, check_vma=False)
            self._jit_cache[key] = jax.jit(shard_fn)
            self.compiles += 1
        return self._jit_cache[key], inputs

    def hlo_text(self, optimized: bool = True) -> str:
        """The (optimized) HLO of the whole plan — used by the UDF-identity
        benchmark (paper Fig. 10) and by EXPLAIN-style tooling."""
        fn, inputs = self._prepare()
        lowered = fn.lower(inputs["scans"], inputs["ext"])
        return lowered.compile().as_text() if optimized else lowered.as_text()

    def __call__(self, scan_arrays: dict[str, dict[str, np.ndarray]] | None = None,
                 scan_nodes=None):
        """Execute.  scan_arrays overrides source columns by scan id (str);
        scan_nodes rebinds scans to other same-shape tables (plan cache)."""
        fn, inputs = self._prepare(scan_arrays, scan_nodes)
        out = fn(inputs["scans"], inputs["ext"])
        cap = self.pplan.root_op.cap
        flags = np.asarray(out["overflow"]).reshape(self.P, -1)
        reqs = np.asarray(out["ovf_req"]).reshape(self.P, -1)
        overflow_ops = self._attribute_overflow(flags, reqs)
        failures = self._check_invariants(out, overflow_ops)
        return DTable(columns=out["cols"], counts=out["count"],
                      capacity=cap, nshards=self.P, dist=self.dists[self.root.id],
                      overflow=bool(flags.any()),
                      overflow_ops=overflow_ops,
                      invariant_failures=failures)

    def _attribute_overflow(self, flags: np.ndarray,
                            reqs: np.ndarray) -> dict[int, dict]:
        """Reduce per-shard (flag, requirement) vectors to the per-op
        attribution record the retry policy escalates from."""
        overflow_ops: dict[int, dict] = {}
        for i, (op_id, kind, rule, strategy) in enumerate(self.sites):
            if not flags[:, i].any():
                continue
            vals = reqs[:, i].astype(np.float64)
            cap_req = {"max": float(vals.max()),
                       "sum": float(vals.sum()),
                       "block": float(np.ceil(vals.sum() / max(self.P, 1)))
                       }[rule]
            op = self.pplan.ops[op_id]
            overflow_ops[op_id] = {
                "kind": kind, "op": type(op).__name__, "strategy": strategy,
                "cap": int(op.cap), "bucket": int(op.bucket),
                "cap_req": int(np.ceil(cap_req)),
                "bucket_req": int(np.ceil(float(vals.max()))),
                "req_shards": vals,     # per-shard requirement estimates
            }
        return overflow_ops

    def _check_invariants(self, out, overflow_ops) -> tuple:
        """Host-side reduction of the ExecConfig.validate check outputs."""
        fails: list[err.InvariantFailure] = []
        if self.val_flags_meta:
            vf = np.asarray(out["val_flags"]).reshape(self.P, -1)
            for i, (kind, opid, detail) in enumerate(self.val_flags_meta):
                if vf[:, i].any():
                    fails.append(err.InvariantFailure(kind, opid, detail))
        if self.val_pairs_meta:
            vp = np.asarray(out["val_pairs"]).reshape(
                self.P, len(self.val_pairs_meta), 2).astype(np.uint64)
            for i, (kind, opid, detail) in enumerate(self.val_pairs_meta):
                if opid in overflow_ops:
                    continue    # clamped rows legitimately break conservation
                a, b = int(vp[:, i, 0].sum()), int(vp[:, i, 1].sum())
                if kind == "checksum":
                    a &= 0xFFFFFFFF
                    b &= 0xFFFFFFFF
                if a != b:
                    fails.append(err.InvariantFailure(
                        kind, opid, f"{detail}: in={a} out={b}"))
        return tuple(fails)


def _capacity_sites(pplan: pp.PhysicalPlan) -> list[tuple[int, str, str, str]]:
    """The static capacity-site table for per-op overflow attribution: one
    entry per overflow-flagged buffer, in per-shard flag order —
    ``(op_id, kind, reduce-rule, escalation-strategy)``.

    The reduce rule maps per-shard requirement estimates to a global cap
    requirement: "max" for per-shard buffers, "sum" for exchange receive
    totals, "block" for evenly re-split rows.  Strategy "abs" sites report a
    true upper bound, so ONE retry at that size heals; "double" sites
    (join/salt expansion) only know a worst-case product and escalate
    geometrically instead.
    """
    sites = []
    for op in pplan.ops:
        rep = op.dist == D.REP
        if isinstance(op, pp.Compact):
            sites.append((op.op_id, "compact", "max", "abs"))
        elif isinstance(op, pp.HashExchange):
            sites.append((op.op_id, "exchange",
                          "max" if rep else "sum", "abs"))
        elif isinstance(op, pp.MergeJoin):
            sites.append((op.op_id, "join", "max", "double"))
        elif isinstance(op, pp.SaltOp):
            if op.build:
                sites.append((op.op_id, "salt", "max", "double"))
        elif isinstance(op, pp.PartialAgg):
            sites.append((op.op_id, "partial_agg", "max", "abs"))
        elif isinstance(op, pp.SegmentAgg):
            sites.append((op.op_id, "segment_agg", "max", "abs"))
        elif isinstance(op, pp.SampleSort):
            sites.append((op.op_id, "sort", "max" if rep else "sum", "abs"))
        elif isinstance(op, pp.RebalanceOp):
            sites.append((op.op_id, "rebalance",
                          "max" if rep else "block", "abs"))
        elif isinstance(op, pp.ConcatOp):
            sites.append((op.op_id, "concat", "max", "abs"))
    return sites


def _kernel_wrap(fault):
    """Registry ``wrap`` hook: type real kernel-backend failures as
    KernelBackendError and honor FaultPlan.fail_kernel injection."""
    def wrap(name, mode, fn):
        injected = fault is not None and fault.kernel_fails(name, mode)
        if mode == "off" and not injected:
            return fn
        def call(*a, **k):
            if injected:
                raise err.KernelBackendError(
                    name, mode, "injected fault (FaultPlan.fail_kernel)")
            try:
                return fn(*a, **k)
            except err.HiFramesError:
                raise
            except Exception as e:
                raise err.KernelBackendError(name, mode, e) from e
        return call
    return wrap


def _checksum_u32(cols: dict, cnt) -> jax.Array:
    """Order-invariant uint32 payload checksum of the valid prefix: the
    word-packed columns (a pure bitcast, so float payload bits survive
    exactly), masked to valid rows, summed mod 2**32.  Exchanges permute
    rows across shards, so the host-side sum over shards is conserved."""
    cap = next(iter(cols.values())).shape[0]
    valid = phys.valid_mask(cnt, cap)
    words, _ = phys.pack_columns({k: cols[k] for k in sorted(cols)})
    w = jnp.where(valid[:, None], words, jnp.zeros((), words.dtype))
    return jnp.sum(w, dtype=jnp.uint32)


def _mono_violation(col, cnt, ascending: bool = True) -> jax.Array:
    """True iff an adjacent pair inside the valid prefix is out of order.
    NaN-lenient: comparisons with NaN are False, so null floats never flag."""
    cap = col.shape[0]
    if cap < 2:
        return jnp.zeros((), jnp.bool_)
    pair_valid = phys.valid_mask(cnt, cap)[1:]   # pair (i-1, i) needs i < cnt
    a, b = col[:-1], col[1:]
    bad = (b < a) if ascending else (b > a)
    return jnp.any(bad & pair_valid)


def _distinct_runs(keys: tuple, cnt) -> jax.Array:
    """Exact count of key runs in the valid prefix of sorted key columns —
    the true PartialAgg/SegmentAgg output requirement (NaN keys each count
    as their own run: a safe upper bound)."""
    cap = keys[0].shape[0]
    if cap < 2:
        return (cnt > 0).astype(jnp.int32)
    valid = phys.valid_mask(cnt, cap)
    neq = functools.reduce(
        jnp.logical_or, [k[1:] != k[:-1] for k in keys])
    return (jnp.sum((neq & valid[1:]).astype(jnp.int32))
            + (cnt > 0).astype(jnp.int32))


def _agg_nulltags(n: ir.Aggregate) -> dict[str, str | None]:
    """Per-output null tag for an Aggregate's value expressions, decided
    from the child's LOGICAL schema (None = exact pre-null code path)."""
    sch = n.children[0].schema
    return {name: nulltag_for(agg.expr, sch) for name, agg in n.aggs.items()}


def _join_null_fill(n: ir.Join) -> dict[str, Any] | None:
    """Unmatched-row fill values for a left join's right columns, from the
    right child's logical schema: null code for categories, NaN for floats
    (matching the nullable output schema ir.Join declares); int columns
    keep the legacy zero-fill + ``_matched`` flag."""
    if n.how != "left":
        return None
    fill: dict[str, Any] = {}
    for c, dt in n.children[1].schema.items():
        if c in n.right_on:
            continue
        if is_category(dt):
            fill[c] = NULL_CODE
        elif np.issubdtype(physical_dtype(dt), np.floating):
            fill[c] = np.nan
    return fill or None


def _restore_key_names(out: dict, key: tuple[str, ...]) -> dict:
    """Segment-aggregation outputs name key columns ``__key<i>__`` in key
    order; restore the real names, keeping them FIRST (schema order)."""
    renamed = {k: out.pop(f"__key{i}__") for i, k in enumerate(key)}
    renamed.update(out)
    return renamed


def _node_exprs(n: ir.Node):
    if isinstance(n, ir.Filter):
        yield n.pred
    elif isinstance(n, ir.Project):
        yield from n.cols.values()
    elif isinstance(n, ir.Aggregate):
        for a in n.aggs.values():
            if a.expr is not None:
                yield a.expr
    elif isinstance(n, ir.Window):
        if n.expr is not None:
            yield n.expr


def _walk_expr(e):
    yield e
    for c in e.children:
        yield from _walk_expr(c)


def lower(root: ir.Node, cfg: ExecConfig | None = None,
          keep: set[str] | None = None, collect_block: bool = False,
          force_rep: set[int] = frozenset()) -> tuple[Lowered, dict]:
    """optimize -> infer distributions -> insert rebalance -> plan physical
    ops (exchange/sort elision) -> plan capacities -> build executor.

    Kernel backends (``cfg.use_pallas``) play no part here: the physical
    plan is backend-oblivious; ``Lowered`` resolves the registry when it
    builds the per-shard program.
    """
    from . import optimizer as opt

    cfg = cfg or ExecConfig()
    stats: dict = {}
    if cfg.optimize_plan:
        root, stats = opt.optimize(root, keep)
    info = D.infer(root, force_rep=force_rep,
                   broadcast_join=cfg.broadcast_join)
    root = D.insert_rebalance(root, info, collect_block=collect_block)
    mesh = cfg.get_mesh()
    Pn = int(np.prod([mesh.shape[a] for a in cfg.axes]))
    order = ir.topo_order(root)
    source_rows = {n.id: pp.scan_rows(n)
                   for n in order if isinstance(n, ir.Scan)}
    sctx = None
    events: list = []
    if cfg.adaptive_stats:
        from . import stats as st
        try:
            sctx = st.analyze(root, cfg)
        except Exception as e:   # degradation ladder: adaptive -> static
            events.append({"kind": "degrade_stats",
                           "detail": f"adaptive -> static planning: {e}"})
            sctx = None
    pplan = pp.plan_physical(root, info.dists, cfg, stats=sctx)
    pp.plan_capacities(pplan, Pn, cfg, source_rows)
    lowered = Lowered(root, cfg, info.dists, pplan)
    lowered.events.extend(events)
    return lowered, stats

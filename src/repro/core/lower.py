"""Lowering: optimized logical plan -> physical plan -> ONE jitted SPMD program.

This is where the paper's end-to-end claim is realized: the entire plan —
relational operators, window analytics, UDFs and free array computation —
executes inside a single ``jax.shard_map`` region under a single ``jax.jit``,
so XLA fuses across relational boundaries exactly as CGen+icc fused the
generated C++.  There is no runtime scheduler and no master (paper §2.2).

The per-shard program is no longer derived node-by-node from the logical
plan: lowering first runs the property-driven physical planner
(core/physical_plan.py), which decides where hash exchanges and local sorts
are actually REQUIRED, and this module merely executes the resulting op list.
Capacity planning also lives with the physical plan — an elided exchange
means smaller buffers, not just fewer collectives.
"""
from __future__ import annotations

import functools
import os
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import distribution as D
from . import ir, physical as phys
from . import physical_plan as pp
from ..kernels import registry as kreg
from .compat import shard_map as _compat_shard_map
from .dtypes import NULL_CODE, is_category, physical_dtype
from .expr import ExternalArray, evaluate, nulltag_for
from .table import DTable, pad_to


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclass
class ExecConfig:
    """Execution configuration (capacity planning + physical choices)."""

    mesh: Any = None                  # jax Mesh; default: all local devices, axis "data"
    axes: tuple[str, ...] = ("data",)
    # capacity policy: "safe" bounds every buffer by the worst case (tests);
    # otherwise capacities are input_cap * slack and overflow is flagged.
    safe_capacities: bool = True
    shuffle_slack: float = 2.0
    join_expansion: float = 1.5
    # physical choices (§Perf levers)
    exscan_method: str = "allgather"  # or "ladder"
    broadcast_join: bool = True       # beyond-paper: REP side joins without shuffle
    # use_pallas: the ONE kernel-backend lever.  "off" runs every hot-path
    # primitive as its lax composition (ref backend); "interpret" runs the
    # Pallas kernels under the interpreter (CPU CI, numerics debugging);
    # "compiled" compiles them for the accelerator (TPU).  Empty string
    # defers to $HIFRAMES_USE_PALLAS, defaulting to "off".  Backends are a
    # numerics swap only — the physical plan is identical in all modes.
    use_pallas: str = ""
    # deprecated alias for use_pallas="interpret" (the pre-registry bool).
    use_kernels: bool = False
    optimize_plan: bool = True
    # property-driven exchange/sort elision (core/physical_plan.py); False
    # restores the exchange-per-operator baseline — the A/B lever for
    # benchmarks and a safety valve.
    elide_exchanges: bool = True
    # -- shuffle engine v2 levers (both A/B-gated like elide_exchanges) -----
    # packed_exchange: ship ALL columns of an exchange as ONE word-packed
    # (P, bucket, W) uint32 payload — exactly 2 all_to_all per exchange
    # (counts + payload) instead of 1 + n_columns.  False restores the
    # per-column-collective baseline.
    packed_exchange: bool = True
    # partial_agg: split a shuffling aggregate with decomposable agg fns
    # into PartialAgg -> HashExchange -> FinalAgg, so each shard ships at
    # most its DISTINCT local key groups instead of all raw rows.
    partial_agg: bool = True
    # agg_group_cap: optional user bound on distinct groups per shard; when
    # set, PartialAgg buffers (and the post-partial exchange bucket) shrink
    # to it.  Overflow-flagged and doubled by the collect() retry loop.
    agg_group_cap: int | None = None
    # capacity-overflow auto-retry (runtime/ft.py semantics, built into
    # collect): replan with doubled expansion, at most this many times.
    auto_retry: int = 3
    # -- adaptive statistics (core/stats.py; docs/adaptive_planning.md) -----
    # adaptive_stats: build a sampled StatsContext per plan and let it make
    # planner DECISIONS: salted skew joins, cheaper-side re-exchange for
    # mixed-alignment joins, and PartialAgg auto-capacity from the
    # distinct-count estimate (plus realized feedback from previous runs of
    # the same plan fingerprint).  Off by default: plans are byte-identical
    # to the stats-blind planner.  explain() annotates estimates either way.
    adaptive_stats: bool = False
    # salt_threshold: sampled key frequency above which a join key counts as
    # a heavy hitter and gets salted across salt_factor sub-partitions.
    # Halved automatically when realized feedback shows shard skew.
    salt_threshold: float = 0.1
    salt_factor: int = 8
    # stats_sample: rows sampled per base table (even-position, like
    # sample_sort's splitter sampling).
    stats_sample: int = 256
    # stats_cap_slack: headroom multiplier on SAMPLED estimates when they
    # size buffers (realized feedback is exact and gets none).  Doubled by
    # the overflow-retry loop alongside shuffle_slack.
    stats_cap_slack: float = 2.0

    def __post_init__(self):
        if not self.use_pallas:
            self.use_pallas = os.environ.get("HIFRAMES_USE_PALLAS", "off")
        if self.use_kernels and self.use_pallas == "off":
            self.use_pallas = "interpret"
        if self.use_pallas not in kreg.MODES:
            raise ValueError(
                f"use_pallas must be one of {kreg.MODES}, "
                f"got {self.use_pallas!r}")

    def get_mesh(self) -> Mesh:
        if self.mesh is not None:
            return self.mesh
        devs = np.array(jax.devices())
        return Mesh(devs.reshape((len(devs),)), ("data",))


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------


def _cnt_tag(scan_id: int) -> str:
    """Reserved ext-group tag carrying a persisted scan's (P,) count vector
    (kept out of the scans group so the shard_map signature stays stable)."""
    return f"__cnt:{scan_id}"


class Lowered:
    """A compiled physical plan: callable on (possibly fresh) source arrays."""

    def __init__(self, root: ir.Node, cfg: ExecConfig, dists: dict[int, str],
                 pplan: pp.PhysicalPlan):
        self.root = root
        self.cfg = cfg
        self.dists = dists
        self.pplan = pplan
        self.kernels = kreg.resolve(cfg.use_pallas)
        self.mesh = cfg.get_mesh()
        self.P = int(np.prod([self.mesh.shape[a] for a in cfg.axes]))
        self._build()

    # -- input marshalling ---------------------------------------------------

    def _gather_inputs(self):
        scans = [n for n in ir.topo_order(self.root) if isinstance(n, ir.Scan)]
        exts: dict[str, Any] = {}
        ext_caps: dict[str, int] = {}
        for n in ir.topo_order(self.root):
            for e in _node_exprs(n):
                for sub in _walk_expr(e):
                    if isinstance(sub, ExternalArray):
                        exts[sub.tag] = sub.array
                        child = n.children[0] if n.children else n
                        ext_caps[sub.tag] = self.pplan.final_op(child).cap
        self._ext_caps = ext_caps
        return scans, exts

    def _build(self):
        cfg, mesh, axes = self.cfg, self.mesh, self.cfg.axes
        scans, exts = self._gather_inputs()
        self.scans, self.exts = scans, exts
        # persisted scans whose device shards re-enter directly (no host
        # round-trip): their per-shard valid counts ride in as a sharded
        # (P,) vector instead of being derived from a block row count.  The
        # vector travels in the ext input group under a reserved tag, so the
        # shard_map signature (scans, ext) stays stable.
        self.dev_scans = {s.id for s in scans
                          if s.layout is not None
                          and s.layout.device_valid(self.P)
                          and self.dists[s.id] != D.REP}

        in_specs = {"scans": {}, "ext": {}}
        for s in scans:
            rep = self.dists[s.id] == D.REP
            spec = P() if rep else P(axes)
            in_specs["scans"][str(s.id)] = {c: spec for c in s.columns}
            if s.id in self.dev_scans:
                in_specs["ext"][_cnt_tag(s.id)] = P(axes)
        for tag in exts:
            in_specs["ext"][tag] = P(axes)

        out_specs = {"cols": {c: P(axes) for c in self.root.schema},
                     "count": P(axes), "overflow": P(axes)}

        root = self.root
        pplan = self.pplan
        kernels = self.kernels

        def per_shard(inputs):
            rank = phys.my_rank(axes)
            env: dict[int, tuple[dict, Any]] = {}
            flags = []
            ext = {f"ext:{t}": v for t, v in inputs["ext"].items()}

            for op in pplan.ops:
                n = op.node
                ax = axes if op.dist != D.REP else ()

                if isinstance(op, pp.Source):
                    cols = inputs["scans"][str(n.id)]
                    if _cnt_tag(n.id) in inputs["ext"]:
                        # persisted device shards: this shard's valid count
                        # arrives sharded off the (P,) layout vector.
                        cnt = inputs["ext"][_cnt_tag(n.id)][0].astype(jnp.int32)
                    else:
                        rows = inputs["rows"][str(n.id)]   # static int
                        if op.dist == D.REP:
                            cnt = jnp.int32(rows)
                        else:
                            cnt = jnp.clip(rows - rank * op.cap, 0,
                                           op.cap).astype(jnp.int32)
                    res = (dict(cols), cnt)

                elif isinstance(op, pp.Compact):
                    cols, cnt = env[op.inputs[0]]
                    env_e = dict(cols)
                    env_e.update(ext)
                    pred = evaluate(n.pred, env_e)
                    keep = pred & phys.valid_mask(
                        cnt, next(iter(cols.values())).shape[0])
                    out, cnt2, ovf = phys.compact(cols, keep, op.cap,
                                                  kernels=kernels)
                    flags.append(ovf)
                    res = (out, cnt2)

                elif isinstance(op, pp.Map):
                    cols, cnt = env[op.inputs[0]]
                    env_e = dict(cols)
                    env_e.update(ext)
                    cache: dict = {}
                    out = {}
                    for name, e in n.cols.items():
                        v = evaluate(e, env_e, cache)
                        cap = next(iter(cols.values())).shape[0]
                        out[name] = jnp.broadcast_to(v, (cap,)) if v.ndim == 0 else v
                    res = (out, cnt)

                elif isinstance(op, pp.WindowOp):
                    cols, cnt = env[op.inputs[0]]
                    env_e = dict(cols)
                    env_e.update(ext)
                    x = (evaluate(n.expr, env_e)
                         if n.expr is not None else None)
                    if n.partition_by:
                        # grouped layout established upstream (hash exchange
                        # + local sort, possibly elided): segment kernels,
                        # no collectives.
                        pk = tuple(cols[k] for k in n.partition_by)
                        if n.kind == "cumsum":
                            tag = nulltag_for(n.expr, n.children[0].schema)
                            col = phys.segment_cumsum(x, pk, cnt,
                                                      kernels=kernels,
                                                      nulltag=tag)
                        elif n.kind == "stencil":
                            col = phys.segment_stencil1d(x, pk, cnt,
                                                         n.weights, n.center,
                                                         exact=n.exact,
                                                         kernels=kernels)
                        else:
                            ok = tuple(cols[k] for k in n.order_by)
                            col = phys.segment_rank(pk, ok, cnt, n.kind,
                                                    kernels=kernels)
                    elif n.kind == "cumsum":
                        tag = nulltag_for(n.expr, n.children[0].schema)
                        nullm = phys.null_mask(x, tag)
                        if nullm is not None:   # pandas: nulls stay null,
                            x = jnp.where(nullm, jnp.zeros((), x.dtype), x)
                        col = phys.dist_cumsum(x, cnt, ax,
                                               method=cfg.exscan_method,
                                               kernels=kernels)
                        if nullm is not None:   # the running total skips them
                            col = jnp.where(
                                nullm,
                                phys.null_value(col.dtype, tag).astype(col.dtype),
                                col)
                    else:
                        col = phys.stencil1d(x, cnt, n.weights, n.center, ax,
                                             kernels=kernels, exact=n.exact)
                    out = dict(cols)
                    out[n.out] = col
                    res = (out, cnt)

                elif isinstance(op, pp.HashExchange):
                    cols, cnt = env[op.inputs[0]]
                    out, cnt2, ovf = phys.shuffle_by_key(
                        cols, cnt, op.keys, axes=axes,
                        bucket_cap=op.bucket, cap_out=op.cap,
                        kernels=kernels, packed=cfg.packed_exchange)
                    flags.append(ovf)
                    res = (out, cnt2)

                elif isinstance(op, pp.LocalSort):
                    cols, cnt = env[op.inputs[0]]
                    out, _ = phys.local_sort(cols, cnt, op.keys)
                    res = (out, cnt)

                elif isinstance(op, pp.MergeJoin):
                    lcols, lcnt = env[op.inputs[0]]
                    rcols, rcnt = env[op.inputs[1]]
                    lon, ron = n.left_on, n.right_on
                    if op.salted:
                        # join on keys+salt: each (probe, build) key match
                        # agrees on exactly one salt (see pp.SaltOp).
                        lon = lon + (phys.SALT_COL,)
                        ron = ron + (phys.SALT_COL,)
                    smap = {c: n.right_out_name(c) for c in rcols
                            if c not in ron}
                    out, cnt2, ovf = phys.merge_join(
                        lcols, lcnt, rcols, rcnt, lon, ron,
                        cap_out=op.cap, r_suffix_map=smap, how=n.how,
                        null_fill=_join_null_fill(n))
                    flags.append(ovf)
                    out.pop(phys.SALT_COL, None)    # strip probe-side salt
                    res = (out, cnt2)

                elif isinstance(op, pp.SaltOp):
                    cols, cnt = env[op.inputs[0]]
                    if op.build:
                        out, cnt2, ovf = phys.salt_build(
                            cols, cnt, op.keys, op.hot, op.R,
                            cap_out=op.cap, kernels=kernels)
                        flags.append(ovf)
                    else:
                        out, cnt2 = phys.salt_probe(cols, cnt, op.keys,
                                                    op.hot, op.R)
                    res = (out, cnt2)

                elif isinstance(op, pp.AggPrep):
                    cols, cnt = env[op.inputs[0]]
                    env_e = dict(cols)
                    env_e.update(ext)
                    cache = {}
                    key0 = cols[n.key[0]]
                    out = {k: cols[k] for k in n.key}
                    for name, agg in n.aggs.items():
                        arr = (evaluate(agg.expr, env_e, cache)
                               if agg.expr is not None
                               else jnp.zeros_like(key0, dtype=jnp.int32))
                        if arr.ndim == 0:
                            arr = jnp.broadcast_to(arr, key0.shape)
                        out["__v_" + name] = arr
                    res = (out, cnt)

                elif isinstance(op, pp.PartialAgg):
                    cols, cnt = env[op.inputs[0]]
                    tags = _agg_nulltags(n)
                    values = {name: (agg.fn, cols["__v_" + name],
                                     agg.skipna, tags[name])
                              if tags[name] is not None
                              else (agg.fn, cols["__v_" + name])
                              for name, agg in n.aggs.items()}
                    keys = tuple(cols[k] for k in n.key)
                    out, n_seg, ovf = phys.partial_aggregate(
                        keys, cnt, values, cap_out=op.cap, kernels=kernels)
                    flags.append(ovf)
                    res = (_restore_key_names(out, n.key), n_seg)

                elif isinstance(op, pp.SegmentAgg):
                    cols, cnt = env[op.inputs[0]]
                    keys = tuple(cols[k] for k in n.key)
                    tags = _agg_nulltags(n)
                    if op.from_partials:
                        fns = {name: (agg.fn, agg.skipna, tags[name])
                               if tags[name] is not None else agg.fn
                               for name, agg in n.aggs.items()}
                        out, n_seg, ovf = phys.final_aggregate(
                            keys, cnt, fns,
                            cols, cap_out=op.cap, kernels=kernels)
                    else:
                        values = {name: (agg.fn, cols["__v_" + name],
                                         agg.skipna, tags[name])
                                  if tags[name] is not None
                                  else (agg.fn, cols["__v_" + name])
                                  for name, agg in n.aggs.items()}
                        out, n_seg, ovf = phys.segment_aggregate(
                            keys, cnt, values, cap_out=op.cap,
                            kernels=kernels,
                            presorted=(op.nunique_ride,)
                            if op.nunique_ride else ())
                    flags.append(ovf)
                    res = (_restore_key_names(out, n.key), n_seg)

                elif isinstance(op, pp.SampleSort):
                    cols, cnt = env[op.inputs[0]]
                    out, cnt2, ovf = phys.sample_sort(
                        cols, cnt, n.by, axes=ax, bucket_cap=op.bucket,
                        cap_out=op.cap, ascending=n.ascending,
                        pre_sorted=op.pre_sorted, kernels=kernels,
                        packed=cfg.packed_exchange)
                    flags.append(ovf)
                    res = (out, cnt2)

                elif isinstance(op, pp.LimitOp):
                    cols, cnt = env[op.inputs[0]]
                    out, cnt2 = phys.limit(cols, cnt, n.n, ax, cap_out=op.cap)
                    res = (out, cnt2)

                elif isinstance(op, pp.RebalanceOp):
                    cols, cnt = env[op.inputs[0]]
                    out, cnt2, ovf = phys.rebalance(
                        cols, cnt, axes=axes, bucket_cap=op.bucket,
                        cap_out=op.cap, kernels=kernels,
                        packed=cfg.packed_exchange)
                    flags.append(ovf)
                    res = (out, cnt2)

                elif isinstance(op, pp.ConcatOp):
                    parts = [env[i] for i in op.inputs]
                    out, cnt, ovf = phys.concat(parts, op.cap, kernels=kernels)
                    flags.append(ovf)
                    res = (out, cnt)

                else:
                    raise TypeError(op)

                env[op.op_id] = res

            cols, cnt = env[pplan.root_id]
            ovf = functools.reduce(jnp.logical_or, flags, jnp.array(False))
            return {"cols": {k: cols[k] for k in root.schema},
                    "count": cnt.reshape(1),
                    "overflow": ovf.reshape(1)}

        # rows are static python ints — closed over, not traced.
        self._per_shard = per_shard
        self._in_specs = in_specs
        self._out_specs = out_specs

    # -- public call -----------------------------------------------------------

    def _prepare(self, scan_arrays=None):
        """Marshal inputs and return the (cached) jitted shard_map callable.

        The jit is cached per source-row signature: rebuilding the closure on
        every call would otherwise retrace+recompile per execution (measured
        as a 50x CPU slowdown in the benchmark harness).
        """
        mesh, Pn = self.mesh, self.P
        inputs = {"scans": {}, "ext": {}, "rows": {}}
        for s in self.scans:
            overridden = scan_arrays is not None and str(s.id) in scan_arrays
            src = scan_arrays[str(s.id)] if overridden else s.columns
            lay = s.layout
            if s.id in self.dev_scans:
                if overridden:
                    raise ValueError(
                        "cannot override columns of a persisted scan "
                        f"({s.name!r}): its buffers carry a device layout; "
                        "rebuild the input with hf.table(...) instead")
                # persisted device shards: feed the (P*cap,) arrays and the
                # (P,) count vector straight through — no host round-trip,
                # no padding pass.  rows is only the jit-cache key.
                inputs["scans"][str(s.id)] = {c: v for c, v in src.items()}
                inputs["ext"][_cnt_tag(s.id)] = jnp.asarray(
                    np.asarray(lay.counts, dtype=np.int32))
                inputs["rows"][str(s.id)] = lay.rows()
                continue
            if lay is not None and lay.counts is not None and not overridden:
                # shard-count mismatch: gather the valid prefixes on the
                # host and re-enter as a plain block table (layout claims
                # were already dropped at planning time).
                src = lay.gather_host(src)
            rows = len(next(iter(src.values())))
            cap = self.pplan.final_op(s).cap
            rep = self.dists[s.id] == D.REP
            n_pad = rows if rep else Pn * cap
            inputs["scans"][str(s.id)] = {
                c: jnp.asarray(pad_to(np.asarray(v), n_pad)) for c, v in src.items()}
            inputs["rows"][str(s.id)] = rows
        for tag, arr in self.exts.items():
            a = np.asarray(arr)
            cap = self._ext_caps[tag]
            inputs["ext"][tag] = jnp.asarray(pad_to(a, Pn * cap))

        rows_static = dict(inputs["rows"])
        key = tuple(sorted(rows_static.items()))
        if not hasattr(self, "_jit_cache"):
            self._jit_cache = {}
        if key not in self._jit_cache:
            def wrapped(scan_cols, ext_cols):
                return self._per_shard({"scans": scan_cols, "ext": ext_cols,
                                        "rows": rows_static})

            shard_fn = _compat_shard_map(
                wrapped, mesh=mesh,
                in_specs=(self._in_specs["scans"], self._in_specs["ext"]),
                out_specs=self._out_specs, check_vma=False)
            self._jit_cache[key] = jax.jit(shard_fn)
        return self._jit_cache[key], inputs

    def hlo_text(self, optimized: bool = True) -> str:
        """The (optimized) HLO of the whole plan — used by the UDF-identity
        benchmark (paper Fig. 10) and by EXPLAIN-style tooling."""
        fn, inputs = self._prepare()
        lowered = fn.lower(inputs["scans"], inputs["ext"])
        return lowered.compile().as_text() if optimized else lowered.as_text()

    def __call__(self, scan_arrays: dict[str, dict[str, np.ndarray]] | None = None):
        """Execute.  scan_arrays overrides source columns by scan id (str)."""
        fn, inputs = self._prepare(scan_arrays)
        out = fn(inputs["scans"], inputs["ext"])
        cap = self.pplan.root_op.cap
        return DTable(columns=out["cols"], counts=out["count"],
                      capacity=cap, nshards=self.P, dist=self.dists[self.root.id],
                      overflow=bool(np.any(np.asarray(out["overflow"]))))


def _agg_nulltags(n: ir.Aggregate) -> dict[str, str | None]:
    """Per-output null tag for an Aggregate's value expressions, decided
    from the child's LOGICAL schema (None = exact pre-null code path)."""
    sch = n.children[0].schema
    return {name: nulltag_for(agg.expr, sch) for name, agg in n.aggs.items()}


def _join_null_fill(n: ir.Join) -> dict[str, Any] | None:
    """Unmatched-row fill values for a left join's right columns, from the
    right child's logical schema: null code for categories, NaN for floats
    (matching the nullable output schema ir.Join declares); int columns
    keep the legacy zero-fill + ``_matched`` flag."""
    if n.how != "left":
        return None
    fill: dict[str, Any] = {}
    for c, dt in n.children[1].schema.items():
        if c in n.right_on:
            continue
        if is_category(dt):
            fill[c] = NULL_CODE
        elif np.issubdtype(physical_dtype(dt), np.floating):
            fill[c] = np.nan
    return fill or None


def _restore_key_names(out: dict, key: tuple[str, ...]) -> dict:
    """Segment-aggregation outputs name key columns ``__key<i>__`` in key
    order; restore the real names, keeping them FIRST (schema order)."""
    renamed = {k: out.pop(f"__key{i}__") for i, k in enumerate(key)}
    renamed.update(out)
    return renamed


def _node_exprs(n: ir.Node):
    if isinstance(n, ir.Filter):
        yield n.pred
    elif isinstance(n, ir.Project):
        yield from n.cols.values()
    elif isinstance(n, ir.Aggregate):
        for a in n.aggs.values():
            if a.expr is not None:
                yield a.expr
    elif isinstance(n, ir.Window):
        if n.expr is not None:
            yield n.expr


def _walk_expr(e):
    yield e
    for c in e.children:
        yield from _walk_expr(c)


def lower(root: ir.Node, cfg: ExecConfig | None = None,
          keep: set[str] | None = None, collect_block: bool = False,
          force_rep: set[int] = frozenset()) -> tuple[Lowered, dict]:
    """optimize -> infer distributions -> insert rebalance -> plan physical
    ops (exchange/sort elision) -> plan capacities -> build executor.

    Kernel backends (``cfg.use_pallas``) play no part here: the physical
    plan is backend-oblivious; ``Lowered`` resolves the registry when it
    builds the per-shard program.
    """
    from . import optimizer as opt

    cfg = cfg or ExecConfig()
    stats: dict = {}
    if cfg.optimize_plan:
        root, stats = opt.optimize(root, keep)
    info = D.infer(root, force_rep=force_rep,
                   broadcast_join=cfg.broadcast_join)
    root = D.insert_rebalance(root, info, collect_block=collect_block)
    mesh = cfg.get_mesh()
    Pn = int(np.prod([mesh.shape[a] for a in cfg.axes]))
    order = ir.topo_order(root)
    source_rows = {n.id: pp.scan_rows(n)
                   for n in order if isinstance(n, ir.Scan)}
    sctx = None
    if cfg.adaptive_stats:
        from . import stats as st
        sctx = st.analyze(root, cfg)
    pplan = pp.plan_physical(root, info.dists, cfg, stats=sctx)
    pp.plan_capacities(pplan, Pn, cfg, source_rows)
    return Lowered(root, cfg, info.dists, pplan), stats

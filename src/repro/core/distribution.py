r"""Distribution inference — the Distributed-Pass analogue (paper §4.4).

HPAT infers a distribution for every array/parfor by fixed-point iteration
over a meet-semilattice; HiFrames extends the lattice with 1D_VAR for the
data-dependent output sizes of relational operations (paper Fig. 7):

        1D_BLOCK            (top: even block rows per rank)
           |
        1D_VAR              (variable valid-prefix per rank)
        /    \
    2D_BLOCK  |             (block-cyclic for linear algebra; meet with 1D -> REP)
        \    /
         REP                (bottom: replicated / sequential)

On TPU the *carrier* of 1D_VAR changes (static capacity + per-shard count —
see DESIGN.md §2) but the lattice, the transfer functions, and the
rebalance-only-when-needed rule are implemented verbatim.

Composite (multi-column) keys do not change the lattice: Join/Aggregate/Sort
carry key TUPLES in the IR, but their transfer functions depend only on node
shape (data-dependent output length => 1D_VAR), never on key arity — the
physical layer routes on a combined hash so co-location still holds.

This pass decides WHERE rows may live; HOW they move is decided downstream
by the property-driven physical planner (core/physical_plan.py), which seeds
its partitioning properties from these lattice elements (REP scans provide
"rep" — satisfying every co-location requirement — everything else starts
"block") and inserts exchanges only where a required property is missing.
"""
from __future__ import annotations

from dataclasses import dataclass

from . import ir

# Lattice elements, ordered by "height" (higher = more structured).
ONE_D = "1D_BLOCK"
ONE_D_VAR = "1D_VAR"
TWO_D = "2D_BLOCK"
REP = "REP"

_HEIGHT = {ONE_D: 3, ONE_D_VAR: 2, TWO_D: 2, REP: 0}


def meet(a: str, b: str) -> str:
    """Greatest lower bound in the semilattice of Fig. 7."""
    if a == b:
        return a
    # 2D is incomparable with the 1D chain: meet is REP.
    if TWO_D in (a, b):
        return REP
    if REP in (a, b):
        return REP
    # remaining: {1D_BLOCK, 1D_VAR} -> 1D_VAR
    return ONE_D_VAR


def leq(a: str, b: str) -> bool:
    """Partial order: a ⊑ b iff meet(a, b) == a."""
    return meet(a, b) == a


# Nodes whose OUTPUT length is data-dependent (=> at most 1D_VAR).
# Limit rides along: its per-shard count depends on how rows were
# distributed upstream, so it can't promise 1D_BLOCK either.
_VAR_OUT = (ir.Filter, ir.Join, ir.Aggregate, ir.Limit)


def scan_seed(n: ir.Scan) -> str:
    """Lattice element a Scan provides: plain host tables are 1D_BLOCK; a
    persisted scan re-enters at the element its producing plan satisfied
    (typically 1D_VAR — per-shard counts vary)."""
    return n.layout.dist if n.layout is not None else ONE_D


def requires_block(n: ir.Node) -> bool:
    """Nodes that REQUIRE 1D_BLOCK inputs: GLOBAL stencil neighborhoods assume
    even blocks (cumsum masks validity and accepts 1D_VAR); matrix assembly
    for ML does too (handled via collect_block).  PARTITIONED windows never
    do — their groups are made shard-local by a hash exchange and taps never
    cross a group edge, so no halo is needed."""
    return (isinstance(n, ir.Window) and n.kind == "stencil"
            and not n.partition_by)


def is_partitioned_window(n: ir.Node) -> bool:
    """Partitioned windows redistribute rows (hash on the partition keys), so
    their output length per shard is data-dependent: at most 1D_VAR."""
    return isinstance(n, ir.Window) and bool(n.partition_by)


@dataclass
class DistInfo:
    dists: dict[int, str]           # node id -> lattice element
    rebalanced: set[int]            # node ids under which a Rebalance was inserted


def infer(root: ir.Node, *, force_rep: set[int] = frozenset(),
          broadcast_join: bool = True) -> DistInfo:
    """Fixed-point distribution inference + rebalance insertion.

    ``force_rep``: node ids the caller pins to REP (e.g. tiny broadcast
    tables).  ``broadcast_join``: beyond-paper rule — a Join whose right input
    is REP keeps the left distribution (no shuffle, no sequentialization);
    with it disabled the paper's plain meet applies and REP poisons the join.

    Returns the annotation map.  The caller then calls :func:`insert_rebalance`
    to materialize Rebalance nodes where a 1D_VAR producer feeds a
    1D_BLOCK-requiring consumer — the paper's "rebalance only when necessary".
    """
    order = ir.topo_order(root)
    dist: dict[int, str] = {}

    # Initialize at top (1D_BLOCK), pin forced nodes.
    for n in order:
        dist[n.id] = REP if n.id in force_rep else ONE_D

    changed = True
    while changed:
        changed = False
        for n in order:
            d = dist[n.id]
            new = d
            is_bcast_join = (broadcast_join and isinstance(n, ir.Join)
                             and dist[n.right.id] == REP
                             and dist[n.left.id] != REP)
            if isinstance(n, ir.Scan):
                new = meet(new, scan_seed(n))
            elif is_bcast_join:
                new = meet(ONE_D_VAR, dist[n.left.id])
            elif is_partitioned_window(n):
                new = meet(ONE_D_VAR, dist[n.child.id])
            elif isinstance(n, _VAR_OUT):
                # out = 1D_VAR ∧ dist[in1] ∧ dist[in2] ...   (paper §4.4)
                new = ONE_D_VAR
                for c in n.children:
                    new = meet(new, dist[c.id])
            elif requires_block(n):
                # consumes blocks; output is 1D_BLOCK unless an input is REP.
                new = ONE_D
                for c in n.children:
                    if dist[c.id] == REP:
                        new = REP
            elif isinstance(n, ir.Concat):
                new = ONE_D_VAR
                for c in n.children:
                    new = meet(new, dist[c.id])
            elif isinstance(n, ir.Rebalance):
                new = ONE_D if dist[n.child.id] != REP else REP
            elif isinstance(n, ir.Sort):
                new = ONE_D_VAR if dist[n.child.id] != REP else REP
            elif isinstance(n, ir.Repartition):
                if n.by:
                    # hash exchange: per-shard counts become data-dependent
                    new = meet(ONE_D_VAR, dist[n.child.id])
                else:
                    # sort_within_partitions: no row movement, pass-through
                    new = meet(new, dist[n.child.id])
            else:  # Project / Window-like pass-through
                for c in n.children:
                    new = meet(new, dist[c.id])
            if n.id in force_rep:
                new = REP
            if new != d:
                dist[n.id] = new
                changed = True
            # REP inputs make relational ops sequential: propagate the meet
            # back to the inputs (paper: "all input and output arrays of an
            # aggregate should be replicated if any of them is").
            if ((isinstance(n, _VAR_OUT) or is_partitioned_window(n))
                    and dist[n.id] == REP and not is_bcast_join):
                for c in n.children:
                    if dist[c.id] != REP:
                        dist[c.id] = REP
                        changed = True
    return DistInfo(dists=dist, rebalanced=set())


def insert_rebalance(root: ir.Node, info: DistInfo,
                     collect_block: bool = False) -> ir.Node:
    """Insert Rebalance nodes exactly where 1D_VAR meets a 1D_BLOCK consumer."""

    memo: dict[int, ir.Node] = {}

    def need_block_child(parent: ir.Node) -> bool:
        return requires_block(parent)

    def rec(n: ir.Node) -> ir.Node:
        if n.id in memo:
            return memo[n.id]
        new_children = tuple(rec(c) for c in n.children)
        out = n if new_children == n.children else n.with_children(new_children)
        if out is not n:
            info.dists[out.id] = info.dists[n.id]
        if need_block_child(n):
            fixed = []
            for c_old, c_new in zip(n.children, out.children):
                if info.dists[c_old.id] == ONE_D_VAR:
                    rb = ir.Rebalance(c_new)
                    info.dists[rb.id] = ONE_D
                    info.rebalanced.add(rb.id)
                    fixed.append(rb)
                else:
                    fixed.append(c_new)
            if tuple(fixed) != out.children:
                out2 = out.with_children(tuple(fixed))
                info.dists[out2.id] = info.dists[n.id]
                out = out2
        memo[n.id] = out
        return out

    new_root = rec(root)
    if collect_block and info.dists[new_root.id] == ONE_D_VAR:
        rb = ir.Rebalance(new_root)
        info.dists[rb.id] = ONE_D
        info.rebalanced.add(rb.id)
        new_root = rb
    return new_root

"""Relational optimizer — the DataFrame-Pass analogue (paper §4.3).

The paper builds a query tree over *only* the relational nodes of a general
program AST and applies rule-based rewrites after validating them against the
surrounding array code via liveness.  Here the plan IS the relational DAG
(array code hangs off it through Project/Window expressions and
ExternalArray leaves), so validity reduces to: a rewrite must not change the
multiset of rows feeding any *other* consumer of a shared subplan.  We check
consumer counts (DAG fan-out) before rewriting — the liveness analogue.

Rules implemented (fixed-point, bottom-up):
  * filter fusion             Filter(Filter(x,p),q)      -> Filter(x, p&q)
  * push predicate through project (rename-aware)
  * push predicate through join (the paper's flagship, Fig. 6)
  * push predicate through concat
  * column pruning            narrow Scans/Projects to live columns
  * redundant-sort removal    Sort(Sort(x,K1),K2) -> Sort(x,K2) when K1 is a
                              prefix of K2 (stability makes them identical);
                              Aggregate(Sort(x)) -> Aggregate(x) unless an
                              order-sensitive agg ("first") consumes the order

The logical sort rules complement the PHYSICAL exchange/sort elision in
core/physical_plan.py: the optimizer removes sorts whose *result* is
unobservable, the physical planner skips sorts/exchanges whose *effect* is
already provided by upstream data placement.
"""
from __future__ import annotations

from . import ir
from .expr import BinOp, ColRef, Expr


def _consumers(root: ir.Node) -> dict[int, int]:
    counts: dict[int, int] = {}
    for n in ir.topo_order(root):
        for c in n.children:
            counts[c.id] = counts.get(c.id, 0) + 1
    return counts


def column_provenance(root: ir.Node) -> dict[int, dict[str, tuple[int, str]]]:
    """For every node, map each output column to the SCAN column it is a pure
    pass-through of: node id -> {col name: (scan id, scan col)}.

    Only value-preserving paths count (renames, filters, joins carrying a
    side's columns, aggregate keys); computed columns and aggregate outputs
    have no entry.  This is the liveness-style analysis the sampled
    statistics pass (core/stats.py) uses to answer "which base-table sample
    describes this node's key columns" without materializing anything.
    """
    prov: dict[int, dict[str, tuple[int, str]]] = {}
    for n in ir.topo_order(root):
        if isinstance(n, ir.Scan):
            prov[n.id] = {c: (n.id, c) for c in n.columns}
        elif isinstance(n, ir.Project):
            child = prov.get(n.child.id, {})
            prov[n.id] = {out: child[e.name]
                          for out, e in n.cols.items()
                          if isinstance(e, ColRef) and e.name in child}
        elif isinstance(n, ir.Join):
            left = prov.get(n.left.id, {})
            right = prov.get(n.right.id, {})
            m = dict(left)                      # keys unified into left names
            for c, src in right.items():
                if c in n.right_on:
                    continue
                m.setdefault(n.right_out_name(c), src)
            prov[n.id] = m
        elif isinstance(n, ir.Aggregate):
            child = prov.get(n.child.id, {})
            prov[n.id] = {k: child[k] for k in n.key if k in child}
        elif isinstance(n, ir.Window):
            child = prov.get(n.child.id, {})
            prov[n.id] = {c: s for c, s in child.items() if c != n.out}
        elif isinstance(n, ir.Concat):
            prov[n.id] = {}                     # rows from multiple scans
        elif n.children:
            # Filter / Sort / Limit / Repartition / Rebalance: row-subset or
            # row-reorder ops — every column passes through by value.
            prov[n.id] = dict(prov.get(n.children[0].id, {}))
        else:
            prov[n.id] = {}
    return prov


# ---------------------------------------------------------------------------
# predicate pushdown
# ---------------------------------------------------------------------------


def _rename_refs(e: Expr, mapping: dict[str, str]) -> Expr:
    def fix(ref: ColRef) -> Expr:
        return ColRef(ref.table_id, mapping.get(ref.name, ref.name))
    return e.map_refs(fix)


def _try_push_filter(f: ir.Filter, fanout: dict[int, int]) -> ir.Node | None:
    child = f.child
    # Never push through a node another consumer also reads (liveness check —
    # the other consumer would observe the filtered rows).
    if fanout.get(child.id, 0) > 1:
        return None
    names = {n for (_tid, n) in f.pred.columns()}

    if isinstance(child, ir.Filter):
        fused = ir.Filter(child.child, BinOp("and", child.pred, f.pred))
        return fused

    if isinstance(child, ir.Project):
        # push only if every referenced output column is a pure rename
        mapping: dict[str, str] = {}
        for n in names:
            e = child.cols.get(n)
            if not isinstance(e, ColRef):
                return None
            mapping[n] = e.name
        new_pred = _rename_refs(f.pred, mapping)
        return child.with_children((ir.Filter(child.child, new_pred),))

    if isinstance(child, ir.Join):
        j = child
        lnames = set(j.left.schema)
        rnames_out = {j.right_out_name(n): n for n in j.right.schema
                      if n not in j.right_on}
        # Predicates over left columns (incl. the unified key columns) commute
        # with both inner and left joins: every output row carries its left
        # row's values unchanged, and a left join emits >= 1 row per left row.
        if names <= lnames:
            nl = ir.Filter(j.left, f.pred)
            return j.with_children((nl, j.right))
        # Right-side (or unified-key -> right) pushes are ONLY valid for
        # inner joins: below a how="left" join the filter would shrink the
        # right table, turning matched rows into zero-filled "unmatched"
        # output rows — post-join filtering drops them instead.
        if j.how == "inner" and names <= (set(rnames_out) | set(j.left_on)):
            mapping = dict(rnames_out)
            mapping.update(dict(zip(j.left_on, j.right_on)))
            np_ = _rename_refs(f.pred, mapping)
            nr = ir.Filter(j.right, np_)
            return j.with_children((j.left, nr))
        return None

    if isinstance(child, ir.Concat):
        parts = tuple(ir.Filter(p, f.pred) for p in child.parts)
        return child.with_children(parts)

    return None


def push_predicates(root: ir.Node) -> tuple[ir.Node, int]:
    """Apply pushdown rules to fixed point; returns (new_root, n_rewrites)."""
    n_rewrites = 0
    changed = True
    while changed:
        changed = False
        fanout = _consumers(root)
        memo: dict[int, ir.Node] = {}

        def rec(n: ir.Node) -> ir.Node:
            nonlocal changed, n_rewrites
            if n.id in memo:
                return memo[n.id]
            new_children = tuple(rec(c) for c in n.children)
            out = n if new_children == n.children else n.with_children(new_children)
            if isinstance(out, ir.Filter):
                pushed = _try_push_filter(out, fanout)
                if pushed is not None:
                    changed = True
                    n_rewrites += 1
                    out = pushed
            memo[n.id] = out
            return out

        root = rec(root)
    return root, n_rewrites


# ---------------------------------------------------------------------------
# column pruning (whole-plan liveness; paper: DCE removes unused columns)
# ---------------------------------------------------------------------------


def _required_columns(root: ir.Node, keep: set[str] | None) -> dict[int, set[str]]:
    """For every node, the set of its output columns actually consumed."""
    req: dict[int, set[str]] = {root.id: set(keep) if keep else set(root.schema)}
    for n in reversed(ir.topo_order(root)):
        need = req.setdefault(n.id, set(n.schema))
        if isinstance(n, ir.Filter):
            child_need = set(need) | {c for (_t, c) in n.pred.columns()}
            req.setdefault(n.child.id, set()).update(child_need)
        elif isinstance(n, ir.Project):
            child_need = set()
            for out_name, e in n.cols.items():
                if out_name in need:
                    child_need |= {c for (_t, c) in e.columns()}
            req.setdefault(n.child.id, set()).update(child_need)
        elif isinstance(n, ir.Join):
            lneed, rneed = set(n.left_on), set(n.right_on)
            lschema = n.left.schema
            for out_name in need:
                if out_name in lneed or (n.how == "left" and out_name == "_matched"):
                    continue  # _matched is synthesized by the join itself
                if out_name in lschema:
                    lneed.add(out_name)
                else:
                    base = out_name
                    if out_name.endswith(n.suffix) and out_name[: -len(n.suffix)] in lschema:
                        base = out_name[: -len(n.suffix)]
                    rneed.add(base)
            req.setdefault(n.left.id, set()).update(lneed)
            req.setdefault(n.right.id, set()).update(rneed)
        elif isinstance(n, ir.Aggregate):
            child_need = set(n.key)
            for name, agg in n.aggs.items():
                if name in need and agg.expr is not None:
                    child_need |= {c for (_t, c) in agg.expr.columns()}
            req.setdefault(n.child.id, set()).update(child_need)
        elif isinstance(n, ir.Window):
            child_need = set(need) - {n.out}
            if n.expr is not None:
                child_need |= {c for (_t, c) in n.expr.columns()}
            # partition/order keys are read by the segment kernels (and by
            # the exchange/sort the planner may insert): always live.
            child_need |= set(n.partition_by) | set(n.order_by)
            req.setdefault(n.child.id, set()).update(child_need)
        elif isinstance(n, ir.Sort):
            req.setdefault(n.child.id, set()).update(set(need) | set(n.by))
        elif isinstance(n, ir.Repartition):
            # the exchange/sort read the layout keys even when a downstream
            # consumer drops them
            req.setdefault(n.child.id, set()).update(
                set(need) | set(n.by) | set(n.sort_by))
        elif isinstance(n, ir.Concat):
            for c in n.parts:
                req.setdefault(c.id, set()).update(need)
        elif isinstance(n, (ir.Rebalance, ir.Limit)):
            req.setdefault(n.child.id, set()).update(need)
    return req


def prune_columns(root: ir.Node, keep: set[str] | None = None) -> tuple[ir.Node, int]:
    """Narrow Scan and Project nodes to live columns."""
    req = _required_columns(root, keep)
    pruned = 0
    memo: dict[int, ir.Node] = {}

    def rec(n: ir.Node) -> ir.Node:
        nonlocal pruned
        if n.id in memo:
            return memo[n.id]
        need = req.get(n.id, set(n.schema))
        if isinstance(n, ir.Scan):
            live = {k: v for k, v in n.columns.items() if k in need}
            if len(live) < len(n.columns):
                pruned += len(n.columns) - len(live)
                # persisted layouts survive pruning restricted to the live
                # columns (partitioning iff every key lives; ordering keeps
                # its surviving prefix) — the device shards still re-enter.
                lay = (n.layout.restrict(set(live))
                       if n.layout is not None else None)
                out = ir.Scan(n.name, live,
                              {k: v for k, v in n._schema.items() if k in live},
                              layout=lay)
                # keep the source's identity: distribution pins (force_rep
                # from DataFrame.replicate()) are id-based, and only SOURCE
                # pins are load-bearing — interior nodes re-derive REP via
                # the lattice meet.  Without this, pruning a broadcast
                # dimension table silently un-broadcasts it.
                out.id = n.id
            else:
                out = n
        else:
            new_children = tuple(rec(c) for c in n.children)
            out = n if new_children == n.children else n.with_children(new_children)
            if isinstance(out, ir.Project):
                live_cols = {k: v for k, v in out.cols.items() if k in need}
                if len(live_cols) < len(out.cols):
                    pruned += len(out.cols) - len(live_cols)
                    dts = ({k: v for k, v in out.dtypes.items()
                            if k in live_cols} if out.dtypes else None)
                    out = ir.Project(out.child, live_cols, dts)
            elif isinstance(out, ir.Aggregate):
                live_aggs = {k: v for k, v in out.aggs.items()
                             if k in need or k in out.key}
                if len(live_aggs) < len(out.aggs):
                    pruned += len(out.aggs) - len(live_aggs)
                    out = ir.Aggregate(out.child, out.key, live_aggs)
        memo[n.id] = out
        return out

    return rec(root), pruned


# ---------------------------------------------------------------------------
# redundant sorts (order destroyed or re-established downstream)
# ---------------------------------------------------------------------------


def drop_redundant_sorts(root: ir.Node) -> tuple[ir.Node, int]:
    """Remove Sort nodes whose effect is unobservable.

    * ``Sort(Sort(x, K1, asc), K2, asc)`` == ``Sort(x, K2, asc)`` when K1 is
      a prefix of K2: the outer stable sort re-establishes exactly the order
      the inner one contributed (ties on K2 are ties on K1, and stability
      reduces them to input order either way).
    * ``Aggregate(Sort(x), key)`` == ``Aggregate(x, key)``: aggregation is
      order-insensitive — EXCEPT for ``first``, which reads the in-group
      arrival order and pins the sort.

    Bypassing is per-edge, so a Sort shared with another consumer still runs
    for that consumer.
    """
    dropped = 0
    memo: dict[int, ir.Node] = {}

    def rec(n: ir.Node) -> ir.Node:
        nonlocal dropped
        if n.id in memo:
            return memo[n.id]
        new_children = tuple(rec(c) for c in n.children)
        out = n if new_children == n.children else n.with_children(new_children)
        if isinstance(out, ir.Sort):
            c = out.child
            if (isinstance(c, ir.Sort) and c.ascending == out.ascending
                    and c.by == out.by[: len(c.by)]):
                out = out.with_children((c.child,))
                dropped += 1
        elif isinstance(out, ir.Aggregate):
            c = out.child
            if (isinstance(c, ir.Sort)
                    and not any(a.fn == "first" for a in out.aggs.values())):
                out = out.with_children((c.child,))
                dropped += 1
        memo[n.id] = out
        return out

    return rec(root), dropped


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def optimize(root: ir.Node, keep: set[str] | None = None,
             enable: tuple[str, ...] = ("pushdown", "sorts", "prune")
             ) -> tuple[ir.Node, dict]:
    stats = {"pushdown": 0, "pruned_columns": 0, "sorts_dropped": 0}
    if "pushdown" in enable:
        root, k = push_predicates(root)
        stats["pushdown"] = k
    if "sorts" in enable:
        root, s = drop_redundant_sorts(root)
        stats["sorts_dropped"] = s
    if "prune" in enable:
        root, p = prune_columns(root, keep)
        stats["pruned_columns"] = p
    return root, stats

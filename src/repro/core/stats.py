"""Sampled statistics pass + realized-stats feedback store.

The property-driven planner (core/physical_plan.py) decides WHERE exchanges
and sorts go; this module tells it how much data moves and how it is
distributed, from two sources:

  * **Sampled estimates** — per base-table key statistics from a small
    evenly-spaced row sample (the same even-position idiom
    ``physical.sample_sort`` uses for splitter sampling; persisted device
    scans pay one tiny gather of the sampled positions instead of a full
    host round-trip).  Per key tuple we estimate the distinct count (GEE
    estimator: ``sqrt(n/r)*f1 + (d - f1)``) and the heavy hitters (sample
    frequency per distinct tuple).  Column provenance
    (optimizer.column_provenance) maps interior-node key columns back to the
    scan columns the sample describes, so a join or aggregate deep in the
    plan still gets estimates as long as its keys are pass-through.

  * **Realized feedback** — ``collect()``/``persist()`` record the ROOT
    result's per-shard counts under a structural fingerprint of the
    (optimized) plan.  A repeated query self-tunes: an aggregate whose
    fingerprint has realized counts sizes its partial-aggregation buffers
    from the exact group count instead of the sample estimate, and a join
    whose previous run showed shard-occupancy skew lowers its salting
    threshold on replan.  Fingerprints are structural (node kinds, key
    names, expression shapes, scan names/schemas/row counts) — node ids are
    process-local and never participate.

The planner consumes a :class:`StatsContext` in three places (ExecConfig
``adaptive_stats``): automatic ``agg_group_cap`` for PartialAgg, cheaper-side
re-exchange for mixed-alignment joins, and salted skew joins
(docs/adaptive_planning.md).  Every estimate is advisory — a missing or wrong
estimate degrades to the static rules plus the overflow-retry fallback, never
to a wrong answer.
"""
from __future__ import annotations

import hashlib
import json
import os
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from . import ir
from .dtypes import categories_of, is_category, is_nullable
from .expr import AggExpr, BinOp, ColRef, Const, Expr, ExternalArray, UnOp
from .optimizer import column_provenance

# Estimated frequency above which residual skew is worth salting away even
# when it costs re-exchanging an otherwise-aligned build side.
_OCCUPANCY_TRIGGER = 2.0        # realized max/mean shard ratio that flags skew
_MAX_HOT = 16                   # cap on tracked heavy hitters per key tuple


# ---------------------------------------------------------------------------
# sampling (even-position, per shard — the sample_sort splitter idiom)
# ---------------------------------------------------------------------------


def _even_positions(n: int, k: int) -> np.ndarray:
    """k evenly spaced positions in [0, n) (sample_sort's splitter spacing)."""
    k = max(0, min(int(n), int(k)))
    if k == 0:
        return np.zeros(0, np.int64)
    return (np.arange(k, dtype=np.int64) * n) // k


def sample_scan(scan: ir.Scan, columns: tuple[str, ...],
                sample: int) -> dict[str, np.ndarray]:
    """Evenly-spaced row sample of ``columns`` from a scan.

    Host tables index numpy directly.  Persisted device layouts sample each
    shard's valid prefix proportionally and gather ONLY the sampled
    positions (one tiny device->host transfer, not a shard round-trip).
    """
    lay = scan.layout
    if lay is not None and lay.counts is not None:
        cnts = np.asarray(lay.counts, dtype=np.int64)
        total = int(cnts.sum())
        if total == 0:
            return {c: np.zeros(0) for c in columns}
        pos = []
        for r in range(int(lay.nshards)):
            k = -(-sample * int(cnts[r]) // max(total, 1))   # proportional
            pos.append(r * int(lay.capacity) + _even_positions(int(cnts[r]), k))
        idx = np.concatenate(pos) if pos else np.zeros(0, np.int64)
        out = {}
        for c in columns:
            col = scan.columns[c]
            out[c] = np.asarray(col[idx.astype(np.int32)]) if idx.size \
                else np.zeros(0)
        return out
    n = len(next(iter(scan.columns.values()))) if scan.columns else 0
    idx = _even_positions(n, sample)
    return {c: np.asarray(scan.columns[c])[idx] for c in columns}


# ---------------------------------------------------------------------------
# estimators
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class KeyStats:
    """Estimates for one key tuple at one plan node."""

    rows: int                                   # total rows the sample covers
    sampled: int                                # sample size
    distinct: int                               # GEE distinct-count estimate
    # conservative sizing estimate: sample singletons extrapolate LINEARLY
    # (each may represent n/r unseen distinct values) instead of GEE's
    # sqrt(n/r).  GEE minimizes ratio error (best for join-row estimates);
    # the linear bound is what buffer sizing wants — a heavy-tailed (zipf)
    # key column under-samples its tail and would otherwise overflow.
    distinct_cap: int = 0
    heavy: tuple[tuple[tuple, float], ...] = ()  # (key values, sample freq)
    source: str = "sample"                      # "sample" | "realized"


def _tuple_counts(cols: list[np.ndarray]) -> dict[tuple, int]:
    counts: dict[tuple, int] = {}
    for row in zip(*(np.asarray(c).tolist() for c in cols)):
        counts[row] = counts.get(row, 0) + 1
    return counts


def estimate_keys(cols: list[np.ndarray], total_rows: int) -> KeyStats:
    """Distinct-count (GEE) + heavy-hitter estimates from a sample."""
    r = len(cols[0]) if cols else 0
    n = max(total_rows, 1)
    if r == 0:
        return KeyStats(total_rows, 0, n, n, ())
    counts = _tuple_counts(cols)
    d = len(counts)
    f1 = sum(1 for c in counts.values() if c == 1)
    est = int(np.sqrt(n / r) * f1 + (d - f1))
    est = max(d, min(est, n))
    cap_est = max(d, min(int((n / r) * f1 + (d - f1)), n))
    heavy = sorted(((k, c / r) for k, c in counts.items()),
                   key=lambda kv: -kv[1])[:_MAX_HOT]
    return KeyStats(total_rows, r, est, cap_est, tuple(heavy))


# ---------------------------------------------------------------------------
# realized-stats feedback store (per-plan-fingerprint)
# ---------------------------------------------------------------------------


@dataclass
class StatsStore:
    """The per-fingerprint feedback store: realized per-shard counts
    (consumed by :class:`StatsContext`) plus the retry/degradation event log
    (``runtime/retry.py``), unified so one sidecar persists both.

    The module holds one CURRENT store (process default); a long-lived
    ``runtime.session.Session`` installs its own via :func:`use_store` and
    persists it as a JSON sidecar under its ``session_dir``, so a restarted
    server plans warm (docs/serving.md).
    """

    realized: dict[str, dict] = field(default_factory=dict)
    events: dict[str, tuple] = field(default_factory=dict)

    # -- disk sidecar --------------------------------------------------------

    def save(self, path: str) -> None:
        """Atomically write the store as a JSON sidecar (tmp + rename, so a
        crashed writer leaves either the old file or a ``.tmp`` orphan —
        never a torn sidecar at ``path`` itself)."""
        from ..runtime.retry import RetryEvent
        doc = {"version": 1,
               "realized": self.realized,
               "events": {fp: [{"kind": e.kind, "attempt": e.attempt,
                                "op_id": e.op_id, "detail": e.detail}
                               for e in evs if isinstance(e, RetryEvent)]
                          for fp, evs in self.events.items()}}
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "StatsStore":
        """Load a sidecar written by :meth:`save`.

        A corrupt or partial file (truncated JSON, wrong shape, bad record
        types) raises a typed :class:`~repro.core.errors.StatsError` — the
        caller decides whether to quarantine and start cold
        (``Session(recover_stats=True)``) or surface the failure."""
        from ..runtime.retry import RetryEvent
        from .errors import StatsError
        try:
            with open(path) as f:
                doc = json.load(f)
            if not isinstance(doc, dict) or doc.get("version") != 1:
                raise ValueError(f"unrecognized sidecar shape: "
                                 f"{type(doc).__name__}")
            realized = {}
            for fp, rec in dict(doc.get("realized", {})).items():
                realized[str(fp)] = {"rows": int(rec["rows"]),
                                     "max": int(rec["max"]),
                                     "mean": float(rec["mean"]),
                                     "nshards": int(rec["nshards"])}
            events = {}
            for fp, evs in dict(doc.get("events", {})).items():
                events[str(fp)] = tuple(
                    RetryEvent(kind=str(e["kind"]), attempt=int(e["attempt"]),
                               op_id=int(e["op_id"]), detail=str(e["detail"]))
                    for e in evs)
        except OSError:
            raise
        except Exception as e:
            raise StatsError(
                f"corrupt stats sidecar {path!r}: {e} — delete the file (or "
                "start the session with recover_stats=True) to plan cold"
            ) from e
        st = cls()
        st.realized = realized
        st.events = events
        return st


_STORE = StatsStore()


def current_store() -> StatsStore:
    return _STORE


def use_store(store: StatsStore) -> StatsStore:
    """Install ``store`` as the process-current feedback store; returns the
    previous one (sessions swap their scoped store in on start)."""
    global _STORE
    prev = _STORE
    _STORE = store
    return prev


def _expr_sig(e: Optional[Expr]) -> str:
    if e is None:
        return "-"
    if isinstance(e, ColRef):
        return f"c:{e.name}"
    if isinstance(e, Const):
        v = e.value
        try:
            a = np.asarray(v)
            body = str(a.item()) if a.size == 1 else f"arr{a.shape}"
        except Exception:
            body = type(v).__name__
        return f"k:{body}"
    if isinstance(e, ExternalArray):
        return f"x:{np.asarray(e.array).shape}"
    if isinstance(e, (BinOp, UnOp)):
        kids = ",".join(_expr_sig(c) for c in e.children)
        return f"{e.op}({kids})"
    kids = ",".join(_expr_sig(c) for c in e.children)
    return f"{type(e).__name__}({kids})"


def _dtype_sig(d) -> str:
    """LOGICAL dtype signature: category columns hash their dictionary (two
    tables with the same int32 codes but different categories must never
    share a fingerprint — plan constants are code-space rewrites), and
    nullability marks with ``?``."""
    if is_category(d):
        cats = categories_of(d)
        h = hashlib.sha1("\x00".join(map(str, cats)).encode()).hexdigest()[:12]
        return f"cat[{len(cats)}:{h}]" + ("?" if is_nullable(d) else "")
    return np.dtype(d).str + ("?" if is_nullable(d) else "")


def _layout_sig(lay: Optional[ir.ScanLayout]) -> str:
    """The plan-shaping part of a ScanLayout: partitioning/ordering claims
    (they seed the physical planner) plus the device-carrier geometry
    (capacity/nshards fix the compiled buffer shapes)."""
    if lay is None:
        return "-"
    dev = (f"{lay.capacity}x{lay.nshards}" if lay.counts is not None
           else "host")
    return (f"{lay.kind}|{','.join(lay.partitioned_by)}|{int(lay.ascending)}"
            f"|{int(lay.globally_sorted)}|{','.join(lay.sorted_by)}"
            f"|{int(lay.order_ascending)}|{dev}|{lay.dist}")


def _scan_sig(n: ir.Scan, scans: str) -> str:
    sch = ",".join(f"{k}:{_dtype_sig(d)}" for k, d in n.schema.items())
    device = n.layout is not None and n.layout.counts is not None
    if scans == "shape":
        # identity-free: NO scan name, and no row count for device layouts
        # (per-shard counts ride in as runtime inputs; only the capacity
        # geometry shapes the trace).  Two registered tables with the same
        # schema + layout shape therefore share a plan-cache trace and
        # rebind data (docs/serving.md cache-key definition).
        rows = ("-" if device
                else len(next(iter(n.columns.values()))) if n.columns else 0)
        return f"Scan[{sch}|{_layout_sig(n.layout)}|{rows}]"
    rows = (n.layout.rows() if device
            else len(next(iter(n.columns.values()))) if n.columns else 0)
    return f"Scan[{n.name}|{sch}|{rows}]"


def _node_sig(n: ir.Node, scans: str = "identity") -> str:
    if isinstance(n, ir.Scan):
        return _scan_sig(n, scans)
    if isinstance(n, ir.Filter):
        return f"Filter[{_expr_sig(n.pred)}]"
    if isinstance(n, ir.Project):
        cols = ",".join(f"{k}={_expr_sig(e)}" for k, e in n.cols.items())
        return f"Project[{cols}]"
    if isinstance(n, ir.Join):
        return (f"Join[{','.join(n.left_on)}|{','.join(n.right_on)}"
                f"|{n.how}|{n.suffix}]")
    if isinstance(n, ir.Aggregate):
        aggs = ",".join(f"{k}:{a.fn}:{_expr_sig(a.expr)}"
                        for k, a in n.aggs.items())
        return f"Agg[{','.join(n.key)}|{aggs}]"
    if isinstance(n, ir.Window):
        return (f"Win[{n.kind}|{_expr_sig(n.expr)}|{n.out}|{n.weights}"
                f"|{n.center}|{','.join(n.partition_by)}"
                f"|{','.join(n.order_by)}]")
    if isinstance(n, ir.Sort):
        return f"Sort[{','.join(n.by)}|{n.ascending}]"
    if isinstance(n, ir.Limit):
        return f"Limit[{n.n}]"
    if isinstance(n, ir.Repartition):
        return f"Repart[{','.join(n.by)}|{','.join(n.sort_by)}]"
    return type(n).__name__


def plan_fingerprint(node: ir.Node, scans: str = "identity") -> str:
    """Structural hash of the subplan rooted at ``node`` — stable across
    processes (node ids never participate).

    ``scans="identity"`` (default) keys scans by name + schema + row count —
    the realized-stats / retry-event store keying.  ``scans="shape"`` keys
    scans by schema (dictionary-aware) + layout geometry only — the
    session plan-cache keying, where same-shaped registered tables HIT the
    compiled trace and rebind data (docs/serving.md).
    """
    parts = []

    def rec(n: ir.Node):
        parts.append(_node_sig(n, scans))
        parts.append("(")
        for c in n.children:
            rec(c)
        parts.append(")")

    rec(node)
    return hashlib.sha1("".join(parts).encode()).hexdigest()


def record_realized(root: ir.Node, counts: np.ndarray) -> None:
    """Feed a finished execution's per-shard valid counts back into the
    store (called by collect()/persist() under ``adaptive_stats``)."""
    while isinstance(root, ir.Rebalance):
        root = root.child
    counts = np.asarray(counts, dtype=np.int64).reshape(-1)
    if counts.size == 0:
        return
    _STORE.realized[plan_fingerprint(root)] = {
        "rows": int(counts.sum()),
        "max": int(counts.max()),
        "mean": float(counts.mean()),
        "nshards": int(counts.size),
    }


def record_failure(node: ir.Node, reqs: np.ndarray) -> None:
    """Record an OVERFLOW's observed per-shard buffer requirements under the
    failing op's logical-node fingerprint (runtime/retry.py calls this when
    a PartialAgg site exhausts its retry budget).

    The record has the realized-feedback shape, so the next adaptive run of
    the same plan sizes the site from it with ``ndv_src == "realized"`` —
    exact sizing, no slack, no retry.  ``rows`` is the summed per-shard
    requirement: local distinct groups can double-count a key across shards,
    so the sum is a safe upper bound on the per-shard capacity it feeds.
    """
    while isinstance(node, ir.Rebalance):
        node = node.child
    reqs = np.asarray(reqs, dtype=np.int64).reshape(-1)
    if reqs.size == 0:
        return
    _STORE.realized[plan_fingerprint(node)] = {
        "rows": int(reqs.sum()),
        "max": int(reqs.max()),
        "mean": float(reqs.mean()),
        "nshards": int(reqs.size),
    }


def realized_for(node: ir.Node) -> Optional[dict]:
    while isinstance(node, ir.Rebalance):
        node = node.child
    return _STORE.realized.get(plan_fingerprint(node))


def clear_realized() -> None:
    _STORE.realized.clear()


# ---------------------------------------------------------------------------
# the per-plan analysis context
# ---------------------------------------------------------------------------


def _scan_rows(n: ir.Scan) -> int:
    if n.layout is not None and n.layout.counts is not None:
        return n.layout.rows()
    return len(next(iter(n.columns.values()))) if n.columns else 0


class StatsContext:
    """Per-plan statistics: row estimates per node plus key-tuple stats on
    demand.  Built once per planning pass by :func:`analyze`."""

    def __init__(self, root: ir.Node, sample: int = 256):
        self.root = root
        self.sample = int(sample)
        self.prov = column_provenance(root)
        self.scans = {n.id: n for n in ir.topo_order(root)
                      if isinstance(n, ir.Scan)}
        self._samples: dict[tuple, dict[str, np.ndarray]] = {}
        self._key_cache: dict[tuple, Optional[KeyStats]] = {}
        self.rows_est: dict[int, float] = {}
        self._estimate_rows(root)

    # -- base-table sampling -------------------------------------------------

    def _scan_sample(self, scan_id: int,
                     cols: tuple[str, ...]) -> Optional[dict[str, np.ndarray]]:
        key = (scan_id, tuple(sorted(cols)))
        if key not in self._samples:
            try:
                self._samples[key] = sample_scan(self.scans[scan_id],
                                                 key[1], self.sample)
            except Exception:
                self._samples[key] = None
        return self._samples[key]

    def _trace(self, node: ir.Node,
               cols: tuple[str, ...]) -> Optional[tuple[int, tuple[str, ...]]]:
        """Resolve ``cols`` at ``node`` to columns of ONE scan, or None."""
        p = self.prov.get(node.id, {})
        srcs = [p.get(c) for c in cols]
        if any(s is None for s in srcs):
            return None
        sids = {s[0] for s in srcs}
        if len(sids) != 1:
            return None
        return srcs[0][0], tuple(s[1] for s in srcs)

    # -- public estimates ----------------------------------------------------

    def key_stats(self, node: ir.Node,
                  keys: tuple[str, ...]) -> Optional[KeyStats]:
        """Sampled stats for the ``keys`` tuple at ``node`` (provenance-
        traced to one base table), or None when untraceable."""
        ck = (node.id, tuple(keys))
        if ck in self._key_cache:
            return self._key_cache[ck]
        out = None
        traced = self._trace(node, tuple(keys))
        if traced is not None:
            sid, scols = traced
            smp = self._scan_sample(sid, scols)
            if smp is not None and len(next(iter(smp.values()), ())) > 0:
                out = estimate_keys([smp[c] for c in scols],
                                    _scan_rows(self.scans[sid]))
        self._key_cache[ck] = out
        return out

    def ndv(self, node: ir.Node, keys: tuple[str, ...]) -> Optional[int]:
        """Distinct-count estimate for ``keys`` at ``node``, clamped by the
        node's row estimate (a filtered/joined stream can't grow NDV)."""
        ks = self.key_stats(node, keys)
        if ks is None:
            return None
        est = ks.distinct
        rows = self.rows_est.get(node.id)
        if rows is not None:
            est = min(est, max(1, int(rows)))
        return max(1, est)

    def ndv_cap(self, node: ir.Node, keys: tuple[str, ...]) -> Optional[int]:
        """CONSERVATIVE distinct-count bound for buffer sizing (linear
        singleton extrapolation — see KeyStats.distinct_cap)."""
        ks = self.key_stats(node, keys)
        if ks is None:
            return None
        est = ks.distinct_cap
        rows = self.rows_est.get(node.id)
        if rows is not None:
            est = min(est, max(1, int(rows)))
        return max(1, est)

    def hot_keys(self, node: ir.Node, keys: tuple[str, ...],
                 threshold: float) -> tuple[tuple[tuple, float], ...]:
        """Heavy hitters of ``keys`` at ``node``: sampled frequency >=
        ``threshold`` (frequencies are scan-level; filters are assumed
        skew-preserving — a wrong call costs balance, never correctness)."""
        ks = self.key_stats(node, keys)
        if ks is None:
            return ()
        return tuple((k, f) for k, f in ks.heavy if f >= threshold)

    def hot_fraction(self, node: ir.Node, keys: tuple[str, ...],
                     hot: tuple[tuple[tuple, float], ...]) -> Optional[float]:
        """Estimated fraction of ``node``'s rows whose key tuple is in the
        ``hot`` set (sizes the build side's replication buffer)."""
        if not hot:
            return 0.0
        ks = self.key_stats(node, keys)
        if ks is None:
            return None
        want = {k for k, _f in hot}
        frac = sum(f for k, f in ks.heavy if k in want)
        # one-sided sampling error margin so a small sample can't undersize
        # the replication buffer into a guaranteed overflow-retry.
        return min(1.0, frac + 1.0 / np.sqrt(max(ks.sampled, 1)))

    def realized(self, node: ir.Node) -> Optional[dict]:
        return realized_for(node)

    def skewed_before(self, node: ir.Node) -> bool:
        """Did a previous run of this exact subplan realize shard-occupancy
        skew (max/mean above the trigger)?  Drives the self-tuning salting
        threshold on replan."""
        rl = realized_for(node)
        return bool(rl and rl["nshards"] > 1 and rl["mean"] > 0
                    and rl["max"] / rl["mean"] >= _OCCUPANCY_TRIGGER)

    def layout_skewed(self, node: ir.Node, keys: tuple[str, ...]) -> bool:
        """Skew evidence a REGISTERED table carries for free: when ``keys``
        at ``node`` trace to a persisted scan that is hash-partitioned on
        (a subsequence of) those keys, its ScanLayout per-shard counts ARE
        the realized key distribution under hash routing — shard occupancy
        above the trigger means heavy hitters, with no sampling pass and no
        prior run of this plan (docs/serving.md; PR 7 follow-up)."""
        traced = self._trace(node, tuple(keys))
        if traced is None:
            return False
        sid, scols = traced
        lay = self.scans[sid].layout
        if (lay is None or lay.counts is None or lay.nshards <= 1
                or lay.kind != "hash" or not lay.partitioned_by):
            return False
        # the hash routing must be BY the traced keys (subsequence rule,
        # physical_plan.colocates): counts then reflect key-group sizes.
        it = iter(scols)
        if not all(k in it for k in lay.partitioned_by):
            return False
        cnts = np.asarray(lay.counts, dtype=np.float64).reshape(-1)
        mean = float(cnts.mean()) if cnts.size else 0.0
        return bool(mean > 0 and float(cnts.max()) / mean
                    >= _OCCUPANCY_TRIGGER)

    # -- row estimation (one forward pass) -----------------------------------

    def _filter_selectivity(self, n: ir.Filter) -> float:
        """Sampled selectivity: evaluate the predicate over the base-table
        sample when every referenced column traces to one scan."""
        names = tuple(sorted({c for (_t, c) in n.pred.columns()}))
        if not names:
            return 1.0
        traced = self._trace(n.child, names)
        if traced is None:
            return 1.0
        sid, scols = traced
        smp = self._scan_sample(sid, scols)
        if smp is None:
            return 1.0
        r = len(next(iter(smp.values()), ()))
        if r == 0:
            return 1.0
        try:
            from .expr import evaluate
            env = {name: smp[sc] for name, sc in zip(names, scols)}
            mask = np.asarray(evaluate(n.pred, env))
            return float(np.mean(mask.astype(np.float64)))
        except Exception:
            return 1.0

    def _estimate_rows(self, root: ir.Node) -> None:
        est = self.rows_est
        for n in ir.topo_order(root):
            if isinstance(n, ir.Scan):
                est[n.id] = float(_scan_rows(n))
            elif isinstance(n, ir.Filter):
                est[n.id] = est[n.child.id] * self._filter_selectivity(n)
            elif isinstance(n, ir.Limit):
                est[n.id] = min(float(n.n), est[n.child.id])
            elif isinstance(n, ir.Join):
                lr, rr = est[n.left.id], est[n.right.id]
                ndv_l = self.ndv(n.left, n.left_on)
                ndv_r = self.ndv(n.right, n.right_on)
                if ndv_l and ndv_r:
                    out = lr * rr / max(ndv_l, ndv_r)
                else:
                    out = max(lr, rr)
                if n.how == "left":
                    out = max(out, lr)
                est[n.id] = out
            elif isinstance(n, ir.Aggregate):
                d = self.ndv(n.child, n.key)
                est[n.id] = float(d) if d else est[n.child.id]
            elif isinstance(n, ir.Concat):
                est[n.id] = sum(est[c.id] for c in n.parts)
            elif n.children:
                est[n.id] = est[n.children[0].id]
            else:
                est[n.id] = 0.0


def analyze(root: ir.Node, cfg) -> StatsContext:
    """Build the per-plan statistics context (planner entry point).

    Fault injection (``cfg.fault_inject.poison_stats``, armed only under
    ``adaptive_stats``): ``"raise"`` raises a typed StatsError — lowering
    degrades to static planning; ``"ndv"`` clamps the buffer-sizing
    distinct-count bound to 1 — an undersized PartialAgg the per-op overflow
    retry must heal (tests/test_faults.py).
    """
    fault = getattr(cfg, "fault_inject", None)
    poison = (getattr(fault, "poison_stats", "")
              if getattr(cfg, "adaptive_stats", False) else "")
    if poison == "raise":
        from .errors import StatsError
        raise StatsError("injected stats failure (fault_inject.poison_stats)")
    ctx = StatsContext(root, sample=getattr(cfg, "stats_sample", 256))
    if poison == "ndv":
        ctx.ndv_cap = lambda node, keys: 1      # type: ignore[method-assign]
    return ctx

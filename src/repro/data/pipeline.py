"""Training data pipeline built ON TOP of HiFrames — the integration story.

The paper's thesis is that relational preprocessing and array/ML computation
belong in one compiled program.  Here the LM training pipeline uses HiFrames
verbs for its relational stages:

  1. corpus curation: FILTER documents by length/quality (compiled filter),
  2. curriculum stats: AGGREGATE per-quality-bucket token counts,
  3. sequence packing plan: CUMSUM of document lengths (the paper's scan
     pattern) assigns every document a contiguous token offset,

and only then materializes token batches.  A background thread prefetches
(double-buffering) so the accelerator never waits on batch assembly —
compute/IO overlap at the pipeline level.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

from repro import hiframes as hf


@dataclass
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    min_len: int = 64
    min_quality: float = 0.2
    prefetch: int = 2
    seed: int = 0


class TokenPipeline:
    """Iterator of {tokens, labels} batches from a curated document table."""

    def __init__(self, corpus: dict[str, np.ndarray], cfg: PipelineConfig,
                 exec_cfg=None):
        self.cfg = cfg
        df = hf.table(corpus, name="corpus")
        # 1. curation filter (compiled; 1D_VAR output)
        cur = df[(df["length"] >= cfg.min_len) &
                 (df["quality"] > cfg.min_quality)]
        # 3. packing plan: cumulative token offsets (MPI_Exscan pattern)
        packed = hf.cumsum(cur, cur["length"], out="offset")
        t = packed.collect(exec_cfg)
        cols = t.to_numpy()
        self.doc_len = cols["length"]
        self.doc_seed = cols["seed"]
        self.doc_offset = cols["offset"] - cols["length"]   # exclusive
        self.total_tokens = int(cols["offset"][-1]) if len(cols["offset"]) else 0
        # 2. curriculum stats (compiled aggregate) — exposed for logging
        sdf = hf.table({"bucket": (cols["quality"] * 10).astype(np.int32),
                        "length": cols["length"]}, name="stats")
        sagg = hf.aggregate(sdf, "bucket", tokens=hf.sum_(sdf["length"]),
                            docs=hf.count()).collect(exec_cfg).to_numpy()
        self.bucket_stats = sagg
        self._rng = np.random.default_rng(cfg.seed)
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    # -- batch assembly ------------------------------------------------------
    def _make_batch(self):
        cfg = self.cfg
        n = cfg.global_batch
        toks = np.empty((n, cfg.seq_len + 1), np.int32)
        # sample documents proportional to length; generate tokens from seed
        idx = self._rng.integers(0, len(self.doc_len), n)
        for i, d in enumerate(idx):
            rng = np.random.default_rng(int(self.doc_seed[d]) + 7919 * i)
            toks[i] = rng.integers(0, cfg.vocab, cfg.seq_len + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def _producer(self):
        while not self._stop.is_set():
            batch = self._make_batch()
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

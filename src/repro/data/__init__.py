from . import pipeline, synth

"""Synthetic data generators for the paper's workloads.

- Uniform tables for Fig. 8a/8b micro-benchmarks (the paper draws from a
  uniform distribution "to avoid load balance issues").
- TPCx-BB-like store_sales / item / web_clickstream tables for Q05/Q25/Q26,
  including the Zipf-skewed join key that makes Q05 the paper's skew stress
  (hash partitioning imbalance, §5.1).
"""
from __future__ import annotations

import numpy as np


def relational_tables(n_rows: int, n_keys: int, seed: int = 0):
    """Key + two float columns (paper's basic-relational-ops input)."""
    rng = np.random.default_rng(seed)
    return {
        "id": rng.integers(0, n_keys, n_rows).astype(np.int32),
        "x": rng.normal(size=n_rows).astype(np.float32),
        "y": rng.normal(size=n_rows).astype(np.float32),
    }


def series(n_rows: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=n_rows).astype(np.float32)


# -- TPCx-BB-like -------------------------------------------------------------

N_CLASSES = 16
N_CATEGORIES = 8


def store_sales(n_rows: int, n_items: int, n_customers: int, seed: int = 0,
                skew: float = 0.0):
    """ss_item_sk is Zipf-skewed when skew > 0 (Q05's failure mode)."""
    rng = np.random.default_rng(seed)
    if skew > 0:
        # bounded Zipf over item ids
        z = rng.zipf(1.0 + skew, size=n_rows)
        item = ((z - 1) % n_items).astype(np.int32)
    else:
        item = rng.integers(0, n_items, n_rows).astype(np.int32)
    return {
        "ss_item_sk": item,
        "ss_customer_sk": rng.integers(0, n_customers, n_rows).astype(np.int32),
        "ss_ticket_number": rng.integers(0, n_rows, n_rows).astype(np.int32),
        "ss_net_paid": rng.gamma(2.0, 30.0, n_rows).astype(np.float32),
    }


def item(n_items: int, seed: int = 1):
    rng = np.random.default_rng(seed)
    return {
        "i_item_sk": np.arange(n_items, dtype=np.int32),
        "i_class_id": rng.integers(1, N_CLASSES + 1, n_items).astype(np.int32),
        "i_category_id": rng.integers(1, N_CATEGORIES + 1, n_items).astype(np.int32),
    }


def web_clickstream(n_rows: int, n_items: int, n_users: int, seed: int = 2,
                    skew: float = 0.0):
    rng = np.random.default_rng(seed)
    if skew > 0:
        z = rng.zipf(1.0 + skew, size=n_rows)
        item_sk = ((z - 1) % n_items).astype(np.int32)
    else:
        item_sk = rng.integers(0, n_items, n_rows).astype(np.int32)
    return {
        "wcs_item_sk": item_sk,
        "wcs_user_sk": rng.integers(0, n_users, n_rows).astype(np.int32),
        "wcs_click_date_sk": rng.integers(0, 365, n_rows).astype(np.int32),
    }


# -- string/categorical variants (docs/dtypes.md) -----------------------------

CATEGORY_NAMES = ("appliances", "books", "clothing", "electronics",
                  "garden", "music", "sports", "toys")
CHANNELS = ("catalog", "store", "web")


def item_ext(n_items: int, seed: int = 1):
    """:func:`item` plus a STRING category-name column (dictionary-encoded
    at ingest).  The name maps deterministically from ``i_category_id`` so
    string-keyed and int-keyed query variants stay comparable."""
    base = item(n_items, seed)
    names = np.asarray(CATEGORY_NAMES, dtype=object)
    base["i_category_name"] = names[(base["i_category_id"] - 1)
                                    % len(CATEGORY_NAMES)]
    return base


def store_sales_ext(n_rows: int, n_items: int, n_customers: int,
                    seed: int = 0, skew: float = 0.0,
                    null_rate: float = 0.02):
    """:func:`store_sales` plus the ingest-coercion stressors: a string
    sales-channel column with ``None`` holes and a nullable float discount
    column (NaN holes) — the Q09-style skipna-aggregation input."""
    base = store_sales(n_rows, n_items, n_customers, seed, skew)
    rng = np.random.default_rng(seed + 2000)
    ch = np.asarray(CHANNELS, dtype=object)[
        rng.integers(0, len(CHANNELS), n_rows)]
    ch[rng.random(n_rows) < null_rate] = None
    base["ss_channel"] = ch
    disc = rng.gamma(1.5, 5.0, n_rows).astype(np.float32)
    disc[rng.random(n_rows) < null_rate] = np.nan
    base["ss_discount"] = disc
    return base


# -- tokenized corpus stub (LM pipeline) --------------------------------------


def token_corpus(n_docs: int, vocab: int, max_len: int = 2048, seed: int = 0):
    """Document table: (doc_id, length, quality, seed) — token content is
    generated lazily per batch from the seed (no corpus on disk needed)."""
    rng = np.random.default_rng(seed)
    return {
        "doc_id": np.arange(n_docs, dtype=np.int32),
        "length": rng.integers(32, max_len, n_docs).astype(np.int32),
        "quality": rng.uniform(0, 1, n_docs).astype(np.float32),
        "seed": rng.integers(0, 2**31 - 1, n_docs).astype(np.int32),
    }

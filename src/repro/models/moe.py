"""Mixture-of-Experts with capacity-based, sort-free dispatch.

The dispatch problem — route a data-dependent number of tokens to each expert
shard under a static-shape compiler — is EXACTLY the paper's 1D_VAR problem,
and the solution is the same static-capacity + validity-count scheme as
core.physical.exchange (DESIGN.md §3): tokens are ranked within their target
expert (the hash_partition pattern), clamped to a per-expert capacity, and
scattered into an (E, C, d) buffer that is expert-sharded over the "model"
mesh axis (EP).  Overflowed tokens are dropped (standard capacity-factor MoE
semantics) and their probability mass is renormalized away.

Shared experts (DeepSeek-MoE / Kimi lineage) are plain always-on SwiGLU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core.compat import shard_map as _compat_shard_map
from .config import ModelConfig
from .layers import swiglu

# Expert-parallel mesh registry: set by the launcher (steps/dryrun) so the
# optimized EP dispatch path can shard_map over the "model" axis.  None ->
# the GSPMD-auto path (the recorded baseline; see EXPERIMENTS.md §Perf).
_EP_MESH = None


def set_ep_mesh(mesh):
    global _EP_MESH
    _EP_MESH = mesh


def get_ep_mesh():
    return _EP_MESH


def expert_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(np.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, -(-cap // 8) * 8)                    # round to sublane


def moe_block(p: dict, x, cfg: ModelConfig):
    """Dispatch to the EP shard_map path when a mesh is registered and the
    config asks for it; otherwise the GSPMD-auto baseline."""
    mesh = _EP_MESH
    if (getattr(cfg, "moe_impl", "gspmd") == "ep" and mesh is not None
            and "model" in mesh.axis_names
            and cfg.n_experts % mesh.shape["model"] == 0):
        return _moe_block_ep(p, x, cfg, mesh)
    return _moe_block_gspmd(p, x, cfg)


def _moe_block_gspmd(p: dict, x, cfg: ModelConfig):
    """x: (B, S, d) -> (out, aux_loss).

    p: router (d, E); experts {w_gate,w_up,w_down: (E, d, ff)/(E, ff, d)};
    optional shared {w_gate,w_up,w_down}.
    """
    b, s, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = b * s
    dt = x.dtype
    xt = x.reshape(T, d)

    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = lax.top_k(probs, k)                   # (T, k)
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style)
    me = jnp.mean(probs, axis=0)                       # (E,)
    ce = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    # --- dispatch: 1D_VAR-style capacity + rank (no argsort) ---------------
    C = expert_capacity(cfg, T)
    flat_e = topi.reshape(T * k)                       # (Tk,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    flat_w = topw.reshape(T * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (Tk, E)
    ranks = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=1)
    keep = ranks < C
    slot = jnp.where(keep, ranks, C)

    buf = jnp.zeros((E, C + 1, d), dt)
    buf = buf.at[flat_e, slot].set(xt[flat_t], mode="drop")
    buf = buf[:, :C]                                   # (E, C, d)

    # --- expert computation (EP over the "model" axis) ---------------------
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["experts"]["w_gate"].astype(dt)))
    u = jnp.einsum("ecd,edf->ecf", buf, p["experts"]["w_up"].astype(dt))
    eo = jnp.einsum("ecf,efd->ecd", g * u, p["experts"]["w_down"].astype(dt))

    # --- combine ------------------------------------------------------------
    contrib = eo[flat_e, jnp.minimum(slot, C - 1)]     # (Tk, d)
    contrib = contrib * (flat_w * keep.astype(jnp.float32)).astype(dt)[:, None]
    y = jnp.zeros((T, d), dt).at[flat_t].add(contrib)

    if "shared" in p:
        y = y + swiglu(p["shared"], xt, dt)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Optimized EP dispatch (§Perf iteration 1 — see EXPERIMENTS.md)
# ---------------------------------------------------------------------------


def _moe_block_ep(p: dict, x, cfg: ModelConfig, mesh):
    """Expert-parallel dispatch via shard_map — the HiFrames 1D_VAR scheme.

    The GSPMD-auto baseline replicates the data-dependent scatter dispatch
    across the model axis (TBs of all-gather — the measured baseline).  Here
    the block-input activations are ALREADY replicated over "model" (standard
    TP), so each model shard simply SELECTS the token copies routed to its
    local experts — static capacity + within-expert rank, exactly the
    hash_partition/compact pattern of core.physical — computes its expert
    matmuls, and contributes partial outputs through ONE psum.  Per-layer
    collective volume drops from O(E·C·d) all-gathers to one (T_loc, d)
    all-reduce.  Capacity is per (expert, data-shard) rather than global —
    standard per-device-capacity MoE semantics (noted in DESIGN.md).
    """
    b, s, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    M = mesh.shape["model"]
    E_loc = E // M
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dt = x.dtype

    def fn(x_loc, router, experts):
        bl = x_loc.shape[0]
        T = bl * s
        xt = x_loc.reshape(T, d)
        logits = (xt @ router.astype(dt)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        topw, topi = lax.top_k(probs, k)
        topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
        if dp:   # product of GLOBAL means (matches the baseline exactly)
            me = lax.pmean(me, dp)
            ce = lax.pmean(ce, dp)
        aux = E * jnp.sum(me * ce)

        m_idx = lax.axis_index("model")
        flat_e = topi.reshape(T * k)
        flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
        flat_w = topw.reshape(T * k)
        le = flat_e - m_idx * E_loc
        mine = (le >= 0) & (le < E_loc)
        le_c = jnp.where(mine, le, E_loc)
        onehot = jax.nn.one_hot(le_c, E_loc, dtype=jnp.int32)   # row E_loc -> 0
        ranks = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=1)
        C = expert_capacity(cfg, T)
        keep = mine & (ranks < C)
        slot = jnp.where(keep, ranks, C)

        buf = jnp.zeros((E_loc + 1, C + 1, d), dt)
        buf = buf.at[le_c, slot].set(xt[flat_t], mode="drop")
        buf = buf[:E_loc, :C]

        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                                   experts["w_gate"].astype(dt)))
        u = jnp.einsum("ecd,edf->ecf", buf, experts["w_up"].astype(dt))
        eo = jnp.einsum("ecf,efd->ecd", g * u,
                        experts["w_down"].astype(dt))

        contrib = eo[jnp.minimum(le_c, E_loc - 1), jnp.minimum(slot, C - 1)]
        contrib = contrib * (flat_w * keep.astype(jnp.float32)).astype(dt)[:, None]
        y = jnp.zeros((T, d), dt).at[flat_t].add(contrib)
        y = lax.psum(y, "model")
        return y.reshape(bl, s, d), aux

    x_spec = P(dp if dp else None, None, None)
    e_spec = jax.tree.map(lambda _: P("model", None, None), p["experts"])
    y, aux = _compat_shard_map(
        fn, mesh=mesh,
        in_specs=(x_spec, P(), e_spec),
        out_specs=(x_spec, P()), check_vma=False,
    )(x, p["router"], p["experts"])

    if "shared" in p:
        y = y + swiglu(p["shared"], x.reshape(b * s, d), dt).reshape(b, s, d)
    return y, aux


def moe_param_shapes(cfg: ModelConfig) -> dict:
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    shapes = {
        "router": (d, E),
        "experts": {"w_gate": (E, d, ff), "w_up": (E, d, ff),
                    "w_down": (E, ff, d)},
    }
    if cfg.n_shared_experts:
        sf = ff * cfg.n_shared_experts
        shapes["shared"] = {"w_gate": (d, sf), "w_up": (d, sf),
                            "w_down": (sf, d)}
    return shapes

"""Model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # attention variants
    qk_norm: bool = False       # qwen3
    qkv_bias: bool = False      # qwen2/2.5
    nonparam_ln: bool = False   # olmo: LayerNorm without scale/bias
    rope_theta: float = 1_000_000.0
    mrope: bool = False         # qwen2-vl M-RoPE
    mrope_sections: tuple[int, int, int] = (16, 24, 24)

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0
    d_ff_first_dense: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "gspmd"     # "gspmd" (baseline) | "ep" (shard_map EP)
    attn_batch_shard: bool = False  # §Perf (REFUTED — see EXPERIMENTS.md):
                                # batch-over-(dp x model) attention
    attn_seq_shard: bool = False    # §Perf: shard attention over query-seq on
                                # "model" (Megatron-SP style) — softmax stays
                                # local, KV replicated per layer
    cache_update: str = "dus"   # "dus" (dynamic_update_slice baseline) |
                                # "masked" (§Perf: elementwise iota-select —
                                # no resharding of the seq-sharded cache)
    attn_decode_kernel: bool = False  # route s==1 decode attention through
                                # the fused Pallas kernel (kernels/
                                # decode_attention); single-device/TPU path

    # SSM (mamba)
    ssm_state: int = 0
    d_inner: int = 0            # 0 -> 2 * d_model
    conv_kernel: int = 4
    mamba_version: int = 1
    mamba_headdim: int = 64     # mamba2 head dim
    ssm_chunk: int = 256        # chunked-scan length

    # hybrid (zamba2): one SHARED attention+MLP block applied every period
    shared_attn_period: int = 0

    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_frames: int = 1500
    enc_d_model: int = 0        # 0 -> d_model

    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    remat: str = "block"        # "block": jax.checkpoint per layer | "none"
    unroll_scans: bool = False  # cost-accounting mode: XLA costs a While body
                                # ONCE regardless of trip count, so the dry-run
                                # compiles L-pairs with every scan unrolled
    kv_chunk: int = 1024        # flash-attention KV chunk length
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # notes for DESIGN.md provenance
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def din(self) -> int:
        return self.d_inner or 2 * self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- analytic parameter / FLOP counts (roofline §MODEL_FLOPS) ----------

    def param_count(self) -> int:
        d, hd = self.d_model, self.hd
        n = self.vocab * d                       # embed
        if not self.tie_embeddings:
            n += self.vocab * d                  # lm head
        att = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        mlp_dense = 3 * d * self.d_ff            # SwiGLU
        if self.family in ("dense", "vlm"):
            n += self.n_layers * (att + mlp_dense + 2 * d)
        elif self.family == "moe":
            moe = 3 * d * self.d_ff_expert * self.n_experts \
                + 3 * d * self.d_ff_expert * self.n_shared_experts \
                + d * self.n_experts
            nl_moe = self.n_layers - self.first_dense_layers
            n += nl_moe * (att + moe + 2 * d)
            n += self.first_dense_layers * (att + 3 * d * self.d_ff_first_dense + 2 * d)
        elif self.family == "ssm":
            din, st = self.din, self.ssm_state
            blk = d * 2 * din + din * self.conv_kernel + din * (2 * st + 2) \
                + din * st + din * d + d
            n += self.n_layers * (blk + d)
        elif self.family == "hybrid":
            din, st = self.din, self.ssm_state
            blk = d * 2 * din + din * self.conv_kernel \
                + 2 * din + din * d + d            # mamba2: scalar A/dt per head
            n += self.n_layers * (blk + d)
            n += att + mlp_dense + 2 * d           # ONE shared attn block
        elif self.family == "encdec":
            enc_att = att
            dec = att + d * self.n_kv_heads * hd * 2 + d * self.n_heads * hd \
                + self.n_heads * hd * d            # self + cross
            n += self.n_enc_layers * (enc_att + 2 * d * self.d_ff + 2 * d)
            n += self.n_layers * (dec + 2 * d * self.d_ff + 3 * d)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dense_like = self.replace(family="dense", d_ff=0).param_count()
        act = dense_like + self.n_layers * 3 * d * self.d_ff_expert * (
            self.top_k + self.n_shared_experts)
        return act

    def model_flops_per_token(self) -> float:
        """6·N_active (training fwd+bwd) per token."""
        return 6.0 * self.active_param_count()

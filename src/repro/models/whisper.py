"""Whisper-style encoder-decoder backbone (audio frontend is a STUB).

Per the assignment, only the transformer backbone is modeled: ``input_specs``
provides precomputed mel-frame embeddings (B, enc_frames, d) — the conv
frontend is out of scope.  Positions are sinusoidal (the original uses
learned tables; swapping to sinusoids decouples parameter shapes from the
assigned 32k decoder sequence lengths — noted in DESIGN.md).

Decoder layers carry BOTH a causal self-attention cache and a cross-attention
KV computed once from the encoder output at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .config import ModelConfig
from .layers import attention_block, gelu_mlp, rmsnorm
from .lm import _attn_shapes, _dt, _pdt


def _sinusoid(seq: int, d: int, offset=0):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None] + offset
    div = jnp.exp(-np.log(10000.0) * jnp.arange(0, d, 2, jnp.float32) / d)
    ang = pos * div
    out = jnp.zeros((seq, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return out


def param_shapes(cfg: ModelConfig) -> dict:
    d, V = cfg.d_model, cfg.vocab
    enc_layer = {"attn": _attn_shapes(cfg),
                 "ln1": (d,), "ln2": (d,),
                 "mlp": {"w_up": (d, cfg.d_ff), "w_down": (cfg.d_ff, d)}}
    dec_layer = {"attn": _attn_shapes(cfg), "xattn": _attn_shapes(cfg),
                 "ln1": (d,), "ln2": (d,), "ln3": (d,),
                 "mlp": {"w_up": (d, cfg.d_ff), "w_down": (cfg.d_ff, d)}}

    def stack(shapes, L):
        return jax.tree.map(lambda s: (L, *s), shapes,
                            is_leaf=lambda x: isinstance(x, tuple))

    return {
        "embed": (V, d),
        "enc_in_proj": (d, d),            # stub frontend projection
        "enc_layers": stack(enc_layer, cfg.n_enc_layers),
        "enc_final_ln": (d,),
        "dec_layers": stack(dec_layer, cfg.n_layers),
        "final_ln": (d,),
    }


def param_specs(cfg: ModelConfig):
    pdt = _pdt(cfg)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, pdt),
                        param_shapes(cfg), is_leaf=lambda x: isinstance(x, tuple))


def init_params(cfg: ModelConfig, key):
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree.flatten(shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(leaves))
    pdt = _pdt(cfg)

    def init_one(shape, k):
        if len(shape) == 1 or (len(shape) == 2 and shape[-1] == cfg.d_model
                               and shape[0] == cfg.n_layers):
            return jnp.ones(shape, pdt)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        return (jax.random.normal(k, shape) * (1.0 / np.sqrt(fan_in))).astype(pdt)

    return jax.tree.unflatten(treedef, [init_one(s, k) for s, k in zip(leaves, keys)])


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, F, d) stub embeddings -> encoder states (B, F, d)."""
    cdt = _dt(cfg)
    x = frames.astype(cdt) @ params["enc_in_proj"].astype(cdt)
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(cdt)[None]

    # non-causal self attention: reuse the cross-attn path with KV = self
    def enc_body(h, lp):
        a, _ = _encoder_self_attn(lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg)
        h = h + a
        h = h + gelu_mlp(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
        return h, None

    if cfg.remat == "block":
        enc_body = jax.checkpoint(enc_body)
    x, _ = lax.scan(enc_body, x, params["enc_layers"],
                    unroll=cfg.unroll_scans)
    return rmsnorm(x, params["enc_final_ln"], cfg.norm_eps)


def _encoder_self_attn(p, x, cfg):
    """Bidirectional self-attention (reuses the cross-attn path with KV=self)."""
    b, s, d = x.shape
    hkv, hd = cfg.n_kv_heads, cfg.hd
    dt = x.dtype
    k = (x @ p["wk"].astype(dt)).reshape(b, s, hkv, hd)
    v = (x @ p["wv"].astype(dt)).reshape(b, s, hkv, hd)
    return attention_block(p, x, cfg, positions=None, layer_cross_kv=(k, v))


def _cross_kv(p, enc, cfg):
    b, f, d = enc.shape
    hkv, hd = cfg.n_kv_heads, cfg.hd
    dt = enc.dtype
    k = (enc @ p["wk"].astype(dt)).reshape(b, f, hkv, hd)
    v = (enc @ p["wv"].astype(dt)).reshape(b, f, hkv, hd)
    return k, v


def decode_forward(params, tokens, enc_states, cfg: ModelConfig, *,
                   caches=None, q_offset=None):
    """Decoder forward (teacher forcing when caches=None, else one-step)."""
    cdt = _dt(cfg)
    b, s = tokens.shape
    x = params["embed"].astype(cdt)[tokens]
    off = q_offset if q_offset is not None else 0
    x = x + _sinusoid(s, cfg.d_model, offset=off).astype(cdt)[None]

    def body(h, xs):
        if caches is None:
            lp = xs
            cache = None
        else:
            lp, cache = xs
        a, nc = attention_block(lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps),
                                cfg, positions=None,
                                cache=cache["self"] if cache else None)
        h = h + a
        kv = _cross_kv(lp["xattn"], enc_states, cfg) if caches is None else \
            (cache["xk"], cache["xv"])
        ca, _ = attention_block(lp["xattn"], rmsnorm(h, lp["ln2"], cfg.norm_eps),
                                cfg, positions=None, layer_cross_kv=kv)
        h = h + ca
        h = h + gelu_mlp(lp["mlp"], rmsnorm(h, lp["ln3"], cfg.norm_eps))
        if caches is None:
            return h, None
        return h, {"self": nc, "xk": cache["xk"], "xv": cache["xv"]}

    if caches is None:
        if cfg.remat == "block":
            body = jax.checkpoint(body)
        x, _ = lax.scan(body, x, params["dec_layers"], unroll=cfg.unroll_scans)
        new_caches = None
    else:
        x, new_caches = lax.scan(body, x, (params["dec_layers"], caches),
                                 unroll=cfg.unroll_scans)
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(cdt))
    return logits, new_caches


def loss_fn(params, batch, cfg: ModelConfig):
    """batch: {frames (B,F,d), tokens (B,S), labels (B,S)}."""
    enc = encode(params, batch["frames"], cfg)
    logits, _ = decode_forward(params, batch["tokens"], enc, cfg)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def init_cache_specs(cfg: ModelConfig, batch: int, max_seq: int):
    cdt = _dt(cfg)
    hkv, hd = cfg.n_kv_heads, cfg.hd
    L, F = cfg.n_layers, cfg.enc_frames
    return {
        "self": {"k": jax.ShapeDtypeStruct((L, batch, max_seq, hkv, hd), cdt),
                 "v": jax.ShapeDtypeStruct((L, batch, max_seq, hkv, hd), cdt),
                 "index": jax.ShapeDtypeStruct((L,), jnp.int32)},
        "xk": jax.ShapeDtypeStruct((L, batch, F, hkv, hd), cdt),
        "xv": jax.ShapeDtypeStruct((L, batch, F, hkv, hd), cdt),
    }


def prefill(params, frames, tokens, cfg: ModelConfig, max_seq: int):
    """Encode + build caches + teacher-force the prompt tokens."""
    b, s = tokens.shape
    enc = encode(params, frames, cfg)
    specs = init_cache_specs(cfg, b, max_seq)
    caches = jax.tree.map(lambda sp: jnp.zeros(sp.shape, sp.dtype), specs)
    xk, xv = _stacked_cross_kv(params, enc, cfg)   # cross KV once per layer
    caches = {"self": caches["self"], "xk": xk, "xv": xv}
    logits, caches = decode_forward(params, tokens, enc, cfg, caches=caches,
                                    q_offset=0)
    return logits[:, -1], caches


def _stacked_cross_kv(params, enc, cfg):
    def one(lp):
        return _cross_kv(lp, enc, cfg)
    return jax.lax.map(one, params["dec_layers"]["xattn"])


def decode_step(params, token, caches, cfg: ModelConfig):
    idx = caches["self"]["index"][0]
    logits, new_caches = decode_forward(params, token, None, cfg,
                                        caches=caches, q_offset=idx)
    return logits[:, -1], new_caches

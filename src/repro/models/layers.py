"""Transformer building blocks: norms, RoPE/M-RoPE, GQA attention, SwiGLU.

All modules are pure functions over parameter pytrees (stacked over layers by
the callers and scanned), bf16 compute with f32 normalization/softmax
accumulation.  Attention is GSPMD-friendly: plain einsum under 4k sequence,
chunked online-softmax (flash-style lax.scan over KV blocks) above — O(chunk)
memory, identical FLOPs, compiles on CPU and runs on TPU.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .config import ModelConfig

ATTN_CHUNK_THRESHOLD = 8192   # plain softmax below, chunked above
KV_CHUNK = 1024

# TP mesh registry for sharding-constraint perf paths (set by launcher).
_TP_MESH = None


def set_tp_mesh(mesh):
    global _TP_MESH
    _TP_MESH = mesh


def _pin_cache_sharding(ck, cv, cfg):
    """Pin the per-layer KV cache slice to its canonical layout (batch over
    dp, SEQUENCE over model) so the scan's ys stacking never permutes it —
    GSPMD otherwise returns the attention-read resharding to the carry."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = _TP_MESH
    if mesh is None or "model" not in mesh.axis_names or             cfg.cache_update != "masked":
        return ck, cv
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ok_b = ck.shape[0] % max(
        1, int(np.prod([mesh.shape[a] for a in dp]))) == 0
    ok_s = ck.shape[1] % mesh.shape["model"] == 0
    spec = P(dp if ok_b else None, "model" if ok_s else None, None, None)
    sh = NamedSharding(mesh, spec)
    return (jax.lax.with_sharding_constraint(ck, sh),
            jax.lax.with_sharding_constraint(cv, sh))


def _seq_shard_qkv(q, k, v, cfg):
    """§Perf lever (attn_seq_shard): shard the QUERY sequence over "model",
    replicate KV — every softmax/weighted-sum stays device-local; the only
    added comm is the per-layer KV broadcast + output re-shard, instead of
    head-misaligned resharding storms (yi-34b: 56 heads on a 16-way axis)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = _TP_MESH
    if not (cfg.attn_seq_shard and mesh is not None
            and "model" in mesh.axis_names):
        return q, k, v
    if q.shape[1] % mesh.shape["model"] != 0 or q.shape[1] == 1:
        return q, k, v
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    qs = NamedSharding(mesh, P(dp, "model", None, None, None))
    kv = NamedSharding(mesh, P(dp, None, None, None))
    return (jax.lax.with_sharding_constraint(q, qs),
            jax.lax.with_sharding_constraint(k, kv),
            jax.lax.with_sharding_constraint(v, kv))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    return y.astype(x.dtype)


def nonparam_layernorm(x, eps):
    """OLMo's non-parametric LayerNorm: normalize, no learned scale/bias."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * lax.rsqrt(var + eps)).astype(x.dtype)


def norm(x, scale, cfg: ModelConfig):
    if cfg.nonparam_ln:
        return nonparam_layernorm(x, cfg.norm_eps)
    return rmsnorm(x, scale, cfg.norm_eps)


# ---------------------------------------------------------------------------
# rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv       # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: tuple[int, int, int]):
    """Qwen2-VL M-RoPE: frequency slots are split into (t, h, w) sections,
    each rotated by its own position stream.  positions3: (3, B, S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    sec = np.asarray(sections)
    assert sec.sum() == hd // 2, (sections, hd)
    sec_id = np.repeat(np.arange(3), sec)             # (hd/2,) which stream
    pos = positions3.astype(jnp.float32)              # (3, B, S)
    # per-frequency position: pick the stream for each slot
    p = pos[sec_id]                                   # (hd/2, B, S)
    ang = jnp.moveaxis(p, 0, -1) * inv                # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_scores(q, k, v, causal: bool, q_offset=0):
    """Plain grouped attention: q (B,Sq,Hkv,G,hd), k/v (B,Sk,Hkv,hd).

    GQA is computed WITHOUT materializing repeated KV heads: the group axis G
    rides on the query side of the einsum (saves n_rep x KV memory — the
    decode-path working set).
    """
    hd = q.shape[-1]
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        qi = jnp.arange(sq)[:, None] + q_offset
        ki = jnp.arange(sk)[None, :]
        scores = jnp.where(qi >= ki, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v)


def attention_chunked(q, k, v, causal: bool, q_offset=0, kv_chunk: int = KV_CHUNK,
                      unroll: bool = False):
    """Flash-style online-softmax over KV chunks (O(chunk) memory).

    q (B,Sq,Hkv,G,hd), k/v (B,Sk,Hkv,hd).  Implemented as lax.scan so the
    32k/500k shapes compile without materializing (Sq, Sk) score tensors.
    """
    b, sq, hkv, g, hd = q.shape
    sk = k.shape[1]
    nchunks = -(-sk // kv_chunk)
    pad = nchunks * kv_chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunks, kv_chunk, hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunks, kv_chunk, hkv, hd).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / np.sqrt(hd)
    qi = jnp.arange(sq)[:, None] + q_offset

    def step(carry, xs):
        m, l, o = carry
        kb, vb, ci = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, kb).astype(jnp.float32) * scale
        ki = ci * kv_chunk + jnp.arange(kv_chunk)[None, :]
        mask = ki <= (qi if causal else jnp.full_like(qi, sk))
        mask = mask & (ki < sk)                      # drop padding keys
        s = jnp.where(mask[None, None, None, :, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(q.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, hkv, g, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    o0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    (m, l, o), _ = lax.scan(step, (m0, l0, o0),
                            (kc, vc, jnp.arange(nchunks)), unroll=unroll)
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o.transpose(0, 3, 1, 2, 4).astype(q.dtype)   # (B, Sq, Hkv, G, hd)


@dataclasses.dataclass
class AttnParams:
    """Parameter name conventions for one attention block (per layer)."""
    # wq: (d, H*hd), wk/wv: (d, Hkv*hd), wo: (H*hd, d)
    # optional: bq/bk/bv, q_norm/k_norm scales


def attention_block(p: dict, x, cfg: ModelConfig, positions, cache=None,
                    layer_cross_kv=None):
    """Full attention: projections + rope + (cached) attention + out proj.

    cache: None (train/prefill-full) or dict {k, v, index} for decode —
    k/v (B, Skv, Hkv, hd) ring buffers, index = current length (scalar).
    Returns (out, new_cache).
    """
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
    q = q.reshape(b, s, h, hd)
    if layer_cross_kv is None:
        k = x @ p["wk"].astype(dt)
        v = x @ p["wv"].astype(dt)
        if cfg.qkv_bias:
            k = k + p["bk"].astype(dt)
            v = v + p["bv"].astype(dt)
        k = k.reshape(b, s, hkv, hd)
        v = v.reshape(b, s, hkv, hd)
    else:
        k, v = layer_cross_kv                         # pre-computed cross KV

    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        if layer_cross_kv is None:
            k = rmsnorm(k, p["k_norm"], cfg.norm_eps)

    causal = layer_cross_kv is None
    if layer_cross_kv is None and positions is not None:
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and layer_cross_kv is None:
        # decode: write the new K/V at cache["index"], attend over the buffer
        idx = cache["index"]
        if cfg.cache_update == "masked" and s == 1:
            # elementwise one-token write: each device applies its local
            # slice of the iota mask — NO resharding of a seq-sharded cache
            # (vs dynamic_update_slice at a dynamic index, which GSPMD
            # lowers to a full cache permute+all-reduce per layer).
            sel = (jnp.arange(cache["k"].shape[1], dtype=jnp.int32)
                   == idx)[None, :, None, None]
            ck = jnp.where(sel, k.astype(cache["k"].dtype), cache["k"])
            cv = jnp.where(sel, v.astype(cache["v"].dtype), cache["v"])
        else:
            ck = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, idx, 0, 0))
            cv = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, idx, 0, 0))
        ck, cv = _pin_cache_sharding(ck, cv, cfg)
        new_cache = {"k": ck, "v": cv, "index": idx + s}
        k, v = ck.astype(dt), cv.astype(dt)
        skv = k.shape[1]
        # mask beyond current length via "causal" with q_offset = idx
        q_offset = idx
    else:
        q_offset = 0

    n_rep = h // hkv
    qg = q.reshape(b, s, hkv, n_rep, hd)
    qg, k, v = _seq_shard_qkv(qg, k, v, cfg)
    is_causal = causal or cache is not None
    # single-token decode always uses the direct path: scores are (B,H,1,S)
    # (tiny per device with S model-sharded) and GSPMD turns the softmax over
    # the sharded S into the flash-decoding max/sum combine.  The chunked
    # path would instead ring-permute every cache chunk (measured: ~2 GiB of
    # collective-permute per layer per token — EXPERIMENTS.md §Perf).
    if s == 1 and cfg.attn_decode_kernel and cache is not None:
        # fused Pallas decode kernel: one streaming pass over the cache,
        # VMEM-carried online softmax (kernels/decode_attention)
        from ..kernels.decode_attention import ops as da_ops
        from ..kernels import interpret_default
        length = jnp.broadcast_to(q_offset + 1, (b,)).astype(jnp.int32)
        o = da_ops.decode_attention(qg[:, 0], k, v, length,
                                    interpret=interpret_default())
        out = o[:, None]                              # (B, 1, Hkv, G, hd)
    elif s == 1 or (k.shape[1] <= ATTN_CHUNK_THRESHOLD
                    and s <= ATTN_CHUNK_THRESHOLD):
        out = attention_scores(qg, k, v, causal=is_causal, q_offset=q_offset)
    else:
        out = attention_chunked(qg, k, v, causal=is_causal, q_offset=q_offset,
                                kv_chunk=cfg.kv_chunk,
                                unroll=cfg.unroll_scans)
    out = out.reshape(b, s, h * hd) @ p["wo"].astype(dt)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def swiglu(p: dict, x, dt=None):
    dt = dt or x.dtype
    g = jax.nn.silu(x @ p["w_gate"].astype(dt))
    u = x @ p["w_up"].astype(dt)
    return (g * u) @ p["w_down"].astype(dt)


def gelu_mlp(p: dict, x, dt=None):
    """2-matrix GELU MLP (whisper-style)."""
    dt = dt or x.dtype
    return jax.nn.gelu(x @ p["w_up"].astype(dt)) @ p["w_down"].astype(dt)

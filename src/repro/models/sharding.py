"""Sharding rules: parameter/batch/cache PartitionSpecs for the production mesh.

Megatron-style tensor parallelism on the "model" axis (column-parallel in-
projections, row-parallel out-projections), experts sharded for EP, vocab
sharded for the embedding/head, decode KV caches sharded along SEQUENCE on
"model" (GSPMD turns softmax over the sharded axis into the flash-decoding
max/sum combine), batch over ("pod","data") for DP.

Every rule degrades gracefully: a dim that does not divide its mesh axes is
replicated (e.g. qwen2-vl's 12 heads on a 16-way model axis shard the fused
head*dim projections, which DO divide).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ModelConfig


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def fit_spec(mesh: Mesh, shape: tuple, want: tuple) -> P:
    """Drop sharding on dims that don't divide their axes."""
    out = []
    for dim, ax in zip(shape, want):
        if ax is None or dim % _axsize(mesh, ax) != 0:
            out.append(None)
        else:
            out.append(ax)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


# -- parameters ---------------------------------------------------------------

_COL_PARALLEL = ("wq", "wk", "wv", "bq", "bk", "bv", "w_gate", "w_up",
                 "in_proj", "w_dtx", "w_B", "w_C", "w_dt", "enc_in_proj")
_ROW_PARALLEL = ("wo", "w_down", "out_proj")
_VOCAB = ("embed", "lm_head")
_CHANNEL = ("conv_w", "conv_b", "A_log", "D", "dt_bias")


def param_spec_for(mesh: Mesh, path: tuple[str, ...], shape: tuple) -> P:
    name = path[-1]
    in_experts = "experts" in path
    if in_experts:
        # (L, E, d, ff): EP — shard experts
        want = [None] * len(shape)
        want[1] = "model"
        return fit_spec(mesh, shape, tuple(want))
    if name in _VOCAB:
        return fit_spec(mesh, shape, ("model", None))
    if name in _COL_PARALLEL:
        want = [None] * len(shape)
        want[-1] = "model"
        return fit_spec(mesh, shape, tuple(want))
    if name in _ROW_PARALLEL:
        want = [None] * len(shape)
        want[-2] = "model"
        return fit_spec(mesh, shape, tuple(want))
    if name in _CHANNEL:
        # (L, din, ...) — shard the channel dim
        want = [None] * len(shape)
        if len(shape) >= 2:
            want[1] = "model"
        return fit_spec(mesh, shape, tuple(want))
    return P()  # norms, router, scalars: replicated


def param_shardings(cfg: ModelConfig, mesh: Mesh, specs) -> Any:
    """Map a param pytree (arrays or ShapeDtypeStructs) to NamedShardings."""
    def one(path, leaf):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        return NamedSharding(mesh, param_spec_for(mesh, names, leaf.shape))
    return jax.tree_util.tree_map_with_path(one, specs)


# -- batches ------------------------------------------------------------------


def batch_spec(mesh: Mesh, name: str, shape: tuple) -> P:
    dp = dp_axes(mesh)
    if name == "positions" and len(shape) == 3:      # (3, B, S) mrope
        return fit_spec(mesh, shape, (None, dp, None))
    if len(shape) >= 2:
        return fit_spec(mesh, shape, (dp,) + (None,) * (len(shape) - 1))
    return P()


def batch_shardings(mesh: Mesh, batch_specs: dict) -> dict:
    return {k: NamedSharding(mesh, batch_spec(mesh, k, v.shape))
            for k, v in batch_specs.items() if v is not None}


# -- decode caches ------------------------------------------------------------


def cache_spec_for(mesh: Mesh, path: tuple[str, ...], shape: tuple) -> P:
    dp = dp_axes(mesh)
    name = path[-1]
    if name in ("k", "v", "xk", "xv"):
        # (L, B, S, hkv, hd): batch over dp, SEQUENCE over model
        return fit_spec(mesh, shape, (None, dp, "model", None, None)[:len(shape)])
    if name == "index":
        return P()
    # ssm states: (L, B, ..., din/H, ...) — shard channels on model
    if len(shape) >= 3:
        want = [None, dp] + [None] * (len(shape) - 2)
        want[2] = "model"
        return fit_spec(mesh, shape, tuple(want))
    return P()


def cache_shardings(mesh: Mesh, specs) -> Any:
    def one(path, leaf):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        return NamedSharding(mesh, cache_spec_for(mesh, names, leaf.shape))
    return jax.tree_util.tree_map_with_path(one, specs)

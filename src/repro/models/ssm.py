"""Selective state-space blocks: Mamba1 (falcon-mamba) and Mamba2/SSD (zamba2).

Training/prefill uses a CHUNKED scan: an outer lax.scan over sequence chunks
carries the (B, din, state) recurrent state; inside a chunk the linear
recurrence h_t = dA_t h_{t-1} + dBx_t is evaluated with an associative scan —
O(B·chunk·din·state) live memory instead of O(B·S·din·state), which is what
makes the 4k-train and 500k-decode shapes fit (DESIGN.md §5).

Decode is O(1) in context length: the entire "KV cache" is the SSM state plus
a (conv_kernel-1)-deep convolution tail — the reason the long_500k shape runs
for the SSM/hybrid architectures only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig


def _assoc_combine(a, b):
    """Compose linear recurrences h -> A h + b."""
    a1, b1 = a
    a2, b2 = b
    return a2 * a1, a2 * b1 + b2


def _causal_conv(x, w, b, kernel: int):
    """Depthwise causal conv1d: x (B, S, din), w (din, k), b (din,)."""
    pad = jnp.pad(x, ((0, 0), (kernel - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(kernel):
        out = out + pad[:, i:i + x.shape[1], :].astype(jnp.float32) * \
            w[kernel - 1 - i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, cfg.d_model // 16)


# ---------------------------------------------------------------------------
# Mamba1
# ---------------------------------------------------------------------------

def mamba1_forward(p: dict, x, cfg: ModelConfig, state=None):
    """x: (B, S, d).  state: None (train) or (conv_tail, h) for decode.

    Returns (y, new_state)."""
    b, s, d = x.shape
    din, st, k = cfg.din, cfg.ssm_state, cfg.conv_kernel
    dt = x.dtype
    xz = x @ p["in_proj"].astype(dt)                  # (B, S, 2*din)
    x1, z = jnp.split(xz, 2, axis=-1)

    if state is None:
        x1 = _causal_conv(x1, p["conv_w"].T, p["conv_b"], k)
        conv_tail_new = None
    else:
        conv_tail, h0 = state
        # decode: prepend cached tail, conv over the last k samples
        seq = jnp.concatenate([conv_tail, x1], axis=1)     # (B, k-1+s, din)
        x1 = _causal_conv(seq, p["conv_w"].T, p["conv_b"], k)[:, k - 1:, :]
        conv_tail_new = seq[:, -(k - 1):, :]
    x1 = jax.nn.silu(x1)

    # input-dependent SSM parameters
    dt_lr = x1 @ p["w_dtx"].astype(dt)                 # (B, S, rank)
    delta = jax.nn.softplus(
        (dt_lr @ p["w_dt"].astype(dt)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))            # (B, S, din) f32
    Bm = (x1 @ p["w_B"].astype(dt)).astype(jnp.float32)    # (B, S, st)
    Cm = (x1 @ p["w_C"].astype(dt)).astype(jnp.float32)    # (B, S, st)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))       # (din, st)

    x1f = x1.astype(jnp.float32)

    def chunk(h, xs):
        xc, dc, bc, cc = xs                            # (B, c, ...)
        dA = jnp.exp(dc[..., None] * A)                # (B, c, din, st)
        dBx = (dc * xc)[..., None] * bc[:, :, None, :]  # (B, c, din, st)
        cumA, cumB = lax.associative_scan(_assoc_combine, (dA, dBx), axis=1)
        hs = cumA * h[:, None] + cumB                  # (B, c, din, st)
        y = jnp.einsum("bcds,bcs->bcd", hs, cc)
        return hs[:, -1], y

    if state is None and s > 1:
        c = min(cfg.ssm_chunk, s)
        nch = -(-s // c)
        pad = nch * c - s
        def pads(a):
            return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        xs = tuple(a.reshape(b, nch, c, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))
                   for a in map(pads, (x1f, delta, Bm, Cm)))
        h0 = jnp.zeros((b, din, st), jnp.float32)
        h_last, ys = lax.scan(chunk, h0, xs, unroll=cfg.unroll_scans)
        y = ys.transpose(1, 0, 2, 3).reshape(b, nch * c, din)[:, :s]
        new_state = None
    else:
        h0 = jnp.zeros((b, din, st), jnp.float32) if state is None else state[1]
        h_last, y = chunk(h0, (x1f, delta, Bm, Cm))
        new_state = (conv_tail_new, h_last) if state is not None else None

    y = y + x1f * p["D"].astype(jnp.float32)
    y = (y.astype(dt) * jax.nn.silu(z))
    out = y @ p["out_proj"].astype(dt)
    return out, new_state


def mamba1_param_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    din, st, d, k = cfg.din, cfg.ssm_state, cfg.d_model, cfg.conv_kernel
    r = dt_rank(cfg)
    return {
        "in_proj": (d, 2 * din), "conv_w": (din, k), "conv_b": (din,),
        "w_dtx": (din, r), "w_dt": (r, din), "dt_bias": (din,),
        "w_B": (din, st), "w_C": (din, st),
        "A_log": (din, st), "D": (din,), "out_proj": (din, d),
    }


# ---------------------------------------------------------------------------
# Mamba2 (SSD): per-head scalar decay, shared B/C across head channels
# ---------------------------------------------------------------------------

def mamba2_forward(p: dict, x, cfg: ModelConfig, state=None):
    """Simplified SSD block (scalar A per head).  x: (B, S, d)."""
    b, s, d = x.shape
    din, st, k = cfg.din, cfg.ssm_state, cfg.conv_kernel
    hd = cfg.mamba_headdim
    H = din // hd
    dt = x.dtype
    xz = x @ p["in_proj"].astype(dt)
    x1, z = jnp.split(xz, 2, axis=-1)

    if state is None:
        x1 = _causal_conv(x1, p["conv_w"].T, p["conv_b"], k)
        conv_tail_new = None
    else:
        conv_tail, h0 = state
        seq = jnp.concatenate([conv_tail, x1], axis=1)
        x1 = _causal_conv(seq, p["conv_w"].T, p["conv_b"], k)[:, k - 1:, :]
        conv_tail_new = seq[:, -(k - 1):, :]
    x1 = jax.nn.silu(x1)

    delta = jax.nn.softplus(
        (x1 @ p["w_dt"].astype(dt)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))            # (B, S, H)
    Bm = (x1 @ p["w_B"].astype(dt)).astype(jnp.float32)    # (B, S, st)
    Cm = (x1 @ p["w_C"].astype(dt)).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))       # (H,)
    xh = x1.astype(jnp.float32).reshape(b, s, H, hd)

    def chunk(h, xs):
        xc, dc, bc, cc = xs                            # (B,c,H,hd) (B,c,H) (B,c,st)
        dA = jnp.exp(dc * A)                           # (B, c, H)
        dBx = jnp.einsum("bch,bchp,bcs->bchps", dc, xc, bc)   # (B,c,H,hd,st)
        cumA, cumB = lax.associative_scan(
            _assoc_combine, (dA[..., None, None], dBx), axis=1)
        hs = cumA * h[:, None] + cumB                  # (B,c,H,hd,st)
        y = jnp.einsum("bchps,bcs->bchp", hs, cc)
        return hs[:, -1], y

    if state is None and s > 1:
        c = min(cfg.ssm_chunk, s)
        nch = -(-s // c)
        pad = nch * c - s
        def pads(a):
            return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        xs = tuple(a.reshape(b, nch, c, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))
                   for a in map(pads, (xh, delta, Bm, Cm)))
        h0 = jnp.zeros((b, H, hd, st), jnp.float32)
        h_last, ys = lax.scan(chunk, h0, xs, unroll=cfg.unroll_scans)
        y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nch * c, H, hd)[:, :s]
        new_state = None
    else:
        h0 = jnp.zeros((b, H, hd, st), jnp.float32) if state is None else state[1]
        h_last, y = chunk(h0, (xh, delta, Bm, Cm))
        new_state = (conv_tail_new, h_last) if state is not None else None

    y = y.reshape(b, s, din) + x1.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = y.astype(dt) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt)
    return out, new_state


def mamba2_param_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    din, st, d, k = cfg.din, cfg.ssm_state, cfg.d_model, cfg.conv_kernel
    H = din // cfg.mamba_headdim
    return {
        "in_proj": (d, 2 * din), "conv_w": (din, k), "conv_b": (din,),
        "w_dt": (din, H), "dt_bias": (H,),
        "w_B": (din, st), "w_C": (din, st),
        "A_log": (H,), "D": (din,), "out_proj": (din, d),
    }


def ssm_state_shapes(cfg: ModelConfig, batch: int) -> tuple[tuple, tuple]:
    """(conv_tail, h) shapes for one layer's decode state."""
    din, st, k = cfg.din, cfg.ssm_state, cfg.conv_kernel
    if cfg.mamba_version == 2:
        H = din // cfg.mamba_headdim
        return ((batch, k - 1, din), (batch, H, cfg.mamba_headdim, st))
    return ((batch, k - 1, din), (batch, din, st))

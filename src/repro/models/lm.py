"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

Layers are scanned (stacked params, lax.scan) to keep HLO small — one While
body per homogeneous block type; heterogeneous structure (MoE first-dense
layers, Zamba's shared attention block) is expressed as a short unrolled
Python loop of scans.  Decode carries caches through the same scans.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import attention_block, norm, swiglu

# TP mesh registry (set by the launcher) for sharding-constraint perf paths.
_TP_MESH = None


def set_tp_mesh(mesh):
    global _TP_MESH
    _TP_MESH = mesh


def _attn_dp_constraint(x, cfg):
    """§Perf lever: when heads don't divide the model axis (yi-34b: 56 heads
    on 16), Megatron-style head TP degenerates into per-layer activation
    resharding (measured: 35 GiB all-reduce/layer).  Instead run attention
    DATA-parallel over (dp x model): batch sharded across every chip, the
    (much smaller) per-layer attention weights all-gathered FSDP-style."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = _TP_MESH
    if not (cfg.attn_batch_shard and mesh is not None
            and "model" in mesh.axis_names):
        return x, None
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    all_ax = dp + ("model",)
    total = 1
    for a in all_ax:
        total *= mesh.shape[a]
    if x.shape[0] % total != 0:
        return x, None
    inner = NamedSharding(mesh, P(all_ax, None, None))
    outer = NamedSharding(mesh, P(dp, None, None))
    return jax.lax.with_sharding_constraint(x, inner), outer


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def _pdt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# parameter shapes / init / specs
# ---------------------------------------------------------------------------


def _attn_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = {"wq": (d, h * hd), "wk": (d, hkv * hd), "wv": (d, hkv * hd),
         "wo": (h * hd, d)}
    if cfg.qkv_bias:
        s |= {"bq": (h * hd,), "bk": (hkv * hd,), "bv": (hkv * hd,)}
    if cfg.qk_norm:
        s |= {"q_norm": (hd,), "k_norm": (hd,)}
    return s


def _mlp_shapes(d: int, ff: int) -> dict[str, tuple]:
    return {"w_gate": (d, ff), "w_up": (d, ff), "w_down": (ff, d)}


def _ln_shapes(cfg: ModelConfig, names: tuple[str, ...]) -> dict[str, tuple]:
    if cfg.nonparam_ln:
        return {}
    return {n: (cfg.d_model,) for n in names}


def param_shapes(cfg: ModelConfig) -> dict:
    """Nested dict of parameter shapes (pre-stacking: per-layer dicts carry a
    leading L dim added here)."""
    d, V = cfg.d_model, cfg.vocab
    out: dict[str, Any] = {"embed": (V, d)}
    if not cfg.tie_embeddings:
        out["lm_head"] = (V, d)
    out["final_ln"] = (d,)

    def stack(shapes: dict, L: int) -> dict:
        return jax.tree.map(lambda s: (L, *s), shapes,
                            is_leaf=lambda x: isinstance(x, tuple))

    if cfg.family in ("dense", "vlm"):
        layer = {"attn": _attn_shapes(cfg), "mlp": _mlp_shapes(d, cfg.d_ff)}
        layer |= _ln_shapes(cfg, ("ln1", "ln2"))
        out["layers"] = stack(layer, cfg.n_layers)
    elif cfg.family == "moe":
        nl = cfg.n_layers - cfg.first_dense_layers
        layer = {"attn": _attn_shapes(cfg), "moe": moe_mod.moe_param_shapes(cfg)}
        layer |= _ln_shapes(cfg, ("ln1", "ln2"))
        out["layers"] = stack(layer, nl)
        if cfg.first_dense_layers:
            dl = {"attn": _attn_shapes(cfg),
                  "mlp": _mlp_shapes(d, cfg.d_ff_first_dense)}
            dl |= _ln_shapes(cfg, ("ln1", "ln2"))
            out["dense_layers"] = stack(dl, cfg.first_dense_layers)
    elif cfg.family == "ssm":
        layer = {"mamba": ssm_mod.mamba1_param_shapes(cfg)}
        layer |= _ln_shapes(cfg, ("ln1",))
        out["layers"] = stack(layer, cfg.n_layers)
    elif cfg.family == "hybrid":
        mshapes = (ssm_mod.mamba1_param_shapes if cfg.mamba_version == 1
                   else ssm_mod.mamba2_param_shapes)
        layer = {"mamba": mshapes(cfg)}
        layer |= _ln_shapes(cfg, ("ln1",))
        out["layers"] = stack(layer, cfg.n_layers)
        shared = {"attn": _attn_shapes(cfg), "mlp": _mlp_shapes(d, cfg.d_ff)}
        shared |= _ln_shapes(cfg, ("ln1", "ln2"))
        out["shared_block"] = shared
    else:
        raise ValueError(cfg.family)
    return out


def param_specs(cfg: ModelConfig):
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    pdt = _pdt(cfg)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, pdt),
                        param_shapes(cfg),
                        is_leaf=lambda x: isinstance(x, tuple))


def init_params(cfg: ModelConfig, key):
    """Real initialization (smoke tests / the ~100M example run)."""
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree.flatten(shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(leaves))
    pdt = _pdt(cfg)

    def init_one(shape, k):
        if len(shape) <= 2 and (shape[-1:] == (cfg.d_model,) or len(shape) == 1):
            # norms / biases / 1-d params
            if "int" in str(pdt):
                return jnp.zeros(shape, pdt)
            return jnp.ones(shape, pdt) if len(shape) == 1 else \
                jax.random.normal(k, shape, pdt) * 0.02
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        return (jax.random.normal(k, shape) * (1.0 / np.sqrt(fan_in))).astype(pdt)

    inits = [init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, inits)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _transformer_block(lp, x, cfg: ModelConfig, positions, cache=None,
                       mlp_fn=None):
    xa = norm(x, lp.get("ln1"), cfg)
    xa, outer = _attn_dp_constraint(xa, cfg)
    h, new_cache = attention_block(
        lp["attn"], xa, cfg, positions, cache=cache)
    if outer is not None:
        h = jax.lax.with_sharding_constraint(h, outer)
    x = x + h
    y = (mlp_fn or (lambda p_, v: swiglu(p_, v)))(lp, norm(x, lp.get("ln2"), cfg))
    if isinstance(y, tuple):
        y, aux = y
    else:
        aux = 0.0
    return x + y, new_cache, aux


def _mamba_block(lp, x, cfg: ModelConfig, state=None):
    fwd = ssm_mod.mamba1_forward if cfg.mamba_version == 1 else ssm_mod.mamba2_forward
    h, new_state = fwd(lp["mamba"], norm(x, lp.get("ln1"), cfg), cfg, state=state)
    return x + h, new_state


# ---------------------------------------------------------------------------
# forward (train / prefill); cache-threaded scan for decode
# ---------------------------------------------------------------------------


def _scan_blocks(params_stacked, x, body, caches=None, remat=False,
                 unroll=False):
    """Scan a homogeneous stack of layers, threading optional caches.

    remat=True wraps the body in jax.checkpoint (rematerialization): the
    backward pass recomputes layer internals from the (B,S,d) carry instead
    of saving L x per-layer activations — the standard memory/compute trade
    that makes the 4k-train shapes fit HBM (accounted in §Roofline via the
    MODEL_FLOPS/HLO_FLOPs ratio).
    """
    if caches is None:
        def f(carry, lp):
            y, _c, aux = body(lp, carry, None)
            return y, aux
        if remat:
            f = jax.checkpoint(f)
        x, auxs = lax.scan(f, x, params_stacked, unroll=unroll)
        return x, None, jnp.sum(auxs) if auxs is not None else 0.0

    def f(carry, xs):
        lp, cache = xs
        y, new_cache, aux = body(lp, carry, cache)
        return y, (new_cache, aux)
    x, (new_caches, auxs) = lax.scan(f, x, (params_stacked, caches),
                                     unroll=unroll)
    return x, new_caches, jnp.sum(auxs)


def forward(params, tokens, cfg: ModelConfig, *, positions=None,
            inputs_embeds=None, caches=None, q_offset=None):
    """Shared forward.  tokens: (B, S) int32 (or inputs_embeds for vlm).

    caches: None for train/prefill-logits; a cache pytree for decode.
    Returns (logits, new_caches, aux_loss).
    """
    cdt = _dt(cfg)
    if inputs_embeds is not None:
        x = inputs_embeds.astype(cdt)
        b, s = x.shape[:2]
    else:
        b, s = tokens.shape
        x = params["embed"].astype(cdt)[tokens]
    if positions is None:
        base = jnp.arange(s, dtype=jnp.int32)[None, :] + (
            q_offset if q_offset is not None else 0)
        positions = jnp.broadcast_to(base, (b, s))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[None], (3, b, s))

    total_aux = 0.0
    new_caches: dict[str, Any] = {}
    remat = (cfg.remat == "block") and caches is None
    unroll = cfg.unroll_scans

    if cfg.family in ("dense", "vlm"):
        def body(lp, h, cache):
            return _transformer_block(lp, h, cfg, positions, cache=cache,
                                      mlp_fn=lambda p_, v: swiglu(p_["mlp"], v))
        x, nc, aux = _scan_blocks(params["layers"], x, body,
                                  None if caches is None else caches["layers"],
                                  remat=remat, unroll=unroll)
        new_caches["layers"] = nc
        total_aux += aux

    elif cfg.family == "moe":
        if cfg.first_dense_layers:
            def dbody(lp, h, cache):
                return _transformer_block(lp, h, cfg, positions, cache=cache,
                                          mlp_fn=lambda p_, v: swiglu(p_["mlp"], v))
            x, ncd, aux = _scan_blocks(
                params["dense_layers"], x, dbody,
                None if caches is None else caches["dense_layers"],
                remat=remat, unroll=unroll)
            new_caches["dense_layers"] = ncd
            total_aux += aux

        def mbody(lp, h, cache):
            return _transformer_block(lp, h, cfg, positions, cache=cache,
                                      mlp_fn=lambda p_, v: moe_mod.moe_block(p_["moe"], v, cfg))
        x, ncm, aux = _scan_blocks(params["layers"], x, mbody,
                                   None if caches is None else caches["layers"],
                                   remat=remat, unroll=unroll)
        new_caches["layers"] = ncm
        total_aux += aux

    elif cfg.family == "ssm":
        def sbody(lp, h, state):
            y, ns = _mamba_block(lp, h, cfg, state=state)
            return y, ns, 0.0
        x, ns, _ = _scan_blocks(params["layers"], x, sbody,
                                None if caches is None else caches["layers"],
                                remat=remat, unroll=unroll)
        new_caches["layers"] = ns

    elif cfg.family == "hybrid":
        # Zamba structure: groups of `period` Mamba2 layers with ONE weight-
        # shared attention+MLP block applied between groups.  Lowered as a
        # scan over GROUPS (shared weights closed over, so every group body
        # is identical -> a single While in HLO); the tail (L % period
        # layers + one final shared application) is scanned separately.
        period = cfg.shared_attn_period or cfg.n_layers
        L = cfg.n_layers
        n_groups, tail = divmod(L, period)

        def hbody(lp, h, state):
            y, ns = _mamba_block(lp, h, cfg, state=state)
            return y, ns, 0.0

        def shared_apply(h, sc):
            return _transformer_block(
                params["shared_block"], h, cfg, positions, cache=sc,
                mlp_fn=lambda p_, v: swiglu(p_["mlp"], v))

        def regroup(a):
            return a[: n_groups * period].reshape(
                n_groups, period, *a.shape[1:])

        grp = jax.tree.map(regroup, params["layers"])
        tail_p = jax.tree.map(lambda a: a[n_groups * period:], params["layers"])

        def group_body(h, xs):
            lp_grp, cache_grp, sc = xs
            h, ns, _ = _scan_blocks(lp_grp, h, hbody, cache_grp,
                                    remat=False, unroll=unroll)
            h, nsc, _ = shared_apply(h, sc)
            return h, (ns, nsc)

        if remat:
            group_body = jax.checkpoint(group_body)

        if caches is None:
            xs = (grp, None, None)
            # scan needs concrete xs leaves; build dummy Nones via length
            def gb(h, lp_grp):
                h, ns, _ = _scan_blocks(lp_grp, h, hbody, None,
                                        remat=False, unroll=unroll)
                h, _nsc, _ = shared_apply(h, None)
                return h, None
            if remat:
                gb = jax.checkpoint(gb)
            x, _ = lax.scan(gb, x, grp, unroll=unroll)
            new_caches["layers"] = None
            new_caches["shared"] = None
            if tail:
                x, _, _ = _scan_blocks(tail_p, x, hbody, None,
                                       remat=remat, unroll=unroll)
                x, _, _ = shared_apply(x, None)
        else:
            cache_grp = jax.tree.map(regroup, caches["layers"])
            x, (ns_grp, nsc_grp) = lax.scan(
                group_body, x, (grp, cache_grp, caches["shared"]["grp"]),
                unroll=unroll)
            ns_flat = jax.tree.map(
                lambda a: a.reshape(n_groups * period, *a.shape[2:]), ns_grp)
            new_shared = {"grp": nsc_grp}
            if tail:
                tail_cache = jax.tree.map(lambda a: a[n_groups * period:],
                                          caches["layers"])
                x, ns_tail, _ = _scan_blocks(tail_p, x, hbody, tail_cache,
                                             remat=False, unroll=unroll)
                x, nsc_tail, _ = shared_apply(x, caches["shared"]["tail"])
                ns_flat = jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                                       ns_flat, ns_tail)
                new_shared["tail"] = nsc_tail
            new_caches["layers"] = ns_flat
            new_caches["shared"] = new_shared
    else:
        raise ValueError(cfg.family)

    x = norm(x, params.get("final_ln"), cfg)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head.astype(cdt))
    return logits, (new_caches if caches is not None else None), total_aux


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------


def loss_fn(params, batch, cfg: ModelConfig):
    """Causal LM loss.  batch: {tokens (B,S), labels (B,S)} (+ vlm extras)."""
    logits, _, aux = forward(
        params, batch.get("tokens"), cfg,
        positions=batch.get("positions"),
        inputs_embeds=batch.get("inputs_embeds"))
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ll = (logz - gold) * mask
    loss = jnp.sum(ll) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache_specs(cfg: ModelConfig, batch: int, max_seq: int):
    """ShapeDtypeStruct pytree of the decode cache."""
    cdt = _dt(cfg)
    hkv, hd = cfg.n_kv_heads, cfg.hd

    def attn_cache(n):
        return {
            "k": jax.ShapeDtypeStruct((n, batch, max_seq, hkv, hd), cdt),
            "v": jax.ShapeDtypeStruct((n, batch, max_seq, hkv, hd), cdt),
            "index": jax.ShapeDtypeStruct((n,), jnp.int32),
        }

    def ssm_cache(n):
        conv_s, h_s = ssm_mod.ssm_state_shapes(cfg, batch)
        return (jax.ShapeDtypeStruct((n, *conv_s), cdt),
                jax.ShapeDtypeStruct((n, *h_s), jnp.float32))

    if cfg.family in ("dense", "vlm"):
        return {"layers": attn_cache(cfg.n_layers)}
    if cfg.family == "moe":
        out = {"layers": attn_cache(cfg.n_layers - cfg.first_dense_layers)}
        if cfg.first_dense_layers:
            out["dense_layers"] = attn_cache(cfg.first_dense_layers)
        return out
    if cfg.family == "ssm":
        return {"layers": ssm_cache(cfg.n_layers)}
    if cfg.family == "hybrid":
        period = cfg.shared_attn_period or cfg.n_layers
        n_groups, tail = divmod(cfg.n_layers, period)
        shared = {"grp": attn_cache(n_groups)}
        if tail:
            a = attn_cache(1)
            shared["tail"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), a)
        return {"layers": ssm_cache(cfg.n_layers), "shared": shared}
    raise ValueError(cfg.family)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    specs = init_cache_specs(cfg, batch, max_seq)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)


def decode_step(params, token, caches, cfg: ModelConfig, positions=None):
    """One-token decode.  token: (B, 1) int32.  Returns (logits, caches).

    Attention caches are stacked (L, ...) pytrees; lax.scan slices one layer's
    {k, v, index-scalar} per step and restacks the updates — the cache flows
    through the same scan as the parameters.
    """
    if cfg.family in ("dense", "vlm", "moe"):
        idx = caches["layers"]["index"][0]
    elif cfg.family == "hybrid":
        idx = caches["shared"]["grp"]["index"][0]
    else:
        idx = None  # SSM: position-free
    logits, new_caches, _ = forward(params, token, cfg, caches=caches,
                                    q_offset=idx, positions=positions)
    return logits[:, -1], new_caches

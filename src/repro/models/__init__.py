"""Model zoo: decoder-only LM families + whisper enc-dec + sharding rules."""
from . import config, layers, lm, moe, sharding, ssm, whisper
from .config import ModelConfig

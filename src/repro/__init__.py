"""repro — HiFrames on JAX/TPU: distributed data frames + LM training substrate."""
from . import core
from .core import api as hiframes  # `from repro import hiframes as hf`

__version__ = "0.1.0"

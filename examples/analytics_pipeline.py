"""Advanced analytics walk-through (paper Fig. 8b territory): cumulative
sums, moving averages, and free mixing with array code — with EXPLAIN output
showing where the distribution pass inserts communication.

Run:  PYTHONPATH=src python examples/analytics_pipeline.py
"""
import numpy as np

from repro import hiframes as hf

rng = np.random.default_rng(0)
n = 500_000

# a synthetic daily price series with regime changes
t = np.arange(n, dtype=np.float32)
price = (np.cumsum(rng.normal(0, 0.5, n)) + 100
         + 5 * np.sin(t / 5000)).astype(np.float32)
volume = rng.gamma(2.0, 100.0, n).astype(np.float32)

df = hf.table({"price": price, "volume": volume})

# running turnover: cumsum of price*volume — expression feeds the window op
turnover = hf.cumsum(df, df["price"] * df["volume"], out="turnover")

# 5-point weighted moving average (WMA) — stencil + halo exchange
smooth = hf.wma(df, df["price"], [1, 2, 3, 2, 1], out="wma")
print("=== WMA plan (stencil on 1D_BLOCK, no rebalance needed) ===")
print(smooth.explain())

# filtered series then SMA — note the Rebalance the pass inserts (1D_VAR
# filter output -> stencil needs 1D_BLOCK).  Fluent chain + df.volume sugar.
liquid = df[df.volume > 150.0]
liquid_sma = hf.sma(liquid, liquid.price, 3, out="sma")
print("\n=== filtered SMA plan (Rebalance inserted automatically) ===")
print(liquid_sma.explain())

# trailing rolling mean, padded vs exact borders: the exact mode divides by
# the rows that actually contributed (pandas min_periods=1), so the leading
# edge is unbiased instead of damped toward zero.
rm_pad = hf.rolling_mean(df, df.price, 20, out="rm")
rm_exact = hf.rolling_mean(df, df.price, 20, out="rm", exact=True)

out = turnover.collect().to_numpy()
ref = np.cumsum(price.astype(np.float64) * volume)
print("\ncumsum rel-err:",
      abs(out["turnover"][-1] - ref[-1]) / abs(ref[-1]))

w = smooth.collect().to_numpy()["wma"]
print("wma sample:", w[1000:1003], "vs raw:", price[1000:1003])

pad = rm_pad.collect().to_numpy()["rm"]
exact = rm_exact.collect().to_numpy()["rm"]
print("rolling-mean row 0: padded", pad[0], "exact", exact[0],
      "raw", price[0])

ls = liquid_sma.collect()
print(f"liquid rows: {ls.num_rows()} / {n}")

# free integration with array code: z-score of the WMA, back into a frame
z = (w - w.mean()) / w.std()
spikes = hf.table({"z": z.astype(np.float32)})
n_spikes = spikes[abs(spikes["z"]) > 3.0].collect().num_rows()
print("3-sigma spikes:", n_spikes)

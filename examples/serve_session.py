"""Serving-session walk-through (docs/serving.md): one long-lived Session
owning the mesh, a shared-table registry, and a fingerprint-keyed plan
cache — the steady-state multi-query deployment shape.

Run:  PYTHONPATH=src python examples/serve_session.py
(8 fake devices: XLA_FLAGS=--xla_force_host_platform_device_count=8)
"""
import numpy as np

from repro import hiframes as hf
from repro.core.api import ExecConfig
from repro.data import synth
from repro.runtime.session import Session

with Session(ExecConfig()) as sess:
    # --- registry: layout once, share with every query -------------------
    ss = synth.store_sales(50_000, n_items=1_000, n_customers=5_000, seed=0)
    it = synth.item(1_000, seed=1)
    sess.register("store_sales", hf.table(ss, "store_sales"),
                  partition_by="ss_item_sk")
    sess.register("item", hf.table(it, "item").replicate())

    def q26():
        s, i = sess.table("store_sales"), sess.table("item")
        j = s.merge(i, on=("ss_item_sk", "i_item_sk"))
        agg = (j.groupby("ss_customer_sk")
               .agg(cnt="count", cls=hf.sum_(j["i_class_id"] == 1)))
        return agg[agg["cnt"] > 2]

    def leaderboard():
        s = sess.table("store_sales")
        per = s.groupby("ss_customer_sk").agg(spend=("ss_net_paid", "sum"))
        # global rank (no partition_by): per-shard-count exscan + O(P)
        # boundary scalars — no second global sort, no row movement.
        return hf.rank(per, [], ["spend"], out="r", ascending=False)

    # --- cold pass: plans, lowers, compiles ------------------------------
    t1 = sess.collect(q26())
    t2 = sess.collect(leaderboard())
    print("=== cold ===")
    for t in (t1, t2):
        r = t.query_record
        print(f"  {r.cache:12s} plan={r.plan_s * 1e3:7.1f}ms "
              f"exec={r.exec_s * 1e3:7.1f}ms compiles={r.compiles}")

    # --- warm pass: same shapes -> cache hits, zero compiles -------------
    # (concurrent: submit() overlaps host planning, mesh stays serialized)
    futs = [sess.submit(q26()), sess.submit(leaderboard())]
    print("=== warm ===")
    for f in futs:
        r = f.result().query_record
        print(f"  {r.cache:12s} plan={r.plan_s * 1e3:7.1f}ms "
              f"exec={r.exec_s * 1e3:7.1f}ms compiles={r.compiles}")

    # a DIFFERENT same-shape table hits too: the cache key is the shape
    # fingerprint (schema + layout geometry), not the table identity — the
    # compiled executable is rebound onto the new buffers.
    ss2 = synth.store_sales(50_000, n_items=1_000, n_customers=5_000,
                            seed=7)
    sess.register("store_sales_v2", hf.table(ss2, "store_sales_v2"),
                  partition_by="ss_item_sk")
    s2 = sess.table("store_sales_v2")
    per2 = s2.groupby("ss_customer_sk").agg(spend=("ss_net_paid", "sum"))
    r2 = sess.collect(hf.rank(per2, [], ["spend"], out="r",
                              ascending=False)).query_record
    print(f"=== rebind (new table, same shape) ===\n  {r2.cache}")

    print("=== session stats ===")
    st = sess.stats()
    print(f"  queries={st['queries']} cache={st['plan_cache']} "
          f"compiles={st['compiles']}")
    print(sess.explain(leaderboard()).splitlines()[0])

# On exit the session drained its pool, saved the stats sidecar (when
# session_dir is set), and released the mesh.  docs/serving.md covers the
# cache-key definition, resharding (P -> P'), and failure behaviour.

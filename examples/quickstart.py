"""Quickstart: the HiFrames data-frame API (paper Table 1) in 40 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import hiframes as hf

rng = np.random.default_rng(0)
n = 100_000

# DataSource analogue: a frame from arrays (columns ARE arrays — dual repr.)
df = hf.table({
    "id": rng.integers(0, 100, n).astype(np.int32),
    "x": rng.normal(size=n).astype(np.float32),
    "y": rng.normal(size=n).astype(np.float32),
})

# filter — compiles to a no-communication compaction (1D_VAR output)
small = df[df["id"] < 10]

# join — hash-shuffle + sort-merge; different key names allowed
dim = hf.table({"cid": np.arange(100, dtype=np.int32),
                "weight": rng.normal(size=100).astype(np.float32)}, "dim")
joined = hf.join(df, dim, on=("id", "cid"))

# aggregate with expressions (sum(:x < 1.0) — the paper's sugar)
stats = hf.aggregate(joined, "id",
                     xc=hf.sum_(joined["x"] < 1.0),
                     ym=hf.mean(joined["y"]),
                     n=hf.count())

# analytics: cumsum (MPI_Exscan pattern) and WMA (stencil + halo exchange)
cs = hf.cumsum(df, df["x"], out="running")
wma = hf.wma(df, df["x"], [1, 2, 1], out="smooth")

# UDFs compile into the same program — zero overhead (paper Fig. 10)
via_udf = df[hf.udf(lambda x, y: np.cos(1.0) * x + y > 0.0, df["x"], df["y"])]

# EXPLAIN shows the optimized plan + inferred distributions (Fig. 7 lattice)
f = joined[joined["weight"] > 0.0]        # will push below the join
print("=== optimized plan (note Filter pushed under Join) ===")
print(f.explain())

print("\n=== results ===")
t = stats.collect()
print("aggregate:", t)
out = t.to_numpy()
print("first rows:", {k: v[:4] for k, v in out.items()})
print("cumsum tail:", cs.collect().to_numpy()["running"][-3:])
print("wma head:", wma.collect().to_numpy()["smooth"][:3])
print("udf rows:", via_udf.collect().num_rows())

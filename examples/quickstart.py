"""Quickstart: the fluent HiFrames data-frame API in 40 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import hiframes as hf

rng = np.random.default_rng(0)
n = 100_000

# DataSource analogue: a frame from arrays (columns ARE arrays — dual repr.)
df = hf.table({
    "id": rng.integers(0, 100, n).astype(np.int32),
    "x": rng.normal(size=n).astype(np.float32),
    "y": rng.normal(size=n).astype(np.float32),
})

# a dimension table to join against
dim = hf.table({"cid": np.arange(100, dtype=np.int32),
                "weight": rng.normal(size=100).astype(np.float32)}, "dim")

# the fluent chain: filter -> join -> derived column -> group-by -> top-k.
# Everything is LAZY; collect() compiles ONE SPMD program.
stats = (df[df.id < 50]                          # filter: no communication
           .merge(dim, on=("id", "cid"))         # join (key-pair form)
           .assign(wx=lambda d: d.x * d.weight)  # derived column
           .groupby("id")
           .agg(xc=(df.x < 1.0, "sum"),          # expression agg (paper sugar)
                ym=("y", "mean"),
                ws=("wx", "sum"),
                n="count")
           .sort_values("n", ascending=False)
           .head(10))                            # top-k: count clamps only

# column assignment, the paper's df[:c] = ... form
df["r"] = df.x / (abs(df.y) + 1.0)

# analytics: running total and weighted moving average (halo-exchange stencil)
cs = hf.cumsum(df, df.x, out="running")
wma = hf.wma(df, df.x, [1, 2, 1], out="smooth")
# exact rolling mean (pandas min_periods=1 borders)
rm = hf.rolling_mean(df, df.x, 5, out="rm", exact=True)

# UDFs compile into the same program — zero overhead (paper Fig. 10)
via_udf = df[hf.udf(lambda x, y: np.cos(1.0) * x + y > 0.0, df.x, df.y)]

# EXPLAIN shows the optimized plan + the physical plan with its shuffle census
print("=== plan ===")
print(stats.explain())

# persist(): materialize WITH layout — the repeated-query hook.  The second
# aggregation below plans ZERO exchanges and ZERO sorts and its device
# shards re-enter execution without a host round-trip.
hot = df.groupby("id").agg(s=("x", "sum"), m=("y", "mean")).persist()
again = hot.groupby("id").agg(total=("s", "sum"))
print("\n=== persisted re-aggregation (0 shuffles, 0 sorts) ===")
print(again.explain().split("\n\n")[1].splitlines()[0])

print("\n=== results ===")
t = stats.collect()
print("top-10 groups:", t)
out = t.to_numpy()
print("first rows:", {k: v[:4] for k, v in out.items()})
print("cumsum tail:", cs.collect().to_numpy()["running"][-3:])
print("wma head:", wma.collect().to_numpy()["smooth"][:3])
print("exact rolling-mean head:", rm.collect().to_numpy()["rm"][:3])
print("udf rows:", via_udf.collect().num_rows())
print("persisted re-agg rows:", again.collect().num_rows())

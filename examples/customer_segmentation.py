"""End-to-end driver — the paper's §3.2 program (TPCx-BB Q26-inspired):
relational pipeline -> feature scaling -> matrix assembly -> K-means.

This is the paper's flagship integration claim: the relational stages and
the ML math compile through ONE system, with the distribution pass inserting
the single rebalance the K-means input needs (1D_VAR -> 1D_BLOCK).

Run:  PYTHONPATH=src python examples/customer_segmentation.py [--rows 400000]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import hiframes as hf
from repro.data import synth


def customer_model(min_count: int, num_centroids: int, iterations: int,
                   n_rows: int):
    # -- load ---------------------------------------------------------------
    ss = synth.store_sales(n_rows, n_items=5_000, n_customers=20_000, seed=1)
    it = synth.item(5_000, seed=2)
    store_sales = hf.table(ss, "store_sales")
    item = hf.table(it, "item")

    # -- relational stage (compiled, distributed; fluent chain) --------------
    sale_items = store_sales.merge(item, on=("ss_item_sk", "i_item_sk"))
    c_i_points = (sale_items
                  .groupby("ss_customer_sk")
                  .agg(c_i_count="count",
                       id1=(sale_items.i_class_id == 1, "sum"),
                       id2=(sale_items.i_class_id == 2, "sum"),
                       id3=(sale_items.i_class_id == 3, "sum")))
    c_i_points = c_i_points[c_i_points.c_i_count > min_count]

    # -- feature scaling as column assignment (id3 standardized) -------------
    t = c_i_points.collect()
    id3 = t.column("id3").astype(jnp.float32)
    counts = np.asarray(t.counts)
    n = int(counts.sum())
    # valid-prefix mask across shards
    mask = np.zeros(t.capacity * t.nshards, bool)
    for r in range(t.nshards):
        mask[r * t.capacity: r * t.capacity + counts[r]] = True
    mask = jnp.asarray(mask)
    mean = jnp.sum(jnp.where(mask, id3, 0)) / n
    var = jnp.sum(jnp.where(mask, (id3 - mean) ** 2, 0)) / n
    scaled = hf.table({k: np.asarray(t.column(k)) for k in
                       ("ss_customer_sk", "c_i_count", "id1", "id2")}
                      | {"id3": np.asarray((id3 - mean) /
                                           jnp.sqrt(var + 1e-6))}, "scaled")
    scaled = scaled[hf.udf(lambda c: c > 0, scaled["c_i_count"])]

    # -- matrix assembly (transpose_hcat pattern; rebalanced to 1D_BLOCK) ----
    samples, counts, cap = scaled.collect_matrix(
        ["c_i_count", "id1", "id2", "id3"])
    n = int(np.sum(np.asarray(counts)))
    x = jnp.asarray(samples)[:n]

    # -- K-means (jit-compiled array code, same program family) --------------
    @jax.jit
    def kmeans(x, cent):
        def step(cent, _):
            d2 = jnp.sum((x[:, None] - cent[None]) ** 2, axis=-1)
            a = jnp.argmin(d2, axis=1)
            one = jax.nn.one_hot(a, cent.shape[0], dtype=x.dtype)
            tot = one.T @ x
            cnt = one.sum(0)[:, None]
            return tot / jnp.maximum(cnt, 1.0), None
        cent, _ = jax.lax.scan(step, cent, None, length=iterations)
        return cent

    cent = kmeans(x, x[:num_centroids])
    return x, cent


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=200_000)
    ap.add_argument("--min-count", type=int, default=4)
    ap.add_argument("--centroids", type=int, default=8)
    ap.add_argument("--iterations", type=int, default=20)
    args = ap.parse_args()

    t0 = time.perf_counter()
    x, cent = customer_model(args.min_count, args.centroids, args.iterations,
                             args.rows)
    dt = time.perf_counter() - t0
    print(f"segmented {x.shape[0]} customers into {cent.shape[0]} clusters "
          f"in {dt:.2f}s (rows={args.rows})")
    print("centroid[0]:", np.asarray(cent[0]))
    assert np.all(np.isfinite(np.asarray(cent)))


if __name__ == "__main__":
    main()

"""End-to-end LM training driver: HiFrames data pipeline -> sharded train
loop with AdamW/ZeRO-1, gradient accumulation, async checkpointing,
preemption safety, straggler stats.

Defaults run a ~13M-param model for 30 steps on CPU in ~a minute; pass
--preset 100m --steps 300 for the deliverable-scale run (same code path).

Run:  PYTHONPATH=src python examples/train_lm.py [--preset 100m] [--steps N]
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.data.synth import token_corpus
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import OptConfig, adamw
from repro.runtime import FTConfig, TrainDriver

PRESETS = {
    "tiny": ModelConfig(name="tiny-lm", family="dense", n_layers=4,
                        d_model=256, n_heads=4, n_kv_heads=2, d_ff=1024,
                        vocab=8192, tie_embeddings=True),
    "100m": ModelConfig(name="lm-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
                        vocab=32768, tie_embeddings=True),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    ocfg = OptConfig(lr=3e-4, warmup_steps=max(args.steps // 10, 1),
                     total_steps=args.steps)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw.init_state(params, ocfg)}

    # HiFrames-powered data pipeline (curation filter + cumsum packing plan)
    corpus = token_corpus(5_000, cfg.vocab)
    pipe = TokenPipeline(corpus, PipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
    print("pipeline: docs per quality bucket:", dict(zip(
        pipe.bucket_stats["bucket"].tolist(),
        pipe.bucket_stats["docs"].tolist())))

    n_micro = args.micro

    @jax.jit
    def train_step(state, batch):
        params = state["params"]

        def split(x):
            return x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])
        mb = {k: split(v) for k, v in batch.items()}

        def micro(carry, b):
            g, l = carry
            loss, grads = jax.value_and_grad(
                lambda p: lm.loss_fn(p, b, cfg))(params)
            return (jax.tree.map(jnp.add, g, grads), l + loss), None
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mb)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        new_p, new_o, _ = adamw.update(params, grads, state["opt"], ocfg)
        return {"params": new_p, "opt": new_o}, lsum / n_micro

    batch0 = {k: jnp.asarray(v) for k, v in next(iter(pipe)).items()}

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                             f"repro_{cfg.name}")
    driver = TrainDriver(FTConfig(ckpt_dir=ckpt_dir, ckpt_every=20),
                         state, train_step, metadata={"model": cfg.name})
    if args.resume and driver.maybe_resume():
        print(f"resumed from step {driver.step}")

    def batches():
        for b in pipe:
            yield {k: jnp.asarray(v) for k, v in b.items()}

    res = driver.run(batches(), num_steps=args.steps, log_every=5)
    pipe.close()
    print(f"done: {res['steps']} steps, final loss "
          f"{res['losses'][-1]:.4f} (first {res['losses'][0]:.4f}), "
          f"{res['stragglers']} straggler steps, "
          f"{res['mean_step_s']*1e3:.1f} ms/step; checkpoints in {ckpt_dir}")
    assert res["losses"][-1] < res["losses"][0], "loss must decrease"


if __name__ == "__main__":
    main()

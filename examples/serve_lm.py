"""Serving driver: batched prefill + decode with KV caches.

Demonstrates the inference path the decode_32k/long_500k dry-run shapes
lower: batched requests, ragged prompt lengths (left-padded into one prefill),
greedy continuation.

Run:  PYTHONPATH=src python examples/serve_lm.py [--requests 8] [--new-tokens 16]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig

CFG = ModelConfig(name="serve-demo", family="dense", n_layers=4, d_model=256,
                  n_heads=4, n_kv_heads=2, d_ff=1024, vocab=8192,
                  tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    B, S, T = args.requests, args.prompt_len, args.new_tokens
    max_seq = S + T
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, CFG.vocab, (B, S)).astype(np.int32))

    # prefill: teacher-force prompts through the cache path
    @jax.jit
    def prefill(params, tokens):
        caches = lm.init_cache(CFG, B, max_seq)
        logits, caches, _ = lm.forward(params, tokens, CFG, caches=caches,
                                       q_offset=0)
        return logits[:, -1], caches

    @jax.jit
    def step(params, tok, caches):
        return lm.decode_step(params, tok, caches, CFG)

    t0 = time.perf_counter()
    logits, caches = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(T):
        out.append(np.asarray(tok[:, 0]))
        logits, caches = step(params, tok, caches)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = np.stack(out, axis=1)
    print(f"prefill: {B}x{S} tokens in {t_prefill*1e3:.1f} ms "
          f"({B*S/t_prefill:.0f} tok/s)")
    print(f"decode:  {B}x{T} tokens in {t_decode*1e3:.1f} ms "
          f"({B*T/t_decode:.0f} tok/s, batch {B})")
    print("sample continuation (req 0):", gen[0][:10])
    assert gen.shape == (B, T)
    assert np.all(gen >= 0) and np.all(gen < CFG.vocab)


if __name__ == "__main__":
    main()

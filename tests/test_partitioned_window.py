"""Partitioned window functions (`over(partition_by=..., order_by=...)`).

Correctness is checked against per-group numpy oracles (tests/oracle.py):
duplicate and empty groups, groups spanning input-shard boundaries, fewer
groups than shards, and 1/2/8 fake devices via subprocesses.  Plan-shape
assertions live in tests/test_plan_census.py.
"""
import numpy as np
import pytest

from repro import hiframes as hf
from repro.core import ir
from oracle import o_group_apply, o_group_rank, o_stencil
from test_physical_plan import run_sharded


def _grouped_frame(n=600, n_groups=9, seed=7):
    """Groups interleaved across the whole input (they span shard
    boundaries under any block layout); group ids are sparse (2 of every 3
    ids in the key space are EMPTY); ``t`` is unique per row so every
    order-dependent window is deterministic."""
    rng = np.random.default_rng(seed)
    return {"g": (3 * rng.integers(0, n_groups, n)).astype(np.int32),
            "t": rng.permutation(n).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32)}


def _sorted_out(out: dict, keys=("g", "t")) -> dict:
    order = np.lexsort(tuple(out[k] for k in reversed(keys)))
    return {k: v[order] for k, v in out.items()}


# -- single-process oracle checks ---------------------------------------------


def test_grouped_cumsum_matches_oracle():
    cols = _grouped_frame()
    df = hf.table(cols)
    out = _sorted_out(df.over("g", order_by="t").cumsum(df["x"], out="c")
                      .collect().to_numpy())
    ref = o_group_apply(cols, "g", "t", cols["x"], np.cumsum)
    np.testing.assert_array_equal(out["g"], ref["g"])
    np.testing.assert_array_equal(out["t"], ref["t"])
    np.testing.assert_allclose(out["c"], ref["_o"], atol=1e-3)


@pytest.mark.parametrize("weights,center", [([1, 2, 1], 1), ([1, 1, 1], 1),
                                            ([1, 0, 0, 2], 3)])
def test_grouped_stencil_masks_group_edges(weights, center):
    """Taps crossing a group boundary contribute zero — each group behaves
    like an independent series with the zero-border convention."""
    cols = _grouped_frame(seed=8)
    df = hf.table(cols)
    out = _sorted_out(
        hf.stencil(df, df["x"], weights, center=center, out="s",
                   partition_by="g", order_by="t").collect().to_numpy())
    ref = o_group_apply(cols, "g", "t", cols["x"],
                        lambda s: o_stencil(s, weights, center))
    np.testing.assert_array_equal(out["g"], ref["g"])
    np.testing.assert_allclose(out["s"], ref["_o"], atol=1e-3)


def test_grouped_wma_and_lag_lead():
    cols = _grouped_frame(seed=9)
    df = hf.table(cols)
    w = df.over("g", order_by="t")
    wma = _sorted_out(w.wma(df["x"], [1, 2, 1], out="w").collect().to_numpy())
    ref = o_group_apply(cols, "g", "t", cols["x"],
                        lambda s: o_stencil(s, [0.25, 0.5, 0.25], 1))
    np.testing.assert_allclose(wma["w"], ref["_o"], atol=1e-3)

    lag = _sorted_out(w.lag(df["x"], n=2, out="l").collect().to_numpy())
    ref_lag = o_group_apply(
        cols, "g", "t", cols["x"],
        lambda s: np.concatenate([np.zeros(min(2, len(s)), np.float32),
                                  s[:-2]])[: len(s)])
    np.testing.assert_allclose(lag["l"], ref_lag["_o"], atol=1e-5)

    lead = _sorted_out(w.lead(df["x"], n=1, out="l").collect().to_numpy())
    ref_lead = o_group_apply(
        cols, "g", "t", cols["x"],
        lambda s: np.concatenate([s[1:], np.zeros(min(1, len(s)), np.float32)]))
    np.testing.assert_allclose(lead["l"], ref_lead["_o"], atol=1e-5)


def test_grouped_rolling_sum_mean():
    cols = _grouped_frame(seed=10)
    df = hf.table(cols)
    w = df.over("g", order_by="t")
    out = _sorted_out(w.rolling_sum(df["x"], 4, out="r").collect().to_numpy())

    def roll(s):
        acc = np.zeros(len(s), np.float32)
        for i in range(len(s)):
            acc[i] = s[max(0, i - 3): i + 1].sum()
        return acc

    ref = o_group_apply(cols, "g", "t", cols["x"], roll)
    np.testing.assert_allclose(out["r"], ref["_o"], atol=1e-3)
    # rolling_mean == rolling_sum / window (zero-padded borders, see api doc)
    mean = _sorted_out(w.rolling_mean(df["x"], 4, out="m").collect().to_numpy())
    np.testing.assert_allclose(mean["m"], ref["_o"] / 4.0, atol=1e-3)


@pytest.mark.parametrize("kind", ["rank", "dense_rank", "row_number"])
def test_rank_kinds_with_duplicate_order_keys(kind):
    cols = _grouped_frame(seed=11)
    cols["t"] = (cols["t"] // 7).astype(np.int32)      # duplicate order keys
    df = hf.table(cols)
    out = hf.__dict__[kind](df, "g", "t", out="r").collect().to_numpy()
    ref = o_group_rank(cols, "g", "t", kind)
    # ties make row identity ambiguous: compare the multiset of ranks per
    # (g, t) pair — identical for rank/dense_rank, a permutation of
    # 1..#ties offsets for row_number.
    def by_pair(g, t, r):
        m = {}
        for a, b, c in zip(g, t, r):
            m.setdefault((int(a), int(b)), []).append(int(c))
        return {k: sorted(v) for k, v in m.items()}
    assert by_pair(out["g"], out["t"], out["r"]) == \
        by_pair(ref["g"], ref["t"], ref["_o"])


def test_rank_requires_keys():
    df = hf.table(_grouped_frame())
    with pytest.raises(ValueError):
        hf.rank(df, "g", ())
    with pytest.raises(ValueError):
        ir.Window(df.node, "rank", None, "r", partition_by=("g",),
                  order_by=())
    # empty partition_by is now LEGAL for rank kinds (global ranking via the
    # per-shard-count exscan) as long as order_by is present
    ir.Window(df.node, "rank", None, "r", partition_by=(), order_by=("t",))
    with pytest.raises(ValueError):
        ir.Window(df.node, "nope", None, "r")


def test_over_fluent_equals_kwargs_form():
    cols = _grouped_frame(seed=12)
    df = hf.table(cols)
    a = df.over("g", order_by="t").cumsum(df["x"], out="c")
    b = hf.cumsum(df, df["x"], out="c", partition_by="g", order_by="t")
    assert a.node.short() == b.node.short()
    na, nb = _sorted_out(a.collect().to_numpy()), _sorted_out(b.collect().to_numpy())
    np.testing.assert_allclose(na["c"], nb["c"], atol=1e-6)


def test_column_pruning_keeps_window_keys():
    """Selecting only the window output must not prune the partition/order
    keys (they feed the exchange, the sort and the segment kernels)."""
    cols = _grouped_frame(seed=13)
    df = hf.table(cols)
    win = df.over("g", order_by="t").cumsum(df["x"], out="c")
    only_c = win[["c"]].collect().to_numpy()
    ref = o_group_apply(cols, "g", "t", cols["x"], np.cumsum)
    np.testing.assert_allclose(np.sort(only_c["c"]), np.sort(ref["_o"]),
                               atol=1e-3)


def test_duplicate_partition_order_key_column():
    """order_by repeating a partition column must not double-sort or crash."""
    cols = _grouped_frame(seed=14)
    df = hf.table(cols)
    node = hf.cumsum(df, df["x"], out="c", partition_by="g",
                     order_by=("g", "t")).node
    assert node.sort_keys() == ("g", "t")


def test_elided_vs_baseline_join_window_equal():
    """elide_exchanges on/off must be observationally identical for the
    join -> partitioned-window pipeline."""
    rng = np.random.default_rng(15)
    n = 400
    left = {"k": rng.integers(0, 6, n).astype(np.int32),
            "t": rng.permutation(n).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32)}
    right = {"k": np.arange(6, dtype=np.int32),
             "w": rng.normal(size=6).astype(np.float32)}
    j = hf.join(hf.table(left), hf.table(right, "d"), on="k")
    win = hf.wma(j, j["x"] * j["w"], [1, 2, 1], out="v",
                 partition_by="k", order_by="t")
    on = _sorted_out(win.collect(hf.ExecConfig(elide_exchanges=True)).to_numpy(),
                     keys=("k", "t"))
    off = _sorted_out(win.collect(hf.ExecConfig(elide_exchanges=False)).to_numpy(),
                      keys=("k", "t"))
    for c in on:
        np.testing.assert_allclose(on[c], off[c], rtol=1e-5)


# -- sharded subprocess checks (groups span shard boundaries) -----------------


_GROUPED_BODY = """
    from oracle import o_group_apply, o_group_rank, o_stencil
    rng = np.random.default_rng(21)
    n = 700
    cols = {"g": (3 * rng.integers(0, 5, n)).astype(np.int32),
            "t": rng.permutation(n).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32)}
    df = hf.table(cols)
    w = df.over("g", order_by="t")

    def sort_out(out):
        order = np.lexsort((out["t"], out["g"]))
        return {k: v[order] for k, v in out.items()}

    out = sort_out(w.cumsum(df["x"], out="c").collect().to_numpy())
    ref = o_group_apply(cols, "g", "t", cols["x"], np.cumsum)
    assert np.array_equal(out["g"], ref["g"]) and np.array_equal(out["t"], ref["t"])
    assert np.allclose(out["c"], ref["_o"], atol=1e-3)

    out = sort_out(w.wma(df["x"], [1, 2, 1], out="w").collect().to_numpy())
    ref = o_group_apply(cols, "g", "t", cols["x"],
                        lambda s: o_stencil(s, [0.25, 0.5, 0.25], 1))
    assert np.allclose(out["w"], ref["_o"], atol=1e-3)

    out = sort_out(w.lag(df["x"], out="l").collect().to_numpy())
    ref = o_group_apply(cols, "g", "t", cols["x"],
                        lambda s: np.concatenate([[np.float32(0)], s[:-1]]))
    assert np.allclose(out["l"], ref["_o"], atol=1e-5)

    out = sort_out(w.rank(out="r").collect().to_numpy())
    ref = o_group_rank(cols, "g", "t", "rank")
    assert np.array_equal(out["r"], ref["_o"])

    # fewer groups than shards: some shards hold zero groups after the
    # exchange — counts must still reconcile and values match.
    few = {"g": np.repeat(np.int32(4), 64) * (np.arange(64) % 2).astype(np.int32),
           "t": np.arange(64, dtype=np.int32),
           "x": np.ones(64, np.float32)}
    fdf = hf.table(few, "few")
    fout = sort_out(fdf.over("g", order_by="t").cumsum(fdf["x"], out="c")
                    .collect().to_numpy())
    fref = o_group_apply(few, "g", "t", few["x"], np.cumsum)
    assert np.array_equal(fout["g"], fref["g"])
    assert np.allclose(fout["c"], fref["_o"], atol=1e-4)
"""


@pytest.mark.parametrize("devices", [1, 2, 8])
def test_grouped_windows_match_oracle_sharded(devices):
    run_sharded(_GROUPED_BODY, devices)

"""Adaptive statistics-driven planning: salted skew joins, auto group caps,
cheap-side re-exchange, and the census gates that pin adaptive_stats as a
zero-cost no-op on uniform data (docs/adaptive_planning.md).

Oracle-checked on 1/2/8 shards via the same subprocess harness as
test_physical_plan.py; plan-shape assertions run in-process (the planner is
deterministic and device-free).
"""
import numpy as np
import pytest

from repro import hiframes as hf
from repro.core import physical_plan as pp
from repro.core import stats
from oracle import o_aggregate
from test_physical_plan import run_sharded


@pytest.fixture(autouse=True)
def _fresh_feedback_store():
    """The realized-stats store is process-global (keyed by plan
    fingerprint); isolate every test from its neighbours."""
    stats.clear_realized()
    yield
    stats.clear_realized()


def _skewed(n=4000, m=90, hot_frac=0.35, seed=7):
    """Probe table with one zipf-hot key (~hot_frac of all rows) plus a
    uniform dimension covering every key."""
    rng = np.random.default_rng(seed)
    k = rng.integers(0, m, n).astype(np.int32)
    k[: int(hot_frac * n)] = 3
    rng.shuffle(k)
    probe = {"k": k, "v": rng.normal(size=n).astype(np.float32)}
    dim = {"k": np.arange(m, dtype=np.int32),
           "w": rng.normal(size=m).astype(np.float32)}
    return probe, dim


ADAPTIVE = dict(adaptive_stats=True)


# -- plan shape ---------------------------------------------------------------


def test_skewed_join_plans_salted():
    probe, dim = _skewed()
    j = hf.table(probe, "probe").merge(hf.table(dim, "dim"), on="k")
    plan = j.physical_plan(hf.ExecConfig(**ADAPTIVE))
    c = plan.counts()
    assert c["salt_ops"] == 2, plan.render()
    mj = [op for op in plan.ops if isinstance(op, pp.MergeJoin)]
    assert len(mj) == 1 and mj[0].salted
    # salt stripped: no __salt__ in the output schema, but the exchanges
    # carry it on the wire.
    assert "__salt__" not in mj[0].schema
    ex = [op for op in plan.ops if isinstance(op, pp.HashExchange)]
    assert all("__salt__" in op.schema for op in ex), plan.render()


def test_salting_adds_zero_extra_collectives():
    """Both sides of a fresh-table join pay an exchange anyway, so salting
    is collective-free: same exchange count, same all_to_all count."""
    probe, dim = _skewed()
    j = hf.table(probe, "probe").merge(hf.table(dim, "dim"), on="k")
    on = j.physical_plan(hf.ExecConfig(**ADAPTIVE))
    off = j.physical_plan(hf.ExecConfig())
    assert on.counts()["hash_exchanges"] == off.counts()["hash_exchanges"]
    assert on.collective_count() == off.collective_count()
    assert off.counts()["salt_ops"] == 0


def test_uniform_plans_byte_identical_adaptive_on_off():
    """The census gate: on uniform keys adaptive_stats must be a no-op —
    identical op census, collectives, row bytes, AND the full fixed-P
    payload census (buckets included)."""
    rng = np.random.default_rng(11)
    n, m = 4000, 90
    probe = {"k": rng.integers(0, m, n).astype(np.int32),
             "v": rng.normal(size=n).astype(np.float32)}
    dim = {"k": np.arange(m, dtype=np.int32),
           "w": rng.normal(size=m).astype(np.float32)}
    j = hf.table(probe, "probe").merge(hf.table(dim, "dim"), on="k")
    # high-cardinality uniform aggregate: the ndv estimate exceeds the
    # per-shard capacity, so the auto-cap changes nothing either.
    u = {"k": rng.integers(0, 1 << 30, n).astype(np.int32),
         "v": rng.normal(size=n).astype(np.float32)}
    a = hf.table(u, "u").groupby("k").agg(s=("v", "sum"))
    for q in (j, a):
        on = q.physical_plan(hf.ExecConfig(**ADAPTIVE))
        off = q.physical_plan(hf.ExecConfig())
        assert on.counts() == off.counts(), (on.render(), off.render())
        assert on.collective_count() == off.collective_count()
        assert on.shuffle_row_bytes() == off.shuffle_row_bytes()
        assert on.shuffle_census(P=8) == off.shuffle_census(P=8)


def test_explain_reports_estimates_and_realized():
    probe, dim = _skewed()
    j = hf.table(probe, "probe").merge(hf.table(dim, "dim"), on="k")
    txt = j.explain(hf.ExecConfig(**ADAPTIVE))
    assert "est~" in txt                    # per-exchange rows/bytes estimate
    assert "estimated output rows" in txt
    assert "realized" not in txt            # nothing executed yet
    j.collect(hf.ExecConfig(**ADAPTIVE))
    txt2 = j.explain(hf.ExecConfig(**ADAPTIVE))
    assert "realized (previous run)" in txt2


# -- salted-join correctness (oracle, 1/2/8 shards) ---------------------------


_SALTED_BODY = """
    from oracle import o_join
    rng = np.random.default_rng(7)
    n, m = 4000, 90
    k = rng.integers(0, m, n).astype(np.int32)
    k[: int(0.35 * n)] = 3
    rng.shuffle(k)
    probe = {"k": k, "v": rng.normal(size=n).astype(np.float32)}
    dim = {"k": np.arange(m, dtype=np.int32),
           "w": rng.normal(size=m).astype(np.float32)}
    for how in ("inner", "left"):
        j = hf.table(probe, "probe").merge(hf.table(dim, "dim"), on="k",
                                           how=how)
        plan = j.physical_plan(hf.ExecConfig(adaptive_stats=True))
        assert plan.counts()["salt_ops"] == 2, plan.render()
        out = j.collect(hf.ExecConfig(adaptive_stats=True))
        assert not out.overflow
        got = out.to_numpy()
        ref = o_join(probe, dim, "k", "k", how=how)
        assert set(got) == set(ref), (set(got), set(ref))
        oi = np.lexsort([got[c] for c in sorted(got)])
        ri = np.lexsort([ref[c] for c in sorted(ref)])
        for c in ref:
            np.testing.assert_allclose(np.asarray(got[c])[oi], ref[c][ri],
                                       atol=1e-5, err_msg=f"{how}:{c}")
"""


@pytest.mark.parametrize("devices", [1, 2, 8])
def test_salted_join_matches_oracle_sharded(devices):
    run_sharded(_SALTED_BODY, devices)


def test_salted_occupancy_drops_8dev():
    """The point of salting: the hot key no longer pins one shard.  At P=8
    with ~35% of probe rows on one key, the unsalted join's max/mean shard
    occupancy is ~3x; salted it must drop measurably."""
    run_sharded("""
        rng = np.random.default_rng(7)
        n, m = 4000, 90
        k = rng.integers(0, m, n).astype(np.int32)
        k[: int(0.35 * n)] = 3
        rng.shuffle(k)
        probe = {"k": k, "v": rng.normal(size=n).astype(np.float32)}
        dim = {"k": np.arange(m, dtype=np.int32),
               "w": rng.normal(size=m).astype(np.float32)}
        j = hf.table(probe, "probe").merge(hf.table(dim, "dim"), on="k")
        salted = j.collect(hf.ExecConfig(adaptive_stats=True))
        base = j.collect(hf.ExecConfig())
        cs = np.asarray(salted.counts, dtype=np.float64)
        cb = np.asarray(base.counts, dtype=np.float64)
        assert cs.sum() == cb.sum() == n
        r_salted = cs.max() / cs.mean()
        r_base = cb.max() / cb.mean()
        assert r_base > 2.0, (r_base, cb)         # skew is real unsalted
        assert r_salted < 0.6 * r_base, (r_salted, r_base)
        assert cs.max() < 0.75 * cb.max(), (cs, cb)
    """, devices=8)


# -- auto agg_group_cap -------------------------------------------------------


def test_auto_cap_zipf_aggregate_no_user_cap_no_overflow():
    """The PR 4 zipf scenario with NO user-declared agg_group_cap: the
    sampled distinct-count estimate sizes the partial-agg buckets, the run
    completes without overflow on the FIRST attempt (auto_retry=0), and the
    result matches the oracle."""
    rng = np.random.default_rng(4)
    n = 16000
    zk = rng.zipf(1.5, n).astype(np.int32)
    zv = rng.normal(size=n).astype(np.float32)
    ag = hf.table({"k": zk, "v": zv}, "z").groupby("k").agg(
        s=("v", "sum"), c=("v", "count"))
    cfg = hf.ExecConfig(adaptive_stats=True, safe_capacities=False,
                        auto_retry=0)
    plan = ag.lower(cfg).pplan          # lower(): capacities are filled
    pa = [op for op in plan.ops if isinstance(op, pp.PartialAgg)]
    assert len(pa) == 1 and pa[0].ndv_est is not None
    assert pa[0].ndv_src == "sample"
    # the auto cap actually tightened the post-partial exchange
    src_cap = plan.ops[pa[0].inputs[0]].cap
    assert 0 < pa[0].cap < src_cap, (pa[0].cap, src_cap)
    t = ag.collect(cfg)
    assert not t.overflow
    got = t.to_numpy()
    ref = o_aggregate({"k": zk, "v": zv}, "k",
                      {"s": ("sum", zv), "c": ("count", None)})
    o = np.argsort(got["k"])
    np.testing.assert_array_equal(np.asarray(got["k"])[o], ref["k"])
    np.testing.assert_allclose(np.asarray(got["s"])[o], ref["s"], atol=1e-2)
    np.testing.assert_array_equal(np.asarray(got["c"])[o], ref["c"])


def test_realized_feedback_tightens_cap_on_second_run():
    rng = np.random.default_rng(4)
    n = 16000
    zk = rng.zipf(1.5, n).astype(np.int32)
    zv = rng.normal(size=n).astype(np.float32)
    ag = hf.table({"k": zk, "v": zv}, "z").groupby("k").agg(s=("v", "sum"))
    cfg = hf.ExecConfig(adaptive_stats=True, safe_capacities=False,
                        auto_retry=0)
    t = ag.collect(cfg)
    assert not t.overflow
    true_groups = len(np.unique(zk))
    plan2 = ag.lower(cfg).pplan
    pa = [op for op in plan2.ops if isinstance(op, pp.PartialAgg)][0]
    assert pa.ndv_src == "realized"
    assert pa.ndv_est == true_groups
    assert pa.cap == max(64, true_groups)
    t2 = ag.collect(cfg)
    assert not t2.overflow
    assert int(np.sum(np.asarray(t2.counts))) == true_groups


# -- cheap-side re-exchange ---------------------------------------------------


def _mixed_alignment_join(big_left: bool):
    """Both sides pre-partitioned on DIFFERENT join-key positions, so one
    must re-hash: left persisted on k1 (position 0), right on cb
    (position 1)."""
    rng = np.random.default_rng(9)
    nl, nr = (6000, 300) if big_left else (300, 6000)
    left = hf.table({"k1": rng.integers(0, 7, nl).astype(np.int32),
                     "k2": rng.integers(0, 9, nl).astype(np.int32),
                     "x": rng.normal(size=nl).astype(np.float32)},
                    "L").repartition(by="k1").persist(name="Lp")
    right = hf.table({"ca": rng.integers(0, 7, nr).astype(np.int32),
                      "cb": rng.integers(0, 9, nr).astype(np.int32),
                      "w": rng.normal(size=nr).astype(np.float32)},
                     "R").repartition(by="cb").persist(name="Rp")
    return left.merge(right, on=[("k1", "ca"), ("k2", "cb")])


def _exchanged_keys(plan):
    return [op.keys for op in plan.ops if isinstance(op, pp.HashExchange)]


def test_cheap_side_reexchange_picks_smaller_input():
    # static rule: keep the LEFT alignment (hash on k1, position 0), re-hash
    # the right on ITS position-0 column ca — regardless of sizes.  Adaptive
    # with a big left agrees with it...
    j = _mixed_alignment_join(big_left=True)
    assert _exchanged_keys(j.physical_plan(hf.ExecConfig())) == [("ca",)]
    on = j.physical_plan(hf.ExecConfig(**ADAPTIVE))
    assert _exchanged_keys(on) == [("ca",)], on.render()
    # ...and with a big RIGHT it flips: re-hash the small left on k2
    # (the right-aligned key position) instead.
    j2 = _mixed_alignment_join(big_left=False)
    assert _exchanged_keys(j2.physical_plan(hf.ExecConfig())) == [("ca",)]
    on2 = j2.physical_plan(hf.ExecConfig(**ADAPTIVE))
    assert _exchanged_keys(on2) == [("k2",)], on2.render()
    # either way one exchange total, and results match the stats-blind plan
    got = j2.collect(hf.ExecConfig(**ADAPTIVE)).to_numpy()
    ref = j2.collect(hf.ExecConfig()).to_numpy()
    oi = np.lexsort([got[c] for c in sorted(got)])
    ri = np.lexsort([ref[c] for c in sorted(ref)])
    for c in ref:
        np.testing.assert_allclose(np.asarray(got[c])[oi],
                                   np.asarray(ref[c])[ri], atol=1e-5)


# -- GroupBy.transform / GroupBy.head -----------------------------------------


def test_groupby_transform_matches_oracle():
    rng = np.random.default_rng(13)
    n = 1200
    g = rng.integers(0, 11, n).astype(np.int32)
    x = rng.normal(size=n).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    df = hf.table({"g": g, "x": x, "y": y})
    out = df.groupby("g").transform("mean").collect().to_numpy()
    assert set(out) == {"g", "x", "y", "x_mean", "y_mean"}
    ref_m = o_aggregate({"g": g, "x": x, "y": y}, "g",
                        {"xm": ("mean", x), "ym": ("mean", y)})
    lut_x = dict(zip(ref_m["g"].tolist(), ref_m["xm"]))
    lut_y = dict(zip(ref_m["g"].tolist(), ref_m["ym"]))
    oi = np.lexsort((out["x"], out["g"]))
    ei = np.lexsort((x, g))
    np.testing.assert_array_equal(np.asarray(out["g"])[oi], g[ei])
    np.testing.assert_allclose(np.asarray(out["x"])[oi], x[ei], atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(out["x_mean"])[oi],
        np.array([lut_x[int(v)] for v in g[ei]]), atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(out["y_mean"])[oi],
        np.array([lut_y[int(v)] for v in g[ei]]), atol=1e-4)
    # named-spec spelling + collision guard
    out2 = df.groupby("g").transform(total=("x", "sum")).collect().to_numpy()
    assert set(out2) == {"g", "x", "y", "total"}
    with pytest.raises(ValueError):
        df.groupby("g").transform(x=("x", "sum"))


def test_groupby_head_matches_pandas_rows():
    """head(n) = first n rows per group in ORIGINAL order — the exact row
    multiset pandas returns."""
    rng = np.random.default_rng(14)
    n = 900
    g = rng.integers(0, 7, n).astype(np.int32)
    x = np.arange(n, dtype=np.float32)         # row identity
    df = hf.table({"g": g, "x": x})
    for k in (1, 3):
        got = df.groupby("g").head(k).collect().to_numpy()
        seen: dict = {}
        exp = []
        for gi, xi in zip(g.tolist(), x.tolist()):
            if seen.get(gi, 0) < k:
                exp.append((gi, xi))
            seen[gi] = seen.get(gi, 0) + 1
        assert sorted(zip(np.asarray(got["g"]).tolist(),
                          np.asarray(got["x"]).tolist())) == sorted(exp)
        assert set(got) == {"g", "x"}          # helper column dropped


def test_groupby_head_plans_single_exchange():
    """The fusion claim: head(n) rides the grouped-sort layout — one hash
    exchange + one local sort, nothing else; on a frame already persisted
    on the keys, ZERO exchanges."""
    rng = np.random.default_rng(15)
    df = hf.table({"g": rng.integers(0, 7, 800).astype(np.int32),
                   "x": rng.normal(size=800).astype(np.float32)})
    c = df.groupby("g").head(3).physical_plan().counts()
    assert c["hash_exchanges"] == 1 and c["local_sorts"] == 1
    assert c["sample_sorts"] == 0 and c["rebalances"] == 0
    p = df.repartition(by="g").persist(name="pg")
    cp = p.groupby("g").head(3).physical_plan().counts()
    assert cp["hash_exchanges"] == 0, cp


def test_transform_sharded_matches_oracle():
    run_sharded("""
        from oracle import o_aggregate
        rng = np.random.default_rng(16)
        n = 2000
        g = rng.integers(0, 9, n).astype(np.int32)
        g[: n // 3] = 4                         # hot group
        rng.shuffle(g)
        x = rng.normal(size=n).astype(np.float32)
        df = hf.table({"g": g, "x": x})
        out = df.groupby("g").transform("sum").collect(
            hf.ExecConfig(adaptive_stats=True)).to_numpy()
        ref = o_aggregate({"g": g, "x": x}, "g", {"s": ("sum", x)})
        lut = dict(zip(ref["g"].tolist(), ref["s"]))
        oi = np.lexsort((out["x"], out["g"]))
        ei = np.lexsort((x, g))
        assert np.array_equal(np.asarray(out["g"])[oi], g[ei])
        np.testing.assert_allclose(
            np.asarray(out["x_sum"])[oi],
            np.array([lut[int(v)] for v in g[ei]]), atol=1e-2)
        out8 = df.groupby("g").head(2).collect().to_numpy()
        seen = {}
        exp = []
        for gi, xi in zip(g.tolist(), x.tolist()):
            if seen.get(gi, 0) < 2:
                exp.append((gi, round(float(xi), 4)))
            seen[gi] = seen.get(gi, 0) + 1
        got = sorted((int(a), round(float(b), 4))
                     for a, b in zip(out8["g"], out8["x"]))
        assert got == sorted(exp)
    """, devices=8)

"""Checkpoint, optimizer, FT driver, data pipeline tests."""
import os
import signal
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncSaver, latest_step, restore, save
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.data.synth import token_corpus
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import OptConfig, adamw
from repro.runtime import FTConfig, TrainDriver, run_with_overflow_retry

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128)


def _state(ocfg):
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    return {"params": params, "opt": adamw.init_state(params, ocfg)}


def test_checkpoint_roundtrip():
    ocfg = OptConfig()
    state = _state(ocfg)
    with tempfile.TemporaryDirectory() as d:
        save(d, 3, state, {"note": "x"})
        assert latest_step(d) == 3
        restored, step, meta = restore(d, jax.tree.map(jnp.zeros_like, state))
        assert step == 3 and meta["note"] == "x"
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_and_gc():
    with tempfile.TemporaryDirectory() as d:
        for s in range(1, 6):
            save(d, s, {"x": jnp.full((4,), s)})
        steps = sorted(int(p.split("_")[1]) for p in os.listdir(d))
        assert len(steps) == 3 and steps[-1] == 5  # gc keeps 3
        assert not any(p.endswith(".tmp") for p in os.listdir(d))


def test_async_saver():
    with tempfile.TemporaryDirectory() as d:
        saver = AsyncSaver(d)
        saver.save(7, {"x": jnp.arange(8)})
        saver.wait()
        assert latest_step(d) == 7


def test_adamw_decreases_loss():
    ocfg = OptConfig(lr=5e-3, warmup_steps=1, total_steps=100, weight_decay=0.0)
    state = _state(ocfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, CFG.vocab)
    batch = {"tokens": toks, "labels": toks}

    @jax.jit
    def step(state, batch):
        loss, g = jax.value_and_grad(lambda p: lm.loss_fn(p, batch, CFG))(state["params"])
        p, o, _ = adamw.update(state["params"], g, state["opt"], ocfg)
        return {"params": p, "opt": o}, loss

    losses = []
    for _ in range(20):
        state, loss = step(state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses[::5]


def test_lr_schedule():
    ocfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="cosine")
    assert float(adamw.lr_at(jnp.int32(5), ocfg)) == pytest.approx(0.5, abs=1e-3)
    assert float(adamw.lr_at(jnp.int32(10), ocfg)) == pytest.approx(1.0, abs=1e-3)
    assert float(adamw.lr_at(jnp.int32(100), ocfg)) == pytest.approx(0.0, abs=1e-3)


def test_grad_clip():
    ocfg = OptConfig(grad_clip=1e-6)
    state = _state(ocfg)
    g = jax.tree.map(lambda p: jnp.ones_like(p), state["params"])
    newp, _, stats = adamw.update(state["params"], g, state["opt"], ocfg)
    assert float(stats["grad_norm"]) > 1.0  # raw norm reported


def test_zero1_spec():
    from jax.sharding import Mesh, PartitionSpec as P
    # a 1x1 mesh regardless of how many (possibly fake) devices exist, so the
    # test also runs under CI's XLA_FLAGS=--xla_force_host_platform_device_count=8
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    spec = adamw.zero1_spec(mesh, P(None, "model"), (8, 16))
    # data axis size 1 divides everything; first free dim gets it
    assert spec == P("data", "model")


def test_driver_preemption_checkpoint():
    ocfg = OptConfig()
    state = _state(ocfg)

    def step_fn(state, batch):
        return state, jnp.float32(1.0)

    with tempfile.TemporaryDirectory() as d:
        drv = TrainDriver(FTConfig(ckpt_dir=d, ckpt_every=100), state, step_fn)

        def batches():
            n = 0
            while True:
                if n == 3:
                    os.kill(os.getpid(), signal.SIGTERM)  # simulated preemption
                n += 1
                yield {}

        res = drv.run(batches(), num_steps=100)
        assert res["steps"] <= 5               # stopped early
        assert latest_step(d) == res["steps"]  # final checkpoint written


def test_straggler_detection():
    from repro.runtime.ft import StepStats
    st = StepStats()
    flags = [st.record(0.1, 3.0) for _ in range(10)]
    assert not any(flags)
    assert st.record(1.0, 3.0)  # 10x the EMA
    assert st.stragglers == 1


def test_overflow_retry():
    calls = []

    class T:
        def __init__(self, overflow):
            self.overflow = overflow

    def build(slack):
        calls.append(slack)
        return T(overflow=len(calls) < 3)

    t, attempts = run_with_overflow_retry(build, base_slack=2.0)
    assert attempts == 2 and calls == [2.0, 4.0, 8.0]

    with pytest.raises(RuntimeError):
        run_with_overflow_retry(lambda s: T(True), max_retries=2)


def test_pipeline_stats_and_batches():
    corpus = token_corpus(300, vocab=128)
    pipe = TokenPipeline(corpus, PipelineConfig(vocab=128, seq_len=16,
                                                global_batch=4, min_len=64,
                                                min_quality=0.3))
    try:
        b = next(iter(pipe))
        assert b["tokens"].shape == (4, 16)
        assert b["labels"].shape == (4, 16)
        assert (b["tokens"] < 128).all()
        # curation respected: every surviving doc obeys the filters
        assert (pipe.doc_len >= 64).all()
        assert pipe.total_tokens == pipe.doc_len.sum()
        assert pipe.bucket_stats["docs"].sum() == len(pipe.doc_len)
    finally:
        pipe.close()


def test_compression_error_feedback():
    from repro.optim import compression
    g = jnp.asarray(np.random.default_rng(0).normal(size=256).astype(np.float32))
    q, scale = compression.quantize(g)
    deq = compression.dequantize(q, scale)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) * 0.51

"""End-to-end system behaviour: the paper's flagship program (TPCx-BB Q26-
style customer segmentation) — relational pipeline -> matrix assembly ->
K-means — all through the public API, validated against NumPy oracles."""
import numpy as np

from repro import hiframes as hf
from repro.data import synth
from oracle import o_aggregate, o_join


def q26_pipeline(min_count=4):
    ss = synth.store_sales(20_000, n_items=500, n_customers=800, seed=5)
    it = synth.item(500, seed=6)
    store_sales = hf.table(ss, "store_sales")
    item = hf.table(it, "item")

    sale_items = hf.join(store_sales, item, on=("ss_item_sk", "i_item_sk"))
    c_i = hf.aggregate(
        sale_items, "ss_customer_sk",
        c_i_count=hf.count(),
        id1=hf.sum_(sale_items["i_class_id"] == 1),
        id2=hf.sum_(sale_items["i_class_id"] == 2),
        id3=hf.sum_(sale_items["i_class_id"] == 3))
    c_i = c_i[c_i["c_i_count"] > min_count]
    return ss, it, c_i


def oracle_q26(ss, it, min_count=4):
    j = o_join(ss, it, "ss_item_sk", "i_item_sk")
    a = o_aggregate(j, "ss_customer_sk", {
        "c_i_count": ("count", None),
        "id1": ("sum", j["i_class_id"] == 1),
        "id2": ("sum", j["i_class_id"] == 2),
        "id3": ("sum", j["i_class_id"] == 3)})
    keep = a["c_i_count"] > min_count
    return {k: v[keep] for k, v in a.items()}


def test_q26_relational_stage():
    ss, it, c_i = q26_pipeline()
    out = c_i.collect().to_numpy()
    ref = oracle_q26(ss, it)
    o = np.argsort(out["ss_customer_sk"])
    np.testing.assert_array_equal(out["ss_customer_sk"][o], ref["ss_customer_sk"])
    for k in ("c_i_count", "id1", "id2", "id3"):
        np.testing.assert_array_equal(out[k][o], ref[k])


def test_q26_matrix_assembly_and_kmeans():
    """Matrix assembly (transpose_hcat pattern) feeds K-means; 1D_BLOCK is
    enforced by the distribution pass (rebalance after the 1D_VAR filter)."""
    import jax.numpy as jnp
    ss, it, c_i = q26_pipeline()
    feats = ["c_i_count", "id1", "id2", "id3"]
    mat, counts, cap = c_i.collect_matrix(feats)
    n = int(np.sum(np.asarray(counts)))
    ref = oracle_q26(ss, it)
    assert n == len(ref["ss_customer_sk"])
    mat = np.asarray(mat)[:n]  # single-shard prefix

    # K-means (pure jnp, as the paper calls into an ML library)
    x = jnp.asarray(mat)
    k = 4
    cent = x[:k]
    for _ in range(10):
        d2 = jnp.sum((x[:, None] - cent[None]) ** 2, axis=-1)
        a = jnp.argmin(d2, axis=1)
        cent = jnp.stack([jnp.where((a == i)[:, None], x, 0).sum(0)
                          / jnp.maximum((a == i).sum(), 1) for i in range(k)])
    assert np.all(np.isfinite(np.asarray(cent)))
    # every cluster non-degenerate on this data
    sizes = np.bincount(np.asarray(a), minlength=k)
    assert sizes.sum() == n


def test_overflow_retry_integration():
    """Skewed join overflows a tight plan and succeeds after driver retry."""
    from repro.runtime import run_with_overflow_retry
    ss = synth.store_sales(5_000, n_items=50, n_customers=100, seed=7, skew=1.2)
    it = synth.item(50, seed=8)

    def build(slack):
        cfg = hf.ExecConfig(safe_capacities=False, shuffle_slack=slack,
                            join_expansion=slack)
        j = hf.join(hf.table(ss, "ss"), hf.table(it, "it"),
                    on=("ss_item_sk", "i_item_sk"))
        return j.collect(cfg)

    table, attempts = run_with_overflow_retry(build, base_slack=1.0,
                                              max_retries=6)
    assert not table.overflow
    assert table.num_rows() == 5_000  # item keys unique -> row-preserving join


def test_integration_with_array_code():
    """Columns flow into arbitrary jax computation and back (dual repr)."""
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    data = {"id": rng.integers(0, 10, 400).astype(np.int32),
            "x": rng.normal(size=400).astype(np.float32)}
    df = hf.table(data)
    t = df.collect()
    x = t.column("x")                       # a plain jax array
    z = jnp.tanh(x) * 2.0                    # arbitrary array computation
    df2 = hf.table({"id": np.asarray(t.column("id")), "z": np.asarray(z)})
    out = hf.aggregate(df2, "id", m=hf.mean(df2["z"])).collect().to_numpy()
    ref = o_aggregate({"id": data["id"], "z": np.tanh(data["x"]) * 2.0},
                      "id", {"m": ("mean", np.tanh(data["x"]) * 2.0)})
    o = np.argsort(out["id"])
    np.testing.assert_allclose(out["m"][o], ref["m"], atol=1e-5)

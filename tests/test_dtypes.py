"""Dictionary-encoded string columns + the validity/null model
(docs/dtypes.md): ingest coercion, code-space expression rewriting, the
pandas-style null API, and pandas-parity oracles for string-key
merge/groupby/sort and skipna aggregation — cross-checked at 1, 2 and 8
shards through the subprocess harness."""
import numpy as np
import pytest

from repro import hiframes as hf
from repro.core import dtypes as dt
from test_physical_plan import run_sharded

pd = pytest.importorskip("pandas")


# ---------------------------------------------------------------------------
# encoding layer (host-side, no device)
# ---------------------------------------------------------------------------


def test_dict_encode_sorted_roundtrip():
    vals = np.array(["pear", "apple", None, "fig", "apple"], dtype=object)
    codes, cats, has_null = dt.dict_encode(vals)
    assert cats == ("apple", "fig", "pear")        # sorted: code order == lex
    assert has_null
    assert codes.dtype == dt.CODE_DTYPE
    assert codes.tolist() == [2, 0, dt.NULL_CODE, 1, 0]
    back = dt.dict_decode(codes, cats)
    assert back.tolist() == ["pear", "apple", None, "fig", "apple"]


def test_dict_encode_fixed_dictionary_rejects_unknown():
    with pytest.raises(ValueError, match="outside the dictionary"):
        dt.dict_encode(np.array(["a", "z"], dtype=object), categories=("a", "b"))


def test_union_and_recode():
    a, b = ("b", "d"), ("a", "b", "c")
    u = dt.union_categories(a, b)
    assert u == ("a", "b", "c", "d")
    lut = dt.recode_map(a, u)
    assert lut.tolist() == [1, 3]
    with pytest.raises(ValueError, match="superset"):
        dt.recode_map(("a", "x"), ("a", "b"))


def test_dtype_equality_semantics():
    cat = dt.DType(dt.CODE_DTYPE, ("a", "b"))
    assert cat != np.dtype(np.int32)               # category != raw code dtype
    assert cat == dt.DType(dt.CODE_DTYPE, ("a", "b"))
    assert cat != dt.DType(dt.CODE_DTYPE, ("a", "c"))
    assert np.dtype(cat) == np.int32               # physical resolution
    nf = dt.DType(np.float32, nullable=True)
    assert nf == np.dtype(np.float32)              # nullability is transparent
    assert repr(nf) == "float32?"
    assert repr(cat) == "category[str]"


def test_coerce_rejects_datetime_with_guidance():
    with pytest.raises(TypeError, match="epoch"):
        dt.coerce_column("ts", np.array(["2024-01-01"], dtype="datetime64[D]"))
    with pytest.raises(TypeError, match="homogeneous"):
        dt.coerce_column("m", np.array(["a", 1], dtype=object))


def test_ingest_dtypes():
    df = hf.table({
        "s": np.array(["x", "y", None], dtype=object),
        "f": np.array([1.0, np.nan, 3.0], np.float32),
        "i": np.arange(3, dtype=np.int32),
        "o": np.array([1, None, 3], dtype=object),
    })
    d = df.dtypes
    assert dt.is_category(d["s"]) and dt.is_nullable(d["s"])
    assert dt.is_nullable(d["f"]) and np.dtype(d["f"]) == np.float32
    assert d["i"] == np.dtype(np.int32)
    assert dt.is_nullable(d["o"]) and np.dtype(d["o"]) == np.float32


def test_from_pandas_object_and_holes():
    pdf = pd.DataFrame({"s": ["b", None, "a"], "v": [1.0, np.nan, 3.0]})
    df = hf.from_pandas(pdf)
    assert dt.is_category(df.dtypes["s"])
    out = df.to_numpy()
    assert out["s"].tolist() == ["b", None, "a"]
    with pytest.raises(TypeError, match="DataFrame"):
        hf.from_pandas({"s": [1, 2]})


# ---------------------------------------------------------------------------
# code-space expression rewriting
# ---------------------------------------------------------------------------


@pytest.fixture()
def strdf():
    return hf.table({
        "cat": np.array(["b", "a", None, "c", "a", "b"], dtype=object),
        "x": np.array([1.0, 2.0, 3.0, np.nan, 5.0, 6.0], np.float32),
        "n": np.arange(6, dtype=np.int32),
    })


def test_string_equality_and_membership(strdf):
    assert strdf[strdf["cat"] == "a"].to_numpy()["n"].tolist() == [1, 4]
    assert strdf[strdf["cat"] != "a"].to_numpy()["n"].tolist() == [0, 2, 3, 5]
    assert sorted(strdf[strdf["cat"].isin(["a", "c"])].to_numpy()["n"]) \
        == [1, 3, 4]
    # absent value: eq -> empty, isin ignores it
    assert len(strdf[strdf["cat"] == "zzz"].to_numpy()["n"]) == 0
    assert sorted(strdf[strdf["cat"].isin(["zzz", "c"])].to_numpy()["n"]) == [3]


def test_string_range_comparisons_match_pandas(strdf):
    pdf = pd.DataFrame({"cat": ["b", "a", None, "c", "a", "b"],
                        "n": np.arange(6)})
    for op, ref in [("gt", pdf.cat > "a"), ("ge", pdf.cat >= "b"),
                    ("lt", pdf.cat < "b"), ("le", pdf.cat <= "a")]:
        e = {"gt": strdf["cat"] > "a", "ge": strdf["cat"] >= "b",
             "lt": strdf["cat"] < "b", "le": strdf["cat"] <= "a"}[op]
        got = sorted(strdf[e].to_numpy()["n"].tolist())
        assert got == sorted(pdf.n[ref].tolist()), op


def test_string_vs_plain_column_raises(strdf):
    with pytest.raises(TypeError, match="non-category"):
        strdf[strdf["n"] == "a"]


def test_different_dictionaries_comparison_raises():
    a = hf.table({"u": np.array(["a", "b"], dtype=object),
                  "v": np.array(["b", "c"], dtype=object)})
    with pytest.raises(TypeError, match="different"):
        a[a["u"] == a["v"]]


# ---------------------------------------------------------------------------
# null API surface
# ---------------------------------------------------------------------------


def test_isna_dropna_fillna(strdf):
    m = strdf.isna().to_numpy()
    assert np.asarray(m["cat"]).astype(bool).tolist() \
        == [False, False, True, False, False, False]
    assert np.asarray(m["x"]).astype(bool).tolist() \
        == [False, False, False, True, False, False]
    assert strdf.dropna().to_numpy()["n"].tolist() == [0, 1, 4, 5]
    assert strdf.dropna(subset="cat").to_numpy()["n"].tolist() == [0, 1, 3, 4, 5]
    f = strdf.fillna({"cat": "zz", "x": -1.0})
    assert not dt.is_nullable(f.dtypes["cat"])
    out = f.to_numpy()
    assert out["cat"].tolist() == ["b", "a", "zz", "c", "a", "b"]
    assert out["x"][3] == -1.0
    # filling with an in-dictionary value does not grow the dictionary
    f2 = strdf.fillna({"cat": "a"})
    assert dt.categories_of(f2.dtypes["cat"]) == ("a", "b", "c")


def test_astype_paths(strdf):
    t = hf.table({"x": np.array([1.5, 2.5], np.float32)})
    assert t.astype({"x": np.float64}).dtypes["x"] == np.dtype(np.float64)
    with pytest.raises(TypeError, match="decode"):
        strdf.astype({"cat": np.int32})
    with pytest.raises(TypeError, match="fillna"):
        strdf.astype({"x": np.int32})
    # nullable float -> float keeps nullability
    assert dt.is_nullable(strdf.astype({"x": np.float64}).dtypes["x"])


def test_all_null_and_empty_dictionary():
    df = hf.table({"s": np.array([None, None, None], dtype=object),
                   "x": np.ones(3, np.float32)})
    assert dt.categories_of(df.dtypes["s"]) == ()
    out = df.to_numpy()
    assert out["s"].tolist() == [None, None, None]
    # every key null: groupby drops all rows -> empty result
    g = df.groupby("s").agg(s=("x", "sum")).to_numpy()
    assert len(g["s"]) == 0


# ---------------------------------------------------------------------------
# pandas-parity oracles (single shard, in-process)
# ---------------------------------------------------------------------------


def _pdframe(seed=21, n=300):
    rng = np.random.default_rng(seed)
    cats = np.array(["aa", "bb", "cc", "dd", "ee"], dtype=object)
    k = cats[rng.integers(0, 5, n)].astype(object)
    k[rng.random(n) < 0.1] = None
    x = rng.normal(size=n).astype(np.float32)
    x[rng.random(n) < 0.15] = np.nan
    return {"k": k, "x": x}


def _sorted_by_key(out):
    order = np.argsort(np.asarray(out["k"], dtype=object))
    return {c: np.asarray(v, dtype=object)[order] if v.dtype == object
            else np.asarray(v)[order] for c, v in out.items()}


def test_groupby_skipna_matches_pandas():
    cols = _pdframe()
    df = hf.table(cols)
    out = df.groupby("k").agg(
        s=("x", "sum"), m=("x", "mean"), mn=("x", "min"), mx=("x", "max"),
        c=("x", "count"), n="count").to_numpy()
    out = _sorted_by_key(out)
    pdf = pd.DataFrame({"k": cols["k"], "x": cols["x"].astype(np.float64)})
    ref = pdf.groupby("k").agg(
        s=("x", "sum"), m=("x", "mean"), mn=("x", "min"), mx=("x", "max"),
        c=("x", "count"), n=("x", "size")).sort_index()
    assert list(out["k"]) == list(ref.index)
    np.testing.assert_allclose(out["s"].astype(np.float64), ref["s"],
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(out["m"].astype(np.float64), ref["m"],
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(out["mn"].astype(np.float64), ref["mn"])
    np.testing.assert_allclose(out["mx"].astype(np.float64), ref["mx"])
    assert out["c"].astype(int).tolist() == ref["c"].tolist()
    assert out["n"].astype(int).tolist() == ref["n"].tolist()


def test_groupby_all_null_group_matches_pandas():
    k = np.array(["a", "a", "b", "b"], dtype=object)
    x = np.array([1.0, 2.0, np.nan, np.nan], np.float32)
    out = hf.table({"k": k, "x": x}).groupby("k").agg(
        s=("x", "sum"), m=("x", "mean"), c=("x", "count")).to_numpy()
    out = _sorted_by_key(out)
    # pandas: all-NaN sum -> 0.0, mean -> NaN, count -> 0
    assert out["s"].tolist() == [3.0, 0.0]
    assert out["m"][0] == pytest.approx(1.5) and np.isnan(out["m"][1])
    assert out["c"].astype(int).tolist() == [2, 0]


def test_groupby_skipna_false_poisons():
    k = np.array(["a", "a", "b"], dtype=object)
    x = np.array([1.0, np.nan, 3.0], np.float32)
    df = hf.table({"k": k, "x": x})
    out = _sorted_by_key(df.groupby("k").sum(skipna=False).to_numpy())
    # group "a" holds a NaN -> poisoned; "b" is clean
    assert np.isnan(out["x"][0]) and out["x"][1] == 3.0
    # default skipna=True drops the NaN instead
    out = _sorted_by_key(df.groupby("k").sum().to_numpy())
    assert out["x"].tolist() == [1.0, 3.0]


def test_category_numeric_agg_rejected():
    df = hf.table({"k": np.array(["a", "b"], dtype=object),
                   "s": np.array(["x", "y"], dtype=object)})
    with pytest.raises(TypeError, match="category"):
        df.groupby("k").agg(bad=("s", "sum"))
    # min/max/nunique stay valid (code order is lexicographic)
    out = df.groupby("k").agg(lo=("s", "min")).to_numpy()
    assert sorted(out["lo"].tolist()) == ["x", "y"]


def test_merge_string_keys_matches_pandas():
    cols = _pdframe(seed=5)
    dim = {"k": np.array(["aa", "cc", "ee", "zz"], dtype=object),
           "w": np.array([10.0, 20.0, 30.0, 40.0], np.float32)}
    got = hf.table(cols).merge(hf.table(dim, "d"), on="k").to_numpy()
    ref = pd.DataFrame(cols).merge(pd.DataFrame(dim), on="k")
    assert len(got["k"]) == len(ref)
    np.testing.assert_allclose(np.sort(got["w"]), np.sort(ref["w"]))


def test_sort_string_column_nulls_first():
    """Divergence from pandas documented in docs/dtypes.md: the null code -1
    sorts FIRST (pandas na_position defaults to last); non-null order is
    plain lexicographic."""
    k = np.array(["b", None, "a", "c"], dtype=object)
    out = hf.table({"k": k}).sort("k").to_numpy()
    assert out["k"].tolist() == [None, "a", "b", "c"]


def test_concat_unifies_dictionaries():
    a = hf.table({"k": np.array(["b", "a"], dtype=object)})
    b = hf.table({"k": np.array(["c", None], dtype=object)})
    cc = hf.concat(a, b)
    assert dt.categories_of(cc.dtypes["k"]) == ("a", "b", "c")
    assert dt.is_nullable(cc.dtypes["k"])
    assert cc.to_numpy()["k"].tolist() == ["b", "a", "c", None]


def test_explain_shows_logical_dtypes(strdf):
    txt = strdf.explain()
    logical = txt.split("\n\n")[0]
    assert "schema:" in logical
    assert "category[str]?" in logical and "float32?" in logical
    # the physical-plan header stays the first line of section 2
    assert txt.split("\n\n")[1].splitlines()[0].startswith("physical plan:")


# ---------------------------------------------------------------------------
# sharded pandas-parity (subprocess, 1/2/8 devices)
# ---------------------------------------------------------------------------

_SHARDED_GROUPBY = """
    import pandas as pd
    rng = np.random.default_rng(23)
    n = 600
    cats = np.array(["aa","bb","cc","dd","ee","ff","gg"], dtype=object)
    k = cats[rng.integers(0, 7, n)].astype(object)
    k[rng.random(n) < 0.1] = None
    x = rng.normal(size=n).astype(np.float32)
    x[rng.random(n) < 0.15] = np.nan
    df = hf.table({"k": k, "x": x})
    out = df.groupby("k").agg(s=("x","sum"), m=("x","mean"),
                              c=("x","count"), mn=("x","min")).to_numpy()
    order = np.argsort(np.asarray(out["k"], dtype=object))
    ref = pd.DataFrame({"k": k, "x": x.astype(np.float64)}).groupby("k").agg(
        s=("x","sum"), m=("x","mean"), c=("x","count"),
        mn=("x","min")).sort_index()
    assert list(np.asarray(out["k"], dtype=object)[order]) == list(ref.index)
    np.testing.assert_allclose(np.asarray(out["s"])[order].astype(np.float64),
                               ref["s"], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(out["m"])[order].astype(np.float64),
                               ref["m"], rtol=1e-3, atol=1e-3)
    assert np.asarray(out["c"])[order].astype(int).tolist() == ref["c"].tolist()
    np.testing.assert_allclose(np.asarray(out["mn"])[order].astype(np.float64),
                               ref["mn"], rtol=1e-5, atol=1e-5)
"""

_SHARDED_MERGE = """
    import pandas as pd
    rng = np.random.default_rng(29)
    n = 500
    cats = np.array(["aa","bb","cc","dd","ee","ff"], dtype=object)
    k = cats[rng.integers(0, 6, n)].astype(object)
    x = rng.normal(size=n).astype(np.float32)
    # the dimension table's dictionary only OVERLAPS the fact table's —
    # merge must recode both onto the union before joining
    dim = {"k": np.array(["cc", "dd", "ee", "ff", "xx"], dtype=object),
           "w": np.arange(5, dtype=np.float32)}
    got = (hf.table({"k": k, "x": x})
             .merge(hf.table(dim, "d"), on="k")
             .groupby("k").agg(s=("x","sum"), c="count").to_numpy())
    order = np.argsort(np.asarray(got["k"], dtype=object))
    ref = (pd.DataFrame({"k": k, "x": x.astype(np.float64)})
             .merge(pd.DataFrame(dim), on="k")
             .groupby("k").agg(s=("x","sum"), c=("x","size")).sort_index())
    assert list(np.asarray(got["k"], dtype=object)[order]) == list(ref.index)
    np.testing.assert_allclose(np.asarray(got["s"])[order].astype(np.float64),
                               ref["s"], rtol=1e-3, atol=1e-3)
    assert np.asarray(got["c"])[order].astype(int).tolist() == ref["c"].tolist()
"""


@pytest.mark.parametrize("devices", [1, 2, 8])
def test_sharded_groupby_skipna_parity(devices):
    run_sharded(_SHARDED_GROUPBY, devices)


@pytest.mark.parametrize("devices", [1, 2, 8])
def test_sharded_merge_dictionary_parity(devices):
    run_sharded(_SHARDED_MERGE, devices)

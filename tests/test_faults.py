"""Chaos suite for the execution guardrails (docs/robustness.md).

Every injectable fault site is driven to one of exactly two outcomes: a
TYPED error (errors.py taxonomy) or a healed retry/degradation within
budget, oracle-checked — never a silent wrong result.  A census gate pins
``validate=True`` and ``fault_inject=None`` as zero-plan-change levers, and
the flagship acceptance scenario shows per-op overflow attribution beating
global slack-doubling on the PR-7 skew join: strictly fewer retries AND
strictly smaller total buffer bytes.
"""
import numpy as np
import pytest

from repro import hiframes as hf
from repro.core import errors as err
from repro.core import stats
from repro.runtime import retry as rt
from repro.runtime.faults import FaultPlan
from repro.runtime.ft import run_with_overflow_retry
from oracle import o_aggregate
from test_physical_plan import run_sharded


@pytest.fixture(autouse=True)
def _fresh_stores():
    """Realized-stats and retry-event stores are process-global (keyed by
    plan fingerprint); isolate every test from its neighbours."""
    stats.clear_realized()
    rt.clear_events()
    yield
    stats.clear_realized()
    rt.clear_events()


def _frame(n=600, keys=23, seed=11):
    rng = np.random.default_rng(seed)
    return {"k": rng.integers(0, keys, n).astype(np.int32),
            "v": rng.normal(size=n).astype(np.float32)}


def _agg(df):
    return df.groupby("k").agg(s=("v", "sum"), n=("v", "count"))


def _check_agg(out, cols):
    ref = o_aggregate(cols, "k", {"s": ("sum", cols["v"]),
                                  "n": ("count", None)})
    o = np.argsort(out["k"])
    np.testing.assert_array_equal(np.sort(out["k"]), ref["k"])
    np.testing.assert_allclose(out["s"][o], ref["s"], rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(out["n"][o], ref["n"])


# -- taxonomy -----------------------------------------------------------------


def test_error_taxonomy_types_and_fields():
    e = err.CapacityOverflow(op_id=7, op="HashExchange",
                             observed_est=1400, cap=250, attempts=2)
    assert isinstance(e, RuntimeError)          # legacy matchers keep working
    assert isinstance(e, err.HiFramesError)
    assert e.op_id == 7 and e.observed_est == 1400 and e.cap == 250
    assert "overflow" in str(e) and "op #7" in str(e)

    f = err.InvariantFailure("checksum", 3, "HashExchange")
    assert "checksum@op#3" in f.render()
    pe = err.PlanInvariantError((f,))
    assert isinstance(pe, RuntimeError) and pe.failures == (f,)
    assert "checksum" in str(pe)

    ke = err.KernelBackendError("prefix_sum", "compiled", "boom")
    assert isinstance(ke, RuntimeError)
    assert ke.kernel == "prefix_sum" and ke.backend == "compiled"


def test_ft_shim_typed_error_reports_last_slack():
    """run_with_overflow_retry now delegates to RetryPolicy and raises the
    typed CapacityOverflow naming the LAST slack actually attempted."""

    class T:
        overflow = True

    calls = []
    with pytest.raises(err.CapacityOverflow, match="last slack attempted 8"):
        run_with_overflow_retry(lambda s: (calls.append(s), T())[1],
                                base_slack=2.0, max_retries=2)
    assert calls == [2.0, 4.0, 8.0]             # exact legacy call sequence


# -- census gate: guardrail levers change ZERO plans --------------------------


def test_validate_and_fault_inject_change_zero_plans():
    cols = _frame()
    dim = {"k": np.arange(23, dtype=np.int32),
           "w": np.random.default_rng(1).normal(size=23).astype(np.float32)}
    q = _agg(hf.join(hf.table(cols, "t"), hf.table(dim, "d"),
                     on=("k", "k"))).sort_values("s")
    base = q.physical_plan(hf.ExecConfig())
    for cfg in (hf.ExecConfig(validate=True),
                hf.ExecConfig(fault_inject=FaultPlan()),
                hf.ExecConfig(validate=True, fault_inject=FaultPlan())):
        plan = q.physical_plan(cfg)
        assert plan.counts() == base.counts()
        assert plan.collective_count() == base.collective_count()
        assert plan.shuffle_census(P=8) == base.shuffle_census(P=8)
        assert plan.render() == base.render()


def test_validate_clean_run_no_failures():
    cols = _frame()
    t = _agg(hf.table(cols, "t")).collect(hf.ExecConfig(validate=True))
    assert t.invariant_failures == ()
    assert not t.overflow and t.overflow_ops == {}
    _check_agg(t.to_numpy(), cols)


# -- per-op attribution beats global slack-doubling (acceptance) --------------


_SKEW_BEATS_GLOBAL = """
import numpy as np
from oracle import o_join
rng = np.random.default_rng(7)
n = 4000
k = np.where(rng.random(n) < 0.35, 0,
             rng.integers(1, 400, n)).astype(np.int64)
probe = {"k": k, "v": rng.normal(size=n).astype(np.float32)}
dim = {"k": np.arange(400).astype(np.int64),
       "w": rng.normal(size=400).astype(np.float32)}
q = hf.join(hf.table(probe, "t"), hf.table(dim, "d"), on=("k", "k"))
ref = o_join(probe, dim, "k", "k")

results = {}
for scope in ("op", "global"):
    cfg = hf.ExecConfig(safe_capacities=False, shuffle_slack=1.0,
                        join_expansion=1.0, auto_retry=6,
                        retry_scope=scope, broadcast_join=False)
    lowered, t = q._execute(cfg)
    assert not t.overflow
    out = t.to_numpy()
    assert len(out["k"]) == len(ref["k"])
    np.testing.assert_allclose(np.sort(out["v"]), np.sort(ref["v"]),
                               rtol=1e-4, atol=1e-4)
    attempts = {e.attempt for e in t.events
                if e.kind in ("retry", "retry_global")}
    results[scope] = (len(attempts), lowered.pplan.buffer_bytes())
(op_r, op_b), (gl_r, gl_b) = results["op"], results["global"]
assert op_r >= 1, "scenario must actually overflow"
assert op_r < gl_r, (op_r, gl_r)        # strictly fewer retries
assert op_b < gl_b, (op_b, gl_b)        # strictly smaller buffers
print("RETRIES", op_r, gl_r, "BYTES", op_b, gl_b)
"""


def test_per_op_retry_beats_global_on_skew_join():
    out = run_sharded(_SKEW_BEATS_GLOBAL, 8)
    assert "RETRIES" in out


# -- forced overflow: healed retry within budget, oracle parity ---------------


_FORCED_OVERFLOW_HEAL = """
import numpy as np
from oracle import o_aggregate
from repro.runtime.faults import FaultPlan
rng = np.random.default_rng(11)
cols = {"k": rng.integers(0, 23, 600).astype(np.int32),
        "v": rng.normal(size=600).astype(np.float32)}
q = hf.table(cols, "t").groupby("k").agg(s=("v", "sum"), n=("v", "count"))
cfg = hf.ExecConfig(validate=True, auto_retry=3,
                    fault_inject=FaultPlan(force_overflow=("HashExchange",)))
t = q.collect(cfg)
assert not t.overflow, t.overflow_ops
assert any(e.kind == "retry" for e in t.events), t.events
assert t.invariant_failures == ()
out = t.to_numpy()
ref = o_aggregate(cols, "k", {"s": ("sum", cols["v"]), "n": ("count", None)})
o = np.argsort(out["k"])
np.testing.assert_array_equal(np.sort(out["k"]), ref["k"])
np.testing.assert_allclose(out["s"][o], ref["s"], rtol=1e-4, atol=1e-4)
np.testing.assert_array_equal(out["n"][o], ref["n"])
"""


@pytest.mark.parametrize("devices", [1, 2, 8])
def test_forced_overflow_heals_within_budget(devices):
    run_sharded(_FORCED_OVERFLOW_HEAL, devices)


def test_forced_overflow_attribution_names_the_op():
    cols = _frame()
    q = _agg(hf.table(cols, "t"))
    cfg = hf.ExecConfig(auto_retry=0,
                        fault_inject=FaultPlan(force_overflow=("HashExchange",)))
    t = q.collect(cfg)
    assert t.overflow
    assert len(t.overflow_ops) == 1
    (op_id, rec), = t.overflow_ops.items()
    assert rec["op"] == "HashExchange" and rec["kind"] == "exchange"
    assert rec["cap_req"] >= 1 and rec["cap"] >= rec["cap_req"]


def test_persist_overflow_raises_typed_capacity_overflow():
    cols = _frame()
    q = _agg(hf.table(cols, "t"))
    cfg = hf.ExecConfig(auto_retry=1, fault_inject=FaultPlan(
        force_overflow=("HashExchange",), overflow_shots=-1))
    with pytest.raises(err.CapacityOverflow, match="persist"):
        q.persist(cfg)
    try:
        q.persist(cfg)
    except err.CapacityOverflow as e:
        assert e.op_id >= 0 and e.observed_est >= 1   # names the op + cap
        assert "HashExchange" in str(e)


def test_retry_events_rendered_in_explain():
    cols = _frame()
    q = _agg(hf.table(cols, "t"))
    cfg = hf.ExecConfig(auto_retry=2,
                        fault_inject=FaultPlan(force_overflow=("HashExchange",)))
    t = q.collect(cfg)
    assert not t.overflow and any(e.kind == "retry" for e in t.events)
    txt = q.explain(hf.ExecConfig())
    assert "events (previous run):" in txt and "retry" in txt


# -- kernel-backend degradation ladder ----------------------------------------


def test_kernel_fault_degrades_one_rung_and_heals():
    cols = _frame()
    df = hf.table(cols, "t")
    q = _agg(df[df["v"] > -0.3])
    cfg = hf.ExecConfig(use_pallas="interpret", fault_inject=FaultPlan(
        fail_kernel="prefix_sum", fail_modes=("interpret",)))
    t = q.collect(cfg)
    assert not t.overflow
    keep = cols["v"] > -0.3
    sub = {k: v[keep] for k, v in cols.items()}
    _check_agg(t.to_numpy(), sub)
    evs = [e for e in t.events if e.kind == "degrade_kernel"]
    assert evs and "prefix_sum" in evs[0].detail
    assert "interpret -> off" in evs[0].detail


def test_kernel_fault_exhausted_raises_typed_error():
    cols = _frame()
    df = hf.table(cols, "t")
    q = _agg(df[df["v"] > 0.0])
    cfg = hf.ExecConfig(use_pallas="off", fault_inject=FaultPlan(
        fail_kernel="prefix_sum",
        fail_modes=("off", "interpret", "compiled")))
    with pytest.raises(err.KernelBackendError, match="prefix_sum"):
        q.collect(cfg)


# -- packed-exchange corruption: validate catches, ladder degrades ------------


_CORRUPT_PACKED_DEGRADE = """
import numpy as np
from oracle import o_aggregate
from repro.runtime.faults import FaultPlan
rng = np.random.default_rng(3)
cols = {"k": rng.integers(0, 17, 500).astype(np.int32),
        "v": rng.normal(size=500).astype(np.float32)}
q = hf.table(cols, "t").groupby("k").agg(s=("v", "sum"))
cfg = hf.ExecConfig(validate=True, fault_inject=FaultPlan(
    corrupt_exchange=("HashExchange",), corrupt_packed_only=True))
t = q.collect(cfg)
assert any(e.kind == "degrade_packed" for e in t.events), t.events
assert t.invariant_failures == ()
out = t.to_numpy()
ref = o_aggregate(cols, "k", {"s": ("sum", cols["v"])})
o = np.argsort(out["k"])
np.testing.assert_array_equal(np.sort(out["k"]), ref["k"])
np.testing.assert_allclose(out["s"][o], ref["s"], rtol=1e-4, atol=1e-4)
"""


@pytest.mark.parametrize("devices", [2, 8])
def test_corrupt_packed_exchange_degrades_to_unpacked(devices):
    """A packed-payload fault trips the checksum invariant; the ladder falls
    back to the unpacked per-column exchange and the answer is right."""
    run_sharded(_CORRUPT_PACKED_DEGRADE, devices)


def test_unhealable_corruption_raises_plan_invariant_error():
    cols = _frame()
    q = _agg(hf.table(cols, "t"))
    cfg = hf.ExecConfig(validate=True, packed_exchange=False,
                        fault_inject=FaultPlan(
                            corrupt_exchange=("HashExchange",),
                            corrupt_packed_only=False))
    with pytest.raises(err.PlanInvariantError, match="checksum"):
        q.collect(cfg)


def test_corruption_without_validate_goes_undetected():
    """The control: the same fault with validate=False flows through —
    documenting exactly what the validation lever buys."""
    cols = _frame()
    q = _agg(hf.table(cols, "t"))
    cfg = hf.ExecConfig(validate=False, packed_exchange=False,
                        fault_inject=FaultPlan(
                            corrupt_exchange=("HashExchange",),
                            corrupt_packed_only=False))
    t = q.collect(cfg)                          # no error raised
    assert t.invariant_failures == ()


# -- stats poisoning ----------------------------------------------------------


def test_poison_stats_raise_degrades_to_static_planning():
    cols = _frame()
    q = _agg(hf.table(cols, "t"))
    cfg = hf.ExecConfig(adaptive_stats=True,
                        fault_inject=FaultPlan(poison_stats="raise"))
    t = q.collect(cfg)
    assert not t.overflow
    evs = [e for e in t.events if e.kind == "degrade_stats"]
    assert evs and "static" in evs[0].detail
    _check_agg(t.to_numpy(), cols)


def test_poison_stats_ndv_healed_by_per_op_retry():
    """A poisoned distinct-count estimate undersizes PartialAgg to 1 group;
    the per-op retry reads the TRUE requirement from the attribution vector
    and heals in one attempt.  (>64 keys: the auto-cap floor would otherwise
    absorb the poison.)"""
    cols = _frame(n=2000, keys=500)
    q = _agg(hf.table(cols, "t"))
    cfg = hf.ExecConfig(adaptive_stats=True, safe_capacities=False,
                        auto_retry=2,
                        fault_inject=FaultPlan(poison_stats="ndv"))
    t = q.collect(cfg)
    assert not t.overflow
    assert any(e.kind == "retry" for e in t.events), t.events
    _check_agg(t.to_numpy(), cols)


def test_overflow_failure_feeds_realized_store():
    """Satellite: an exhausted PartialAgg overflow records its observed
    requirement, so the NEXT adaptive run sizes correctly with no retry."""
    cols = _frame(n=2000, keys=500)
    q = _agg(hf.table(cols, "t"))
    bad = hf.ExecConfig(adaptive_stats=True, safe_capacities=False,
                        auto_retry=0,
                        fault_inject=FaultPlan(poison_stats="ndv"))
    t1 = q.collect(bad)
    assert t1.overflow and any(
        rec["kind"] == "partial_agg" for rec in t1.overflow_ops.values())
    good = hf.ExecConfig(adaptive_stats=True, safe_capacities=False,
                         auto_retry=0)
    t2 = q.collect(good)
    assert not t2.overflow                       # sized from the failure
    _check_agg(t2.to_numpy(), cols)

"""Property-driven physical planning: exchange/sort elision.

Plan-introspection tests assert EXACT shuffle/sort counts (the planner is
deterministic and device-free), and subprocess tests cross-check the elided
pipelines against the numpy oracle on 1, 2 and 8 shards.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import hiframes as hf
from repro.core import ir, optimizer
from repro.core import physical_plan as pp
from oracle import o_aggregate, o_join

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sharded(body: str, devices: int):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import numpy as np
        import jax
        assert jax.device_count() == {devices}
        from repro import hiframes as hf
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBPROC_OK")
    """)
    env = dict(os.environ)
    # src for the package, tests for the numpy oracles (oracle.py)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), os.path.join(ROOT, "tests")])
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"stderr:\n{res.stderr[-4000:]}"
    assert "SUBPROC_OK" in res.stdout
    return res.stdout


def _frames(n=800, m=90, seed=31):
    rng = np.random.default_rng(seed)
    left = {"k1": rng.integers(0, 7, n).astype(np.int32),
            "k2": rng.integers(0, 9, n).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32)}
    right = {"ca": rng.integers(0, 7, m).astype(np.int32),
             "cb": rng.integers(0, 9, m).astype(np.int32),
             "w": rng.normal(size=m).astype(np.float32)}
    return left, right


# -- plan introspection: exact exchange / sort counts -------------------------


def test_join_agg_same_keys_two_exchanges_one_sort():
    """(a) join -> aggregate(by=join keys): the aggregate's hash exchange AND
    its pre-exchange sort collapse; only the join's two exchanges plus the
    aggregate's one local sort remain."""
    left, right = _frames()
    j = hf.join(hf.table(left), hf.table(right, "d"),
                on=[("k1", "ca"), ("k2", "cb")])
    a = hf.aggregate(j, by=("k1", "k2"), s=hf.sum_(j["w"]), c=hf.count())
    c = a.physical_plan().counts()
    assert c["hash_exchanges"] == 2
    assert c["local_sorts"] == 1
    assert c["sample_sorts"] == 0


def test_join_agg_different_keys_three_exchanges():
    """(b) aggregate by a NON-join key still pays its own exchange — which,
    with decomposable agg fns, takes the partial-aggregation path (one extra
    local sort, but the exchange ships only distinct local groups)."""
    left, right = _frames()
    j = hf.join(hf.table(left), hf.table(right, "d"),
                on=[("k1", "ca"), ("k2", "cb")])
    a = hf.aggregate(j, by="x", c=hf.count())
    c = a.physical_plan().counts()
    assert c["hash_exchanges"] == 3
    assert c["local_sorts"] == 2
    assert c["partial_aggs"] == 1
    c_off = a.physical_plan(hf.ExecConfig(partial_agg=False)).counts()
    assert c_off["hash_exchanges"] == 3
    assert c_off["local_sorts"] == 1
    assert c_off["partial_aggs"] == 0


def test_broadcast_join_zero_shuffles():
    """(c) REP right side: no exchange, no sort (rank join sorts internally)."""
    left, right = _frames()
    j = hf.join(hf.table(left), hf.table(right, "d").replicate(),
                on=[("k1", "ca"), ("k2", "cb")])
    c = j.physical_plan().counts()
    assert c["hash_exchanges"] == 0
    assert c["local_sorts"] == 0
    assert c["sample_sorts"] == 0


def test_superset_and_reordered_keys_do_not_elide():
    """hash(k1,k2) satisfies by=(k1,k2); by=(k2,k1) and by=(k1,) vs a
    partitioning on (k1,k2) do not (reordering/superset rejected)."""
    left, right = _frames()
    j = hf.join(hf.table(left), hf.table(right, "d"),
                on=[("k1", "ca"), ("k2", "cb")])
    reordered = hf.aggregate(j, by=("k2", "k1"), c=hf.count())
    assert reordered.physical_plan().counts()["hash_exchanges"] == 3
    narrower = hf.aggregate(j, by="k1", c=hf.count())
    assert narrower.physical_plan().counts()["hash_exchanges"] == 3
    # but a SUBSET partitioning satisfies a wider aggregate key: equal
    # (k1,k2) tuples are equal on k1, hence co-located.
    j1 = hf.join(hf.table(left), hf.table(right, "d"), on=("k1", "ca"))
    wider = hf.aggregate(j1, by=("k1", "k2"), c=hf.count())
    assert wider.physical_plan().counts()["hash_exchanges"] == 2


def test_sort_then_aggregate_elides_everything():
    """range partitioning + ordering from a sample sort satisfy the
    aggregate: no hash exchange, no local sort.  (optimize_plan=False so the
    logical sort-under-aggregate rule doesn't remove the Sort first.)"""
    left, _ = _frames()
    cfg = hf.ExecConfig(optimize_plan=False)
    a = hf.aggregate(hf.table(left).sort(by=("k1", "k2")), by=("k1", "k2"),
                     c=hf.count())
    c = a.physical_plan(cfg).counts()
    assert c["sample_sorts"] == 1
    assert c["hash_exchanges"] == 0
    assert c["local_sorts"] == 0


def test_sort_prefix_of_range_keys_is_noop():
    """sort(by=(k1,k2)) then sort(by=k1): the data is already globally
    sorted by the k1 prefix, so the second sort plans NOTHING — in both
    prefix directions."""
    left, _ = _frames()
    cfg = hf.ExecConfig(optimize_plan=False)   # keep both logical sorts
    narrower = hf.table(left).sort(by=("k1", "k2")).sort(by="k1")
    assert narrower.physical_plan(cfg).counts()["sample_sorts"] == 1
    wider_sorted = hf.table(left).sort(by="k1").sort(by=("k1", "k2"))
    # the wider re-sort is NOT redundant physically (ordering (k1,) doesn't
    # cover (k1,k2)) — but the optimizer's Sort∘Sort rule removes the inner
    # one, so the default config still pays exactly one sample sort.
    assert wider_sorted.physical_plan(cfg).counts()["sample_sorts"] == 2
    assert wider_sorted.physical_plan().counts()["sample_sorts"] == 1
    # results stay oracle-correct with the elision
    out = narrower.collect(cfg).to_numpy()
    order = np.lexsort((left["k2"], left["k1"]))
    np.testing.assert_array_equal(out["k1"], left["k1"][order])
    np.testing.assert_array_equal(out["k2"], left["k2"][order])


def test_elide_exchanges_false_restores_baseline():
    left, right = _frames()
    j = hf.join(hf.table(left), hf.table(right, "d"),
                on=[("k1", "ca"), ("k2", "cb")])
    a = hf.aggregate(j, by=("k1", "k2"), c=hf.count())
    # the FULL baseline needs both PR-2 elision and PR-4 partial aggregation
    # off (each is its own A/B lever)
    c = a.physical_plan(hf.ExecConfig(elide_exchanges=False,
                                      partial_agg=False)).counts()
    assert c["hash_exchanges"] == 3
    assert c["local_sorts"] == 1
    assert c["partial_aggs"] == 0


def test_join_chain_reuses_partitioning():
    """join on k then join on the same key: the second join re-exchanges only
    the NEW side (the left flow is already hash-partitioned on k)."""
    rng = np.random.default_rng(33)
    n = 300
    a = hf.table({"k": rng.integers(0, 9, n).astype(np.int32),
                  "x": rng.normal(size=n).astype(np.float32)}, "a")
    b = hf.table({"k": rng.integers(0, 9, 50).astype(np.int32),
                  "w": rng.normal(size=50).astype(np.float32)}, "b")
    c = hf.table({"k": rng.integers(0, 9, 40).astype(np.int32),
                  "v": rng.normal(size=40).astype(np.float32)}, "c")
    j2 = hf.join(hf.join(a, b, on="k"), c, on="k")
    counts = j2.physical_plan().counts()
    assert counts["hash_exchanges"] == 3        # a, b, c — not 4


def test_filter_and_project_preserve_partitioning():
    """A filter or pure-rename projection between join and aggregate must not
    reintroduce the exchange; a computed key column must."""
    left, right = _frames()
    j = hf.join(hf.table(left), hf.table(right, "d"),
                on=[("k1", "ca"), ("k2", "cb")])
    f = j[j["w"] > 0.0]
    a = hf.aggregate(f, by=("k1", "k2"), c=hf.count())
    assert a.physical_plan().counts()["hash_exchanges"] == 2
    ren = f.rename({"k1": "r1", "k2": "r2"})
    a2 = hf.aggregate(ren, by=("r1", "r2"), c=hf.count())
    assert a2.physical_plan().counts()["hash_exchanges"] == 2
    derived = f.with_column("k1", f["k1"] + 1)   # key overwritten: prop lost
    a3 = hf.aggregate(derived, by=("k1", "k2"), c=hf.count())
    assert a3.physical_plan().counts()["hash_exchanges"] == 3


def test_explain_renders_physical_plan():
    left, right = _frames()
    j = hf.join(hf.table(left), hf.table(right, "d"),
                on=[("k1", "ca"), ("k2", "cb")])
    a = hf.aggregate(j, by=("k1", "k2"), c=hf.count())
    text = a.explain()
    assert "physical plan: 2 shuffles" in text
    assert "HashExchange(k1,k2)" in text or "HashExchange(ca,cb)" in text
    assert "MergeJoin" in text and "SegmentAgg" in text
    assert "part=hash(k1,k2)" in text


# -- optimizer: redundant-sort removal ----------------------------------------


def test_optimizer_drops_sort_under_aggregate():
    left, _ = _frames()
    a = hf.aggregate(hf.table(left).sort("k1"), by="k1", c=hf.count())
    new_root, n = optimizer.drop_redundant_sorts(a.node)
    assert n == 1
    assert not any(isinstance(x, ir.Sort) for x in ir.topo_order(new_root))


def test_optimizer_keeps_sort_for_first_agg():
    left, _ = _frames()
    df = hf.table(left).sort("x")
    a = hf.aggregate(df, by="k1", f=hf.first(df["x"]))
    _, n = optimizer.drop_redundant_sorts(a.node)
    assert n == 0


def test_optimizer_collapses_prefix_sorts():
    left, _ = _frames()
    s = hf.table(left).sort("k1").sort(by=("k1", "k2"))
    new_root, n = optimizer.drop_redundant_sorts(s.node)
    assert n == 1
    sorts = [x for x in ir.topo_order(new_root) if isinstance(x, ir.Sort)]
    assert len(sorts) == 1 and sorts[0].by == ("k1", "k2")
    # different leading key: NOT redundant
    s2 = hf.table(left).sort("k2").sort("k1")
    _, n2 = optimizer.drop_redundant_sorts(s2.node)
    assert n2 == 0


# -- property-rule unit tests -------------------------------------------------


def test_colocation_rules():
    h = pp.Partitioning("hash", ("k1", "k2"))
    assert pp.colocates(h, ("k1", "k2"))
    assert pp.colocates(h, ("k1", "k2", "k3"))      # subsequence of wider key
    assert not pp.colocates(h, ("k2", "k1"))        # reordering rejected
    assert not pp.colocates(h, ("k1",))             # superset partitioning
    assert pp.colocates(pp.Partitioning("rep"), ("anything",))
    assert not pp.colocates(pp.Partitioning("block"), ("k1",))


def test_grouping_rules():
    o = pp.Ordering(("k1", "k2"))
    assert pp.grouped(o, ("k1",))
    assert pp.grouped(o, ("k1", "k2"))
    assert not pp.grouped(o, ("k2",))
    assert not pp.grouped(o, ("k1", "k2", "k3"))


# -- execution cross-checks on 1 / 2 / 8 shards -------------------------------


_ELISION_BODY = """
    rng = np.random.default_rng(31)
    n, m = 800, 90
    left = {"k1": rng.integers(0, 7, n).astype(np.int32),
            "k2": rng.integers(0, 9, n).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32)}
    right = {"ca": rng.integers(0, 7, m).astype(np.int32),
             "cb": rng.integers(0, 9, m).astype(np.int32),
             "w": rng.normal(size=m).astype(np.float32)}
    j = hf.join(hf.table(left), hf.table(right, "d"),
                on=[("k1", "ca"), ("k2", "cb")])
    a = hf.aggregate(j, by=("k1", "k2"), s=hf.sum_(j["w"]), c=hf.count())
    cts = a.physical_plan().counts()
    assert cts["hash_exchanges"] == 2 and cts["local_sorts"] == 1, cts
    out = a.collect().to_numpy()
    # numpy oracle
    pairs = {}
    for i in range(m):
        pairs.setdefault((int(right["ca"][i]), int(right["cb"][i])), []).append(i)
    ref = {}
    for i in range(n):
        kt = (int(left["k1"][i]), int(left["k2"][i]))
        for ridx in pairs.get(kt, ()):
            s, c = ref.get(kt, (0.0, 0))
            ref[kt] = (s + float(right["w"][ridx]), c + 1)
    got = {(int(a1), int(a2)): (float(s), int(c))
           for a1, a2, s, c in zip(out["k1"], out["k2"], out["s"], out["c"])}
    assert len(got) == len(ref), (len(got), len(ref))
    assert all(abs(got[k][0] - ref[k][0]) < 1e-2 and got[k][1] == ref[k][1]
               for k in ref)
    # broadcast join: 0 shuffles, same row count as the shuffled join
    bj = hf.join(hf.table(left), hf.table(right, "d").replicate(),
                 on=[("k1", "ca"), ("k2", "cb")])
    assert bj.physical_plan().counts()["hash_exchanges"] == 0
    n_pairs = sum(len(pairs.get((int(left["k1"][i]), int(left["k2"][i])), ()))
                  for i in range(n))
    assert bj.collect().num_rows() == n_pairs
    assert j.collect().num_rows() == n_pairs
"""


@pytest.mark.parametrize("devices", [1, 2, 8])
def test_elision_matches_oracle_sharded(devices):
    run_sharded(_ELISION_BODY, devices)


def test_skewed_key0_composite_splitters_balance():
    """Regression: 90% of rows tie on the most-significant sort key.  The
    rank-based composite splitters spread ties by the minor key; the old
    key0-only splitters piled them onto one shard."""
    run_sharded("""
        rng = np.random.default_rng(41)
        n = 4000
        k0 = np.zeros(n, np.int32)
        k0[: n // 10] = rng.integers(1, 5, n // 10)
        kk = rng.integers(0, 1000, n).astype(np.int32)
        x = rng.normal(size=n).astype(np.float32)
        t = hf.table({"k0": k0, "kk": kk, "x": x}).sort(by=("k0", "kk")).collect()
        counts = np.asarray(t.counts)
        st = t.to_numpy()
        order = np.lexsort((kk, k0))
        assert np.array_equal(st["k0"], k0[order])
        assert np.array_equal(st["kk"], kk[order])
        # balanced: no shard holds more than half the rows (the skewed key0
        # value alone covers 90%)
        assert counts.max() < 0.5 * n, counts
    """, devices=8)


def test_multi_nunique_matches_oracle():
    rng = np.random.default_rng(43)
    n = 1500
    g = {"id": rng.integers(0, 11, n).astype(np.int32),
         "u": rng.integers(0, 7, n).astype(np.int32),
         "v": rng.integers(0, 13, n).astype(np.int32),
         "x": rng.normal(size=n).astype(np.float32)}
    dg = hf.table(g)
    a = hf.aggregate(dg, "id", nu=hf.nunique(dg["u"]), nv=hf.nunique(dg["v"]),
                     s=hf.sum_(dg["x"]), c=hf.count()).collect().to_numpy()
    ref = o_aggregate(g, "id", {"nu": ("nunique", g["u"]),
                                "nv": ("nunique", g["v"]),
                                "s": ("sum", g["x"]), "c": ("count", None)})
    o = np.argsort(a["id"])
    np.testing.assert_array_equal(a["id"][o], ref["id"])
    np.testing.assert_array_equal(a["nu"][o], ref["nu"])
    np.testing.assert_array_equal(a["nv"][o], ref["nv"])
    np.testing.assert_allclose(a["s"][o], ref["s"], atol=1e-3)
    np.testing.assert_array_equal(a["c"][o], ref["c"])


def test_multi_nunique_composite_key_8dev():
    run_sharded("""
        rng = np.random.default_rng(44)
        n = 1003
        k1 = rng.integers(0, 5, n).astype(np.int32)
        k2 = rng.integers(0, 4, n).astype(np.int32)
        u = rng.integers(0, 6, n).astype(np.int32)
        v = rng.integers(0, 9, n).astype(np.int32)
        df = hf.table({"k1": k1, "k2": k2, "u": u, "v": v})
        a = hf.aggregate(df, by=("k1", "k2"), nu=hf.nunique(df["u"]),
                         nv=hf.nunique(df["v"])).collect().to_numpy()
        ref = {}
        for i in range(n):
            kt = (int(k1[i]), int(k2[i]))
            su, sv = ref.setdefault(kt, (set(), set()))
            su.add(int(u[i])); sv.add(int(v[i]))
        got = {(int(a1), int(a2)): (int(x), int(y))
               for a1, a2, x, y in zip(a["k1"], a["k2"], a["nu"], a["nv"])}
        assert len(got) == len(ref)
        assert all(got[k] == (len(ref[k][0]), len(ref[k][1])) for k in ref)
    """, devices=8)


def test_rep_aggregate_never_exchanges():
    """Regression: a REP (replicated) aggregate must not shuffle even with
    elision disabled — every shard already holds the whole table; a
    collective exchange would multiply groups by the shard count."""
    left, _ = _frames()
    rep = hf.table(left).replicate()
    a = hf.aggregate(rep, by="k1", c=hf.count(), s=hf.sum_(rep["x"]))
    for cfg in (hf.ExecConfig(), hf.ExecConfig(elide_exchanges=False)):
        assert a.physical_plan(cfg).counts()["hash_exchanges"] == 0
    run_sharded("""
        rng = np.random.default_rng(46)
        n = 400
        left = {"k1": rng.integers(0, 7, n).astype(np.int32),
                "x": rng.normal(size=n).astype(np.float32)}
        rep = hf.table(left).replicate()
        a = hf.aggregate(rep, by="k1", c=hf.count(), s=hf.sum_(rep["x"]))
        out = a.collect(hf.ExecConfig(elide_exchanges=False)).to_numpy()
        o = np.argsort(out["k1"])
        uids = np.unique(left["k1"])
        assert np.array_equal(out["k1"][o], uids)
        assert np.array_equal(out["c"][o],
                              [(left["k1"] == u).sum() for u in uids])
        assert np.allclose(out["s"][o],
                           [left["x"][left["k1"] == u].sum() for u in uids],
                           atol=1e-3)
    """, devices=4)


def test_elided_plan_matches_unelided_results():
    """elide_exchanges on/off must be observationally identical."""
    left, right = _frames(seed=45)
    j = hf.join(hf.table(left), hf.table(right, "d"),
                on=[("k1", "ca"), ("k2", "cb")])
    a = hf.aggregate(j, by=("k1", "k2"), s=hf.sum_(j["w"]), c=hf.count())
    on = a.collect(hf.ExecConfig(elide_exchanges=True)).to_numpy()
    off = a.collect(hf.ExecConfig(elide_exchanges=False)).to_numpy()
    oo, of = (np.lexsort((on["k2"], on["k1"])), np.lexsort((off["k2"], off["k1"])))
    for k in on:
        # atol absorbs f32 summation-order round-off: the elided and
        # unelided plans feed group sums rows in different orders, and the
        # Pallas segment_sums backend (use_pallas != "off") accumulates
        # directly instead of via scan differences.
        np.testing.assert_allclose(on[k][oo], off[k][of], rtol=1e-5,
                                   atol=1e-4)

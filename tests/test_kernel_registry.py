"""Kernel registry: ref-vs-pallas parity sweep + the ``use_pallas`` lever.

Three contracts are pinned here:

  * PARITY — every registered primitive produces the same result from its
    ``ref`` (lax composition) and ``pallas`` (interpret-mode kernel)
    backends, swept over sizes (incl. zero-length and non-block-multiple),
    dtypes (f32/int32/bool in-process, f64 in an x64 subprocess) and, end to
    end, over 1/2/8 device shards with empty shards in the mix.  The sweep
    is registry-driven: a newly registered primitive without a case entry
    fails ``test_every_primitive_has_a_case``.
  * CENSUS GATE — ``use_pallas`` is a numerics-only lever: the planned
    exchanges, sorts and collective counts are identical across
    "off"/"interpret"/"compiled" (the planner never sees the mode).
  * LEVER — mode validation, the env default and the ``use_kernels``
    deprecation alias.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro import hiframes as hf
from repro.kernels import registry as kreg

from test_physical_plan import run_sharded

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# per-primitive parity cases
# ---------------------------------------------------------------------------


def _seg_mask(rng, n):
    """Random 0/1 segment-start mask; position 0 is always a start."""
    m = (rng.random(n) < 0.15).astype(np.int32)
    if n:
        m[0] = 1
    return m


def _values(rng, n, dtype):
    if dtype == np.bool_:
        return rng.random(n) < 0.5
    if np.issubdtype(np.dtype(dtype), np.integer):
        return rng.integers(-50, 50, n).astype(dtype)
    return rng.normal(size=n).astype(dtype)


def _case_prefix_sum(rng, n, dtype):
    return (jnp.asarray(_values(rng, n, dtype)),)


def _case_segment_scan(rng, n, dtype):
    return (jnp.asarray(_values(rng, n, dtype)),
            jnp.asarray(_seg_mask(rng, n)))


def _case_segment_rank(rng, n, dtype):
    seg = _seg_mask(rng, n)
    # order starts are a superset of segment starts (the physical layer's
    # run_starts invariant: a partition head always heads an order run too)
    ordb = np.maximum(seg, (rng.random(n) < 0.3).astype(np.int32))
    return [(jnp.asarray(seg), jnp.asarray(ordb), kind)
            for kind in ("rank", "dense_rank", "row_number")]


def _case_segment_sums(rng, n, dtype):
    # caller contract (physical.segment_aggregate): seg_id = cumsum of run
    # starts over the VALID prefix — sorted, consecutive from 0, no gaps
    nvalid = n - n // 5
    starts = _seg_mask(rng, nvalid)
    sid_valid = (np.cumsum(starts) - 1 if nvalid
                 else np.zeros(0, np.int64)).astype(np.int32)
    nseg = int(sid_valid[-1]) + 1 if nvalid else 1
    valid = np.arange(n) < nvalid
    # invalid tail rows route to the overflow segment, like the caller does
    sid = np.concatenate([sid_valid,
                          np.full(n - nvalid, nseg, np.int32)])
    return (jnp.asarray(_values(rng, n, dtype)), jnp.asarray(sid),
            jnp.asarray(valid), nseg)


def _case_bucket_scatter(rng, n, dtype):
    P = 8
    dest = rng.integers(0, P, n).astype(np.int32)
    if n > 4:           # some invalid rows (dest == P, slot is don't-care)
        dest[rng.choice(n, size=n // 6, replace=False)] = P
    return (jnp.asarray(dest), P)


_W3 = (0.25, 0.5, 0.25)


def _case_stencil1d(rng, n, dtype):
    ext = np.zeros(n + len(_W3) - 1, dtype)
    ext[1:1 + n] = _values(rng, n, dtype)
    return (jnp.asarray(ext), _W3)


def _case_stencil1d_exact(rng, n, dtype):
    ext, _ = _case_stencil1d(rng, n, dtype)
    ext_m = np.zeros(n + len(_W3) - 1, dtype)
    ext_m[1:1 + n] = 1
    return (ext, jnp.asarray(ext_m), _W3)


def _case_segment_stencil(rng, n, dtype):
    k = len(_W3)
    center = 1
    ext = np.zeros(n + k - 1, dtype)
    ext[center:center + n] = _values(rng, n, dtype)
    seg = _seg_mask(rng, n)
    sid = np.cumsum(seg) - 1 if n else np.zeros(0, np.int64)
    ext_s = np.full(n + k - 1, -2, np.int32)
    ext_s[center:center + n] = sid
    return (jnp.asarray(ext), jnp.asarray(ext_s), _W3, center, False)


# name -> (case builder, dtypes swept in-process).  A builder may return one
# arg tuple or a list of them (static-arg variants, e.g. rank kinds).
CASES = {
    "prefix_sum":      (_case_prefix_sum, (np.int32, np.float32)),
    "segment_scan":    (_case_segment_scan, (np.int32, np.float32)),
    "segment_rank":    (_case_segment_rank, (np.int32,)),
    "segment_sums":    (_case_segment_sums, (np.float32,)),
    "bucket_scatter":  (_case_bucket_scatter, (np.int32,)),
    "stencil1d":       (_case_stencil1d, (np.float32,)),
    "stencil1d_exact": (_case_stencil1d_exact, (np.float32,)),
    "segment_stencil": (_case_segment_stencil, (np.float32,)),
}

SIZES = (0, 1, 7, 257, 2048, 5000)     # incl. empty + non-block-multiple


def test_every_primitive_has_a_case():
    """Registering a primitive without a parity case fails the sweep."""
    assert set(kreg.names()) == set(CASES)


def _assert_same(a, b):
    """Integer/bool results must match exactly; floats get tolerances sized
    for the backends' different summation orders (the ref scans are cumsum
    differences, the kernels accumulate directly)."""
    a = a if isinstance(a, tuple) else (a,)
    b = b if isinstance(b, tuple) else (b,)
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        ra, rb = np.asarray(ra), np.asarray(rb)
        assert ra.shape == rb.shape
        if np.issubdtype(ra.dtype, np.floating):
            np.testing.assert_allclose(ra, rb, rtol=1e-4, atol=1e-3)
        else:
            np.testing.assert_array_equal(ra, rb)


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("name", sorted(CASES))
def test_parity_ref_vs_interpret(name, n):
    build, dtypes = CASES[name]
    ref = getattr(kreg.resolve("off"), name)
    pal = getattr(kreg.resolve("interpret"), name)
    for dtype in dtypes:
        rng = np.random.default_rng(hash((name, n, np.dtype(dtype).num)) % 2**31)
        variants = build(rng, n, dtype)
        if not isinstance(variants, list):
            variants = [variants]
        for args in variants:
            a, b = ref(*args), pal(*args)
            if name == "bucket_scatter":
                slot_a, cnt_a = a
                slot_b, cnt_b = b
                np.testing.assert_array_equal(np.asarray(cnt_a),
                                              np.asarray(cnt_b))
                dest = np.asarray(args[0])
                valid = dest < args[1]
                np.testing.assert_array_equal(np.asarray(slot_a)[valid],
                                              np.asarray(slot_b)[valid])
            else:
                _assert_same(a, b)


def test_parity_bool_values_via_physical_layer():
    """Bool columns route through int32 casts in the physical layer; pin the
    cumsum/aggregate results rather than raw-kernel bool inputs."""
    from repro.core import physical as phys
    rng = np.random.default_rng(5)
    n = 400
    x = jnp.asarray(rng.random(n) < 0.5)
    keys = (jnp.asarray(np.sort(rng.integers(0, 9, n)).astype(np.int32)),)
    off = kreg.resolve("off")
    itp = kreg.resolve("interpret")
    a = phys.segment_cumsum(x, keys, jnp.int32(n), kernels=off)
    b = phys.segment_cumsum(x, keys, jnp.int32(n), kernels=itp)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_parity_f64_subprocess():
    """float64 sweep needs jax_enable_x64, which is process-global — run the
    scan/sum primitives in a child interpreter."""
    script = textwrap.dedent("""
        import numpy as np
        import jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp
        from repro.kernels import registry as kreg
        rng = np.random.default_rng(11)
        n = 700
        x = jnp.asarray(rng.normal(size=n))          # float64
        assert x.dtype == jnp.float64
        seg = (rng.random(n) < 0.2).astype(np.int32); seg[0] = 1
        off, itp = kreg.resolve("off"), kreg.resolve("interpret")
        a = off.prefix_sum(x); b = itp.prefix_sum(x)
        assert a.dtype == x.dtype
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
        a = off.segment_scan(x, jnp.asarray(seg))
        b = itp.segment_scan(x, jnp.asarray(seg))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
        print("X64_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"stderr:\n{res.stderr[-4000:]}"
    assert "X64_OK" in res.stdout


# ---------------------------------------------------------------------------
# end-to-end: lever flips numerics only
# ---------------------------------------------------------------------------


def _pipeline(n=1200, seed=3):
    rng = np.random.default_rng(seed)
    t = {"k": rng.integers(0, 13, n).astype(np.int32),
         "t": rng.integers(0, 10_000, n).astype(np.int32),
         "x": rng.normal(size=n).astype(np.float32)}
    df = hf.table(t)
    w = df.over("k", order_by="t")
    return (w.cumsum(df.x, out="cs")
             .over("k", order_by="t").rank(out="r")
             .groupby("k").agg(s=("x", "sum"), n="count")
             .sort_values("k"))


def test_e2e_off_vs_interpret_single_device():
    frame = _pipeline()
    a = frame.collect(hf.ExecConfig(use_pallas="off")).to_numpy()
    b = frame.collect(hf.ExecConfig(use_pallas="interpret")).to_numpy()
    assert set(a) == set(b)
    for c in a:
        np.testing.assert_allclose(a[c], b[c], rtol=2e-5, atol=2e-5)


_E2E_BODY = """
    import numpy as np
    rng = np.random.default_rng(3)
    n = 1600
    t = {"k": rng.integers(0, 13, n).astype(np.int32),
         "t": rng.integers(0, 10_000, n).astype(np.int32),
         "x": rng.normal(size=n).astype(np.float32)}
    df = hf.table(t)
    # filter thresholds: a normal mix AND an all-drop predicate, so some
    # shards run the segment kernels over count=0 valid prefixes
    for thresh in (0.0, 1e9):
        frame = (df[df.x > -float(thresh)]
                   .over("k", order_by="t").cumsum(df.x, out="cs")
                   .over("k", order_by="t").rank(out="r")
                   .groupby("k").agg(s=("x", "sum"), n="count")
                   .sort_values("k"))
        outs = {}
        for mode in ("off", "interpret"):
            outs[mode] = frame.collect(hf.ExecConfig(use_pallas=mode)).to_numpy()
        for c in outs["off"]:
            np.testing.assert_allclose(outs["off"][c], outs["interpret"][c],
                                       rtol=2e-5, atol=2e-5)
"""


@pytest.mark.parametrize("devices", [2, 8])
def test_e2e_off_vs_interpret_sharded(devices):
    run_sharded(_E2E_BODY, devices)


# ---------------------------------------------------------------------------
# census gate: planning is backend-oblivious
# ---------------------------------------------------------------------------


def test_census_identical_across_modes():
    frame = _pipeline()
    ref = None
    for mode in kreg.MODES:
        cfg = hf.ExecConfig(use_pallas=mode)
        plan = frame.physical_plan(cfg)
        sig = (plan.counts(), plan.collective_count(),
               plan.shuffle_row_bytes(), plan.shuffle_count())
        if ref is None:
            ref = sig
        assert sig == ref, f"use_pallas={mode!r} changed the plan: {sig} != {ref}"


def test_census_identical_with_repartition_and_stencil():
    rng = np.random.default_rng(9)
    n = 500
    df = hf.table({"k": rng.integers(0, 5, n).astype(np.int32),
                   "x": rng.normal(size=n).astype(np.float32)})
    frame = (df.repartition("k").sort_within_partitions("k")
               .over("k").rolling_mean(df.x, 4, exact=True))
    ref = None
    for mode in kreg.MODES:
        plan = frame.physical_plan(hf.ExecConfig(use_pallas=mode))
        sig = (plan.counts(), plan.collective_count())
        ref = ref or sig
        assert sig == ref


# ---------------------------------------------------------------------------
# the lever itself
# ---------------------------------------------------------------------------


def test_use_kernels_alias(monkeypatch):
    monkeypatch.delenv("HIFRAMES_USE_PALLAS", raising=False)
    assert hf.ExecConfig().use_pallas == "off"
    assert hf.ExecConfig(use_kernels=True).use_pallas == "interpret"
    # explicit use_pallas wins over the alias
    assert hf.ExecConfig(use_kernels=True,
                         use_pallas="compiled").use_pallas == "compiled"


def test_bad_mode_rejected():
    with pytest.raises(ValueError, match="use_pallas"):
        hf.ExecConfig(use_pallas="gpu")
    with pytest.raises(ValueError):
        kreg.resolve("nope")


def test_env_default(monkeypatch):
    monkeypatch.setenv("HIFRAMES_USE_PALLAS", "interpret")
    assert hf.ExecConfig().use_pallas == "interpret"
    monkeypatch.setenv("HIFRAMES_USE_PALLAS", "off")
    assert hf.ExecConfig().use_pallas == "off"


def test_registry_shape():
    ks = kreg.resolve("interpret")
    assert "KernelSet" in repr(ks)
    with pytest.raises(AttributeError, match="no kernel"):
        ks.not_a_kernel
    spec = kreg.get("prefix_sum")
    assert spec.name == "prefix_sum" and callable(spec.ref)
    with pytest.raises(ValueError, match="already registered"):
        kreg.register("prefix_sum", ref=lambda x: x, pallas=lambda x: x)

"""Serving layer tests: plan-cache key correctness, cross-table rebind,
session admission parity, stats sidecar persistence, global ranking, and
on-device resharding (docs/serving.md)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pandas as pd
import pytest

from repro import hiframes as hf
from repro.core import ir
from repro.core import stats as st
from repro.core.api import ExecConfig
from repro.core.errors import StatsError
from repro.runtime.reshard import reshard
from repro.runtime.session import PlanCache, Session, _CacheEntry, \
    cfg_signature

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sharded(body: str, devices: int):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import numpy as np
        import jax
        assert jax.device_count() == {devices}
        from repro import hiframes as hf
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBPROC_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), os.path.join(ROOT, "tests")])
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"stderr:\n{res.stderr[-4000:]}"
    assert "SUBPROC_OK" in res.stdout
    return res.stdout


def _frame(n=160, seed=5):
    rng = np.random.default_rng(seed)
    return {"k": rng.integers(0, 11, n).astype(np.int64),
            "v": rng.normal(size=n).astype(np.float64)}


# -- plan-cache key definition ------------------------------------------------

def test_shape_fingerprint_ignores_table_identity():
    a = hf.table(_frame(seed=1), "a")
    b = hf.table(_frame(seed=2), "b")   # same schema+rows, different data
    qa = a.groupby("k").agg(s=("v", "sum"))
    qb = b.groupby("k").agg(s=("v", "sum"))
    assert st.plan_fingerprint(qa.node, scans="shape") == \
        st.plan_fingerprint(qb.node, scans="shape")
    # the identity mode (stats store keying) keeps them apart
    assert st.plan_fingerprint(qa.node) != st.plan_fingerprint(qb.node)


def test_shape_fingerprint_literal_and_dictionary_miss():
    df = hf.table(_frame(), "t")
    f3 = df[df["k"] > 3]
    f5 = df[df["k"] > 5]
    assert st.plan_fingerprint(f3.node, scans="shape") != \
        st.plan_fingerprint(f5.node, scans="shape")
    # same int32 codes under DIFFERENT dictionaries must not share a key:
    # plan constants are code-space rewrites against the dictionary.
    s1 = hf.table({"c": np.array(["a", "b", "a", "c"], object)}, "s1")
    s2 = hf.table({"c": np.array(["x", "y", "x", "z"], object)}, "s2")
    assert st.plan_fingerprint(s1.node, scans="shape") != \
        st.plan_fingerprint(s2.node, scans="shape")


def test_cfg_signature_levers():
    base = ExecConfig()
    assert cfg_signature(base, 1) == cfg_signature(ExecConfig(), 1)
    assert cfg_signature(base, 1) != cfg_signature(
        ExecConfig(packed_exchange=False), 1)
    assert cfg_signature(base, 1) != cfg_signature(
        ExecConfig(cap_overrides={3: (64, 8)}), 1)
    assert cfg_signature(base, 1) != cfg_signature(base, 2)


def test_plan_cache_lru_eviction():
    pc = PlanCache(capacity=2)
    e = _CacheEntry(lowered=None, scan_ids=(), rebindable=False)
    pc.put("a", e), pc.put("b", e)
    assert pc.get("a") is not None       # refresh a
    pc.put("c", e)                       # evicts b (LRU)
    assert pc.get("b") is None
    assert pc.get("a") is not None and pc.get("c") is not None
    assert pc.evictions == 1


# -- session: cache hits, rebind, fallback ------------------------------------

def test_session_hit_zero_compiles_and_stats():
    with Session(ExecConfig()) as sess:
        sess.register("t", hf.table(_frame(), "t").repartition("k"))
        q = lambda: sess.table("t").groupby("k").agg(s=("v", "sum"))
        t1 = sess.collect(q())
        t2 = sess.collect(q())
        assert t1.query_record.cache == "miss"
        assert t2.query_record.cache == "hit"
        assert t2.query_record.compiles == 0
        stats = sess.stats()
        assert stats["plan_cache"]["hits"] == 1
        assert stats["plan_cache"]["misses"] == 1
        assert stats["queries"] == 2
        assert "HIT" in sess.explain(q())


def test_session_rebind_different_table_returns_its_data():
    f1, f2 = _frame(seed=11), _frame(seed=22)
    with Session(ExecConfig()) as sess:
        sess.register("A", hf.table(f1, "A").repartition("k"))
        sess.register("B", hf.table(f2, "B").repartition("k"))
        la = sess.table("A").node.layout
        lb = sess.table("B").node.layout
        assert la.capacity == lb.capacity      # same persist recipe
        q = lambda t: t.groupby("k").agg(s=("v", "sum"))
        sess.collect(q(sess.table("A")))
        t = sess.collect(q(sess.table("B")))
        assert t.query_record.cache == "hit"
        assert t.query_record.compiles == 0
        got = pd.DataFrame({c: np.asarray(v)
                            for c, v in t.to_numpy().items()})
        got = got.sort_values("k").reset_index(drop=True)
        ref = pd.DataFrame(f2).groupby("k", as_index=False)["v"].sum()
        assert np.allclose(got["s"].values, ref["v"].values)


def test_session_cfg_lever_and_literal_miss():
    with Session(ExecConfig()) as sess:
        sess.register("t", hf.table(_frame(), "t"))
        q = lambda: sess.table("t").groupby("k").agg(s=("v", "sum"))
        sess.collect(q())
        t = sess.collect(q(), ExecConfig(packed_exchange=False))
        assert t.query_record.cache == "miss"
        f = lambda th: sess.table("t")[sess.table("t")["k"] > th] \
            .groupby("k").agg(s=("v", "sum"))
        sess.collect(f(3))
        assert sess.collect(f(5)).query_record.cache == "miss"
        assert sess.collect(f(3)).query_record.cache == "hit"


def test_session_hit_falls_back_on_overflow():
    """A cached plan whose capacities can't fit a bigger rebound table must
    fall back to the miss path (replan), not return truncated rows."""
    small, big = _frame(n=40, seed=1), _frame(n=400, seed=2)
    cfg = ExecConfig(safe_capacities=False, shuffle_slack=1.0,
                     auto_retry=3)
    with Session(cfg) as sess:
        sess.register("S", hf.table(small, "S").repartition("k"))
        q = lambda t: t.groupby("k").agg(s=("v", "sum"))
        sess.collect(q(sess.table("S")))
        # register a table with the same schema but 10x the rows -- persist
        # picks a bigger capacity, so the layout shape differs and the
        # lookup itself misses; parity is what matters.
        sess.register("B", hf.table(big, "B").repartition("k"))
        t = sess.collect(q(sess.table("B")))
        got = pd.DataFrame({c: np.asarray(v)
                            for c, v in t.to_numpy().items()})
        got = got.sort_values("k").reset_index(drop=True)
        ref = pd.DataFrame(big).groupby("k", as_index=False)["v"].sum()
        assert np.allclose(got["s"].values, ref["v"].values)


# -- stats sidecar ------------------------------------------------------------

def test_sidecar_roundtrip(tmp_path):
    d = str(tmp_path)
    cfg = ExecConfig(adaptive_stats=True)
    with Session(cfg, session_dir=d) as sess:
        sess.register("t", hf.table(_frame(), "t"))
        sess.collect(sess.table("t").groupby("k").agg(s=("v", "sum")))
        n_realized = len(sess.store.realized)
    assert os.path.exists(os.path.join(d, "stats.json"))
    assert n_realized > 0
    with Session(cfg, session_dir=d) as s2:
        assert len(s2.store.realized) == n_realized


def test_sidecar_corrupt_raises_and_recovers(tmp_path):
    d = str(tmp_path)
    p = os.path.join(d, "stats.json")
    with open(p, "w") as f:
        f.write('{"version": 1, "realized": {"x": ')   # truncated JSON
    with pytest.raises(StatsError):
        Session(ExecConfig(), session_dir=d)
    with Session(ExecConfig(), session_dir=d, recover_stats=True) as sess:
        assert len(sess.store.realized) == 0
    assert os.path.exists(p + ".corrupt")
    # wrong shape (valid JSON, bad version) also raises
    with open(p, "w") as f:
        f.write('{"version": 99}')
    with pytest.raises(StatsError):
        st.StatsStore.load(p)


def test_sidecar_persists_retry_events(tmp_path):
    d = str(tmp_path)
    store = st.StatsStore()
    from repro.runtime.retry import RetryEvent
    store.events["fp1"] = (RetryEvent("retry", 1, 3, "cap 8 -> 16"),)
    store.realized["fp1"] = {"rows": 10, "max": 4, "mean": 2.5,
                             "nshards": 4}
    p = os.path.join(d, "stats.json")
    store.save(p)
    back = st.StatsStore.load(p)
    assert back.realized == store.realized
    assert back.events["fp1"][0] == store.events["fp1"][0]


# -- global ranking (no partition_by) -----------------------------------------

def test_global_rank_oracle_single_device():
    f = _frame(n=90, seed=8)
    df = hf.table(f, "t")
    s = pd.Series(f["k"])
    for kind, fn, method in [("rank", hf.rank, "min"),
                             ("dense_rank", hf.dense_rank, "dense")]:
        out = fn(df, [], ["k"], out="r").collect()
        got = pd.DataFrame({c: np.asarray(v)
                            for c, v in out.to_numpy().items()})
        got = got.sort_values(["k", "r"]).reset_index(drop=True)
        exp = s.rank(method=method).astype(np.int64)
        ref = pd.DataFrame({"k": s, "r": exp}).sort_values(
            ["k", "r"]).reset_index(drop=True)
        assert (got["r"].values == ref["r"].values).all(), kind
    rn = hf.row_number(df, [], out="rn").collect()
    vals = np.sort(np.asarray(rn.to_numpy()["rn"]))
    assert (vals == np.arange(1, len(f["k"]) + 1)).all()


def test_global_rank_requires_adjacency():
    """Raw-IR users skipping api.rank's sort must get a planner error when
    equal order keys are not adjacent across shards."""
    df = hf.table(_frame(), "t")
    w = ir.Window(df.node, "rank", None, "r", partition_by=(),
                  order_by=("k",))
    with pytest.raises(ValueError, match="adjacent"):
        hf.DataFrame(w).lower(ExecConfig())


def test_global_rank_multidevice_and_desc():
    run_sharded("""
        import pandas as pd
        from repro.core.api import ExecConfig
        rng = np.random.default_rng(4)
        n = 230
        f = {"k": rng.integers(0, 17, n).astype(np.int64),
             "v": rng.normal(size=n)}
        df = hf.table(f, "t")
        s = pd.Series(f["k"])
        for kind, fn, method, asc in [
                ("rank", hf.rank, "min", True),
                ("dense_rank", hf.dense_rank, "dense", True),
                ("rank", hf.rank, "min", False)]:
            out = fn(df, [], ["k"], out="r", ascending=asc).collect()
            got = pd.DataFrame({c: np.asarray(v)
                                for c, v in out.to_numpy().items()})
            got = got.sort_values(["k", "r"]).reset_index(drop=True)
            exp = s.rank(method=method, ascending=asc).astype(np.int64)
            ref = pd.DataFrame({"k": s, "r": exp}).sort_values(
                ["k", "r"]).reset_index(drop=True)
            assert (got["r"].values == ref["r"].values).all(), (kind, asc)
        rn = hf.row_number(df, [], out="rn").collect()
        vals = np.sort(np.asarray(rn.to_numpy()["rn"]))
        assert (vals == np.arange(1, n + 1)).all()
        print("RANKS_OK")
    """, devices=4)


def test_global_rank_census_elides_on_sorted_persist():
    """rank over a persisted globally-sorted table plans 0 exchanges and 0
    sorts: the api-inserted Sort no-ops on the sorted layout."""
    run_sharded("""
        from repro.core.api import ExecConfig
        rng = np.random.default_rng(9)
        f = {"k": rng.integers(0, 9, 120).astype(np.int64),
             "v": rng.normal(size=120)}
        cfg = ExecConfig()
        p = hf.table(f, "t").sort("k").persist(cfg, name="sorted_t")
        lowered = hf.rank(p, [], ["k"], out="r").lower(cfg)
        c = lowered.pplan.counts()
        assert c["hash_exchanges"] == 0, c
        assert c["sample_sorts"] == 0, c
        assert c["local_sorts"] == 0, c
        print("CENSUS_OK")
    """, devices=2)


# -- concurrent admission parity ----------------------------------------------

_PARITY_BODY = """
    import pandas as pd
    from repro.core.api import ExecConfig
    from repro.runtime.session import Session
    rng = np.random.default_rng(2)
    n = 300
    f = {"k": rng.integers(0, 13, n).astype(np.int64),
         "v": rng.normal(size=n)}
    ref = pd.DataFrame(f).groupby("k")["v"].agg(
        ["sum", "count"]).reset_index()
    with Session(ExecConfig(), admission=4, workers=4) as sess:
        sess.register("t", hf.table(f, "t").repartition("k"))
        q = lambda: sess.table("t").groupby("k").agg(
            s=("v", "sum"), c=("v", "count"))
        futs = [sess.submit(q()) for _ in range(6)]
        for fu in futs:
            t = fu.result()
            got = pd.DataFrame({c: np.asarray(v)
                                for c, v in t.to_numpy().items()})
            got = got.sort_values("k").reset_index(drop=True)
            assert np.allclose(got["s"].values, ref["sum"].values)
            assert (got["c"].values == ref["count"].values).all()
        stats = sess.stats()
        assert stats["queries"] == 6
        assert stats["plan_cache"]["hits"] >= 1
    print("PARITY_OK")
"""


@pytest.mark.parametrize("devices", [1, 2, 8])
def test_concurrent_submit_parity(devices):
    run_sharded(_PARITY_BODY, devices=devices)


# -- layout-driven skew salting -----------------------------------------------

def test_layout_skew_lowers_salt_threshold():
    """A registered table whose persisted per-shard counts show hash-key
    skew halves the salting threshold WITHOUT re-sampling (the planner
    consults the ScanLayout counts)."""
    run_sharded("""
        from repro.core.api import ExecConfig
        from repro.core import stats as st
        rng = np.random.default_rng(0)
        n = 4000
        # one hot key -> one shard holds ~half the rows after hash
        k = np.where(rng.random(n) < 0.5, 0,
                     rng.integers(1, 64, n)).astype(np.int64)
        f = {"k": k, "v": rng.normal(size=n)}
        cfg = ExecConfig(adaptive_stats=True)
        p = hf.table(f, "skewed").repartition("k").persist(cfg, name="sk")
        lay = p.node.layout
        occ = lay.counts.max() / max(lay.counts.mean(), 1)
        assert occ >= 2.0, f"fixture not skewed enough: {occ}"
        ctx = st.StatsContext(p.node)
        assert ctx.layout_skewed(p.node, ("k",))
        # an even table does NOT trip it
        e = {"k": np.arange(n).astype(np.int64) % 64,
             "v": rng.normal(size=n)}
        pe = hf.table(e, "even").repartition("k").persist(cfg, name="ev")
        ctx2 = st.StatsContext(pe.node)
        assert not ctx2.layout_skewed(pe.node, ("k",))
        print("SKEW_OK")
    """, devices=4)


# -- resharding ---------------------------------------------------------------

_RESHARD_BODY = """
    import pandas as pd
    from jax.sharding import Mesh
    from repro.core import ir
    from repro.core.api import ExecConfig
    from repro.runtime.reshard import reshard

    calls = {"n": 0}
    orig = ir.ScanLayout.gather_host
    def guard(self, src):
        calls["n"] += 1
        return orig(self, src)
    ir.ScanLayout.gather_host = guard

    rng = np.random.default_rng(6)
    n = 173
    f = {"k": rng.integers(0, 10, n).astype(np.int64),
         "v": rng.normal(size=n)}
    cfg4 = ExecConfig()
    cfg2 = ExecConfig(mesh=Mesh(np.array(jax.devices()[:2]), ("data",)))

    def valid_rows(d):
        lay = d.node.layout
        cols = {c: np.asarray(v) for c, v in d.node.columns.items()}
        keep = np.concatenate([np.arange(r * lay.capacity,
                                         r * lay.capacity + c)
                               for r, c in enumerate(np.asarray(lay.counts))])
        return np.stack([cols["k"][keep], cols["v"][keep]])

    p4 = hf.table(f, "t").repartition("k").sort_within_partitions("k") \\
        .persist(cfg4, name="t4")
    a = valid_rows(p4)

    # merge 4 -> 2, re-establishing the hash claim on the smaller mesh
    r2 = reshard(p4, 2, cfg2)
    l2 = r2.node.layout
    assert l2.device_valid(2)
    assert l2.kind == "hash" and l2.partitioned_by == ("k",), l2
    b = valid_rows(r2)
    assert np.allclose(a[:, np.lexsort(a)], b[:, np.lexsort(b)])

    # split 2 -> 4 and run a query through the re-entered shards
    r4 = reshard(r2, 4, cfg4)
    assert r4.node.layout.device_valid(4)
    t = r4.groupby("k").agg(s=("v", "sum")).collect(cfg4)
    got = pd.DataFrame({c: np.asarray(v) for c, v in t.to_numpy().items()})
    got = got.sort_values("k").reset_index(drop=True)
    ref = pd.DataFrame(f).groupby("k", as_index=False)["v"].sum()
    assert np.allclose(got["s"].values, ref["v"].values)

    # groupby on the re-established hash claim plans 0 exchanges
    lowered = r2.groupby("k").agg(s=("v", "sum")).lower(cfg2)
    assert lowered.pplan.counts()["hash_exchanges"] == 0

    # ordering claims survive an order-preserving reshard
    ps = hf.table(f, "t").sort("k").persist(cfg4, name="ts")
    rs = reshard(ps, 2, cfg2, reestablish=False)
    assert rs.node.layout.sorted_by == ps.node.layout.sorted_by
    assert rs.node.layout.globally_sorted == ps.node.layout.globally_sorted
    d = valid_rows(rs)
    assert (np.diff(d[0]) >= 0).all()

    assert calls["n"] == 0, f"host gather x{calls['n']} during resharding"
    print("RESHARD_OK")
"""


def test_reshard_roundtrip_no_host_gather():
    run_sharded(_RESHARD_BODY, devices=4)


def test_reshard_rejects_host_frames():
    df = hf.table(_frame(), "t")
    with pytest.raises(ValueError, match="persisted"):
        reshard(df, 2)


def test_session_register_reshards_on_P_mismatch():
    run_sharded("""
        from jax.sharding import Mesh
        from repro.core.api import ExecConfig
        from repro.runtime.session import Session
        import pandas as pd
        rng = np.random.default_rng(3)
        f = {"k": rng.integers(0, 8, 140).astype(np.int64),
             "v": rng.normal(size=140)}
        cfg2 = ExecConfig(mesh=Mesh(np.array(jax.devices()[:2]), ("data",)))
        p2 = hf.table(f, "t").repartition("k").persist(cfg2, name="t2")
        assert p2.node.layout.nshards == 2
        with Session(ExecConfig()) as sess:    # 4-device session
            sess.register("t", p2)
            lay = sess.table("t").node.layout
            assert lay.device_valid(4), lay
            t = sess.collect(sess.table("t").groupby("k").agg(
                s=("v", "sum")))
            got = pd.DataFrame({c: np.asarray(v)
                                for c, v in t.to_numpy().items()})
            got = got.sort_values("k").reset_index(drop=True)
            ref = pd.DataFrame(f).groupby("k", as_index=False)["v"].sum()
            assert np.allclose(got["s"].values, ref["v"].values)
        print("REGISTER_RESHARD_OK")
    """, devices=4)


# -- serve smoke entrypoint ---------------------------------------------------

def test_serve_smoke_cli():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--smoke",
         "--scale", "0.01", "--repeats", "1"],
        env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, \
        f"stdout:\n{res.stdout[-2000:]}\nstderr:\n{res.stderr[-2000:]}"
    assert "serve smoke: PASS" in res.stdout

"""Composite (multi-column) key coverage: join / aggregate / sort against the
oracle, the left-join pushdown guard, pruning of key sets, and the
capacity-overflow auto-retry path."""
import numpy as np
import pytest

from repro import hiframes as hf
from repro.core import ir, optimizer
from oracle import o_aggregate, o_join, sorted_cols


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(21)
    n = 1500
    return {
        "k1": rng.integers(0, 7, n).astype(np.int32),
        "k2": rng.integers(0, 11, n).astype(np.int32),
        "kf": (rng.integers(0, 5, n) * 0.5).astype(np.float32),  # float key col
        "x": rng.normal(size=n).astype(np.float32),
        "y": rng.normal(size=n).astype(np.float32),
    }


@pytest.fixture(scope="module")
def dim():
    rng = np.random.default_rng(22)
    m = 120  # duplicate composite keys on the right on purpose
    return {
        "ca": rng.integers(0, 7, m).astype(np.int32),
        "cb": rng.integers(0, 11, m).astype(np.int32),
        "w": rng.normal(size=m).astype(np.float32),
    }


# -- aggregate ----------------------------------------------------------------


def test_composite_aggregate_matches_oracle(data):
    df = hf.table(data)
    out = hf.aggregate(df, by=("k1", "k2"), s=hf.sum_(df["x"]),
                       m=hf.mean(df["x"]), c=hf.count(),
                       mn=hf.min_(df["y"])).collect().to_numpy()
    ref = o_aggregate(data, ("k1", "k2"), {
        "s": ("sum", data["x"]), "m": ("mean", data["x"]),
        "c": ("count", None), "mn": ("min", data["y"])})
    o = np.lexsort((out["k2"], out["k1"]))
    np.testing.assert_array_equal(out["k1"][o], ref["k1"])
    np.testing.assert_array_equal(out["k2"][o], ref["k2"])
    np.testing.assert_allclose(out["s"][o], ref["s"], atol=1e-3)
    np.testing.assert_allclose(out["m"][o], ref["m"], atol=1e-5)
    np.testing.assert_array_equal(out["c"][o], ref["c"])
    np.testing.assert_allclose(out["mn"][o], ref["mn"])


def test_composite_aggregate_mixed_dtype_keys(data):
    """int32 + float32 key columns group correctly together."""
    df = hf.table(data)
    out = hf.aggregate(df, by=("k1", "kf"), s=hf.sum_(df["x"]),
                       c=hf.count()).collect().to_numpy()
    ref = o_aggregate(data, ("k1", "kf"), {"s": ("sum", data["x"]),
                                           "c": ("count", None)})
    o = np.lexsort((out["kf"], out["k1"]))
    np.testing.assert_array_equal(out["k1"][o], ref["k1"])
    np.testing.assert_allclose(out["kf"][o], ref["kf"])
    np.testing.assert_allclose(out["s"][o], ref["s"], atol=1e-3)
    np.testing.assert_array_equal(out["c"][o], ref["c"])


def test_composite_aggregate_list_by_and_counts_conserved(data):
    df = hf.table(data)
    out = hf.aggregate(df, by=["k1", "k2"], c=hf.count()).collect().to_numpy()
    assert out["c"].sum() == len(data["k1"])


# -- join ---------------------------------------------------------------------


def test_composite_join_matches_oracle(data, dim):
    """2-column key, duplicate keys on both sides."""
    out = hf.join(hf.table(data), hf.table(dim, "d"),
                  on=[("k1", "ca"), ("k2", "cb")]).collect().to_numpy()
    ref = o_join(data, dim, ("k1", "k2"), ("ca", "cb"))
    assert len(out["k1"]) == len(ref["k1"])
    a = sorted_cols(out, ("k1", "k2", "x", "w"))
    b = sorted_cols(ref, ("k1", "k2", "x", "w"))
    for k in b:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-6)


def test_composite_join_shared_names(data):
    """on=[names] joins columns of the same name on both sides."""
    rng = np.random.default_rng(23)
    right = {"k1": rng.integers(0, 7, 60).astype(np.int32),
             "k2": rng.integers(0, 11, 60).astype(np.int32),
             "w": rng.normal(size=60).astype(np.float32)}
    out = hf.join(hf.table(data), hf.table(right, "r"),
                  on=["k1", "k2"]).collect().to_numpy()
    ref = o_join(data, right, ("k1", "k2"), ("k1", "k2"))
    assert len(out["k1"]) == len(ref["k1"])
    a = sorted_cols(out, ("k1", "k2", "x", "w"))
    b = sorted_cols(ref, ("k1", "k2", "x", "w"))
    for k in b:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-6)


def test_composite_join_mixed_dtype_keys(data):
    rng = np.random.default_rng(24)
    right = {"ca": rng.integers(0, 7, 50).astype(np.int32),
             "cf": (rng.integers(0, 5, 50) * 0.5).astype(np.float32),
             "w": rng.normal(size=50).astype(np.float32)}
    out = hf.join(hf.table(data), hf.table(right, "r"),
                  on=[("k1", "ca"), ("kf", "cf")]).collect().to_numpy()
    ref = o_join(data, right, ("k1", "kf"), ("ca", "cf"))
    assert len(out["k1"]) == len(ref["k1"])
    a = sorted_cols(out, ("k1", "kf", "x", "w"))
    b = sorted_cols(ref, ("k1", "kf", "x", "w"))
    for k in b:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-6)


def test_composite_left_join_matches_oracle(data):
    """Left-outer with a 2-column key: unmatched rows kept, zero-filled."""
    right = {"ca": np.array([0, 1, 2], np.int32),
             "cb": np.array([0, 1, 2], np.int32),
             "w": np.array([1.0, 2.0, 3.0], np.float32)}
    out = hf.join(hf.table(data), hf.table(right, "r"),
                  on=[("k1", "ca"), ("k2", "cb")], how="left") \
        .collect().to_numpy()
    ref = o_join(data, right, ("k1", "k2"), ("ca", "cb"), how="left")
    assert len(out["k1"]) == len(ref["k1"])
    a = sorted_cols(out, ("k1", "k2", "x", "w", "_matched"))
    b = sorted_cols(ref, ("k1", "k2", "x", "w", "_matched"))
    for k in b:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-6)


def test_single_pair_on_still_means_one_key(data):
    """Back-compat: on=("a","b") is ONE key pair, not two key columns."""
    right = {"cid": np.arange(7, dtype=np.int32),
             "w": np.arange(7, dtype=np.float32)}
    j = hf.join(hf.table(data), hf.table(right, "r"), on=("k1", "cid"))
    assert j.node.left_on == ("k1",) and j.node.right_on == ("cid",)
    out = j.collect().to_numpy()
    assert len(out["k1"]) == len(data["k1"])   # every k1 in 0..6 matches once


# -- sort ---------------------------------------------------------------------


def test_composite_sort_matches_lexsort(data):
    out = hf.table(data).sort(by=("k1", "k2")).collect().to_numpy()
    order = np.lexsort((data["k2"], data["k1"]))
    np.testing.assert_array_equal(out["k1"], data["k1"][order])
    np.testing.assert_array_equal(out["k2"], data["k2"][order])


def test_composite_sort_descending(data):
    out = hf.table(data).sort(by=("k1", "k2"), ascending=False) \
        .collect().to_numpy()
    order = np.lexsort((data["k2"], data["k1"]))[::-1]
    np.testing.assert_array_equal(out["k1"], data["k1"][order])
    np.testing.assert_array_equal(out["k2"], data["k2"][order])


# -- optimizer: pushdown guard + key-set pruning ------------------------------


def _left_join_frames():
    rng = np.random.default_rng(25)
    n = 400
    left = {"id": rng.integers(0, 30, n).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32)}
    right = {"cid": np.arange(0, 30, 2, dtype=np.int32),
             "w": rng.normal(size=15).astype(np.float32)}
    return left, right


def test_left_join_blocks_right_pushdown():
    """Regression: a right-side predicate must NOT move below how="left"."""
    left, right = _left_join_frames()
    j = hf.join(hf.table(left, "l"), hf.table(right, "r"), on=("id", "cid"),
                how="left")
    f = j[j["w"] > 0.0]
    new_root, n = optimizer.push_predicates(f.node)
    assert n == 0
    assert isinstance(new_root, ir.Filter)     # filter stays above the join


def test_left_join_pushdown_guard_end_to_end():
    """Optimized output == unoptimized == oracle for filter-over-left-join."""
    left, right = _left_join_frames()
    j = hf.join(hf.table(left, "l"), hf.table(right, "r"), on=("id", "cid"),
                how="left")
    f = j[j["w"] > 0.0]
    opt = f.collect(hf.ExecConfig(optimize_plan=True)).to_numpy()
    raw = f.collect(hf.ExecConfig(optimize_plan=False)).to_numpy()
    ref = o_join(left, right, "id", "cid", how="left")
    keep = ref["w"] > 0.0
    ref = {k: v[keep] for k, v in ref.items()}
    assert len(opt["id"]) == len(ref["id"])
    for got in (opt, raw):
        a = sorted_cols(got, ("id", "x", "w"))
        b = sorted_cols(ref, ("id", "x", "w"))
        for k in b:
            np.testing.assert_allclose(a[k], b[k], rtol=1e-6)


def test_left_join_still_pushes_left_side_predicates():
    """Left-column predicates commute with how="left" and still push."""
    left, right = _left_join_frames()
    j = hf.join(hf.table(left, "l"), hf.table(right, "r"), on=("id", "cid"),
                how="left")
    f = j[j["x"] > 0.0]
    new_root, n = optimizer.push_predicates(f.node)
    assert n == 1
    assert isinstance(new_root, ir.Join)
    assert isinstance(new_root.left, ir.Filter)


def test_composite_pushdown_same_rewrites_as_single_key(data, dim):
    """Pushdown + pruning fire identically for 1-key and 2-key joins."""
    right1 = {"ca": dim["ca"], "w": dim["w"]}
    j1 = hf.join(hf.table(data), hf.table(right1, "d1"), on=("k1", "ca"))
    f1 = j1[j1["w"] > 0.0]
    _, stats1 = optimizer.optimize(f1.node, keep={"k1", "w"})

    j2 = hf.join(hf.table(data), hf.table(dim, "d2"),
                 on=[("k1", "ca"), ("k2", "cb")])
    f2 = j2[j2["w"] > 0.0]
    _, stats2 = optimizer.optimize(f2.node, keep={"k1", "k2", "w"})

    assert stats1["pushdown"] == stats2["pushdown"] == 1
    assert stats1["pruned_columns"] > 0 and stats2["pruned_columns"] > 0


def test_composite_pushdown_right_side_rewrites_keys(data, dim):
    """A unified-key predicate maps left key names -> right key names."""
    j = hf.join(hf.table(data), hf.table(dim, "d"),
                on=[("k1", "ca"), ("k2", "cb")])
    f = j[(j["w"] > 0.0)]
    new_root, n = optimizer.push_predicates(f.node)
    assert n == 1
    assert isinstance(new_root.right, ir.Filter)
    assert {c for (_t, c) in new_root.right.pred.columns()} == {"w"}


def test_composite_pruning_keeps_all_key_columns(data, dim):
    j = hf.join(hf.table(data), hf.table(dim, "d"),
                on=[("k1", "ca"), ("k2", "cb")])
    pruned, _ = optimizer.prune_columns(j.node, keep={"w"})
    scans = {s.name: s for s in ir.topo_order(pruned) if isinstance(s, ir.Scan)}
    assert {"k1", "k2"} <= set(scans["t"].columns)
    assert {"ca", "cb"} <= set(scans["d"].columns)
    assert "x" not in scans["t"].columns       # non-key, non-kept: pruned


def test_explain_composite_shows_pushdown(data, dim):
    j = hf.join(hf.table(data), hf.table(dim, "d"),
                on=[("k1", "ca"), ("k2", "cb")])
    f = j[j["w"] > 0.0]
    plan = f.explain()
    lines = plan.splitlines()
    jline = next(i for i, l in enumerate(lines) if "Join" in l)
    assert "k1==ca" in lines[jline] and "k2==cb" in lines[jline]
    # the filter was pushed BELOW the join (appears after it, indented)
    assert any("Filter" in l for l in lines[jline + 1:])
    assert not any("Filter" in l for l in lines[:jline])


# -- auto-retry / overflow path ----------------------------------------------


def test_composite_join_auto_retry_recovers():
    """Undersized capacity plan overflows, auto-retry doubles and succeeds."""
    rng = np.random.default_rng(26)
    n = 300
    left = {"a": rng.integers(0, 3, n).astype(np.int32),
            "b": rng.integers(0, 2, n).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32)}
    right = {"ca": rng.integers(0, 3, 60).astype(np.int32),
             "cb": rng.integers(0, 2, 60).astype(np.int32),
             "w": rng.normal(size=60).astype(np.float32)}
    cfg = hf.ExecConfig(safe_capacities=False, shuffle_slack=1.0,
                        join_expansion=1.0, auto_retry=8)
    out = hf.join(hf.table(left, "l"), hf.table(right, "r"),
                  on=[("a", "ca"), ("b", "cb")]).collect(cfg)
    assert not out.overflow
    ref = o_join(left, right, ("a", "b"), ("ca", "cb"))
    assert out.num_rows() == len(ref["a"])


def test_collect_negative_auto_retry_binds_result(data):
    """Regression: auto_retry < 0 must still run once and return a table."""
    df = hf.table(data)
    cfg = hf.ExecConfig(auto_retry=-1)
    out = df[df["x"] > 0.0].collect(cfg)
    assert out.num_rows() == int((data["x"] > 0.0).sum())


def test_negative_auto_retry_reports_overflow():
    """auto_retry=-3: no retries; an overflowing plan returns flagged."""
    n = 200
    ones = {"k": np.zeros(n, np.int32), "b": np.zeros(n, np.int32),
            "v": np.arange(n, dtype=np.float32)}
    cfg = hf.ExecConfig(safe_capacities=False, shuffle_slack=1.0,
                        join_expansion=1.0, auto_retry=-3)
    out = hf.join(hf.table(ones, "a"), hf.table(ones, "b2"),
                  on=[("k", "k"), ("b", "b")]).collect(cfg)
    assert out.overflow

"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles,
plus slow python-loop oracles for the fused segment kernels (the registry
parity sweep in test_kernel_registry.py compares backends against each other;
these pin both against first-principles loops)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.hash_partition import ops as hp_ops, ref as hp_ref
from repro.kernels.segment_rank import ops as rk_ops, ref as rk_ref
from repro.kernels.segment_reduce import ops as sr_ops, ref as sr_ref
from repro.kernels.segment_scan import ops as ss_ops, ref as ss_ref
from repro.kernels.stencil1d import ops as st_ops, ref as st_ref
from repro.kernels.stream_compact import ops as sc_ops, ref as sc_ref

RNG = np.random.default_rng(11)


@pytest.mark.parametrize("n", [1, 7, 100, 2048, 5000])
@pytest.mark.parametrize("K", [1, 3, 5, 7])
def test_stencil_shapes(n, K):
    ext = RNG.normal(size=n + K - 1).astype(np.float32)
    w = RNG.normal(size=K).tolist()
    got = np.asarray(st_ops.stencil1d(jnp.asarray(ext), w))
    ref = np.asarray(st_ref.stencil1d_ref(jnp.asarray(ext), w))
    np.testing.assert_allclose(got, ref, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
@pytest.mark.parametrize("n", [1, 100, 2048, 4096, 9999])
def test_prefix_sum_shapes(n, dtype):
    if dtype == np.int32:
        x = RNG.integers(-5, 5, n).astype(dtype)
    else:
        x = RNG.normal(size=n).astype(dtype)
    got = np.asarray(sc_ops.prefix_sum(jnp.asarray(x)))
    ref = np.cumsum(x).astype(dtype)
    if dtype == np.int32:
        np.testing.assert_array_equal(got, ref)
    else:
        np.testing.assert_allclose(got, ref, atol=1e-3)


@pytest.mark.parametrize("n,cap", [(100, 60), (100, 200), (2048, 1024)])
def test_compact(n, cap):
    vals = RNG.normal(size=n).astype(np.float32)
    keep = RNG.random(n) < 0.5
    got, cnt = sc_ops.compact(jnp.asarray(vals), jnp.asarray(keep), cap)
    ref, rcnt = sc_ref.compact_ref(jnp.asarray(vals), jnp.asarray(keep), cap)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert int(cnt) == int(rcnt)


@pytest.mark.parametrize("n,nseg", [(50, 5), (2000, 37), (4096, 200), (5000, 1)])
def test_segment_sums(n, nseg):
    rng = np.random.default_rng(n * 31 + nseg)   # deterministic per-case
    seg = np.sort(rng.integers(0, nseg, n)).astype(np.int32)
    vals = rng.normal(size=n).astype(np.float32)
    valid = np.arange(n) < (n - n // 10)
    # contract: seg ids are consecutive 0..k-1 over the VALID prefix (this is
    # how the aggregate lowering constructs them); invalid rows repeat the
    # last valid id so the array stays sorted.
    _, seg = np.unique(seg[valid], return_inverse=True)
    k = int(seg.max()) + 1 if len(seg) else 1
    seg2 = np.concatenate([seg, np.full(n - valid.sum(), seg[-1] if len(seg)
                                        else 0)]).astype(np.int32)
    got = np.asarray(sr_ops.segment_sums(jnp.asarray(vals), jnp.asarray(seg2),
                                         jnp.asarray(valid), k))
    ref = np.asarray(sr_ref.segment_sums_ref(jnp.asarray(vals), jnp.asarray(seg2),
                                             jnp.asarray(valid), k))
    np.testing.assert_allclose(got, ref, atol=2e-3)


@pytest.mark.parametrize("P", [2, 8, 64, 256])
@pytest.mark.parametrize("n", [10, 1000, 3000])
def test_bucket_ranks(P, n):
    d = RNG.integers(0, P + 1, n).astype(np.int32)   # P marks invalid
    r1, c1 = hp_ops.bucket_ranks(jnp.asarray(d), P)
    r2, c2 = hp_ref.bucket_ranks_ref(jnp.asarray(d), P)
    m = d < P
    np.testing.assert_array_equal(np.asarray(r1)[m], np.asarray(r2)[m])
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_bucket_ranks_are_stable_slots():
    """ranks must be a stable enumeration within each bucket."""
    d = np.array([1, 0, 1, 1, 0, 2, 1], np.int32)
    r, c = hp_ops.bucket_ranks(jnp.asarray(d), 3)
    r = np.asarray(r)
    np.testing.assert_array_equal(r, [0, 0, 1, 2, 1, 0, 3])
    np.testing.assert_array_equal(np.asarray(c), [2, 4, 1])


def test_bucket_ranks_argsort_matches_kernel():
    """The registry's ref backend (stable-argsort slots) must agree with the
    Pallas histogram kernel — it backs the exchange in use_pallas='off'."""
    d = RNG.integers(0, 9, 4000).astype(np.int32)   # 8 buckets + invalid
    r1, c1 = hp_ref.bucket_ranks_argsort(jnp.asarray(d), 8)
    r2, c2 = hp_ops.bucket_ranks(jnp.asarray(d), 8)
    m = d < 8
    np.testing.assert_array_equal(np.asarray(r1)[m], np.asarray(r2)[m])
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


# -- fused segment kernels vs python-loop oracles ------------------------------


def _loop_segment_scan(x, b):
    out, run = np.zeros_like(x), x.dtype.type(0)
    for i, (v, f) in enumerate(zip(x, b)):
        run = v if f else run + v
        out[i] = run
    return out


@pytest.mark.parametrize("n", [1, 7, 100, 2048, 6000])
def test_segment_scan_vs_loop(n):
    rng = np.random.default_rng(n)
    x = rng.integers(-40, 40, n).astype(np.int32)
    b = (rng.random(n) < 0.1).astype(np.int32)
    b[0] = 1
    want = _loop_segment_scan(x, b)
    got = np.asarray(ss_ops.segment_scan(jnp.asarray(x), jnp.asarray(b)))
    ref = np.asarray(ss_ref.segment_scan_ref(jnp.asarray(x), jnp.asarray(b)))
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(ref, want)


def _loop_segment_rank(seg_b, ord_b, kind):
    n = len(seg_b)
    out = np.zeros(n, np.int32)
    rn = dr = mx = 0
    for i in range(n):
        if seg_b[i]:
            rn = dr = mx = 0
        rn += 1
        if ord_b[i]:
            dr += 1
            mx = rn
        out[i] = {"row_number": rn, "dense_rank": dr, "rank": mx}[kind]
    return out


@pytest.mark.parametrize("kind", ["rank", "dense_rank", "row_number"])
@pytest.mark.parametrize("n", [1, 9, 333, 2048, 4100])
def test_segment_rank_vs_loop(kind, n):
    rng = np.random.default_rng(n * 7 + len(kind))
    seg = (rng.random(n) < 0.08).astype(np.int32)
    seg[0] = 1
    ordb = np.maximum(seg, (rng.random(n) < 0.35).astype(np.int32))
    want = _loop_segment_rank(seg, ordb, kind)
    got = np.asarray(rk_ops.segment_rank(jnp.asarray(seg), jnp.asarray(ordb),
                                         kind))
    ref = np.asarray(rk_ref.segment_rank_ref(jnp.asarray(seg),
                                             jnp.asarray(ordb), kind))
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(ref, want)


@pytest.mark.parametrize("n,K,center", [(50, 3, 1), (500, 5, 4), (2048, 4, 0),
                                        (3000, 7, 3)])
def test_stencil1d_exact_vs_loop(n, K, center):
    rng = np.random.default_rng(n + K)
    w = rng.random(K).astype(np.float32) + 0.1
    ext = np.zeros(n + K - 1, np.float32)
    ext_m = np.zeros(n + K - 1, np.float32)
    ext[center:center + n] = rng.normal(size=n).astype(np.float32)
    ext_m[center:center + n] = 1.0
    want = np.zeros(n, np.float64)
    total = float(np.float32(np.sum([float(x) for x in w])))
    for i in range(n):
        acc = sum(float(w[j]) * float(ext[i + j]) for j in range(K))
        mass = sum(float(w[j]) * float(ext_m[i + j]) for j in range(K))
        want[i] = acc * total / mass if mass else 0.0
    wl = [float(x) for x in w]
    got = np.asarray(st_ops.stencil1d_exact(jnp.asarray(ext),
                                            jnp.asarray(ext_m), wl))
    ref = np.asarray(st_ref.stencil1d_exact_ref(jnp.asarray(ext),
                                                jnp.asarray(ext_m), wl))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ref, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("exact", [False, True])
@pytest.mark.parametrize("n", [40, 700, 2500])
def test_segment_stencil_vs_loop(n, exact):
    K, center = 3, 1
    rng = np.random.default_rng(n + exact)
    w = [0.25, 0.5, 0.25]
    seg = (rng.random(n) < 0.1).astype(np.int32)
    seg[0] = 1
    sid = np.cumsum(seg) - 1
    x = rng.normal(size=n).astype(np.float32)
    ext = np.zeros(n + K - 1, np.float32)
    ext[center:center + n] = x
    ext_s = np.full(n + K - 1, -2, np.int32)
    ext_s[center:center + n] = sid
    want = np.zeros(n, np.float64)
    total = float(np.float32(sum(w)))
    for i in range(n):
        acc = mass = 0.0
        for j in range(K):
            p = i + j - center
            if 0 <= p < n and sid[p] == sid[i]:
                acc += w[j] * float(x[p])
                mass += w[j]
        want[i] = (acc * total / mass if mass else 0.0) if exact else acc
    got = np.asarray(st_ops.segment_stencil(jnp.asarray(ext),
                                            jnp.asarray(ext_s), w, center,
                                            exact))
    ref = np.asarray(st_ref.segment_stencil_ref(jnp.asarray(ext),
                                                jnp.asarray(ext_s), w, center,
                                                exact))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ref, want, rtol=1e-4, atol=1e-4)

"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.hash_partition import ops as hp_ops, ref as hp_ref
from repro.kernels.segment_reduce import ops as sr_ops, ref as sr_ref
from repro.kernels.stencil1d import ops as st_ops, ref as st_ref
from repro.kernels.stream_compact import ops as sc_ops, ref as sc_ref

RNG = np.random.default_rng(11)


@pytest.mark.parametrize("n", [1, 7, 100, 2048, 5000])
@pytest.mark.parametrize("K", [1, 3, 5, 7])
def test_stencil_shapes(n, K):
    ext = RNG.normal(size=n + K - 1).astype(np.float32)
    w = RNG.normal(size=K).tolist()
    got = np.asarray(st_ops.stencil1d(jnp.asarray(ext), w))
    ref = np.asarray(st_ref.stencil1d_ref(jnp.asarray(ext), w))
    np.testing.assert_allclose(got, ref, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
@pytest.mark.parametrize("n", [1, 100, 2048, 4096, 9999])
def test_prefix_sum_shapes(n, dtype):
    if dtype == np.int32:
        x = RNG.integers(-5, 5, n).astype(dtype)
    else:
        x = RNG.normal(size=n).astype(dtype)
    got = np.asarray(sc_ops.prefix_sum(jnp.asarray(x)))
    ref = np.cumsum(x).astype(dtype)
    if dtype == np.int32:
        np.testing.assert_array_equal(got, ref)
    else:
        np.testing.assert_allclose(got, ref, atol=1e-3)


@pytest.mark.parametrize("n,cap", [(100, 60), (100, 200), (2048, 1024)])
def test_compact(n, cap):
    vals = RNG.normal(size=n).astype(np.float32)
    keep = RNG.random(n) < 0.5
    got, cnt = sc_ops.compact(jnp.asarray(vals), jnp.asarray(keep), cap)
    ref, rcnt = sc_ref.compact_ref(jnp.asarray(vals), jnp.asarray(keep), cap)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert int(cnt) == int(rcnt)


@pytest.mark.parametrize("n,nseg", [(50, 5), (2000, 37), (4096, 200), (5000, 1)])
def test_segment_sums(n, nseg):
    rng = np.random.default_rng(n * 31 + nseg)   # deterministic per-case
    seg = np.sort(rng.integers(0, nseg, n)).astype(np.int32)
    vals = rng.normal(size=n).astype(np.float32)
    valid = np.arange(n) < (n - n // 10)
    # contract: seg ids are consecutive 0..k-1 over the VALID prefix (this is
    # how the aggregate lowering constructs them); invalid rows repeat the
    # last valid id so the array stays sorted.
    _, seg = np.unique(seg[valid], return_inverse=True)
    k = int(seg.max()) + 1 if len(seg) else 1
    seg2 = np.concatenate([seg, np.full(n - valid.sum(), seg[-1] if len(seg)
                                        else 0)]).astype(np.int32)
    got = np.asarray(sr_ops.segment_sums(jnp.asarray(vals), jnp.asarray(seg2),
                                         jnp.asarray(valid), k))
    ref = np.asarray(sr_ref.segment_sums_ref(jnp.asarray(vals), jnp.asarray(seg2),
                                             jnp.asarray(valid), k))
    np.testing.assert_allclose(got, ref, atol=2e-3)


@pytest.mark.parametrize("P", [2, 8, 64, 256])
@pytest.mark.parametrize("n", [10, 1000, 3000])
def test_bucket_ranks(P, n):
    d = RNG.integers(0, P + 1, n).astype(np.int32)   # P marks invalid
    r1, c1 = hp_ops.bucket_ranks(jnp.asarray(d), P)
    r2, c2 = hp_ref.bucket_ranks_ref(jnp.asarray(d), P)
    m = d < P
    np.testing.assert_array_equal(np.asarray(r1)[m], np.asarray(r2)[m])
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_bucket_ranks_are_stable_slots():
    """ranks must be a stable enumeration within each bucket."""
    d = np.array([1, 0, 1, 1, 0, 2, 1], np.int32)
    r, c = hp_ops.bucket_ranks(jnp.asarray(d), 3)
    r = np.asarray(r)
    np.testing.assert_array_equal(r, [0, 0, 1, 2, 1, 0, 3])
    np.testing.assert_array_equal(np.asarray(c), [2, 4, 1])

"""Shape/dtype sweep for the fused decode-attention kernel vs its oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import ops as da_ops, ref as da_ref

RNG = np.random.default_rng(21)


@pytest.mark.parametrize("b,s,hkv,g,hd", [
    (1, 128, 2, 2, 32),
    (2, 512, 2, 4, 64),
    (4, 1024, 8, 7, 64),     # yi-style grouping
    (2, 700, 4, 1, 32),      # MHA, non-multiple-of-block S
])
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_decode_attention_matches_ref(b, s, hkv, g, hd, dtype):
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    q = jnp.asarray(RNG.normal(size=(b, hkv, g, hd)), dt)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, hd)), dt)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, hd)), dt)
    length = jnp.asarray(RNG.integers(1, s + 1, b).astype(np.int32))
    got = np.asarray(da_ops.decode_attention(q, k, v, length), np.float32)
    ref = np.asarray(da_ref.decode_attention_ref(q, k, v, length), np.float32)
    atol = 5e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(got, ref, atol=atol)


def test_decode_attention_full_vs_masked_length():
    """length == S must equal an unmasked softmax attention."""
    b, s, hkv, g, hd = 2, 256, 2, 2, 32
    q = jnp.asarray(RNG.normal(size=(b, hkv, g, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, hd)), jnp.float32)
    full = jnp.full((b,), s, jnp.int32)
    got = np.asarray(da_ops.decode_attention(q, k, v, full))
    # dense oracle without masking
    sc = np.einsum("bhgd,bshd->bhgs", np.asarray(q), np.asarray(k)) / np.sqrt(hd)
    p = np.exp(sc - sc.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    ref = np.einsum("bhgs,bshd->bhgd", p, np.asarray(v))
    np.testing.assert_allclose(got, ref, atol=2e-5)


def test_integrated_decode_path_matches_standard():
    """attn_decode_kernel=True must reproduce the standard decode path."""
    import jax
    from repro.configs import get_reduced
    from repro.models import lm

    cfg = get_reduced("qwen3-0.6b")
    key = jax.random.PRNGKey(3)
    params = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 6), 0, cfg.vocab)

    def decode_seq(c):
        cache = lm.init_cache(c, 2, 8)
        outs = []
        for i in range(6):
            lg, cache = lm.decode_step(params, toks[:, i:i + 1], cache, c)
            outs.append(np.asarray(lg, np.float32))
        return np.stack(outs, 1)

    base = decode_seq(cfg)
    fused = decode_seq(cfg.replace(attn_decode_kernel=True))
    np.testing.assert_allclose(base, fused, atol=0.1)   # bf16 path tolerance

"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; multi-device tests spawn subprocesses (tests/test_distributed.py).
"""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_table(rng, n, n_keys=37):
    return {
        "id": rng.integers(0, n_keys, n).astype(np.int32),
        "x": rng.normal(size=n).astype(np.float32),
        "y": rng.normal(size=n).astype(np.float32),
    }

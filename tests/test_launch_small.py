"""Launch-layer tests at CI scale: lower+compile reduced cells on a small
fake mesh (subprocess; 16 host devices, (4,4) data x model) — the same code
path the 512-chip dry-run uses, so sharding-spec regressions fail fast here.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run16(body: str):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import numpy as np
        import jax
        from jax.sharding import Mesh
        mesh = Mesh(np.array(jax.devices()).reshape(4, 4), ("data", "model"))
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBPROC_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"stderr:\n{res.stderr[-4000:]}"
    assert "SUBPROC_OK" in res.stdout


CELL_BODY = """
from repro import configs
from repro.configs import ShapeSpec
from repro.launch import steps as S
from repro.models import sharding as shmod
from repro.optim import OptConfig
from jax.sharding import NamedSharding, PartitionSpec as P

cfg = configs.get_reduced("{arch}")
shape = ShapeSpec("t", "{kind}", {seq}, {batch})
ocfg = OptConfig()
cell = S.cell_shardings(cfg, shape, mesh, ocfg)
rep = NamedSharding(mesh, P())
if shape.kind == "train":
    fn = S.make_train_step(cfg, ocfg, n_micro=2)
    state_specs = {{"params": cell["param_specs"], "opt": cell["opt_specs"]}}
    state_sh = {{"params": cell["params"], "opt": cell["opt_sh"]}}
    lowered = jax.jit(fn, in_shardings=(state_sh, cell["input_sh"]),
                      out_shardings=(state_sh, rep)).lower(
        state_specs, cell["inputs"])
elif shape.kind == "prefill":
    fn = S.make_prefill_step(cfg, shape.seq)
    csh = shmod.cache_shardings(mesh, S.cache_specs(cfg, shape))
    lsh = NamedSharding(mesh, shmod.fit_spec(
        mesh, (shape.batch, cfg.vocab), (shmod.dp_axes(mesh), "model")))
    lowered = jax.jit(fn, in_shardings=(cell["params"], cell["input_sh"]),
                      out_shardings=(lsh, csh)).lower(
        cell["param_specs"], cell["inputs"])
else:
    fn = S.make_decode_step(cfg)
    csh = cell["cache_sh"]
    lsh = NamedSharding(mesh, shmod.fit_spec(
        mesh, (shape.batch, cfg.vocab), (shmod.dp_axes(mesh), "model")))
    lowered = jax.jit(fn, in_shardings=(cell["params"],
                                        cell["input_sh"]["token"], csh),
                      out_shardings=(lsh, csh)).lower(
        cell["param_specs"], cell["inputs"]["token"], cell["cache_specs"])
compiled = lowered.compile()
assert compiled.cost_analysis() is not None
assert "SUBPROC" not in ""  # noqa
"""


@pytest.mark.parametrize("arch,kind,seq,batch", [
    ("qwen3-0.6b", "train", 64, 8),
    ("qwen2-vl-2b", "train", 64, 8),
    ("deepseek-moe-16b", "train", 64, 8),
    ("zamba2-7b", "train", 64, 8),
    ("falcon-mamba-7b", "train", 64, 8),
    ("whisper-base", "train", 64, 8),
    ("qwen3-0.6b", "prefill", 128, 8),
    ("qwen3-0.6b", "decode", 128, 8),
    ("falcon-mamba-7b", "decode", 128, 8),
    ("zamba2-7b", "decode", 128, 8),
])
def test_cell_lowers_on_small_mesh(arch, kind, seq, batch):
    run16(CELL_BODY.format(arch=arch, kind=kind, seq=seq, batch=batch))


def test_moe_ep_impl_lowers():
    run16("""
        from repro import configs
        from repro.configs import ShapeSpec
        from repro.launch import steps as S
        from repro.models import moe as moe_mod
        from repro.optim import OptConfig
        from jax.sharding import NamedSharding, PartitionSpec as P
        moe_mod.set_ep_mesh(mesh)
        cfg = configs.get_reduced("deepseek-moe-16b").replace(moe_impl="ep")
        shape = ShapeSpec("t", "train", 64, 8)
        ocfg = OptConfig()
        cell = S.cell_shardings(cfg, shape, mesh, ocfg)
        fn = S.make_train_step(cfg, ocfg)
        state_specs = {"params": cell["param_specs"], "opt": cell["opt_specs"]}
        state_sh = {"params": cell["params"], "opt": cell["opt_sh"]}
        lowered = jax.jit(fn, in_shardings=(state_sh, cell["input_sh"]),
                          out_shardings=(state_sh, NamedSharding(mesh, P()))
                          ).lower(state_specs, cell["inputs"])
        lowered.compile()
    """)

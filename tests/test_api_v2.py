"""Fluent API v2 — pandas-parity oracle suite + persist/cache census gates.

Two layers:

* PARITY: every fluent verb chain is cross-checked against real pandas
  (importorskip) on randomized frames — in-process at 1 shard and through
  ``run_sharded`` subprocesses at 2 and 8 shards, so the collective paths
  (hash exchange, sample sort, exscan) are exercised, not just the P=1
  shortcuts.  Rows are compared as SETS keyed on the group/join keys:
  distributed outputs come back in shard order, not pandas order.

* CENSUS: ``persist()`` materializes a frame WITH its layout, and the plan
  census pins the paper-level guarantee — ``persist -> groupby(same key)``
  and ``persist -> merge(on=persisted keys)`` plan 0 hash exchanges and 0
  inserted sorts, ``persist(sorted) -> sort`` plans a full no-op, and the
  ``elide_exchanges=False`` baseline lever restores the exchanges.
"""
import numpy as np
import pytest

from repro import hiframes as hf
from repro.core import ir
from repro.core import physical_plan as pp
from repro.core.expr import AggExpr
from test_physical_plan import run_sharded

pd = pytest.importorskip("pandas")


def _frame(n=600, seed=7):
    rng = np.random.default_rng(seed)
    return {"k1": rng.integers(0, 8, n).astype(np.int32),
            "k2": rng.integers(0, 5, n).astype(np.int32),
            "t": rng.permutation(n).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32),
            "y": rng.normal(size=n).astype(np.float32),
            "b": rng.integers(0, 2, n) > 0}


def _dim(m=40, seed=8):
    rng = np.random.default_rng(seed)
    return {"ck": rng.permutation(m).astype(np.int32)[:m] % 8,
            "w": rng.normal(size=m).astype(np.float32)}


def _sorted_rows(d: dict, keys):
    idx = np.lexsort(tuple(d[k] for k in reversed(list(keys))))
    return {k: np.asarray(v)[idx] for k, v in d.items()}


def assert_frame_close(got: dict, ref: "pd.DataFrame", keys, rtol=1e-4,
                       atol=1e-5):
    """Order-insensitive comparison: sort both sides by ``keys``."""
    ref_d = {c: ref[c].to_numpy() for c in ref.columns}
    g, r = _sorted_rows(got, keys), _sorted_rows(ref_d, keys)
    assert set(g) >= set(r), (sorted(g), sorted(r))
    for c in r:
        assert len(g[c]) == len(r[c]), f"{c}: {len(g[c])} vs {len(r[c])} rows"
        if np.issubdtype(np.asarray(r[c]).dtype, np.floating):
            np.testing.assert_allclose(g[c], r[c].astype(np.float64),
                                       rtol=rtol, atol=atol, err_msg=c)
        else:
            assert np.array_equal(g[c].astype(np.int64),
                                  r[c].astype(np.int64)), c


# ---------------------------------------------------------------------------
# expression surface: __getattr__, __setitem__, assign, drop, rename
# ---------------------------------------------------------------------------


def test_getattr_column_access_matches_getitem():
    df = hf.table(_frame())
    assert df.x.key() == df["x"].key()
    with pytest.raises(AttributeError, match="nope"):
        df.nope
    # methods win over columns; subscript still reaches a shadowed name
    t = dict(_frame())
    t["sort"] = t["x"]
    d2 = hf.table(t)
    assert callable(d2.sort)
    assert d2["sort"].key()[2] == "sort"


def test_setitem_assign_drop_parity():
    t = _frame()
    df = hf.table(t)
    df["z"] = df.x * 2.0 + df.y
    out = (df.assign(w=lambda d: d.z - d.x, c=1.5)
             .drop(["b", "t"])
             .collect().to_numpy())
    pdf = pd.DataFrame({k: v for k, v in t.items()})
    pdf = pdf.assign(z=pdf.x * 2.0 + pdf.y)
    pdf = pdf.assign(w=pdf.z - pdf.x, c=1.5).drop(columns=["b", "t"])
    assert set(out) == set(pdf.columns)
    assert_frame_close(out, pdf, keys=("k1", "k2", "x"))


def test_setitem_keeps_prebuilt_expressions_valid():
    df = hf.table(_frame())
    pred = df.x > 0.0          # built BEFORE the mutation
    df["x2"] = df.x * df.x
    out = df[pred].collect().to_numpy()
    src = _frame()
    assert len(out["x"]) == int((src["x"] > 0).sum())
    np.testing.assert_allclose(out["x2"], out["x"] * out["x"], rtol=1e-6)


def test_rename_columns_kwarg():
    df = hf.table(_frame()).rename(columns={"k1": "g"})
    assert "g" in df.columns and "k1" not in df.columns


# ---------------------------------------------------------------------------
# merge / groupby / agg
# ---------------------------------------------------------------------------


def test_merge_parity_single_key():
    t, d = _frame(), _dim()
    got = hf.table(t).merge(hf.table(d, "d"), on=("k1", "ck")).collect().to_numpy()
    ref = pd.DataFrame(t).merge(pd.DataFrame(d), left_on="k1", right_on="ck",
                                how="inner").drop(columns=["ck"])
    assert_frame_close(got, ref, keys=("k1", "t", "w"))


def test_merge_free_function_is_a_shim():
    t, d = _frame(), _dim()
    l, r = hf.table(t), hf.table(d, "d")
    via_fn = hf.join(l, r, on=("k1", "ck"), how="left")
    via_method = l.merge(r, on=("k1", "ck"), how="left")
    assert isinstance(via_fn.node, ir.Join) and isinstance(via_method.node, ir.Join)
    assert via_fn.node.left_on == via_method.node.left_on
    assert list(via_fn.node.schema) == list(via_method.node.schema)


def test_groupby_agg_named_tuples_parity():
    t = _frame()
    df = hf.table(t)
    got = (df.groupby(("k1", "k2"))
             .agg(total=("x", "sum"), lo=("y", "min"), hi=("y", "max"),
                  m=("x", "mean"), n="count")
             .collect().to_numpy())
    ref = (pd.DataFrame(t).groupby(["k1", "k2"], as_index=False)
             .agg(total=("x", "sum"), lo=("y", "min"), hi=("y", "max"),
                  m=("x", "mean"), n=("x", "size")))
    assert_frame_close(got, ref, keys=("k1", "k2"))


def test_groupby_agg_expression_column_and_aggexpr():
    t = _frame()
    df = hf.table(t)
    got = (df.groupby("k1")
             .agg(hits=(df.x > 0.0, "sum"), s=hf.sum_(df.x))
             .collect().to_numpy())
    pdf = pd.DataFrame(t)
    ref = (pdf.assign(pos=(pdf.x > 0).astype(np.int32))
              .groupby("k1", as_index=False)
              .agg(hits=("pos", "sum"), s=("x", "sum")))
    assert_frame_close(got, ref, keys=("k1",))


def test_groupby_prod_any_all_parity():
    """The decomposable-table satellite: prod/any/all as one-line entries,
    reachable through hf.prod/any_/all_ AND the named-agg spec, on BOTH the
    raw and the map-side-partial aggregation paths."""
    rng = np.random.default_rng(9)
    n = 300
    t = {"k": rng.integers(0, 6, n).astype(np.int32),
         "x": rng.uniform(0.5, 1.5, n).astype(np.float32),
         "b": rng.integers(0, 2, n) > 0}
    df = hf.table(t)
    ref = (pd.DataFrame(t).groupby("k", as_index=False)
             .agg(p=("x", "prod"), ay=("b", "any"), al=("b", "all")))
    for cfg in (hf.ExecConfig(), hf.ExecConfig(partial_agg=False)):
        got = (df.groupby("k")
                 .agg(p=("x", "prod"), ay=hf.any_(df.b), al=hf.all_(df.b))
                 .collect(cfg).to_numpy())
        assert got["ay"].dtype == np.bool_ and got["al"].dtype == np.bool_
        assert_frame_close(got, ref, keys=("k",), rtol=2e-3)
    # all three are decomposable: the bare-scan aggregate takes the
    # partial-agg path (PartialAgg planned, partial columns on the wire)
    plan = df.groupby("k").agg(p=("x", "prod"), ay=hf.any_(df.b)) \
             .physical_plan()
    assert plan.counts()["partial_aggs"] == 1, plan.render()
    ex = [op for op in plan.ops if isinstance(op, pp.HashExchange)][0]
    assert any(c.startswith("__p_") for c in ex.schema), ex.schema


def test_groupby_sugar_methods_parity():
    t = _frame()
    got = hf.table(t).drop(["b"]).groupby("k1").sum().collect().to_numpy()
    ref = (pd.DataFrame(t).drop(columns=["b"])
             .groupby("k1", as_index=False).sum())
    assert_frame_close(got, ref, keys=("k1",), rtol=1e-3)
    got_n = hf.table(t).groupby(("k1", "k2")).size().collect().to_numpy()
    ref_n = (pd.DataFrame(t).groupby(["k1", "k2"], as_index=False)
               .size().rename(columns={"size": "size"}))
    assert_frame_close(got_n, ref_n, keys=("k1", "k2"))


def test_groupby_validates_keys_and_specs():
    df = hf.table(_frame())
    with pytest.raises(KeyError):
        df.groupby("missing")
    with pytest.raises(KeyError):
        df.groupby("k1").agg(s=("missing", "sum"))
    with pytest.raises(TypeError):
        df.groupby("k1").agg(s="sum")       # bare strings only spell count
    with pytest.raises(ValueError):
        df.groupby("k1").agg()


# ---------------------------------------------------------------------------
# head / limit
# ---------------------------------------------------------------------------


def test_head_matches_pandas_on_sorted_frame():
    t = _frame()
    got = hf.table(t).sort_values("t").head(23).collect().to_numpy()
    ref = pd.DataFrame(t).sort_values("t").head(23)
    assert len(got["t"]) == 23
    for c in ref.columns:
        v = ref[c].to_numpy()
        if np.issubdtype(v.dtype, np.floating):
            np.testing.assert_allclose(got[c], v, rtol=1e-6)
        else:
            assert np.array_equal(got[c].astype(np.int64), v.astype(np.int64))


def test_head_plans_no_data_movement():
    df = hf.table(_frame())
    plan = df.head(10).physical_plan()
    assert plan.shuffle_count() == 0, plan.render()
    assert any(isinstance(op, pp.LimitOp) for op in plan.ops)
    # head keeps provided properties: groupby after head on the same key
    # still elides its exchange
    a = df.groupby("k1").agg(s=("x", "sum")).persist()
    c = a.head(3).groupby("k1").agg(s2=("s", "sum")).physical_plan().counts()
    assert c["hash_exchanges"] == 0 and c["local_sorts"] == 0, c


def test_limit_alias_and_edge_sizes():
    t = _frame(n=50)
    df = hf.table(t)
    assert len(df.limit(7).collect().to_numpy()["x"]) == 7
    assert len(df.head(0).collect().to_numpy()["x"]) == 0
    assert len(df.head(10_000).collect().to_numpy()["x"]) == 50
    with pytest.raises(ValueError):
        df.head(-1)


# ---------------------------------------------------------------------------
# rolling_mean exact mode (min_periods-style borders)
# ---------------------------------------------------------------------------


def test_rolling_mean_exact_global_parity():
    t = _frame(n=200)
    df = hf.table(t)
    w = 6
    exact = hf.rolling_mean(df, df.x, w, out="m", exact=True).collect().to_numpy()
    ref = pd.DataFrame(t).x.rolling(w, min_periods=1).mean().to_numpy()
    np.testing.assert_allclose(exact["m"], ref, atol=1e-5)
    # the default stays the zero-padded fast path: first w-1 rows differ
    # (divide by the full window), the steady state agrees
    padded = hf.rolling_mean(df, df.x, w, out="m").collect().to_numpy()
    np.testing.assert_allclose(padded["m"][w - 1:], ref[w - 1:], atol=1e-5)
    assert not np.allclose(padded["m"][: w - 1], ref[: w - 1])


def test_rolling_mean_exact_partitioned_parity():
    t = _frame(n=400)
    df = hf.table(t)
    w = 4
    got = (df.over("k1", order_by="t")
             .rolling_mean(df.x, w, out="m", exact=True)
             .collect().to_numpy())
    pdf = pd.DataFrame(t).sort_values(["k1", "t"])
    pdf["m"] = (pdf.groupby("k1")["x"]
                   .transform(lambda s: s.rolling(w, min_periods=1).mean()))
    assert_frame_close(got, pdf, keys=("k1", "t"))


# ---------------------------------------------------------------------------
# persist / cache: the layout contract + census gates
# ---------------------------------------------------------------------------


def _census(df, cfg=None, **expect):
    plan = df.physical_plan(cfg or hf.ExecConfig())
    c = plan.counts()
    for k, v in expect.items():
        assert c[k] == v, f"{k}: planned {c[k]}, expected {v}\n{plan.render()}"
    return plan


def test_persist_carries_layout():
    df = hf.table(_frame())
    p = df.groupby(("k1", "k2")).agg(s=("x", "sum")).persist()
    lay = p.node.layout
    assert lay.kind == "hash" and lay.partitioned_by == ("k1", "k2")
    assert lay.sorted_by[:2] == ("k1", "k2") and lay.counts is not None
    assert lay.rows() == int(np.sum(lay.counts))
    ps = df.sort_values("t").persist()
    assert ps.node.layout.kind == "range"
    assert ps.node.layout.sorted_by == ("t",)


def test_persist_groupby_same_key_plans_zero_exchanges():
    """THE acceptance gate: a persisted hash-partitioned frame feeds a
    groupby on the persisted keys with 0 exchanges and 0 sorts — only the
    SegmentAgg remains."""
    df = hf.table(_frame())
    p = df.groupby(("k1", "k2")).agg(s=("x", "sum"), n="count").persist()
    again = p.groupby(("k1", "k2")).agg(s2=("s", "sum"), n2=("n", "sum"))
    _census(again, hash_exchanges=0, local_sorts=0, sample_sorts=0,
            rebalances=0, partial_aggs=0, segment_aggs=1)
    # the baseline lever ignores the layout: the exchange comes back
    base = again.physical_plan(hf.ExecConfig(elide_exchanges=False)).counts()
    assert base["hash_exchanges"] == 1, base


def test_persist_merge_on_persisted_keys_plans_zero_exchanges():
    t = _frame()
    df = hf.table(t)
    a = df.groupby("k1").agg(s=("x", "sum")).persist()
    b = df.groupby("k1").agg(m=("y", "mean")).persist()
    m = a.merge(b, on="k1")
    _census(m, hash_exchanges=0, local_sorts=0, sample_sorts=0, rebalances=0)
    ref = (pd.DataFrame(t).groupby("k1", as_index=False)
             .agg(s=("x", "sum"), m=("y", "mean")))
    assert_frame_close(m.collect().to_numpy(), ref, keys=("k1",))


def test_persist_sorted_then_sort_plans_full_noop():
    df = hf.table(_frame())
    ps = df.sort_values(("t", "k1")).persist()
    again = ps.sort_values("t")            # prefix of the persisted ordering
    plan = _census(again, sample_sorts=0, hash_exchanges=0, local_sorts=0)
    # full no-op: the Sort planned NOTHING — root is the persisted Source
    assert isinstance(plan.root_op, pp.Source), plan.render()
    t = _frame()
    out = again.collect().to_numpy()
    assert np.array_equal(out["t"], np.sort(t["t"]))


def test_persist_over_persisted_keys_plans_zero_extra():
    df = hf.table(_frame())
    p = df.groupby(("k1", "k2")).agg(s=("x", "sum")).persist()
    w = p.over(("k1", "k2")).cumsum(p["s"], out="cs")
    _census(w, hash_exchanges=0, local_sorts=0, sample_sorts=0)


def test_persist_replicated_dimension_stays_broadcast():
    t, d = _frame(), _dim()
    pdim = hf.table(d, "dim").replicate().persist()
    assert pdim.node.layout.kind == "rep"
    j = hf.table(t).merge(pdim, on=("k1", "ck"))
    _census(j, hash_exchanges=0, sample_sorts=0, rebalances=0)
    ref = pd.DataFrame(t).merge(pd.DataFrame(d), left_on="k1",
                                right_on="ck").drop(columns=["ck"])
    assert_frame_close(j.collect().to_numpy(), ref, keys=("k1", "t", "w"))


def test_cache_is_persist_alias():
    df = hf.table(_frame())
    c = df.groupby("k1").agg(s=("x", "sum")).cache()
    assert c.node.layout.kind == "hash"
    assert c.groupby("k1").agg(s2=("s", "sum")) \
            .physical_plan().shuffle_count() == 0


def test_persist_device_shards_reenter_without_host_roundtrip():
    """The persisted columns feed the next execution BY IDENTITY — no
    np.asarray round-trip, no re-pad."""
    df = hf.table(_frame())
    p = df.groupby("k1").agg(s=("x", "sum")).persist()
    low = p.groupby("k1").agg(s2=("s", "sum")).lower()
    _fn, inputs = low._prepare()
    sid = str(p.node.id)
    assert inputs["scans"][sid]["s"] is p.node.columns["s"]
    assert f"__cnt:{p.node.id}" in inputs["ext"]


def test_persist_prunes_layout_with_columns():
    """Column pruning restricts the layout instead of dropping it: the
    partitioning survives while its keys survive, and a pruned key demotes
    the claim (no false elision)."""
    df = hf.table(_frame())
    p = df.groupby(("k1", "k2")).agg(s=("x", "sum"), m=("y", "mean")).persist()
    # consumer uses only (k1, k2, s): m is pruned; hash(k1,k2) survives
    again = p.groupby(("k1", "k2")).agg(s2=("s", "sum"))
    assert again.physical_plan().counts()["hash_exchanges"] == 0
    # consumer groups by k1 only and never reads k2: the hash(k1,k2) claim
    # dies with the pruned key and the exchange must come back
    solo = p.groupby("k1").agg(s2=("s", "sum"))
    assert solo.physical_plan().counts()["hash_exchanges"] == 1
    t = _frame()
    ref = pd.DataFrame(t).groupby("k1", as_index=False).agg(s2=("x", "sum"))
    assert_frame_close(solo.collect().to_numpy(), ref, keys=("k1",))


def test_persist_then_replicate_reenters_correctly():
    """Review regression: replicate() on a device-persisted frame forces
    REP, so the runtime gathers to the host — capacity planning must follow
    (not keep the device capacity), and the gather's shard-order concat is
    NOT sorted, so the ordering claim must drop (sort/groupby still plan
    their work instead of a false no-op)."""
    t = _frame()
    df = hf.table(t)
    rep = df.groupby("k1").agg(s=("x", "sum")).persist().replicate()
    out = rep.sort_values("k1").collect().to_numpy()
    ref = (pd.DataFrame(t).groupby("k1", as_index=False)
             .agg(s=("x", "sum")).sort_values("k1"))
    assert np.array_equal(out["k1"], ref["k1"].to_numpy())
    np.testing.assert_allclose(out["s"], ref["s"].to_numpy(), rtol=1e-4)
    g = rep.groupby("k1").agg(s2=("s", "sum")).collect().to_numpy()
    i = np.argsort(g["k1"])
    np.testing.assert_allclose(g["s2"][i], ref["s"].to_numpy(), rtol=1e-4)


def test_persist_refuses_overflowed_result():
    """Review regression: a capacity overflow that survives the retries must
    not be baked into a reusable frame (collect returns the flagged table;
    persist raises)."""
    t = _frame(n=200)
    df = hf.table(t)
    blowup = df.merge(hf.table(t, "t2"), on="k1")     # ~n^2/8 rows
    cfg = hf.ExecConfig(safe_capacities=False, join_expansion=1.0,
                        shuffle_slack=1.0, auto_retry=0)
    assert blowup.collect(cfg).overflow                # flagged, not raised
    with pytest.raises(RuntimeError, match="overflow"):
        blowup.persist(cfg)


def test_agg_count_spec_validates_column():
    df = hf.table(_frame())
    with pytest.raises(KeyError):
        df.groupby("k1").agg(n=("nope", "count"))


def test_agg_spec_validates_fn():
    df = hf.table(_frame())
    with pytest.raises(TypeError, match="median"):
        df.groupby("k1").agg(m=("x", "median"))
    with pytest.raises(ValueError, match="median"):
        hf.aggregate(df, "k1", m=AggExpr("median", df.x))


def test_groupby_min_max_of_bool_column():
    """Review regression: min/max of a bool column compares as 0/1 int32 on
    BOTH agg paths (bool has no sentinel) — and the whole-frame sugar sweeps
    bool columns without crashing."""
    t = _frame()
    ref = (pd.DataFrame(t).drop(columns=["x", "y", "t"])
             .groupby("k1", as_index=False)
             .agg(lo=("b", "min"), hi=("b", "max")))
    for cfg in (hf.ExecConfig(), hf.ExecConfig(partial_agg=False)):
        got = (hf.table(t).groupby("k1").agg(lo=("b", "min"), hi=("b", "max"))
               .collect(cfg).to_numpy())
        i = np.argsort(got["k1"])
        assert np.array_equal(got["lo"][i], ref["lo"].to_numpy().astype(np.int64))
        assert np.array_equal(got["hi"][i], ref["hi"].to_numpy().astype(np.int64))
    hf.table(t).groupby("k1").min().collect()       # sugar sweep, no crash


def test_stencil_exact_rejects_non_positive_mass():
    df = hf.table(_frame())
    with pytest.raises(ValueError, match="weight"):
        hf.stencil(df, df.x, [-1.0, 1.0], exact=True)
    with pytest.raises(ValueError, match="weight"):
        hf.stencil(df, df.x, [0.0, 0.0], exact=True)


def test_persist_restrict_layout_unit():
    lay = ir.ScanLayout(kind="hash", partitioned_by=("a", "b"),
                        sorted_by=("a", "b", "c"), counts=np.array([3]),
                        capacity=8, nshards=1)
    r = lay.restrict({"a", "b", "c"})
    assert r.kind == "hash" and r.sorted_by == ("a", "b", "c")
    r2 = lay.restrict({"a", "c"})
    assert r2.kind == "block" and r2.partitioned_by == ()
    assert r2.sorted_by == ("a",)          # longest surviving prefix


# ---------------------------------------------------------------------------
# multi-shard parity (2 and 8 devices, subprocess)
# ---------------------------------------------------------------------------

_SHARDED_BODY = """
    import pandas as pd   # the outer importorskip already proved it's there
    rng = np.random.default_rng(17)
    n = 900
    t = {"k1": rng.integers(0, 11, n).astype(np.int32),
         "k2": rng.integers(0, 4, n).astype(np.int32),
         "t": rng.permutation(n).astype(np.int32),
         "x": rng.normal(size=n).astype(np.float32),
         "b": rng.integers(0, 2, n) > 0}
    df = hf.table(t)
    pdf = pd.DataFrame(t)

    def close(got, ref, keys):
        gi = np.lexsort(tuple(got[k] for k in reversed(keys)))
        ref = ref.sort_values(list(keys))
        for c in ref.columns:
            v, g = ref[c].to_numpy(), np.asarray(got[c])[gi]
            assert len(g) == len(v), (c, len(g), len(v))
            if np.issubdtype(v.dtype, np.floating):
                np.testing.assert_allclose(g, v.astype(np.float64),
                                           rtol=1e-3, atol=1e-5, err_msg=c)
            else:
                assert np.array_equal(g.astype(np.int64), v.astype(np.int64)), c

    # fluent chain: filter -> assign -> groupby.agg (prod/any ride along)
    got = (df[df.x > -1.0].assign(z=df.x + 1.0)
             .groupby(("k1", "k2"))
             .agg(s=("z", "sum"), p=("z", "prod"), ay=("b", "any"), n="count")
             .collect().to_numpy())
    sel = pdf[pdf.x > -1.0].assign(z=pdf.x + 1.0)
    ref = sel.groupby(["k1", "k2"], as_index=False).agg(
        s=("z", "sum"), p=("z", "prod"), ay=("b", "any"), n=("z", "size"))
    close(got, ref, ("k1", "k2"))

    # persist -> groupby(same keys): 0 exchanges AND correct at this P
    p = df.groupby(("k1", "k2")).agg(s=("x", "sum"), n="count").persist()
    again = p.groupby(("k1", "k2")).agg(s2=("s", "sum"), n2=("n", "sum"))
    c = again.physical_plan().counts()
    assert c["hash_exchanges"] == 0 and c["local_sorts"] == 0, c
    ref2 = pdf.groupby(["k1", "k2"], as_index=False).agg(
        s2=("x", "sum"), n2=("x", "size"))
    close(again.collect().to_numpy(), ref2, ("k1", "k2"))

    # persist -> merge(on=persisted key): 0 exchanges, parity
    a = df.groupby("k1").agg(s=("x", "sum")).persist()
    b = df.groupby("k1").agg(m=("x", "mean")).persist()
    m = a.merge(b, on="k1")
    assert m.physical_plan().counts()["hash_exchanges"] == 0
    ref3 = pdf.groupby("k1", as_index=False).agg(s=("x", "sum"),
                                                 m=("x", "mean"))
    close(m.collect().to_numpy(), ref3, ("k1",))

    # persist(sorted) -> sort full no-op -> head: pandas head parity
    ps = df.sort_values("t").persist()
    assert ps.sort_values("t").physical_plan().counts()["sample_sorts"] == 0
    h = ps.sort_values("t").head(31).collect().to_numpy()
    refh = pdf.sort_values("t").head(31)
    assert np.array_equal(h["t"], refh["t"].to_numpy())
    np.testing.assert_allclose(h["x"], refh["x"].to_numpy(), rtol=1e-6)

    # exact rolling mean across shard boundaries
    e = hf.rolling_mean(ps, ps["x"], 5, out="m", exact=True)
    refm = pdf.sort_values("t").x.rolling(5, min_periods=1).mean().to_numpy()
    np.testing.assert_allclose(e.collect().to_numpy()["m"], refm, atol=1e-4)
"""


@pytest.mark.parametrize("devices", [2, 8])
def test_api_v2_sharded_parity(devices):
    run_sharded(_SHARDED_BODY, devices=devices)


# -- repartition / sort_within_partitions (layout verbs) ----------------------


def test_repartition_plans_one_exchange_and_persists_layout():
    df = hf.table(_frame())
    rp = df.repartition("k1")
    _census(rp, hash_exchanges=1, local_sorts=0, sample_sorts=0)
    p = rp.persist()
    lay = p.node.layout
    assert lay.kind == "hash" and lay.partitioned_by == ("k1",)
    # the payoff: downstream groupby on the pre-staged key plans 0 exchanges
    _census(p.groupby("k1").agg(s=("x", "sum")),
            hash_exchanges=0, partial_aggs=0, segment_aggs=1)


def test_repartition_elided_when_already_partitioned():
    df = hf.table(_frame())
    _census(df.repartition(("k1", "k2")).repartition(("k1", "k2")),
            hash_exchanges=1)
    # a groupby output is hash(key)-partitioned: repartitioning on the same
    # key is a full no-op
    g = df.groupby("k1").agg(s=("x", "sum"))
    _census(g.repartition("k1"), hash_exchanges=1)   # only the groupby's own


def test_sort_within_partitions_layout_and_parity():
    t = _frame()
    df = hf.table(t)
    sp = df.sort_within_partitions(("k1", "t"))
    _census(sp, hash_exchanges=0, local_sorts=1, sample_sorts=0)
    out = sp.collect().to_numpy()
    # single shard in-process: fully sorted by (k1, t); rows preserved
    assert len(out["t"]) == len(t["t"])
    order = np.lexsort((t["t"], t["k1"]))
    np.testing.assert_array_equal(out["k1"], t["k1"][order])
    np.testing.assert_array_equal(out["t"], t["t"][order])
    np.testing.assert_allclose(out["x"], t["x"][order], rtol=1e-6)


def test_repartition_sort_chain_feeds_window_elided():
    df = hf.table(_frame())
    staged = df.repartition("k1").sort_within_partitions(("k1", "t")).persist()
    lay = staged.node.layout
    assert lay.kind == "hash" and lay.sorted_by == ("k1", "t")
    w = staged.over("k1", order_by="t").cumsum(staged["x"], out="cs")
    _census(w, hash_exchanges=0, local_sorts=0)


def test_repartition_validates_columns_and_direction():
    df = hf.table(_frame())
    with pytest.raises(KeyError, match="repartition"):
        df.repartition("nope")
    with pytest.raises(KeyError, match="sort_within_partitions"):
        df.sort_within_partitions("nope")
    with pytest.raises(ValueError, match="ascending"):
        df.sort_within_partitions("k1", ascending=False)
    with pytest.raises(ValueError, match="Repartition"):
        ir.Repartition(df.node)


_REPARTITION_SHARDED_BODY = """
    import numpy as np
    rng = np.random.default_rng(21)
    n = 1200
    t = {"k": rng.integers(0, 11, n).astype(np.int32),
         "t": rng.permutation(n).astype(np.int32),
         "x": rng.normal(size=n).astype(np.float32)}
    df = hf.table(t)
    staged = df.repartition("k").sort_within_partitions(("k", "t")).persist()
    plan = staged.groupby("k").agg(s=("x", "sum"), c="count").physical_plan()
    c = plan.counts()
    assert c["hash_exchanges"] == 0 and c["local_sorts"] == 0, c
    out = staged.groupby("k").agg(s=("x", "sum"), c="count").collect().to_numpy()
    out = {k: v[np.argsort(out["k"])] for k, v in out.items()}
    want_s = np.array([t["x"][t["k"] == k].sum() for k in np.unique(t["k"])])
    np.testing.assert_allclose(out["s"], want_s, atol=1e-3)
    # every row survived the restage
    raw = staged.collect().to_numpy()
    assert sorted(raw["t"].tolist()) == sorted(t["t"].tolist())
"""


@pytest.mark.parametrize("devices", [2, 8])
def test_repartition_sharded(devices):
    run_sharded(_REPARTITION_SHARDED_BODY, devices=devices)


# -- GroupBy column selection -------------------------------------------------


def test_groupby_getitem_single_column():
    t = _frame()
    df = hf.table(t)
    out = df.groupby("k1")["x"].sum().collect().to_numpy()
    assert set(out) == {"k1", "x"}
    pdf = pd.DataFrame(t)
    ref = pdf.groupby("k1")["x"].sum()
    out_s = out["x"][np.argsort(out["k1"])]
    np.testing.assert_allclose(out_s, ref.to_numpy(), atol=1e-3)


def test_groupby_getitem_list_mean():
    t = _frame()
    df = hf.table(t)
    out = df.groupby("k1")[["x", "y"]].mean().collect().to_numpy()
    assert set(out) == {"k1", "x", "y"}
    pdf = pd.DataFrame(t)
    ref = pdf.groupby("k1")[["x", "y"]].mean().sort_index()
    o = np.argsort(out["k1"])
    np.testing.assert_allclose(out["x"][o], ref["x"].to_numpy(), atol=1e-4)
    np.testing.assert_allclose(out["y"][o], ref["y"].to_numpy(), atol=1e-4)


def test_groupby_getitem_validates():
    df = hf.table(_frame())
    with pytest.raises(KeyError, match="groupby"):
        df.groupby("k1")["nope"]
    with pytest.raises(ValueError, match="empty"):
        df.groupby("k1")[[]]
    with pytest.raises(TypeError):
        df.groupby("k1")[[3]]
    # agg() is unaffected by selection; explicit specs still name any column
    out = df.groupby("k1")["x"].agg(ym=("y", "mean")).collect().to_numpy()
    assert set(out) == {"k1", "ym"}

"""Plan-level tests: predicate pushdown, column pruning, distribution lattice."""
import numpy as np
import pytest

from repro import hiframes as hf
from repro.core import distribution as D
from repro.core import ir, optimizer


def _frames():
    n = 100
    left = hf.table({"id": np.arange(n, dtype=np.int32),
                     "phone": np.arange(n, dtype=np.int32)}, "customer")
    right = hf.table({"customerId": np.arange(n, dtype=np.int32),
                      "amount": np.random.default_rng(0).normal(size=n)
                      .astype(np.float32)}, "order")
    return left, right


def test_push_predicate_through_join_right():
    """The paper's Fig. 6 example: filter on right-side column moves below."""
    customer, order = _frames()
    j = hf.join(customer, order, on=("id", "customerId"))
    f = j[j["amount"] > 100.0]
    new_root, n = optimizer.push_predicates(f.node)
    assert n == 1
    assert isinstance(new_root, ir.Join)
    assert isinstance(new_root.right, ir.Filter)
    # renamed back to the right table's own column name
    assert "amount" in {c for (_t, c) in new_root.right.pred.columns()}


def test_push_predicate_left_side():
    customer, order = _frames()
    j = hf.join(customer, order, on=("id", "customerId"))
    f = j[j["phone"] < 50]
    new_root, n = optimizer.push_predicates(f.node)
    assert n == 1
    assert isinstance(new_root.left, ir.Filter)


def test_push_predicate_key_column():
    customer, order = _frames()
    j = hf.join(customer, order, on=("id", "customerId"))
    f = j[j["id"] < 10]
    new_root, n = optimizer.push_predicates(f.node)
    assert n == 1  # key predicates push to (at least) one side


def test_no_push_mixed_predicate():
    customer, order = _frames()
    j = hf.join(customer, order, on=("id", "customerId"))
    f = j[(j["phone"] < 50) & (j["amount"] > 0.0)]
    new_root, n = optimizer.push_predicates(f.node)
    assert n == 0
    assert isinstance(new_root, ir.Filter)


def test_filter_fusion():
    df = hf.table({"a": np.arange(10, dtype=np.int32)})
    f = df[df["a"] > 2][df["a"] < 8]
    new_root, n = optimizer.push_predicates(f.node)
    assert n >= 1
    assert isinstance(new_root, ir.Filter)
    assert isinstance(new_root.child, ir.Scan)


def test_push_through_concat():
    df1 = hf.table({"a": np.arange(10, dtype=np.int32)}, "t1")
    df2 = hf.table({"a": np.arange(10, dtype=np.int32)}, "t2")
    c = hf.concat(df1, df2)
    f = c[c["a"] > 5]
    new_root, n = optimizer.push_predicates(f.node)
    assert n == 1
    assert isinstance(new_root, ir.Concat)
    assert all(isinstance(p, ir.Filter) for p in new_root.parts)


def test_column_pruning_scan():
    df = hf.table({"a": np.arange(10, dtype=np.int32),
                   "b": np.arange(10, dtype=np.int32),
                   "c": np.arange(10, dtype=np.int32)})
    f = df[df["a"] > 2]
    pruned, n = optimizer.prune_columns(f.node, keep={"a"})
    assert n == 2            # b and c removed from the Scan
    scans = [x for x in ir.topo_order(pruned) if isinstance(x, ir.Scan)]
    assert list(scans[0].columns) == ["a"]


def test_pruning_keeps_join_keys():
    l, r = _frames()
    j = hf.join(l, r, on=("id", "customerId"))
    pruned, _ = optimizer.prune_columns(j.node, keep={"amount"})
    scans = {s.name: s for s in ir.topo_order(pruned) if isinstance(s, ir.Scan)}
    assert "id" in scans["customer"].columns
    assert "customerId" in scans["order"].columns


def test_pushdown_correctness_end_to_end():
    """Optimized and unoptimized plans must produce identical tables."""
    rng = np.random.default_rng(3)
    n = 500
    left = {"id": rng.integers(0, 50, n).astype(np.int32),
            "p": rng.normal(size=n).astype(np.float32)}
    right = {"cid": rng.integers(0, 50, 80).astype(np.int32),
             "amount": rng.normal(size=80).astype(np.float32)}
    j = hf.join(hf.table(left, "l"), hf.table(right, "r"), on=("id", "cid"))
    f = j[j["amount"] > 0.0]
    opt = f.collect(hf.ExecConfig(optimize_plan=True)).to_numpy()
    raw = f.collect(hf.ExecConfig(optimize_plan=False)).to_numpy()
    ko = np.lexsort((opt["p"], opt["amount"], opt["id"]))
    kr = np.lexsort((raw["p"], raw["amount"], raw["id"]))
    for k in opt:
        np.testing.assert_allclose(opt[k][ko], raw[k][kr], rtol=1e-6)


# -- distribution lattice -----------------------------------------------------


LATTICE = [D.ONE_D, D.ONE_D_VAR, D.TWO_D, D.REP]


def test_meet_lattice_laws():
    for a in LATTICE:
        assert D.meet(a, a) == a                       # idempotent
        for b in LATTICE:
            assert D.meet(a, b) == D.meet(b, a)        # commutative
            for c in LATTICE:
                assert D.meet(D.meet(a, b), c) == D.meet(a, D.meet(b, c))


def test_meet_paper_figure7():
    assert D.meet(D.ONE_D, D.ONE_D_VAR) == D.ONE_D_VAR
    assert D.meet(D.ONE_D, D.TWO_D) == D.REP
    assert D.meet(D.ONE_D_VAR, D.TWO_D) == D.REP
    assert D.meet(D.ONE_D, D.REP) == D.REP


def test_inference_filter_is_var():
    df = hf.table({"a": np.arange(10, dtype=np.int32)})
    f = df[df["a"] > 2]
    info = D.infer(f.node)
    assert info.dists[f.node.id] == D.ONE_D_VAR


def test_inference_rep_poisons_paper_rule():
    """Paper §4.4: REP input sequentializes the aggregate (broadcast off)."""
    df = hf.table({"a": np.arange(10, dtype=np.int32)})
    info = D.infer(ir.Aggregate(df.node, "a", {}), force_rep={df.node.id},
                   broadcast_join=False)
    agg = [n for n in [ir.Aggregate(df.node, "a", {})]]
    # re-infer on a fresh tree rooted at an aggregate
    root = ir.Aggregate(df.node, "a", {})
    info = D.infer(root, force_rep={df.node.id}, broadcast_join=False)
    assert info.dists[root.id] == D.REP


def test_rebalance_inserted_only_when_needed():
    """1D_VAR -> stencil requires a Rebalance; 1D_BLOCK -> stencil does not."""
    df = hf.table({"a": np.arange(100, dtype=np.int32)})
    plain = hf.sma(df, df["a"], 3)
    info = D.infer(plain.node)
    root = D.insert_rebalance(plain.node, info)
    assert not any(isinstance(n, ir.Rebalance) for n in ir.topo_order(root))

    filtered = hf.sma(df[df["a"] > 5], df["a"], 3)
    info = D.infer(filtered.node)
    root = D.insert_rebalance(filtered.node, info)
    rb = [n for n in ir.topo_order(root) if isinstance(n, ir.Rebalance)]
    assert len(rb) == 1
    assert isinstance(root, ir.Window)
    assert isinstance(root.child, ir.Rebalance)


def test_cumsum_accepts_1d_var_no_rebalance():
    df = hf.table({"a": np.arange(100, dtype=np.int32)})
    c = hf.cumsum(df[df["a"] > 5], df["a"])
    info = D.infer(c.node)
    root = D.insert_rebalance(c.node, info)
    assert not any(isinstance(n, ir.Rebalance) for n in ir.topo_order(root))


def test_broadcast_join_keeps_distribution():
    l, r = _frames()
    j = hf.join(l, r.replicate(), on=("id", "customerId"))
    info = D.infer(j.node, force_rep=j._force_rep(), broadcast_join=True)
    assert info.dists[j.node.id] == D.ONE_D_VAR
    info2 = D.infer(j.node, force_rep=j._force_rep(), broadcast_join=False)
    assert info2.dists[j.node.id] == D.REP

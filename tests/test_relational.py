"""Single-device correctness of the relational operators vs NumPy oracles."""
import numpy as np
import pytest

from repro import hiframes as hf
from oracle import o_aggregate, o_cumsum, o_filter, o_join, o_stencil, sorted_cols


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    n = 2000
    return {
        "id": rng.integers(0, 41, n).astype(np.int32),
        "x": rng.normal(size=n).astype(np.float32),
        "y": rng.normal(size=n).astype(np.float32),
    }


def test_filter(data):
    df = hf.table(data)
    out = df[(df["x"] < 0.5) & (df["id"] > 3)].collect().to_numpy()
    ref = o_filter(data, (data["x"] < 0.5) & (data["id"] > 3))
    for k in ref:
        np.testing.assert_allclose(out[k], ref[k])


def test_filter_no_match(data):
    df = hf.table(data)
    out = df[df["x"] > 1e9].collect()
    assert out.num_rows() == 0


def test_projection(data):
    df = hf.table(data)
    out = df[["x"]].collect().to_numpy()
    assert list(out) == ["x"]
    np.testing.assert_allclose(out["x"], data["x"])


def test_with_column(data):
    df = hf.table(data)
    out = df.with_column("z", df["x"] * 2.0 + df["y"]).collect().to_numpy()
    np.testing.assert_allclose(out["z"], data["x"] * 2 + data["y"], rtol=1e-5)


def test_join_duplicates(data):
    rng = np.random.default_rng(8)
    right = {"cid": rng.integers(0, 41, 100).astype(np.int32),
             "w": rng.normal(size=100).astype(np.float32)}
    out = hf.join(hf.table(data), hf.table(right, "r"), on=("id", "cid")) \
        .collect().to_numpy()
    ref = o_join(data, right, "id", "cid")
    assert len(out["id"]) == len(ref["id"])
    a = sorted_cols(out, ("id", "x", "w"))
    b = sorted_cols(ref, ("id", "x", "w"))
    for k in b:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-6)


def test_aggregate_all_fns(data):
    df = hf.table(data)
    out = hf.aggregate(df, "id",
                       s=hf.sum_(df["x"]), m=hf.mean(df["x"]),
                       c=hf.count(), mn=hf.min_(df["y"]),
                       mx=hf.max_(df["y"]), v=hf.var(df["x"]),
                       nu=hf.nunique(df["id"])).collect().to_numpy()
    ref = o_aggregate(data, "id", {
        "s": ("sum", data["x"]), "m": ("mean", data["x"]),
        "c": ("count", None), "mn": ("min", data["y"]),
        "mx": ("max", data["y"]), "v": ("var", data["x"]),
        "nu": ("nunique", data["id"])})
    o = np.argsort(out["id"])
    for k in out:
        out[k] = out[k][o]
    np.testing.assert_array_equal(out["id"], ref["id"])
    np.testing.assert_allclose(out["s"], ref["s"], atol=1e-3)
    np.testing.assert_allclose(out["m"], ref["m"], atol=1e-5)
    np.testing.assert_array_equal(out["c"], ref["c"])
    np.testing.assert_allclose(out["mn"], ref["mn"])
    np.testing.assert_allclose(out["mx"], ref["mx"])
    np.testing.assert_allclose(out["v"], ref["v"], atol=1e-4)
    np.testing.assert_array_equal(out["nu"], ref["nu"])


def test_aggregate_expression_inputs(data):
    """The paper's sum(:x < 1.0) pattern — expressions inside aggregations."""
    df = hf.table(data)
    out = hf.aggregate(df, "id", xc=hf.sum_(df["x"] < 1.0)).collect().to_numpy()
    o = np.argsort(out["id"])
    ref = o_aggregate(data, "id", {"xc": ("sum", (data["x"] < 1.0))})
    np.testing.assert_allclose(out["xc"][o], ref["xc"])


def test_concat(data):
    df = hf.table(data)
    out = hf.concat(df, df).collect().to_numpy()
    assert len(out["x"]) == 2 * len(data["x"])


def test_sort(data):
    out = hf.table(data).sort("x").collect().to_numpy()
    np.testing.assert_allclose(out["x"], np.sort(data["x"]))


def test_sort_descending(data):
    out = hf.table(data).sort("x", ascending=False).collect().to_numpy()
    np.testing.assert_allclose(out["x"], np.sort(data["x"])[::-1])


def test_cumsum(data):
    df = hf.table(data)
    out = hf.cumsum(df, df["x"], out="cs").collect().to_numpy()
    np.testing.assert_allclose(out["cs"], o_cumsum(data["x"]), atol=1e-3)


@pytest.mark.parametrize("weights,scale", [([1, 1, 1], 3.0), ([1, 2, 1], 4.0),
                                           ([1, 2, 3, 2, 1], 9.0)])
def test_stencil(data, weights, scale):
    df = hf.table(data)
    out = hf.stencil(df, df["x"], weights, scale=scale, out="s") \
        .collect().to_numpy()
    ref = o_stencil(data["x"], [w / scale for w in weights], len(weights) // 2)
    np.testing.assert_allclose(out["s"], ref, atol=1e-5)


def test_udf_zero_cost_semantics(data):
    """UDFs behave exactly like built-ins (paper Fig. 10 semantics)."""
    import jax.numpy as jnp
    df = hf.table(data)
    built = df[(df["x"] * 2.0 + 1.0) > 0.0].collect().to_numpy()
    via_udf = df[hf.udf(lambda x: x * 2.0 + 1.0 > 0.0, df["x"])].collect().to_numpy()
    np.testing.assert_array_equal(built["id"], via_udf["id"])


def test_chained_pipeline(data):
    """filter -> join -> aggregate -> filter end-to-end (Q26 skeleton)."""
    rng = np.random.default_rng(9)
    item = {"cid": np.arange(41, dtype=np.int32),
            "cls": rng.integers(1, 4, 41).astype(np.int32)}
    df = hf.table(data)
    j = hf.join(df, hf.table(item, "item"), on=("id", "cid"))
    a = hf.aggregate(j, "id", n=hf.count(), c1=hf.sum_(j["cls"] == 1))
    out = a[a["n"] > 40].collect().to_numpy()

    ref_j = o_join(data, item, "id", "cid")
    ref_a = o_aggregate(ref_j, "id", {"n": ("count", None),
                                      "c1": ("sum", ref_j["cls"] == 1)})
    keep = ref_a["n"] > 40
    o = np.argsort(out["id"])
    np.testing.assert_array_equal(out["id"][o], ref_a["id"][keep])
    np.testing.assert_array_equal(out["c1"][o], ref_a["c1"][keep])


def test_kernels_path_matches(data):
    """use_kernels=True produces identical results."""
    df = hf.table(data)
    cfg = hf.ExecConfig(use_kernels=True)
    a = hf.aggregate(df, "id", s=hf.sum_(df["x"])).collect(cfg).to_numpy()
    b = hf.aggregate(df, "id", s=hf.sum_(df["x"])).collect().to_numpy()
    oa, ob = np.argsort(a["id"]), np.argsort(b["id"])
    np.testing.assert_allclose(a["s"][oa], b["s"][ob], atol=1e-3)


def test_overflow_flag():
    """Join blow-up beyond planned capacity sets the overflow flag."""
    n = 200
    ones = {"k": np.zeros(n, np.int32), "v": np.arange(n, dtype=np.float32)}
    cfg = hf.ExecConfig(safe_capacities=False, shuffle_slack=1.0,
                        join_expansion=1.0, auto_retry=0)
    out = hf.join(hf.table(ones, "a"), hf.table(ones, "b"), on=("k", "k")) \
        .collect(cfg)
    assert out.overflow  # n^2 rows cannot fit the planned capacity

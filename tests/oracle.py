"""NumPy oracles for the relational operators (pandas-free reference)."""
from __future__ import annotations

import numpy as np


def o_filter(cols: dict, mask: np.ndarray) -> dict:
    return {k: v[mask] for k, v in cols.items()}


def _as_keys(key) -> tuple[str, ...]:
    return (key,) if isinstance(key, str) else tuple(key)


def o_join(left: dict, right: dict, lkey, rkey, suffix="_r",
           how="inner") -> dict:
    """Equi-join preserving all matches (order-insensitive compare).

    ``lkey``/``rkey`` may be a single name or a sequence of names (composite
    key — rows match when ALL key columns are equal).  how="left" keeps
    unmatched left rows with NaN-filled float right columns (pandas' null
    convention), zero-filled int right columns and a ``_matched`` indicator,
    mirroring the system's in-band NULL model (docs/dtypes.md).
    """
    lks, rks = _as_keys(lkey), _as_keys(rkey)
    rpos: dict = {}
    for j in range(len(right[rks[0]])):
        kt = tuple(right[k][j].item() for k in rks)
        rpos.setdefault(kt, []).append(j)
    li, ri, matched = [], [], []
    for i in range(len(left[lks[0]])):
        kt = tuple(left[k][i].item() for k in lks)
        js = rpos.get(kt, ())
        for j in js:
            li.append(i)
            ri.append(j)
            matched.append(1)
        if not js and how == "left":
            li.append(i)
            ri.append(0)            # placeholder; value zeroed below
            matched.append(0)
    li, ri = np.array(li, np.int64), np.array(ri, np.int64)
    matched = np.array(matched, np.int32)
    out = {k: v[li] for k, v in left.items()}
    for k, v in right.items():
        if k in rks:
            continue
        name = k + suffix if k in left else k
        vals = np.zeros(len(ri), v.dtype)
        if np.issubdtype(v.dtype, np.floating):
            vals.fill(np.nan)           # unmatched float rows are null
        hit = matched == 1
        vals[hit] = v[ri[hit]]          # unmatched ints stay zero-filled
        out[name] = vals
    if how == "left":
        out["_matched"] = matched
    return out


def o_aggregate(cols: dict, key, aggs: dict[str, tuple]) -> dict:
    """aggs: name -> (fn, value_array_or_None).

    ``key`` may be a single name or a sequence of names; composite groups
    are the distinct key tuples, emitted in lexicographic order with one
    output column per key column.
    """
    ks = _as_keys(key)
    arrs = [np.asarray(cols[k]) for k in ks]
    n = len(arrs[0])
    tuples = [tuple(a[i].item() for a in arrs) for i in range(n)]
    uniq = sorted(set(tuples))
    out = {k: np.array([u[j] for u in uniq], dtype=arrs[j].dtype)
           for j, k in enumerate(ks)}
    for name, (fn, vals) in aggs.items():
        res = []
        for u in uniq:
            m = np.fromiter((t == u for t in tuples), bool, count=n)
            if fn == "sum":
                res.append(np.sum(vals[m]))
            elif fn == "mean":
                res.append(np.mean(vals[m]))
            elif fn == "count":
                res.append(np.sum(m))
            elif fn == "min":
                res.append(np.min(vals[m]))
            elif fn == "max":
                res.append(np.max(vals[m]))
            elif fn == "var":
                res.append(np.var(vals[m]))
            elif fn == "std":
                res.append(np.std(vals[m]))
            elif fn == "nunique":
                res.append(len(np.unique(vals[m])))
            else:
                raise ValueError(fn)
        out[name] = np.array(res)
    return out


def o_cumsum(x: np.ndarray) -> np.ndarray:
    return np.cumsum(x)


def o_stencil(x: np.ndarray, weights, center: int) -> np.ndarray:
    """Zero-padded 1-D stencil matching HiFrames' border convention."""
    k_left = center
    k_right = len(weights) - 1 - center
    ext = np.concatenate([np.zeros(k_left, np.float32),
                          x.astype(np.float32),
                          np.zeros(k_right, np.float32)])
    out = np.zeros(len(x), np.float32)
    for j, w in enumerate(weights):
        out += np.float32(w) * ext[j:j + len(x)]
    return out


def sorted_cols(cols: dict, by: tuple[str, ...]) -> dict:
    order = np.lexsort(tuple(cols[k] for k in reversed(by)))
    return {k: v[order] for k, v in cols.items()}


# ---------------------------------------------------------------------------
# partitioned (OVER (PARTITION BY ... ORDER BY ...)) window oracles
# ---------------------------------------------------------------------------


def o_group_apply(cols: dict, partition_by, order_by, x: np.ndarray, fn,
                  out: str = "_o", dtype=np.float32) -> dict:
    """Sort rows by (partition, order) keys, apply ``fn`` to each group's
    slice of ``x`` independently, and return the sorted columns plus the
    result column ``out`` — the reference semantics of every partitioned
    window: computation restarts at each group boundary."""
    pk, ok = _as_keys(partition_by), _as_keys(order_by) if order_by else ()
    keys = pk + tuple(k for k in ok if k not in pk)
    order = np.lexsort(tuple(np.asarray(cols[k]) for k in reversed(keys)))
    out_cols = {k: np.asarray(v)[order] for k, v in cols.items()}
    xs = np.asarray(x)[order]
    gk = [out_cols[k] for k in pk]
    res = np.zeros(len(xs), dtype)
    i = 0
    while i < len(xs):
        j = i
        while j < len(xs) and all(k[j] == k[i] for k in gk):
            j += 1
        res[i:j] = fn(xs[i:j])
        i = j
    out_cols[out] = res
    return out_cols


def o_group_rank(cols: dict, partition_by, order_by, kind: str,
                 out: str = "_o") -> dict:
    """SQL rank/dense_rank/row_number oracle over the grouped-sorted layout."""
    pk, ok = _as_keys(partition_by), _as_keys(order_by)
    keys = pk + tuple(k for k in ok if k not in pk)
    order = np.lexsort(tuple(np.asarray(cols[k]) for k in reversed(keys)))
    out_cols = {k: np.asarray(v)[order] for k, v in cols.items()}
    n = len(order)
    gk = [out_cols[k] for k in pk]
    okv = [out_cols[k] for k in ok]
    res = np.zeros(n, np.int32)
    i = 0
    while i < n:
        j = i
        while j < n and all(k[j] == k[i] for k in gk):
            j += 1
        r = dense = 0
        for p in range(i, j):
            new_tuple = p == i or any(k[p] != k[p - 1] for k in okv)
            if new_tuple:
                r, dense = p - i + 1, dense + 1
            if kind == "row_number":
                res[p] = p - i + 1
            elif kind == "rank":
                res[p] = r
            else:
                res[p] = dense
        i = j
    out_cols[out] = res
    return out_cols

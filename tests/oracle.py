"""NumPy oracles for the relational operators (pandas-free reference)."""
from __future__ import annotations

import numpy as np


def o_filter(cols: dict, mask: np.ndarray) -> dict:
    return {k: v[mask] for k, v in cols.items()}


def o_join(left: dict, right: dict, lkey: str, rkey: str, suffix="_r") -> dict:
    """Inner equi-join preserving all matches (order-insensitive compare)."""
    li, ri = [], []
    rpos: dict = {}
    for j, k in enumerate(right[rkey]):
        rpos.setdefault(int(k), []).append(j)
    for i, k in enumerate(left[lkey]):
        for j in rpos.get(int(k), ()):
            li.append(i)
            ri.append(j)
    li, ri = np.array(li, np.int64), np.array(ri, np.int64)
    out = {k: v[li] for k, v in left.items()}
    for k, v in right.items():
        if k == rkey:
            continue
        name = k + suffix if k in left else k
        out[name] = v[ri]
    return out


def o_aggregate(cols: dict, key: str, aggs: dict[str, tuple]) -> dict:
    """aggs: name -> (fn, value_array_or_None)."""
    keys = cols[key]
    uids = np.unique(keys)
    out = {key: uids}
    for name, (fn, vals) in aggs.items():
        res = []
        for u in uids:
            m = keys == u
            if fn == "sum":
                res.append(np.sum(vals[m]))
            elif fn == "mean":
                res.append(np.mean(vals[m]))
            elif fn == "count":
                res.append(np.sum(m))
            elif fn == "min":
                res.append(np.min(vals[m]))
            elif fn == "max":
                res.append(np.max(vals[m]))
            elif fn == "var":
                res.append(np.var(vals[m]))
            elif fn == "std":
                res.append(np.std(vals[m]))
            elif fn == "nunique":
                res.append(len(np.unique(vals[m])))
            else:
                raise ValueError(fn)
        out[name] = np.array(res)
    return out


def o_cumsum(x: np.ndarray) -> np.ndarray:
    return np.cumsum(x)


def o_stencil(x: np.ndarray, weights, center: int) -> np.ndarray:
    """Zero-padded 1-D stencil matching HiFrames' border convention."""
    k_left = center
    k_right = len(weights) - 1 - center
    ext = np.concatenate([np.zeros(k_left, np.float32),
                          x.astype(np.float32),
                          np.zeros(k_right, np.float32)])
    out = np.zeros(len(x), np.float32)
    for j, w in enumerate(weights):
        out += np.float32(w) * ext[j:j + len(x)]
    return out


def sorted_cols(cols: dict, by: tuple[str, ...]) -> dict:
    order = np.lexsort(tuple(cols[k] for k in reversed(by)))
    return {k: v[order] for k, v in cols.items()}

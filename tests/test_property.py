"""Property-based tests (hypothesis) over the system's invariants.

``hypothesis`` is an OPTIONAL dev dependency: when it is absent the whole
module is skipped at collection time (pytest.importorskip) so tier-1
``pytest -x`` degrades gracefully instead of dying with an ImportError.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro import hiframes as hf
from repro.core import distribution as D
from oracle import o_aggregate, o_filter, o_join, sorted_cols

COMMON = dict(deadline=None, max_examples=20,
              suppress_health_check=[HealthCheck.too_slow])


@st.composite
def tables(draw, max_rows=200, max_keys=12):
    n = draw(st.integers(1, max_rows))
    seed = draw(st.integers(0, 2**31 - 1))
    nk = draw(st.integers(1, max_keys))
    rng = np.random.default_rng(seed)
    return {
        "id": rng.integers(0, nk, n).astype(np.int32),
        "x": rng.normal(size=n).astype(np.float32),
    }


@given(t=tables(), thr=st.floats(-2, 2))
@settings(**COMMON)
def test_filter_matches_oracle(t, thr):
    df = hf.table(t)
    out = df[df["x"] < np.float32(thr)].collect().to_numpy()
    ref = o_filter(t, t["x"] < np.float32(thr))
    np.testing.assert_array_equal(out["id"], ref["id"])
    np.testing.assert_allclose(out["x"], ref["x"])


@given(t=tables())
@settings(**COMMON)
def test_aggregate_matches_oracle(t):
    df = hf.table(t)
    out = hf.aggregate(df, "id", s=hf.sum_(df["x"]), c=hf.count()) \
        .collect().to_numpy()
    ref = o_aggregate(t, "id", {"s": ("sum", t["x"]), "c": ("count", None)})
    o = np.argsort(out["id"])
    np.testing.assert_array_equal(out["id"][o], ref["id"])
    np.testing.assert_allclose(out["s"][o], ref["s"], atol=1e-3)
    np.testing.assert_array_equal(out["c"][o], ref["c"])


@given(l=tables(max_rows=80), r=tables(max_rows=40))
@settings(**COMMON)
def test_join_matches_oracle(l, r):
    r = {"cid": r["id"], "w": r["x"]}
    out = hf.join(hf.table(l), hf.table(r, "r"), on=("id", "cid")) \
        .collect().to_numpy()
    ref = o_join(l, r, "id", "cid")
    assert len(out["id"]) == len(ref["id"])
    if len(ref["id"]):
        a = sorted_cols(out, ("id", "x", "w"))
        b = sorted_cols(ref, ("id", "x", "w"))
        for k in b:
            np.testing.assert_allclose(a[k], b[k], rtol=1e-6)


@given(t=tables())
@settings(**COMMON)
def test_cumsum_matches_oracle(t):
    df = hf.table(t)
    out = hf.cumsum(df, df["x"], out="c").collect().to_numpy()
    np.testing.assert_allclose(out["c"], np.cumsum(t["x"]),
                               atol=1e-3 * max(len(t["x"]), 1))


@given(t=tables(max_rows=100))
@settings(**COMMON)
def test_sort_is_permutation_and_sorted(t):
    out = hf.table(t).sort("x").collect().to_numpy()
    assert np.all(np.diff(out["x"]) >= 0)
    np.testing.assert_allclose(np.sort(out["x"]), np.sort(t["x"]))


@given(t=tables(max_rows=60), seed=st.integers(0, 1000))
@settings(**COMMON)
def test_optimizer_never_changes_results(t, seed):
    """Invariant: plan rewrites preserve semantics on join+filter pipelines."""
    rng = np.random.default_rng(seed)
    dim = {"cid": np.arange(12, dtype=np.int32),
           "w": rng.normal(size=12).astype(np.float32)}
    j = hf.join(hf.table(t), hf.table(dim, "d"), on=("id", "cid"))
    f = j[j["w"] > 0.0]
    a = f.collect(hf.ExecConfig(optimize_plan=True)).to_numpy()
    b = f.collect(hf.ExecConfig(optimize_plan=False)).to_numpy()
    assert len(a["id"]) == len(b["id"])
    if len(a["id"]):
        sa = sorted_cols(a, ("id", "x", "w"))
        sb = sorted_cols(b, ("id", "x", "w"))
        for k in sa:
            np.testing.assert_allclose(sa[k], sb[k], rtol=1e-6)


@given(st.lists(st.sampled_from([D.ONE_D, D.ONE_D_VAR, D.TWO_D, D.REP]),
                min_size=1, max_size=6))
@settings(deadline=None, max_examples=50)
def test_meet_chain_is_order_independent(chain):
    import functools, itertools
    ref = functools.reduce(D.meet, chain)
    for perm in itertools.islice(itertools.permutations(chain), 24):
        assert functools.reduce(D.meet, perm) == ref


@given(t=tables(max_rows=100))
@settings(**COMMON)
def test_counts_conserved_by_shuffle_ops(t):
    """Row conservation: aggregate counts sum to input rows."""
    df = hf.table(t)
    out = hf.aggregate(df, "id", c=hf.count()).collect().to_numpy()
    assert out["c"].sum() == len(t["id"])

"""Map-side partial aggregation (shuffle engine v2, PR 4).

The planner splits a shuffling aggregate with decomposable agg fns into
PartialAgg -> HashExchange -> LocalSort -> SegmentAgg(combine), so each shard
ships at most its DISTINCT local key groups.  These tests cover the combine
algebra for every decomposable fn, heavy key skew in both directions (all
rows one group / all rows distinct), the pre-partitioned skip rule, the
agg_group_cap capacity lever, and the nunique aux-sort elision satellite.
"""
import os
import sys

import jax
import numpy as np
import pytest

from repro import hiframes as hf
from repro.core import physical_plan as pp
from oracle import o_aggregate

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from test_physical_plan import run_sharded  # noqa: E402
from test_packed_exchange import _count_prim  # noqa: E402


def _table(n=500, n_keys=9, seed=21):
    rng = np.random.default_rng(seed)
    return {"k": rng.integers(0, n_keys, n).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32),
            "y": rng.integers(0, 50, n).astype(np.int32)}


def _check_against_oracle(t, out, aggs, atol=1e-2):
    ref = o_aggregate(t, "k", aggs)
    order = np.argsort(out["k"][: len(ref["k"])])
    assert len(out["k"]) == len(ref["k"])
    np.testing.assert_array_equal(out["k"][order], ref["k"])
    for name in aggs:
        np.testing.assert_allclose(out[name][order], ref[name], atol=atol,
                                   err_msg=name)


# -- plan shapes ---------------------------------------------------------------


def test_partial_agg_plan_shape():
    t = _table()
    df = hf.table(t)
    a = hf.aggregate(df, "k", s=hf.sum_(df["x"]), c=hf.count())
    plan = a.physical_plan()
    kinds = [type(op).__name__ for op in plan.ops]
    i_p, i_e = kinds.index("PartialAgg"), kinds.index("HashExchange")
    i_f = kinds.index("SegmentAgg")
    assert i_p < i_e < i_f, plan.render()
    final = [op for op in plan.ops if isinstance(op, pp.SegmentAgg)][0]
    assert final.from_partials
    # partial rows ship decomposed __p_* statistics, not raw values
    ex = [op for op in plan.ops if isinstance(op, pp.HashExchange)][0]
    assert any(c.startswith("__p_") for c in ex.schema), ex.schema


def test_prepartitioned_input_skips_partial_stage():
    """join -> aggregate(join keys): the exchange is elided, so the partial
    stage must be skipped entirely (the rewrite composes with elision rather
    than stacking a useless pre-aggregation)."""
    rng = np.random.default_rng(5)
    n, m = 400, 60
    left = {"k": rng.integers(0, 7, n).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32)}
    right = {"k": rng.integers(0, 7, m).astype(np.int32),
             "w": rng.normal(size=m).astype(np.float32)}
    j = hf.join(hf.table(left), hf.table(right, "d"), on="k")
    a = hf.aggregate(j, "k", s=hf.sum_(j["w"]))
    c = a.physical_plan().counts()
    assert c["partial_aggs"] == 0
    assert c["hash_exchanges"] == 2          # just the join's
    # REP aggregates skip it too (no exchange at all)
    rep = hf.table(left).replicate()
    ar = hf.aggregate(rep, "k", s=hf.sum_(rep["x"]))
    cr = ar.physical_plan().counts()
    assert cr["partial_aggs"] == 0 and cr["hash_exchanges"] == 0


def test_non_decomposable_aggs_stay_on_raw_path():
    t = _table()
    df = hf.table(t)
    for agg in (dict(nu=hf.nunique(df["y"])), dict(f=hf.first(df["x"]))):
        a = hf.aggregate(df, "k", **agg)
        c = a.physical_plan().counts()
        assert c["partial_aggs"] == 0, agg
    # mixing one non-decomposable fn disables the rewrite for the whole node
    a = hf.aggregate(df, "k", s=hf.sum_(df["x"]), nu=hf.nunique(df["y"]))
    assert a.physical_plan().counts()["partial_aggs"] == 0


# -- correctness: every decomposable fn, P=1 -----------------------------------


def test_all_decomposable_fns_match_oracle():
    t = _table()
    df = hf.table(t)
    a = hf.aggregate(df, "k",
                     s=hf.sum_(df["x"]), c=hf.count(), m=hf.mean(df["x"]),
                     mn=hf.min_(df["x"]), mx=hf.max_(df["x"]),
                     v=hf.var(df["x"]), sd=hf.std(df["x"]))
    assert a.physical_plan().counts()["partial_aggs"] == 1
    out = a.collect().to_numpy()
    _check_against_oracle(t, out, {
        "s": ("sum", t["x"]), "c": ("count", t["x"]), "m": ("mean", t["x"]),
        "mn": ("min", t["x"]), "mx": ("max", t["x"]),
        "v": ("var", t["x"]), "sd": ("std", t["x"])})


def test_partial_matches_raw_path_exactly_for_ints():
    """Integer sums/counts/min/max are exact: partial and raw paths agree
    bit-for-bit."""
    t = _table()
    df = hf.table(t)
    a = hf.aggregate(df, "k", s=hf.sum_(df["y"]), c=hf.count(),
                     mn=hf.min_(df["y"]), mx=hf.max_(df["y"]))
    on = a.collect(hf.ExecConfig()).to_numpy()
    off = a.collect(hf.ExecConfig(partial_agg=False)).to_numpy()
    for k in on:
        np.testing.assert_array_equal(on[k], off[k], err_msg=k)


# -- key skew on 1/2/8 shards --------------------------------------------------


_SKEW_BODY = """
    rng = np.random.default_rng(31)
    n = 640
    from oracle import o_aggregate

    def check(t):
        df = hf.table(t)
        a = hf.aggregate(df, "k", s=hf.sum_(df["x"]), c=hf.count(),
                         m=hf.mean(df["x"]), v=hf.var(df["x"]))
        out = a.collect().to_numpy()
        ref = o_aggregate(t, "k", {"s": ("sum", t["x"]),
                                   "c": ("count", t["x"]),
                                   "m": ("mean", t["x"]),
                                   "v": ("var", t["x"])})
        ngroups = len(ref["k"])
        assert len(out["k"]) == ngroups, (len(out["k"]), ngroups)
        order = np.argsort(out["k"])
        np.testing.assert_array_equal(out["k"][order], ref["k"])
        np.testing.assert_allclose(out["s"][order], ref["s"], atol=1e-2)
        np.testing.assert_array_equal(out["c"][order], ref["c"])
        np.testing.assert_allclose(out["m"][order], ref["m"], atol=1e-3)
        np.testing.assert_allclose(out["v"][order], ref["v"], atol=1e-2)

    # all rows ONE group: the partial stage collapses each shard to 1 row
    check({"k": np.zeros(n, np.int32),
           "x": rng.normal(size=n).astype(np.float32)})
    # all rows DISTINCT groups: partial aggregation is a no-op pass-through
    check({"k": rng.permutation(n).astype(np.int32),
           "x": rng.normal(size=n).astype(np.float32)})
    # zipf-ish skew between the extremes
    check({"k": (rng.zipf(1.5, n) % 13).astype(np.int32),
           "x": rng.normal(size=n).astype(np.float32)})
"""


@pytest.mark.parametrize("devices", [1, 2, 8])
def test_partial_agg_under_skew(devices):
    run_sharded(_SKEW_BODY, devices=devices)


# -- agg_group_cap: the capacity lever -----------------------------------------


def test_agg_group_cap_shrinks_exchange_buffers():
    """With a distinct-groups bound, the post-partial exchange bucket (and
    its census byte estimate) shrink; results stay correct because at most
    `groups` rows survive the partial stage per shard."""
    t = _table(n=800, n_keys=6)
    df = hf.table(t)
    a = hf.aggregate(df, "k", s=hf.sum_(df["x"]), c=hf.count())
    free = a.physical_plan(hf.ExecConfig())
    capped = a.physical_plan(hf.ExecConfig(agg_group_cap=16))
    assert capped.shuffle_census(P=8)["payload_bytes"] < \
        free.shuffle_census(P=8)["payload_bytes"]
    # low-cardinality keys: 6 distinct groups fit the bound with NO retry —
    # the proof that at most distinct-groups rows crossed the wire per shard
    cfg = hf.ExecConfig(agg_group_cap=16, auto_retry=0)
    out = a.collect(cfg)
    assert not out.overflow
    _check_against_oracle(t, out.to_numpy(),
                          {"s": ("sum", t["x"]), "c": ("count", t["x"])})


def test_agg_group_cap_overflow_retries():
    """A too-tight bound flags overflow; collect()'s retry loop doubles
    agg_group_cap until the partial rows fit."""
    t = _table(n=400, n_keys=64)
    df = hf.table(t)
    a = hf.aggregate(df, "k", c=hf.count())
    tight = hf.ExecConfig(agg_group_cap=4, auto_retry=0)
    assert a.collect(tight).overflow
    healed = a.collect(hf.ExecConfig(agg_group_cap=4, auto_retry=6))
    assert not healed.overflow
    _check_against_oracle(t, healed.to_numpy(), {"c": ("count", t["x"])})


def test_agg_group_cap_multi_device():
    run_sharded("""
        rng = np.random.default_rng(41)
        n = 800
        t = {"k": rng.integers(0, 6, n).astype(np.int32),
             "x": rng.normal(size=n).astype(np.float32)}
        df = hf.table(t)
        a = hf.aggregate(df, "k", s=hf.sum_(df["x"]), c=hf.count())
        out = a.collect(hf.ExecConfig(agg_group_cap=8, auto_retry=0))
        assert not out.overflow
        o = out.to_numpy()
        uids = np.unique(t["k"])
        order = np.argsort(o["k"])
        np.testing.assert_array_equal(o["k"][order], uids)
        np.testing.assert_allclose(
            o["s"][order], [t["x"][t["k"] == u].sum() for u in uids],
            atol=1e-2)
    """, devices=8)


# -- nunique aux-sort elision (satellite) --------------------------------------


def _count_sorts(lowered) -> int:
    fn, inputs = lowered._prepare()
    jaxpr = jax.make_jaxpr(lambda s, e: fn(s, e))(inputs["scans"],
                                                  inputs["ext"])
    return _count_prim(jaxpr, "sort")


def test_nunique_rides_planner_sort():
    """When the planner inserts the aggregate's LocalSort anyway, the FIRST
    nunique column rides it as a trailing key: one lax.sort fewer in the
    traced program, same results."""
    t = _table()
    df = hf.table(t)
    a1 = hf.aggregate(df, "k", nu=hf.nunique(df["y"]))
    plan = a1.physical_plan()
    seg = [op for op in plan.ops if isinstance(op, pp.SegmentAgg)][0]
    assert seg.nunique_ride == "nu", plan.render()
    ls = [op for op in plan.ops if isinstance(op, pp.LocalSort)][0]
    assert ls.keys == ("k", "__v_nu"), plan.render()
    # RELATIVE sort-primitive counts (the exchange itself contributes an
    # argsort at P>1, so absolute counts are device-dependent): a second
    # nunique pays its own aux sort — exactly ONE more than the riding plan.
    s1 = _count_sorts(a1.lower())
    a2 = hf.aggregate(df, "k", nu=hf.nunique(df["y"]),
                      nx=hf.nunique(df["x"]))
    seg2 = [op for op in a2.physical_plan().ops
            if isinstance(op, pp.SegmentAgg)][0]
    assert seg2.nunique_ride == "nu"
    assert _count_sorts(a2.lower()) == s1 + 1
    # adding `first` disables the ride: the SAME single nunique now costs
    # its aux sort again (one more sort than the riding plan)
    anf = hf.aggregate(df, "k", nu=hf.nunique(df["y"]), f=hf.first(df["x"]))
    assert _count_sorts(anf.lower()) == s1 + 1
    if jax.device_count() == 1:
        # single shard: the exchange is a compact (no argsort), so the
        # riding plan's ONLY sort is the LocalSort itself
        assert s1 == 1


def test_nunique_ride_disabled_by_first():
    """`first` reads in-group arrival order; a trailing value sort key would
    scramble it, so the ride is disabled when first is present."""
    t = _table()
    df = hf.table(t)
    a = hf.aggregate(df, "k", nu=hf.nunique(df["y"]), f=hf.first(df["x"]))
    seg = [op for op in a.physical_plan().ops
           if isinstance(op, pp.SegmentAgg)][0]
    assert seg.nunique_ride is None
    ls = [op for op in a.physical_plan().ops
          if isinstance(op, pp.LocalSort)][0]
    assert ls.keys == ("k",)


def test_nunique_ride_correctness():
    t = _table(n=700, n_keys=8, seed=33)
    df = hf.table(t)
    a = hf.aggregate(df, "k", nu=hf.nunique(df["y"]), c=hf.count(),
                     s=hf.sum_(df["x"]))
    out = a.collect().to_numpy()
    _check_against_oracle(t, out, {"nu": ("nunique", t["y"]),
                                   "c": ("count", t["y"]),
                                   "s": ("sum", t["x"])})
    run_sharded("""
        from oracle import o_aggregate
        rng = np.random.default_rng(34)
        n = 700
        t = {"k": rng.integers(0, 8, n).astype(np.int32),
             "y": rng.integers(0, 30, n).astype(np.int32)}
        df = hf.table(t)
        a = hf.aggregate(df, "k", nu=hf.nunique(df["y"]), c=hf.count())
        out = a.collect().to_numpy()
        ref = o_aggregate(t, "k", {"nu": ("nunique", t["y"]),
                                   "c": ("count", t["y"])})
        order = np.argsort(out["k"])
        np.testing.assert_array_equal(out["k"][order], ref["k"])
        np.testing.assert_array_equal(out["nu"][order], ref["nu"])
        np.testing.assert_array_equal(out["c"][order], ref["c"])
    """, devices=8)

"""Shuffle-census regression gate for the canonical pipelines.

The physical planner is deterministic and device-free, so these tests pin
the EXACT number of hash exchanges / local sorts / sample sorts / rebalances
each canonical pipeline plans — both through ``physical_plan().counts()``
and through the ``explain()`` header the CI logs show.  An optimizer or
planner regression that silently re-introduces a shuffle fails here loudly
instead of shipping a slow plan.  Run explicitly in CI as its own step.
"""
import numpy as np

from repro import hiframes as hf
from repro.core import physical_plan as pp


def _frames(n=400, m=60, seed=3):
    rng = np.random.default_rng(seed)
    left = {"k1": rng.integers(0, 7, n).astype(np.int32),
            "k2": rng.integers(0, 9, n).astype(np.int32),
            "t": rng.permutation(n).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32)}
    right = {"ca": rng.integers(0, 7, m).astype(np.int32),
             "cb": rng.integers(0, 9, m).astype(np.int32),
             "w": rng.normal(size=m).astype(np.float32)}
    return left, right


def _census(df, cfg=None, **expect):
    """Assert exact counts and that the explain() header agrees."""
    plan = df.physical_plan(cfg or hf.ExecConfig())
    c = plan.counts()
    for k, v in expect.items():
        assert c[k] == v, f"{k}: planned {c[k]}, census expects {v}\n{plan.render()}"
    header = df.explain(cfg).split("\n\n")[1].splitlines()[0]
    assert f"physical plan: {plan.shuffle_count()} shuffles" in header
    return plan


def test_census_join_then_aggregate_same_keys():
    left, right = _frames()
    j = hf.join(hf.table(left), hf.table(right, "d"),
                on=[("k1", "ca"), ("k2", "cb")])
    a = hf.aggregate(j, by=("k1", "k2"), s=hf.sum_(j["w"]), c=hf.count())
    _census(a, hash_exchanges=2, local_sorts=1, sample_sorts=0, rebalances=0)


def test_census_join_then_aggregate_baseline():
    left, right = _frames()
    j = hf.join(hf.table(left), hf.table(right, "d"),
                on=[("k1", "ca"), ("k2", "cb")])
    a = hf.aggregate(j, by=("k1", "k2"), c=hf.count())
    # full pre-PR2 baseline: elision AND partial aggregation both off
    _census(a, hf.ExecConfig(elide_exchanges=False, partial_agg=False),
            hash_exchanges=3, local_sorts=1, partial_aggs=0)
    # with elision off but partial agg on, the surviving aggregate exchange
    # splits into PartialAgg -> exchange -> FinalAgg (one extra local sort)
    _census(a, hf.ExecConfig(elide_exchanges=False),
            hash_exchanges=3, local_sorts=2, partial_aggs=1)


def test_census_broadcast_join():
    left, right = _frames()
    j = hf.join(hf.table(left), hf.table(right, "d").replicate(),
                on=[("k1", "ca"), ("k2", "cb")])
    _census(j, hash_exchanges=0, local_sorts=0, sample_sorts=0, rebalances=0)


def test_census_sort_then_aggregate_same_key():
    left, _ = _frames()
    a = hf.aggregate(hf.table(left).sort(by=("k1", "k2")), by=("k1", "k2"),
                     c=hf.count())
    _census(a, hf.ExecConfig(optimize_plan=False),
            hash_exchanges=0, local_sorts=0, sample_sorts=1)


def test_census_join_then_window_over_join_keys():
    """The PR 3 acceptance shape: join -> wma OVER the join keys plans the
    SAME number of hash exchanges as the bare join — the window adds zero
    shuffles, only the grouped local sort."""
    left, right = _frames()
    l, r = hf.table(left), hf.table(right, "d")
    bare = hf.join(l, r, on=[("k1", "ca"), ("k2", "cb")])
    bare_hash = bare.physical_plan().counts()["hash_exchanges"]
    win = hf.wma(bare, bare["x"] * bare["w"], [1, 2, 1], out="v",
                 partition_by=("k1", "k2"), order_by="t")
    plan = _census(win, hash_exchanges=bare_hash, local_sorts=1,
                   sample_sorts=0, rebalances=0)
    assert bare_hash == 2
    # the same pipeline without elision pays the window's own exchange
    base = win.physical_plan(hf.ExecConfig(elide_exchanges=False)).counts()
    assert base["hash_exchanges"] == 3
    assert any(isinstance(op, pp.WindowOp) for op in plan.ops)


def test_census_aggregate_then_window_same_key():
    """aggregate -> window over the aggregate key reuses the grouped layout:
    no extra exchange AND no extra sort.  The aggregate itself (a bare scan
    input, so its exchange survives) takes the partial-agg path: a local
    pre-sort, the exchange of partial rows, and the combine-side sort."""
    left, _ = _frames()
    df = hf.table(left)
    a = hf.aggregate(df, "k1", s=hf.sum_(df["x"]))
    w = hf.cumsum(a, a["s"], out="cs", partition_by="k1")
    _census(w, hash_exchanges=1, local_sorts=2, partial_aggs=1)
    # partial agg off: the historical 1-exchange 1-sort plan
    _census(w, hf.ExecConfig(partial_agg=False),
            hash_exchanges=1, local_sorts=1, partial_aggs=0)


def test_census_partitioned_window_on_scan():
    """A bare scan provides nothing: the window pays one exchange + one sort
    (and nothing more)."""
    left, _ = _frames()
    df = hf.table(left)
    w = df.over("k1", order_by="t").cumsum(df["x"], out="c")
    _census(w, hash_exchanges=1, local_sorts=1, sample_sorts=0, rebalances=0)


def test_census_rebalance_preserves_global_order():
    """ROADMAP follow-ups (PR 3 + PR 4): range-partitioned + locally-sorted
    inputs stay globally sorted through Rebalance, and the rebalanced stream
    now carries the ``globally_sorted`` block-partitioning flag — so the
    re-sort after a global stencil plans a FULL no-op (no splitter routing,
    no exchange), not just a pre_sorted sample sort."""
    left, _ = _frames()
    cfg = hf.ExecConfig(optimize_plan=False)
    s = hf.table(left).sort("t")
    st = hf.sma(s, s["x"], 3, out="m")
    again = st.sort("t")
    plan = _census(again, cfg, sample_sorts=1, rebalances=1, hash_exchanges=0)
    reb = [op for op in plan.ops if isinstance(op, pp.RebalanceOp)]
    assert reb and reb[0].order.keys == ("t",), plan.render()
    assert reb[0].part.kind == "block" and reb[0].part.globally_sorted, \
        plan.render()
    # the downstream Sort planned NOTHING: the root op is the stencil window
    # itself, still carrying the globally-sorted block partitioning through.
    assert isinstance(plan.root_op, pp.WindowOp), plan.render()
    assert plan.root_op.part.globally_sorted, plan.render()
    # the conservative baseline (elision off) drops the ordering again and
    # pays the second sample sort
    plan_off = again.physical_plan(hf.ExecConfig(optimize_plan=False,
                                                 elide_exchanges=False))
    reb_off = [op for op in plan_off.ops if isinstance(op, pp.RebalanceOp)]
    assert reb_off and reb_off[0].order.keys == ()
    assert not reb_off[0].part.globally_sorted
    assert plan_off.counts()["sample_sorts"] == 2


def test_census_rebalanced_sorted_stream_chains():
    """The globally_sorted flag survives a second Rebalance and a filter:
    sort -> stencil -> rebalance -> sort(prefix) stays a no-op even when the
    second sort asks for the SAME key prefix through a filter."""
    left, _ = _frames(seed=9)
    cfg = hf.ExecConfig(optimize_plan=False)
    s = hf.table(left).sort(by=("t", "k1"))
    st = hf.sma(s, s["x"], 3, out="m")
    f = st[st["x"] < 10.0]              # keeps every row; preserves order
    again = f.sort("t")                 # prefix of the preserved ordering
    plan = _census(again, cfg, sample_sorts=1, rebalances=1, hash_exchanges=0)
    out = again.collect(cfg).to_numpy()
    assert np.array_equal(out["t"], np.sort(left["t"]))


def test_descending_range_never_satisfies_ascending_sort():
    """Regression (direction-blind range partitioning): a descending sample
    sort leaves descending shard ranges; a planner-inserted ascending
    LocalSort (partitioned window) must NOT let a later ascending Sort
    become a no-op — the data is locally but not globally ascending."""
    left, _ = _frames(seed=6)
    cfg = hf.ExecConfig(optimize_plan=False)
    d = hf.table(left).sort("k1", ascending=False)
    w = d.over("k1", order_by="t").cumsum(d["x"], out="c")
    again = w.sort(by=("k1", "t"))
    plan = again.physical_plan(cfg)
    # descending sample sort + the final ascending sort both plan
    assert plan.counts()["sample_sorts"] == 2, plan.render()
    out = again.collect(cfg).to_numpy()
    assert np.array_equal(out["k1"], np.sort(left["k1"]))
    run_sharded_desc = """
        rng = np.random.default_rng(6)
        n = 400
        left = {"k1": rng.integers(0, 7, n).astype(np.int32),
                "t": rng.permutation(n).astype(np.int32),
                "x": rng.normal(size=n).astype(np.float32)}
        cfg = hf.ExecConfig(optimize_plan=False)
        d = hf.table(left).sort("k1", ascending=False)
        w = d.over("k1", order_by="t").cumsum(d["x"], out="c")
        out = w.sort(by=("k1", "t")).collect(cfg).to_numpy()
        assert np.array_equal(out["k1"], np.sort(left["k1"])), out["k1"]
    """
    from test_physical_plan import run_sharded
    run_sharded(run_sharded_desc, devices=8)


def test_global_order_by_without_partition_raises():
    """SQL SUM() OVER (ORDER BY t) with no PARTITION BY is not silently
    computed in arrival order — it is rejected with guidance to sort."""
    left, _ = _frames()
    df = hf.table(left)
    import pytest
    with pytest.raises(ValueError, match="sort"):
        hf.cumsum(df, df["x"], order_by="t")
    with pytest.raises(ValueError, match="sort"):
        hf.wma(df, df["x"], [1, 2, 1], order_by="t")


def test_census_collectives_and_bytes_join_agg():
    """PR 4 gate: the census now pins COLLECTIVES ISSUED and SHUFFLED-BYTE
    estimates, not just exchange counts.  join -> aggregate(join keys) at a
    fixed P=8: two packed exchanges cost exactly 2 all_to_all each; the
    per-column baseline pays 1 + n_columns per exchange over identical
    payload bytes."""
    left, right = _frames()
    j = hf.join(hf.table(left), hf.table(right, "d"),
                on=[("k1", "ca"), ("k2", "cb")])
    a = hf.aggregate(j, by=("k1", "k2"), s=hf.sum_(j["w"]), c=hf.count())
    packed = a.physical_plan().shuffle_census(P=8)
    assert packed["packed"] and packed["all_to_all"] == 4, packed
    # left ships (k1,k2)=8B/row, right (ca,cb,w)=12B/row after pruning
    rows = {e["op"]: e for e in packed["exchanges"]}
    assert rows["HashExchange(k1,k2)"]["row_bytes"] == 8
    assert rows["HashExchange(ca,cb)"]["row_bytes"] == 12
    assert packed["payload_bytes"] == 3968          # 8*50*8 + 8*8*12
    unpacked = a.physical_plan(
        hf.ExecConfig(packed_exchange=False)).shuffle_census(P=8)
    assert unpacked["all_to_all"] == 7              # (1+2) + (1+3)
    assert unpacked["payload_bytes"] == packed["payload_bytes"]
    # render() surfaces the same census in the explain() header
    header = a.explain().split("\n\n")[1].splitlines()[0]
    assert "4 all_to_all (packed)" in header, header
    assert "B/row shuffled" in header, header


def test_census_wide_table_two_collectives_per_exchange():
    """Acceptance shape at the PLAN level: shuffling a >=8-column table is
    exactly 2 collectives packed vs 1 + n_columns per column unpacked (the
    jaxpr-level cross-check lives in test_packed_exchange.py)."""
    rng = np.random.default_rng(8)
    n = 300
    t = {f"c{i}": rng.normal(size=n).astype(np.float32) for i in range(8)}
    t["k"] = rng.integers(0, 5, n).astype(np.int32)
    df = hf.table(t)
    agg = {f"s{i}": hf.sum_(df[f"c{i}"]) for i in range(8)}
    a = hf.aggregate(df, "k", **agg)
    cfg = hf.ExecConfig(partial_agg=False)      # one 9-column exchange
    plan = a.physical_plan(cfg)
    ex = [op for op in plan.ops if isinstance(op, pp.HashExchange)]
    assert len(ex) == 1 and len(ex[0].schema) == 9, plan.render()
    assert plan.op_collectives(ex[0]) == 2
    off = a.physical_plan(hf.ExecConfig(partial_agg=False,
                                        packed_exchange=False))
    assert off.collective_count() == 10             # counts + 9 columns


def test_census_partial_agg_shrinks_wire_volume():
    """The partial-agg + agg_group_cap pair shrinks the post-partial
    exchange's byte estimate (bucket follows the distinct-group bound)."""
    left, _ = _frames()
    df = hf.table(left)
    a = hf.aggregate(df, "k1", s=hf.sum_(df["x"]), c=hf.count())
    free = a.physical_plan().shuffle_census(P=8)
    capped = a.physical_plan(hf.ExecConfig(agg_group_cap=8)).shuffle_census(P=8)
    assert capped["payload_bytes"] < free["payload_bytes"], (capped, free)
    assert capped["all_to_all"] == free["all_to_all"] == 2
    # the exchange ships decomposed partial statistics, not raw rows
    ex = [op for op in a.physical_plan().ops
          if isinstance(op, pp.HashExchange)][0]
    assert any(c.startswith("__p_") for c in ex.schema)


def test_census_string_keys_identical_to_int_keys():
    """PR 8 gate: a string-key join -> aggregate pipeline plans the SAME
    census as the int-key pipeline of identical shape — exchange/sort
    counts, collectives issued, AND per-row packed bytes (dictionary codes
    are one int32 word, docs/dtypes.md)."""
    rng = np.random.default_rng(12)
    n, m = 400, 26
    codes = rng.integers(0, m, n)
    x = rng.normal(size=n).astype(np.float32)
    w = rng.normal(size=m).astype(np.float32)
    strs = np.array([chr(ord("a") + c) for c in codes], dtype=object)
    sdim = np.array([chr(ord("a") + i) for i in range(m)], dtype=object)

    def pipeline(keys, dimkeys):
        fact = hf.table({"k": keys, "x": x})
        dim = hf.table({"k": dimkeys, "w": w}, "d")
        return fact.merge(dim, on="k").groupby("k").agg(
            s=("x", "sum"), mw=("w", "mean"), c="count")

    qi = pipeline(codes.astype(np.int32), np.arange(m, dtype=np.int32))
    qs = pipeline(strs, sdim)
    pi, ps = qi.physical_plan(), qs.physical_plan()
    assert pi.counts() == ps.counts()
    assert pi.shuffle_census(P=8) == ps.shuffle_census(P=8)
    hi = qi.explain().split("\n\n")[1].splitlines()[0]
    hs = qs.explain().split("\n\n")[1].splitlines()[0]
    assert hi == hs and "B/row shuffled" in hs


def test_census_nullable_values_plan_like_clean_values():
    """skipna aggregation is census-free: a NULLABLE float value column
    decomposes to the same partial columns, wire dtypes and byte counts as
    a clean one (count partials ride the existing count slot)."""
    rng = np.random.default_rng(13)
    n = 400
    k = rng.integers(0, 9, n).astype(np.int32)
    clean = rng.normal(size=n).astype(np.float32)
    holed = clean.copy()
    holed[rng.random(n) < 0.2] = np.nan

    def agg(x):
        df = hf.table({"k": k, "x": x})
        return df.groupby("k").agg(s=("x", "sum"), m=("x", "mean"),
                                   mn=("x", "min"))

    pc = agg(clean).physical_plan()
    pn = agg(holed).physical_plan()
    assert pc.counts() == pn.counts()
    assert pc.shuffle_census(P=8) == pn.shuffle_census(P=8)
    assert pc.counts()["partial_aggs"] == 1     # both ride the partial path


def test_census_rebalance_result_still_sorted():
    """Execution cross-check for the rebalance-ordering fix."""
    left, _ = _frames(seed=5)
    cfg = hf.ExecConfig(optimize_plan=False)
    s = hf.table(left).sort("t")
    res = hf.sma(s, s["x"], 3, out="m").sort("t").collect(cfg).to_numpy()
    assert np.array_equal(res["t"], np.sort(left["t"]))
    ref = np.convolve(left["x"][np.argsort(left["t"])],
                      np.ones(3, np.float32) / 3, mode="same")
    np.testing.assert_allclose(res["m"], ref, atol=1e-3)

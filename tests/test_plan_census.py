"""Shuffle-census regression gate for the canonical pipelines.

The physical planner is deterministic and device-free, so these tests pin
the EXACT number of hash exchanges / local sorts / sample sorts / rebalances
each canonical pipeline plans — both through ``physical_plan().counts()``
and through the ``explain()`` header the CI logs show.  An optimizer or
planner regression that silently re-introduces a shuffle fails here loudly
instead of shipping a slow plan.  Run explicitly in CI as its own step.
"""
import numpy as np

from repro import hiframes as hf
from repro.core import physical_plan as pp


def _frames(n=400, m=60, seed=3):
    rng = np.random.default_rng(seed)
    left = {"k1": rng.integers(0, 7, n).astype(np.int32),
            "k2": rng.integers(0, 9, n).astype(np.int32),
            "t": rng.permutation(n).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32)}
    right = {"ca": rng.integers(0, 7, m).astype(np.int32),
             "cb": rng.integers(0, 9, m).astype(np.int32),
             "w": rng.normal(size=m).astype(np.float32)}
    return left, right


def _census(df, cfg=None, **expect):
    """Assert exact counts and that the explain() header agrees."""
    plan = df.physical_plan(cfg or hf.ExecConfig())
    c = plan.counts()
    for k, v in expect.items():
        assert c[k] == v, f"{k}: planned {c[k]}, census expects {v}\n{plan.render()}"
    header = df.explain(cfg).split("\n\n")[1].splitlines()[0]
    assert f"physical plan: {plan.shuffle_count()} shuffles" in header
    return plan


def test_census_join_then_aggregate_same_keys():
    left, right = _frames()
    j = hf.join(hf.table(left), hf.table(right, "d"),
                on=[("k1", "ca"), ("k2", "cb")])
    a = hf.aggregate(j, by=("k1", "k2"), s=hf.sum_(j["w"]), c=hf.count())
    _census(a, hash_exchanges=2, local_sorts=1, sample_sorts=0, rebalances=0)


def test_census_join_then_aggregate_baseline():
    left, right = _frames()
    j = hf.join(hf.table(left), hf.table(right, "d"),
                on=[("k1", "ca"), ("k2", "cb")])
    a = hf.aggregate(j, by=("k1", "k2"), c=hf.count())
    _census(a, hf.ExecConfig(elide_exchanges=False),
            hash_exchanges=3, local_sorts=1)


def test_census_broadcast_join():
    left, right = _frames()
    j = hf.join(hf.table(left), hf.table(right, "d").replicate(),
                on=[("k1", "ca"), ("k2", "cb")])
    _census(j, hash_exchanges=0, local_sorts=0, sample_sorts=0, rebalances=0)


def test_census_sort_then_aggregate_same_key():
    left, _ = _frames()
    a = hf.aggregate(hf.table(left).sort(by=("k1", "k2")), by=("k1", "k2"),
                     c=hf.count())
    _census(a, hf.ExecConfig(optimize_plan=False),
            hash_exchanges=0, local_sorts=0, sample_sorts=1)


def test_census_join_then_window_over_join_keys():
    """The PR 3 acceptance shape: join -> wma OVER the join keys plans the
    SAME number of hash exchanges as the bare join — the window adds zero
    shuffles, only the grouped local sort."""
    left, right = _frames()
    l, r = hf.table(left), hf.table(right, "d")
    bare = hf.join(l, r, on=[("k1", "ca"), ("k2", "cb")])
    bare_hash = bare.physical_plan().counts()["hash_exchanges"]
    win = hf.wma(bare, bare["x"] * bare["w"], [1, 2, 1], out="v",
                 partition_by=("k1", "k2"), order_by="t")
    plan = _census(win, hash_exchanges=bare_hash, local_sorts=1,
                   sample_sorts=0, rebalances=0)
    assert bare_hash == 2
    # the same pipeline without elision pays the window's own exchange
    base = win.physical_plan(hf.ExecConfig(elide_exchanges=False)).counts()
    assert base["hash_exchanges"] == 3
    assert any(isinstance(op, pp.WindowOp) for op in plan.ops)


def test_census_aggregate_then_window_same_key():
    """aggregate -> window over the aggregate key reuses the grouped layout:
    no extra exchange AND no extra sort."""
    left, _ = _frames()
    df = hf.table(left)
    a = hf.aggregate(df, "k1", s=hf.sum_(df["x"]))
    w = hf.cumsum(a, a["s"], out="cs", partition_by="k1")
    _census(w, hash_exchanges=1, local_sorts=1)


def test_census_partitioned_window_on_scan():
    """A bare scan provides nothing: the window pays one exchange + one sort
    (and nothing more)."""
    left, _ = _frames()
    df = hf.table(left)
    w = df.over("k1", order_by="t").cumsum(df["x"], out="c")
    _census(w, hash_exchanges=1, local_sorts=1, sample_sorts=0, rebalances=0)


def test_census_rebalance_preserves_global_order():
    """ROADMAP follow-up: range-partitioned + locally-sorted inputs stay
    globally sorted through Rebalance — the re-sort after a global stencil
    rides the preserved ordering (SampleSort pre_sorted, no local pre-sort)."""
    left, _ = _frames()
    cfg = hf.ExecConfig(optimize_plan=False)
    s = hf.table(left).sort("t")
    st = hf.sma(s, s["x"], 3, out="m")
    again = st.sort("t")
    plan = _census(again, cfg, sample_sorts=2, rebalances=1, hash_exchanges=0)
    reb = [op for op in plan.ops if isinstance(op, pp.RebalanceOp)]
    assert reb and reb[0].order.keys == ("t",), plan.render()
    final = [op for op in plan.ops if isinstance(op, pp.SampleSort)][-1]
    assert final.pre_sorted, plan.render()
    # the conservative baseline (elision off) drops the ordering again
    plan_off = again.physical_plan(hf.ExecConfig(optimize_plan=False,
                                                 elide_exchanges=False))
    reb_off = [op for op in plan_off.ops if isinstance(op, pp.RebalanceOp)]
    assert reb_off and reb_off[0].order.keys == ()


def test_descending_range_never_satisfies_ascending_sort():
    """Regression (direction-blind range partitioning): a descending sample
    sort leaves descending shard ranges; a planner-inserted ascending
    LocalSort (partitioned window) must NOT let a later ascending Sort
    become a no-op — the data is locally but not globally ascending."""
    left, _ = _frames(seed=6)
    cfg = hf.ExecConfig(optimize_plan=False)
    d = hf.table(left).sort("k1", ascending=False)
    w = d.over("k1", order_by="t").cumsum(d["x"], out="c")
    again = w.sort(by=("k1", "t"))
    plan = again.physical_plan(cfg)
    # descending sample sort + the final ascending sort both plan
    assert plan.counts()["sample_sorts"] == 2, plan.render()
    out = again.collect(cfg).to_numpy()
    assert np.array_equal(out["k1"], np.sort(left["k1"]))
    run_sharded_desc = """
        rng = np.random.default_rng(6)
        n = 400
        left = {"k1": rng.integers(0, 7, n).astype(np.int32),
                "t": rng.permutation(n).astype(np.int32),
                "x": rng.normal(size=n).astype(np.float32)}
        cfg = hf.ExecConfig(optimize_plan=False)
        d = hf.table(left).sort("k1", ascending=False)
        w = d.over("k1", order_by="t").cumsum(d["x"], out="c")
        out = w.sort(by=("k1", "t")).collect(cfg).to_numpy()
        assert np.array_equal(out["k1"], np.sort(left["k1"])), out["k1"]
    """
    from test_physical_plan import run_sharded
    run_sharded(run_sharded_desc, devices=8)


def test_global_order_by_without_partition_raises():
    """SQL SUM() OVER (ORDER BY t) with no PARTITION BY is not silently
    computed in arrival order — it is rejected with guidance to sort."""
    left, _ = _frames()
    df = hf.table(left)
    import pytest
    with pytest.raises(ValueError, match="sort"):
        hf.cumsum(df, df["x"], order_by="t")
    with pytest.raises(ValueError, match="sort"):
        hf.wma(df, df["x"], [1, 2, 1], order_by="t")


def test_census_rebalance_result_still_sorted():
    """Execution cross-check for the rebalance-ordering fix."""
    left, _ = _frames(seed=5)
    cfg = hf.ExecConfig(optimize_plan=False)
    s = hf.table(left).sort("t")
    res = hf.sma(s, s["x"], 3, out="m").sort("t").collect(cfg).to_numpy()
    assert np.array_equal(res["t"], np.sort(left["t"]))
    ref = np.convolve(left["x"][np.argsort(left["t"])],
                      np.ones(3, np.float32) / 3, mode="same")
    np.testing.assert_allclose(res["m"], ref, atol=1e-3)

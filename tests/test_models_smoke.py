"""Per-architecture smoke tests: REDUCED config, one forward + one train step
on CPU, asserting output shapes and no NaNs (full configs are exercised only
via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm, whisper
from repro.optim import OptConfig, adamw


def _batch(cfg, key, B=2, S=16):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["inputs_embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                                   jnp.bfloat16)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
        batch["tokens"] = None
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_frames, cfg.d_model),
                                            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = configs.get_reduced(arch)
    key = jax.random.PRNGKey(0)
    mod = whisper if cfg.family == "encdec" else lm
    params = mod.init_params(cfg, key)
    batch = _batch(cfg, key)

    if cfg.family == "encdec":
        logits, _ = whisper.decode_forward(
            params, batch["tokens"], whisper.encode(params, batch["frames"], cfg), cfg)
    else:
        logits, _, _ = lm.forward(params, batch.get("tokens"), cfg,
                                  positions=batch.get("positions"),
                                  inputs_embeds=batch.get("inputs_embeds"))
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    # one real optimizer step
    ocfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    loss_fn = (whisper.loss_fn if cfg.family == "encdec" else lm.loss_fn)
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, batch, cfg))(params)
    assert np.isfinite(float(loss))
    opt = adamw.init_state(params, ocfg)
    new_params, new_opt, stats = adamw.update(params, grads, opt, ocfg)
    assert np.isfinite(float(stats["grad_norm"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + float(jnp.sum(jnp.abs(b[0].astype(jnp.float32)
                                               - b[1].astype(jnp.float32)))),
        jax.tree.map(lambda x, y: (x, y), new_params, params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "deepseek-moe-16b",
                                  "falcon-mamba-7b", "zamba2-7b",
                                  "whisper-base", "qwen2-vl-2b"])
def test_reduced_decode(arch):
    """Prefill-free decode loop on the reduced config (one per family)."""
    cfg = configs.get_reduced(arch)
    key = jax.random.PRNGKey(1)
    B = 2
    if cfg.family == "encdec":
        params = whisper.init_params(cfg, key)
        frames = jax.random.normal(key, (B, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
        toks = jax.random.randint(key, (B, 4), 0, cfg.vocab)
        lg, cache = whisper.prefill(params, frames, toks, cfg, max_seq=16)
        for _ in range(3):
            tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
            lg, cache = whisper.decode_step(params, tok, cache, cfg)
            assert np.all(np.isfinite(np.asarray(lg, np.float32)))
        return
    params = lm.init_params(cfg, key)
    cache = lm.init_cache(cfg, B, 16)
    tok = jnp.zeros((B, 1), jnp.int32)
    for i in range(3):
        pos = None
        if cfg.mrope:
            pos = jnp.full((3, B, 1), i, jnp.int32)
        lg, cache = lm.decode_step(params, tok, cache, cfg, positions=pos)
        assert lg.shape == (B, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(lg, np.float32)))
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)


def test_full_config_param_counts():
    """Exact-config sanity: totals match the published sizes (DESIGN.md)."""
    expected = {
        "qwen2.5-32b": (31e9, 34e9),
        "yi-34b": (33e9, 36e9),
        "kimi-k2-1t-a32b": (0.95e12, 1.1e12),
        "deepseek-moe-16b": (15e9, 18e9),
        "falcon-mamba-7b": (6.5e9, 7.5e9),
        "zamba2-7b": (6.0e9, 7.5e9),
        "olmo-1b": (1.0e9, 1.4e9),
        "qwen3-0.6b": (0.4e9, 0.8e9),
        "whisper-base": (0.05e9, 0.09e9),
        "qwen2-vl-2b": (1.3e9, 1.8e9),   # backbone only (vision stub)
    }
    for arch, (lo, hi) in expected.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    kimi = configs.get_config("kimi-k2-1t-a32b")
    assert 25e9 <= kimi.active_param_count() <= 40e9
    ds = configs.get_config("deepseek-moe-16b")
    assert 2e9 <= ds.active_param_count() <= 4e9

"""Extended relational features: lag/lead window functions and left-outer
join (the paper's Table 1 lag/lead and its "relaxing inner join is
straightforward" claim, validated)."""
import numpy as np
import pytest

from repro import hiframes as hf


@pytest.fixture(scope="module")
def series():
    rng = np.random.default_rng(13)
    return rng.normal(size=777).astype(np.float32)


@pytest.mark.parametrize("n", [1, 3, 7])
def test_lag(series, n):
    df = hf.table({"x": series})
    out = hf.lag(df, df["x"], n=n, out="l").collect().to_numpy()
    ref = np.concatenate([np.zeros(n, np.float32), series[:-n]])
    np.testing.assert_allclose(out["l"], ref, atol=1e-6)


@pytest.mark.parametrize("n", [1, 2, 5])
def test_lead(series, n):
    df = hf.table({"x": series})
    out = hf.lead(df, df["x"], n=n, out="l").collect().to_numpy()
    ref = np.concatenate([series[n:], np.zeros(n, np.float32)])
    np.testing.assert_allclose(out["l"], ref, atol=1e-6)


def test_lag_lead_expression_input(series):
    """lag of a derived expression (tight array integration)."""
    df = hf.table({"x": series})
    out = hf.lag(df, df["x"] * 2.0, n=1, out="l").collect().to_numpy()
    ref = np.concatenate([[0.0], series[:-1] * 2.0])
    np.testing.assert_allclose(out["l"], ref, atol=1e-5)


def test_wma_via_lag_lead_equivalence(series):
    """WMA == (lag + 2x + lead)/4 — the paper's SQL formulation (Table 1)."""
    df = hf.table({"x": series})
    wma = hf.wma(df, df["x"], [1, 2, 1], out="w").collect().to_numpy()["w"]
    lg = hf.lag(df, df["x"], out="l").collect().to_numpy()["l"]
    ld = hf.lead(df, df["x"], out="l").collect().to_numpy()["l"]
    ref = (lg + 2 * series + ld) / 4.0
    np.testing.assert_allclose(wma, ref, atol=1e-5)


# -- left join ----------------------------------------------------------------


def _tables():
    rng = np.random.default_rng(14)
    left = {"id": rng.integers(0, 30, 400).astype(np.int32),
            "x": rng.normal(size=400).astype(np.float32)}
    # right covers only even keys -> odd-key left rows are unmatched
    right = {"cid": np.arange(0, 30, 2, dtype=np.int32),
             "w": rng.normal(size=15).astype(np.float32)}
    return left, right


def test_left_join_keeps_unmatched():
    left, right = _tables()
    out = hf.join(hf.table(left), hf.table(right, "r"), on=("id", "cid"),
                  how="left").collect().to_numpy()
    assert len(out["id"]) == len(left["id"])          # row-preserving here
    matched = out["_matched"].astype(bool)
    assert np.array_equal(np.sort(out["id"][~matched]),
                          np.sort(left["id"][left["id"] % 2 == 1]))
    assert np.all(np.isnan(out["w"][~matched]))   # NaN-filled NULLs
    # matched rows carry the right value
    wmap = dict(zip(right["cid"].tolist(), right["w"].tolist()))
    for i in range(len(out["id"])):
        if matched[i]:
            assert out["w"][i] == pytest.approx(wmap[int(out["id"][i])])


def test_left_join_duplicates_expand():
    rng = np.random.default_rng(15)
    left = {"id": np.array([0, 1, 2], np.int32),
            "x": np.arange(3, dtype=np.float32)}
    right = {"cid": np.array([0, 0, 0], np.int32),
             "w": np.arange(3, dtype=np.float32)}
    out = hf.join(hf.table(left), hf.table(right, "r"), on=("id", "cid"),
                  how="left").collect().to_numpy()
    # id 0 matches 3 rows; ids 1,2 unmatched once each
    assert len(out["id"]) == 5
    assert np.sum(out["id"] == 0) == 3
    assert np.sum(out["_matched"]) == 3


def test_inner_join_unchanged_by_how_param():
    left, right = _tables()
    a = hf.join(hf.table(left), hf.table(right, "r"), on=("id", "cid")) \
        .collect().to_numpy()
    assert "_matched" not in a
    assert np.all(a["id"] % 2 == 0)
